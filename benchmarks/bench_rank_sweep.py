"""Beyond-paper ablation: TT rank as the compression-vs-cost dial, at
assigned-architecture scale (analytic — runs in milliseconds).

The paper fixes rank 12 for its ATIS model; production deployments must
choose rank per layer family.  For each assigned dense arch this sweep
reports, per rank: parameter compression of the full model, BTT training
FLOPs relative to dense, and the HBM-traffic crossover token count for the
TTM embedding (above which the reconstruct strategy wins — see
core/ttm_embedding.py)."""
from __future__ import annotations

from repro.configs import get_config
from repro.core.cost_model import mul_btt, mul_dense
from repro.core.tt import tt_params_count
from repro.core.tt_linear import make_tt_spec
from repro.core.ttm_embedding import make_ttm_spec, ttm_strategy_crossover

ARCHS = ("qwen3-8b", "llama3-8b", "musicgen-medium")
RANKS = (16, 32, 64, 128)


def _arch_layer_dims(cfg):
    q, kv, d = cfg.attn_dims
    dims = [(q, d), (kv, d), (kv, d), (d, q)]          # attention
    if cfg.d_ff:
        n_mlp = 3 if cfg.mlp_gated else 2
        dims += [(cfg.d_ff, d)] * (n_mlp - 1) + [(d, cfg.d_ff)]
    return dims


def rows():
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        dims = _arch_layer_dims(cfg)
        dense_params = sum(m * n for m, n in dims) * cfg.num_layers
        dense_mul = sum(mul_dense(m, n, 4096) for m, n in dims)
        for rank in RANKS:
            tt_params = sum(
                tt_params_count(make_tt_spec(m, n, 3, rank)) for m, n in dims
            ) * cfg.num_layers
            tt_mul = sum(
                mul_btt(make_tt_spec(m, n, 3, rank), 4096) for m, n in dims)
            espec = make_ttm_spec(cfg.vocab_padded, cfg.d_model, 3, rank)
            out.append((f"rank_sweep/{arch}/r{rank}/param_compression_x",
                        dense_params / tt_params, "transformer body"))
            out.append((f"rank_sweep/{arch}/r{rank}/flops_reduction_x",
                        dense_mul / tt_mul, "per layer fwd, K=4096"))
            out.append((f"rank_sweep/{arch}/r{rank}/ttm_crossover_tokens",
                        float(ttm_strategy_crossover(espec)),
                        "gather->reconstruct switch point"))
    return out
