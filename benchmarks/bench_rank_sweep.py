"""Beyond-paper ablation: TT rank as the compression-vs-cost dial, at
assigned-architecture scale (analytic — runs in milliseconds).

The paper fixes rank 12 for its ATIS model; production deployments must
choose rank per layer family.  For each assigned dense arch this sweep
reports, per rank: parameter compression of the full model, BTT training
FLOPs relative to dense, and the HBM-traffic crossover token count for the
TTM embedding (above which the reconstruct strategy wins — see
core/ttm_embedding.py).

The ATIS envelope sweep asks the converse question against the paper's own
budget (6 MB BRAM + 22.5 MB URAM): sweeping TT rank upward on the 6-encoder
ATIS model, what is the largest rank whose full training step still fits —
once with dense AdamW moments, once with the sketched (count-min /
count-sketch) moments the fused PU kernel can hold instead?  The gap is the
headroom the sketch buys.

The precision sweep stacks the quantized-at-rest tier (``core.quant``) on
the same question: at int8 weights/acts (fp8_e5m2 grads, quantized master
params), the per-rank at-rest pools shrink ~4x, so the largest fitting
rank must RISE vs f32 — that gap is the extra model capacity the paper's
envelope buys from the precision dial alone."""
from __future__ import annotations

from repro.configs import get_config
from repro.configs.atis_transformer import config_n
from repro.core.cost_model import mul_btt, mul_dense
from repro.core.memory_ledger import budget_report, training_step_ledger
from repro.core.tt import tt_params_count
from repro.core.tt_linear import make_tt_spec
from repro.core.ttm_embedding import make_ttm_spec, ttm_strategy_crossover

ARCHS = ("qwen3-8b", "llama3-8b", "musicgen-medium")
RANKS = (16, 32, 64, 128)
ATIS_RANKS = (12, 16, 24, 32, 48, 64)


def _atis_fits(rank: int, sketched: bool, precision: str = "float32") -> bool:
    cfg = config_n(6).with_tt(rank=rank)
    if precision != "float32":
        grad = "bfloat16" if precision == "bfloat16" else "fp8_e5m2"
        cfg = cfg.with_precision(param_dtype=precision, act_dtype=precision,
                                 grad_dtype=grad)
    led = training_step_ledger(cfg, "adamw", sketched=sketched)
    return budget_report(led)["fits"]


def atis_envelope_rows():
    out = []
    max_dense = 0
    max_sketched = 0
    for rank in ATIS_RANKS:
        fits_d = _atis_fits(rank, sketched=False)
        fits_s = _atis_fits(rank, sketched=True)
        if fits_d:
            max_dense = rank
        if fits_s:
            max_sketched = rank
        out.append((f"rank_sweep/atis_6enc/r{rank}/fits_dense_adamw",
                    1.0 if fits_d else 0.0,
                    "full training step vs 6+22.5 MB, dense m/v"))
        out.append((f"rank_sweep/atis_6enc/r{rank}/fits_sketched_adamw",
                    1.0 if fits_s else 0.0,
                    "same step, moments as count-min/count-sketch"))
    out.append(("rank_sweep/atis_6enc/max_rank_dense_adamw",
                float(max_dense), "largest swept rank inside the envelope"))
    out.append(("rank_sweep/atis_6enc/max_rank_sketched_adamw",
                float(max_sketched),
                "sketched moments buy this much rank headroom"))
    # Precision variants: the quantized-at-rest tier shrinks the per-rank
    # weight/residual/grad/master pools.  With DENSE AdamW the binding row
    # is the f32 moment pair (8 bytes/param, bram) — quantizing storage
    # can't move it, so the rank dial only opens when the sketch removes
    # the dense moments: the acceptance row compares int8+sketched against
    # f32+sketched.
    max_by_fmt = {}
    for fmt in ("bfloat16", "int8"):
        max_d = max_s = 0
        for rank in ATIS_RANKS:
            if _atis_fits(rank, sketched=False, precision=fmt):
                max_d = rank
            if _atis_fits(rank, sketched=True, precision=fmt):
                max_s = rank
        max_by_fmt[fmt] = (max_d, max_s)
        out.append((f"rank_sweep/atis_6enc/{fmt}/max_rank_dense_adamw",
                    float(max_d),
                    f"largest swept rank inside the envelope at {fmt} "
                    "weights/acts (dense f32 moments still bind)"))
        out.append((f"rank_sweep/atis_6enc/{fmt}/max_rank_sketched_adamw",
                    float(max_s),
                    f"same at {fmt} with sketched moments + quantized "
                    "master params"))
    out.append(("rank_sweep/atis_6enc/int8_rank_headroom",
                1.0 if max_by_fmt["int8"][1] > max_sketched else 0.0,
                "1 = int8 storage admits a larger TT rank than f32 on the "
                "sketched-AdamW step (acceptance)"))
    return out


def _arch_layer_dims(cfg):
    q, kv, d = cfg.attn_dims
    dims = [(q, d), (kv, d), (kv, d), (d, q)]          # attention
    if cfg.d_ff:
        n_mlp = 3 if cfg.mlp_gated else 2
        dims += [(cfg.d_ff, d)] * (n_mlp - 1) + [(d, cfg.d_ff)]
    return dims


def rows():
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        dims = _arch_layer_dims(cfg)
        dense_params = sum(m * n for m, n in dims) * cfg.num_layers
        dense_mul = sum(mul_dense(m, n, 4096) for m, n in dims)
        for rank in RANKS:
            tt_params = sum(
                tt_params_count(make_tt_spec(m, n, 3, rank)) for m, n in dims
            ) * cfg.num_layers
            tt_mul = sum(
                mul_btt(make_tt_spec(m, n, 3, rank), 4096) for m, n in dims)
            espec = make_ttm_spec(cfg.vocab_padded, cfg.d_model, 3, rank)
            out.append((f"rank_sweep/{arch}/r{rank}/param_compression_x",
                        dense_params / tt_params, "transformer body"))
            out.append((f"rank_sweep/{arch}/r{rank}/flops_reduction_x",
                        dense_mul / tt_mul, "per layer fwd, K=4096"))
            out.append((f"rank_sweep/{arch}/r{rank}/ttm_crossover_tokens",
                        float(ttm_strategy_crossover(espec)),
                        "gather->reconstruct switch point"))
    out.extend(atis_envelope_rows())
    return out
