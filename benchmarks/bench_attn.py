"""ATTN stage: fused flash forward + single-kernel flash backward vs the
pure-JAX blockwise path under autodiff.

The paper's hardware thesis (Sec. V-B2) is that every training stage keeps
its intermediates on chip; FTRANS (arXiv 2007.08563) identifies attention's
S×S score matrix as the dominant off-chip tensor in transformer
accelerators.  This module compares the two training-attention paths on
three axes, mirroring bench_bwd's BWD-stage methodology:

* **FLOPs** — identical by construction (six matmuls over the unmasked
  region); emitted once so trajectory files are self-describing.
* **HBM bytes moved** — the analytic traffic models in
  ``kernels.flash_backward``: the fused side is tile-derived from
  ``choose_attn_tiles`` (padded bytes are real bytes); the blockwise side
  counts raw reads, chunk-restack copies, the online-softmax carry
  round-tripping HBM per KV chunk, and the autodiff-saved S×S
  probabilities — generously to XLA (everything once per pass).
* **wall-clock** — median jitted microseconds of a full fwd+bwd
  (``jax.grad``).  On CPU the fused column runs the kernels in *interpret*
  mode (Python emulation) and is an upper bound; TPU is the target.

Emitted rows (CSV via benchmarks.run, JSON schema documented there):
  attn/paper_shape/flops          fwd+bwd attention FLOPs, ATIS B=1 S=32
  attn/paper_shape/fused_bytes    analytic fused fwd+bwd HBM traffic
  attn/paper_shape/unfused_bytes  analytic blockwise+autodiff HBM traffic
  attn/paper_shape/bytes_ratio    unfused / fused (>1 = fused wins)
  attn/paper_shape/fused_us       median jitted grad step (interpret on CPU)
  attn/paper_shape/unfused_us     median jitted blockwise grad step
  attn/paper_shape/match_maxerr   max |fused - blockwise| over (dq, dk, dv)
  attn/atis_<n>enc/bytes_ratio    per-step (all layers) ratio per config
  attn/atis_<n>enc/fewer_bytes    1.0 iff fused < unfused for the config
  attn/gqa_4k/bytes_ratio         context-scale GQA shape (S×S term wins)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.timing import median_us
from repro.configs.atis_transformer import config_n
from repro.kernels import (
    flash_mha_op,
    fused_attn_hbm_bytes,
    unfused_attn_hbm_bytes,
)
from repro.kernels.flash_backward import attn_flops
from repro.models.attention import blockwise_attention

REPS = 5                    # interpret-mode kernels are slow; median of 5
PAPER = (1, 32, 12, 12, 64)  # (B, S, H, KV, d_head): ATIS Table II, seq 32


def check_rows():
    """Analytic byte rows — the single source for both ``rows()`` and the
    ``benchmarks.run --check`` regression guard (no wall-clock)."""
    B, S, H, KV, D = PAPER
    out = []
    for n_enc in (2, 4, 6):
        c = config_n(n_enc)
        its = jnp.dtype(c.dtype).itemsize
        f = n_enc * fused_attn_hbm_bytes(B, c.n_heads, c.n_kv_heads, S,
                                         c.d_head, its, causal=c.causal)
        u = n_enc * unfused_attn_hbm_bytes(B, c.n_heads, c.n_kv_heads, S,
                                           c.d_head, its,
                                           q_chunk=c.attn_q_chunk,
                                           kv_chunk=c.attn_kv_chunk)
        out.append((f"attn/atis_{n_enc}enc/bytes_ratio", u / f,
                    f"per training step, {n_enc} attention layers"))
        out.append((f"attn/atis_{n_enc}enc/fewer_bytes",
                    1.0 if f < u else 0.0,
                    "1 = fused < unfused HBM bytes for this config"))
    return out


def _grad_fns(B, S, H, KV, D, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    do = jax.random.normal(ks[3], (B, S, H, D))

    def fused(q_, k_, v_):
        return (flash_mha_op(q_, k_, v_, causal=causal, interpret=True)
                * do).sum()

    def unfused(q_, k_, v_):
        return (blockwise_attention(q_, k_, v_, causal=causal,
                                    q_chunk=32, kv_chunk=32) * do).sum()

    g_fused = jax.jit(jax.grad(fused, argnums=(0, 1, 2)))
    g_unfused = jax.jit(jax.grad(unfused, argnums=(0, 1, 2)))
    return g_fused, g_unfused, (q, k, v)


def rows():
    B, S, H, KV, D = PAPER
    cfg = config_n(2)
    its = jnp.dtype(cfg.dtype).itemsize
    causal = cfg.causal                    # False: the paper's encoder

    fb = fused_attn_hbm_bytes(B, H, KV, S, D, its, causal=causal)
    ub = unfused_attn_hbm_bytes(B, H, KV, S, D, its,
                                q_chunk=cfg.attn_q_chunk,
                                kv_chunk=cfg.attn_kv_chunk)

    g_fused, g_unfused, ops = _grad_fns(B, S, H, KV, D, causal)
    gf = g_fused(*ops)
    gu = g_unfused(*ops)
    err = max(float(jnp.max(jnp.abs(u.astype(jnp.float32)
                                    - v.astype(jnp.float32))))
              for u, v in zip(gf, gu))

    out = [
        ("attn/paper_shape/flops",
         float(attn_flops(B, H, S, D, causal=causal)),
         "fwd (QK^T, PV) + bwd (dV, dP, dQ, dK); ATIS B=1 S=32 h=12 d=64"),
        ("attn/paper_shape/fused_bytes", float(fb),
         "analytic HBM traffic: flash fwd + single-kernel bwd, (O,m,l) "
         "residuals only"),
        ("attn/paper_shape/unfused_bytes", float(ub),
         "blockwise+autodiff: chunk restacks + carry round-trips + saved "
         "S^2 probabilities"),
        ("attn/paper_shape/bytes_ratio", ub / fb,
         ">1 = fused moves fewer HBM bytes"),
        ("attn/paper_shape/fused_us",
         median_us(g_fused, *ops, reps=REPS),
         "flash fwd+bwd kernels (interpret mode on CPU; upper bound)"),
        ("attn/paper_shape/unfused_us",
         median_us(g_unfused, *ops, reps=REPS),
         "pure-XLA blockwise fwd+bwd"),
        ("attn/paper_shape/match_maxerr", err,
         "max |fused - blockwise| over (dq, dk, dv)"),
    ]

    out.extend(check_rows())  # per-config byte rows: one source with CI

    f = fused_attn_hbm_bytes(1, 8, 2, 4096, 128, 2)
    u = unfused_attn_hbm_bytes(1, 8, 2, 4096, 128, 2)
    out.append(("attn/gqa_4k/bytes_ratio", u / f,
                "B=1 S=4096 H=8 KV=2 d=128 bf16: the S^2 probability "
                "term dominates the blockwise side"))
    return out
