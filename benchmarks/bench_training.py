"""Paper Fig. 13 + Table III accuracy — tensor-compressed vs matrix training
parity on the (synthetic) ATIS task.

The paper's Fig. 13 shows its accelerator's training curves matching PyTorch;
its Table III shows tensor == matrix accuracy.  Our reproduction target is
*parity*: the tensor model must train to the same task accuracy as the
uncompressed matrix model.  Two deviations, both recorded in EXPERIMENTS.md:

  * optimizer: AdamW for both models.  SGD (the paper's choice) stalls the
    TT model early at this reduced scale — chained-core gradients are badly
    conditioned — while the paper amortizes that over 40 ATIS epochs
    (~180k samples); our 1-core-CPU budget cannot.  AdamW removes the
    conditioning gap without touching the model.
  * budget: tensor gets 3x the steps of matrix (slower early convergence is
    expected for from-scratch tensor training; the trajectory — printed
    below — is still rising when we stop).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.configs.atis_transformer import config_n
from repro.data import AtisGrammar, atis_batch
from repro.models import init_params
from repro.models.classifier import atis_heads_init, atis_loss, atis_metrics
from repro.optim import adamw, warmup_cosine

MATRIX_STEPS = int(os.environ.get("BENCH_ATIS_STEPS", "600"))
BATCH = 32
LR = 3e-3


def _train(tt_mode: str, steps: int):
    cfg = config_n(2, tt_mode=tt_mode).scaled_down(
        d_model=256, n_heads=4, d_ff=256, vocab_size=1000, num_layers=2,
        max_seq_len=64)
    g = AtisGrammar(seed=11)
    params = {"backbone": init_params(jax.random.PRNGKey(0), cfg),
              "heads": atis_heads_init(jax.random.PRNGKey(1), cfg, 26, 120)}
    opt = adamw(warmup_cosine(LR, 50, steps))
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: atis_loss(p, cfg, batch))(params)
        params, state = opt.update(grads, params, state, state["step"])
        return params, state, loss

    first = last = None
    for i in range(steps):
        batch = {k: jnp.asarray(v)
                 for k, v in atis_batch(g, "train", i, BATCH).items()}
        params, state, loss = step(params, state, batch)
        if first is None:
            first = float(loss)
        last = float(loss)
    test = {k: jnp.asarray(v) for k, v in atis_batch(g, "test", 0, 256).items()}
    m = atis_metrics(params, cfg, test)
    return {"first_loss": first, "last_loss": last,
            "intent_acc": float(m["intent_acc"]),
            "slot_acc": float(m["slot_acc"])}


def _multi_device_rows(args) -> list[tuple[str, float, str]]:
    """Pipeline × TP × DP training benchmark rows.  Runs in the CHILD
    process (``--devices`` re-exec) so XLA_FLAGS took effect before the
    jax import at the top of this module."""
    import time

    from repro.core.memory_ledger import pipeline_ledger_rows
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import make_pipeline_train_step, make_train_step
    from repro.optim import sgd
    from repro.runtime.pipeline import (
        StagePartition, bubble_fraction, stage_utilization)

    cfg = config_n(2, tt_mode="tt").scaled_down(
        d_model=256, n_heads=4, d_ff=256, vocab_size=1000, num_layers=2,
        max_seq_len=64).with_tt(flow="kernel").with_fused_attn(
        True).with_fused_ffn(True)
    mesh = make_host_mesh(args.dp, args.tp, stage=args.stages)
    part = StagePartition.from_mesh(mesh, args.microbatches)

    opt = sgd(1e-2, 0.0)
    pipe = make_pipeline_train_step(cfg, opt, mesh,
                                    microbatches=args.microbatches)
    single = jax.jit(make_train_step(cfg, opt))

    from repro.models.transformer import init_params as _init
    B, S = args.batch, args.seq

    def batch_at(i):
        k = jax.random.PRNGKey(100 + i)
        toks = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}

    def timed(step_fn):
        params = _init(jax.random.PRNGKey(0), cfg)
        state = opt.init(params)
        t_first = t_steady = last = None
        for i in range(args.steps):
            b = batch_at(i)
            t0 = time.perf_counter()
            params, state, m = step_fn(params, state, b)
            jax.block_until_ready(m["loss"])
            dt = time.perf_counter() - t0
            if i == 0:
                t_first = dt
            else:
                t_steady = dt if t_steady is None else min(t_steady, dt)
            last = float(m["loss"])
        return t_first, t_steady, last

    tf_p, ts_p, loss_p = timed(pipe)
    tf_s, ts_s, loss_s = timed(single)

    rows_out = [
        ("pipe/devices", float(part.devices),
         f"stage={part.stages} data={part.dp} model={part.tp}"),
        ("pipe/bubble_fraction", bubble_fraction(part),
         f"(S-1)/(M+S-1), M={part.microbatches}"),
        ("pipe/stage_utilization", stage_utilization(part),
         "M/(M+S-1), uniform across stages"),
        ("pipe/step_ms", ts_p * 1e3 if ts_p else tf_p * 1e3,
         f"steady-state; compile-step {tf_p * 1e3:.0f} ms"),
        ("pipe/single_device_step_ms", ts_s * 1e3 if ts_s else tf_s * 1e3,
         "same config, no mesh"),
        ("pipe/loss_vs_single", abs(loss_p - loss_s),
         f"|pipeline - single| after {args.steps} steps "
         f"(pipe {loss_p:.4f}, single {loss_s:.4f})"),
    ]
    for n_enc in (2, 4, 6):
        rows_out.extend(pipeline_ledger_rows(
            config_n(n_enc, tt_mode="tt"), part, "sgd",
            f"pipe/ledger/{n_enc}enc"))
    return rows_out


_CHILD_MARKER = "_BENCH_TRAINING_CHILD"


def main(argv=None) -> int:
    """``--devices N`` multi-device mode (re-execs with forced host devices);
    without it, emits the single-process parity rows like run.py does."""
    import argparse
    import json as _json
    import subprocess
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host CPU devices and benchmark the "
                         "shard_map pipeline (re-execs this script with "
                         "XLA_FLAGS set before jax imports)")
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--json", default=None,
                    help="also write rows as a JSON list to this path")
    args = ap.parse_args(argv)

    if args.devices and _CHILD_MARKER not in os.environ:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + env.get("XLA_FLAGS", "")).strip()
        env[_CHILD_MARKER] = "1"
        env.setdefault("PYTHONPATH", "src")
        cmd = [sys.executable, os.path.abspath(__file__),
               *(a for a in (sys.argv[1:] if argv is None else argv))]
        return subprocess.run(cmd, env=env).returncode

    out = _multi_device_rows(args) if args.devices else rows()
    for name, value, note in out:
        print(f"{name},{value},{note}")
    if args.json:
        with open(args.json, "w") as fh:
            _json.dump([{"name": n, "value": v, "note": t}
                        for n, v, t in out], fh, indent=2)
        print(f"[bench_training] wrote {args.json}", file=sys.stderr)
    return 0


def rows():
    mm = _train("off", MATRIX_STEPS)
    tt = _train("tt", 3 * MATRIX_STEPS)
    out = [
        (f"fig13/matrix@{MATRIX_STEPS}/final_loss", mm["last_loss"], ""),
        (f"fig13/tensor@{3 * MATRIX_STEPS}/final_loss", tt["last_loss"],
         "still decreasing at cutoff"),
        ("fig13/matrix/intent_acc", mm["intent_acc"], ""),
        ("fig13/tensor/intent_acc", tt["intent_acc"],
         "parity target (paper Table III: tensor >= matrix; see module doc)"),
        ("fig13/matrix/slot_acc", mm["slot_acc"], ""),
        ("fig13/tensor/slot_acc", tt["slot_acc"], "parity target"),
        ("fig13/intent_parity_gap", tt["intent_acc"] - mm["intent_acc"],
         "paper: +0.8pt (tensor wins, full 40-epoch budget)"),
        ("fig13/slot_parity_gap", tt["slot_acc"] - mm["slot_acc"],
         "paper: -0.1pt"),
    ]
    return out


if __name__ == "__main__":
    raise SystemExit(main())
