"""Paper Fig. 13 + Table III accuracy — tensor-compressed vs matrix training
parity on the (synthetic) ATIS task.

The paper's Fig. 13 shows its accelerator's training curves matching PyTorch;
its Table III shows tensor == matrix accuracy.  Our reproduction target is
*parity*: the tensor model must train to the same task accuracy as the
uncompressed matrix model.  Two deviations, both recorded in EXPERIMENTS.md:

  * optimizer: AdamW for both models.  SGD (the paper's choice) stalls the
    TT model early at this reduced scale — chained-core gradients are badly
    conditioned — while the paper amortizes that over 40 ATIS epochs
    (~180k samples); our 1-core-CPU budget cannot.  AdamW removes the
    conditioning gap without touching the model.
  * budget: tensor gets 3x the steps of matrix (slower early convergence is
    expected for from-scratch tensor training; the trajectory — printed
    below — is still rising when we stop).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.configs.atis_transformer import config_n
from repro.data import AtisGrammar, atis_batch
from repro.models import init_params
from repro.models.classifier import atis_heads_init, atis_loss, atis_metrics
from repro.optim import adamw, warmup_cosine

MATRIX_STEPS = int(os.environ.get("BENCH_ATIS_STEPS", "600"))
BATCH = 32
LR = 3e-3


def _train(tt_mode: str, steps: int):
    cfg = config_n(2, tt_mode=tt_mode).scaled_down(
        d_model=256, n_heads=4, d_ff=256, vocab_size=1000, num_layers=2,
        max_seq_len=64)
    g = AtisGrammar(seed=11)
    params = {"backbone": init_params(jax.random.PRNGKey(0), cfg),
              "heads": atis_heads_init(jax.random.PRNGKey(1), cfg, 26, 120)}
    opt = adamw(warmup_cosine(LR, 50, steps))
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: atis_loss(p, cfg, batch))(params)
        params, state = opt.update(grads, params, state, state["step"])
        return params, state, loss

    first = last = None
    for i in range(steps):
        batch = {k: jnp.asarray(v)
                 for k, v in atis_batch(g, "train", i, BATCH).items()}
        params, state, loss = step(params, state, batch)
        if first is None:
            first = float(loss)
        last = float(loss)
    test = {k: jnp.asarray(v) for k, v in atis_batch(g, "test", 0, 256).items()}
    m = atis_metrics(params, cfg, test)
    return {"first_loss": first, "last_loss": last,
            "intent_acc": float(m["intent_acc"]),
            "slot_acc": float(m["slot_acc"])}


def rows():
    mm = _train("off", MATRIX_STEPS)
    tt = _train("tt", 3 * MATRIX_STEPS)
    out = [
        (f"fig13/matrix@{MATRIX_STEPS}/final_loss", mm["last_loss"], ""),
        (f"fig13/tensor@{3 * MATRIX_STEPS}/final_loss", tt["last_loss"],
         "still decreasing at cutoff"),
        ("fig13/matrix/intent_acc", mm["intent_acc"], ""),
        ("fig13/tensor/intent_acc", tt["intent_acc"],
         "parity target (paper Table III: tensor >= matrix; see module doc)"),
        ("fig13/matrix/slot_acc", mm["slot_acc"], ""),
        ("fig13/tensor/slot_acc", tt["slot_acc"], "parity target"),
        ("fig13/intent_parity_gap", tt["intent_acc"] - mm["intent_acc"],
         "paper: +0.8pt (tensor wins, full 40-epoch budget)"),
        ("fig13/slot_parity_gap", tt["slot_acc"] - mm["slot_acc"],
         "paper: -0.1pt"),
    ]
    return out
