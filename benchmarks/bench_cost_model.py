"""Paper Table I + Fig. 6 + Fig. 7 — analytic FLOPs/memory of MM / TTM / TT /
BTT contraction flows, exactly as the paper's example is configured
(d_hid 768, d=3, n=(12,8,8), m=(8,8,12), rank 12, seq 32)."""
from __future__ import annotations

from repro.core import TTSpec, btt_contraction_cost, rl_contraction_cost
from repro.core.cost_model import (
    mem_btt,
    mem_tt_rl,
    mul_btt,
    mul_dense,
    mul_tt_rl,
    ttm_forward_cost,
)
from repro.core.tt import TTMSpec

PAPER = TTSpec(out_factors=(8, 8, 12), in_factors=(12, 8, 8), rank=12,
               clamp_ranks=False)
PAPER_TTM = TTMSpec(vocab_factors=(12, 8, 8), hidden_factors=(8, 8, 12), rank=12)


def rows():
    out = []
    K = 32
    dense_mul = mul_dense(768, 768, K)
    dense_mem = 768 * 768 + K * 768  # weights + output activation
    tt_params = sum(r1 * n * r2 for (r1, n, r2) in
                    ((PAPER.ranks[i], (8, 8, 12, 12, 8, 8)[i],
                      PAPER.ranks[i + 1]) for i in range(6)))

    # --- Fig. 6: the paper example -------------------------------------
    btt_m, rl_m = mul_btt(PAPER, K), mul_tt_rl(PAPER, K)
    btt_mem, rl_mem = mem_btt(PAPER, K), mem_tt_rl(PAPER, K)
    out.append(("fig6/mm_over_btt_compute", dense_mul / btt_m, "paper: 22.51x"))
    out.append(("fig6/mm_over_btt_memory",
                dense_mem / (tt_params + btt_mem), "paper: 22.67x"))
    out.append(("fig6/rl_over_btt_compute", rl_m / btt_m, "paper: 1.49x"))
    out.append(("fig6/rl_over_btt_memory", rl_mem / btt_mem, "paper: 2.31x"))

    # closed forms == step-by-step calculator (validates the transcription)
    out.append(("eq18_matches_calculator",
                float(mul_tt_rl(PAPER, K) == rl_contraction_cost(PAPER, K).muls),
                "1.0 = exact"))
    out.append(("eq20_matches_calculator",
                float(mul_btt(PAPER, K) == btt_contraction_cost(PAPER, K).muls),
                "1.0 = exact"))

    # --- Fig. 7 top: sweep sequence length at rank 12 -------------------
    for seq in (8, 32, 128, 512):
        d = mul_dense(768, 768, seq)
        out.append((f"fig7/seq{seq}/flops_reduction_btt",
                    d / mul_btt(PAPER, seq), "vs MM"))
        out.append((f"fig7/seq{seq}/flops_reduction_rl",
                    d / mul_tt_rl(PAPER, seq), "vs MM"))
        ttm_mul, _ = ttm_forward_cost(PAPER_TTM, seq)
        out.append((f"fig7/seq{seq}/flops_reduction_ttm",
                    d / max(ttm_mul, 1), "vs MM"))

    # --- Fig. 7 bottom: sweep rank at seq 32 -----------------------------
    for rank in (1, 4, 12, 24, 48):
        spec = TTSpec((8, 8, 12), (12, 8, 8), rank, clamp_ranks=False)
        d = mul_dense(768, 768, K)
        out.append((f"fig7/rank{rank}/flops_reduction_btt",
                    d / mul_btt(spec, K), "vs MM"))
        out.append((f"fig7/rank{rank}/mem_reduction_btt",
                    dense_mem / max(mem_btt(spec, K), 1), "vs MM"))
    return out
