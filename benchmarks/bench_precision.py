"""Low-precision storage tier: per-dtype ledger rows on the ATIS models.

The paper trains in f32; this module prices the quantized-at-rest tier
(``core.quant``: bf16 cast, int8 / fp8_e4m3 per-tensor-scaled) against the
f32 baseline on the paper's own ATIS configs, per training stage.  Every
byte count comes from the SAME ``training_step_ledger`` the envelope checks
use — the rows here are the acceptance evidence that the precision dial
actually shrinks the at-rest pools (weights, saved residuals, gradient
tier, quantized master params) rather than merely relabeling dtypes.

Emitted rows (CSV via benchmarks.run, JSON schema documented there):
  precision/atis_<n>enc/<fmt>/<stage>/bytes_ratio
                          f32 at-rest bytes / <fmt> at-rest bytes for that
                          stage (params + residuals + attn_residuals +
                          ffn_hidden [+ grads]; PU: params + grads)
  precision/atis_<n>enc/<fmt>/<stage>/fewer_bytes
                          1.0 iff the <fmt> tier is strictly smaller
  precision/atis_<n>enc/<stage>/ordered
                          1.0 iff int8 < bf16 < f32 AND fp8 < bf16 —
                          the scaled formats must beat the cast format,
                          which must beat the baseline
  precision/atis_<n>enc/int8/half_or_better
                          1.0 iff EVERY at-rest row (params, residuals,
                          attn_residuals, ffn_hidden, grads) is <= 0.5x
                          its f32 bytes in the int8 config (acceptance)
  precision/atis_<n>enc/<fmt>/fits
                          1.0 iff the full step fits 6 + 22.5 MB
  precision/ledger_int8/<stage>_mb    ledger stage totals, int8 config
  precision/ledger_int8/fits          vs the paper envelope

Formats swept (grad tier pairs with the storage tier):
  bfloat16   cast-only weights/acts, bf16 grads — no scales, no SR
  int8       per-tile-scaled weights/acts + quantized f32 master with
             in-kernel stochastic-rounding re-write; fp8_e5m2 grads
  fp8_e4m3   emulated fp8 weights/acts (tiles upcast to f32 in VMEM
             before the dot); fp8_e5m2 grads
"""
from __future__ import annotations

from repro.configs.atis_transformer import config_n
from repro.core.memory_ledger import (
    budget_report,
    ledger_rows,
    training_step_ledger,
)

# (storage fmt, grad fmt): int8 grads are rejected (one scale can't span
# the dynamic range), so the scaled variants take the fp8_e5m2 grad tier.
FMTS = (("bfloat16", "bfloat16"),
        ("int8", "fp8_e5m2"),
        ("fp8_e4m3", "fp8_e5m2"))
# At-rest rows per stage — everything the precision tier stores between
# kernel launches (kernel_vmem / tt_intermediates stay at compute width).
AT_REST = {"FWD": ("params", "residuals", "attn_residuals", "ffn_hidden"),
           "BWD": ("params", "residuals", "attn_residuals", "ffn_hidden",
                   "grads"),
           "PU": ("params", "grads")}


def _at_rest(led, stage: str) -> int:
    return sum(led[stage].entry(name).nbytes for name in AT_REST[stage])


def check_rows():
    """Analytic rows for ``benchmarks.run --check`` (no wall-clock)."""
    out = []
    for n_enc in (2, 4, 6):
        cfg = config_n(n_enc)
        base = training_step_ledger(cfg, "adamw")
        led = {}
        for fmt, gfmt in FMTS:
            qcfg = cfg.with_precision(param_dtype=fmt, act_dtype=fmt,
                                      grad_dtype=gfmt)
            led[fmt] = training_step_ledger(qcfg, "adamw")
            out.append((f"precision/atis_{n_enc}enc/{fmt}/fits",
                        1.0 if budget_report(led[fmt])["fits"] else 0.0,
                        "full quantized step vs the 6+22.5 MB envelope"))
        for stage in AT_REST:
            f32b = _at_rest(base, stage)
            by_fmt = {fmt: _at_rest(led[fmt], stage) for fmt, _ in FMTS}
            for fmt, _ in FMTS:
                out.append((
                    f"precision/atis_{n_enc}enc/{fmt}/{stage.lower()}"
                    "/bytes_ratio", f32b / by_fmt[fmt],
                    "f32 at-rest bytes / quantized tier (ledger-derived)"))
                out.append((
                    f"precision/atis_{n_enc}enc/{fmt}/{stage.lower()}"
                    "/fewer_bytes", 1.0 if by_fmt[fmt] < f32b else 0.0,
                    "1 = quantized at-rest tier strictly smaller"))
            ordered = (by_fmt["int8"] < by_fmt["bfloat16"] < f32b
                       and by_fmt["fp8_e4m3"] < by_fmt["bfloat16"])
            out.append((f"precision/atis_{n_enc}enc/{stage.lower()}/ordered",
                        1.0 if ordered else 0.0,
                        "int8/fp8 < bf16 < f32 at-rest bytes"))
        half = all(
            led["int8"][stage].entry(name).nbytes
            <= 0.5 * base[stage].entry(name).nbytes
            for stage, names in AT_REST.items() for name in names)
        out.append((f"precision/atis_{n_enc}enc/int8/half_or_better",
                    1.0 if half else 0.0,
                    "every int8 at-rest row <= 0.5x its f32 bytes "
                    "(acceptance)"))
    return out


def rows():
    out = list(check_rows())
    cfg = config_n(2).with_precision(param_dtype="int8", act_dtype="int8",
                                     grad_dtype="fp8_e5m2")
    out.extend(ledger_rows(cfg, "adamw", "precision/ledger_int8"))
    return out
