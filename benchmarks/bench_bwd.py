"""BWD stage: fused single-kernel backward vs the unfused 4-GEMM path.

The paper's training step has three on-chip stages (Sec. III-A); this
module covers stage 2, where ~2/3 of the step FLOPs live.  It compares the
fused ``kernels.btt_backward`` launch (gx/ga/gb in one pass, t/gt resident
in VMEM) against the unfused path (operand-swap forward launch for gx +
four XLA GEMMs that round-trip t/gt through HBM) on three axes:

* **FLOPs** — identical by construction (same five contractions); emitted
  once so trajectory files are self-describing.
* **HBM bytes moved** — the analytic tile-derived traffic models in
  ``kernels.btt_backward`` (the quantity the fusion exists to shrink).
  Emitted per shipped ATIS config over every TT layer in its parameter
  tree; the ``fewer_bytes`` flag asserts the fused path moves strictly
  fewer bytes for every layer of every config.
* **wall-clock** — median jitted microseconds.  On CPU the fused column
  runs the kernel in *interpret* mode (Python emulation) and is an upper
  bound, as with bench_pu; TPU is the target.

Emitted rows (CSV via benchmarks.run, JSON schema documented there):
  bwd/paper_layer/flops         five-contraction FLOPs, paper 768x768 r12
  bwd/paper_layer/fused_bytes   analytic fused HBM traffic (K=32)
  bwd/paper_layer/unfused_bytes analytic unfused HBM traffic
  bwd/paper_layer/bytes_ratio   unfused / fused (>1 = fused wins)
  bwd/paper_layer/fused_us      median jitted fused bwd (interpret on CPU)
  bwd/paper_layer/unfused_us    median jitted unfused bwd
  bwd/paper_layer/match_maxerr  max |fused - unfused| over (gx, ga, gb)
  bwd/atis_<n>enc/bytes_ratio   min ratio over the config's TT layers
  bwd/atis_<n>enc/fewer_bytes   1.0 iff fused < unfused for EVERY layer
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.timing import median_us
from repro.configs.atis_transformer import config_n
from repro.core.memory_ledger import _collect_modules
from repro.kernels import (
    btt_backward_pallas,
    btt_backward_ref,
    fused_bwd_hbm_bytes,
    unfused_bwd_hbm_bytes,
)
from repro.kernels.btt_backward import bwd_flops
from repro.models import init_params

REPS = 5                # interpret-mode kernels are slow; median of 5
K_PAPER = 32            # batch 1 x seq 32, the paper's training regime
PAPER = (32, 768, 768, 12)  # (K, M, N, R): the paper's 768x768 rank-12 layer


def _config_specs(cfg):
    """(out_dim, in_dim, mid_rank) of every TT linear in the config."""
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    tts, _ = _collect_modules(params)
    return sorted({(m.spec.out_dim, m.spec.in_dim, m.spec.mid_rank)
                   for m in tts})


def check_rows():
    """Analytic byte rows — the single source for both ``rows()`` and the
    ``benchmarks.run --check`` regression guard (no wall-clock)."""
    K, M, N, R = PAPER
    fb = fused_bwd_hbm_bytes(K, M, N, R, 4)
    ub = unfused_bwd_hbm_bytes(K, M, N, R, 4)
    out = [
        ("bwd/paper_layer/fused_bytes", float(fb),
         "analytic HBM traffic of one fused btt_backward launch"),
        ("bwd/paper_layer/unfused_bytes", float(ub),
         "operand-swap gx launch + 4 XLA GEMMs (t/gt round-trip f32)"),
        ("bwd/paper_layer/bytes_ratio", ub / fb,
         ">1 = fused moves fewer HBM bytes"),
    ]
    for n_enc in (2, 4, 6):
        ratios = [unfused_bwd_hbm_bytes(K_PAPER, m, n, r, 4)
                  / fused_bwd_hbm_bytes(K_PAPER, m, n, r, 4)
                  for m, n, r in _config_specs(config_n(n_enc))]
        out.append((f"bwd/atis_{n_enc}enc/bytes_ratio", min(ratios),
                    f"min over {len(ratios)} distinct TT layer shapes"))
        out.append((f"bwd/atis_{n_enc}enc/fewer_bytes",
                    1.0 if min(ratios) > 1.0 else 0.0,
                    "1 = fused < unfused HBM bytes for every TT layer"))
    return out


def rows():
    K, M, N, R = PAPER
    kx, kg, kb, ka = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(kx, (K, N))
    gy = jax.random.normal(kg, (K, M))
    b = jax.random.normal(kb, (R, N)) * 0.05
    a = jax.random.normal(ka, (M, R)) * 0.05

    fused = jax.jit(lambda *ops: btt_backward_pallas(*ops, interpret=True))
    unfused = jax.jit(btt_backward_ref)

    g_f = fused(x, gy, b, a)
    g_u = unfused(x, gy, b, a)
    err = max(float(jnp.max(jnp.abs(u.astype(jnp.float32)
                                    - v.astype(jnp.float32))))
              for u, v in zip(g_f, g_u))

    out = [
        ("bwd/paper_layer/flops", float(bwd_flops(K, M, N, R)),
         "t/gt/gx/ga/gb contractions; 768x768 r12; K=32"),
        ("bwd/paper_layer/fused_us",
         median_us(fused, x, gy, b, a, reps=REPS),
         "Pallas fused BWD kernel (interpret mode on CPU; upper bound)"),
        ("bwd/paper_layer/unfused_us",
         median_us(unfused, x, gy, b, a, reps=REPS),
         "pure-XLA reference backward"),
        ("bwd/paper_layer/match_maxerr", err,
         "max |fused - unfused| over (gx, ga, gb)"),
    ]
    out.extend(check_rows())  # byte rows: one source with the CI guard
    return out
