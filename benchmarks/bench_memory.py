"""Paper Fig. 15 / Table V (memory columns) — computing-memory comparison of
matrix vs tensor-compressed training, from *compiled* artifacts, plus the
per-stage on-chip residency ledger (``core.memory_ledger``).

The paper compares GPU reserved memory against its FPGA's on-chip usage
(17.2 / 17.8 / 34.5 MB for 2/4/6 encoders; 48.2x / 51.4x / 29.6x less than
matrix GPU training).  Without a GPU we report the backend-measured
analogue: XLA buffer allocation (argument + output + temp) for one compiled
training step of the matrix model vs the TT model, same batch (the paper's
batch-1, seq-32 regime).  Energy (Table V) reduces to FLOPs + bytes moved on
a dry-run — reported per cell in EXPERIMENTS.md §Roofline instead.

Emitted rows (CSV via benchmarks.run; JSON trajectory schema is documented
in ``benchmarks/run.py`` — these names are the stable ``"name"`` keys):

  fig15/<n>enc/matrix_total_mb   compiled-step bytes, uncompressed model
  fig15/<n>enc/tensor_total_mb   compiled-step bytes, TT model
                                 (note carries the paper's FPGA MB)
  fig15/<n>enc/reduction_x       matrix/tensor ratio (note: paper's ratio)
  fig15/<n>enc/tensor_args_mb    params + opt state (on-chip-resident set)
  ledger/<n>enc/<stage>_mb       analytic per-stage residency (FWD/BWD/PU),
                                 note splits bram/uram pools
  ledger/<n>enc/fits             1.0 iff peaks fit 6 MB BRAM + 22.5 MB URAM
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.atis_transformer import config_n
from repro.core.memory_ledger import ledger_rows
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import sgd

PAPER_FPGA_MB = {2: 17.2, 4: 17.8, 6: 34.5}
PAPER_RATIO_VS_MATRIX_GPU = {2: 48.2, 4: 51.4, 6: 29.6}


def _step_memory_mb(n_enc: int, tt_mode: str) -> dict:
    cfg = config_n(n_enc, tt_mode=tt_mode)
    opt = sgd(4e-3)
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    opt_state = jax.eval_shape(opt.init, params)
    batch = {
        "tokens": jax.ShapeDtypeStruct((1, 32), jnp.int32),
        "labels": jax.ShapeDtypeStruct((1, 32), jnp.int32),
        "mask": jax.ShapeDtypeStruct((1, 32), jnp.float32),
    }
    step = make_train_step(cfg, opt, remat=False)
    compiled = jax.jit(step, donate_argnums=(0, 1)).lower(
        params, opt_state, batch).compile()
    ma = compiled.memory_analysis()
    return {
        "args": ma.argument_size_in_bytes / 1e6,
        "temp": ma.temp_size_in_bytes / 1e6,
        "total": (ma.argument_size_in_bytes + ma.temp_size_in_bytes) / 1e6,
    }


def rows():
    out = []
    for n_enc in (2, 4, 6):
        mm = _step_memory_mb(n_enc, "off")
        tt = _step_memory_mb(n_enc, "tt")
        out.append((f"fig15/{n_enc}enc/matrix_total_mb", mm["total"],
                    "compiled step: params+grads+activations"))
        out.append((f"fig15/{n_enc}enc/tensor_total_mb", tt["total"],
                    f"paper FPGA on-chip: {PAPER_FPGA_MB[n_enc]} MB"))
        out.append((f"fig15/{n_enc}enc/reduction_x", mm["total"] / tt["total"],
                    f"paper vs matrix-GPU: {PAPER_RATIO_VS_MATRIX_GPU[n_enc]}x"))
        out.append((f"fig15/{n_enc}enc/tensor_args_mb", tt["args"],
                    "params+opt state (the on-chip-resident set)"))
        out.extend(ledger_rows(
            config_n(n_enc, tt_mode="tt"), "sgd", f"ledger/{n_enc}enc",
            fits_note=f"paper on-chip: {PAPER_FPGA_MB[n_enc]} MB"))
    return out
