"""Benchmark driver — one module per paper table/figure.

Each bench module exposes ``rows() -> list[(name, value, note)]``; this
driver prints them as ``name,value,note`` CSV (stdout) so the harness
command ``python -m benchmarks.run`` produces a single auditable artifact.

  bench_cost_model   Table I, Fig. 6, Fig. 7   (FLOPs/memory closed forms)
  bench_model_size   Table III                 (model MB + compression x)
  bench_bram         Figs. 11, 12, 14          (BRAM + TPU packing)
  bench_training     Fig. 13, Table III acc    (tensor vs matrix parity)
  bench_memory       Fig. 15, Table V memory   (compiled-step memory)
  bench_flows        Table V latency proxy     (flow wall-times on CPU)
  bench_rank_sweep   (beyond paper)            (rank ablation at arch scale)
  bench_pu           Sec. III-A PU stage       (fused vs unfused update +
                                                per-stage memory ledger)
  bench_bwd          Sec. III-A BWD stage      (fused single-kernel backward
                                                vs 4-GEMM path: FLOPs, HBM
                                                bytes moved, wall-clock)
  bench_attn         Sec. V-B2 ATTN stage      (flash fwd + single-kernel bwd
                                                vs blockwise+autodiff: FLOPs,
                                                HBM bytes moved, wall-clock)
  bench_ffn          Sec. V FFN stage          (fused megakernel — both TT
                                                linears + act, hidden state
                                                VMEM-only — vs two-call path:
                                                FLOPs, HBM bytes, wall-clock)
  bench_decode       serving DECODE stage      (paged flash-decode + decode-
                                                shape BTT kernels vs unfused
                                                path: HBM bytes, DECODE
                                                ledger, tokens/s vs
                                                concurrency)
  bench_precision    (beyond paper)            (quantized-at-rest tier:
                                                int8/fp8/bf16 ledger rows vs
                                                f32 per training stage on
                                                ATIS 2/4/6-enc)
  bench_robustness   (beyond paper)            (fault-tolerance acceptance:
                                                guard overhead vs unguarded
                                                step, NaN-burst recovery
                                                within 5% of fault-free,
                                                corrupt-checkpoint fallback)

Usage::

  python -m benchmarks.run [module ...] [--json PATH]
  python -m benchmarks.run --check [--write-baseline]

``--check`` is the benchmark-regression guard CI runs on every commit: it
collects the ANALYTIC rows (``check_rows()``; no wall-clock, seconds not
minutes) of every fused-vs-unfused stage — PU (incl. the sketched-vs-dense
AdamW rows, ``pu/*/adamw_sketched/*``), BWD, ATTN, FFN — and fails
if (a) any ``*/fewer_bytes`` flag is not 1.0 or any ``*/bytes_ratio`` is
not > 1.0 (a fused path moving MORE analytic HBM bytes than its unfused
counterpart on a shipped config is a regression by definition), or (b) any
ratio fell more than 0.1% below the committed baseline
(``benchmarks/baseline_check.json`` — the seed of the benchmark
trajectory; regenerate deliberately with ``--check --write-baseline``
after an intentional model change).

With ``--json PATH`` the same rows are also written as a ``BENCH_*.json``
-style trajectory snapshot.  JSON schema (stable — downstream tooling diffs
these files across commits, so only ADD keys, never rename)::

  {
    "schema": 1,                    # bump on incompatible change
    "generated_unix": 1753833600,   # time.time() at emission
    "modules": {
      "<bench module name>": {
        "status": "ok" | "error",
        "seconds": 12.3,            # wall time for the module's rows()
        "rows": [
          {"name": "fig6/comp_mm_x", # metric path: <figure-or-table>/<metric>
           "value": 22.51,           # float | int | str
           "note": "paper: 22.51x"}, # free-text context, incl. paper value
          ...
        ]
      },
      ...
    }
  }

Row ``name``s are slash-paths: the leading segment identifies the paper
artifact (``fig15``, ``table3``, ``pu``, ...) and the remainder the metric;
``note`` carries the paper's printed value where one exists, so a trajectory
file is self-describing without the paper at hand.
"""
from __future__ import annotations

import json
import os
import sys
import time
import traceback

MODULES = [
    "bench_cost_model",
    "bench_model_size",
    "bench_bram",
    "bench_training",
    "bench_memory",
    "bench_flows",
    "bench_rank_sweep",
    "bench_pu",
    "bench_bwd",
    "bench_attn",
    "bench_ffn",
    "bench_decode",
    "bench_precision",
    "bench_robustness",
]

# Modules with a fused-vs-unfused analytic byte model (check_rows()) —
# bench_robustness contributes its deterministic (seeded-chaos, no
# wall-clock) recovery + checkpoint-fallback rows to the same gate.
CHECK_MODULES = ["bench_pu", "bench_bwd", "bench_attn", "bench_ffn",
                 "bench_decode", "bench_precision", "bench_robustness"]
BASELINE_PATH = os.path.join(os.path.dirname(__file__),
                             "baseline_check.json")
BASELINE_SLACK = 0.999  # ratios may not fall >0.1% below the baseline


def run_check(write_baseline: bool) -> None:
    rows: list[tuple[str, float, str]] = []
    for mod_name in CHECK_MODULES:
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["check_rows"])
        rows.extend(mod.check_rows())
    print("name,value,note")
    for name, value, note in rows:
        print(f"{name},{value:.6g},{note}")

    failures = []
    for name, value, _ in rows:
        if name.endswith("/fewer_bytes") and value != 1.0:
            failures.append(f"{name} = {value} (fused path moves >= the "
                            "unfused HBM bytes)")
        if name.endswith("/bytes_ratio") and value <= 1.0:
            failures.append(f"{name} = {value:.4f} (must be > 1.0)")

    current = {name: value for name, value, _ in rows}
    if write_baseline:
        with open(BASELINE_PATH, "w") as f:
            json.dump({"schema": 1, "rows": current}, f, indent=1,
                      sort_keys=True)
        print(f"# wrote baseline {BASELINE_PATH}", file=sys.stderr)
    elif os.path.exists(BASELINE_PATH):
        with open(BASELINE_PATH) as f:
            baseline = json.load(f)["rows"]
        for name, base in baseline.items():
            if not name.endswith("/bytes_ratio"):
                continue
            got = current.get(name)
            if got is None:
                failures.append(f"{name} missing (baseline has it)")
            elif got < base * BASELINE_SLACK:
                failures.append(f"{name} = {got:.4f} regressed below "
                                f"baseline {base:.4f}")
    else:
        print(f"# no baseline at {BASELINE_PATH}; run --check "
              "--write-baseline to seed it", file=sys.stderr)
    if failures:
        raise SystemExit("benchmark-regression check FAILED:\n  "
                         + "\n  ".join(failures))
    print(f"# check OK: {len(rows)} analytic rows, "
          f"{len(CHECK_MODULES)} stages", file=sys.stderr)


def main() -> None:
    argv = sys.argv[1:]
    if "--check" in argv:
        argv.remove("--check")
        write_baseline = "--write-baseline" in argv
        if write_baseline:
            argv.remove("--write-baseline")
        if argv:
            raise SystemExit(f"--check takes no modules, got {argv}")
        run_check(write_baseline)
        return
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            raise SystemExit("--json requires an output path")
        json_path = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    unknown = [a for a in argv if a not in MODULES]
    if unknown:
        raise SystemExit(f"unknown module(s) {unknown}; choose from {MODULES}")
    only = argv or None
    print("name,value,note")
    failures = 0
    record: dict = {"schema": 1, "generated_unix": int(time.time()),
                    "modules": {}}
    for mod_name in MODULES:
        if only and mod_name not in only:
            continue
        t0 = time.time()
        mod_rows = []
        status = "ok"
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["rows"])
            for name, value, note in mod.rows():
                if isinstance(value, float):
                    print(f"{name},{value:.6g},{note}")
                else:
                    print(f"{name},{value},{note}")
                mod_rows.append({"name": name, "value": value, "note": note})
        except Exception:  # noqa: BLE001
            failures += 1
            status = "error"
            traceback.print_exc()
            print(f"{mod_name},ERROR,see stderr")
        dt = time.time() - t0
        record["modules"][mod_name] = {
            "status": status, "seconds": round(dt, 3), "rows": mod_rows}
        print(f"# {mod_name} finished in {dt:.1f}s", file=sys.stderr)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=1)
        print(f"# wrote {json_path}", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
