"""Benchmark driver — one module per paper table/figure.

Each bench module exposes ``rows() -> list[(name, value, note)]``; this
driver prints them as ``name,value,note`` CSV (stdout) so the harness
command ``python -m benchmarks.run`` produces a single auditable artifact.

  bench_cost_model   Table I, Fig. 6, Fig. 7   (FLOPs/memory closed forms)
  bench_model_size   Table III                 (model MB + compression x)
  bench_bram         Figs. 11, 12, 14          (BRAM + TPU packing)
  bench_training     Fig. 13, Table III acc    (tensor vs matrix parity)
  bench_memory       Fig. 15, Table V memory   (compiled-step memory)
  bench_flows        Table V latency proxy     (flow wall-times on CPU)
  bench_rank_sweep   (beyond paper)            (rank ablation at arch scale)
"""
from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    "bench_cost_model",
    "bench_model_size",
    "bench_bram",
    "bench_training",
    "bench_memory",
    "bench_flows",
    "bench_rank_sweep",
]


def main() -> None:
    only = sys.argv[1:] or None
    print("name,value,note")
    failures = 0
    for mod_name in MODULES:
        if only and mod_name not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["rows"])
            for name, value, note in mod.rows():
                if isinstance(value, float):
                    print(f"{name},{value:.6g},{note}")
                else:
                    print(f"{name},{value},{note}")
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"{mod_name},ERROR,see stderr")
        print(f"# {mod_name} finished in {time.time() - t0:.1f}s",
              file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
