"""Benchmark driver — one module per paper table/figure.

Each bench module exposes ``rows() -> list[(name, value, note)]``; this
driver prints them as ``name,value,note`` CSV (stdout) so the harness
command ``python -m benchmarks.run`` produces a single auditable artifact.

  bench_cost_model   Table I, Fig. 6, Fig. 7   (FLOPs/memory closed forms)
  bench_model_size   Table III                 (model MB + compression x)
  bench_bram         Figs. 11, 12, 14          (BRAM + TPU packing)
  bench_training     Fig. 13, Table III acc    (tensor vs matrix parity)
  bench_memory       Fig. 15, Table V memory   (compiled-step memory)
  bench_flows        Table V latency proxy     (flow wall-times on CPU)
  bench_rank_sweep   (beyond paper)            (rank ablation at arch scale)
  bench_pu           Sec. III-A PU stage       (fused vs unfused update +
                                                per-stage memory ledger)
  bench_bwd          Sec. III-A BWD stage      (fused single-kernel backward
                                                vs 4-GEMM path: FLOPs, HBM
                                                bytes moved, wall-clock)
  bench_attn         Sec. V-B2 ATTN stage      (flash fwd + single-kernel bwd
                                                vs blockwise+autodiff: FLOPs,
                                                HBM bytes moved, wall-clock)

Usage::

  python -m benchmarks.run [module ...] [--json PATH]

With ``--json PATH`` the same rows are also written as a ``BENCH_*.json``
-style trajectory snapshot.  JSON schema (stable — downstream tooling diffs
these files across commits, so only ADD keys, never rename)::

  {
    "schema": 1,                    # bump on incompatible change
    "generated_unix": 1753833600,   # time.time() at emission
    "modules": {
      "<bench module name>": {
        "status": "ok" | "error",
        "seconds": 12.3,            # wall time for the module's rows()
        "rows": [
          {"name": "fig6/comp_mm_x", # metric path: <figure-or-table>/<metric>
           "value": 22.51,           # float | int | str
           "note": "paper: 22.51x"}, # free-text context, incl. paper value
          ...
        ]
      },
      ...
    }
  }

Row ``name``s are slash-paths: the leading segment identifies the paper
artifact (``fig15``, ``table3``, ``pu``, ...) and the remainder the metric;
``note`` carries the paper's printed value where one exists, so a trajectory
file is self-describing without the paper at hand.
"""
from __future__ import annotations

import json
import sys
import time
import traceback

MODULES = [
    "bench_cost_model",
    "bench_model_size",
    "bench_bram",
    "bench_training",
    "bench_memory",
    "bench_flows",
    "bench_rank_sweep",
    "bench_pu",
    "bench_bwd",
    "bench_attn",
]


def main() -> None:
    argv = sys.argv[1:]
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            raise SystemExit("--json requires an output path")
        json_path = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    unknown = [a for a in argv if a not in MODULES]
    if unknown:
        raise SystemExit(f"unknown module(s) {unknown}; choose from {MODULES}")
    only = argv or None
    print("name,value,note")
    failures = 0
    record: dict = {"schema": 1, "generated_unix": int(time.time()),
                    "modules": {}}
    for mod_name in MODULES:
        if only and mod_name not in only:
            continue
        t0 = time.time()
        mod_rows = []
        status = "ok"
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["rows"])
            for name, value, note in mod.rows():
                if isinstance(value, float):
                    print(f"{name},{value:.6g},{note}")
                else:
                    print(f"{name},{value},{note}")
                mod_rows.append({"name": name, "value": value, "note": note})
        except Exception:  # noqa: BLE001
            failures += 1
            status = "error"
            traceback.print_exc()
            print(f"{mod_name},ERROR,see stderr")
        dt = time.time() - t0
        record["modules"][mod_name] = {
            "status": status, "seconds": round(dt, 3), "rows": mod_rows}
        print(f"# {mod_name} finished in {dt:.1f}s", file=sys.stderr)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=1)
        print(f"# wrote {json_path}", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
