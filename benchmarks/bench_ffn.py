"""FFN stage: fused megakernel (both TT linears + activation in one
pallas_call per direction) vs the two-call path.

The FFN hidden state is the widest per-layer tensor in training; executed
as separate ``btt_linear_op`` calls it round-trips HBM twice in the
forward and again in the backward (saved as the down projection's input
residual).  This module compares the two paths on three axes, mirroring
bench_bwd's BWD-stage methodology:

* **FLOPs** — identical GEMM work by construction; emitted once so
  trajectory files are self-describing.
* **HBM bytes moved** — the analytic traffic models in
  ``kernels.btt_ffn``: the fused side is tile-derived from
  ``choose_ffn_tiles`` (x/gy/y/gx streamed once, half-factors fetched
  once, f32 gradient accumulators flushed once — the hidden state on
  NEITHER side); the unfused side is generous to XLA (its backward
  launches are the per-linear FUSED btt_backward kernels, every
  activation tensor moves once per use).
* **wall-clock** — median jitted fwd+bwd (``jax.grad``) microseconds.  On
  CPU the fused column runs the kernels in *interpret* mode (Python
  emulation) and is an upper bound; TPU is the target.

Emitted rows (CSV via benchmarks.run, JSON schema documented there):
  ffn/paper_block/flops          fwd+bwd GEMM FLOPs, ATIS 768x768 r12 K=32
  ffn/paper_block/fused_bytes    analytic megakernel fwd+bwd HBM traffic
  ffn/paper_block/unfused_bytes  analytic two-call fwd+bwd HBM traffic
  ffn/paper_block/bytes_ratio    unfused / fused (>1 = fused wins)
  ffn/paper_block/fused_us       median jitted grad step (interpret on CPU)
  ffn/paper_block/unfused_us     median jitted two-call grad step
  ffn/paper_block/match_maxerr   max |fused - two-call| over all grads
  ffn/atis_<n>enc/bytes_ratio    min ratio over the config's FFN blocks
  ffn/atis_<n>enc/fewer_bytes    1.0 iff fused < unfused for EVERY block
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.timing import median_us
from repro.configs.atis_transformer import config_n
from repro.core.memory_ledger import _collect_ffn_blocks, _ffn_block_dims
from repro.core.tt import tt_init
from repro.core.tt_linear import make_tt_spec
from repro.kernels import (
    btt_ffn_op,
    fused_ffn_hbm_bytes,
    unfused_ffn_hbm_bytes,
)
from repro.kernels.btt_ffn import ffn_flops
from repro.models import init_params

REPS = 5                # interpret-mode kernels are slow; median of 5
K_PAPER = 32            # batch 1 x seq 32, the paper's training regime
PAPER = (32, 768, 768, 768, 12, 12, 0)  # (K, M, N, F, R1, R2, Rg)


def _config_ffn_dims(cfg):
    """(M, N, F, R1, R2, Rg) of every TT FFN block in the config."""
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    dims = [_ffn_block_dims(b) for b in _collect_ffn_blocks(params)]
    return sorted({d[:6] for d in dims if d is not None})


def _byte_rows():
    """The analytic-only rows (fast; also the run.py --check subset)."""
    K, M, N, F, R1, R2, Rg = PAPER
    fb = fused_ffn_hbm_bytes(K, M, N, F, R1, R2, Rg, 4)
    ub = unfused_ffn_hbm_bytes(K, M, N, F, R1, R2, Rg, 4)
    out = [
        ("ffn/paper_block/flops", float(ffn_flops(K, M, N, F, R1, R2, Rg)),
         "up/down GEMMs fwd+bwd; 768x768 r12; K=32"),
        ("ffn/paper_block/fused_bytes", float(fb),
         "analytic HBM traffic of one fused fwd + one fused bwd launch"),
        ("ffn/paper_block/unfused_bytes", float(ub),
         "two btt_linear launches + act round-trips + two fused "
         "btt_backward launches + act VJP traffic"),
        ("ffn/paper_block/bytes_ratio", ub / fb,
         ">1 = megakernel moves fewer HBM bytes"),
    ]
    for n_enc in (2, 4, 6):
        ratios = [unfused_ffn_hbm_bytes(K_PAPER, m, n, f, r1, r2, rg, 4)
                  / fused_ffn_hbm_bytes(K_PAPER, m, n, f, r1, r2, rg, 4)
                  for m, n, f, r1, r2, rg in _config_ffn_dims(config_n(n_enc))]
        out.append((f"ffn/atis_{n_enc}enc/bytes_ratio", min(ratios),
                    f"min over {len(ratios)} distinct FFN block shapes"))
        out.append((f"ffn/atis_{n_enc}enc/fewer_bytes",
                    1.0 if min(ratios) > 1.0 else 0.0,
                    "1 = fused < unfused HBM bytes for every FFN block"))
    return out


def check_rows():
    """Analytic rows for ``benchmarks.run --check`` (no wall-clock)."""
    return _byte_rows()


def rows():
    K, M, N, F, R1, R2, _ = PAPER
    up_spec = make_tt_spec(F, N, 3, R1)
    down_spec = make_tt_spec(M, F, 3, R2)
    up = tt_init(jax.random.PRNGKey(0), up_spec)
    down = tt_init(jax.random.PRNGKey(1), down_spec)
    x = jax.random.normal(jax.random.PRNGKey(2), (K, N))

    def loss(fused_ffn):
        def f(cu, cd, xx):
            return (btt_ffn_op(list(cu), list(cd), None, xx, up_spec,
                               down_spec, act="gelu", interpret=True,
                               fused_ffn=fused_ffn) ** 2).sum()
        return jax.jit(jax.grad(f, argnums=(0, 1, 2)))

    g_fused = loss(True)
    g_two = loss(False)
    ops = (tuple(up), tuple(down), x)
    gf = g_fused(*ops)
    gu = g_two(*ops)
    err = max(float(jnp.max(jnp.abs(u.astype(jnp.float32)
                                    - v.astype(jnp.float32))))
              for u, v in zip(jax.tree.leaves(gf), jax.tree.leaves(gu)))

    out = _byte_rows()
    out[4:4] = [
        ("ffn/paper_block/fused_us",
         median_us(g_fused, *ops, reps=REPS),
         "megakernel fwd+bwd (interpret mode on CPU; upper bound)"),
        ("ffn/paper_block/unfused_us",
         median_us(g_two, *ops, reps=REPS),
         "two-call fwd + per-linear fused bwd kernels"),
        ("ffn/paper_block/match_maxerr", err,
         "max |fused - two-call| over (core grads, gx)"),
    ]
    return out
