"""Fault-tolerance acceptance rows: guard overhead, recovery, fallback.

The robustness tier (``runtime.guard`` + ``runtime.chaos`` +
``checkpoint.restore_latest_valid``) has three acceptance claims, and each
gets a row here so the trajectory artifact carries the evidence:

  1. the numerics sentry is effectively free — the fused norm/finite/skip
     machinery adds < 3% to the unguarded training-step wall-clock
     (``robustness/overhead/*``; wall-clock, so ``rows()`` only);
  2. a guarded run rides out a deterministic NaN burst and lands within
     5% of the fault-free final loss, while the SAME step with the guard
     mask off diverges (``robustness/recovery/*``);
  3. a corrupted newest checkpoint never loses the run — restore falls
     back to the previous intact step bit-identically
     (``robustness/checkpoint/*``).

Rows 2-3 are deterministic (seeded chaos, no timing), so they are also the
``check_rows()`` set gating CI via ``benchmarks.run --check``.

Emitted rows (CSV via benchmarks.run, JSON schema documented there):
  robustness/recovery/guarded_finite     1 = guarded loss finite post-burst
  robustness/recovery/unguarded_diverged 1 = guard_on=False run went NaN
  robustness/recovery/rel_loss_err       |faulted - clean| / clean final loss
  robustness/recovery/recovered          1 = rel_loss_err <= 0.05 (acceptance)
  robustness/recovery/skipped_steps      in-jit masked steps (== burst len)
  robustness/checkpoint/fallback_ok      1 = corrupt latest -> earlier step
  robustness/checkpoint/bitwise          1 = fallback leaves bit-identical
  robustness/overhead/unguarded_us       median unguarded train step
  robustness/overhead/guarded_us         median guarded train step
  robustness/overhead/frac               guarded/unguarded - 1
  robustness/overhead/under_3pct         1 = frac < 0.03 (acceptance)
"""
from __future__ import annotations

import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import median_us

STEPS = 150         # recovery run length (tiny model, seconds on CPU)
BURST = range(12, 15)  # NaN-burst steps (after EWMA warmup, before the end)


def _quad_problem():
    """Tiny noisy least-squares problem: y = A x + eps, fit W.

    The 0.1-std label noise puts an irreducible floor (~0.01 MSE) under the
    loss, so both the fault-free and the guarded-faulted run converge TO
    THE FLOOR well before STEPS and the 5% relative comparison is stable —
    a noiseless quadratic decays toward 0 forever and makes the relative
    error between two runs a coin flip."""
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
    X = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    Y = X @ A.T + jnp.asarray(rng.normal(size=(64, 16)) * 0.1, jnp.float32)
    params = {"w": jnp.asarray(rng.normal(size=(16, 16)) * 0.1, jnp.float32)}
    return params, (X, Y)


def _recovery_rows():
    from repro.optim import adamw
    from repro.runtime.chaos import ChaosPlan, GradFault
    from repro.runtime.guard import (
        GuardPolicy, TrainGuard, guard_controls, make_guarded_step)

    params0, batch = _quad_problem()
    opt = adamw(1e-1, fused=True)
    step = jax.jit(make_guarded_step(
        lambda p, b: jnp.mean(jnp.square(b[0] @ p["w"].T - b[1])), opt))
    plan = ChaosPlan(grad_faults=(
        GradFault(step=BURST.start, length=len(BURST), mode="nan"),))

    def run(*, faults: bool, guard_on: bool):
        # recover_after=10: the post-burst lr backoff heals fast enough
        # that both runs sit on the noise floor at STEPS.
        guard = TrainGuard(GuardPolicy(warmup=4, recover_after=10))
        params = jax.tree.map(jnp.array, params0)
        state = guard.attach(opt.init(params))
        loss = float("nan")
        for i in range(STEPS):
            if guard_on:
                ctrl = guard.controls(
                    fault_add=plan.fault_add(i) if faults else 0.0)
            else:
                ctrl = guard_controls(
                    fault_add=plan.fault_add(i) if faults else 0.0,
                    guard_on=False)
            params, state, m = step(params, state, batch, ctrl)
            if guard_on:
                params, state, _ = guard.observe(i, m, params, state)
            loss = float(m["loss"])
        return loss, guard.report()

    clean, _ = run(faults=False, guard_on=True)
    faulted, rep = run(faults=True, guard_on=True)
    unguarded, _ = run(faults=True, guard_on=False)
    rel = abs(faulted - clean) / max(abs(clean), 1e-12)
    return [
        ("robustness/recovery/guarded_finite",
         1.0 if np.isfinite(faulted) else 0.0,
         f"final loss after {len(BURST)}-step NaN burst is finite"),
        ("robustness/recovery/unguarded_diverged",
         0.0 if np.isfinite(unguarded) else 1.0,
         "same step + burst with guard_on=False goes NaN (control)"),
        ("robustness/recovery/rel_loss_err", rel,
         f"guarded faulted {faulted:.4g} vs fault-free {clean:.4g}"),
        ("robustness/recovery/recovered", 1.0 if rel <= 0.05 else 0.0,
         "1 = within 5% of the fault-free final loss (acceptance)"),
        ("robustness/recovery/skipped_steps", float(rep["skipped"]),
         f"in-jit masked steps; burst injected {len(BURST)}"),
    ]


def _checkpoint_rows():
    from repro.checkpoint import restore_latest_valid, save
    from repro.runtime.chaos import corrupt_checkpoint

    tree10 = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
              "step": jnp.asarray(10)}
    tree20 = {"w": tree10["w"] * 2.0, "step": jnp.asarray(20)}
    tmpl = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree10)
    root = tempfile.mkdtemp(prefix="bench_robustness_ckpt_")
    try:
        save(root, 10, tree10)
        save(root, 20, tree20)
        corrupt_checkpoint(root, 20, mode="flip", seed=0)
        got = restore_latest_valid(root, tmpl)
        ok = got is not None and got[0][1] == 10 and got[1] == [20]
        bitwise = ok and all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(tree10),
                            jax.tree.leaves(got[0][0])))
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return [
        ("robustness/checkpoint/fallback_ok", 1.0 if ok else 0.0,
         "corrupt latest (CRC) -> restore falls back to prior step"),
        ("robustness/checkpoint/bitwise", 1.0 if bitwise else 0.0,
         "fallback leaves bit-identical to what was saved"),
    ]


def _overhead_rows():
    from repro.configs.atis_transformer import config_n
    from repro.launch.steps import make_train_step
    from repro.models import init_params
    from repro.optim import adamw
    from repro.runtime.guard import guard_controls

    cfg = config_n(2).scaled_down(d_model=128, n_heads=4, d_ff=128,
                                  vocab_size=1000, num_layers=2)
    opt = adamw(1e-3, fused=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    rng = np.random.default_rng(0)
    # Big enough that fwd/bwd dominates: the guard's fixed cost (the
    # masked select over params + opt state) must amortize, which is the
    # deployment regime the 3% acceptance is about.
    B, S = 32, 128
    batch = {
        "tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    plain = jax.jit(make_train_step(cfg, opt))
    guarded = jax.jit(make_train_step(cfg, opt, guard=True))
    ctrl = guard_controls()
    t_plain = median_us(plain, params, state, batch, reps=10)
    state_g = dict(state, lr_scale=jnp.float32(1.0))
    t_guard = median_us(guarded, params, state_g, batch, ctrl, reps=10)
    frac = t_guard / t_plain - 1.0
    return [
        ("robustness/overhead/unguarded_us", t_plain,
         "median fused ATIS train step, no guard"),
        ("robustness/overhead/guarded_us", t_guard,
         "same step via apply_guarded_update (norm/finite/skip fused)"),
        ("robustness/overhead/frac", frac,
         "guarded/unguarded - 1; acceptance < 0.03"),
        ("robustness/overhead/under_3pct", 1.0 if frac < 0.03 else 0.0,
         "1 = guard overhead under 3% (acceptance; wall-clock, CPU)"),
    ]


def check_rows():
    """Deterministic rows for ``benchmarks.run --check`` (no wall-clock)."""
    return _recovery_rows() + _checkpoint_rows()


def rows():
    return check_rows() + _overhead_rows()
