"""Table V latency/energy proxy — wall-clock of the contraction flows.

The paper's energy claim reduces to executed FLOPs + moved bytes.  On this
CPU container we CAN measure that the BTT flow's analytic FLOP reduction
translates into real wall-time reduction through XLA (same numerics, same
result): dense MM vs right-to-left TT vs BTT vs fused-BTT, forward and
fwd+bwd, at the paper's layer size and at a scaled-up layer."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import (
    TTSpec,
    tt_forward_btt,
    tt_forward_rl,
    tt_init,
    tt_reconstruct,
)
from repro.core.tt_linear import _btt_fused


def _time(fn, *args, iters=20):
    jax.block_until_ready(fn(*args))  # warm up / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _suite(spec: TTSpec, K: int, tag: str):
    cores = tuple(tt_init(jax.random.PRNGKey(0), spec))
    w = tt_reconstruct(cores, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (K, spec.in_dim))

    dense = jax.jit(lambda xx: xx @ w.T)
    rl = jax.jit(lambda xx: tt_forward_rl(cores, xx, spec))
    btt = jax.jit(lambda xx: tt_forward_btt(cores, xx, spec))

    g_dense = jax.jit(jax.grad(lambda ww, xx: (xx @ ww.T).sum(), argnums=(0, 1)))
    g_btt = jax.jit(jax.grad(
        lambda cs, xx: tt_forward_btt(list(cs), xx, spec).sum(), argnums=(0, 1)))
    g_fused = jax.jit(jax.grad(
        lambda cs, xx: _btt_fused(cs, xx, spec).sum(), argnums=(0, 1)))

    rows = [
        (f"flows/{tag}/fwd_dense_us", _time(dense, x), ""),
        (f"flows/{tag}/fwd_rl_us", _time(rl, x), ""),
        (f"flows/{tag}/fwd_btt_us", _time(btt, x), "paper's contraction"),
        (f"flows/{tag}/bwd_dense_us", _time(lambda xx: g_dense(w, xx), x), ""),
        (f"flows/{tag}/bwd_btt_us", _time(lambda xx: g_btt(cores, xx), x), ""),
        (f"flows/{tag}/bwd_btt_fused_us", _time(lambda xx: g_fused(cores, xx), x),
         "fused backward (Sec. V-B2)"),
    ]
    d, b = rows[0][1], rows[2][1]
    rows.append((f"flows/{tag}/fwd_speedup_btt_vs_dense", d / b,
                 "FLOP model predicts >1 when K >> r"))
    return rows


def rows():
    paper = TTSpec((8, 8, 12), (12, 8, 8), 12, clamp_ranks=False)
    big = TTSpec((16, 16, 16), (16, 16, 16), 32)
    return _suite(paper, 32, "paper_768") + _suite(big, 1024, "4096x4096")
