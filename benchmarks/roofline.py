"""§Roofline report generator: dry-run artifacts -> per-cell roofline terms.

For every (arch x shape x mesh x tt-mode) JSON+HLO pair under
``artifacts/dryrun*/``, computes the three roofline terms on TPU v5e
hardware constants and emits a markdown table + machine-readable JSON:

  compute term    = HLO_FLOPs_per_device / 197e12        [s]
  memory term     = HLO_bytes_per_device / 819e9         [s]
  collective term = wire_bytes_per_device / 50e9         [s]

FLOPs/bytes come from the trip-count-aware HLO walker (launch.hlo_flops) —
``cost_analysis()`` counts while bodies once and is reported alongside for
comparison.  MODEL_FLOPS uses the standard 6·N·D (dense) / 6·N_active·D
(MoE) training estimate, or 2·N·D for serving, so the useful-work ratio
exposes remat/redundancy overhead.

Run: PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import SHAPES, get_config
from repro.launch.hlo_analysis import V5E
from repro.launch.hlo_flops import analyze_hlo
from repro.models.transformer import init_params, num_params

HW = V5E()


_PARAM_CACHE: dict = {}


def _model_params(arch: str):
    """(total_params, active_params) of the DENSE model — useful work is
    technique-independent (the TT model computes the same token function),
    so TT cells are scored against the same 6·N_dense·D yardstick; their
    sub-1.0 'useful' ratio then directly reads as the compute *saving*."""
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    import jax
    cfg = get_config(arch)
    tree = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    total = num_params(tree)
    active = total
    if cfg.moe is not None:
        # subtract non-routed expert params: active = shared + top_k experts
        m = cfg.moe
        n_moe_layers = cfg.num_layers // max(m.every, 1)
        per_expert = 3 * m.d_expert * cfg.d_model
        active = total - n_moe_layers * (m.padded_experts - m.top_k) * per_expert
    _PARAM_CACHE[arch] = (total, active)
    return total, active


def model_flops(arch: str, shape_name: str, tt: bool, devices: int) -> float:
    """Per-device useful-work estimate (dense-equivalent; see _model_params)."""
    del tt
    shape = SHAPES[shape_name]
    total, active = _model_params(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens / devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens / devices
    tokens = shape.global_batch  # decode: one token per request
    return 2.0 * active * tokens / devices


def load_cells(art_dir: str) -> list[dict]:
    cells = []
    for fn in sorted(os.listdir(art_dir)):
        if not fn.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(art_dir, fn)))
        if rec.get("status") != "ok":
            cells.append(rec)
            continue
        hlo_path = os.path.join(art_dir, fn[:-5] + ".hlo.txt")
        if os.path.exists(hlo_path):
            stats = analyze_hlo(open(hlo_path).read())
            rec["walker"] = stats.as_dict()
        cells.append(rec)
    return cells


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok" or "walker" not in rec:
        return None
    w = rec["walker"]
    devices = rec["devices"]
    t_comp = w["flops"] / HW.peak_flops
    t_mem = w["bytes"] / HW.hbm_bw
    t_coll = w["collective_wire_bytes"] / HW.ici_bw
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"], rec["tt_mode"] == "tt",
                     devices)
    step_s = max(terms.values())
    useful = mf / max(w["flops"], 1.0)
    # roofline fraction: useful-work time at peak / bound step time
    frac = (mf / HW.peak_flops) / max(step_s, 1e-30)
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "tt_mode")},
        "flops": w["flops"], "bytes": w["bytes"],
        "wire_bytes": w["collective_wire_bytes"],
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops": mf, "useful_ratio": useful,
        "roofline_frac": frac,
        "xla_flops_body_once": rec.get("cost_analysis", {}).get("flops"),
        "temp_bytes_dev": rec.get("memory_analysis", {}).get(
            "temp_size_in_bytes"),
    }


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mode | mesh | compute_s | memory_s | collective_s "
           "| bound | useful | roofline |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['tt_mode']} | {r['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['bottleneck']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.1%} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--json-out", default="artifacts/roofline.json")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    rows = [r for r in (roofline_row(c) for c in cells) if r is not None]
    rows.sort(key=lambda r: (r["mesh"], r["tt_mode"], r["arch"], r["shape"]))
    print(markdown_table(rows))
    skipped = [c for c in cells if c.get("status") == "skipped"]
    print(f"\n{len(rows)} cells analyzed, {len(skipped)} documented skips")
    os.makedirs(os.path.dirname(args.json_out), exist_ok=True)
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {args.json_out}")


if __name__ == "__main__":
    main()
