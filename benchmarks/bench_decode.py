"""DECODE stage: paged flash-decode serving vs the unfused decode path.

Training's fused stages (bench_bwd/attn/ffn) have a serving mirror: at
decode the per-step tensors are single token ROWS, so the HBM-traffic war
is fought over (a) the KV cache — streamed page-table-indirectly exactly
once by ``flash_decode_pallas`` vs gathered into a contiguous copy and
re-read by the unfused path — and (b) the TT half-factors, which the
decode-shape BTT kernels pin in VMEM across a decode burst while the
unfused path re-fetches them every step.  This module compares the two
paths with the same methodology as the training stages:

* **FLOPs** — identical by construction; emitted once for context.
* **HBM bytes moved** — the analytic per-decode-step models in
  ``kernels.flash_decode`` / ``btt_linear`` / ``btt_ffn``: the fused side
  tile-derived from the decode choosers (sublane-granule row tiles,
  half-factor fetches amortized over ``STEPS`` pinned steps); the unfused
  side generous to XLA (every tensor moves once per use, no copy loops
  beyond the unavoidable cache gather).
* **wall-clock** — steady-state continuous-batched tokens/s of the real
  ``PagedDecodeEngine`` vs concurrency (pure-JAX paged path: interpret-mode
  Pallas is Python emulation on CPU and would measure the emulator).

Emitted rows (CSV via benchmarks.run; ``check_rows`` = analytic subset):
  decode/attn/flops              one GQA decode-attention step, S=256
  decode/attn/{fused,unfused}_bytes, bytes_ratio
  decode/linear/bytes_ratio      paper 768x768 r12 TT linear, B=8 streams
  decode/ffn/bytes_ratio         paper FFN block, decode row tiles
  decode/atis_<n>enc/bytes_ratio whole-model per-step bytes (attn + every
                                 TT projection + FFN), min over nothing —
                                 one total, fused/unfused summed
  decode/atis_<n>enc/fewer_bytes 1.0 iff fused < unfused
  decode/atis_<n>enc/DECODE_mb   DECODE-stage ledger (weights bram + paged
                                 KV pools and transients uram)
  decode/atis_<n>enc/fits        1.0 iff inside the 6 MB BRAM + 22.5 MB
                                 URAM envelope
  decode/throughput/c<k>_tok_s   steady-state tokens/s at concurrency k
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.atis_transformer import config_n
from repro.core.memory_ledger import (
    _collect_ffn_blocks,
    _collect_modules,
    _ffn_block_dims,
    _stacked_multiplier,
    decode_ledger_rows,
)
from repro.kernels.btt_ffn import (
    fused_decode_ffn_hbm_bytes,
    unfused_decode_ffn_hbm_bytes,
)
from repro.kernels.btt_linear import (
    fused_decode_linear_hbm_bytes,
    unfused_decode_linear_hbm_bytes,
)
from repro.kernels.flash_decode import (
    decode_attn_flops,
    fused_decode_attn_hbm_bytes,
    unfused_decode_attn_hbm_bytes,
)
from repro.models import init_params
from repro.runtime.decode_engine import PagedDecodeEngine
from repro.runtime.kv_cache import pages_for

B_STREAMS = 8      # concurrent decode slots in the serving regime
SEQ = 256          # steady-state context length per stream
PAGE = 64          # KV page size (kernels.flash_decode.DEFAULT_PAGE_SIZE)
STEPS = 64         # decode burst the VMEM-pinned half-factors amortize over
GQA = (32, 8, 128)  # (H, KV, d_head) — lane-aligned GQA serving shape
PAPER_LIN = (768, 768, 12)   # ATIS (M, N, R)
PAPER_FFN = (768, 768, 768, 12, 12, 0)  # (M, N, F, R1, R2, Rg)
# The envelope point: the paper's on-chip regime scaled to serving —
# 4 slots, 64-token contexts, 32-row pages (ledger fits 6 + 22.5 MB here).
LEDGER_B, LEDGER_LEN, LEDGER_PAGE = 4, 64, 32


def _config_step_bytes(cfg, *, batch: int, seq: int, page: int,
                       steps: int) -> tuple[int, int]:
    """(fused, unfused) analytic HBM bytes of ONE whole-model decode step:
    per-layer paged attention + every TT projection + every FFN block, at
    the shapes the config actually ships (eval_shape walk, the same one
    the memory ledger does)."""
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    it = np.dtype(cfg.dtype).itemsize
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    fused = cfg.num_layers * fused_decode_attn_hbm_bytes(
        batch, H, KV, dh, page, pages_for(seq, page), it)
    unfused = cfg.num_layers * unfused_decode_attn_hbm_bytes(
        batch, H, KV, dh, seq, it)

    ffn_mods: set[int] = set()
    for blk in _collect_ffn_blocks(params):
        dims = _ffn_block_dims(blk)
        if dims is None:
            continue
        M, N, F, R1, R2, Rg, _, mult = dims
        for key in ("up", "down", "gate"):
            if key in blk:
                ffn_mods.add(id(blk[key]))
        fused += mult * fused_decode_ffn_hbm_bytes(
            batch, M, N, F, R1, R2, Rg, it, steps=steps)
        unfused += mult * unfused_decode_ffn_hbm_bytes(
            batch, M, N, F, R1, R2, Rg, it)

    tts, _ = _collect_modules(params)
    for m in tts:
        if id(m) in ffn_mods:
            continue
        mult = _stacked_multiplier(m)
        M, N, R = m.spec.out_dim, m.spec.in_dim, m.spec.mid_rank
        fused += mult * fused_decode_linear_hbm_bytes(batch, M, N, R, it,
                                                      steps=steps)
        unfused += mult * unfused_decode_linear_hbm_bytes(batch, M, N, R,
                                                          it)
    return fused, unfused


def check_rows():
    """Analytic rows for ``benchmarks.run --check`` (no wall-clock)."""
    it = 4
    H, KV, dh = GQA
    fa = fused_decode_attn_hbm_bytes(B_STREAMS, H, KV, dh, PAGE,
                                     pages_for(SEQ, PAGE), it)
    ua = unfused_decode_attn_hbm_bytes(B_STREAMS, H, KV, dh, SEQ, it)
    M, N, R = PAPER_LIN
    fl = fused_decode_linear_hbm_bytes(B_STREAMS, M, N, R, it, steps=STEPS)
    ul = unfused_decode_linear_hbm_bytes(B_STREAMS, M, N, R, it)
    ff = fused_decode_ffn_hbm_bytes(B_STREAMS, *PAPER_FFN, it, steps=STEPS)
    uf = unfused_decode_ffn_hbm_bytes(B_STREAMS, *PAPER_FFN, it)
    out = [
        ("decode/attn/flops",
         float(decode_attn_flops(B_STREAMS, H, dh, SEQ)),
         f"qK^T + pV over S={SEQ} live rows, {B_STREAMS} GQA streams"),
        ("decode/attn/fused_bytes", float(fa),
         "flash-decode launch: pages streamed once, softmax state in VMEM"),
        ("decode/attn/unfused_bytes", float(ua),
         "contiguous gather + score/prob rows round-tripping HBM"),
        ("decode/attn/bytes_ratio", ua / fa,
         ">1 = paged kernel moves fewer HBM bytes"),
        ("decode/linear/bytes_ratio", ul / fl,
         f"768x768 r12 row tiles, half-factors pinned {STEPS} steps"),
        ("decode/ffn/bytes_ratio", uf / ff,
         "megakernel row tiles vs two-call with hidden round-trip"),
    ]
    for n_enc in (2, 4, 6):
        cfg = config_n(n_enc).with_tt(flow="kernel")
        fb, ub = _config_step_bytes(cfg, batch=B_STREAMS, seq=SEQ,
                                    page=PAGE, steps=STEPS)
        out.append((f"decode/atis_{n_enc}enc/bytes_ratio", ub / fb,
                    "whole-model per-decode-step HBM bytes, "
                    "attn + projections + FFN"))
        out.append((f"decode/atis_{n_enc}enc/fewer_bytes",
                    1.0 if ub > fb else 0.0,
                    "1 = fused < unfused HBM bytes per decode step"))
        out.extend(decode_ledger_rows(cfg, f"decode/atis_{n_enc}enc",
                                      batch=LEDGER_B, max_len=LEDGER_LEN,
                                      page_size=LEDGER_PAGE, fused=True))
    return out


def _tokens_per_sec(concurrency: int) -> float:
    """Steady-state continuous-batched decode throughput of the real
    engine (pure-JAX paged path; interpret-mode Pallas would measure the
    Python emulator, not the dataflow)."""
    cfg = get_config("llama3-8b").scaled_down().with_tt(
        mode="tt", rank=8, embed_rank=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    P, steps = 16, 8
    rng = np.random.RandomState(0)
    eng = PagedDecodeEngine(cfg, params, page_size=16,
                            max_concurrency=concurrency,
                            max_len=P + steps + 2, fused_decode=False)
    for slot in range(concurrency):
        eng.prefill(slot, rng.randint(1, cfg.vocab_size, size=(P,)))
    toks = rng.randint(1, cfg.vocab_size,
                       size=(concurrency,)).astype(np.int32)
    poss = np.full((concurrency,), P, np.int32)
    jax.block_until_ready(eng.decode_step(toks, poss))  # compile
    poss += 1
    t0 = time.time()
    for _ in range(steps):
        lg = eng.decode_step(toks, poss)
        poss += 1
    jax.block_until_ready(lg)
    return concurrency * steps / (time.time() - t0)


def rows():
    out = check_rows()
    t1 = _tokens_per_sec(1)
    t4 = _tokens_per_sec(4)
    out += [
        ("decode/throughput/c1_tok_s", t1,
         "scaled-down llama3 TT r8; paged pure-JAX path; CPU"),
        ("decode/throughput/c4_tok_s", t4,
         "same engine, 4 continuously-batched slots"),
        ("decode/throughput/batch_speedup", t4 / t1,
         "continuous batching amortizes the per-step launch"),
    ]
    return out
