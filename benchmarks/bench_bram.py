"""Paper Sec. V-C / Figs. 11, 12, 14 — BRAM allocation model + tensor-core
grouping, plus the TPU (8,128)-tile packing analogue.

Fig. 12 claim: grouping K=(d-1)L cores lifts BRAM utilization 3.9x-8.4x.
Fig. 14: grouped allocation tracks the ideal (theoretical-limit) usage."""
from __future__ import annotations

import math

from repro.core.cost_model import (
    BRAM_BITS,
    bram_blocks,
    bram_efficiency,
    tpu_packing_efficiency,
)

# ATIS accelerator geometry: L encoders x 6 TT linears x 2d cores each.
D_TENSOR = 3
CORE_DEPTH = 8 * 12          # (r, n, r) core streamed along rank: n*r rows
RANK = 12


def _n_cores(layers: int) -> int:
    return layers * 6 * 2 * D_TENSOR


def rows():
    out = []
    # --- Fig. 12: utilization efficiency vs model size, all strategies ----
    for layers in (2, 4, 6):
        n = _n_cores(layers)
        group = (D_TENSOR - 1) * layers
        for strat in ("partition", "reshape"):
            base = bram_efficiency(n, CORE_DEPTH, RANK, strategy=strat, group=1)
            grp = bram_efficiency(n, CORE_DEPTH, RANK, strategy=strat,
                                  group=group)
            out.append((f"fig12/{layers}enc/{strat}/eta_default", base, ""))
            out.append((f"fig12/{layers}enc/{strat}/eta_grouped", grp, ""))
            out.append((f"fig12/{layers}enc/{strat}/gain_x", grp / base,
                        "paper: 3.9x-8.4x"))

    # --- Fig. 14: BRAM blocks vs rank, grouped vs default vs ideal --------
    for rank in (4, 12, 24, 48):
        n = _n_cores(6)
        depth = 8 * rank
        blocks_default = bram_blocks(n, depth, rank, strategy="reshape", group=1)
        blocks_grouped = bram_blocks(n, depth, rank, strategy="reshape",
                                     group=(D_TENSOR - 1) * 6)
        ideal = math.ceil(n * depth * rank * 32 / BRAM_BITS)
        out.append((f"fig14/rank{rank}/blocks_default", blocks_default, ""))
        out.append((f"fig14/rank{rank}/blocks_grouped", blocks_grouped,
                    f"ideal: {ideal}"))
        out.append((f"fig14/rank{rank}/grouped_over_ideal",
                    blocks_grouped / ideal, "1.0 = theoretical limit"))

    # --- TPU analogue: (8,128) tile padding vs flat-packed core stacks ----
    core_shapes = [(1, 12, 12), (12, 8, 12), (12, 8, 12), (12, 8, 12),
                   (12, 8, 12), (12, 12, 1)]
    for layers in (2, 6, 24):
        eta_i, eta_p = tpu_packing_efficiency(core_shapes, n_layers=layers)
        out.append((f"tpu_packing/{layers}layers/eta_individual", eta_i, ""))
        out.append((f"tpu_packing/{layers}layers/eta_packed", eta_p,
                    "flat-packed stacks"))
        out.append((f"tpu_packing/{layers}layers/gain_x", eta_p / eta_i,
                    "TPU edition of Fig. 12"))
    return out
