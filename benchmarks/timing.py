"""Shared wall-clock helper for the benchmark modules.

One definition so the fused-vs-unfused timing columns emitted by different
modules (bench_pu, bench_bwd, ...) stay methodologically comparable: warm
the jit cache with ``warmup`` fully-blocked calls, then report the median
of ``reps`` runs, each blocked on EVERY output leaf, in microseconds.

Blocking matters twice: the warmup call must be blocked too (otherwise its
async dispatch bleeds into the first timed rep), and ``block_until_ready``
is applied to the whole output pytree — a tuple/dict result with one
not-yet-ready leaf would otherwise report dispatch latency, not compute.
(``jax.block_until_ready`` maps over pytree leaves, so every output leaf
is awaited.)
"""
from __future__ import annotations

import time

import jax
import numpy as np

__all__ = ["median_us"]


def median_us(fn, *args, reps: int = 20, warmup: int = 1) -> float:
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(*args))  # compile + settle, fully blocked
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)
