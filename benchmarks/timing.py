"""Shared wall-clock helper for the benchmark modules.

One definition so the fused-vs-unfused timing columns emitted by different
modules (bench_pu, bench_bwd, ...) stay methodologically comparable: warm
the jit cache with one call, then report the median of ``reps`` blocked
runs in microseconds.
"""
from __future__ import annotations

import time

import jax
import numpy as np

__all__ = ["median_us"]


def median_us(fn, *args, reps: int = 20) -> float:
    fn(*args)  # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)
