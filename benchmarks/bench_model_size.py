"""Paper Table III — model sizes and compression ratios, 2/4/6-encoder ATIS
transformers, FP32.

Paper:  36.7 -> 1.2 MB (30.5x) | 65.1 -> 1.5 MB (43.4x) | 93.5 -> 1.8 MB (52.0x)

Our model omits the segment-embedding table (synthetic single-segment data)
and uses a 64-entry learned position table (paper trains seq 32), so the
absolute MBs sit slightly below the paper's; the compression RATIO is the
reproduction target and lands in the same band when the same tables are
compressed."""
from __future__ import annotations

import jax

from repro.configs.atis_transformer import config_n
from repro.models import init_params, param_bytes
from repro.models.classifier import atis_heads_init

PAPER_TABLE_III = {2: (36.7, 1.2), 4: (65.1, 1.5), 6: (93.5, 1.8)}


def _size_mb(n_enc: int, tt_mode: str) -> float:
    cfg = config_n(n_enc, tt_mode=tt_mode)
    params = jax.eval_shape(
        lambda: {"backbone": init_params(jax.random.PRNGKey(0), cfg),
                 "heads": atis_heads_init(jax.random.PRNGKey(1), cfg, 26, 120)})
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree.leaves(params)) / 1e6


def rows():
    out = []
    for n_enc, (paper_mm, paper_tt) in PAPER_TABLE_III.items():
        mm = _size_mb(n_enc, "off")
        tt = _size_mb(n_enc, "tt")
        out.append((f"table3/{n_enc}enc/matrix_mb", mm, f"paper: {paper_mm}"))
        out.append((f"table3/{n_enc}enc/tensor_mb", tt, f"paper: {paper_tt}"))
        out.append((f"table3/{n_enc}enc/compression_x", mm / tt,
                    f"paper: {paper_mm / paper_tt:.1f}x"))
    return out
