"""Parameter-update (PU) stage: fused Pallas kernel vs unfused XLA update.

The paper's training step has three on-chip stages (Sec. III-A); FWD/BWD
fusion is covered by bench_flows.  This module times stage 3 in isolation
over the real ATIS TT parameter tree: ``opt.update`` jitted with donated
buffers, pure-JAX (``fused=False``) vs the fused Pallas kernel
(``fused=True``, interpret mode on CPU — the *interpret* column measures
the Python-emulated kernel, so on this backend it is an upper bound; TPU is
the target where the fused path wins by touching each buffer once).

Also reports the memory-ledger PU-stage residency, connecting the timing to
the on-chip budget the kernel is designed for.

Emitted rows (CSV via benchmarks.run, JSON schema documented there):
  pu/<opt>/unfused_us       median jitted unfused update, microseconds
  pu/<opt>/fused_us         median jitted fused update (interpret on CPU)
  pu/<opt>/match_maxerr     max |fused - unfused| over params after a step
  pu/atis_<n>enc/<opt>/bytes_ratio   analytic unfused / fused HBM bytes
                            (unfused: per-leaf tile-padded footprints;
                            fused: dense flat packing — paper Eqs. 24/25)
  pu/atis_<n>enc/<opt>/fewer_bytes   1.0 iff fused < unfused
  pu/atis_<n>enc/adamw_sketched/bytes_ratio   dense-fused / sketched HBM
                            bytes (the sketched kernel drops the dense
                            moment traffic entirely)
  pu/atis_<n>enc/adamw_sketched/fewer_bytes   1.0 iff sketched < dense
  pu/atis_<n>enc/adamw_sketched/moment_shrink  dense moment bytes /
                            sketch state bytes (ledger-derived; the paper
                            envelope's BRAM win)
  pu/adamw_sketched/fused_us  median jitted sketched update
  pu/ledger/<stage>_mb      ledger stage totals for the ATIS config
  pu/ledger/fits            1.0 iff peaks fit the 6 + 22.5 MB envelope
  pu/ledger_sketched/*      same ledger rows with sketched AdamW moments
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.timing import median_us
from repro.configs.atis_transformer import config_n
from repro.core.memory_ledger import ledger_rows, training_step_ledger
from repro.kernels.fused_update import (
    fused_pu_hbm_bytes,
    sketched_pu_hbm_bytes,
    unfused_pu_hbm_bytes,
)
from repro.models import init_params
from repro.optim import adamw, sgd

REPS = 20


def _max_err(a, b) -> float:
    return max(
        float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def check_rows():
    """Analytic rows for ``benchmarks.run --check`` (no wall-clock)."""
    out = []
    for n_enc in (2, 4, 6):
        cfg = config_n(n_enc)
        params = jax.eval_shape(
            lambda c=cfg: init_params(jax.random.PRNGKey(0), c))
        leaves = jax.tree.leaves(params)
        for opt, mom in (("sgd", 0.9), ("adamw", 0.0)):
            fb = fused_pu_hbm_bytes(leaves, opt, momentum=mom)
            ub = unfused_pu_hbm_bytes(leaves, opt, momentum=mom)
            out.append((f"pu/atis_{n_enc}enc/{opt}/bytes_ratio", ub / fb,
                        "unfused counts each TT core at its per-leaf "
                        "(8,128)-tile-padded footprint; fused the packed "
                        "buffers"))
            out.append((f"pu/atis_{n_enc}enc/{opt}/fewer_bytes",
                        1.0 if fb < ub else 0.0,
                        "1 = fused < unfused HBM bytes for this tree"))
        # Sketched AdamW vs the dense fused kernel: the dense moment
        # traffic (16 bytes/elem) is replaced by O(depth*width) per launch,
        # and the persistent moment state shrinks by moment_shrink.
        fb_dense = fused_pu_hbm_bytes(leaves, "adamw")
        sb = sketched_pu_hbm_bytes(leaves)
        out.append((f"pu/atis_{n_enc}enc/adamw_sketched/bytes_ratio",
                    fb_dense / sb,
                    "dense-fused / sketched HBM bytes: no dense m/v "
                    "streams"))
        out.append((f"pu/atis_{n_enc}enc/adamw_sketched/fewer_bytes",
                    1.0 if sb < fb_dense else 0.0,
                    "1 = sketched < dense-fused HBM bytes"))
        dense_mom = training_step_ledger(cfg, "adamw")["PU"].entry(
            "moments").nbytes
        sk_mom = training_step_ledger(cfg, "adamw", sketched=True)[
            "PU"].entry("moments").nbytes
        out.append((f"pu/atis_{n_enc}enc/adamw_sketched/moment_shrink",
                    dense_mom / sk_mom,
                    "dense AdamW moment bytes / sketch state bytes "
                    "(ledger-derived; acceptance floor 4x)"))
    return out


def rows():
    cfg = config_n(2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    grads = jax.tree.map(
        lambda p: 0.01 * jnp.ones_like(p, dtype=jnp.float32), params)
    out = []
    for name, mk in (("sgd", lambda f: sgd(4e-3, momentum=0.9, fused=f)),
                     ("adamw", lambda f: adamw(1e-3, weight_decay=0.01,
                                               fused=f))):
        opt_u, opt_f = mk(False), mk(True)
        state = opt_u.init(params)

        def run(opt):
            # No donate_argnums: the timing loop reuses the same param/state
            # buffers every rep (donated inputs would be invalidated), and on
            # CPU — where this bench runs — donation is a no-op anyway.  The
            # in-place aliased path is exercised by the training drivers.
            return jax.jit(lambda g, p, s: opt.update(g, p, s, s["step"]))

        upd_u, upd_f = run(opt_u), run(opt_f)
        err = _max_err(upd_u(grads, params, state)[0],
                       upd_f(grads, params, state)[0])
        t_u = median_us(upd_u, grads, params, state, reps=REPS)
        t_f = median_us(upd_f, grads, params, state, reps=REPS)
        out.append((f"pu/{name}/unfused_us", t_u, "pure-JAX XLA update"))
        out.append((f"pu/{name}/fused_us", t_f,
                    "Pallas fused kernel (interpret mode on CPU)"))
        out.append((f"pu/{name}/match_maxerr", err,
                    "max |fused - unfused| over params after one step"))
    # Sketched AdamW: timing only (numerics vs the dense path are bounded by
    # the optimizer-oracle suite in tests/test_sketched_update.py, not by a
    # maxerr row — the sketch is lossy by design).
    opt_s = adamw(1e-3, weight_decay=0.01, sketched=True)
    state_s = opt_s.init(params)
    if "vs" in state_s:
        upd_s = jax.jit(lambda g, p, s: opt_s.update(g, p, s, s["step"]))
        t_s = median_us(upd_s, grads, params, state_s, reps=REPS)
        out.append(("pu/adamw_sketched/fused_us", t_s,
                    "Pallas sketched-update kernel (interpret mode on CPU)"))
    out.extend(check_rows())
    # momentum=0.9 so the ledger describes the SGD configuration timed above
    # (a mu moment buffer + the 3-block momentum kernel).
    out.extend(ledger_rows(cfg, "sgd", "pu/ledger", momentum=0.9))
    out.extend(ledger_rows(cfg, "adamw", "pu/ledger_sketched",
                           sketched=True))
    return out
