"""Continuous-batching scheduler: fairness, conservation, token identity.

Pure-policy invariants (no model):

* conservation — every submitted request retires exactly once, as
  ``finished`` or ``evicted``, never both, never twice;
* FIFO no-starvation — a request is never admitted before an
  earlier-arrived one, and the admission gate stops at the queue head
  (refusing the head never lets a later request jump it);
* ``report()`` is consistent with the trace.

Plus the serving-correctness oracle: greedy decode of the SAME request is
token-identical solo vs continuously batched alongside other traffic —
the engine's fixed-slot layout keeps per-row math independent of batch
composition, so this holds bitwise at the logits and hence exactly at the
tokens.
"""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.runtime.scheduler import Request, Scheduler


def drive(sched, *, eos_steps=None, gate=None, evict_at=None, max_steps=200):
    """Run the standard serve loop with a fake engine: request r emits
    token ``100 + rid`` each step; ``eos_steps[rid]`` forces EOS via the
    request's own eos_id after that many tokens."""
    eos_steps = eos_steps or {}
    evict_at = evict_at or {}
    admissions = []
    while sched.has_work() and sched.step < max_steps:
        for req in sched.admit(gate):
            admissions.append(req.rid)
        for req in list(sched.running()):
            if req.rid in evict_at and len(req.out) >= evict_at[req.rid]:
                sched.evict(req.slot)
                continue
            tok = 100 + req.rid
            if req.rid in eos_steps and len(req.out) + 1 >= eos_steps[req.rid]:
                tok = req.eos_id
            sched.observe(req.slot, tok)
        sched.end_step()
    return admissions


def check_conservation(sched, n_submitted):
    rids = [r.rid for r in sched.retired]
    assert len(rids) == len(set(rids)), "request retired twice"
    assert len(sched.retired) + len(sched.waiting) == n_submitted
    for r in sched.retired:
        assert r.state in ("finished", "evicted")
        assert r.slot is None and r.done_step is not None


def test_fifo_admission_order():
    sched = Scheduler(2)
    sched.submit_all(Request(rid=i, prompt=[1], max_new=3 + i)
                     for i in range(5))
    admissions = drive(sched)
    assert admissions == sorted(admissions) == list(range(5))
    check_conservation(sched, 5)
    rep = sched.report()
    assert rep["finished"] == 5 and rep["evicted"] == 0
    assert rep["still_waiting"] == 0
    assert rep["tokens_out"] == sum(3 + i for i in range(5))


def test_eos_and_budget_retirement():
    sched = Scheduler(4)
    sched.submit_all([
        Request(rid=0, prompt=[1], max_new=10, eos_id=9),   # EOS at tok 4
        Request(rid=1, prompt=[1], max_new=2, eos_id=9),    # budget
    ])
    drive(sched, eos_steps={0: 4})
    by_rid = {r.rid: r for r in sched.retired}
    assert by_rid[0].out[-1] == 9 and len(by_rid[0].out) == 4
    assert len(by_rid[1].out) == 2 and 9 not in by_rid[1].out
    assert all(r.state == "finished" for r in sched.retired)


def test_eviction_counts_once():
    sched = Scheduler(2)
    sched.submit_all(Request(rid=i, prompt=[1], max_new=6)
                     for i in range(3))
    drive(sched, evict_at={1: 2})
    check_conservation(sched, 3)
    rep = sched.report()
    assert rep["finished"] == 2 and rep["evicted"] == 1
    evicted = [r for r in sched.retired if r.state == "evicted"]
    assert [r.rid for r in evicted] == [1] and len(evicted[0].out) == 2


def test_admission_gate_stops_at_queue_head():
    """A refused head must NOT be overtaken by an admissible later
    request — that would starve long prompts."""
    sched = Scheduler(2)
    sched.submit_all([
        Request(rid=0, prompt=[1] * 100, max_new=2),   # too big for gate
        Request(rid=1, prompt=[1], max_new=2),
    ])
    admitted = sched.admit(lambda r: len(r.prompt) <= 10)
    assert admitted == [] and len(sched.waiting) == 2
    # once the gate admits the head, both go, in order
    admissions = drive(sched)
    assert admissions == [0, 1]


def test_retired_slot_refilled_from_queue_head():
    sched = Scheduler(1)
    sched.submit_all(Request(rid=i, prompt=[1], max_new=1)
                     for i in range(4))
    drive(sched)
    rep = sched.report()
    assert rep["finished"] == 4
    # with 1 slot and 1-token requests, rid i waits exactly i steps
    assert rep["max_wait_steps"] == 3
    check_conservation(sched, 4)


def test_observe_empty_slot_raises():
    sched = Scheduler(2)
    with pytest.raises(ValueError):
        sched.observe(0, 1)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16), conc=st.integers(1, 4), data=st.data())
def test_random_trace_invariants(seed, conc, data):
    n = data.draw(st.integers(1, 12))
    sched = Scheduler(conc)
    reqs = [Request(rid=i, prompt=[1] * data.draw(st.integers(1, 8)),
                    max_new=data.draw(st.integers(1, 6)), eos_id=9)
            for i in range(n)]
    sched.submit_all(reqs)
    eos_steps = {i: data.draw(st.integers(1, 6)) for i in range(n)
                 if data.draw(st.booleans())}
    evict_at = {i: data.draw(st.integers(0, 3)) for i in range(n)
                if data.draw(st.booleans())}
    admissions = drive(sched, eos_steps=eos_steps, evict_at=evict_at)
    assert admissions == sorted(admissions), "admission overtook arrival"
    check_conservation(sched, n)
    assert not sched.has_work()
    rep = sched.report()
    assert rep["finished"] + rep["evicted"] == n
    assert rep["tokens_out"] == sum(len(r.out) for r in sched.retired)


# ---------------------------------------------------------------------------
# Token identity: solo == continuously batched (greedy).
# ---------------------------------------------------------------------------


def test_batched_greedy_token_identical_to_solo():
    """The SAME request decoded alone and decoded while sharing the engine
    with other traffic must emit the SAME tokens — the fixed-slot batch
    layout makes per-row logits independent of batch composition."""
    from repro.configs import get_config
    from repro.launch.serve import serve_paged
    from repro.models import init_params

    cfg = get_config("llama3-8b").scaled_down()
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab_size, size=(n,)).tolist()
               for n in (7, 5, 9)]
    gen = 6
    solo = serve_paged(cfg, params, [prompts[0]], gen=gen,
                       max_concurrency=3, page_size=4,
                       fused_decode=False, quiet=True)
    batched = serve_paged(cfg, params, prompts, gen=gen,
                          max_concurrency=3, page_size=4,
                          fused_decode=False, quiet=True)
    tok_solo = solo["tokens"][0]
    tok_batched = batched["tokens"][0]
    np.testing.assert_array_equal(tok_solo, tok_batched)
    assert batched["report"]["finished"] == 3
