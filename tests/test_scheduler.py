"""Continuous-batching scheduler: fairness, conservation, token identity,
overload hardening (deadlines + bounded-queue shedding).

Pure-policy invariants (no model):

* conservation — every submitted request retires exactly once, in exactly
  one terminal state (``finished`` / ``evicted`` / ``timeout`` /
  ``shed``), never both, never twice — shed and timed-out requests are
  retired too, not silently dropped;
* FIFO no-starvation — a request is never admitted before an
  earlier-arrived one, and the admission gate stops at the queue head
  (refusing the head never lets a later request jump it);
* deadlines degrade overload to bounded latency: a request past its TTL
  is retired by ``expire()`` whether waiting or running;
* ``report()`` is consistent with the trace.

Plus the serving-correctness oracle: greedy decode of the SAME request is
token-identical solo vs continuously batched alongside other traffic —
the engine's fixed-slot layout keeps per-row math independent of batch
composition, so this holds bitwise at the logits and hence exactly at the
tokens.
"""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.runtime.scheduler import (
    TERMINAL_STATES,
    Request,
    Scheduler,
)


def drive(sched, *, eos_steps=None, gate=None, evict_at=None, max_steps=200):
    """Run the standard serve loop with a fake engine: request r emits
    token ``100 + rid`` each step; ``eos_steps[rid]`` forces EOS via the
    request's own eos_id after that many tokens.  Mirrors
    ``launch.serve.serve_paged``: expire at the loop top, then admit."""
    eos_steps = eos_steps or {}
    evict_at = evict_at or {}
    admissions = []
    while sched.has_work() and sched.step < max_steps:
        sched.expire()
        for req in sched.admit(gate):
            admissions.append(req.rid)
        for req in list(sched.running()):
            if req.rid in evict_at and len(req.out) >= evict_at[req.rid]:
                sched.evict(req.slot)
                continue
            tok = 100 + req.rid
            if req.rid in eos_steps and len(req.out) + 1 >= eos_steps[req.rid]:
                tok = req.eos_id
            sched.observe(req.slot, tok)
        sched.end_step()
    return admissions


def check_conservation(sched, n_submitted):
    rids = [r.rid for r in sched.retired]
    assert len(rids) == len(set(rids)), "request retired twice"
    assert len(sched.retired) + len(sched.waiting) == n_submitted
    for r in sched.retired:
        assert r.state in TERMINAL_STATES
        assert r.slot is None and r.done_step is not None


def test_fifo_admission_order():
    sched = Scheduler(2)
    sched.submit_all(Request(rid=i, prompt=[1], max_new=3 + i)
                     for i in range(5))
    admissions = drive(sched)
    assert admissions == sorted(admissions) == list(range(5))
    check_conservation(sched, 5)
    rep = sched.report()
    assert rep["finished"] == 5 and rep["evicted"] == 0
    assert rep["still_waiting"] == 0
    assert rep["tokens_out"] == sum(3 + i for i in range(5))


def test_eos_and_budget_retirement():
    sched = Scheduler(4)
    sched.submit_all([
        Request(rid=0, prompt=[1], max_new=10, eos_id=9),   # EOS at tok 4
        Request(rid=1, prompt=[1], max_new=2, eos_id=9),    # budget
    ])
    drive(sched, eos_steps={0: 4})
    by_rid = {r.rid: r for r in sched.retired}
    assert by_rid[0].out[-1] == 9 and len(by_rid[0].out) == 4
    assert len(by_rid[1].out) == 2 and 9 not in by_rid[1].out
    assert all(r.state == "finished" for r in sched.retired)


def test_eviction_counts_once():
    sched = Scheduler(2)
    sched.submit_all(Request(rid=i, prompt=[1], max_new=6)
                     for i in range(3))
    drive(sched, evict_at={1: 2})
    check_conservation(sched, 3)
    rep = sched.report()
    assert rep["finished"] == 2 and rep["evicted"] == 1
    evicted = [r for r in sched.retired if r.state == "evicted"]
    assert [r.rid for r in evicted] == [1] and len(evicted[0].out) == 2


def test_admission_gate_stops_at_queue_head():
    """A refused head must NOT be overtaken by an admissible later
    request — that would starve long prompts."""
    sched = Scheduler(2)
    sched.submit_all([
        Request(rid=0, prompt=[1] * 100, max_new=2),   # too big for gate
        Request(rid=1, prompt=[1], max_new=2),
    ])
    admitted = sched.admit(lambda r: len(r.prompt) <= 10)
    assert admitted == [] and len(sched.waiting) == 2
    # once the gate admits the head, both go, in order
    admissions = drive(sched)
    assert admissions == [0, 1]


def test_retired_slot_refilled_from_queue_head():
    sched = Scheduler(1)
    sched.submit_all(Request(rid=i, prompt=[1], max_new=1)
                     for i in range(4))
    drive(sched)
    rep = sched.report()
    assert rep["finished"] == 4
    # with 1 slot and 1-token requests, rid i waits exactly i steps
    assert rep["max_wait_steps"] == 3
    check_conservation(sched, 4)


def test_observe_empty_slot_raises():
    sched = Scheduler(2)
    with pytest.raises(ValueError):
        sched.observe(0, 1)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16), conc=st.integers(1, 4), data=st.data())
def test_random_trace_invariants(seed, conc, data):
    n = data.draw(st.integers(1, 12))
    sched = Scheduler(conc)
    reqs = [Request(rid=i, prompt=[1] * data.draw(st.integers(1, 8)),
                    max_new=data.draw(st.integers(1, 6)), eos_id=9)
            for i in range(n)]
    sched.submit_all(reqs)
    eos_steps = {i: data.draw(st.integers(1, 6)) for i in range(n)
                 if data.draw(st.booleans())}
    evict_at = {i: data.draw(st.integers(0, 3)) for i in range(n)
                if data.draw(st.booleans())}
    admissions = drive(sched, eos_steps=eos_steps, evict_at=evict_at)
    assert admissions == sorted(admissions), "admission overtook arrival"
    check_conservation(sched, n)
    assert not sched.has_work()
    rep = sched.report()
    assert rep["finished"] + rep["evicted"] == n
    assert rep["tokens_out"] == sum(len(r.out) for r in sched.retired)


def test_evict_empty_slot_raises():
    """Satellite fix: evicting an empty slot used to die with an opaque
    AttributeError on ``None.state``; it must be a clear ValueError."""
    sched = Scheduler(2)
    with pytest.raises(ValueError, match="empty slot"):
        sched.evict(0)
    sched.submit(Request(rid=0, prompt=[1], max_new=3))
    sched.admit()
    sched.evict(0)
    with pytest.raises(ValueError, match="empty slot"):
        sched.evict(0)  # double-evict is the same programming error


def test_constructor_validation():
    with pytest.raises(ValueError):
        Scheduler(0)
    with pytest.raises(ValueError):
        Scheduler(1, max_queue=-1)
    with pytest.raises(ValueError):
        Scheduler(1, default_deadline=0)


def test_deadline_times_out_running_and_waiting():
    """TTL measured from arrival: with 1 slot, the running request is cut
    off mid-decode at its deadline and the waiting one never gets in."""
    sched = Scheduler(1, default_deadline=3)
    sched.submit_all([Request(rid=0, prompt=[1], max_new=10),
                      Request(rid=1, prompt=[1], max_new=10)])
    expired = []
    while sched.has_work() and sched.step < 20:
        expired.extend(sched.expire())
        sched.admit()
        for req in list(sched.running()):
            sched.observe(req.slot, 100 + req.rid)
        sched.end_step()
    check_conservation(sched, 2)
    rep = sched.report()
    assert rep["timed_out"] == 2 and rep["finished"] == 0
    by_rid = {r.rid: r for r in sched.retired}
    assert len(by_rid[0].out) == 3          # 3 decode steps, then cut off
    assert by_rid[1].out == []              # starved past its TTL
    # the running one handed back its slot for engine-resource release;
    # the waiting one had no slot to release
    slots = {req.rid: slot for req, slot in expired}
    assert slots[0] == 0 and slots[1] is None


def test_per_request_deadline_overrides_default():
    sched = Scheduler(2, default_deadline=100)
    sched.submit_all([Request(rid=0, prompt=[1], max_new=10,
                              deadline_steps=2),
                      Request(rid=1, prompt=[1], max_new=3)])
    drive(sched)
    by_rid = {r.rid: r for r in sched.retired}
    assert by_rid[0].state == "timeout" and len(by_rid[0].out) == 2
    assert by_rid[1].state == "finished"


def test_bounded_queue_sheds_at_submit():
    sched = Scheduler(1, max_queue=2)
    reqs = [Request(rid=i, prompt=[1], max_new=1) for i in range(5)]
    accepted = sched.submit_all(reqs)
    assert accepted == 2
    assert [r.state for r in reqs] == ["waiting", "waiting", "shed",
                                      "shed", "shed"]
    drive(sched)
    check_conservation(sched, 5)
    rep = sched.report()
    assert rep["shed"] == 3 and rep["finished"] == 2
    # shed requests are retired (conservation), with no tokens and no slot
    for r in sched.retired:
        if r.state == "shed":
            assert r.out == [] and r.done_step == 0
    # once the queue drains, the door reopens
    assert sched.submit(Request(rid=9, prompt=[1], max_new=1))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16), conc=st.integers(1, 3), data=st.data())
def test_random_fault_trace_conservation_and_fifo(seed, conc, data):
    """PROPERTY: under random arrivals, EOS, evictions, deadlines, AND a
    bounded queue, every request reaches exactly one terminal state, the
    report adds up, and admission never overtakes arrival order."""
    n = data.draw(st.integers(1, 12))
    max_queue = data.draw(st.one_of(st.none(), st.integers(0, 6)))
    deadline = data.draw(st.one_of(st.none(), st.integers(1, 8)))
    sched = Scheduler(conc, max_queue=max_queue, default_deadline=deadline)
    reqs = [Request(
        rid=i, prompt=[1] * data.draw(st.integers(1, 8)),
        max_new=data.draw(st.integers(1, 6)), eos_id=9,
        deadline_steps=(data.draw(st.integers(1, 8))
                        if data.draw(st.booleans()) else None))
        for i in range(n)]
    sched.submit_all(reqs)
    eos_steps = {i: data.draw(st.integers(1, 6)) for i in range(n)
                 if data.draw(st.booleans())}
    evict_at = {i: data.draw(st.integers(0, 3)) for i in range(n)
                if data.draw(st.booleans())}
    admissions = drive(sched, eos_steps=eos_steps, evict_at=evict_at)
    assert admissions == sorted(admissions), "admission overtook arrival"
    check_conservation(sched, n)
    assert not sched.has_work()
    rep = sched.report()
    assert (rep["finished"] + rep["evicted"] + rep["timed_out"]
            + rep["shed"]) == n
    assert rep["tokens_out"] == sum(len(r.out) for r in sched.retired)
    # FIFO no-starvation under deadlines: every request either ran or
    # timed out / was shed — none left in limbo
    assert all(r.state in TERMINAL_STATES for r in sched.retired)


# ---------------------------------------------------------------------------
# Token identity: solo == continuously batched (greedy).
# ---------------------------------------------------------------------------


def test_batched_greedy_token_identical_to_solo():
    """The SAME request decoded alone and decoded while sharing the engine
    with other traffic must emit the SAME tokens — the fixed-slot batch
    layout makes per-row logits independent of batch composition."""
    from repro.configs import get_config
    from repro.launch.serve import serve_paged
    from repro.models import init_params

    cfg = get_config("llama3-8b").scaled_down()
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab_size, size=(n,)).tolist()
               for n in (7, 5, 9)]
    gen = 6
    solo = serve_paged(cfg, params, [prompts[0]], gen=gen,
                       max_concurrency=3, page_size=4,
                       fused_decode=False, quiet=True)
    batched = serve_paged(cfg, params, prompts, gen=gen,
                          max_concurrency=3, page_size=4,
                          fused_decode=False, quiet=True)
    tok_solo = solo["tokens"][0]
    tok_batched = batched["tokens"][0]
    np.testing.assert_array_equal(tok_solo, tok_batched)
    assert batched["report"]["finished"] == 3
