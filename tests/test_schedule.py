"""optim/schedule.py: boundary steps and lr_t plumbing.

`warmup_cosine` was previously exercised only at a few spot values; this
module pins the boundary behaviour (step 0, the warmup->cosine handoff,
the decay tail) and asserts that a SCHEDULE (callable lr) threads through
`Optimizer.update` identically to the equivalent per-step float — on the
pure-JAX path, the fused Pallas path, and the sketched path (lr enters all
kernels through the same SMEM scalar block)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, sgd, warmup_cosine
from repro.optim.schedule import constant

PEAK, WARM, TOTAL = 0.8, 10, 100


def _lr(step):
    return float(warmup_cosine(PEAK, WARM, TOTAL)(step))


# ---------------------------------------------------------------------------
# Boundary steps.
# ---------------------------------------------------------------------------


def test_warmup_starts_at_zero_and_is_linear():
    assert _lr(0) == 0.0
    for s in range(1, WARM):
        np.testing.assert_allclose(_lr(s), PEAK * s / WARM, rtol=1e-6)


def test_warmup_boundary_hits_peak_exactly():
    # step WARM is the first cosine step with progress 0 -> exactly peak
    np.testing.assert_allclose(_lr(WARM), PEAK, rtol=1e-6)
    # no overshoot on either side of the handoff
    assert _lr(WARM - 1) < _lr(WARM)
    assert _lr(WARM + 1) < _lr(WARM)


def test_cosine_tail_and_clip_beyond_total():
    final = PEAK * 0.1  # default final_frac
    np.testing.assert_allclose(_lr(TOTAL), final, rtol=1e-5)
    # progress clips at 1.0: lr holds at the floor past total_steps
    np.testing.assert_allclose(_lr(TOTAL + 50), final, rtol=1e-5)


def test_cosine_monotone_decay_and_midpoint():
    vals = [_lr(s) for s in range(WARM, TOTAL + 1)]
    assert all(a >= b - 1e-7 for a, b in zip(vals, vals[1:]))
    # cosine midpoint: halfway between peak and floor
    mid = (WARM + TOTAL) // 2
    np.testing.assert_allclose(_lr(mid), PEAK * (0.1 + 0.9 * 0.5),
                               rtol=1e-2)


def test_final_frac_parameter():
    fn = warmup_cosine(1.0, 0, 10, final_frac=0.25)
    np.testing.assert_allclose(float(fn(10)), 0.25, rtol=1e-5)


def test_constant_schedule():
    fn = constant(0.3)
    assert float(fn(0)) == float(fn(10_000)) == pytest.approx(0.3)
    assert fn(0).dtype == jnp.float32


def test_degenerate_warmup_zero_steps():
    fn = warmup_cosine(1.0, 0, 100)
    # no warmup: step 0 is already on the cosine at progress 0
    np.testing.assert_allclose(float(fn(0)), 1.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# lr_t callable vs float through Optimizer.update (all PU paths).
# ---------------------------------------------------------------------------


def _step_once(opt, params, grads, state=None):
    state = opt.init(params) if state is None else state
    new_p, new_s = opt.update(grads, params, state, state["step"])
    return new_p, new_s


def _params(n=30_000):
    rng = np.random.default_rng(0)
    return ({"w": jnp.asarray(rng.normal(size=n), jnp.float32)},
            {"w": jnp.asarray(rng.normal(size=n), jnp.float32)})


@pytest.mark.parametrize("mk", [
    lambda lr: sgd(lr),
    lambda lr: sgd(lr, momentum=0.9),
    lambda lr: sgd(lr, fused=True),
    lambda lr: adamw(lr),
    lambda lr: adamw(lr, fused=True),
    lambda lr: adamw(lr, sketched=True),
], ids=["sgd", "sgd_momentum", "sgd_fused", "adamw", "adamw_fused",
        "adamw_sketched"])
def test_schedule_matches_equivalent_float_lr(mk):
    """At any fixed step t, an optimizer built with a callable schedule
    must produce the same update as one built with the float lr(t) —
    bitwise, since both reach the kernel through the same scalar."""
    params, grads = _params()
    sched = warmup_cosine(PEAK, WARM, TOTAL)
    opt_c = mk(sched)
    opt_f = mk(_lr(0 + 1 - 1))  # lr at step 0, the step update() sees first

    p_c, s_c = _step_once(opt_c, params, grads)
    p_f, s_f = _step_once(opt_f, params, grads)
    # schedules are evaluated at state["step"]; both saw step=0 here
    for a, b in zip(jax.tree.leaves(p_c), jax.tree.leaves(p_f)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # and at a later step: advance the callable one, rebuild the float one
    p_c2, s_c2 = _step_once(opt_c, p_c, grads, s_c)
    opt_f2 = mk(_lr(1))
    p_f2, _ = _step_once(opt_f2, p_f, grads, s_f)
    for a, b in zip(jax.tree.leaves(p_c2), jax.tree.leaves(p_f2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_schedule_advances_with_step_counter():
    """The schedule is a function of state["step"]: two steps under warmup
    use two different lrs (pure sgd: delta = -lr_t * g exactly)."""
    params = {"w": jnp.zeros(8)}
    grads = {"w": jnp.ones(8)}
    sched = warmup_cosine(1.0, 4, 20)
    opt = sgd(sched)
    state = opt.init(params)
    p1, state = opt.update(grads, params, state, state["step"])
    p2, state = opt.update(grads, p1, state, state["step"])
    d1 = float((params["w"] - p1["w"])[0])
    d2 = float((p1["w"] - p2["w"])[0])
    np.testing.assert_allclose(d1, float(sched(0)), rtol=1e-6)
    np.testing.assert_allclose(d2, float(sched(1)), rtol=1e-6)
