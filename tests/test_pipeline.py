"""Mesh-aware fused training: GPipe pipeline + row-TP shard_map (8 devices).

Acceptance gates for the distributed substrate:
  * the 2-stage x 2-DP x 2-TP pipeline reproduces the single-device loss
    per step (f32 tolerance) with the fused bwd/attn/ffn kernels ACTIVE
    (dispatch predicates observed via trace-time counters);
  * the int8 ring all-reduce error bound is independent of ring size;
  * microbatch accumulation is exact under ragged masks;
  * both step builders report a real grad_norm with clipping off;
  * per-device ledger rows reuse the kernels' own tile choosers at the
    pipeline's local K and the ATIS 2/4/6-encoder configs fit the paper's
    6 MB BRAM + 22.5 MB URAM envelope per device.

Multi-device tests fork a subprocess so XLA_FLAGS lands before jax imports
(same idiom as tests/test_ddp_compress.py).
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_child(code: str) -> dict:
    r = subprocess.run([sys.executable, "-c", code],
                       env={**os.environ, "PYTHONPATH": SRC},
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT "):])


PIPELINE_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import repro.kernels.ops as ops

# Trace-time dispatch counters: the predicates (ffn_vmem_fits etc.) choose
# the path while tracing, so wrapping the pallas entry points counts how
# often the FUSED branch was actually taken inside the jitted steps.
counts = {}
def wrap(name):
    orig = getattr(ops, name)
    def counting(*a, **k):
        counts[name] = counts.get(name, 0) + 1
        return orig(*a, **k)
    setattr(ops, name, counting)
for n in ("btt_ffn_pallas", "btt_ffn_bwd_pallas", "flash_attention_pallas",
          "flash_attention_bwd_pallas", "btt_backward_pallas"):
    wrap(n)

from repro.configs.atis_transformer import config_n
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_pipeline_train_step, make_train_step
from repro.models.transformer import init_params
from repro.optim import sgd

cfg = (config_n(2, tt_mode="tt")
       .scaled_down(d_model=256, n_heads=4, d_ff=256, vocab_size=1000,
                    num_layers=2, max_seq_len=64)
       .with_tt(flow="kernel").with_fused_attn(True).with_fused_ffn(True))
B, S, M = 8, 32, 2
params = init_params(jax.random.PRNGKey(0), cfg)
opt = sgd(1e-2, 0.0)
state = opt.init(params)

mesh = make_host_mesh(2, 2, stage=2)
pipe = make_pipeline_train_step(cfg, opt, mesh, microbatches=M)
single = jax.jit(make_train_step(cfg, opt))

def batch_at(i):
    k = jax.random.PRNGKey(100 + i)
    toks = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    mask = (jax.random.uniform(jax.random.fold_in(k, 1), (B, S)) > 0.2
            ).astype(jnp.float32)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1),
            "mask": mask}

# copy BEFORE the donating pipeline step consumes the originals
p2 = jax.tree.map(jnp.copy, params)
s2 = jax.tree.map(jnp.copy, state)
p1, s1 = params, state
b = batch_at(0)
p1, s1, m1 = pipe(p1, s1, b)
pipe_counts = dict(counts)  # only the pipeline step has traced so far

pairs = [[float(m1["loss"]), None, float(m1["grad_norm"]), None]]
p2, s2, m2 = single(p2, s2, b)
pairs[0][1] = float(m2["loss"]); pairs[0][3] = float(m2["grad_norm"])
for i in range(1, 5):
    b = batch_at(i)
    p1, s1, m1 = pipe(p1, s1, b)
    p2, s2, m2 = single(p2, s2, b)
    pairs.append([float(m1["loss"]), float(m2["loss"]),
                  float(m1["grad_norm"]), float(m2["grad_norm"])])
print("RESULT", json.dumps({"pairs": pairs, "pipe_counts": pipe_counts,
                            "mesh": dict(mesh.shape)}))
"""


def test_pipeline_matches_single_device_with_fused_kernels():
    res = _run_child(PIPELINE_CODE)
    assert res["mesh"] == {"stage": 2, "data": 2, "model": 2}
    assert len(res["pairs"]) == 5
    for lp, ls, gp, gs in res["pairs"]:
        assert abs(lp - ls) < 1e-3 * max(1.0, abs(ls)), (lp, ls)
        assert abs(gp - gs) < 1e-3 * max(1.0, abs(gs)), (gp, gs)
    # fused kernels active INSIDE the shard_map pipeline step: the FFN
    # megakernel (fwd + bwd), flash attention (fwd + bwd), and the fused
    # TT backward all traced at least once before the single-device step
    # ever compiled.
    c = res["pipe_counts"]
    for name in ("btt_ffn_pallas", "btt_ffn_bwd_pallas",
                 "flash_attention_pallas", "flash_attention_bwd_pallas",
                 "btt_backward_pallas"):
        assert c.get(name, 0) >= 1, (name, c)


RING_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.runtime.compress import compressed_allreduce_mean

out = {}
for n in (2, 4, 8):
    mesh = Mesh(np.array(jax.devices()[:n]), ("data",))
    # heavy-tailed per-shard magnitudes: re-quantizing at every hop (the
    # old bug) compounds error with ring size; quantize-once must not.
    rng = np.random.default_rng(0)
    x = np.stack([(10.0 ** (i % 3)) * rng.standard_normal(512)
                  for i in range(n)]).astype(np.float32)
    f = shard_map(lambda v: compressed_allreduce_mean(v[0], "data")[None],
                  mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    got = np.asarray(jax.jit(f)(jnp.asarray(x)))
    exact = x.mean(axis=0)
    scales = np.abs(x).max(axis=1) / 127.0
    bound = scales.max() / 2.0
    err = float(np.abs(got - exact).max())
    out[str(n)] = {"err": err, "bound": float(bound)}
print("RESULT", json.dumps(out))
"""


def test_ring_allreduce_error_independent_of_ring_size():
    res = _run_child(RING_CODE)
    errs = []
    for n in ("2", "4", "8"):
        err, bound = res[n]["err"], res[n]["bound"]
        # quantize-once: every remote contribution pays exactly one int8
        # rounding, so the mean error is <= max_j scale_j / 2 for ANY n.
        assert err <= bound, (n, err, bound)
        errs.append(err)
    # and growing the ring must not grow the error past the fixed bound
    # (the re-quantizing scheme scaled roughly linearly with hops)
    assert max(errs) <= res["2"]["bound"] + res["8"]["bound"]


def test_microbatch_ragged_mask_parity():
    import jax
    import jax.numpy as jnp

    from repro.configs.atis_transformer import config_n
    from repro.launch.steps import make_train_step
    from repro.models.transformer import init_params
    from repro.optim import sgd

    cfg = config_n(2, tt_mode="tt").scaled_down(
        d_model=64, n_heads=2, d_ff=64, vocab_size=257, num_layers=2,
        max_seq_len=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd(1e-2, 0.0)
    state = opt.init(params)

    k = jax.random.PRNGKey(7)
    toks = jax.random.randint(k, (4, 16), 0, cfg.vocab_size)
    # RAGGED: microbatch 0 keeps almost all tokens, microbatch 1 almost
    # none — the old unweighted mean-of-means weighted both equally.
    mask = jnp.concatenate([
        (jax.random.uniform(jax.random.fold_in(k, 1), (2, 16)) > 0.05),
        (jax.random.uniform(jax.random.fold_in(k, 2), (2, 16)) > 0.9),
    ]).astype(jnp.float32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1),
             "mask": mask}

    one = jax.jit(make_train_step(cfg, opt, microbatches=1))
    two = jax.jit(make_train_step(cfg, opt, microbatches=2))
    p1, s1, m1 = one(jax.tree.map(jnp.copy, params),
                     jax.tree.map(jnp.copy, state), batch)
    p2, s2, m2 = two(jax.tree.map(jnp.copy, params),
                     jax.tree.map(jnp.copy, state), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert jnp.allclose(a, b, atol=1e-5), (a - b)


def test_grad_norm_reported_without_clipping():
    import jax
    import jax.numpy as jnp

    from repro.configs.atis_transformer import config_n
    from repro.launch.steps import make_ddp_train_step, make_train_step
    from repro.models.transformer import init_params
    from repro.optim import sgd
    from repro.runtime import ef_init

    cfg = config_n(2, tt_mode="tt").scaled_down(
        d_model=64, n_heads=2, d_ff=64, vocab_size=257, num_layers=2,
        max_seq_len=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd(1e-2, 0.0)
    state = opt.init(params)
    toks = jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}

    step = jax.jit(make_train_step(cfg, opt, clip_norm=0.0))
    _, _, m = step(jax.tree.map(jnp.copy, params),
                   jax.tree.map(jnp.copy, state), batch)
    gn = float(m["grad_norm"])
    assert gn > 0.0 and jnp.isfinite(gn), gn

    mesh = jax.make_mesh((1,), ("data",))
    ddp = make_ddp_train_step(cfg, opt, mesh, compress=False, clip_norm=0.0)
    _, _, _, m2 = ddp(jax.tree.map(jnp.copy, params),
                      jax.tree.map(jnp.copy, state), ef_init(params), batch)
    gn2 = float(m2["grad_norm"])
    # the old ddp builder hard-coded 0.0 here
    assert gn2 > 0.0 and jnp.isfinite(gn2), gn2
    assert abs(gn - gn2) < 1e-3 * max(1.0, gn), (gn, gn2)


def test_make_host_mesh_clamps_and_validates():
    import jax

    from repro.launch.mesh import make_host_mesh

    # data=0 used to ZeroDivisionError; now clamps to a 1x1 mesh
    mesh = make_host_mesh(0, 0)
    assert dict(mesh.shape) == {"data": 1, "model": 1}
    # stage never silently clamps: it changes the schedule semantics
    n = len(jax.devices())
    with pytest.raises(ValueError):
        make_host_mesh(1, 1, stage=n + 1)
    assert dict(make_host_mesh(1, 1, stage=1).shape) == {"data": 1,
                                                         "model": 1}


def test_straggler_flag_rate_post_warmup():
    from repro.runtime.straggler import StragglerMonitor

    mon = StragglerMonitor(warmup=8)
    for _ in range(10):
        mon.observe(0.1)
    assert mon.observe(0.5) is True
    # 3 post-warmup samples, 1 flagged -> 1/3.  The old denominator used
    # all 11 samples (1/11), diluting the rate CheckpointCadence keys on.
    assert mon.flag_rate == pytest.approx(1 / 3)
    mon2 = StragglerMonitor(warmup=8)
    for _ in range(5):
        mon2.observe(0.1)
    assert mon2.flag_rate == 0.0  # still inside warmup: no division blowup


def test_stage_partition_and_cycles_validation():
    from repro.configs.atis_transformer import config_n
    from repro.runtime.pipeline import (
        StagePartition,
        bubble_fraction,
        cycles_per_stage,
        stage_utilization,
    )

    part = StagePartition(stages=2, dp=2, tp=2, microbatches=2)
    assert part.devices == 8 and part.ticks == 3
    assert bubble_fraction(part) == pytest.approx(1 / 3)
    assert stage_utilization(part) == pytest.approx(2 / 3)
    with pytest.raises(ValueError):
        StagePartition(stages=0)

    cfg = config_n(4, tt_mode="tt")
    assert cycles_per_stage(cfg, 2) == 2
    with pytest.raises(ValueError):
        cycles_per_stage(cfg, 3)  # 4 cycles don't split 3 ways


def test_pipeline_ledger_per_device_envelope():
    from repro.configs.atis_transformer import config_n
    from repro.core.memory_ledger import (
        budget_report,
        pipeline_ledger_rows,
        training_step_ledger,
    )
    from repro.runtime.pipeline import StagePartition

    part = StagePartition(stages=2, dp=2, tp=2, microbatches=2)
    for n_enc in (2, 4, 6):
        cfg = config_n(n_enc, tt_mode="tt")
        rows = pipeline_ledger_rows(cfg, part, "sgd", f"pipe/{n_enc}enc")
        fits = [r for r in rows if r[0].endswith("/fits")]
        assert fits and fits[0][1] == 1.0, rows
        # partition=None stays the single-device ledger (regression)
        led0 = training_step_ledger(cfg, "sgd")
        assert budget_report(led0)["fits"]
        names0 = [e.name for e in led0["FWD"].entries]
        assert "pipeline_carries" in names0  # entry present, 0 bytes
        carry0 = led0["FWD"].entry("pipeline_carries")
        assert carry0.nbytes == 0


def test_pipeline_ledger_rows_match_tile_choosers():
    """The partitioned ledger's kernel rows ARE the kernels' tile choosers
    evaluated at the pipeline's local K (b_mb x seq) — same numbers the
    dispatch predicates see inside the shard_map body."""
    import jax

    from repro.configs.atis_transformer import config_n
    from repro.core.memory_ledger import training_step_ledger
    from repro.runtime.pipeline import StagePartition

    cfg = config_n(2, tt_mode="tt")
    part = StagePartition(stages=2, dp=2, tp=2, microbatches=2)
    batch, seq = 8, 32
    b_loc = -(-batch // (part.dp * part.tp))
    b_mb = -(-b_loc // part.microbatches)
    led = training_step_ledger(cfg, "sgd", batch=batch, seq=seq,
                               partition=part)
    led_local = training_step_ledger(cfg, "sgd", batch=b_mb, seq=seq)
    for stage, name in (("FWD", "kernel_vmem"), ("BWD", "kernel_vmem"),
                        ("FWD", "attn_kernel_vmem"),
                        ("BWD", "attn_kernel_vmem")):
        a = led[stage].entry(name)
        b = led_local[stage].entry(name)
        assert a.nbytes == b.nbytes, (stage, name, a.nbytes, b.nbytes)
