"""Fused BWD-stage kernel (kernels.btt_backward) — gradient-oracle harness.

Three layers of ground truth, in interpret mode as with every kernel test:

1. ``btt_backward_ref`` — the simplest expression of the five BWD
   contractions.  The kernel must match it bit-for-bit whenever N fits a
   single column block (identical GEMM calls), and to f32 tolerance when
   the tiled accumulation orders differ.
2. The dense-reconstruction autodiff oracle — ``jax.vjp`` through
   ``x @ (A @ B)^T`` with dense W.  Property-tested over sampled
   ``(d, rank, K, M, N)`` via hypothesis.
3. The pure-JAX flows — gradient parity across ``rl`` / ``btt`` /
   ``btt_fused`` and the kernel op (fused and unfused backward), which the
   seed suite only covered at the forward level.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import TTSpec, tt_init, tt_linear_apply, tt_linear_init
from repro.core.tt import tt_half_factors, tt_reconstruct
from repro.core.tt_linear import make_tt_spec
from repro.kernels import (
    btt_backward_pallas,
    btt_backward_ref,
    btt_linear_op,
    bwd_vmem_fits,
    fused_bwd_hbm_bytes,
    unfused_bwd_hbm_bytes,
)

# (K, N, M, R) — mirrors the forward sweep in test_kernels.py: the paper's
# layer, degenerate batch, ragged everything, rank == lane width.
SHAPES = [
    (32, 768, 768, 12),      # the paper's layer (rank 12)
    (1, 256, 128, 4),        # degenerate batch
    (300, 1000, 515, 64),    # ragged everything
    (512, 512, 512, 128),    # rank == lane width
    (48, 1536, 640, 24),     # multi-block N (tn = 512 path)
]

# Every dim already a hardware-tile multiple AND one grid step: the kernel
# adds no padding and issues the reference's exact GEMM calls, so results
# must be bit-identical.  (Padded-rank shapes are excluded: zero-padding a
# CONTRACTION dim changes XLA's reduction tree, which legitimately moves
# the last ulp.)
SINGLE_TILE_SHAPES = [(32, 768, 768, 128), (256, 512, 512, 128),
                      (8, 128, 128, 128)]


def _operands(K, N, M, R, dtype=jnp.float32, seed=None):
    kx, kg, kb, ka = jax.random.split(
        jax.random.PRNGKey(seed if seed is not None else K + N + M + R), 4)
    x = jax.random.normal(kx, (K, N), dtype)
    gy = jax.random.normal(kg, (K, M), dtype)
    b = (jax.random.normal(kb, (R, N), dtype) * 0.05).astype(dtype)
    a = (jax.random.normal(ka, (M, R), dtype) * 0.05).astype(dtype)
    return x, gy, b, a


def _assert_close(got, want, tol, names=("gx", "ga", "gb")):
    """Scale-relative comparison: |u - v| <= tol * max|v| per output.
    Tiled accumulation reorders f32 sums, so per-element atol on
    near-zero entries would flag last-ulp noise as error."""
    for name, u, v in zip(names, got, want):
        u = np.asarray(u, np.float32)
        v = np.asarray(v, np.float32)
        scale = max(float(np.max(np.abs(v))), 1e-6)
        np.testing.assert_allclose(u / scale, v / scale, rtol=0, atol=tol,
                                   err_msg=name)


# ---------------------------------------------------------------------------
# Kernel vs the pure-jnp reference.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bwd_kernel_vs_ref(shape, dtype):
    K, N, M, R = shape
    x, gy, b, a = _operands(K, N, M, R, dtype)
    got = btt_backward_pallas(x, gy, b, a, interpret=True)
    want = btt_backward_ref(x, gy, b, a)
    assert got[0].shape == (K, N) and got[0].dtype == dtype
    assert got[1].shape == (M, R) and got[1].dtype == jnp.float32
    assert got[2].shape == (R, N) and got[2].dtype == jnp.float32
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    _assert_close(got, want, tol)


@pytest.mark.parametrize("shape", SINGLE_TILE_SHAPES)
def test_bwd_kernel_bitmatches_ref_single_tile(shape):
    """One grid step => the kernel issues the reference's exact GEMMs; the
    results must be bit-identical (zero padding is exact)."""
    K, N, M, R = shape
    x, gy, b, a = _operands(K, N, M, R)
    got = btt_backward_pallas(x, gy, b, a, interpret=True)
    want = btt_backward_ref(x, gy, b, a)
    for name, u, v in zip(("gx", "ga", "gb"), got, want):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v),
                                      err_msg=name)


@pytest.mark.parametrize("tk,tn", [(32, 128), (64, 512), (256, 256)])
def test_bwd_kernel_tile_sweep(tk, tn):
    """Result must be invariant to the BlockSpec tiling (incl. the
    accumulator revisiting pattern across both grid axes)."""
    K, N, M, R = 96, 640, 384, 24
    x, gy, b, a = _operands(K, N, M, R, seed=7)
    got = btt_backward_pallas(x, gy, b, a, tk=tk, tn=tn, interpret=True)
    want = btt_backward_ref(x, gy, b, a)
    _assert_close(got, want, 1e-5)


# ---------------------------------------------------------------------------
# Kernel vs jax.grad of the dense-reconstruction oracle (hypothesis).
# ---------------------------------------------------------------------------


def _dense_oracle(x, gy, b, a):
    """(gx, ga, gb) via autodiff through the dense matrix W = A @ B."""
    _, vjp = jax.vjp(lambda xx, aa, bb: xx @ (aa @ bb).T, x, a, b)
    gx, ga, gb = vjp(gy)
    return gx, ga, gb


@settings(max_examples=12, deadline=None)
@given(
    d=st.integers(2, 3),
    rank=st.integers(2, 16),
    k=st.integers(1, 48),
    m=st.integers(8, 260),
    n=st.integers(8, 260),
    seed=st.integers(0, 2**31 - 1),
)
def test_bwd_kernel_matches_dense_autodiff_oracle(d, rank, k, m, n, seed):
    """Property: over sampled (d, rank, K, M, N), the fused kernel's
    (gx, ga, gb) track jax.grad of the dense reconstruction to <= 1e-5
    relative error in f32."""
    spec = make_tt_spec(m, n, d, rank)
    cores = tt_init(jax.random.PRNGKey(seed), spec)
    a, b = tt_half_factors(cores, spec)
    M, N = spec.out_dim, spec.in_dim
    kx, kg = jax.random.split(jax.random.PRNGKey(seed + 1))
    x = jax.random.normal(kx, (k, N))
    gy = jax.random.normal(kg, (k, M))
    got = btt_backward_pallas(x, gy, b, a, interpret=True)
    want = _dense_oracle(x, gy, b, a)
    _assert_close(got, want, 1e-5)


# ---------------------------------------------------------------------------
# Op level: fused backward == unfused backward == dense oracle through cores.
# ---------------------------------------------------------------------------

SPEC = TTSpec(out_factors=(8, 8, 12), in_factors=(12, 8, 8), rank=12)


def _op_grads(cores, x, fused_bwd):
    return jax.grad(
        lambda c, xx: (btt_linear_op(list(c), xx, SPEC, use_kernel=True,
                                     interpret=True,
                                     fused_bwd=fused_bwd) ** 2).sum(),
        argnums=(0, 1))(tuple(cores), x)


def test_op_fused_bwd_matches_unfused_and_dense():
    cores = tt_init(jax.random.PRNGKey(0), SPEC)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, SPEC.in_dim))
    g_fused = _op_grads(cores, x, True)
    g_unfused = _op_grads(cores, x, False)
    g_dense = jax.grad(
        lambda c, xx: ((xx @ tt_reconstruct(list(c), SPEC).T) ** 2).sum(),
        argnums=(0, 1))(tuple(cores), x)
    fu, uu, du = (jax.tree.leaves(g) for g in (g_fused, g_unfused, g_dense))
    _assert_close(fu, uu, 1e-5, names=[f"leaf{i}" for i in range(len(fu))])
    _assert_close(fu, du, 2e-4, names=[f"leaf{i}" for i in range(len(fu))])


def test_op_fallback_when_working_set_exceeds_budget():
    """qwen3-class FFN dims bust the fused-bwd VMEM budget: the op must
    silently take the reference path (fused_bwd=True notwithstanding) and
    still produce grads matching plain autodiff through the pure flow."""
    spec = make_tt_spec(12288, 4096, 3, 96)
    assert not bwd_vmem_fits(spec.out_dim, spec.in_dim, spec.mid_rank, 4,
                             K=16)
    cores = tt_init(jax.random.PRNGKey(2), spec)
    x = jax.random.normal(jax.random.PRNGKey(3), (16, spec.in_dim))

    def loss(use_kernel):
        return jax.grad(lambda xx: (btt_linear_op(
            cores, xx, spec, use_kernel=use_kernel, interpret=True,
            fused_bwd=True) ** 2).sum())(x)

    # use_kernel=False -> tt_forward_btt under plain autodiff: an
    # independent gradient path for the same function.
    np.testing.assert_allclose(loss(True), loss(False), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Gradient parity across the EXISTING pure-JAX flows (rl / btt / btt_fused)
# — the seed suite only tested forward parity; _btt_fused_bwd had no
# direct coverage.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def flow_setup():
    p = tt_linear_init(jax.random.PRNGKey(4), 256, 192, d=2, rank=8)
    x = jax.random.normal(jax.random.PRNGKey(5), (32, 192))
    return p, x


def _flow_grads(p, x, flow):
    def loss(cores, xx):
        import dataclasses
        q = dataclasses.replace(p, cores=list(cores))
        return (tt_linear_apply(q, xx, flow=flow) ** 2).sum()

    return jax.grad(loss, argnums=(0, 1))(tuple(p.cores), x)


@pytest.mark.parametrize("flow", ["rl", "btt", "btt_fused"])
def test_flow_grads_match_dense_oracle(flow_setup, flow):
    """Each pure-JAX flow's gradients (cores AND input) vs autodiff through
    the dense reconstruction."""
    p, x = flow_setup
    got = _flow_grads(p, x, flow)
    want = jax.grad(
        lambda c, xx: ((xx @ tt_reconstruct(list(c), p.spec).T) ** 2).sum(),
        argnums=(0, 1))(tuple(p.cores), x)
    for u, v in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(u, v, rtol=2e-4, atol=2e-4)


def test_btt_fused_grads_match_rl_grads(flow_setup):
    """The custom-VJP flow vs plain autodiff through the rl contraction —
    two independent gradient paths for the same function."""
    p, x = flow_setup
    g_fused = _flow_grads(p, x, "btt_fused")
    g_rl = _flow_grads(p, x, "rl")
    for u, v in zip(jax.tree.leaves(g_fused), jax.tree.leaves(g_rl)):
        np.testing.assert_allclose(u, v, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Precision regression: the old unfused path cast t/gt to the storage dtype
# between the f32 accumulation and the dependent ga/gb products.
# ---------------------------------------------------------------------------


def test_core_grad_chain_stays_f32_for_bf16_inputs():
    """With bf16 operands, ga/gb from both the fused kernel and the f32
    reference chain must track the f32 oracle strictly more closely than
    the old lossy chain (t/gt rounded to bf16 mid-chain) does."""
    K, N, M, R = 64, 768, 768, 12
    x, gy, b, a = _operands(K, N, M, R, jnp.bfloat16, seed=11)
    x32, gy32, b32, a32 = (v.astype(jnp.float32) for v in (x, gy, b, a))
    _, ga_oracle, gb_oracle = _dense_oracle(x32, gy32, b32, a32)

    # The pre-fix chain: f32 GEMMs but t/gt cast back to bf16 in between.
    t_lossy = jnp.dot(x, b.T, preferred_element_type=jnp.float32).astype(
        x.dtype)
    gt_lossy = jnp.dot(gy, a, preferred_element_type=jnp.float32).astype(
        gy.dtype)
    ga_lossy = jnp.dot(gy.T, t_lossy, preferred_element_type=jnp.float32)
    gb_lossy = jnp.dot(gt_lossy.T, x, preferred_element_type=jnp.float32)

    _, ga_ref, gb_ref = btt_backward_ref(x, gy, b, a)
    _, ga_kern, gb_kern = btt_backward_pallas(x, gy, b, a, interpret=True)

    def err(u, v):
        return float(jnp.max(jnp.abs(u - v)))

    for fixed, lossy, oracle in ((ga_ref, ga_lossy, ga_oracle),
                                 (ga_kern, ga_lossy, ga_oracle),
                                 (gb_ref, gb_lossy, gb_oracle),
                                 (gb_kern, gb_lossy, gb_oracle)):
        assert err(fixed, oracle) < err(lossy, oracle), \
            "f32 chain must beat the lossy bf16 mid-chain"


# ---------------------------------------------------------------------------
# HBM traffic: fused must move strictly fewer bytes (acceptance criterion).
# ---------------------------------------------------------------------------


def test_fused_moves_fewer_hbm_bytes_for_shipped_shapes():
    """For the paper layer and every test-swept shape, the fused launch's
    analytic HBM traffic is strictly below the unfused 4-GEMM path's."""
    for K, N, M, R in SHAPES:
        fused = fused_bwd_hbm_bytes(K, M, N, R, 4)
        unfused = unfused_bwd_hbm_bytes(K, M, N, R, 4)
        assert fused < unfused, (K, N, M, R, fused, unfused)
