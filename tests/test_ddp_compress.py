"""Compressed-DDP training (shard_map + int8 ring all-reduce), 8 devices.

The paper's technique pairing: tiny replicated TT params + error-feedback
int8 gradients.  The compressed run must track the uncompressed run's loss
trajectory (EF keeps the accumulated update unbiased)."""
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.data import lm_batch
from repro.launch.steps import make_ddp_train_step
from repro.models import init_params
from repro.optim import sgd
from repro.runtime import ef_init

cfg = get_config("qwen3-8b").scaled_down().with_tt(mode="tt", rank=8,
                                                   embed_rank=8)
mesh = jax.make_mesh((8,), ("data",))
opt = sgd(1e-2)

def run(compress):
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    ef = ef_init(params)
    step = make_ddp_train_step(cfg, opt, mesh, compress=compress)
    losses = []
    for i in range(12):
        batch = {k: jnp.asarray(v)
                 for k, v in lm_batch(0, i, 16, 64, cfg.vocab_size).items()}
        params, opt_state, ef, m = step(params, opt_state, ef, batch)
        losses.append(float(m["loss"]))
    return losses

la = run(False)
lb = run(True)
print("RESULT", json.dumps({"plain": la, "compressed": lb}))
"""


def test_compressed_ddp_tracks_uncompressed():
    r = subprocess.run([sys.executable, "-c", CODE],
                       env={**os.environ, "PYTHONPATH": SRC},
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][0]
    res = json.loads(line[len("RESULT "):])
    plain, comp = res["plain"], res["compressed"]
    # both learn
    assert plain[-1] < plain[0]
    assert comp[-1] < comp[0]
    # compressed trajectory tracks plain within a small tolerance
    for a, b in zip(plain, comp):
        assert abs(a - b) < 0.05 * max(abs(a), 1.0), (a, b)
