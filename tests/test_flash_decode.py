"""Flash-decode Pallas kernel vs its oracles.

Three tiers of equality, matching the kernel's design contract:

* BIT-equality against a dense single-query softmax on unpadded
  single-tile shapes (G=8, D=128, one page) — at one grid step the online
  update degenerates to exactly the dense primitive sequence;
* BIT-equality against ``paged_decode_ref`` everywhere (the reference
  executes the identical primitive order, so kernel, fallback, and the
  ``flash_decode_op`` VMEM-budget fallback must agree to the last ulp);
* tolerance against an independent plain-softmax reference on ragged,
  windowed, multi-page, permuted-page cases (math, not just plumbing).

Physical page ids carry no positional meaning, so decode output must be
invariant to page-table permutation — asserted bitwise.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_decode import (
    NEG_INF,
    flash_decode_pallas,
    paged_decode_ref,
)
from repro.kernels.ops import flash_decode_op


def build_case(seed, *, B, KV, G, D, P, lengths, pos0, n_spare_pages=0,
               perm_seed=None, dtype=jnp.float32):
    """Random paged case.  Returns (q, k_pool, v_pool, table, lengths,
    pos0, dense) where ``dense[b] = (k_rows, v_rows)`` is request b's
    logical contiguous view ``[pos0, length)`` of shape (KV, held, D)."""
    rng = np.random.RandomState(seed)
    lengths = np.asarray(lengths, np.int32)
    pos0 = np.asarray(pos0, np.int32)
    held = lengths - pos0
    n_pages = [-(-int(h) // P) for h in held]
    np_max = max(max(n_pages), 1)
    NP = 1 + sum(n_pages) + n_spare_pages
    ids = list(range(1, NP))
    if perm_seed is not None:
        np.random.RandomState(perm_seed).shuffle(ids)
    k_pool = rng.randn(NP, KV, P, D).astype(dtype)   # junk everywhere:
    v_pool = rng.randn(NP, KV, P, D).astype(dtype)   # dead rows must not
    table = np.zeros((B, np_max), np.int32)          # leak into the math
    dense = []
    take = 0
    for b in range(B):
        h = int(held[b])
        kr = rng.randn(KV, h, D).astype(dtype)
        vr = rng.randn(KV, h, D).astype(dtype)
        dense.append((kr, vr))
        pages = ids[take: take + n_pages[b]]
        take += n_pages[b]
        table[b, : len(pages)] = pages
        pad = len(pages) * P - h
        kp = np.pad(kr, ((0, 0), (0, pad), (0, 0))).reshape(
            KV, len(pages), P, D).transpose(1, 0, 2, 3)
        vp = np.pad(vr, ((0, 0), (0, pad), (0, 0))).reshape(
            KV, len(pages), P, D).transpose(1, 0, 2, 3)
        k_pool[pages] = kp
        v_pool[pages] = vp
    q = rng.randn(B, KV, G, D).astype(dtype)
    return (jnp.asarray(q), jnp.asarray(k_pool), jnp.asarray(v_pool),
            jnp.asarray(table), jnp.asarray(lengths), jnp.asarray(pos0),
            dense)


def plain_softmax_ref(q, dense, lengths, pos0, window):
    """Independent dense reference: plain f32 softmax over each request's
    logical rows (no online update, no paging)."""
    B, KV, G, D = q.shape
    out = np.zeros((B, KV, G, D), np.float32)
    for b in range(B):
        kr, vr = dense[b]
        held = int(lengths[b]) - int(pos0[b])
        positions = int(pos0[b]) + np.arange(held)
        valid = positions < int(lengths[b])
        if window is not None:
            valid &= positions >= int(lengths[b]) - window
        for h in range(KV):
            s = (np.asarray(q[b, h], np.float32) @
                 np.asarray(kr[h, :held], np.float32).T) / math.sqrt(D)
            s[:, ~valid] = -np.inf
            p = np.exp(s - s.max(axis=1, keepdims=True))
            p /= p.sum(axis=1, keepdims=True)
            out[b, h] = p @ np.asarray(vr[h, :held], np.float32)
    return out


# ---------------------------------------------------------------------------
# Tier 1: bit-equality vs the dense single-query softmax, single tile.
# ---------------------------------------------------------------------------


def dense_single_tile(q, k, v, length, window, scale):
    """The kernel's exact primitive sequence at one grid step: dense
    single-query softmax written with the same ops in the same order."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    lpos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = lpos < length
    if window is not None:
        mask &= lpos >= length - window
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.maximum(jnp.full_like(s[:, :1], NEG_INF),
                    s.max(axis=1, keepdims=True))
    pr = jnp.exp(s - m)
    l = pr.sum(axis=1, keepdims=True)
    acc = jax.lax.dot_general(pr.astype(v.dtype), v,
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


@pytest.mark.parametrize("window", [None, 5])
@pytest.mark.parametrize("length", [8, 3])
def test_bit_equal_dense_single_tile(window, length):
    B, KV, G, D, P = 2, 2, 8, 128, 8
    q, kp, vp, table, lengths, pos0, dense = build_case(
        0, B=B, KV=KV, G=G, D=D, P=P, lengths=[length] * B, pos0=[0] * B)
    out = flash_decode_pallas(q, kp, vp, table, lengths, pos0,
                              window=window, interpret=True)
    scale = 1.0 / math.sqrt(D)
    for b in range(B):
        for h in range(KV):
            page = int(table[b, 0])
            ref = dense_single_tile(q[b, h], kp[page, h], vp[page, h],
                                    length, window, scale)
            np.testing.assert_array_equal(np.asarray(out[b, h]),
                                          np.asarray(ref))


# ---------------------------------------------------------------------------
# Tier 2: bitwise kernel/reference/fallback parity on hard layouts.
# ---------------------------------------------------------------------------


PARITY_CASES = [
    # (B, KV, G, D, P, lengths, pos0, window)
    (2, 2, 1, 64, 8, [17, 9], [0, 0], None),       # MHA, ragged tails
    (2, 2, 4, 64, 8, [24, 5], [0, 0], None),       # GQA groups
    (3, 1, 8, 128, 16, [40, 33, 16], [0, 0, 0], None),  # unpadded tile
    (2, 2, 2, 64, 8, [30, 21], [16, 8], 12),       # windowed, ring pos0
    (2, 1, 3, 48, 8, [19, 8], [0, 0], 7),          # ragged G and D
]


@pytest.mark.parametrize("case", PARITY_CASES)
def test_kernel_vs_paged_ref_bitwise(case):
    B, KV, G, D, P, lengths, pos0, window = case
    q, kp, vp, table, lengths, pos0, dense = build_case(
        1, B=B, KV=KV, G=G, D=D, P=P, lengths=lengths, pos0=pos0,
        n_spare_pages=2)
    out = flash_decode_pallas(q, kp, vp, table, lengths, pos0,
                              window=window, interpret=True)
    ref = paged_decode_ref(q, kp, vp, table, lengths, pos0, window=window)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # and the math is right, not just self-consistent:
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        plain_softmax_ref(q, dense, lengths, pos0, window),
        rtol=2e-5, atol=2e-5)


def test_op_fallback_bitwise_parity():
    """flash_decode_op: kernel path, explicit ref path, and the
    VMEM-budget-forced fallback must agree bitwise."""
    B, KV, G, D, P = 2, 2, 4, 64, 8
    q, kp, vp, table, lengths, pos0, _ = build_case(
        2, B=B, KV=KV, G=G, D=D, P=P, lengths=[20, 11], pos0=[0, 0])
    qh = q.reshape(B, KV * G, D)
    kern = flash_decode_op(qh, kp, vp, table, lengths, pos0,
                           use_kernel=True, interpret=True)
    ref = flash_decode_op(qh, kp, vp, table, lengths, pos0,
                          use_kernel=False)
    forced = flash_decode_op(qh, kp, vp, table, lengths, pos0,
                             use_kernel=True, budget=1)  # nothing fits
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(forced), np.asarray(ref))


def test_page_permutation_invariance():
    """Same logical KV content in two different physical page layouts ->
    bitwise-identical decode output (page ids carry no positional
    meaning)."""
    kwargs = dict(B=3, KV=2, G=4, D=64, P=8, lengths=[26, 13, 8],
                  pos0=[0, 0, 0], n_spare_pages=3)
    a = build_case(3, perm_seed=None, **kwargs)
    b = build_case(3, perm_seed=123, **kwargs)
    # same logical content by construction (same data seed):
    for (ka, va), (kb, vb) in zip(a[6], b[6]):
        np.testing.assert_array_equal(ka, kb)
    out_a = flash_decode_pallas(*a[:6], window=None, interpret=True)
    out_b = flash_decode_pallas(*b[:6], window=None, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_b))


def test_dead_slot_zero_length():
    """length-0 lanes (free decode slots pointing at the trash page) must
    produce finite output and not disturb live lanes."""
    B, KV, G, D, P = 2, 1, 2, 32, 8
    q, kp, vp, table, lengths, pos0, dense = build_case(
        4, B=B, KV=KV, G=G, D=D, P=P, lengths=[12, 9], pos0=[0, 0])
    lengths = jnp.asarray([12, 0], jnp.int32)   # lane 1 goes dead
    table = table.at[1].set(0)
    out = flash_decode_pallas(q, kp, vp, table, lengths, pos0,
                              window=None, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    solo = flash_decode_pallas(q[:1], kp, vp, table[:1], lengths[:1],
                               pos0[:1], window=None, interpret=True)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(solo[0]))


# ---------------------------------------------------------------------------
# Tier 3: property sweep (skipped when hypothesis is not installed).
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    g=st.sampled_from([1, 2, 4, 8]),
    b=st.integers(1, 3),
    p=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
    window=st.none() | st.integers(2, 20),
    data=st.data(),
)
def test_paged_sweep(g, b, p, seed, window, data):
    kv = data.draw(st.sampled_from([1, 2]))
    d = data.draw(st.sampled_from([16, 32, 64]))
    lengths = [data.draw(st.integers(1, 4 * p)) for _ in range(b)]
    pos0 = [0] * b
    if window is not None:
        # ring-evicted start: whole pages wholly outside the window
        pos0 = [max(0, (ln - window) // p * p) for ln in lengths]
    q, kp, vp, table, lengths, pos0, dense = build_case(
        seed, B=b, KV=kv, G=g, D=d, P=p, lengths=lengths, pos0=pos0,
        n_spare_pages=data.draw(st.integers(0, 3)),
        perm_seed=data.draw(st.none() | st.integers(0, 100)))
    out = flash_decode_pallas(q, kp, vp, table, lengths, pos0,
                              window=window, interpret=True)
    ref = paged_decode_ref(q, kp, vp, table, lengths, pos0, window=window)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        plain_softmax_ref(q, dense, lengths, pos0, window),
        rtol=3e-5, atol=3e-5)
