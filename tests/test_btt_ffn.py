"""Fused tensorized-FFN megakernel (kernels.btt_ffn) — gradient-oracle
harness, mirroring tests/test_btt_backward.py:

1. ``btt_ffn_ref`` / ``btt_ffn_backward_ref`` — the two-call (three when
   gated) reference issuing the megakernel's exact GEMM + cast sequence.
   The kernel must match it bit-for-bit on unpadded single-tile shapes
   (both refs jitted — same compilation regime as the jitted kernel
   wrapper; XLA's gelu lowering differs bitwise between eager and jit).
2. The dense-reconstruction autodiff oracle — ``jax.vjp`` through
   ``down(act(up(x)))`` with dense ``W = A @ B`` per projection.
   Property-tested over sampled ``(d, rank, K, N, F)`` x gated/ungated x
   silu/gelu via hypothesis.
3. Op/model level — ``btt_ffn_op`` gradients vs the two-call composition,
   VMEM-budget fallback parity, ``mlp_apply`` + MoE expert parity with
   ``fused_ffn`` on/off, and the fused<unfused HBM-bytes acceptance
   criterion over every shipped ATIS config.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.tt import tt_half_factors, tt_init, tt_reconstruct
from repro.core.tt_linear import make_tt_spec
from repro.kernels import (
    btt_ffn_backward_ref,
    btt_ffn_bwd_pallas,
    btt_ffn_op,
    btt_ffn_pallas,
    btt_ffn_ref,
    btt_linear_op,
    ffn_vmem_fits,
    fused_ffn_hbm_bytes,
    unfused_ffn_hbm_bytes,
)

# (K, N, F, M, R1, R2, Rg) — Rg=0 means ungated.  The paper's FFN
# (768x768, rank 12), degenerate batch, ragged everything, rank == lanes.
SHAPES = [
    (32, 768, 768, 768, 12, 12, 0),      # the paper's FFN block
    (1, 256, 512, 256, 4, 4, 4),         # degenerate batch, gated
    (300, 300, 515, 290, 12, 24, 8),     # ragged everything, gated
    (96, 512, 1024, 512, 128, 128, 0),   # rank == lane width
]

# Every dim a hardware-tile multiple AND K == one row block: the kernel
# issues the reference's exact GEMM calls — results must be bit-identical.
SINGLE_TILE = [
    (32, 768, 768, 768, 128, 128, 0),
    (32, 512, 1024, 512, 128, 128, 128),
    (32, 128, 256, 128, 128, 128, 0),
]


def _operands(K, N, F, M, R1, R2, Rg, dtype=jnp.float32, seed=None):
    ks = jax.random.split(
        jax.random.PRNGKey(seed if seed is not None else K + N + F + M), 8)
    x = jax.random.normal(ks[0], (K, N), dtype)
    gy = jax.random.normal(ks[1], (K, M), dtype)
    b1 = (jax.random.normal(ks[2], (R1, N), dtype) * 0.05).astype(dtype)
    a1 = (jax.random.normal(ks[3], (F, R1), dtype) * 0.05).astype(dtype)
    b2 = (jax.random.normal(ks[4], (R2, F), dtype) * 0.05).astype(dtype)
    a2 = (jax.random.normal(ks[5], (M, R2), dtype) * 0.05).astype(dtype)
    bg = (jax.random.normal(ks[6], (Rg, N), dtype) * 0.05).astype(dtype) \
        if Rg else None
    ag = (jax.random.normal(ks[7], (F, Rg), dtype) * 0.05).astype(dtype) \
        if Rg else None
    return x, gy, b1, a1, b2, a2, bg, ag


def _assert_close(got, want, tol, names):
    """Scale-relative comparison (see test_btt_backward)."""
    for name, u, v in zip(names, got, want):
        u = np.asarray(u, np.float32)
        v = np.asarray(v, np.float32)
        scale = max(float(np.max(np.abs(v))), 1e-6)
        np.testing.assert_allclose(u / scale, v / scale, rtol=0, atol=tol,
                                   err_msg=name)


_GNAMES = ("gx", "ga1", "gb1", "ga2", "gb2", "gag", "gbg")


# ---------------------------------------------------------------------------
# Kernel vs the pure-jnp two-call reference.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["gelu", "silu"])
def test_ffn_kernel_vs_ref(shape, dtype, act):
    K, N, F, M, R1, R2, Rg = shape
    x, gy, b1, a1, b2, a2, bg, ag = _operands(K, N, F, M, R1, R2, Rg, dtype)
    y = btt_ffn_pallas(x, b1, a1, b2, a2, bg, ag, act=act, interpret=True)
    want = jax.jit(lambda *o: btt_ffn_ref(*o, act=act))(
        x, b1, a1, b2, a2, bg, ag)
    assert y.shape == (K, M) and y.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    _assert_close([y], [want], tol, ["y"])

    got = btt_ffn_bwd_pallas(x, gy, b1, a1, b2, a2, bg, ag, act=act,
                             interpret=True)
    wantg = jax.jit(lambda *o: btt_ffn_backward_ref(*o, act=act))(
        x, gy, b1, a1, b2, a2, bg, ag)
    assert got[0].shape == (K, N) and got[0].dtype == dtype
    assert all(g.dtype == jnp.float32 for g in got[1:])
    _assert_close(got, wantg, tol, _GNAMES)


@pytest.mark.parametrize("shape", SINGLE_TILE)
@pytest.mark.parametrize("act", ["gelu", "silu"])
def test_ffn_kernel_bitmatches_ref_single_tile(shape, act):
    """One grid step => the megakernel issues the reference's exact GEMM
    and activation calls; forward AND all gradients must be bit-identical
    (zero padding is exact)."""
    K, N, F, M, R1, R2, Rg = shape
    x, gy, b1, a1, b2, a2, bg, ag = _operands(K, N, F, M, R1, R2, Rg)
    y = btt_ffn_pallas(x, b1, a1, b2, a2, bg, ag, act=act, interpret=True)
    want = jax.jit(lambda *o: btt_ffn_ref(*o, act=act))(
        x, b1, a1, b2, a2, bg, ag)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want),
                                  err_msg="y")
    got = btt_ffn_bwd_pallas(x, gy, b1, a1, b2, a2, bg, ag, act=act,
                             interpret=True)
    wantg = jax.jit(lambda *o: btt_ffn_backward_ref(*o, act=act))(
        x, gy, b1, a1, b2, a2, bg, ag)
    for name, u, v in zip(_GNAMES, got, wantg):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v),
                                      err_msg=name)


def test_ffn_kernel_tile_sweep():
    """Result invariant to the K-row tiling (incl. the accumulator
    revisiting pattern across the sequential grid)."""
    K, N, F, M, R1, R2, Rg = 96, 640, 384, 640, 24, 24, 12
    x, gy, b1, a1, b2, a2, bg, ag = _operands(K, N, F, M, R1, R2, Rg, seed=7)
    want = btt_ffn_bwd_pallas(x, gy, b1, a1, b2, a2, bg, ag, act="silu",
                              interpret=True)
    for tk in (32, 64):
        got = btt_ffn_bwd_pallas(x, gy, b1, a1, b2, a2, bg, ag, act="silu",
                                 tk=tk, interpret=True)
        _assert_close(got, want, 1e-5, _GNAMES)


def test_ffn_kernel_masks_logical_hidden_columns():
    """With f_logical < F the kernel must reproduce the two-call path's
    slice-then-repad semantics: hidden columns past the logical d_ff (REAL
    half-factor rows, not tile padding) contribute nothing, and their
    up-projection rows receive zero gradient."""
    K, N, F, M, R1, R2 = 32, 256, 512, 256, 12, 12
    f_logical = 500
    x, gy, b1, a1, b2, a2, _, _ = _operands(K, N, F, M, R1, R2, 0, seed=9)
    y = btt_ffn_pallas(x, b1, a1, b2, a2, act="gelu",
                       f_logical=f_logical, interpret=True)
    u = jnp.dot(jnp.dot(x, b1.T), a1.T)[:, :f_logical]
    h = jnp.pad(jax.nn.gelu(u), ((0, 0), (0, F - f_logical)))
    want = jnp.dot(jnp.dot(h, b2.T), a2.T)
    _assert_close([y], [want], 1e-5, ["y"])
    grads = btt_ffn_bwd_pallas(x, gy, b1, a1, b2, a2, act="gelu",
                               f_logical=f_logical, interpret=True)
    ga1, gb2 = grads[1], grads[4]
    np.testing.assert_array_equal(np.asarray(ga1[f_logical:]), 0.0)
    np.testing.assert_array_equal(np.asarray(gb2[:, f_logical:]), 0.0)


# ---------------------------------------------------------------------------
# Kernel vs jax.grad of the dense-composition oracle (hypothesis).
# ---------------------------------------------------------------------------


def _dense_oracle(x, gy, b1, a1, b2, a2, bg, ag, act):
    actf = jax.nn.gelu if act == "gelu" else jax.nn.silu

    if bg is None:
        def f(xx, aa1, bb1, aa2, bb2):
            return actf(xx @ (aa1 @ bb1).T) @ (aa2 @ bb2).T

        _, vjp = jax.vjp(f, x, a1, b1, a2, b2)
        gx, ga1, gb1, ga2, gb2 = vjp(gy)
        return gx, ga1, gb1, ga2, gb2

    def f(xx, aa1, bb1, aa2, bb2, aag, bbg):
        return ((actf(xx @ (aag @ bbg).T) * (xx @ (aa1 @ bb1).T))
                @ (aa2 @ bb2).T)

    _, vjp = jax.vjp(f, x, a1, b1, a2, b2, ag, bg)
    gx, ga1, gb1, ga2, gb2, gag, gbg = vjp(gy)
    return gx, ga1, gb1, ga2, gb2, gag, gbg


@settings(max_examples=10, deadline=None)
@given(
    d=st.integers(2, 3),
    rank=st.integers(2, 12),
    k=st.integers(1, 48),
    n=st.integers(8, 200),
    f=st.integers(8, 260),
    gated=st.booleans(),
    act=st.sampled_from(["gelu", "silu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ffn_kernel_matches_dense_autodiff_oracle(d, rank, k, n, f, gated,
                                                  act, seed):
    """Property: over sampled (d, rank, K, N, F) x gated/ungated x
    silu/gelu, the megakernel's gradients track jax.grad of the dense
    composition to <= 2e-5 relative error in f32."""
    up_spec = make_tt_spec(f, n, d, rank)
    down_spec = make_tt_spec(n, f, d, rank)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    a1, b1 = tt_half_factors(tt_init(ks[0], up_spec), up_spec)
    a2, b2 = tt_half_factors(tt_init(ks[1], down_spec), down_spec)
    if gated:
        ag, bg = tt_half_factors(tt_init(ks[2], up_spec), up_spec)
    else:
        ag = bg = None
    N, F, M = up_spec.in_dim, up_spec.out_dim, down_spec.out_dim
    kx, kg = jax.random.split(ks[3])
    x = jax.random.normal(kx, (k, N))
    gy = jax.random.normal(kg, (k, M))
    got = btt_ffn_bwd_pallas(x, gy, b1, a1, b2, a2, bg, ag, act=act,
                             interpret=True)
    want = _dense_oracle(x, gy, b1, a1, b2, a2, bg, ag, act)
    _assert_close(got, want, 2e-5, _GNAMES)


# ---------------------------------------------------------------------------
# Op level: fused == two-call composition == dense oracle through cores;
# VMEM fallback.
# ---------------------------------------------------------------------------

UP_SPEC = make_tt_spec(768, 768, 3, 12)
DOWN_SPEC = make_tt_spec(768, 768, 3, 12)


def _op_grads(up, down, x, fused_ffn):
    return jax.grad(
        lambda cu, cd, xx: (btt_ffn_op(
            list(cu), list(cd), None, xx, UP_SPEC, DOWN_SPEC, act="gelu",
            interpret=True, fused_ffn=fused_ffn) ** 2).sum(),
        argnums=(0, 1, 2))(tuple(up), tuple(down), x)


def test_op_fused_matches_twocall_and_dense():
    up = tt_init(jax.random.PRNGKey(0), UP_SPEC)
    down = tt_init(jax.random.PRNGKey(1), DOWN_SPEC)
    x = jax.random.normal(jax.random.PRNGKey(2), (32, UP_SPEC.in_dim))
    g_fused = _op_grads(up, down, x, True)
    g_two = _op_grads(up, down, x, False)

    def dense_loss(cu, cd, xx):
        h = jax.nn.gelu(xx @ tt_reconstruct(list(cu), UP_SPEC).T)
        return ((h @ tt_reconstruct(list(cd), DOWN_SPEC).T) ** 2).sum()

    g_dense = jax.grad(dense_loss, argnums=(0, 1, 2))(
        tuple(up), tuple(down), x)
    fu, tu, du = (jax.tree.leaves(g) for g in (g_fused, g_two, g_dense))
    names = [f"leaf{i}" for i in range(len(fu))]
    _assert_close(fu, tu, 1e-5, names)
    _assert_close(fu, du, 2e-4, names)


def test_op_fallback_when_working_set_exceeds_budget():
    """qwen3-class FFN dims bust the megakernel VMEM budget: the op must
    silently take the two-call path (fused_ffn=True notwithstanding) and
    produce BITWISE the same result/gradients as fused_ffn=False — they
    are the same launches."""
    up_spec = make_tt_spec(12288, 4096, 3, 64)
    down_spec = make_tt_spec(4096, 12288, 3, 64)
    assert not ffn_vmem_fits(down_spec.out_dim, up_spec.in_dim,
                             up_spec.out_dim, up_spec.mid_rank,
                             down_spec.mid_rank, 0, 4, K=8)
    up = tt_init(jax.random.PRNGKey(3), up_spec)
    down = tt_init(jax.random.PRNGKey(4), down_spec)
    x = jax.random.normal(jax.random.PRNGKey(5), (8, up_spec.in_dim))

    def run(fused_ffn):
        y, vjp = jax.vjp(
            lambda xx: btt_ffn_op(up, down, None, xx, up_spec, down_spec,
                                  act="gelu", interpret=True,
                                  fused_ffn=fused_ffn), x)
        (gx,) = vjp(jnp.ones_like(y))
        return y, gx

    y_t, gx_t = run(True)
    y_f, gx_f = run(False)
    np.testing.assert_array_equal(np.asarray(y_t), np.asarray(y_f))
    np.testing.assert_array_equal(np.asarray(gx_t), np.asarray(gx_f))


def test_op_twocall_path_bitmatches_manual_composition():
    """The op's fallback IS the two-call path: composing btt_linear_op +
    act by hand must give bitwise the same forward."""
    up = tt_init(jax.random.PRNGKey(0), UP_SPEC)
    down = tt_init(jax.random.PRNGKey(1), DOWN_SPEC)
    x = jax.random.normal(jax.random.PRNGKey(2), (32, UP_SPEC.in_dim))
    y_op = btt_ffn_op(up, down, None, x, UP_SPEC, DOWN_SPEC, act="gelu",
                      interpret=True, fused_ffn=False)
    h = jax.nn.gelu(btt_linear_op(up, x, UP_SPEC, interpret=True))
    y_manual = btt_linear_op(down, h, DOWN_SPEC, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_op), np.asarray(y_manual))


# ---------------------------------------------------------------------------
# Model level: mlp_apply / MoE experts with fused_ffn on/off.
# ---------------------------------------------------------------------------


def test_mlp_apply_fused_ffn_grad_parity():
    """ATIS FFN through mlp_apply: fused_ffn on/off gradient parity (two
    independent backward implementations of the same function)."""
    from repro.configs.atis_transformer import config_n
    from repro.models.layers import make_mlp, mlp_apply

    cfg = config_n(2).with_tt(flow="kernel")
    p = make_mlp(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))

    def grads(c):
        return jax.grad(lambda pp, xx: (mlp_apply(pp, xx, c) ** 2).sum(),
                        argnums=(0, 1))(p, x)

    g_on = grads(cfg.with_fused_ffn(True))
    g_off = grads(cfg)
    leaves_on, leaves_off = jax.tree.leaves(g_on), jax.tree.leaves(g_off)
    _assert_close(leaves_on, leaves_off, 1e-5,
                  [f"leaf{i}" for i in range(len(leaves_on))])


def test_moe_expert_fused_ffn_parity():
    """Per-expert FFN through the megakernel under vmap: fused_ffn on/off
    loss and gradient parity on a TT MoE config."""
    from repro.configs import get_config
    from repro.models import init_params, loss_fn

    cfg = (get_config("qwen2-moe-a2.7b").scaled_down()
           .with_tt(mode="tt", rank=8, embed_rank=8, flow="kernel"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    def lg(c):
        return jax.value_and_grad(
            lambda p: loss_fn(p, c, batch, remat=False))(params)

    l_on, g_on = lg(cfg.with_fused_ffn(True))
    l_off, g_off = lg(cfg)
    np.testing.assert_allclose(float(l_on), float(l_off), rtol=1e-6)
    for u, v in zip(jax.tree.leaves(g_on), jax.tree.leaves(g_off)):
        scale = max(float(jnp.max(jnp.abs(v))), 1e-5)
        np.testing.assert_allclose(np.asarray(u) / scale,
                                   np.asarray(v) / scale,
                                   rtol=0, atol=1e-4)


def test_mlp_apply_fused_ffn_dense_params_fall_back():
    """Dense (tt.mode='off') FFNs are ineligible: fused_ffn must be a
    no-op, bit for bit."""
    from repro.configs.atis_transformer import config_n
    from repro.models.layers import make_mlp, mlp_apply

    cfg = config_n(2, tt_mode="off")
    p = make_mlp(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y_on = mlp_apply(p, x, cfg.with_fused_ffn(True))
    y_off = mlp_apply(p, x, cfg)
    np.testing.assert_array_equal(np.asarray(y_on), np.asarray(y_off))


# ---------------------------------------------------------------------------
# HBM traffic: fused must move strictly fewer bytes (acceptance criterion).
# ---------------------------------------------------------------------------


def test_fused_moves_fewer_hbm_bytes_for_swept_shapes():
    for K, N, F, M, R1, R2, Rg in SHAPES + SINGLE_TILE:
        fused = fused_ffn_hbm_bytes(K, M, N, F, R1, R2, Rg, 4)
        unfused = unfused_ffn_hbm_bytes(K, M, N, F, R1, R2, Rg, 4)
        assert fused < unfused, (K, N, F, M, R1, R2, Rg, fused, unfused)


def test_fused_moves_fewer_hbm_bytes_on_every_shipped_atis_config():
    """Acceptance: for every FFN block of every shipped ATIS config, the
    megakernel's analytic fwd+bwd HBM traffic is strictly below the
    two-call path's."""
    from repro.configs.atis_transformer import config_n
    from repro.core.memory_ledger import _collect_ffn_blocks, _ffn_block_dims
    from repro.models import init_params

    for n_enc in (2, 4, 6):
        cfg = config_n(n_enc)
        params = jax.eval_shape(
            lambda c=cfg: init_params(jax.random.PRNGKey(0), c))
        dims = [_ffn_block_dims(b) for b in _collect_ffn_blocks(params)]
        dims = [d for d in dims if d is not None]
        assert dims, f"{n_enc}-enc config has no TT FFN blocks"
        for M, N, F, R1, R2, Rg, _, _ in dims:
            fused = fused_ffn_hbm_bytes(32, M, N, F, R1, R2, Rg, 4)
            unfused = unfused_ffn_hbm_bytes(32, M, N, F, R1, R2, Rg, 4)
            assert fused < unfused, (n_enc, M, N, F, fused, unfused)
