"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device; only the dry-run
launcher (repro.launch.dryrun) forces 512 placeholder devices, in its own
process."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
