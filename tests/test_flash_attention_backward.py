"""Fused flash-attention backward (kernels.flash_backward) — gradient-oracle
harness, mirroring test_btt_backward's three layers of ground truth:

1. ``flash_attention_bwd_ref`` — the simplest per-head expression of the
   same contractions (P recomputed from the saved (m, l); D = rowsum(dO⊙O)
   as the kernel computes it).  The kernel must match it bit-for-bit on
   unpadded single-tile shapes (identical dot_generals in identical
   accumulation order) and to f32 tolerance elsewhere.
2. Autodiff through dense softmax — ``jax.vjp`` of the naive S×S attention.
   Parametrized over causal / sliding-window / GQA / ragged shapes, plus
   hypothesis property tests sampling the same axes.
3. The op level (``flash_mha_op``): gradient parity with autodiff through
   ``blockwise_attention``, the VMEM-budget fallback (bitwise-identical to
   the blockwise path when the budget gate trips), and the analytic
   HBM-traffic acceptance: the fused path moves strictly fewer bytes than
   the blockwise path on every shipped ATIS config.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.kernels import (
    attn_bwd_vmem_fits,
    flash_attention_bwd_pallas,
    flash_attention_bwd_ref,
    flash_attention_pallas,
    flash_mha_op,
    fused_attn_hbm_bytes,
    unfused_attn_hbm_bytes,
)
from repro.models.attention import blockwise_attention


def naive_attention(q, k, v, causal, window, group):
    """Dense softmax attention, (BH, S, D) layout — the autodiff oracle."""
    BH, S, D = q.shape
    kr = jnp.repeat(k, group, axis=0)
    vr = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / math.sqrt(D)
    idx = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= idx[None, :] <= idx[:, None]
    if window is not None:
        mask &= idx[None, :] > idx[:, None] - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, vr.astype(jnp.float32)).astype(q.dtype)


def _operands(bh_kv, group, S, D, dtype=jnp.float32, seed=None):
    ks = jax.random.split(
        jax.random.PRNGKey(seed if seed is not None else bh_kv + group + S + D), 4)
    q = jax.random.normal(ks[0], (bh_kv * group, S, D), dtype)
    k = jax.random.normal(ks[1], (bh_kv, S, D), dtype)
    v = jax.random.normal(ks[2], (bh_kv, S, D), dtype)
    do = jax.random.normal(ks[3], (bh_kv * group, S, D), dtype)
    return q, k, v, do


def _kernel_grads(q, k, v, do, causal, window, group, tq=None, tk=None):
    o, m, l = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                     group=group, tq=tq, tk=tk,
                                     interpret=True, return_residuals=True)
    return flash_attention_bwd_pallas(q, k, v, o, m, l, do, causal=causal,
                                      window=window, group=group, tq=tq,
                                      tk=tk, interpret=True)


def _oracle_grads(q, k, v, do, causal, window, group):
    _, vjp = jax.vjp(
        lambda a, b, c: naive_attention(a, b, c, causal, window, group),
        q, k, v)
    return vjp(do)


def _assert_close(got, want, tol, names=("dq", "dk", "dv")):
    """Scale-relative comparison (see test_btt_backward for rationale)."""
    for name, u, v in zip(names, got, want):
        u = np.asarray(u, np.float32)
        v = np.asarray(v, np.float32)
        scale = max(float(np.max(np.abs(v))), 1e-6)
        np.testing.assert_allclose(u / scale, v / scale, rtol=0, atol=tol,
                                   err_msg=name)


# ---------------------------------------------------------------------------
# Kernel vs autodiff through dense softmax.
# ---------------------------------------------------------------------------

CASES = [
    # (BH_kv, group, S, D, causal, window)
    (2, 1, 256, 64, True, None),
    (2, 4, 256, 64, True, None),      # GQA
    (1, 2, 300, 80, True, None),      # ragged S and D
    (2, 1, 256, 64, False, None),     # encoder (non-causal; the ATIS model)
    (2, 2, 512, 64, True, 128),       # sliding window
    (1, 1, 32, 64, False, None),      # the paper's S=32 regime, unpadded
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bwd_kernel_matches_dense_autodiff(case, dtype):
    bh_kv, group, S, D, causal, window = case
    q, k, v, do = _operands(bh_kv, group, S, D, dtype)
    got = _kernel_grads(q, k, v, do, causal, window, group)
    want = _oracle_grads(q, k, v, do, causal, window, group)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    _assert_close(got, want, tol)


# ---------------------------------------------------------------------------
# Bit-equality vs the reference on unpadded single-tile shapes.
# ---------------------------------------------------------------------------

SINGLE_TILE = [
    # (BH_kv, group, S, causal, window) — D = 128, tq = tk = S: no padding,
    # one grid step per (head, q-block), identical GEMMs in identical order.
    (2, 2, 256, True, None),
    (1, 1, 128, False, None),
    (2, 1, 32, True, None),
    (2, 1, 32, False, None),
    (1, 1, 256, True, 64),
]


@pytest.mark.parametrize("case", SINGLE_TILE)
def test_bwd_kernel_bitmatches_ref_single_tile(case):
    """One grid step per (head, q-block) => the kernel issues the
    reference's exact GEMMs in the reference's accumulation order; results
    must be bit-identical (both paths fed the same forward (o, m, l))."""
    bh_kv, group, S, causal, window = case
    q, k, v, do = _operands(bh_kv, group, S, 128)
    o, m, l = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                     group=group, tq=S, tk=S, interpret=True,
                                     return_residuals=True)
    got = flash_attention_bwd_pallas(q, k, v, o, m, l, do, causal=causal,
                                     window=window, group=group, tq=S, tk=S,
                                     interpret=True)
    want = flash_attention_bwd_ref(q, k, v, o, m, l, do, causal=causal,
                                   window=window, group=group)
    for name, u, w in zip(("dq", "dk", "dv"), got, want):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(w),
                                      err_msg=name)


@pytest.mark.parametrize("case", CASES[:3])
def test_bwd_kernel_close_to_ref_multi_tile(case):
    """Tiled launches reorder the f32 accumulations; the kernel must still
    track the reference to tolerance on padded/multi-tile shapes."""
    bh_kv, group, S, D, causal, window = case
    q, k, v, do = _operands(bh_kv, group, S, D)
    o, m, l = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                     group=group, tq=128, tk=128,
                                     interpret=True, return_residuals=True)
    got = flash_attention_bwd_pallas(q, k, v, o, m, l, do, causal=causal,
                                     window=window, group=group, tq=128,
                                     tk=128, interpret=True)
    want = flash_attention_bwd_ref(q, k, v, o, m, l, do, causal=causal,
                                   window=window, group=group)
    _assert_close(got, want, 1e-5)


# ---------------------------------------------------------------------------
# Hypothesis property tests: causal/window/GQA/ragged-S sweep at op level.
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    kv=st.integers(1, 2),
    group=st.sampled_from([1, 2, 4]),
    s=st.integers(4, 130),
    d=st.sampled_from([16, 64, 80]),
    causal=st.booleans(),
    windowed=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_op_grads_match_dense_autodiff_oracle(b, kv, group, s, d, causal,
                                              windowed, seed):
    """Property: over sampled (B, KV, group, ragged S, D, causal, window),
    jax.grad through flash_mha_op tracks autodiff through dense softmax."""
    window = max(s // 2, 1) if windowed else None
    H = kv * group
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, s, H, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    do = jax.random.normal(ks[3], (b, s, H, d))

    def fused(q_, k_, v_):
        out = flash_mha_op(q_, k_, v_, causal=causal, window=window,
                           interpret=True)
        return (out * do).sum()

    def oracle(q_, k_, v_):
        qf = q_.transpose(0, 2, 1, 3).reshape(b * H, s, d)
        kf = k_.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
        vf = v_.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
        out = naive_attention(qf, kf, vf, causal, window, group)
        out = out.reshape(b, H, s, d).transpose(0, 2, 1, 3)
        return (out * do).sum()

    got = jax.grad(fused, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(oracle, argnums=(0, 1, 2))(q, k, v)
    _assert_close(got, want, 2e-5)


# ---------------------------------------------------------------------------
# VMEM-budget fallback parity.
# ---------------------------------------------------------------------------


def test_op_fallback_when_budget_exceeded():
    """With a tiny budget the op must silently take the blockwise path —
    bitwise-identical gradients to calling blockwise_attention directly —
    and the grads must still match the dense oracle."""
    B, S, H, KV, D = 1, 96, 4, 2, 32
    assert not attn_bwd_vmem_fits(S, D, 4, budget=1)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))

    def loss_fb(q_, k_, v_):
        return (flash_mha_op(q_, k_, v_, causal=True, q_chunk=32,
                             kv_chunk=32, budget=1) ** 2).sum()

    def loss_bw(q_, k_, v_):
        return (blockwise_attention(q_, k_, v_, causal=True, q_chunk=32,
                                    kv_chunk=32) ** 2).sum()

    g_fb = jax.grad(loss_fb, argnums=(0, 1, 2))(q, k, v)
    g_bw = jax.grad(loss_bw, argnums=(0, 1, 2))(q, k, v)
    for u, w in zip(jax.tree.leaves(g_fb), jax.tree.leaves(g_bw)):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(w))

    def oracle(q_, k_, v_):
        qf = q_.transpose(0, 2, 1, 3).reshape(B * H, S, D)
        kf = k_.transpose(0, 2, 1, 3).reshape(B * KV, S, D)
        vf = v_.transpose(0, 2, 1, 3).reshape(B * KV, S, D)
        out = naive_attention(qf, kf, vf, True, None, H // KV)
        return (out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
                .astype(q_.dtype) ** 2).sum()

    g_or = jax.grad(oracle, argnums=(0, 1, 2))(q, k, v)
    _assert_close(g_fb, g_or, 2e-5)


def test_long_sequences_exceed_real_budget():
    """The real budget gate: decode/prefill-scale sequences (dK/dV residency
    grows with S) must route to the blockwise path."""
    assert not attn_bwd_vmem_fits(32768, 128, 2)
    assert attn_bwd_vmem_fits(32, 64, 4)          # the paper's regime fits


# ---------------------------------------------------------------------------
# Model-level threading: fused_attn flag end to end.
# ---------------------------------------------------------------------------


def test_model_grads_match_with_fused_attn():
    """loss_fn grads with cfg.fused_attn on vs off (ATIS encoder: the
    non-causal paper model) — the flag must be numerics-preserving."""
    from repro.configs.atis_transformer import config_n
    from repro.models import init_params, loss_fn

    cfg = config_n(2).scaled_down(d_model=128, n_heads=4, d_ff=128,
                                  vocab_size=1000, num_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    l0, g0 = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch, remat=False))(params)
    l1, g1 = jax.value_and_grad(
        lambda p: loss_fn(p, cfg.with_fused_attn(True), batch,
                          remat=False))(params)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Analytic HBM traffic: fused must move strictly fewer bytes (acceptance).
# ---------------------------------------------------------------------------


def test_fused_moves_fewer_hbm_bytes_for_shipped_configs():
    """For every shipped ATIS config's attention shape (and a larger
    GQA shape), the fused fwd+bwd launch pair's analytic HBM traffic is
    strictly below the blockwise+autodiff path's."""
    from repro.configs.atis_transformer import config_n

    for n_enc in (2, 4, 6):
        cfg = config_n(n_enc)
        its = jnp.dtype(cfg.dtype).itemsize
        fused = fused_attn_hbm_bytes(1, cfg.n_heads, cfg.n_kv_heads, 32,
                                     cfg.d_head, its, causal=cfg.causal)
        unfused = unfused_attn_hbm_bytes(1, cfg.n_heads, cfg.n_kv_heads, 32,
                                         cfg.d_head, its,
                                         q_chunk=cfg.attn_q_chunk,
                                         kv_chunk=cfg.attn_kv_chunk)
        assert fused < unfused, (n_enc, fused, unfused)
    # At context scale the S×S probability term keeps the blockwise path
    # >1.5x the fused traffic (the fused side's own K/V refetch per Q block
    # bounds the asymptotic ratio near tq/dp — it does not grow unboundedly).
    for S in (256, 1024, 4096):
        fused = fused_attn_hbm_bytes(1, 8, 2, S, 128, 2)
        unfused = unfused_attn_hbm_bytes(1, 8, 2, S, 128, 2)
        assert unfused > 1.5 * fused, (S, fused, unfused)
