"""Optional-``hypothesis`` shim for the property-based tests.

``pip install -e .[test]`` (see pyproject.toml) provides the real
``hypothesis``; in minimal environments without it the property tests are
*skipped* instead of breaking collection for the whole suite.  The stand-in
``st`` object is chainable so module-level strategy expressions
(``st.integers(1, 4).flatmap(...)``) still evaluate at decoration time.

Usage in a test module (instead of ``from hypothesis import ...``):

    from _hypothesis_compat import given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # minimal env: skip property tests, keep the rest
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Chainable stand-in: every call / attribute / operator returns
        another stand-in, so strategy-building expressions evaluate fine."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

        def __or__(self, other):
            return self

    st = _Strategy()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[test])"
            )(fn)
        return deco

    def settings(*args, **kwargs):
        return lambda fn: fn

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
