"""Fused PU-stage kernels (kernels.fused_update) + the memory ledger.

The fused path must be a drop-in for the pure-JAX optimizers: same state
layout, same numerics within fp32 tolerance — including momentum and AdamW
bias correction compounding over multiple steps.  Verified over the real
ATIS TT parameter tree (TT cores, TTM embedding cores, biases, norms), in
interpret mode as with every kernel test here.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.atis_transformer import config_n
from repro.core.cost_model import mem_btt
from repro.core.memory_ledger import (
    BRAM_BUDGET_BYTES,
    URAM_BUDGET_BYTES,
    budget_report,
    training_step_ledger,
)
from repro.core import make_tt_spec
from repro.kernels.fused_update import (
    pack_leaves,
    pu_block_shape,
    unpack_leaves,
)
from repro.models import init_params, num_params
from repro.optim import adamw, sgd

N_STEPS = 4


@pytest.fixture(scope="module")
def tt_params():
    """The paper's 2-encoder ATIS model: TT cores + TTM cores + biases."""
    return init_params(jax.random.PRNGKey(0), config_n(2))


def _fake_grads(params, seed):
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return jax.tree.unflatten(treedef, [
        0.1 * jax.random.normal(k, x.shape, jnp.float32)
        for k, x in zip(keys, leaves)])


def _run_steps(opt, params, n_steps):
    state = opt.init(params)
    upd = jax.jit(lambda g, p, s: opt.update(g, p, s, s["step"]))
    for i in range(n_steps):
        params, state = upd(_fake_grads(params, i), params, state)
    return params, state


def _assert_tree_close(a, b, rtol=2e-6, atol=2e-7):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("momentum", [0.0, 0.9])
def test_fused_sgd_matches_unfused_over_steps(tt_params, momentum):
    p_ref, s_ref = _run_steps(sgd(4e-3, momentum), tt_params, N_STEPS)
    p_fus, s_fus = _run_steps(
        sgd(4e-3, momentum, fused=True, interpret=True), tt_params, N_STEPS)
    _assert_tree_close(p_ref, p_fus)
    if momentum:
        _assert_tree_close(s_ref["mu"], s_fus["mu"])
    assert int(s_fus["step"]) == N_STEPS


def test_fused_adamw_matches_unfused_over_steps(tt_params):
    """Moment EMAs + in-kernel bias correction + weight decay, compounded
    over N steps, must track the pure-JAX path."""
    mk = lambda fused: adamw(1e-3, b1=0.9, b2=0.95, eps=1e-8,
                             weight_decay=0.01, fused=fused,
                             interpret=True if fused else None)
    p_ref, s_ref = _run_steps(mk(False), tt_params, N_STEPS)
    p_fus, s_fus = _run_steps(mk(True), tt_params, N_STEPS)
    _assert_tree_close(p_ref, p_fus)
    _assert_tree_close(s_ref["m"], s_fus["m"], rtol=1e-5, atol=1e-7)
    _assert_tree_close(s_ref["v"], s_fus["v"], rtol=1e-5, atol=1e-9)


def test_fused_sgd_schedule_lr(tt_params):
    """Traced (scheduled) learning rates flow through the SMEM scalars."""
    from repro.optim import warmup_cosine
    lr = warmup_cosine(1e-2, 2, 10)
    p_ref, _ = _run_steps(sgd(lr), tt_params, 3)
    p_fus, _ = _run_steps(sgd(lr, fused=True, interpret=True), tt_params, 3)
    _assert_tree_close(p_ref, p_fus)


def test_fused_mixed_dtype_groups():
    """bf16 params + f32 params in one tree: one kernel launch per group."""
    params = {
        "w16": jnp.ones((96, 40), jnp.bfloat16),
        "w32": jnp.ones((300,), jnp.float32),
    }
    grads = jax.tree.map(lambda p: jnp.full(p.shape, 0.5, jnp.float32), params)
    new = sgd(0.1, fused=True, interpret=True).update(
        grads, params, {"step": jnp.zeros((), jnp.int32)},
        jnp.zeros((), jnp.int32))[0]
    assert new["w16"].dtype == jnp.bfloat16
    assert new["w32"].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(new["w32"]), 0.95, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new["w16"], np.float32), 0.95,
                               rtol=1e-2)


def test_pack_unpack_roundtrip():
    shapes = [(12, 8, 12), (1, 8, 12), (300,), (768, 12)]
    leaves = [jax.random.normal(jax.random.PRNGKey(i), s) for i, s in
              enumerate(shapes)]
    n = sum(int(np.prod(s)) for s in shapes)
    br, rows_p, lanes = pu_block_shape(n)
    assert rows_p % br == 0 and rows_p * lanes >= n
    buf = pack_leaves(leaves, jnp.float32, rows_p, lanes)
    back = unpack_leaves(buf, shapes, [jnp.float32] * len(shapes))
    for x, y in zip(leaves, back):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@given(seed=st.integers(0, 10_000),
       sizes=st.lists(st.integers(1, 400), min_size=1, max_size=8),
       nd=st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip_ragged_property(seed, sizes, nd):
    """Any ragged list of leaf sizes survives pack -> unpack exactly, and
    the padding tail of the packed buffer is zero (the scatter-identity the
    sketched kernel's mask relies on)."""
    rng = np.random.default_rng(seed)
    shapes = []
    for n in sizes:
        if nd == 1 or n < 4:
            shapes.append((n,))
        else:
            d0 = max(int(rng.integers(1, n)), 1)
            shapes.append((d0, -(-n // d0)))  # >= n elems, 2-D
    leaves = [jnp.asarray(rng.normal(size=s), jnp.float32) for s in shapes]
    total = sum(int(np.prod(s)) for s in shapes)
    br, rows_p, lanes = pu_block_shape(total)
    assert rows_p % br == 0 and rows_p * lanes >= total
    buf = pack_leaves(leaves, jnp.float32, rows_p, lanes)
    assert buf.shape == (rows_p, lanes)
    back = unpack_leaves(buf, shapes, [jnp.float32] * len(shapes))
    for x, y in zip(leaves, back):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    flat = np.asarray(buf).reshape(-1)
    np.testing.assert_array_equal(flat[total:], 0.0)


@given(seed=st.integers(0, 10_000), n16=st.integers(1, 300),
       n32=st.integers(1, 300), n8=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_pack_unpack_mixed_dtype_groups_property(seed, n16, n32, n8):
    """_dtype_groups partitions leaves by dtype preserving order; packing
    each group at its own dtype and unpacking restores every leaf exactly
    (bf16/f32 exact since values are stored at their own precision)."""
    from repro.kernels.fused_update import _dtype_groups

    rng = np.random.default_rng(seed)
    leaves = [
        jnp.asarray(rng.normal(size=n32), jnp.float32),
        jnp.asarray(rng.normal(size=n16), jnp.float32).astype(jnp.bfloat16),
        jnp.asarray(rng.normal(size=max(n32 // 2, 1)), jnp.float32),
    ]
    if n8:
        leaves.append(jnp.asarray(rng.integers(-100, 100, size=n8),
                                  jnp.int8))
    groups = _dtype_groups(leaves)
    # every leaf appears in exactly one group, order preserved within
    flat_idx = [i for g in groups for i in g]
    assert sorted(flat_idx) == list(range(len(leaves)))
    for idx in groups:
        dts = {leaves[i].dtype for i in idx}
        assert len(dts) == 1
        assert list(idx) == sorted(idx)
        group = [leaves[i] for i in idx]
        dt = group[0].dtype
        total = sum(int(np.prod(x.shape)) for x in group)
        _, rows_p, lanes = pu_block_shape(total)
        buf = pack_leaves(group, dt, rows_p, lanes)
        back = unpack_leaves(buf, [x.shape for x in group],
                             [dt] * len(group))
        for x, y in zip(group, back):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_pack_unpack_empty_leaf_edge():
    """Zero-size leaves pack to nothing and unpack to their own (empty)
    shape without disturbing their neighbours."""
    shapes = [(7,), (0,), (3, 5), (2, 0, 4)]
    leaves = [jnp.asarray(np.arange(int(np.prod(s))).reshape(s),
                          jnp.float32) for s in shapes]
    total = sum(int(np.prod(s)) for s in shapes)
    _, rows_p, lanes = pu_block_shape(max(total, 1))
    buf = pack_leaves(leaves, jnp.float32, rows_p, lanes)
    back = unpack_leaves(buf, shapes, [jnp.float32] * len(shapes))
    for x, y in zip(leaves, back):
        assert y.shape == x.shape
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Memory ledger vs the cost model, on the paper's config.
# ---------------------------------------------------------------------------

# The paper's layer (Table II): 768x768, d=3, rank 12, uniform ranks —
# built through the model's own factorization path (factorize orders the
# factors (12, 8, 8), a permutation of the paper's printed (8, 8, 12)).
PAPER_SPEC = make_tt_spec(768, 768, 3, 12, clamp_ranks=False)
K_PAPER = 32  # batch 1 x seq 32


@pytest.fixture(scope="module")
def atis_ledger():
    return training_step_ledger(config_n(2), "sgd", batch=1, seq=32)


def test_ledger_tt_intermediates_match_cost_model(atis_ledger):
    """The FWD/BWD intermediate entry is exactly Eq. (21) on the paper's
    768x768 rank-12 layer (the largest TT layer in the ATIS model)."""
    expect = mem_btt(PAPER_SPEC, K_PAPER) * 4  # f32
    assert atis_ledger["FWD"].entry("tt_intermediates").nbytes == expect
    assert atis_ledger["BWD"].entry("tt_intermediates").nbytes == expect


def test_ledger_param_and_grad_totals(atis_ledger, tt_params):
    """params entry == eval_shape-exact bytes == the real initialized tree;
    grads entry == one f32 per parameter."""
    n = num_params(tt_params)
    assert atis_ledger["PU"].entry("params").nbytes == n * 4  # fp32 model
    assert atis_ledger["BWD"].entry("grads").nbytes == n * 4
    # SGD without momentum keeps no moments.
    assert atis_ledger["PU"].entry("moments").nbytes == 0


def test_ledger_adamw_moments(tt_params):
    led = training_step_ledger(config_n(2), "adamw")
    assert led["PU"].entry("moments").nbytes == num_params(tt_params) * 2 * 4


def test_ledger_fits_paper_envelope(atis_ledger):
    """The paper's central claim, checked in software: every stage of the
    ATIS training step fits the 6 MB BRAM + 22.5 MB URAM envelope."""
    rep = budget_report(atis_ledger)
    assert rep["fits_bram"] and rep["fits_uram"] and rep["fits"]
    assert rep["bram_peak_bytes"] <= BRAM_BUDGET_BYTES
    assert rep["uram_peak_bytes"] <= URAM_BUDGET_BYTES
    # ... and the 6-encoder model still fits (paper Table IV runs it).
    rep6 = budget_report(training_step_ledger(config_n(6), "sgd"))
    assert rep6["fits"]


def test_ledger_matrix_model_busts_budget():
    """Sanity inversion: the uncompressed (matrix) model must NOT fit —
    otherwise the ledger isn't measuring anything."""
    rep = budget_report(training_step_ledger(config_n(2, tt_mode="off"),
                                             "sgd"))
    assert not rep["fits_bram"]
