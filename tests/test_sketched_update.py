"""Optimizer-oracle suite for the sketched-AdamW PU kernel.

The sketch is lossy BY DESIGN, so — like the gradient-oracle harnesses for
the BWD/ATTN/FFN kernels — the deliverable here is the harness that bounds
the loss:

* the non-sketched fallback is BIT-equal to ``fused_adamw_update`` (the
  sketch may only ever change numerics when it is actually engaged);
* a dense-reference NumPy oracle computes the exact same hashes
  (``sketch_bucket_ids`` / ``sketch_signs`` are shared functions) and the
  kernel's sketches match it;
* the count-min overestimate invariant: the sketch estimate of ``v`` never
  under-shoots the true dense ``v``, elementwise, after any number of
  steps (property-tested over random shapes/widths/depths);
* recovery error is a decreasing function of sketch width;
* an ATIS convergence smoke: sketched loss tracks dense AdamW within 5%.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.fused_update import (
    SKETCH_DEPTH_DEFAULT,
    default_sketch_width,
    fused_adamw_update,
    sketch_bucket_ids,
    sketch_pu_fits,
    sketch_signs,
    sketch_state_bytes,
    sketched_adamw_update,
    sketched_pu_hbm_bytes,
    fused_pu_hbm_bytes,
)
from repro.optim import adamw

B1, B2, EPS, WD = 0.9, 0.95, 1e-8, 0.01


# ---------------------------------------------------------------------------
# Dense-reference NumPy oracle: same hashes, same update order semantics.
# ---------------------------------------------------------------------------


def _hashes(n, depth, width):
    idx = np.arange(n)
    h = np.asarray(sketch_bucket_ids(idx, depth, width))
    s = np.asarray(sketch_signs(idx, depth))
    return h, s


def _oracle_query(vs, ms, h, s):
    """(est_v, est_m) for every parameter: count-min min-over-rows and
    count-sketch lower-median-over-rows — exactly the kernel's estimators."""
    depth = vs.shape[0]
    rows = np.arange(depth)[:, None]
    est_v = np.min(vs[rows, h], axis=0)
    est_m = np.sort(ms[rows, h] * s, axis=0)[(depth - 1) // 2]
    return est_v, est_m


def _oracle_step(p, g, vs, ms, t, h, s, lr):
    """One full sketched-AdamW step on flat f32 arrays (dense reference)."""
    depth, width = vs.shape
    est_v, est_m = _oracle_query(vs, ms, h, s)
    m_new = B1 * est_m + (1.0 - B1) * g
    v_new = B2 * est_v + (1.0 - B2) * g * g
    # conservative count-min refresh (max over colliders of the decayed
    # estimate) + linear count-sketch refresh (decay cells, add increments)
    vs_out = np.zeros_like(vs)
    ms_out = B1 * ms
    for r in range(depth):
        np.maximum.at(vs_out[r], h[r], v_new)
        np.add.at(ms_out[r], h[r], s[r] * (1.0 - B1) * g)
    bc1 = 1.0 - B1 ** t
    bc2 = 1.0 - B2 ** t
    step = lr * (m_new / bc1) / (np.sqrt(v_new / bc2) + EPS) + lr * WD * p
    return p - step, vs_out, ms_out


def _run_kernel(p0, grads_per_step, depth, width, lr):
    """T steps of the real kernel over a single-leaf tree; returns the
    param trajectory and final sketches."""
    params = {"w": jnp.asarray(p0)}
    vs = jnp.zeros((depth, width), jnp.float32)
    ms = jnp.zeros((depth, width), jnp.float32)
    for t, g in enumerate(grads_per_step, start=1):
        params, vs, ms = sketched_adamw_update(
            params, {"w": jnp.asarray(g)}, vs, ms, lr, t,
            b1=B1, b2=B2, eps=EPS, weight_decay=WD)
    return np.asarray(params["w"]), np.asarray(vs), np.asarray(ms)


def test_kernel_matches_dense_reference_oracle():
    """Multi-step: the Pallas kernel's params AND sketches track the NumPy
    oracle (max-scatter is order-independent -> vs near-exact; ms/params
    differ only by float summation order)."""
    rng = np.random.default_rng(0)
    n, depth, width, steps = 700, 3, 256, 4
    p0 = rng.normal(size=n).astype(np.float32)
    gs = [rng.normal(size=n).astype(np.float32) * 0.1 for _ in range(steps)]
    h, s = _hashes(n, depth, width)

    kp, kvs, kms = _run_kernel(p0, gs, depth, width, lr=1e-2)
    p, vs, ms = p0.copy(), np.zeros((depth, width), np.float32), \
        np.zeros((depth, width), np.float32)
    for t, g in enumerate(gs, start=1):
        p, vs, ms = _oracle_step(p, g, vs, ms, t, h, s, lr=1e-2)

    np.testing.assert_allclose(kvs, vs, rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(kms, ms, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(kp, p, rtol=1e-5, atol=1e-6)


def test_kernel_multi_leaf_multi_dtype_groups():
    """Mixed-dtype trees launch one kernel per dtype group with chained
    sketch seeds and global flat offsets; the final sketches must cover the
    whole tree exactly as a single concatenated oracle pass."""
    rng = np.random.default_rng(1)
    depth, width = 3, 256
    params = {
        "a": jnp.asarray(rng.normal(size=300), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(20, 11)), jnp.float32),
        "c": jnp.asarray(rng.normal(size=150), jnp.bfloat16),
    }
    grads = jax.tree.map(
        lambda x: jnp.asarray(rng.normal(size=x.shape), jnp.float32), params)
    vs = jnp.zeros((depth, width), jnp.float32)
    ms = jnp.zeros((depth, width), jnp.float32)
    newp, vs1, ms1 = sketched_adamw_update(
        params, grads, vs, ms, 1e-2, 1, b1=B1, b2=B2, eps=EPS,
        weight_decay=WD)
    assert jax.tree.map(lambda x: x.shape, newp) == \
        jax.tree.map(lambda x: x.shape, params)
    assert newp["c"].dtype == jnp.bfloat16

    # Oracle over the SAME concatenated layout: f32 group (a, b) at offset
    # 0, bf16 group (c) after it — dtype groups preserve leaf order.
    ga = np.ravel(np.asarray(grads["a"]))
    gb = np.ravel(np.asarray(grads["b"]))
    gc = np.ravel(np.asarray(grads["c"]))
    g = np.concatenate([ga, gb, gc]).astype(np.float32)
    n = g.size
    h, s = _hashes(n, depth, width)
    v_new = (1.0 - B2) * g * g
    vs_ref = np.zeros((depth, width), np.float32)
    ms_ref = np.zeros((depth, width), np.float32)
    for r in range(depth):
        np.maximum.at(vs_ref[r], h[r], v_new)
        np.add.at(ms_ref[r], h[r], s[r] * (1.0 - B1) * g)
    np.testing.assert_allclose(np.asarray(vs1), vs_ref, rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(ms1), ms_ref, rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# The count-min overestimate invariant (property-tested on the oracle; the
# oracle==kernel test above transfers it to the kernel).
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 10_000), n=st.integers(10, 400),
       logw=st.integers(7, 10), depth=st.integers(2, 4),
       steps=st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_cms_overestimate_invariant(seed, n, logw, depth, steps):
    """After any number of steps, the count-min estimate of ``v`` is >= the
    true dense ``v``, elementwise — collisions can only INFLATE the second
    moment (shrink Adam steps), never deflate it."""
    rng = np.random.default_rng(seed)
    width = 2 ** logw
    h, s = _hashes(n, depth, width)
    p = rng.normal(size=n).astype(np.float32)
    vs = np.zeros((depth, width), np.float32)
    ms = np.zeros((depth, width), np.float32)
    v_dense = np.zeros(n, np.float32)
    for t in range(1, steps + 1):
        g = rng.normal(size=n).astype(np.float32)
        v_dense = B2 * v_dense + (1.0 - B2) * g * g
        p, vs, ms = _oracle_step(p, g, vs, ms, t, h, s, lr=1e-3)
        est_v, _ = _oracle_query(vs, ms, h, s)
        assert (est_v >= v_dense - 1e-7 * (1.0 + v_dense)).all(), \
            f"CMS under-estimated v at step {t}"


def test_cms_overestimate_invariant_on_kernel():
    """The invariant on the REAL kernel (not just the oracle): run steps,
    query the returned sketches, compare against dense-v tracking."""
    rng = np.random.default_rng(3)
    n, depth, width = 900, 3, 256
    h, s = _hashes(n, depth, width)
    p0 = rng.normal(size=n).astype(np.float32)
    gs = [rng.normal(size=n).astype(np.float32) for _ in range(3)]
    _, kvs, _ = _run_kernel(p0, gs, depth, width, lr=1e-3)
    v_dense = np.zeros(n, np.float32)
    for g in gs:
        v_dense = B2 * v_dense + (1.0 - B2) * g * g
    est_v = np.min(kvs[np.arange(depth)[:, None], h], axis=0)
    assert (est_v >= v_dense - 1e-6 * (1.0 + v_dense)).all()


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_recovery_error_decreases_with_width(seed):
    """The width dial: mean count-min overestimate (est - true v, always
    >= 0 by the invariant) must shrink as buckets are added, and be small
    once width approaches n."""
    rng = np.random.default_rng(seed)
    n, depth = 4096, 3
    g = rng.normal(size=n).astype(np.float32)
    v = (1.0 - B2) * g * g
    errs = []
    for width in (128, 512, 2048):
        h, _ = _hashes(n, depth, width)
        vs = np.zeros((depth, width), np.float32)
        for r in range(depth):
            np.maximum.at(vs[r], h[r], v)
        est = np.min(vs[np.arange(depth)[:, None], h], axis=0)
        err = est - v
        assert (err >= -1e-9).all()
        errs.append(float(err.mean()))
    # 4x the buckets -> strictly fewer collisions in expectation; allow
    # 10% slack for unlucky hash draws at a fixed seed.
    assert errs[1] <= errs[0] * 1.1
    assert errs[2] <= errs[1] * 1.1
    # at width 2048 (n/2 per row, depth 3) the estimate is near-exact for
    # most coordinates
    assert errs[2] < 0.5 * errs[0]


# ---------------------------------------------------------------------------
# Fallback parity: when the sketch is NOT engaged, numerics are bit-equal
# to the dense fused path.
# ---------------------------------------------------------------------------


def _bit_equal_trees(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _run_opt(opt, params, grads_per_step):
    state = opt.init(params)
    for g in grads_per_step:
        params, state = opt.update(g, params, state, state["step"])
    return params, state


def test_fallback_small_tree_bitwise_parity():
    """A tiny tree fails the memory-win half of ``sketch_pu_fits``: init
    must return dense fused state and every step must be BITWISE identical
    to ``adamw(fused=True)``."""
    rng = np.random.default_rng(5)
    params = {"w": jnp.asarray(rng.normal(size=64), jnp.float32)}
    gs = [{"w": jnp.asarray(rng.normal(size=64), jnp.float32)}
          for _ in range(3)]
    opt_s = adamw(1e-3, weight_decay=WD, sketched=True)
    opt_f = adamw(1e-3, weight_decay=WD, fused=True)
    st_s = opt_s.init(params)
    assert "vs" not in st_s and "m" in st_s  # fallback engaged
    ps, ss = _run_opt(opt_s, params, gs)
    pf, sf = _run_opt(opt_f, params, gs)
    _bit_equal_trees(ps, pf)
    _bit_equal_trees(ss, sf)


def test_fallback_oversized_sketch_bitwise_parity():
    """An absurd ``sketch_width`` fails the VMEM half of the predicate —
    same dense fallback, same bitwise parity, on a tree that WOULD sketch
    at the default width."""
    rng = np.random.default_rng(6)
    params = {"w": jnp.asarray(rng.normal(size=40_000), jnp.float32)}
    n = 40_000
    assert sketch_pu_fits(n, default_sketch_width(n), SKETCH_DEPTH_DEFAULT)
    assert not sketch_pu_fits(n, 2 ** 22, SKETCH_DEPTH_DEFAULT)
    gs = [{"w": jnp.asarray(rng.normal(size=n), jnp.float32)}
          for _ in range(2)]
    opt_s = adamw(1e-3, sketched=True, sketch_width=2 ** 22)
    opt_f = adamw(1e-3, fused=True)
    assert "vs" not in opt_s.init(params)
    ps, ss = _run_opt(opt_s, params, gs)
    pf, sf = _run_opt(opt_f, params, gs)
    _bit_equal_trees(ps, pf)
    _bit_equal_trees(ss, sf)


def test_sketched_first_step_bitwise_matches_dense():
    """Step 1 from zero sketches: est_v = est_m = 0, so the sketched kernel
    computes the EXACT float sequence of the dense kernel — bit-equal
    params before any lossiness can appear."""
    rng = np.random.default_rng(7)
    params = {"w": jnp.asarray(rng.normal(size=30_000), jnp.float32)}
    grads = {"w": jnp.asarray(rng.normal(size=30_000), jnp.float32)}
    opt_s = adamw(1e-3, weight_decay=WD, sketched=True)
    st_s = opt_s.init(params)
    assert "vs" in st_s  # sketch actually engaged
    ps, _ = opt_s.update(grads, params, st_s, st_s["step"])
    m0 = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
    v0 = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
    pd, _, _ = fused_adamw_update(params, grads, m0, v0, 1e-3, 1.0,
                                  b1=B1, b2=B2, eps=EPS, weight_decay=WD)
    _bit_equal_trees(ps, pd)


# ---------------------------------------------------------------------------
# Size/traffic helpers (consistency of the analytic surface the ledger and
# benchmarks consume).
# ---------------------------------------------------------------------------


def test_default_width_guarantees_memory_win():
    """``default_sketch_width`` must make the sketch state at least 8x
    smaller than ONE dense moment buffer (16x vs AdamW's two), and pass
    the fits predicate, for any plausible parameter count."""
    for n in (10_000, 3 * 10 ** 5, 10 ** 6, 10 ** 7):
        w = default_sketch_width(n)
        assert w & (w - 1) == 0
        state = sketch_state_bytes(SKETCH_DEPTH_DEFAULT, w)
        assert state * 8 <= 2 * n * 4
        assert sketch_pu_fits(n, w)


def test_sketched_hbm_bytes_beat_dense_fused():
    leaves = [jax.ShapeDtypeStruct((1000, 350), jnp.float32)]
    assert sketched_pu_hbm_bytes(leaves) < fused_pu_hbm_bytes(leaves,
                                                              "adamw")


def test_width_must_be_power_of_two():
    with pytest.raises(ValueError):
        sketch_bucket_ids(jnp.arange(4), 3, 100)


# ---------------------------------------------------------------------------
# ATIS convergence smoke: the end-to-end bound on the sketch's lossiness.
# ---------------------------------------------------------------------------


def test_atis_convergence_sketched_tracks_dense():
    """Short tensor-compressed ATIS run, dense fused AdamW vs sketched:
    final training loss within 5% relative (the acceptance bound)."""
    from repro.configs.atis_transformer import config_n
    from repro.data import AtisGrammar, atis_batch
    from repro.models import init_params
    from repro.models.classifier import atis_heads_init, atis_loss

    cfg = config_n(2).scaled_down(d_model=128, n_heads=4, d_ff=128,
                                  vocab_size=1000, num_layers=2)
    g = AtisGrammar(seed=1)

    def run(opt, steps=60):
        params = {"backbone": init_params(jax.random.PRNGKey(0), cfg),
                  "heads": atis_heads_init(jax.random.PRNGKey(1), cfg,
                                           26, 120)}
        state = opt.init(params)

        @jax.jit
        def step(params, state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: atis_loss(p, cfg, batch))(params)
            params, state = opt.update(grads, params, state, state["step"])
            return params, state, loss

        loss = None
        for i in range(steps):
            batch = {k: jnp.asarray(v)
                     for k, v in atis_batch(g, "train", i, 32).items()}
            params, state, loss = step(params, state, batch)
        return float(loss), state

    loss_d, _ = run(adamw(2e-3, fused=True))
    loss_s, st_s = run(adamw(2e-3, sketched=True))
    assert "vs" in st_s  # the sketch path was actually exercised
    assert loss_s < loss_d * 1.05, (loss_d, loss_s)
    # and it genuinely trained (same bar as test_atis_task_learns)
    assert loss_s < 8.0
