"""HLO analyzers: trip-count-aware walker + collective parser on synthetic
HLO text with known ground truth."""
from repro.launch.hlo_analysis import parse_collectives, roofline_terms
from repro.launch.hlo_flops import analyze_hlo

# A miniature partitioned module: one dot in a fusion inside a 10-trip while,
# one all-reduce over groups of 16, one bf16-emulation convert fusion.
HLO = """\
HloModule test

%wrapped_compare_computation (a: s32[], b: s32[]) -> pred[] {
  %a = s32[] parameter(0)
  %b = s32[] parameter(1)
  ROOT %cmp = pred[] compare(%a, %b), direction=LT
}

%cond.1 (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c10 = s32[] constant(10)
  ROOT %lt = pred[] fusion(%i, %c10), kind=kLoop, calls=%wrapped_compare_computation
}

%inner.dot (pa: f32[8,32], pb: f32[32,16]) -> f32[8,16] {
  %pa = f32[8,32]{1,0} parameter(0)
  %pb = f32[32,16]{1,0} parameter(1)
  ROOT %d = f32[8,16]{1,0} dot(%pa, %pb), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  %x = f32[8,32]{1,0} parameter(1)
  %w = f32[32,16]{1,0} parameter(2)
  %y = f32[8,16]{1,0} fusion(%x, %w), kind=kOutput, calls=%inner.dot
  %ar = f32[8,16]{1,0} all-reduce(%y), replica_groups=[16,16]<=[256], to_apply=%sum
  ROOT %t = (s32[], f32[8,16]) tuple(%ip, %ar)
}

%conv.emul (q: bf16[128,128]) -> f32[128,128] {
  %q = bf16[128,128]{1,0} parameter(0)
  ROOT %cv = f32[128,128]{1,0} convert(%q)
}

ENTRY %main (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg = (s32[], f32[8,16]) parameter(0)
  %big = bf16[128,128]{1,0} parameter(1)
  %emul = f32[128,128]{1,0} fusion(%big), kind=kLoop, calls=%conv.emul
  ROOT %w = (s32[], f32[8,16]) while(%arg), condition=%cond.1, body=%body.1
}
"""


def test_walker_trip_count_and_dot_flops():
    s = analyze_hlo(HLO)
    # dot: 2*8*16*32 = 8192 flops; while trips = 10; add(1 flop) per trip
    assert s.flops == 10 * (8192 + 1)


def test_walker_collectives_with_trips():
    s = analyze_hlo(HLO)
    # all-reduce of 8*16*4 bytes, group 16 -> wire = 2*512*15/16 = 960; x10
    assert s.collective_counts["all-reduce"] == 10
    assert abs(s.collective_wire_bytes - 10 * 960) < 1
    assert s.collective_result_bytes == 10 * 512


def test_walker_ignores_dtype_emulation():
    s = analyze_hlo(HLO)
    # the conv.emul fusion (pure convert) must contribute zero bytes; the
    # remaining bytes come from the while body's dot fusion + all-reduce.
    per_trip = (8 * 32 * 4 + 32 * 16 * 4 + 8 * 16 * 4) + 2 * 512
    assert s.bytes == 10 * per_trip


def test_collective_parser_group_formats():
    stats = parse_collectives(
        "%ag = f32[64,16]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}\n"
        "%cp = bf16[32]{0} collective-permute(%y), source_target_pairs={{0,1}}\n")
    assert stats.counts == {"all-gather": 1, "collective-permute": 1}
    rb = 64 * 16 * 4
    assert stats.result_bytes["all-gather"] == rb
    assert stats.wire_bytes["all-gather"] == int(rb * 3 / 4)
    assert stats.wire_bytes["collective-permute"] == 64


def test_roofline_terms_bottleneck():
    t = roofline_terms(1e15, 1e9, 1e9)
    assert t["bottleneck"] == "compute_s"
    t2 = roofline_terms(1e12, 1e12, 1e9)
    assert t2["bottleneck"] == "memory_s"
