"""Checkpointing (atomicity, keep-k, async, integrity/CRC, corrupt-step
fallback) + runtime (sharding rules, straggler monitor, EF compression)."""
import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.checkpoint import (
    CheckpointCorruptError,
    CheckpointManager,
    latest_step,
    list_steps,
    restore,
    restore_latest_valid,
    save,
    verify_step,
)
from repro.configs import SHAPES, get_config
from repro.core import tt_linear_init
from repro.launch.steps import make_inputs
from repro.models import init_params
from repro.runtime import (
    CheckpointCadence,
    StragglerMonitor,
    batch_specs,
    cache_specs,
    dequantize_int8,
    ef_compress_tree,
    ef_init,
    kv_repeat_for_mesh,
    param_specs,
    quantize_int8,
)


def _tree(seed=0):
    return {
        "lin": tt_linear_init(jax.random.PRNGKey(seed), 128, 128, d=2, rank=4),
        "emb": {"table": jax.random.normal(jax.random.PRNGKey(seed + 1), (64, 16))},
        "step": jnp.asarray(41),
    }


def _template(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t)
    restored, step = restore(str(tmp_path), _template(t))
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), b)


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30, 40):
        mgr.save_async(s, _tree())
    mgr.wait()
    assert list_steps(str(tmp_path)) == [30, 40]
    assert latest_step(str(tmp_path)) == 40
    _, step = mgr.restore_latest(_template(_tree()))
    assert step == 40


def test_checkpoint_atomicity_partial_dir_ignored(tmp_path):
    """A crash mid-save (stray tmp dir, no manifest entry) must not corrupt
    restore."""
    t = _tree()
    save(str(tmp_path), 5, t)
    # simulate a crashed writer: partial temp dir + orphan step dir
    os.makedirs(tmp_path / ".tmp_save_crash")
    (tmp_path / ".tmp_save_crash" / "leaf_00000.npy").write_bytes(b"garbage")
    os.makedirs(tmp_path / "step_00000099")  # no meta.json, not in manifest
    restored, step = restore(str(tmp_path), _template(t))
    assert step == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    bad = _template(t)
    bad["emb"]["table"] = jax.ShapeDtypeStruct((65, 16), jnp.float32)
    with pytest.raises(ValueError):
        restore(str(tmp_path), bad)


def test_checkpoint_manifest_is_json(tmp_path):
    save(str(tmp_path), 3, _tree())
    m = json.load(open(tmp_path / "manifest.json"))
    assert m["latest"] == 3


def test_checkpoint_fused_sketched_opt_state_roundtrip(tmp_path):
    """Fused-optimizer state including the sketch buffers survives a
    checkpoint round-trip, and a restored run continues BIT-identically —
    the hash families are module-level constants, so bucket assignment is
    stable across processes and the sketches resume exactly."""
    from repro.optim import adamw

    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=30_000), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(40, 12)), jnp.float32)}
    grads = [jax.tree.map(
        lambda x: jnp.asarray(rng.normal(size=x.shape), jnp.float32), params)
        for _ in range(4)]
    opt = adamw(1e-3, weight_decay=0.01, sketched=True)
    state = opt.init(params)
    assert "vs" in state  # sketch engaged: buffers are part of the state

    # two steps, checkpoint, two more
    for g in grads[:2]:
        params, state = opt.update(g, params, state, state["step"])
    save(str(tmp_path), 2, (params, state))
    for g in grads[2:]:
        params, state = opt.update(g, params, state, state["step"])

    # restore mid-run and replay the same two steps
    (rp, rs), step = restore(str(tmp_path), _template((params, state)))
    assert step == 2
    rp = jax.tree.map(jnp.asarray, rp)
    rs = jax.tree.map(jnp.asarray, rs)
    assert rs["vs"].shape == state["vs"].shape
    for g in grads[2:]:
        rp, rs = opt.update(g, rp, rs, rs["step"])
    for a, b in zip(jax.tree.leaves((params, state)),
                    jax.tree.leaves((rp, rs))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Integrity: per-leaf CRC, corrupt-step fallback, async-writer failures.
# ---------------------------------------------------------------------------


def test_crc_recorded_and_verified(tmp_path):
    t = _tree()
    save(str(tmp_path), 4, t)
    meta = json.load(open(tmp_path / "step_00000004" / "meta.json"))
    assert all("crc32" in rec for rec in meta["leaves"])
    assert verify_step(str(tmp_path), 4)


@pytest.mark.parametrize("mode", ["flip", "truncate", "delete", "meta"])
def test_corrupt_step_restore_raises(tmp_path, mode):
    """Any corruption of the newest step must surface as an exception on
    direct restore — never as silently wrong weights."""
    from repro.runtime.chaos import corrupt_checkpoint

    t = _tree()
    save(str(tmp_path), 4, t)
    corrupt_checkpoint(str(tmp_path), 4, mode=mode, seed=1)
    assert not verify_step(str(tmp_path), 4)
    with pytest.raises((CheckpointCorruptError, ValueError, OSError,
                        KeyError, EOFError, FileNotFoundError)):
        restore(str(tmp_path), _template(t))


def test_flip_corruption_is_crc_not_shape(tmp_path):
    """A bit flip inside leaf DATA keeps shape/dtype valid — only the CRC
    catches it, and it reports as CheckpointCorruptError specifically."""
    t = _tree()
    save(str(tmp_path), 4, t)
    # corrupt a byte well past the .npy header, inside the payload
    step_dir = tmp_path / "step_00000004"
    leaf = sorted(f for f in os.listdir(step_dir)
                  if f.startswith("leaf_"))[0]
    path = step_dir / leaf
    data = bytearray(path.read_bytes())
    data[-1] ^= 0xFF
    path.write_bytes(bytes(data))
    with pytest.raises(CheckpointCorruptError):
        restore(str(tmp_path), _template(t))


def test_restore_latest_valid_falls_back_and_repairs(tmp_path):
    from repro.runtime.chaos import corrupt_checkpoint

    trees = {s: _tree(seed=s) for s in (1, 2, 3)}
    for s, t in trees.items():
        save(str(tmp_path), s, t)
    corrupt_checkpoint(str(tmp_path), 3, mode="truncate", seed=0)
    got = restore_latest_valid(str(tmp_path), _template(trees[1]))
    assert got is not None
    (restored, step), skipped = got
    assert step == 2 and skipped == [3]
    for a, b in zip(jax.tree.leaves(trees[2]), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # repaired: the bad step is pruned from manifest AND disk, so plain
    # restore now works without the fallback
    assert list_steps(str(tmp_path)) == [1, 2]
    assert not (tmp_path / "step_00000003").exists()
    _, step = restore(str(tmp_path), _template(trees[1]))
    assert step == 2


def test_restore_latest_valid_all_corrupt_returns_none(tmp_path):
    from repro.runtime.chaos import corrupt_checkpoint

    t = _tree()
    save(str(tmp_path), 1, t)
    corrupt_checkpoint(str(tmp_path), 1, mode="delete", seed=0)
    assert restore_latest_valid(str(tmp_path), _template(t)) is None
    # nothing valid found -> nothing repaired/deleted (wrong-template
    # safety: a bad template must not nuke good checkpoints)
    assert list_steps(str(tmp_path)) == [1]


def test_async_writer_failure_reraised_by_wait(tmp_path):
    """Satellite fix: a background-save exception must re-raise from
    wait(), not vanish into the thread — and the crashed save must leave
    no step directory (atomicity)."""
    from repro.runtime.chaos import WriterCrash, async_writer_crash

    mgr = CheckpointManager(str(tmp_path), keep=3)
    t = _tree()
    mgr.save_async(1, t)
    mgr.wait()
    with async_writer_crash(after_leaves=2):
        mgr.save_async(2, t)
        with pytest.raises(RuntimeError, match="step 2"):
            mgr.wait()
    assert list_steps(str(tmp_path)) == [1]
    assert not any(d.startswith(".tmp_save") for d in os.listdir(tmp_path))
    # the cause chain names the real failure
    try:
        with async_writer_crash():
            mgr.save_async(3, t)
            mgr.wait()
    except RuntimeError as e:
        assert isinstance(e.__cause__, WriterCrash)
    else:
        raise AssertionError("wait() swallowed the writer crash")
    # the manager recovers: a later save works
    mgr.save_async(4, t)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 4


def test_manager_restore_latest_valid_skips_corrupt(tmp_path):
    from repro.runtime.chaos import corrupt_checkpoint

    mgr = CheckpointManager(str(tmp_path), keep=3)
    for s in (1, 2):
        mgr.save_async(s, _tree(seed=s))
    mgr.wait()
    corrupt_checkpoint(str(tmp_path), 2, mode="flip", seed=5)
    got = mgr.restore_latest_valid(_template(_tree()))
    assert got is not None and got[1] == 1


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16),
       mode=st.sampled_from(["flip", "truncate", "delete", "meta"]),
       data=st.data())
def test_property_corruption_never_loses_the_run(seed, mode, data):
    """PROPERTY: whatever byte of whichever leaf of the newest checkpoint
    is flipped/truncated/deleted, ``restore_latest_valid`` returns the
    earlier intact step BIT-identically and never raises.  (Fresh tmpdir
    per example — pytest's tmp_path is per-test, not per-example.)"""
    from repro.runtime.chaos import corrupt_checkpoint

    root = tempfile.mkdtemp(prefix="ckpt_prop_")
    try:
        good = _tree(seed=7)
        save(root, 5, good)
        save(root, 9, _tree(seed=8))
        n_leaves = len(jax.tree.leaves(good))
        leaf = (data.draw(st.integers(0, n_leaves - 1))
                if mode in ("flip", "truncate", "delete") else None)
        corrupt_checkpoint(root, 9, leaf=leaf, mode=mode, seed=seed)
        got = restore_latest_valid(root, _template(good))
        assert got is not None
        (restored, step), skipped = got
        assert step == 5 and skipped == [9]
        for a, b in zip(jax.tree.leaves(good), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        shutil.rmtree(root, ignore_errors=True)


# ---------------------------------------------------------------------------
# Sharding rules (single-device mesh: specs must still be derivable).
# ---------------------------------------------------------------------------


def _leaf_specs(tree):
    return jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ["qwen3-8b", "llama4-maverick-400b-a17b",
                                  "mamba2-130m", "recurrentgemma-2b"])
def test_param_specs_cover_every_leaf(arch):
    cfg = get_config(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    specs = param_specs(cfg, params, mesh)
    p_leaves = jax.tree.leaves(params)
    s_leaves = _leaf_specs(specs)
    assert len(p_leaves) == len(s_leaves)
    for leaf, spec in zip(p_leaves, s_leaves):
        assert len(tuple(spec)) <= len(leaf.shape)


def test_param_specs_tt_cores_replicated():
    cfg = get_config("qwen3-8b").with_tt(mode="tt")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    specs = param_specs(cfg, params, mesh)
    sflat = _leaf_specs(specs)
    for (path, leaf), spec in zip(flat, sflat):
        if ".cores[" in jax.tree_util.keystr(path) or "cores" in str(path):
            assert tuple(spec) == () or all(s is None for s in tuple(spec)), \
                f"TT core {jax.tree_util.keystr(path)} not replicated: {spec}"


def test_batch_and_cache_specs():
    cfg = get_config("llama3-8b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = SHAPES["decode_32k"]
    kvr = kv_repeat_for_mesh(cfg, mesh)
    inputs = make_inputs(cfg, shape, kv_repeat=kvr)
    cs = cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
    # structurally compatible with the cache inputs
    jax.tree.map(lambda leaf, spec: None, inputs["cache"], cs)
    bs = batch_specs({"tokens": inputs["tokens"]}, mesh)
    assert isinstance(bs["tokens"], P)


def test_kv_repeat_rules():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert kv_repeat_for_mesh(get_config("llama3-8b"), mesh) >= 1
    # 16-way TP mesh requires fake devices; the divisor logic is pure:
    from repro.runtime.sharding import kv_repeat_for_mesh as f
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    assert f(get_config("llama3-8b"), FakeMesh()) == 2       # kv8 x2 = 16
    assert f(get_config("recurrentgemma-2b"), FakeMesh()) == 1  # 10 heads
    assert f(get_config("qwen3-8b"), FakeMesh()) == 2        # kv8 group4


# ---------------------------------------------------------------------------
# Straggler monitor + cadence.
# ---------------------------------------------------------------------------


def test_straggler_flags_injected_delay():
    m = StragglerMonitor()
    rng = np.random.default_rng(0)
    for _ in range(50):
        assert not m.observe(0.1 + 0.002 * rng.random())
    assert m.observe(0.5)            # 5x spike -> flagged
    assert not m.persistent
    m.observe(0.5)
    m.observe(0.5)
    assert m.persistent              # 3 consecutive -> escalated


def test_straggler_stats_robust_to_outliers():
    m = StragglerMonitor()
    for _ in range(30):
        m.observe(0.1)
    m.observe(10.0)                  # outlier must not poison the baseline
    assert m.mean < 0.2


def test_cadence_shrinks_under_instability():
    mon = StragglerMonitor()
    cad = CheckpointCadence(base_interval=1000, min_interval=50)
    for _ in range(30):
        mon.observe(0.1)
    healthy = cad.interval(mon)
    mon.persistent = True
    assert cad.interval(mon) == 50 < healthy


# ---------------------------------------------------------------------------
# int8 gradient compression + error feedback.
# ---------------------------------------------------------------------------


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,))
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) / 2 + 1e-6
    assert q.dtype == jnp.int8


def test_error_feedback_unbiased_accumulation():
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (256,)) * 1e-3}
    r = ef_init(g)
    acc = jnp.zeros(256)
    n = 100
    for _ in range(n):
        qg, r = ef_compress_tree(g, r)
        acc = acc + qg["w"]
    rel = float(jnp.abs(acc - n * g["w"]).max() / jnp.abs(n * g["w"]).max())
    assert rel < 5e-3  # EF keeps the long-run average unbiased
