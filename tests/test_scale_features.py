"""Scale-oriented features added during §Perf iterations: mesh-context
activation constraints, MoE expert padding, TTM strategy crossover."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.meshctx import activation_mesh, constrain, current_mesh
from repro.core.ttm_embedding import (
    make_ttm_spec,
    ttm_embedding_apply,
    ttm_embedding_init,
    ttm_strategy_crossover,
)
from repro.models.moe import moe_apply, moe_init


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 8))
    assert current_mesh() is None
    y = constrain(x, "model", None)
    np.testing.assert_array_equal(x, y)


def test_constrain_applies_and_degrades():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with activation_mesh(mesh):
        assert current_mesh() is mesh
        x = jnp.ones((4, 8))
        # divisible dims -> constraint applied (values unchanged)
        y = constrain(x, "data", "model")
        np.testing.assert_array_equal(x, y)
        # unknown axis name and non-divisible dims degrade silently
        z = constrain(jnp.ones((3, 5)), "expert", ("data", "model"))
        assert z.shape == (3, 5)
    assert current_mesh() is None


def test_constrain_inside_jit():
    mesh = jax.make_mesh((1,), ("model",))

    def f(x):
        return constrain(x * 2, "model") + 1

    with activation_mesh(mesh):
        out = jax.jit(f)(jnp.arange(4.0))
    np.testing.assert_allclose(out, jnp.arange(4.0) * 2 + 1)


# ---------------------------------------------------------------------------
# MoE expert padding.
# ---------------------------------------------------------------------------


def test_expert_padding_shapes_and_routing():
    cfg = get_config("qwen2-moe-a2.7b").scaled_down()
    m = dataclasses.replace(cfg.moe, num_experts=6, pad_experts_to=8,
                            capacity_factor=8.0)
    cfg = dataclasses.replace(cfg, moe=m)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    assert p["up"]["w"].shape[0] == 8          # padded expert stack
    assert p["router"].shape[0] == 6           # router covers real experts
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y = moe_apply(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    # dummy experts receive zero gradient (never routed to)
    g = jax.grad(lambda pp: (moe_apply(pp, x, cfg) ** 2).sum())(p)
    dummy_grad = np.abs(np.asarray(g["up"]["w"][6:])).max()
    assert dummy_grad == 0.0


def test_expert_padding_matches_unpadded_math():
    cfg = get_config("qwen2-moe-a2.7b").scaled_down()
    m0 = dataclasses.replace(cfg.moe, num_experts=6, pad_experts_to=None,
                             capacity_factor=8.0)
    m1 = dataclasses.replace(m0, pad_experts_to=8)
    c0 = dataclasses.replace(cfg, moe=m0)
    c1 = dataclasses.replace(cfg, moe=m1)
    p0 = moe_init(jax.random.PRNGKey(0), c0)
    p1 = moe_init(jax.random.PRNGKey(0), c1)
    # copy the real experts so both models share weights
    for k in ("up", "gate", "down"):
        p1[k]["w"] = p1[k]["w"].at[:6].set(p0[k]["w"])
    p1["router"] = p0["router"]
    p1["shared"] = p0["shared"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    np.testing.assert_allclose(moe_apply(p0, x, c0), moe_apply(p1, x, c1),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# TTM strategy crossover.
# ---------------------------------------------------------------------------


def test_ttm_strategies_agree():
    emb = ttm_embedding_init(jax.random.PRNGKey(0), 1000, 256, d=3, rank=16)
    ids = jax.random.randint(jax.random.PRNGKey(1), (64,), 0, 1000)
    a = ttm_embedding_apply(emb, ids, strategy="gather")
    b = ttm_embedding_apply(emb, ids, strategy="reconstruct")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-5)


def test_ttm_crossover_scales_with_table():
    small = make_ttm_spec(1000, 256, 3, 16)
    big = make_ttm_spec(131072, 4096, 3, 64)
    assert ttm_strategy_crossover(big) > ttm_strategy_crossover(small)
    # the auto rule: decode-sized batches gather, training-sized reconstruct
    assert ttm_strategy_crossover(big) > 128          # decode stays gather
    assert ttm_strategy_crossover(big) < 256 * 4096   # train reconstructs


@pytest.mark.parametrize("n_ids", [4, 50_000])
def test_ttm_auto_strategy_is_consistent(n_ids):
    emb = ttm_embedding_init(jax.random.PRNGKey(0), 512, 64, d=2, rank=4)
    ids = jax.random.randint(jax.random.PRNGKey(1), (n_ids,), 0, 512)
    out = ttm_embedding_apply(emb, ids)  # auto
    ref = ttm_embedding_apply(emb, ids[:16], strategy="gather")
    np.testing.assert_allclose(out[:16], ref, rtol=2e-4, atol=1e-5)
