"""Model zoo: per-arch smoke (reduced configs), attention/SSM/MoE references,
prefill->decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import forward, init_cache, init_params, loss_fn, num_params
from repro.models.attention import blockwise_attention, decode_attention
from repro.models.moe import _route, moe_apply, moe_init
from repro.models.ssm import causal_conv, causal_conv_step, ssd_chunked

ARCHS = [a for a in list_archs()]


def _smoke_cfg(arch):
    cfg = get_config(arch).scaled_down()
    if cfg.tt.mode == "off":
        cfg = cfg.with_tt(mode="tt", rank=8, embed_rank=8)
    return cfg


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """One forward + one backward on CPU: output shapes + finite values.

    Every arch runs in TT mode — the paper's technique applied across the
    whole assigned zoo (DESIGN.md §Arch-applicability)."""
    cfg = _smoke_cfg(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 64
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend == "patch":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.frontend_len, cfg.d_model),
            jnp.dtype(cfg.dtype))
    logits, _ = forward(params, cfg, tokens, patches=batch.get("patches"),
                        mode="train")
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-130m",
                                  "recurrentgemma-2b", "qwen2-moe-a2.7b"])
def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill == greedy continuation of full forward."""
    cfg = _smoke_cfg(arch)
    cfg = dataclasses.replace(cfg, attn_q_chunk=32, attn_kv_chunk=32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    # Reference: full forward over S+1 tokens (teacher forcing).
    logits_pre, pcache = forward(params, cfg, toks, mode="prefill")
    nxt = jnp.argmax(logits_pre[:, -1:, : cfg.vocab_size], -1).astype(jnp.int32)
    full = jnp.concatenate([toks, nxt], axis=1)
    logits_full, _ = forward(params, cfg, full, mode="train", remat=False)

    from repro.launch.steps import prepare_decode_cache
    cache = prepare_decode_cache(cfg, pcache, S, S + 8, kv_repeat=1)
    logits_dec, _ = forward(params, cfg, nxt, cache=cache, mode="decode", pos=S)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32), rtol=2e-2, atol=2e-2)


def test_blockwise_attention_vs_naive():
    B, S, H, KV, D = 2, 128, 8, 4, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, D))

    def naive(q, k, v, causal, window):
        kr = jnp.repeat(k, H // KV, axis=2)
        vr = jnp.repeat(v, H // KV, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(D)
        idx = jnp.arange(S)
        mask = jnp.ones((S, S), bool)
        if causal:
            mask &= idx[None, :] <= idx[:, None]
        if window:
            mask &= idx[None, :] > idx[:, None] - window
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, vr)

    for causal, window, qc, kc in [(True, None, 32, 64), (True, 64, 64, 32),
                                   (False, None, 32, 32)]:
        out = blockwise_attention(q, k, v, causal=causal, window=window,
                                  q_chunk=qc, kv_chunk=kc)
        ref = naive(q, k, v, causal, window)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_decode_attention_vs_naive():
    B, H, KV, D, S = 2, 8, 8, 16, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, D))
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, D))
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, D))
    pos = 40  # only first 40 slots valid
    out = decode_attention(q, kc, vc, jnp.asarray(pos))
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kc[:, :pos]) / np.sqrt(D)
    p = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bhqk,bkhd->bqhd", p, vc[:, :pos])
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_ssd_chunked_vs_sequential():
    """Mamba-2 SSD chunked scan == naive per-step recurrence."""
    B, L, H, P, N = 2, 64, 4, 8, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (B, L, H, P)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (B, L, H)))
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)) * 0.3)
    b = jax.random.normal(jax.random.PRNGKey(3), (B, L, N)) * 0.3
    c = jax.random.normal(jax.random.PRNGKey(4), (B, L, N)) * 0.3

    y_chunk, h_last = ssd_chunked(x, dt, a, b, c, chunk=16)

    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(L):
        da = jnp.exp(dt[:, t] * a[None])                     # (B, H)
        upd = jnp.einsum("bn,bhp->bhpn", b[:, t], x[:, t] * dt[:, t, :, None])
        h = h * da[..., None, None] + upd
        ys.append(jnp.einsum("bn,bhpn->bhp", c[:, t], h))
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(h_last, h, rtol=2e-3, atol=2e-3)


def test_causal_conv_step_matches_full():
    B, L, C, W = 2, 10, 6, 4
    x = jax.random.normal(jax.random.PRNGKey(0), (B, L, C))
    k = jax.random.normal(jax.random.PRNGKey(1), (W, C))
    full = causal_conv(x, k)
    state = jnp.zeros((B, W - 1, C))
    for t in range(L):
        y, state = causal_conv_step(x[:, t], state, k)
        np.testing.assert_allclose(y, full[:, t], rtol=1e-5, atol=1e-5)


def test_moe_grouped_vs_brute_force():
    cfg = get_config("qwen2-moe-a2.7b").scaled_down()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y = moe_apply(p, x, cfg)

    from repro.models.layers import mlp_apply
    gates, idx = _route(x, p["router"], cfg.moe.top_k)
    ref = jnp.zeros_like(x)
    for bi in range(2):
        for t in range(16):
            acc = jnp.zeros((cfg.d_model,))
            for j in range(cfg.moe.top_k):
                e = int(idx[bi, t, j])
                v = x[bi, t]
                up = v @ p["up"]["w"][e].T
                g = v @ p["gate"]["w"][e].T
                acc += gates[bi, t, j] * ((jax.nn.silu(g) * up) @ p["down"]["w"][e].T)
            ref = ref.at[bi, t].set(acc)
    ref = ref + mlp_apply(p["shared"], x, cfg)
    np.testing.assert_allclose(y, ref, rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 most tokens are dropped (output ~ shared-only)."""
    cfg = get_config("qwen2-moe-a2.7b").scaled_down()
    tight = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.01))
    p = moe_init(jax.random.PRNGKey(0), tight)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
    y_tight = moe_apply(p, x, tight)
    loose = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    y_loose = moe_apply(p, x, loose)
    # dropping must change the output
    assert float(jnp.abs(y_tight - y_loose).max()) > 1e-3


def test_tt_vs_dense_param_reduction():
    """The paper's headline on an assigned arch: big parameter shrink."""
    cfg = get_config("qwen3-8b").scaled_down(d_model=512, d_ff=1024,
                                             vocab_size=4096, num_layers=2)
    dense = init_params(jax.random.PRNGKey(0), cfg)
    tt = init_params(jax.random.PRNGKey(0),
                     cfg.with_tt(mode="tt", rank=8, embed_rank=8))
    ratio = num_params(dense) / num_params(tt)
    assert ratio > 5.0, f"compression ratio only {ratio:.1f}x"
