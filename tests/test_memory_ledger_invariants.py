"""Memory-ledger invariants over EVERY shipped config.

The ledger's contract is that its per-stage kernel rows are *derived from
the kernels' own tile choosers* — the residency it reports is the residency
the launched tiles imply, with no second bookkeeping that could drift.
These tests walk every registered arch (TT-compressed, scaled to the CPU
test regime for the non-paper archs), recompute each stage's working set
straight from ``choose_tiles`` / ``bwd_stage_vmem_bytes`` /
``pu_block_shape``, and assert byte-for-byte equality with the ledger —
plus the paper's envelope checks: kernel working sets fit the 22.5 MB URAM
pool everywhere, and the paper's own ATIS models fit the full
6 MB BRAM + 22.5 MB URAM budget at every stage.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.atis_transformer import config_n
from repro.core.memory_ledger import (
    BRAM_BUDGET_BYTES,
    URAM_BUDGET_BYTES,
    _collect_modules,
    budget_report,
    training_step_ledger,
)
from repro.kernels.btt_backward import bwd_stage_vmem_bytes
from repro.kernels.btt_linear import choose_tiles
from repro.kernels.fused_update import pu_block_shape

BATCH, SEQ = 1, 32          # the paper's training regime (Sec. VI)
K = BATCH * SEQ


def _tt_config(arch):
    cfg = get_config(arch)
    if arch != "atis-transformer":
        cfg = cfg.scaled_down().with_tt(mode="tt", rank=8, embed_rank=8)
    return cfg


def _abstract_params(cfg):
    from repro.models.transformer import init_params
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def _specs(cfg):
    tts, _ = _collect_modules(_abstract_params(cfg))
    return [m.spec for m in tts]


@pytest.mark.parametrize("arch", list_archs())
def test_kernel_rows_are_chooser_derived(arch):
    """FWD and BWD kernel_vmem == the max over TT layers of the values the
    tile choosers return for this step's K — recomputed here independently
    of the ledger's own code path."""
    cfg = _tt_config(arch)
    led = training_step_ledger(cfg, "sgd", batch=BATCH, seq=SEQ)
    itemsize = jnp.dtype(cfg.dtype).itemsize
    specs = _specs(cfg)
    assert specs, f"{arch}: TT mode produced no TT layers"

    fwd_expect = max(
        choose_tiles(s.out_dim, s.mid_rank, itemsize, K=K)[4] for s in specs)
    bwd_expect = max(
        bwd_stage_vmem_bytes(s.out_dim, s.in_dim, s.mid_rank, itemsize, K=K)
        for s in specs)
    assert led["FWD"].entry("kernel_vmem").nbytes == fwd_expect
    assert led["BWD"].entry("kernel_vmem").nbytes == bwd_expect


@pytest.mark.parametrize("arch", list_archs())
def test_kernel_working_sets_fit_uram_envelope(arch):
    """Every stage's kernel-derived VMEM working set fits the paper's
    22.5 MB URAM pool — the transient on-chip residency the kernels are
    designed around (the PU row is checked against its own chooser too)."""
    cfg = _tt_config(arch)
    led = training_step_ledger(cfg, "sgd", batch=BATCH, seq=SEQ)
    for stage in ("FWD", "BWD", "PU"):
        kv = led[stage].entry("kernel_vmem").nbytes
        assert kv <= URAM_BUDGET_BYTES, (arch, stage, kv)

    params = _abstract_params(cfg)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    br, _, lanes = pu_block_shape(n)
    assert led["PU"].entry("kernel_vmem").nbytes == 2 * br * lanes * 4


def test_bwd_row_tracks_fused_bwd_flag():
    """With fused_bwd=False the op launches the operand-swap forward kernel
    instead of btt_backward_pallas; the ledger's BWD row must follow the
    flag (no drift in either direction)."""
    cfg = config_n(2)
    itemsize = jnp.dtype(cfg.dtype).itemsize
    specs = _specs(cfg)
    led_off = training_step_ledger(cfg.with_tt(fused_bwd=False), "sgd",
                                   batch=BATCH, seq=SEQ)
    expect_off = max(
        bwd_stage_vmem_bytes(s.out_dim, s.in_dim, s.mid_rank, itemsize,
                             K=K, fused=False) for s in specs)
    expect_swap = max(
        choose_tiles(s.in_dim, s.mid_rank, itemsize, K=K)[4] for s in specs)
    assert led_off["BWD"].entry("kernel_vmem").nbytes == expect_off
    assert expect_off == expect_swap  # the operand-swap launch's tiles
    led_on = training_step_ledger(cfg, "sgd", batch=BATCH, seq=SEQ)
    assert (led_on["BWD"].entry("kernel_vmem").nbytes
            != led_off["BWD"].entry("kernel_vmem").nbytes)


@pytest.mark.parametrize("fused_attn", [False, True])
@pytest.mark.parametrize("n_enc", [2, 4, 6])
def test_paper_atis_models_fit_full_envelope(n_enc, fused_attn):
    """The paper's central claim for its own models: every training stage
    of the 2/4/6-encoder ATIS transformer fits 6 MB BRAM + 22.5 MB URAM,
    with the BWD row derived from the fused backward kernel — and with the
    attention stage on either path (fused flash kernels / blockwise)."""
    cfg = config_n(n_enc).with_fused_attn(fused_attn)
    led = training_step_ledger(cfg, "sgd", batch=BATCH, seq=SEQ)
    rep = budget_report(led)
    assert rep["fits_bram"] and rep["fits_uram"] and rep["fits"]
    assert rep["bram_peak_bytes"] <= BRAM_BUDGET_BYTES
    assert rep["uram_peak_bytes"] <= URAM_BUDGET_BYTES


# ---------------------------------------------------------------------------
# Attention rows: chooser-derived, and no S×S residual under fused_attn.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", list_archs())
def test_attn_kernel_rows_are_chooser_derived(arch):
    """With fused_attn the FWD/BWD attn_kernel_vmem rows must equal the
    flash backward kernel's own tile-chooser numbers (recomputed here
    independently); without it, 0 — no Pallas launch on the blockwise
    path."""
    from repro.kernels.flash_backward import attn_stage_vmem_bytes

    cfg = _tt_config(arch)
    itemsize = jnp.dtype(cfg.dtype).itemsize

    led_on = training_step_ledger(cfg.with_fused_attn(True), "sgd",
                                  batch=BATCH, seq=SEQ)
    for stage in ("FWD", "BWD"):
        expect = attn_stage_vmem_bytes(SEQ, cfg.d_head, itemsize,
                                       stage=stage, fused=True)
        assert led_on[stage].entry("attn_kernel_vmem").nbytes == expect
        assert expect <= URAM_BUDGET_BYTES

    led_off = training_step_ledger(cfg, "sgd", batch=BATCH, seq=SEQ)
    for stage in ("FWD", "BWD"):
        assert led_off[stage].entry("attn_kernel_vmem").nbytes == 0


@pytest.mark.parametrize("n_enc", [2, 4, 6])
def test_fused_attn_reports_no_sxs_probability_residual(n_enc):
    """Acceptance: with fused_attn=True the ledger charges only (O, m, l)
    per layer — byte-for-byte the attn_residual_bytes closed form, never
    the S×S probabilities the blockwise path saves."""
    from repro.kernels.flash_backward import attn_residual_bytes

    cfg = config_n(n_enc)
    its = jnp.dtype(cfg.dtype).itemsize
    probs = cfg.num_layers * BATCH * cfg.n_heads * SEQ * SEQ * its
    oml = cfg.num_layers * attn_residual_bytes(
        BATCH, cfg.n_heads, SEQ, cfg.d_head, its, fused=True)

    led = training_step_ledger(cfg.with_fused_attn(True), "sgd",
                               batch=BATCH, seq=SEQ)
    for stage in ("FWD", "BWD"):
        got = led[stage].entry("attn_residuals").nbytes
        assert got == oml
        assert got != probs
        assert "S×S" not in led[stage].entry("attn_residuals").note \
            or "no S×S" in led[stage].entry("attn_residuals").note

    led_off = training_step_ledger(cfg, "sgd", batch=BATCH, seq=SEQ)
    assert led_off["FWD"].entry("attn_residuals").nbytes == probs


# ---------------------------------------------------------------------------
# FFN rows: chooser-derived, residual shrink, gated on the dispatch predicate.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_enc", [2, 4, 6])
def test_ffn_kernel_rows_are_chooser_derived(n_enc):
    """With fused_ffn the FWD/BWD ffn_kernel_vmem rows must equal the
    megakernel's own tile-chooser numbers (recomputed here independently
    of the ledger); without it, 0 — no megakernel launch on the two-call
    path."""
    from repro.core.memory_ledger import _collect_ffn_blocks, _ffn_block_dims
    from repro.kernels.btt_ffn import ffn_stage_vmem_bytes

    cfg = config_n(n_enc).with_tt(flow="kernel")
    itemsize = jnp.dtype(cfg.dtype).itemsize
    params = _abstract_params(cfg)
    dims = [d for d in (_ffn_block_dims(b)
                        for b in _collect_ffn_blocks(params))
            if d is not None]
    assert dims

    led_on = training_step_ledger(cfg.with_fused_ffn(True), "sgd",
                                  batch=BATCH, seq=SEQ)
    for stage in ("FWD", "BWD"):
        expect = max(ffn_stage_vmem_bytes(M, N, F, R1, R2, Rg, itemsize,
                                          K=K, stage=stage)
                     for M, N, F, R1, R2, Rg, _, _ in dims)
        assert led_on[stage].entry("ffn_kernel_vmem").nbytes == expect
        assert expect <= URAM_BUDGET_BYTES

    led_off = training_step_ledger(cfg, "sgd", batch=BATCH, seq=SEQ)
    for stage in ("FWD", "BWD"):
        assert led_off[stage].entry("ffn_kernel_vmem").nbytes == 0


@pytest.mark.parametrize("n_enc", [2, 4, 6])
def test_fused_ffn_residuals_shrink_to_layer_input(n_enc):
    """Acceptance: with fused_ffn the ledger drops exactly the FFN hidden
    state — the down projection's (K, d_ff) saved input leaves the
    residuals row and the activation pre-images (ffn_hidden) go to zero,
    so FFN residuals are O(K*d_model), not O(K*d_ff)."""
    cfg = config_n(n_enc).with_tt(flow="kernel")
    its = jnp.dtype(cfg.dtype).itemsize
    led_off = training_step_ledger(cfg, "sgd", batch=BATCH, seq=SEQ)
    led_on = training_step_ledger(cfg.with_fused_ffn(True), "sgd",
                                  batch=BATCH, seq=SEQ)
    hidden = cfg.num_layers * K * cfg.d_ff * its  # one (K, d_ff) per block
    for stage in ("FWD", "BWD"):
        drop = (led_off[stage].entry("residuals").nbytes
                - led_on[stage].entry("residuals").nbytes)
        assert drop == hidden
        # ungated GELU FFN: one pre-activation per block on the two-call
        # path, none with the megakernel.
        assert led_off[stage].entry("ffn_hidden").nbytes == hidden
        assert led_on[stage].entry("ffn_hidden").nbytes == 0


@pytest.mark.parametrize("n_enc", [2, 4, 6])
def test_paper_atis_models_fit_envelope_with_fused_ffn(n_enc):
    """The paper's envelope claim survives the megakernel: every stage of
    the ATIS models still fits 6 MB BRAM + 22.5 MB URAM with fused_ffn on
    (alone and together with fused_attn)."""
    base = config_n(n_enc).with_tt(flow="kernel")
    for cfg in (base.with_fused_ffn(True),
                base.with_fused_ffn(True).with_fused_attn(True)):
        led = training_step_ledger(cfg, "sgd", batch=BATCH, seq=SEQ)
        rep = budget_report(led)
        assert rep["fits_bram"] and rep["fits_uram"] and rep["fits"]


def test_ffn_rows_gate_on_vmem_fits_predicate():
    """A config whose FFN busts the megakernel budget must ledger exactly
    like fused_ffn=False even when the flag is on — the SAME predicate the
    op dispatches on (no drift between ledger and dispatch)."""
    from repro.core.memory_ledger import _collect_ffn_blocks, _ffn_block_dims
    from repro.kernels.btt_ffn import ffn_vmem_fits

    cfg = (get_config("qwen3-8b")
           .with_tt(mode="tt", rank=64, embed_rank=64,
                    flow="kernel"))  # full-size d_ff
    itemsize = jnp.dtype(cfg.dtype).itemsize
    params = _abstract_params(cfg)
    dims = [d for d in (_ffn_block_dims(b)
                        for b in _collect_ffn_blocks(params))
            if d is not None]
    assert dims
    assert all(not ffn_vmem_fits(M, N, F, R1, R2, Rg, itemsize, K=K)
               for M, N, F, R1, R2, Rg, _, _ in dims)
    led_on = training_step_ledger(cfg.with_fused_ffn(True), "sgd",
                                  batch=BATCH, seq=SEQ)
    led_off = training_step_ledger(cfg, "sgd", batch=BATCH, seq=SEQ)
    for stage in ("FWD", "BWD"):
        for row in ("residuals", "ffn_hidden", "ffn_kernel_vmem"):
            assert (led_on[stage].entry(row).nbytes
                    == led_off[stage].entry(row).nbytes)
        assert led_on[stage].entry("ffn_kernel_vmem").nbytes == 0


def test_ffn_rows_require_kernel_flow():
    """fused_ffn refines the kernel flow only (like tt.fused_bwd): on a
    pure-JAX flow the model never dispatches the megakernel, and the
    ledger must agree — no ffn_kernel_vmem, no residual shrink."""
    cfg = config_n(2).with_fused_ffn(True)  # default flow: btt_fused
    assert cfg.tt.flow != "kernel"
    led = training_step_ledger(cfg, "sgd", batch=BATCH, seq=SEQ)
    led_ref = training_step_ledger(config_n(2), "sgd", batch=BATCH, seq=SEQ)
    for stage in ("FWD", "BWD"):
        assert led[stage].entry("ffn_kernel_vmem").nbytes == 0
        assert (led[stage].entry("residuals").nbytes
                == led_ref[stage].entry("residuals").nbytes)
        assert (led[stage].entry("ffn_hidden").nbytes
                == led_ref[stage].entry("ffn_hidden").nbytes)


def test_ffn_rows_use_moe_expert_dispatch_k():
    """MoE expert blocks dispatch the megakernel per expert on the
    capacity-dispatched (G*cap) rows, not on batch*seq — the ledger's
    ffn_kernel_vmem rows must be the chooser's numbers at THAT K."""
    import math

    from repro.core.memory_ledger import _collect_ffn_blocks, _ffn_block_dims
    from repro.kernels.btt_ffn import ffn_stage_vmem_bytes

    cfg = (get_config("qwen2-moe-a2.7b").scaled_down()
           .with_tt(mode="tt", rank=8, embed_rank=8, flow="kernel")
           .with_fused_ffn(True))
    itemsize = jnp.dtype(cfg.dtype).itemsize
    m = cfg.moe
    cap = int(math.ceil(SEQ * m.top_k / m.num_experts * m.capacity_factor))
    params = _abstract_params(cfg)
    led = training_step_ledger(cfg, "sgd", batch=BATCH, seq=SEQ)
    for stage in ("FWD", "BWD"):
        expect = 0
        for blk in _collect_ffn_blocks(params):
            dims = _ffn_block_dims(blk)
            if dims is None:
                continue
            M_, N_, F_, R1, R2, Rg, _, _ = dims
            k_blk = BATCH * cap if "router" in blk else K
            expect = max(expect, ffn_stage_vmem_bytes(
                M_, N_, F_, R1, R2, Rg, itemsize, K=k_blk, stage=stage))
        assert led[stage].entry("ffn_kernel_vmem").nbytes == expect


# ---------------------------------------------------------------------------
# Sketched-AdamW PU rows: kernel-helper-derived, envelope, moment shrink.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_enc", [2, 4, 6])
def test_sketched_pu_rows_are_kernel_helper_derived(n_enc):
    """With sketched AdamW, the PU rows must equal the sketched kernel's
    OWN size helpers — moments == sketch_state_bytes at the state's actual
    (depth, width), kernel_vmem == sketch_pu_vmem_bytes — recomputed here
    independently of the ledger."""
    from repro.kernels.fused_update import (
        SKETCH_DEPTH_DEFAULT,
        default_sketch_width,
        sketch_pu_vmem_bytes,
        sketch_state_bytes,
    )

    cfg = config_n(n_enc)
    its = jnp.dtype(cfg.dtype).itemsize
    params = _abstract_params(cfg)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    depth = SKETCH_DEPTH_DEFAULT
    width = default_sketch_width(n, depth)

    led = training_step_ledger(cfg, "adamw", batch=BATCH, seq=SEQ,
                               sketched=True)
    pu = led["PU"]
    assert pu.entry("moments").nbytes == sketch_state_bytes(depth, width)
    assert pu.entry("kernel_vmem").nbytes == sketch_pu_vmem_bytes(
        n, width, depth, itemsize=its)
    assert "sketch" in pu.entry("moments").note


@pytest.mark.parametrize("n_enc", [2, 4, 6])
def test_sketched_pu_moments_at_least_4x_smaller(n_enc):
    """Acceptance: on every shipped ATIS config, the sketched PU moment
    row is >= 4x smaller than dense AdamW's moment footprint, and the
    full step stays inside the 6 + 22.5 MB envelope with strictly smaller
    persistent (bram) PU residency."""
    cfg = config_n(n_enc)
    led_d = training_step_ledger(cfg, "adamw", batch=BATCH, seq=SEQ)
    led_s = training_step_ledger(cfg, "adamw", batch=BATCH, seq=SEQ,
                                 sketched=True)
    dense = led_d["PU"].entry("moments").nbytes
    sketch = led_s["PU"].entry("moments").nbytes
    assert sketch * 4 <= dense, (n_enc, dense, sketch)
    assert (led_s["PU"].pool_bytes("bram")
            < led_d["PU"].pool_bytes("bram"))
    rep = budget_report(led_s)
    assert rep["fits_bram"] and rep["fits_uram"] and rep["fits"]


def test_sketched_ledger_follows_fallback_predicate():
    """When sketch_pu_fits rejects the requested sketch (absurd width),
    eval_shape-init falls back to dense state and the ledger must charge
    EXACTLY like sketched=False — the ledger and the op share the decision
    by construction."""
    cfg = config_n(2)
    led_fb = training_step_ledger(cfg, "adamw", batch=BATCH, seq=SEQ,
                                  sketched=True, sketch_width=2 ** 22)
    led_d = training_step_ledger(cfg, "adamw", batch=BATCH, seq=SEQ)
    for row in ("moments", "kernel_vmem", "grads", "params"):
        assert (led_fb["PU"].entry(row).nbytes
                == led_d["PU"].entry(row).nbytes)
    assert "sketch" not in led_fb["PU"].entry("moments").note


def test_sketched_state_matches_optimizer_init():
    """The ledger's moment bytes equal the bytes of the REAL optimizer
    state the training step would carry (minus the step scalar) — the
    eval_shape contract, now including sketch buffers."""
    from repro.optim import adamw

    cfg = config_n(2)
    params = _abstract_params(cfg)
    opt = adamw(1e-3, sketched=True)
    state = jax.eval_shape(opt.init, params)
    assert "vs" in state
    state_bytes = sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
                      for x in jax.tree.leaves(state)) - 4
    led = training_step_ledger(cfg, "adamw", batch=BATCH, seq=SEQ,
                               sketched=True)
    assert led["PU"].entry("moments").nbytes == state_bytes


# ---------------------------------------------------------------------------
# DECODE stage (serving): paged-KV ledger.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_enc", [2, 4, 6])
def test_decode_ledger_fits_envelope(n_enc):
    """Acceptance: every shipped ATIS config serves inside the 6 MB BRAM +
    22.5 MB URAM envelope at the paper-scale serving point (4 slots,
    64-token contexts, 32-row pages) — the row bench_decode gates on."""
    from repro.core.memory_ledger import decode_ledger_rows

    cfg = config_n(n_enc).with_tt(flow="kernel")
    rows = dict((n, v) for n, v, _ in decode_ledger_rows(
        cfg, "x", batch=4, max_len=64, page_size=32, fused=True))
    assert rows["x/fits"] == 1.0
    assert rows["x/DECODE_mb"] > 0


def test_decode_kv_row_matches_engine_allocator():
    """The kv_pages row is sized by the SAME layout the engine allocates:
    sum over window groups of kv_pool_bytes at max_pages_per_request —
    checked on a hybrid (global + attn_local) config where the two groups
    genuinely differ."""
    import dataclasses

    from repro.core.memory_ledger import decode_step_ledger
    from repro.runtime.decode_engine import _layout
    from repro.runtime.kv_cache import kv_pool_bytes, max_pages_per_request

    cfg = get_config("llama3-8b").scaled_down()
    cfg = dataclasses.replace(cfg, hybrid_pattern=("attn", "attn_local"),
                              window=8)
    B, max_len, page = 3, 48, 4
    led = decode_step_ledger(cfg, batch=B, max_len=max_len, page_size=page)
    n_cycles, _, _, n_pat, n_tail, windows = _layout(cfg)
    assert set(windows.values()) == {None, 8}
    expect = 0
    it = jnp.dtype(cfg.dtype).itemsize
    for gid, window in windows.items():
        n_layers = n_cycles * n_pat.get(gid, 0) + n_tail.get(gid, 0)
        np_max = max_pages_per_request(max_len, page, window)
        expect += kv_pool_bytes(n_layers, 1 + B * np_max, cfg.n_kv_heads,
                                page, cfg.d_head, it)
    assert led.entry("kv_pages").nbytes == expect
    # the windowed group's table is narrower than the global one
    assert (max_pages_per_request(max_len, page, 8)
            < max_pages_per_request(max_len, page, None))


def test_decode_kernel_rows_are_chooser_derived():
    """DECODE kernel-VMEM rows come from the same sizing helpers the ops
    dispatch gates on, and stay inside the URAM envelope."""
    from repro.core.memory_ledger import decode_step_ledger
    from repro.kernels.flash_decode import decode_attn_stage_vmem_bytes

    cfg = config_n(2).with_tt(flow="kernel")
    page = 32
    led = decode_step_ledger(cfg, batch=4, max_len=64, page_size=page)
    it = jnp.dtype(cfg.dtype).itemsize
    G = cfg.n_heads // cfg.n_kv_heads
    assert led.entry("attn_kernel_vmem").nbytes == \
        decode_attn_stage_vmem_bytes(G, cfg.d_head, page, it, fused=True)
    for row in ("attn_kernel_vmem", "kernel_vmem", "ffn_kernel_vmem"):
        assert led.entry(row).nbytes <= URAM_BUDGET_BYTES
    # without the megakernel the hidden column rides URAM...
    assert led.entry("ffn_kernel_vmem").nbytes == 0
    assert led.entry("ffn_hidden").nbytes > 0
    # ...with it, the hidden state is VMEM-resident and the row flips
    led_f = decode_step_ledger(cfg.with_fused_ffn(), batch=4, max_len=64,
                               page_size=page)
    assert led_f.entry("ffn_kernel_vmem").nbytes > 0
    assert led_f.entry("ffn_hidden").nbytes == 0


def test_decode_ledger_rejects_non_attention_families():
    from repro.core.memory_ledger import decode_step_ledger

    cfg = get_config("mamba2-130m").scaled_down()
    with pytest.raises(ValueError):
        decode_step_ledger(cfg)
