"""Flash-attention Pallas kernel vs the naive softmax oracle (interpret)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas


def naive(q, k, v, causal, window, group):
    BH, S, D = q.shape
    kr = jnp.repeat(k, group, axis=0)
    vr = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / math.sqrt(D)
    idx = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= idx[None, :] <= idx[:, None]
    if window is not None:
        mask &= idx[None, :] > idx[:, None] - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, vr.astype(jnp.float32)).astype(q.dtype)


CASES = [
    # (BH_kv, group, S, D, causal, window, tq, tk)
    (2, 1, 256, 64, True, None, 128, 128),
    (2, 4, 256, 64, True, None, 128, 128),      # GQA
    (1, 2, 300, 80, True, None, 128, 128),      # ragged S and D
    (2, 1, 256, 64, False, None, 128, 128),     # encoder (non-causal)
    (2, 2, 512, 64, True, 128, 128, 128),       # sliding window
    (1, 1, 256, 128, True, None, 256, 128),     # asymmetric tiles
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_naive(case, dtype):
    bh_kv, group, S, D, causal, window, tq, tk = case
    kq, kk, kv_ = jax.random.split(jax.random.PRNGKey(sum(case[:4])), 3)
    q = jax.random.normal(kq, (bh_kv * group, S, D), dtype)
    k = jax.random.normal(kk, (bh_kv, S, D), dtype)
    v = jax.random.normal(kv_, (bh_kv, S, D), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 group=group, tq=tq, tk=tk, interpret=True)
    ref = naive(q, k, v, causal, window, group)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_blockwise_model_layer():
    """Cross-check against the model's blockwise attention (B,S,H,D layout)."""
    from repro.models.attention import blockwise_attention
    B, S, H, KV, D = 2, 256, 8, 4, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, D))
    ref = blockwise_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, D)
    out = flash_attention_pallas(qf, kf, vf, causal=True, group=H // KV,
                                 tq=128, tk=128, interpret=True)
    out = out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)
