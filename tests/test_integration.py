"""End-to-end integration: training driver (+ checkpoint resume), serving
driver, a real (subprocess) dry-run cell, and the int8 ring all-reduce on a
multi-device mesh.  Subprocesses are used wherever a different device count
is required — jax locks the platform device count at first use."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ENV = {**os.environ, "PYTHONPATH": SRC}


def test_train_driver_loss_decreases_and_resumes(tmp_path):
    from repro.launch.train import main
    ckpt = str(tmp_path / "ckpt")
    args = ["--arch", "qwen3-8b", "--tt", "--scale-down", "--steps", "16",
            "--batch", "4", "--seq", "64", "--lr", "1e-2",
            "--ckpt-dir", ckpt, "--ckpt-every", "8", "--log-every", "8"]
    out1 = main(args)
    assert out1["final_loss"] < out1["first_loss"]
    # resume: latest checkpoint is step 16 -> no steps left; extend to 24
    out2 = main(args[:5] + ["24"] + args[6:])
    assert out2["final_loss"] is not None
    from repro.checkpoint import latest_step
    assert latest_step(ckpt) == 24


def test_serve_driver_generates(tmp_path):
    from repro.launch.serve import main
    out = main(["--arch", "recurrentgemma-2b", "--scale-down", "--batch", "2",
                "--prompt-len", "32", "--gen", "8"])
    assert out["tokens"].shape == (2, 8)
    assert np.isfinite(out["tokens"]).all()


def test_serve_driver_attention_arch():
    from repro.launch.serve import main
    out = main(["--arch", "musicgen-medium", "--scale-down", "--tt",
                "--batch", "2", "--prompt-len", "16", "--gen", "4"])
    assert out["tokens"].shape == (2, 4)


@pytest.mark.parametrize("cell", [("mamba2-130m", "long_500k"),
                                  ("recurrentgemma-2b", "decode_32k")])
def test_dryrun_cell_subprocess(cell, tmp_path):
    """One real production-mesh (256-device) dry-run cell, end to end."""
    arch, shape = cell
    out_dir = str(tmp_path / "dryrun")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--out", out_dir],
        env=ENV, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    files = os.listdir(out_dir)
    assert len(files) == 1
    rec = json.load(open(os.path.join(out_dir, files[0])))
    assert rec["status"] == "ok"
    assert rec["devices"] == 256
    assert rec["cost_analysis"]["flops"] > 0
    assert rec["memory_analysis"]["temp_size_in_bytes"] > 0


def test_compressed_allreduce_subprocess():
    """int8 ring all-reduce == pmean within quantization error (8 devices)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.runtime import compressed_allreduce_mean
mesh = jax.make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
f = shard_map(lambda v: compressed_allreduce_mean(v, "data"), mesh=mesh,
              in_specs=P("data", None), out_specs=P("data", None),
              check_vma=False)
y = f(x)
ref = jnp.broadcast_to(x.mean(0, keepdims=True), x.shape)
rel = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
assert rel < 0.02, rel
print("OK", rel)
"""
    r = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_elastic_reshard_subprocess():
    """Checkpoint on mesh A (2x4), restore+reshard on mesh B (4x2)."""
    code = """
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import init_params
from repro.checkpoint import save, restore
from repro.runtime import param_specs, named_sharding_tree
from repro.runtime.elastic import replan_for_mesh

cfg = get_config("qwen3-8b").scaled_down(d_model=256, d_ff=512, vocab_size=1024)
mesh_a = jax.make_mesh((2, 4), ("data", "model"))
params = init_params(jax.random.PRNGKey(0), cfg)
specs_a = param_specs(cfg, params, mesh_a)
params_a = jax.tree.map(jax.device_put, params, named_sharding_tree(mesh_a, specs_a))

with tempfile.TemporaryDirectory() as d:
    save(d, 3, params_a)
    tmpl = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    host, step = restore(d, tmpl)

mesh_b = jax.make_mesh((4, 2), ("data", "model"))
params_b, _ = replan_for_mesh(cfg, host, None, mesh_b)
for a, b in zip(jax.tree.leaves(params_a), jax.tree.leaves(params_b)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("OK elastic", step)
"""
    r = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK elastic" in r.stdout


def test_microbatch_accumulation_parity():
    """make_train_step(microbatches=4) on one batch == microbatches=1:
    same loss/grad-norm metrics and the same updated parameters (the
    accumulation scan averages per-microbatch grads; with a uniform mask
    the full-batch gradient is the same average, up to f32 reordering)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.data import lm_batch
    from repro.launch.steps import make_train_step
    from repro.models.transformer import init_params
    from repro.optim import sgd

    cfg = (get_config("qwen3-8b").scaled_down()
           .with_tt(mode="tt", rank=8, embed_rank=8))
    opt = sgd(1e-2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    batch = {k: jnp.asarray(v)
             for k, v in lm_batch(0, 0, 8, 64, cfg.vocab_size).items()}

    step1 = jax.jit(make_train_step(cfg, opt, microbatches=1))
    step4 = jax.jit(make_train_step(cfg, opt, microbatches=4))
    p1, s1, m1 = step1(params, state, batch)
    p4, s4, m4 = step4(params, state, batch)

    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-6)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m4["grad_norm"]), rtol=1e-4)
    assert int(s1["step"]) == int(s4["step"]) == 1
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


def _teacher_forced_engine_check(cfg, *, prompt_len=6, gen=5, page_size=4,
                                 rtol=5e-4, atol=5e-5):
    """Prefill->decode through the paged engine, teacher-forced with the
    ground-truth next tokens, must reproduce the full-sequence training
    forward's logits position by position (tolerance: different tile
    accumulation orders; argmax exact)."""
    import jax
    import jax.numpy as jnp
    from repro.models import init_params
    from repro.models.transformer import forward
    from repro.runtime import PagedDecodeEngine

    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    tokens = rng.randint(1, cfg.vocab_size,
                         size=(1, prompt_len + gen)).astype(np.int32)
    full_logits, _ = forward(params, cfg, jnp.asarray(tokens),
                             mode="train", remat=False)
    ref = np.asarray(full_logits[0], np.float32)

    eng = PagedDecodeEngine(cfg, params, page_size=page_size,
                            max_concurrency=2,
                            max_len=prompt_len + gen + 1,
                            fused_decode=False)
    slot = 1    # off-zero slot: layout must not assume slot 0
    got = [np.asarray(eng.prefill(slot, tokens[0, :prompt_len]))]
    toks = np.zeros((2,), np.int32)
    poss = np.zeros((2,), np.int32)
    for t in range(gen):
        toks[slot] = tokens[0, prompt_len + t]
        poss[slot] = prompt_len + t
        logits = eng.decode_step(toks, poss)
        got.append(np.asarray(logits[slot], np.float32))
    for i, g in enumerate(got):
        pos = prompt_len - 1 + i
        np.testing.assert_allclose(g, ref[pos], rtol=rtol, atol=atol)
        assert (int(g[: cfg.vocab_size].argmax())
                == int(ref[pos, : cfg.vocab_size].argmax())), pos
    eng.release(slot)


@pytest.mark.parametrize("family", ["global", "local", "tt-kernel"])
def test_engine_teacher_forced_matches_training_forward(family):
    """Paged decode engine == training forward, per KV-cache family:
    global GQA attention, windowed attn_local (ring eviction), and the
    TT kernel-flow projection path."""
    import dataclasses
    from repro.configs import get_config

    cfg = get_config("llama3-8b").scaled_down()
    cfg = dataclasses.replace(cfg, dtype="float32")
    if family == "local":
        cfg = dataclasses.replace(cfg,
                                  hybrid_pattern=("attn", "attn_local"),
                                  window=6)
    elif family == "tt-kernel":
        cfg = cfg.with_tt(mode="tt", rank=8, embed_rank=8, flow="kernel")
    _teacher_forced_engine_check(cfg)


def test_serve_driver_paged_continuous_batching():
    """Paged serve path end to end on CPU: oversubscribed queue (3
    requests, 2 slots) drains with every request finished."""
    from repro.launch.serve import main
    out = main(["--arch", "llama3-8b", "--scale-down", "--tt",
                "--kernel-flow", "--batch", "3", "--prompt-len", "12",
                "--gen", "4", "--max-concurrency", "2",
                "--page-size", "4"])
    assert out["mode"] == "paged"
    assert out["tokens"].shape == (3, 4)
    assert np.isfinite(out["tokens"]).all()
    assert out["report"]["finished"] == 3
    assert out["report"]["evicted"] == 0


def test_atis_task_learns():
    """Short tensor-compressed ATIS run: joint loss drops substantially."""
    import jax
    import jax.numpy as jnp
    from repro.configs.atis_transformer import config_n
    from repro.data import AtisGrammar, atis_batch
    from repro.models import init_params
    from repro.models.classifier import atis_heads_init, atis_loss
    from repro.optim import sgd

    cfg = config_n(2).scaled_down(d_model=128, n_heads=4, d_ff=128,
                                  vocab_size=1000, num_layers=2)
    g = AtisGrammar(seed=1)
    params = {"backbone": init_params(jax.random.PRNGKey(0), cfg),
              "heads": atis_heads_init(jax.random.PRNGKey(1), cfg, 26, 120)}
    opt = sgd(0.05)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: atis_loss(p, cfg, batch))(params)
        params, state = opt.update(grads, params, state, state["step"])
        return params, state, loss

    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v)
                 for k, v in atis_batch(g, "train", i, 32).items()}
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert losses[-1] < 0.7 * losses[0], (losses[0], losses[-1])
