"""flow="kernel" — end-to-end model forward/backward through the Pallas
BTT kernel (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import tt_linear_apply, tt_linear_init
from repro.models import init_params, loss_fn


def test_kernel_flow_matches_btt_fused():
    p = tt_linear_init(jax.random.PRNGKey(0), 256, 192, d=2, rank=8)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 192))
    y_ref = tt_linear_apply(p, x, flow="btt_fused")
    y_k = tt_linear_apply(p, x, flow="kernel")
    np.testing.assert_allclose(y_k, y_ref, rtol=1e-4, atol=1e-5)


def test_kernel_flow_full_model_train_step():
    cfg = (get_config("qwen3-8b").scaled_down()
           .with_tt(mode="tt", rank=8, embed_rank=8, flow="kernel"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, {"tokens": toks, "labels": toks},
                          remat=False))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0

    # parity with the pure-JAX fused flow
    cfg2 = cfg.with_tt(flow="btt_fused")
    loss2 = loss_fn(params, cfg2, {"tokens": toks, "labels": toks},
                    remat=False)
    np.testing.assert_allclose(float(loss), float(loss2), rtol=1e-4)
