"""Analytic cost models (paper Sec. IV Eqs. 18-21, Sec. V-C Eqs. 22-25).

Three-way validation: closed forms == first-principles step calculator, and
the paper's printed example ratios come out exactly (Fig. 6: 22.51x /
22.67x vs dense MM; 1.49x / 2.31x vs right-to-left TT).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import TTSpec, btt_contraction_cost, dense_matmul_cost, rl_contraction_cost
from repro.core.cost_model import (
    bram_blocks,
    bram_efficiency,
    mem_btt,
    mem_tt_rl,
    mul_btt,
    mul_tt_rl,
    mul_dense,
    tpu_packing_efficiency,
    tpu_tile_padded_bytes,
)

# The paper's running example (Sec. IV-B "Example"): d_hid 768, d=3,
# n = (12, 8, 8), m = (8, 8, 12), rank 12, seq len 32 (batch 1 -> K=32).
# clamp_ranks=False: the paper's Eqs. (18)-(21) use UNIFORM interior ranks.
PAPER = TTSpec(out_factors=(8, 8, 12), in_factors=(12, 8, 8), rank=12,
               clamp_ranks=False)
K_PAPER = 32


def test_closed_forms_match_step_calculator_paper_example():
    assert mul_tt_rl(PAPER, K_PAPER) == rl_contraction_cost(PAPER, K_PAPER).muls
    assert mul_btt(PAPER, K_PAPER) == btt_contraction_cost(PAPER, K_PAPER).muls
    assert mem_btt(PAPER, K_PAPER) == (
        btt_contraction_cost(PAPER, K_PAPER).total_intermediate)
    assert mem_tt_rl(PAPER, K_PAPER) == (
        rl_contraction_cost(PAPER, K_PAPER).total_intermediate)


@given(st.integers(1, 4).flatmap(lambda d: st.tuples(
    st.lists(st.integers(2, 12), min_size=d, max_size=d),
    st.lists(st.integers(2, 12), min_size=d, max_size=d),
    st.integers(1, 16), st.integers(1, 128))))
@settings(max_examples=60, deadline=None)
def test_closed_forms_match_step_calculator_property(args):
    mf, nf, rank, K = args
    # The paper's closed forms assume uniform interior ranks.
    spec = TTSpec(out_factors=tuple(mf), in_factors=tuple(nf), rank=rank,
                  clamp_ranks=False)
    assert mul_tt_rl(spec, K) == rl_contraction_cost(spec, K).muls
    assert mul_btt(spec, K) == btt_contraction_cost(spec, K).muls
    assert mem_btt(spec, K) == btt_contraction_cost(spec, K).total_intermediate
    assert mem_tt_rl(spec, K) == rl_contraction_cost(spec, K).total_intermediate


def test_paper_fig6_ratios():
    """Fig. 6 claims: BTT is 22.51x compute / 22.67x memory better than MM,
    and 1.49x / 2.31x better than right-to-left TT.

    Our exact transcription of Eqs. (18)-(21) yields 22.76x (uniform ranks)
    or 22.93x (clamped) for MM/BTT compute — within 2% of the printed 22.51x
    but not equal: the paper's example arithmetic is not exactly recoverable
    from its own closed forms (EXPERIMENTS.md §Cost-model).  We therefore
    assert the claims at reproducible precision: the MM ratio to 2%, and the
    RL ratios as strict lower bounds (our transcription shows BTT is at
    least as favorable as the paper claims in memory)."""
    dense_mul = mul_dense(768, 768, K_PAPER)
    r_comp_mm = dense_mul / mul_btt(PAPER, K_PAPER)
    r_comp_rl = mul_tt_rl(PAPER, K_PAPER) / mul_btt(PAPER, K_PAPER)
    r_mem_rl = mem_tt_rl(PAPER, K_PAPER) / mem_btt(PAPER, K_PAPER)
    assert r_comp_mm == pytest.approx(22.51, rel=0.02)
    assert r_comp_rl > 1.3          # paper: 1.49x — BTT strictly cheaper
    assert r_mem_rl > 2.3           # paper: 2.31x — at least the claim
    # MM memory ratio (weights + intermediates): paper claims 22.67x.
    tt_params = sum(r1 * n * r2 for (r1, n, r2) in
                    ((PAPER.ranks[i], ((8, 8, 12, 12, 8, 8))[i],
                      PAPER.ranks[i + 1]) for i in range(6)))
    r_mem_mm = (768 * 768 + K_PAPER * 768) / (tt_params + mem_btt(PAPER, K_PAPER))
    assert r_mem_mm == pytest.approx(22.67, rel=0.05)


def test_btt_always_cheaper_when_k_large():
    """Paper claim: BTT wins whenever m_i, n_i < K."""
    for K in (64, 256, 4096):
        assert mul_btt(PAPER, K) < mul_tt_rl(PAPER, K)
        assert mem_btt(PAPER, K) < mem_tt_rl(PAPER, K)


def test_btt_k_scaling_is_rank_linear():
    """BTT's K-dependent term is K*r*(M+N) — doubling K adds exactly that."""
    d1 = mul_btt(PAPER, 64) - mul_btt(PAPER, 32)
    assert d1 == 32 * PAPER.mid_rank * (PAPER.out_dim + PAPER.in_dim)


# ---------------------------------------------------------------------------
# BRAM model (Eqs. 22-25) + grouping.
# ---------------------------------------------------------------------------


def test_bram_grouping_improves_efficiency():
    """Paper Fig. 12: grouping K=(d-1)L cores lifts utilization 3.9-8.4x."""
    # ATIS 6-ENC: L=6 encoders x 6 linear layers, d=3 -> many (12, 8/12, 12)
    # cores; depth per core ~ n*r = 96..144, r = 12.
    n_cores, depth, r = 6 * 6 * 6, 8 * 12, 12
    base = bram_efficiency(n_cores, depth, r, strategy="reshape", group=1)
    grouped = bram_efficiency(n_cores, depth, r, strategy="reshape",
                              group=(3 - 1) * 6)
    gain = grouped / base
    assert gain > 3.0, f"grouping gain {gain:.2f}"
    assert grouped <= 1.0 + 1e-9


def test_bram_partition_vs_reshape():
    """Array reshaping needs <= blocks than partitioning (paper Sec. V-C)."""
    for r in (4, 12, 30, 48):
        nr = bram_blocks(10, 96, r, strategy="reshape")
        npart = bram_blocks(10, 96, r, strategy="partition")
        assert nr <= npart


def test_bram_blocks_monotone_in_group():
    for g in (1, 2, 6, 12):
        blocks = bram_blocks(36, 96, 12, strategy="reshape", group=g)
        assert blocks >= bram_blocks(36, 96, 12, strategy="reshape", group=12)


@given(r=st.integers(1, 64), depth=st.integers(8, 4096),
       n=st.integers(1, 64), group=st.integers(1, 16))
@settings(max_examples=40, deadline=None)
def test_bram_efficiency_bounded(r, depth, n, group):
    eta = bram_efficiency(n, depth, r, group=group)
    assert 0.0 < eta <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# TPU tile-padding analogue of the BRAM waste.
# ---------------------------------------------------------------------------


def test_tpu_tile_padding():
    assert tpu_tile_padded_bytes((12,), 4) == 8 * 128 * 4       # 1-D promotes
    assert tpu_tile_padded_bytes((12, 8, 12), 4) == 12 * 8 * 128 * 4
    assert tpu_tile_padded_bytes((256, 256), 4) == 256 * 256 * 4  # aligned


def test_tpu_packing_beats_individual_cores():
    """Stacking L layers of tiny TT cores into one buffer per core index
    recovers most tile-padding waste — the paper's grouping, TPU edition."""
    core_shapes = [(1, 12, 12), (12, 8, 12), (12, 8, 12), (12, 8, 12),
                   (12, 8, 12), (12, 12, 1)]
    eta_ind, eta_packed = tpu_packing_efficiency(core_shapes, n_layers=24)
    assert eta_packed > eta_ind
    assert eta_packed > 0.5
    assert eta_ind < 0.15  # individual tiny cores waste >85% of their tiles
