"""Quantized-at-rest storage tier (core.quant + the precision-threaded
kernel stack) — oracle harness.

Layers of ground truth:

1. ``quantize``/``dequantize`` round-trip: per-tensor max-abs RTN must land
   within half a quantization step of the input, per element.
2. Stochastic rounding: UNBIASED (the mean over many steps of the rounded
   value converges to the input — the property that keeps the parameter
   update from drifting) and DETERMINISTIC in ``(element, step, block)``
   (the property that makes checkpoint resume replay bit-identical
   updates).  Deterministic fixed-seed versions always run; hypothesis
   sweeps ride along where it is installed.
3. The quant kernel path vs a straight-through-estimator (STE) oracle:
   ``btt_linear_op(precision=...)`` must match, in value AND gradient, the
   pure-JAX composition through explicitly dequantized operands (the STE
   identity ``a + stop_grad(deq(quant(a)) - a)``).
4. The quantized-master fused update vs the dense f32 AdamW oracle: one
   step lands within the storage grid's resolution of the f32 result, and
   two runs from the same state are bit-identical.
5. The memory ledger: every at-rest row at int8 is <= 0.5x its f32 bytes
   (the PR's acceptance floor).
6. ATIS convergence smoke: the int8 config's final loss stays within 5%
   relative of the f32 run on the same seed/steps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs.atis_transformer import config_n
from repro.core import quant
from repro.core.memory_ledger import training_step_ledger
from repro.core.tt import tt_half_factors, tt_init
from repro.core.tt_linear import make_tt_spec
from repro.kernels.ops import btt_linear_op
from repro.optim import adamw, master_view

SCALED = [f for f in ("int8", "fp8_e4m3", "fp8_e5m2") if f in quant.FORMATS]


# ---------------------------------------------------------------------------
# 1. Round-trip.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", SCALED)
def test_quantize_roundtrip_within_half_step(fmt):
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 96)) * 3.0
    q, s = quant.quantize(x, fmt)
    back = quant.dequantize(q, s)
    if fmt == "int8":
        # Uniform grid: half a step is s/2 everywhere.
        bound = 0.5 * float(s) + 1e-7
        assert float(jnp.max(jnp.abs(back - x))) <= bound
    else:
        # fp8 grids are exponential: one ULP at each magnitude.  (XLA's
        # f32->fp8 convert double-rounds, so half-ULP does NOT hold — the
        # storage contract this tier relies on is the full-ULP bound.)
        z = np.asarray(x, np.float64) / float(s)
        mant = {"fp8_e4m3": 3, "fp8_e5m2": 2}[fmt]
        ulp = 2.0 ** (np.floor(np.log2(np.maximum(np.abs(z), 2.0**-6)))
                      - mant)
        err = np.abs(np.asarray(back, np.float64) / float(s) - z)
        assert np.all(err <= ulp * (1 + 1e-6) + 2.0**-9)


def test_quantize_allzero_and_identity_formats():
    z = jnp.zeros((8, 8))
    q, s = quant.quantize(z, "int8")
    assert float(jnp.max(jnp.abs(q.astype(jnp.float32)))) == 0.0
    assert np.isfinite(float(s)) and float(s) > 0.0
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
    assert quant.cast_format(x, "float32") is x
    bf = quant.cast_format(x, "bfloat16")
    assert bf.dtype == x.dtype  # round-trips back to the input dtype
    np.testing.assert_array_equal(
        np.asarray(bf), np.asarray(x.astype(jnp.bfloat16).astype(x.dtype)))


def test_int8_grad_tier_rejected():
    from repro.launch.steps import _grads_at_rest

    cfg = config_n(2).with_precision(grad_dtype="int8")
    with pytest.raises(ValueError, match="int8"):
        _grads_at_rest({"w": jnp.ones((4,))}, cfg)


# ---------------------------------------------------------------------------
# 2. Stochastic rounding: unbiased + deterministic.
# ---------------------------------------------------------------------------


def test_sr_int8_unbiased_over_steps():
    # Fractional targets across the range; the empirical mean over many
    # (step, block) draws must converge to the real value.
    z = jnp.asarray(np.linspace(-120.0, 120.0, 241) + 0.37, jnp.float32)
    n = 2048
    acc = np.zeros(z.shape, np.float64)
    for step in range(0, n, 256):
        batch = jnp.stack([
            quant.stochastic_round(z, "int8", step + i, 0).astype(
                jnp.float32) for i in range(256)])
        acc += np.asarray(jnp.sum(batch, axis=0), np.float64)
    mean = acc / n
    # SR variance is <= 1/4 per draw -> SE <= 0.011 at n=2048; allow 5 SEs.
    assert np.max(np.abs(mean - np.asarray(z, np.float64))) < 0.06


@pytest.mark.parametrize("fmt", [f for f in ("fp8_e4m3", "fp8_e5m2")
                                 if f in quant.FORMATS])
def test_sr_fp8_unbiased_over_steps(fmt):
    z = jnp.asarray(np.linspace(1.0, 200.0, 64) * 1.0137, jnp.float32)
    n = 2048
    acc = np.zeros(z.shape, np.float64)
    for step in range(0, n, 256):
        batch = jnp.stack([
            quant.stochastic_round(z, fmt, step + i, 3).astype(jnp.float32)
            for i in range(256)])
        acc += np.asarray(jnp.sum(batch, axis=0), np.float64)
    mean = acc / n
    mant = {"fp8_e4m3": 3, "fp8_e5m2": 2}[fmt]
    ulp = 2.0 ** (np.floor(np.log2(np.asarray(z, np.float64))) - mant)
    # Empirical mean within a quarter ULP of the true value (SE ~ ulp/90).
    assert np.all(np.abs(mean - np.asarray(z, np.float64)) < 0.25 * ulp)


@pytest.mark.parametrize("fmt", SCALED)
def test_sr_deterministic_in_step_and_block(fmt):
    z = jax.random.uniform(jax.random.PRNGKey(2), (32, 64),
                           minval=-100.0, maxval=100.0)
    a = quant.stochastic_round(z, fmt, 7, 3)
    b = quant.stochastic_round(z, fmt, 7, 3)
    np.testing.assert_array_equal(np.asarray(a.view(jnp.int8)),
                                  np.asarray(b.view(jnp.int8)))
    c = quant.stochastic_round(z, fmt, 8, 3)
    d = quant.stochastic_round(z, fmt, 7, 4)
    as_i = np.asarray(a.view(jnp.int8))
    assert (as_i != np.asarray(c.view(jnp.int8))).any()
    assert (as_i != np.asarray(d.view(jnp.int8))).any()


@settings(max_examples=16, deadline=None)
@given(step=st.integers(0, 2**20), block=st.integers(0, 255),
       seed=st.integers(0, 2**31 - 1))
def test_sr_determinism_property(step, block, seed):
    """Property: SR is a pure function of (value, step, block) for every
    sampled counter — and moving the counter changes some decision."""
    z = jax.random.uniform(jax.random.PRNGKey(seed), (16, 128),
                           minval=-126.0, maxval=126.0)
    a = quant.stochastic_round(z, "int8", step, block)
    b = quant.stochastic_round(z, "int8", step, block)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = quant.stochastic_round(z, "int8", step + 1, block)
    assert (np.asarray(a) != np.asarray(c)).any()


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_sr_unbiased_property(seed):
    """Property: per-element empirical mean over 1024 steps tracks the
    real value to ~5 standard errors, for random targets."""
    z = jax.random.uniform(jax.random.PRNGKey(seed), (128,),
                           minval=-126.0, maxval=126.0)
    total = jnp.zeros(z.shape, jnp.float32)
    for step in range(1024):
        total = total + quant.stochastic_round(z, "int8", step, 0).astype(
            jnp.float32)
    mean = np.asarray(total, np.float64) / 1024
    assert np.max(np.abs(mean - np.asarray(z, np.float64))) < 0.09


def test_counter_uniform_range_and_spread():
    idx = jnp.arange(1 << 14, dtype=jnp.int32).reshape(1, -1)
    u = np.asarray(quant.counter_uniform(idx, 5, 1)).ravel()
    assert u.min() >= 0.0 and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.01
    assert abs(np.var(u) - 1 / 12) < 0.005


# ---------------------------------------------------------------------------
# 3. Kernel path vs the STE oracle (value + gradient).
# ---------------------------------------------------------------------------


def _ste(v, fmt):
    """Straight-through: forward sees deq(quant(v)), backward identity."""
    if fmt == "float32":
        return v
    f = quant.resolve(fmt)
    if not f.needs_scale:
        rt = v.astype(f.dtype).astype(v.dtype)
    else:
        q, s = quant.quantize(v, fmt)
        rt = quant.dequantize(q, s, v.dtype)
    return v + jax.lax.stop_gradient(rt - v)


@pytest.mark.parametrize("pfmt,afmt", [
    ("int8", "int8"),
    ("int8", "float32"),
    ("float32", "int8"),
    ("bfloat16", "bfloat16"),
] + ([("fp8_e4m3", "float32"), ("fp8_e4m3", "int8")]
     if "fp8_e4m3" in quant.FORMATS else []))
def test_quant_kernel_matches_ste_oracle(pfmt, afmt):
    from repro.configs.base import PrecisionConfig

    spec = make_tt_spec(96, 128, 3, 8)
    cores = tt_init(jax.random.PRNGKey(3), spec)
    x = jax.random.normal(jax.random.PRNGKey(4), (24, spec.in_dim))
    prec = PrecisionConfig(param_dtype=pfmt, act_dtype=afmt)

    def kernel_loss(cores, x):
        y = btt_linear_op(cores, x, spec, interpret=True, precision=prec)
        return jnp.sum(y * y), y

    def oracle_loss(cores, x):
        a, b = tt_half_factors(cores, spec)
        y = _ste(x, afmt) @ (_ste(a, pfmt) @ _ste(b, pfmt)).T
        return jnp.sum(y * y), y

    (lk, yk), gk = jax.value_and_grad(kernel_loss, argnums=(0, 1),
                                      has_aux=True)(cores, x)
    (lo, yo), go = jax.value_and_grad(oracle_loss, argnums=(0, 1),
                                      has_aux=True)(cores, x)
    scale = float(jnp.max(jnp.abs(yo))) + 1e-30
    assert float(jnp.max(jnp.abs(yk - yo))) / scale < 1e-5
    for u, v in zip(jax.tree.leaves(gk), jax.tree.leaves(go)):
        ref = float(jnp.max(jnp.abs(v))) + 1e-30
        assert float(jnp.max(jnp.abs(u.astype(jnp.float32)
                                     - v.astype(jnp.float32)))) / ref < 2e-4


@settings(max_examples=8, deadline=None)
@given(d=st.integers(2, 3), rank=st.integers(2, 12), k=st.integers(1, 32),
       m=st.integers(8, 130), n=st.integers(8, 130),
       seed=st.integers(0, 2**31 - 1))
def test_quant_gradient_oracle_property(d, rank, k, m, n, seed):
    """Property: over sampled (d, rank, K, M, N), the int8 kernel path's
    value and STE gradients track the pure-JAX dequantized composition."""
    from repro.configs.base import PrecisionConfig

    spec = make_tt_spec(m, n, d, rank)
    cores = tt_init(jax.random.PRNGKey(seed), spec)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, spec.in_dim))
    prec = PrecisionConfig(param_dtype="int8", act_dtype="int8")

    def kernel_loss(cores, x):
        return jnp.sum(jnp.square(
            btt_linear_op(cores, x, spec, interpret=True, precision=prec)))

    def oracle_loss(cores, x):
        a, b = tt_half_factors(cores, spec)
        y = _ste(x, "int8") @ (_ste(a, "int8") @ _ste(b, "int8")).T
        return jnp.sum(jnp.square(y))

    lk, gk = jax.value_and_grad(kernel_loss, argnums=(0, 1))(cores, x)
    lo, go = jax.value_and_grad(oracle_loss, argnums=(0, 1))(cores, x)
    assert abs(lk - lo) / (abs(lo) + 1e-30) < 1e-5
    for u, v in zip(jax.tree.leaves(gk), jax.tree.leaves(go)):
        ref = float(jnp.max(jnp.abs(v))) + 1e-30
        assert float(jnp.max(jnp.abs(u.astype(jnp.float32)
                                     - v.astype(jnp.float32)))) / ref < 2e-4


def test_f32_precision_config_is_bit_identical_to_none():
    from repro.configs.base import PrecisionConfig

    spec = make_tt_spec(96, 128, 3, 8)
    cores = tt_init(jax.random.PRNGKey(5), spec)
    x = jax.random.normal(jax.random.PRNGKey(6), (16, spec.in_dim))
    y0 = btt_linear_op(cores, x, spec, interpret=True)
    y1 = btt_linear_op(cores, x, spec, interpret=True,
                       precision=PrecisionConfig())
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


# ---------------------------------------------------------------------------
# 4. Quantized-master fused update vs the dense f32 AdamW oracle.
# ---------------------------------------------------------------------------


def _tiny_tree(seed=7):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"w": jax.random.normal(k1, (40, 33)) * 0.05,
            "b": jax.random.normal(k2, (65,)) * 0.02}


@pytest.mark.parametrize("fmt", [f for f in ("int8", "fp8_e4m3")
                                 if f in quant.FORMATS])
def test_quant_master_update_tracks_f32_adamw(fmt):
    params = _tiny_tree()
    grads = jax.tree.map(
        lambda p: 0.01 * jnp.sign(p) + 0.003 * jnp.ones_like(p), params)
    opt_q = adamw(1e-2, param_format=fmt)
    opt_f = adamw(1e-2, fused=False)
    sq = opt_q.init(params)
    # The f32 oracle starts from the SAME dequantized master the quant
    # path sees, so the only divergence left is the SR re-round at the
    # updated block's new scale.
    pq = master_view(sq, params)
    sf = opt_f.init(pq)
    pq1, sq1 = opt_q.update(grads, pq, sq, sq["step"])
    pf1, _ = opt_f.update(grads, pq, sf, sf["step"])
    # The quantized master can differ from the f32 trajectory by the
    # storage grid's resolution around the ACTUAL per-block scale the
    # kernel wrote (the tiny tree packs into a single block): int8 one
    # quantum of RTN + one of SR; fp8 the per-magnitude ULP, doubled.
    assert sq1["ps"].shape[0] == 1
    s = float(sq1["ps"][0, 0])
    mant = {"int8": None, "fp8_e4m3": 3}[fmt]
    for got, want in zip(jax.tree.leaves(master_view(sq1, pq1)),
                         jax.tree.leaves(pf1)):
        err = np.abs(np.asarray(got, np.float64)
                     - np.asarray(want, np.float64))
        if mant is None:
            bound = 2.0 * s
        else:
            z = np.abs(np.asarray(want, np.float64)) / s
            bound = 2.0 * s * 2.0 ** (
                np.floor(np.log2(np.maximum(z, 2.0**-6))) - mant)
        assert np.all(err <= bound + 1e-7), (fmt, err.max())


def test_quant_master_update_bitwise_reproducible():
    params = _tiny_tree(8)
    grads = jax.tree.map(lambda p: 0.02 * jnp.ones_like(p), params)
    opt = adamw(1e-2, param_format="int8")

    def one_run():
        s = opt.init(params)
        p = master_view(s, params)
        for _ in range(3):
            p, s = opt.update(grads, p, s, s["step"])
        return s

    s1, s2 = one_run(), one_run()
    np.testing.assert_array_equal(np.asarray(s1["pq"]), np.asarray(s2["pq"]))
    np.testing.assert_array_equal(np.asarray(s1["ps"]), np.asarray(s2["ps"]))


# ---------------------------------------------------------------------------
# 5. Ledger acceptance: int8 at-rest rows <= 0.5x f32, per row, per stage.
# ---------------------------------------------------------------------------

AT_REST = {"FWD": ("params", "residuals", "attn_residuals", "ffn_hidden"),
           "BWD": ("params", "residuals", "attn_residuals", "ffn_hidden",
                   "grads"),
           "PU": ("params", "grads")}


@pytest.mark.parametrize("n_enc", (2, 4, 6))
def test_ledger_int8_rows_half_or_better(n_enc):
    cfg = config_n(n_enc)
    base = training_step_ledger(cfg, "adamw")
    qcfg = cfg.with_precision(param_dtype="int8", act_dtype="int8",
                              grad_dtype="fp8_e5m2")
    led = training_step_ledger(qcfg, "adamw")
    for stage, names in AT_REST.items():
        for name in names:
            f32b = base[stage].entry(name).nbytes
            qb = led[stage].entry(name).nbytes
            assert qb <= 0.5 * f32b, (stage, name, qb, f32b)


def test_ledger_f32_precision_identical_to_default():
    cfg = config_n(2)
    base = training_step_ledger(cfg, "adamw")
    same = training_step_ledger(cfg.with_precision(param_dtype="float32"),
                                "adamw")
    for stage in base:
        for e0, e1 in zip(base[stage].entries, same[stage].entries):
            assert (e0.name, e0.nbytes, e0.pool) == (e1.name, e1.nbytes,
                                                     e1.pool)


# ---------------------------------------------------------------------------
# 6. ATIS convergence smoke: int8 within 5% relative final loss of f32.
# ---------------------------------------------------------------------------


def test_atis_int8_convergence_within_5pct():
    from repro.data import AtisGrammar, atis_batch
    from repro.models import init_params
    from repro.models.classifier import atis_heads_init, atis_loss

    def run(precision):
        cfg = config_n(2).with_tt(flow="kernel").scaled_down(
            d_model=256, n_heads=4, d_ff=256, vocab_size=1000,
            num_layers=2, max_seq_len=64)
        if precision is not None:
            cfg = cfg.with_precision(**precision)
        g = AtisGrammar(seed=0)
        params = {"backbone": init_params(jax.random.PRNGKey(0), cfg),
                  "heads": atis_heads_init(jax.random.PRNGKey(1), cfg,
                                           26, 120)}
        opt = adamw(3e-3, param_format=cfg.tt.precision.param_dtype)
        state = opt.init(params)
        params = master_view(state, params)

        @jax.jit
        def step(params, state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: atis_loss(p, cfg, batch))(params)
            params, state = opt.update(grads, params, state, state["step"])
            return params, state, loss

        loss = None
        for i in range(12):
            batch = {k: jnp.asarray(v)
                     for k, v in atis_batch(g, "train", i, 4).items()}
            params, state, loss = step(params, state, batch)
        return float(loss)

    f32 = run(None)
    q = run(dict(param_dtype="int8", act_dtype="int8",
                 grad_dtype="fp8_e5m2"))
    assert abs(q - f32) / abs(f32) < 0.05, (q, f32)
