"""Pallas kernel sweeps vs the pure-jnp ref.py oracles (interpret mode).

TPU v5e is the TARGET; interpret=True executes the kernel bodies in Python
on CPU, which validates tiling/indexing/accumulation logic exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import TTSpec, make_ttm_spec, tt_init, ttm_init
from repro.core.contraction import tt_forward_btt, ttm_lookup
from repro.kernels import (
    btt_linear_op,
    btt_linear_pallas,
    btt_linear_ref,
    ttm_embed_op,
    ttm_embed_ref,
)

SHAPES = [
    # (K, N, M, R) — includes non-tile-aligned K/N/M and rank < lane
    (32, 768, 768, 12),      # the paper's layer (rank 12)
    (1, 256, 128, 4),        # degenerate batch
    (300, 1000, 515, 64),    # ragged everything
    (128, 4096, 12288, 96),  # qwen3-class FFN dims
    (512, 512, 512, 128),    # rank == lane width
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_btt_kernel_vs_ref(shape, dtype):
    K, N, M, R = shape
    kx, kb, ka = jax.random.split(jax.random.PRNGKey(sum(shape)), 3)
    x = jax.random.normal(kx, (K, N), dtype)
    b = (jax.random.normal(kb, (R, N), dtype) * 0.05).astype(dtype)
    a = (jax.random.normal(ka, (M, R), dtype) * 0.05).astype(dtype)
    y_kernel = btt_linear_pallas(x, b, a, interpret=True)
    y_ref = btt_linear_ref(x, b, a)
    assert y_kernel.shape == (K, M)
    assert y_kernel.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(y_kernel, np.float32), np.asarray(y_ref, np.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("tk,tn", [(64, 128), (128, 512), (256, 256)])
def test_btt_kernel_tile_sweep(tk, tn):
    """Result must be invariant to the BlockSpec tiling."""
    K, N, M, R = 96, 640, 384, 24
    x = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    b = jax.random.normal(jax.random.PRNGKey(1), (R, N)) * 0.1
    a = jax.random.normal(jax.random.PRNGKey(2), (M, R)) * 0.1
    y = btt_linear_pallas(x, b, a, tk=tk, tn=tn, interpret=True)
    np.testing.assert_allclose(y, btt_linear_ref(x, b, a), rtol=1e-5, atol=1e-5)


def test_btt_op_forward_and_grads_match_pure_flow():
    spec = TTSpec(out_factors=(8, 8, 12), in_factors=(12, 8, 8), rank=12)
    cores = tt_init(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, spec.in_dim))
    y_k = btt_linear_op(cores, x, spec, use_kernel=True, interpret=True)
    y_p = tt_forward_btt(cores, x, spec)
    np.testing.assert_allclose(y_k, y_p, rtol=1e-4, atol=1e-5)

    gk = jax.grad(lambda c, xx: (btt_linear_op(
        list(c), xx, spec, use_kernel=True, interpret=True) ** 2).sum(),
        argnums=(0, 1))(tuple(cores), x)
    gp = jax.grad(lambda c, xx: (tt_forward_btt(list(c), xx, spec) ** 2).sum(),
                  argnums=(0, 1))(tuple(cores), x)
    for u, v in zip(jax.tree.leaves(gk), jax.tree.leaves(gp)):
        np.testing.assert_allclose(u, v, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("vocab,hidden,rank,n_ids", [
    (1000, 768, 30, 97),     # the paper's embedding (Table II)
    (512, 64, 8, 5),
    (4096, 256, 16, 256),
])
def test_ttm_kernel_vs_gather_chain(vocab, hidden, rank, n_ids):
    spec = make_ttm_spec(vocab, hidden, 3, rank)
    cores = ttm_init(jax.random.PRNGKey(2), spec)
    ids = jax.random.randint(jax.random.PRNGKey(3), (n_ids,), 0, vocab)
    out_k = ttm_embed_op(cores, ids, spec, use_kernel=True, interpret=True)
    out_g = ttm_lookup(cores, ids, spec)
    np.testing.assert_allclose(out_k, out_g, rtol=1e-5, atol=1e-6)


def test_ttm_kernel_ref_oracle_matches_gather():
    """ref.py one-hot formulation == the gather chain (independent paths)."""
    spec = make_ttm_spec(1000, 768, 3, 30)
    cores = ttm_init(jax.random.PRNGKey(4), spec)
    ids = jax.random.randint(jax.random.PRNGKey(5), (17,), 0, 1000)
    from repro.core.contraction import token_digits
    dg = token_digits(ids, spec.vocab_factors)
    oh = tuple(jax.nn.one_hot(dg[:, k], spec.vocab_factors[k])
               for k in range(3))
    ref = ttm_embed_ref(oh, tuple(cores))
    np.testing.assert_allclose(ref, ttm_lookup(cores, ids, spec),
                               rtol=1e-5, atol=1e-6)


def test_ttm_kernel_grads_match_gather_chain_oracle():
    """Kernel-path core gradients (custom VJP through the one-hot chain)
    vs plain autodiff through the gather-chain lookup — two independent
    gradient paths for the same function (paper Eq. (12))."""
    spec = make_ttm_spec(1000, 768, 3, 30)      # the paper's embedding
    cores = ttm_init(jax.random.PRNGKey(8), spec)
    ids = jax.random.randint(jax.random.PRNGKey(9), (64,), 0, 1000)
    gk = jax.grad(lambda c: (ttm_embed_op(
        list(c), ids, spec, use_kernel=True, interpret=True) ** 2).sum())(
        tuple(cores))
    gg = jax.grad(lambda c: (ttm_lookup(list(c), ids, spec) ** 2).sum())(
        tuple(cores))
    for i, (u, v) in enumerate(zip(gk, gg)):
        np.testing.assert_allclose(u, v, rtol=1e-4, atol=1e-5,
                                   err_msg=f"core {i}")


@settings(max_examples=8, deadline=None)
@given(
    vocab=st.integers(64, 2000),
    hidden=st.sampled_from([27, 64, 125, 768]),
    rank=st.integers(2, 30),
    n_ids=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_ttm_kernel_grad_parity_property(vocab, hidden, rank, n_ids, seed):
    """Property: over sampled (vocab, hidden, rank, batch), kernel-path
    core gradients track the gather-chain autodiff oracle.  Duplicate ids
    are drawn deliberately — the backward must scatter-add, not overwrite."""
    spec = make_ttm_spec(vocab, hidden, 3, rank)
    cores = ttm_init(jax.random.PRNGKey(seed), spec)
    ids = jax.random.randint(jax.random.PRNGKey(seed + 1), (n_ids,), 0,
                             vocab)
    gy = jax.random.normal(jax.random.PRNGKey(seed + 2),
                           (n_ids, spec.hidden_dim))

    def loss(c, op):
        return (op(list(c)) * gy).sum()

    gk = jax.grad(loss)(tuple(cores),
                        lambda c: ttm_embed_op(c, ids, spec,
                                               use_kernel=True,
                                               interpret=True))
    gg = jax.grad(loss)(tuple(cores),
                        lambda c: ttm_lookup(c, ids, spec))
    for i, (u, v) in enumerate(zip(gk, gg)):
        u, v = np.asarray(u, np.float32), np.asarray(v, np.float32)
        scale = max(float(np.max(np.abs(v))), 1e-6)
        np.testing.assert_allclose(u / scale, v / scale, rtol=0, atol=1e-5,
                                   err_msg=f"core {i}")


def test_ttm_kernel_falls_back_when_ineligible():
    spec = make_ttm_spec(256, 64, 2, 4)  # d=2 -> kernel ineligible
    cores = ttm_init(jax.random.PRNGKey(6), spec)
    ids = jnp.arange(13)
    out = ttm_embed_op(cores, ids, spec, use_kernel=True, interpret=True)
    np.testing.assert_allclose(out, ttm_lookup(cores, ids, spec),
                               rtol=1e-6, atol=1e-7)


def test_btt_kernel_batch_shape_via_op():
    """Model-level integration: TT linear with kernel, padded dims."""
    from repro.core import tt_linear_init
    p = tt_linear_init(jax.random.PRNGKey(7), 50, 70, d=2, rank=6)
    x = jax.random.normal(jax.random.PRNGKey(8), (4, 70))
    y_pure = tt_forward_btt(p.cores, jnp.pad(x, ((0, 0), (0, p.spec.in_dim - 70))),
                            p.spec)[:, :50]
    y_kern = btt_linear_op(p.cores, jnp.pad(x, ((0, 0), (0, p.spec.in_dim - 70))),
                           p.spec, use_kernel=True, interpret=True)[:, :50]
    np.testing.assert_allclose(y_kern, y_pure, rtol=1e-4, atol=1e-5)
