"""Data pipeline determinism/seekability + optimizer behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.data import AtisGrammar, atis_batch, lm_batch, lm_eval_batch
from repro.optim import adamw, clip_by_global_norm, sgd, warmup_cosine


# ---------------------------------------------------------------------------
# Data: pure function of (seed, step) == seekable restart.
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 1000), step=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_lm_batch_deterministic(seed, step):
    a = lm_batch(seed, step, 4, 32, 997)
    b = lm_batch(seed, step, 4, 32, 997)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 997


def test_lm_batch_streams_disjoint():
    tr = lm_batch(0, 5, 4, 64, 1000)
    ev = lm_eval_batch(0, 5, 4, 64, 1000)
    assert not np.array_equal(tr["tokens"], ev["tokens"])


def test_lm_labels_are_shifted_tokens():
    b = lm_batch(0, 0, 2, 16, 100)
    # labels[t] must equal the actual next generated token; check the
    # internal consistency labels[:-1] vs tokens[1:]
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_lm_markov_structure_learnable():
    """Next token matches a fixed successor table >> chance."""
    b = lm_batch(0, 0, 64, 128, 1000)
    from repro.data.synthetic import _markov_tables
    succ = _markov_tables(0, 1000)
    hits = 0
    total = 0
    for i in range(64):
        for t in range(127):
            total += 1
            if b["tokens"][i, t + 1] in succ[b["tokens"][i, t]]:
                hits += 1
    assert hits / total > 0.7  # 85% markov - noise collisions


def test_atis_batch_properties():
    g = AtisGrammar(seed=3)
    b = atis_batch(g, "train", 0, 32)
    assert b["tokens"].shape == (32, 32)
    assert b["intent"].shape == (32,)
    assert b["slots"].shape == (32, 32)
    assert b["intent"].max() < 26 and b["slots"].max() < 120
    # slot labels only on slot-value tokens (band >= 730)
    has_slot = b["slots"] > 0
    assert (b["tokens"][has_slot] >= 730).all()
    # train/test disjoint
    t = atis_batch(g, "test", 0, 32)
    assert not np.array_equal(b["tokens"], t["tokens"])


def test_atis_intent_identifiable():
    """Keyword band tokens encode the intent — check grammar consistency."""
    g = AtisGrammar(seed=3)
    kw, _, _, _ = g.tables()
    b = atis_batch(g, "train", 7, 16)
    for i in range(16):
        kws = [t for t in b["tokens"][i] if 600 <= t < 730]
        assert kws, "every utterance carries intent keywords"
        intents = {int(np.argwhere(kw == t)[0][0]) for t in kws}
        assert intents == {int(b["intent"][i])}


# ---------------------------------------------------------------------------
# Optimizers.
# ---------------------------------------------------------------------------


def _quad_min(opt, steps=200):
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return opt.update(grads, params, state, state["step"])

    for _ in range(steps):
        params, state = step(params, state)
    return float(jnp.abs(params["w"] - target).max())


def test_sgd_converges_quadratic():
    assert _quad_min(sgd(0.1)) < 1e-3


def test_sgd_momentum_converges():
    assert _quad_min(sgd(0.05, momentum=0.9)) < 1e-3


def test_adamw_converges_quadratic():
    assert _quad_min(adamw(0.1)) < 1e-2


def test_adamw_weight_decay_shrinks():
    opt = adamw(0.1, weight_decay=0.5)
    params = {"w": jnp.ones(4) * 5.0}
    state = opt.init(params)
    zeros = {"w": jnp.zeros(4)}
    for _ in range(50):
        params, state = opt.update(zeros, params, state, state["step"])
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0, "b": jnp.ones(9) * 10.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) < 1.0 + 1e-5
    assert float(gn) > 30.0
    # below threshold: untouched
    g2 = {"a": jnp.ones(2) * 1e-3}
    c2, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(c2["a"], g2["a"], rtol=1e-6)


def test_warmup_cosine_shape():
    fn = warmup_cosine(1.0, 10, 100)
    assert float(fn(0)) == 0.0
    assert float(fn(10)) == 1.0
    assert float(fn(100)) < 0.2
    assert float(fn(5)) == 0.5


def test_sgd_paper_faithful_core_update():
    """PU stage on actual TT cores: G_k <- G_k - lr * G'_k (Sec. III-A)."""
    from repro.core import tt_linear_init, tt_linear_apply
    p = {"lin": tt_linear_init(jax.random.PRNGKey(0), 64, 64, d=2, rank=4)}
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    opt = sgd(0.05)
    state = opt.init(p)

    def loss(p):
        return (tt_linear_apply(p["lin"], x) ** 2).mean()

    l0 = float(loss(p))
    for _ in range(20):
        grads = jax.grad(loss)(p)
        p, state = opt.update(grads, p, state, state["step"])
    assert float(loss(p)) < 0.5 * l0
