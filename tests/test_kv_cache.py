"""Paged KV-cache invariants: the allocator and the logical<->physical map.

Oracles:

* conservation — over any alloc/append/free trace, {free} ∪ {in use} is a
  partition of pages 1..NP-1 and ``TRASH_PAGE`` is never handed out;
* ``gather`` is the inverse of ``write_prefill`` — bitwise;
* appended rows land where ``gather`` says they do: a dense per-slot
  logical stream replayed through ``append_target`` reconstructs exactly,
  and windowed groups retain precisely the suffix ring eviction promises
  (every page freed only when wholly outside the window);
* ``device_view``/``write_targets`` route free slots at the trash page.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.runtime.kv_cache import (
    TRASH_PAGE,
    PagedKVCache,
    max_pages_per_request,
    pages_for,
)

L, KV, D = 2, 2, 4   # small but non-degenerate pool shape


def make_cache(**kw):
    kw.setdefault("page_size", 4)
    kw.setdefault("max_len", 32)
    kw.setdefault("max_concurrency", 3)
    return PagedKVCache(L, KV, D, **kw)


def check_conservation(c):
    """Free list and tables partition pages {1..NP-1}; trash never owned."""
    free = set(c._free)
    used = c.pages_in_use()
    assert len(c._free) == len(free), "double entry in free list"
    assert not free & used, "page both free and in use"
    assert free | used == set(range(1, c.n_pages)), "page leaked"
    assert TRASH_PAGE not in used
    per_slot = [p for t in c._tables.values() for p in t]
    assert len(per_slot) == len(set(per_slot)), "page owned by two slots"


def rows_like(rng, s):
    return jnp.asarray(rng.randn(L, s, KV, D).astype(np.float32))


# ---------------------------------------------------------------------------
# Allocator bookkeeping.
# ---------------------------------------------------------------------------


def test_pages_for_and_table_width():
    assert pages_for(1, 4) == 1 and pages_for(4, 4) == 1
    assert pages_for(5, 4) == 2
    assert max_pages_per_request(32, 4, None) == 8
    # window 6 -> 2 pages of live rows + partially-evicted + partial tail
    assert max_pages_per_request(32, 4, 6) == 4
    # a window covering max_len degenerates to the unwindowed width
    assert max_pages_per_request(8, 4, 100) == 2


def test_alloc_free_roundtrip():
    c = make_cache()
    total = c.n_free
    pages = c.alloc(0, 9)          # 3 pages of 4
    assert len(pages) == 3 and TRASH_PAGE not in pages
    assert c.n_free == total - 3
    check_conservation(c)
    with pytest.raises(ValueError):
        c.alloc(0, 1)              # slot already allocated
    c.free_slot(0)
    assert c.n_free == total
    check_conservation(c)


def test_alloc_exhaustion_and_can_admit():
    c = make_cache(max_concurrency=1, max_len=8)   # 1 + 2 pages
    assert c.can_admit(8) and not c.can_admit(9)
    c.alloc(0, 8)
    assert not c.can_admit(1)
    with pytest.raises(MemoryError):
        c.alloc(1, 1)
    check_conservation(c)


def test_append_target_walks_rows_then_pages():
    c = make_cache()
    c.alloc(0, 1)
    first = c.table(0)[0]
    targets = [c.append_target(0) for _ in range(6)]
    # rows 1..3 fill page 1, then a fresh page takes rows 0..2
    assert [r for _, r in targets] == [1, 2, 3, 0, 1, 2]
    assert all(p == first for p, _ in targets[:3])
    second = targets[3][0]
    assert second != first and all(p == second for p, _ in targets[3:])
    assert c.length(0) == 7
    check_conservation(c)


def test_device_view_and_write_targets_route_free_slots_to_trash():
    c = make_cache()
    c.alloc(1, 5)
    table, lengths, pos0 = c.device_view(3)
    assert table.shape == (3, c.np_max)
    assert int(lengths[0]) == 0 and int(lengths[2]) == 0
    assert (np.asarray(table[0]) == TRASH_PAGE).all()
    assert np.array_equal(np.asarray(table[1, :2]), c.table(1))
    pids, rows = c.write_targets(3)
    assert int(pids[0]) == TRASH_PAGE and int(pids[2]) == TRASH_PAGE
    assert int(pids[1]) == c.table(1)[1] and int(rows[1]) == 1
    assert c.length(1) == 6


# ---------------------------------------------------------------------------
# Logical <-> physical mapping.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s", [1, 4, 7, 12])
def test_gather_inverts_write_prefill(s):
    rng = np.random.RandomState(s)
    c = make_cache()
    k, v = rows_like(rng, s), rows_like(rng, s)
    c.write_prefill(0, k, v)
    gk, gv = c.gather(0)
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(k))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(v))
    check_conservation(c)


def _append_column(c, slot, kcol, vcol):
    """One decode step's KV write, the way the engine scatters it."""
    pid, row = c.append_target(slot)
    c.k_pool = c.k_pool.at[:, pid, :, row].set(kcol)
    c.v_pool = c.v_pool.at[:, pid, :, row].set(vcol)


@pytest.mark.parametrize("window", [None, 6])
def test_appended_stream_reconstructs(window):
    """Dense oracle: prefill + appended rows == a plain logical stream;
    windowed caches retain exactly the ring suffix."""
    rng = np.random.RandomState(0)
    c = make_cache(window=window, max_len=64)
    stream_k = rows_like(rng, 5)
    stream_v = rows_like(rng, 5)
    c.write_prefill(0, stream_k, stream_v)
    for _ in range(17):
        kcol, vcol = rows_like(rng, 1)[:, 0], rows_like(rng, 1)[:, 0]
        _append_column(c, 0, kcol, vcol)
        stream_k = jnp.concatenate([stream_k, kcol[:, None]], axis=1)
        stream_v = jnp.concatenate([stream_v, vcol[:, None]], axis=1)
        length, pos0 = c.length(0), c.pos0(0)
        assert length == stream_k.shape[1]
        if window is None:
            assert pos0 == 0
        else:
            # every retained page still holds >= 1 in-window row, and the
            # whole window is retained
            assert pos0 % c.page_size == 0
            assert pos0 <= length - window < pos0 + c.page_size
        gk, gv = c.gather(0)
        np.testing.assert_array_equal(np.asarray(gk),
                                      np.asarray(stream_k[:, pos0:]))
        np.testing.assert_array_equal(np.asarray(gv),
                                      np.asarray(stream_v[:, pos0:]))
        check_conservation(c)


def test_ring_eviction_bounds_pages_held():
    """A windowed slot's page count never exceeds the advertised
    max_pages_per_request, no matter how long it decodes."""
    c = make_cache(window=6, max_len=256, max_concurrency=1)
    c.alloc(0, 1)
    for _ in range(200):
        c.append_target(0)
        assert len(c.table(0)) <= c.np_max
    assert c.np_max == max_pages_per_request(256, 4, 6)
    check_conservation(c)


def test_trash_page_isolated_from_prefill():
    """Prefill scatter touches only the pages it allocated."""
    rng = np.random.RandomState(1)
    c = make_cache()
    before = np.asarray(c.k_pool[:, TRASH_PAGE])
    c.write_prefill(0, rows_like(rng, 6), rows_like(rng, 6))
    np.testing.assert_array_equal(np.asarray(c.k_pool[:, TRASH_PAGE]),
                                  before)
    untouched = sorted(set(range(c.n_pages)) - set(c.table(0)))
    assert not np.asarray(c.k_pool[:, untouched]).any()


# ---------------------------------------------------------------------------
# Property sweep: random traces (skipped without hypothesis).
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), window=st.none() | st.integers(3, 12),
       data=st.data())
def test_random_trace_conservation(seed, window, data):
    rng = np.random.RandomState(seed)
    c = make_cache(window=window, max_len=48, max_concurrency=4)
    live: dict[int, int] = {}   # slot -> logical length
    for _ in range(data.draw(st.integers(5, 40))):
        ops = ["append", "free"] if live else []
        if len(live) < 4:
            ops.append("alloc")
        op = data.draw(st.sampled_from(ops))
        if op == "alloc":
            slot = min(set(range(4)) - set(live))
            n = data.draw(st.integers(1, 10))
            if c.can_admit(n):
                c.alloc(slot, n)
                live[slot] = n
        elif op == "append":
            slot = data.draw(st.sampled_from(sorted(live)))
            try:
                c.append_target(slot)
                live[slot] += 1
            except MemoryError:
                pass    # pool full is legal; state must stay consistent
        else:
            slot = data.draw(st.sampled_from(sorted(live)))
            c.free_slot(slot)
            del live[slot]
        check_conservation(c)
        for slot, n in live.items():
            assert c.length(slot) == n
    for slot in sorted(live):
        c.free_slot(slot)
    assert c.n_free == c.n_pages - 1
