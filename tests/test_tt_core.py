"""Core TT/TTM correctness: flows vs dense oracle, fused VJP vs autodiff,
factorization properties (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    TTSpec,
    factorize,
    make_tt_spec,
    make_ttm_spec,
    tt_forward_btt,
    tt_forward_rl,
    tt_half_factors,
    tt_init,
    tt_linear_apply,
    tt_linear_init,
    tt_params_count,
    tt_reconstruct,
    ttm_embedding_apply,
    ttm_embedding_init,
    ttm_init,
    ttm_lookup,
    ttm_reconstruct,
)

PAPER_SPEC = TTSpec(out_factors=(8, 8, 12), in_factors=(12, 8, 8), rank=12)


# ---------------------------------------------------------------------------
# Contraction flows agree with the dense reconstruction (paper: contraction
# order never changes the math, only the cost).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [
    PAPER_SPEC,
    TTSpec(out_factors=(4, 4), in_factors=(4, 4), rank=3),
    TTSpec(out_factors=(16, 16, 16), in_factors=(8, 8, 8), rank=24),
    TTSpec(out_factors=(3, 5, 7, 2), in_factors=(2, 7, 5, 3), rank=6),
])
@pytest.mark.parametrize("K", [1, 32])
def test_flows_match_dense(spec, K, rng):
    cores = tt_init(rng, spec)
    w = tt_reconstruct(cores, spec)
    assert w.shape == (spec.out_dim, spec.in_dim)
    x = jax.random.normal(jax.random.PRNGKey(1), (K, spec.in_dim))
    y_ref = x @ w.T
    np.testing.assert_allclose(tt_forward_rl(cores, x, spec), y_ref,
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(tt_forward_btt(cores, x, spec), y_ref,
                               rtol=2e-4, atol=2e-5)


def test_half_factors_shapes(rng):
    cores = tt_init(rng, PAPER_SPEC)
    a, b = tt_half_factors(cores, PAPER_SPEC)
    assert a.shape == (PAPER_SPEC.out_dim, PAPER_SPEC.mid_rank)
    assert b.shape == (PAPER_SPEC.mid_rank, PAPER_SPEC.in_dim)
    np.testing.assert_allclose(a @ b, tt_reconstruct(cores, PAPER_SPEC),
                               rtol=1e-5, atol=1e-6)


def test_rank_clamping_boundary():
    spec = TTSpec(out_factors=(2, 2), in_factors=(2, 2), rank=64)
    rs = spec.ranks
    assert rs[0] == rs[-1] == 1
    # interior ranks clamp to the dense boundary (never waste params)
    assert rs[1] == 2 and rs[2] == 4 and rs[3] == 2


# ---------------------------------------------------------------------------
# Fused custom VJP == plain autodiff == autodiff through dense reconstruct.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,rank,dims", [(3, 12, (768, 768)),
                                         (2, 8, (64, 48)),
                                         (3, 16, (512, 1024))])
def test_fused_vjp_matches_autodiff(d, rank, dims, rng):
    out_dim, in_dim = dims
    p = tt_linear_init(rng, out_dim, in_dim, d=d, rank=rank)
    x = jax.random.normal(jax.random.PRNGKey(2), (16, in_dim))
    ct = jax.random.normal(jax.random.PRNGKey(3), (16, out_dim))

    def run(flow):
        def f(cores, xx):
            pp = dataclasses.replace(p, cores=list(cores))
            y = tt_linear_apply(pp, xx, flow=flow)
            return jnp.vdot(y, ct)
        return jax.grad(f, argnums=(0, 1))(tuple(p.cores), x)

    g_fused = run("btt_fused")
    g_plain = run("btt")
    g_rl = run("rl")
    for a, b in zip(jax.tree.leaves(g_fused), jax.tree.leaves(g_plain)):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-4)
    for a, b in zip(jax.tree.leaves(g_fused), jax.tree.leaves(g_rl)):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-4)


def test_grad_vs_dense_reconstruction(rng):
    """Core grads equal autodiff through the dense W = reconstruct(cores)."""
    spec = TTSpec(out_factors=(4, 6), in_factors=(6, 4), rank=5)
    cores = tuple(tt_init(rng, spec))
    x = jax.random.normal(jax.random.PRNGKey(4), (8, spec.in_dim))

    def via_flow(cs):
        return (tt_forward_btt(list(cs), x, spec) ** 2).sum()

    def via_dense(cs):
        w = tt_reconstruct(list(cs), spec)
        return ((x @ w.T) ** 2).sum()

    g1 = jax.grad(via_flow)(cores)
    g2 = jax.grad(via_dense)(cores)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# Padding path: logical dims that do not factorize exactly.
# ---------------------------------------------------------------------------


def test_padded_logical_dims(rng):
    p = tt_linear_init(rng, 50, 70, d=3, rank=4)  # 50, 70 need padding
    assert p.spec.in_dim >= 70 and p.spec.out_dim >= 50
    x = jax.random.normal(jax.random.PRNGKey(5), (9, 70))
    y = tt_linear_apply(p, x)
    assert y.shape == (9, 50)
    # padding must behave as zero-extension: matches manual pad + slice
    w = tt_reconstruct(p.cores, p.spec)[:50, :70]
    np.testing.assert_allclose(y, x @ w.T, rtol=2e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# TTM embedding.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("vocab,hidden,d,rank", [
    (1000, 768, 3, 30),   # the paper's Table II embedding
    (512, 64, 2, 8),
    (50432, 768, 3, 16),
])
def test_ttm_lookup_matches_dense(vocab, hidden, d, rank, rng):
    emb = ttm_embedding_init(rng, vocab, hidden, d=d, rank=rank)
    ids = jax.random.randint(jax.random.PRNGKey(6), (33,), 0, vocab)
    out = ttm_embedding_apply(emb, ids)
    dense = ttm_reconstruct(emb.cores, emb.spec)[:vocab, :hidden]
    np.testing.assert_allclose(out, jnp.take(dense, ids, axis=0),
                               rtol=2e-4, atol=1e-6)


def test_ttm_grads_flow(rng):
    emb = ttm_embedding_init(rng, 100, 32, d=2, rank=4)
    ids = jnp.arange(10)

    def f(cores):
        e = dataclasses.replace(emb, cores=list(cores))
        return (ttm_embedding_apply(e, ids) ** 2).sum()

    grads = jax.grad(f)(tuple(emb.cores))
    assert all(bool(jnp.any(g != 0)) for g in grads)


# ---------------------------------------------------------------------------
# factorize: property-based.
# ---------------------------------------------------------------------------


@given(n=st.integers(2, 300_000), d=st.integers(1, 4))
@settings(max_examples=60, deadline=None)
def test_factorize_properties(n, d):
    fac, npad = factorize(n, d)
    assert len(fac) == d
    assert int(np.prod(fac)) == npad
    assert npad >= n
    assert all(f >= 1 for f in fac)


@given(out_dim=st.sampled_from([64, 768, 4096, 12288]),
       in_dim=st.sampled_from([64, 768, 5120]),
       d=st.integers(2, 3), rank=st.sampled_from([1, 4, 12, 64]))
@settings(max_examples=20, deadline=None)
def test_tt_param_count_below_dense(out_dim, in_dim, d, rank):
    if rank * rank >= min(out_dim, in_dim):
        return  # not in the compression regime (e.g. 64x64 at rank 64)
    spec = make_tt_spec(out_dim, in_dim, d, rank)
    assert tt_params_count(spec) < spec.out_dim * spec.in_dim


@given(K=st.integers(1, 64))
@settings(max_examples=10, deadline=None)
def test_flow_equivalence_property(K):
    """Contraction order invariance, the paper's Sec. IV premise."""
    spec = TTSpec(out_factors=(4, 4), in_factors=(4, 4), rank=5)
    cores = tt_init(jax.random.PRNGKey(K), spec)
    x = jax.random.normal(jax.random.PRNGKey(K + 1), (K, spec.in_dim))
    np.testing.assert_allclose(tt_forward_rl(cores, x, spec),
                               tt_forward_btt(cores, x, spec),
                               rtol=2e-4, atol=2e-5)


def test_init_variance_targets(rng):
    """Reconstructed W element std matches the Glorot target (+-40%)."""
    spec = make_tt_spec(768, 768, 3, 12)
    cores = tt_init(rng, spec)
    w = tt_reconstruct(cores, spec)
    target = (2.0 / (spec.in_dim + spec.out_dim)) ** 0.5
    assert 0.6 * target < float(jnp.std(w)) < 1.4 * target
