"""Fault-tolerant training & serving: the numerics sentry (runtime.guard),
the escalation ladder, rollback bit-identity across optimizer-state
layouts, the quant-saturation sentinel, serving's NaN-logit guard, and the
chaos harness's own determinism — every failure is injected via
``runtime.chaos``, so each path here is reproducible, not flaky.

The e2e acceptance test (ATIS NaN burst) asserts BOTH directions: the
guarded run converges within 5% of the fault-free loss, and the identical
step with the guard mask off diverges — proving the guard is what saves
the run, not luck.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.optim import adamw, master_view, sgd
from repro.runtime.chaos import (
    ChaosPlan,
    GradFault,
    LogitPoison,
    corrupt_checkpoint,
)
from repro.runtime.guard import (
    GuardPolicy,
    TrainGuard,
    apply_guarded_update,
    guard_controls,
    make_guarded_step,
)


def _problem(seed=0):
    """Two-leaf least-squares target, big enough to engage the sketch."""
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=30_000), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(40, 12)), jnp.float32)}
    target = jax.tree.map(
        lambda x: jnp.asarray(rng.normal(size=x.shape), jnp.float32), params)

    def loss_of(p, t):
        return (jnp.mean(jnp.square(p["w"] - t["w"]))
                + jnp.mean(jnp.square(p["b"] - t["b"])))

    return params, target, loss_of


def _trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# In-jit guard: skip-step mask, guard-off control, lr_scale plumbing.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["dense", "sketched", "quant"])
def test_nan_step_holds_params_and_state_bitwise(layout):
    """A non-finite step must be a true no-op for EVERY state layout:
    params, moments (dense m/v or sketches vs/ms), quantized masters
    (pq/ps), and the bias-correction step counter all stay bit-identical
    — the in-jit masked select, not a host-side restore."""
    params, target, loss_of = _problem()
    opt = {"dense": lambda: adamw(1e-2),
           "sketched": lambda: adamw(1e-2, sketched=True),
           "quant": lambda: adamw(1e-2, param_format="int8"),
           }[layout]()
    state = opt.init(params)
    if layout == "sketched":
        assert "vs" in state
    if layout == "quant":
        assert "pq" in state
        params = master_view(state, params)
    step = jax.jit(make_guarded_step(loss_of, opt))

    params, state, m = step(params, state, target, guard_controls())
    assert float(m["applied"]) == 1.0 and float(m["nonfinite"]) == 0.0
    before = jax.device_get((params, state))

    params, state, m = step(params, state, target,
                            guard_controls(fault_add=float("nan")))
    assert float(m["nonfinite"]) == 1.0 and float(m["applied"]) == 0.0
    assert not np.isfinite(float(m["grad_norm"]))
    assert _trees_equal(before, (params, state))
    assert int(state["step"]) == 1  # counter frozen on the skipped step

    # and the run continues cleanly afterwards
    params, state, m = step(params, state, target, guard_controls())
    assert float(m["applied"]) == 1.0 and np.isfinite(float(m["loss"]))
    assert int(state["step"]) == 2


def test_guard_off_lets_the_fault_through():
    """guard_on=False is the divergence control: the same compiled step
    applies the poisoned update instead of masking it."""
    params, target, loss_of = _problem()
    opt = adamw(1e-2)
    state = opt.init(params)
    step = jax.jit(make_guarded_step(loss_of, opt))
    params, state, m = step(params, state, target,
                            guard_controls(fault_add=float("nan"),
                                           guard_on=False))
    assert float(m["nonfinite"]) == 1.0      # probe still fired
    assert float(m["applied"]) == 1.0        # ...but the mask was off
    assert not np.all(np.isfinite(np.asarray(params["w"])))


def test_lr_scale_leaf_scales_the_update():
    """The backoff knob: halving the state's lr_scale leaf must exactly
    halve an SGD delta — no retrace, no optimizer rebuild."""
    opt = sgd(0.1)
    params = {"w": jnp.ones(8)}
    target = {"w": jnp.zeros(8)}
    loss_of = lambda p, t: jnp.mean(jnp.square(p["w"] - t["w"]))
    step = jax.jit(make_guarded_step(loss_of, opt, clip_norm=0.0))

    def delta(scale):
        state = dict(opt.init(params), lr_scale=jnp.float32(scale))
        p2, _, _ = step(params, state, target, guard_controls())
        return np.asarray(params["w"] - p2["w"])

    np.testing.assert_allclose(delta(1.0), 2.0 * delta(0.5), rtol=1e-6)


def test_int8_grad_tier_rejected():
    with pytest.raises(ValueError, match="int8"):
        apply_guarded_update(sgd(0.1), jnp.float32(0.0), {"w": jnp.ones(4)},
                             {"w": jnp.ones(4)},
                             {"step": jnp.zeros((), jnp.int32)},
                             guard_controls(), grad_fmt="int8")


# ---------------------------------------------------------------------------
# Host-side ladder: skip -> backoff -> rollback, recovery, counters.
# ---------------------------------------------------------------------------


def _metrics(loss=1.0, gnorm=1.0, nonfinite=0.0, sat=0.0):
    return {"loss": jnp.float32(loss), "grad_norm": jnp.float32(gnorm),
            "nonfinite": jnp.float32(nonfinite), "sat_frac": jnp.float32(sat),
            "applied": jnp.float32(1.0 - nonfinite)}


def test_escalation_ladder_and_recovery():
    guard = TrainGuard(GuardPolicy(warmup=2, backoff_after=2,
                                   rollback_after=4, recover_after=3,
                                   snapshot_every=10**9))
    params = {"w": jnp.zeros(2)}
    state = guard.attach({"step": jnp.zeros((), jnp.int32)})
    actions = []
    for i in range(4):
        params, state, a = guard.observe(i, _metrics(), params, state)
        actions.append(a)
    assert actions == ["ok"] * 4 and guard.report()["snapshots"] == 1

    for i in range(4, 8):
        params, state, a = guard.observe(i, _metrics(nonfinite=1.0),
                                         params, state)
        actions.append(a)
    # bad #1 skip, #2/#3 backoff (0.5 then 0.25), #4 rollback
    assert actions[4:] == ["skip", "backoff", "backoff", "rollback"]
    rep = guard.report()
    assert rep["skipped"] == 4 and rep["backoffs"] == 2
    assert rep["rollbacks"] == 1 and rep["lr_scale"] == 0.25
    assert float(state["lr_scale"]) == 0.25

    # recovery: every 3 consecutive good steps doubles lr_scale back
    for i in range(8, 14):
        params, state, a = guard.observe(i, _metrics(), params, state)
        assert a == "ok"
    rep = guard.report()
    assert rep["lr_scale"] == 1.0 and rep["recoveries"] == 2


def test_spike_flagging_feeds_only_finite_samples():
    """A NaN loss must not poison the EWMA baseline: after a NaN step the
    monitors still flag the next finite spike."""
    guard = TrainGuard(GuardPolicy(warmup=2, backoff_after=10**9,
                                   rollback_after=10**9))
    params, state = {}, guard.attach({"step": jnp.zeros((), jnp.int32)})
    for i in range(8):
        guard.observe(i, _metrics(loss=1.0, gnorm=1.0), params, state)
    guard.observe(8, _metrics(nonfinite=1.0), params, state)
    _, _, a = guard.observe(9, _metrics(loss=50.0), params, state)
    assert a == "skip" and guard.report()["flagged"] == 1


@pytest.mark.parametrize("layout", ["sketched", "quant"])
def test_rollback_restores_state_bitwise(layout):
    """After K consecutive finite-spike steps the guard rolls back to the
    last-good snapshot — and the restored sketched (vs/ms) or quantized
    master (pq/ps) state is BIT-identical to what was snapshotted, not
    merely close."""
    params, target, loss_of = _problem()
    opt = (adamw(1e-2, sketched=True) if layout == "sketched"
           else adamw(1e-2, param_format="int8"))
    state = opt.init(params)
    if layout == "quant":
        params = master_view(state, params)
    # backoff_after > rollback_after: lr_scale stays 1.0 throughout, so
    # the bitwise comparison is not disturbed by a backed-off leaf.
    guard = TrainGuard(GuardPolicy(warmup=2, backoff_after=10**9,
                                   rollback_after=3, snapshot_every=10**9))
    state = guard.attach(state)
    step = jax.jit(make_guarded_step(loss_of, opt))
    # 1e10 stays finite through the f32 sum-of-squares (1e28 would
    # overflow it to inf and take the skip path instead of the EWMA one).
    plan = ChaosPlan(grad_faults=(
        GradFault(step=6, length=3, mode="spike", magnitude=1e10),))

    snap = None
    for i in range(9):
        ctrl = guard.controls(fault_add=plan.fault_add(i))
        params, state, m = step(params, state, target, ctrl)
        assert float(m["nonfinite"]) == 0.0  # spikes are finite faults
        params, state, action = guard.observe(i, m, params, state)
        if i == 0:
            snap = jax.device_get((params, state))  # == guard's snapshot
        if i < 6:
            assert action == "ok"
    assert action == "rollback", action
    assert guard.report()["flagged"] == 3
    assert _trees_equal(snap, (params, state))
    # the spiked steps genuinely diverged the state before the rollback
    # (otherwise this test would pass vacuously)
    p2, s2, _ = step(params, state, target, guard.controls())
    assert not _trees_equal(snap, (p2, s2))


# ---------------------------------------------------------------------------
# Quant-saturation sentinel: fp8_e5m2 underflow -> bf16 escalation.
# ---------------------------------------------------------------------------


@pytest.mark.skipif(not quant.HAVE_FP8, reason="no fp8 dtypes in this jax")
def test_saturation_sentinel_escalates_grad_tier():
    """One outlier inflates the per-tensor scale until the bulk of the
    gradient underflows fp8_e5m2 to zero; the sentinel sees the lost
    fraction and escalates the tier to bf16 — after which the small
    gradient mass survives the round trip."""
    opt = sgd(1.0)
    params = {"w": jnp.ones(257)}
    state = {"step": jnp.zeros((), jnp.int32)}
    # 256 tiny grads + 1 outlier: tiny/scale ~ 6e-8 << e5m2 subnormal min
    grads = {"w": jnp.concatenate(
        [jnp.full((256,), 1e-6, jnp.float32), jnp.array([1e6], jnp.float32)])}
    loss = jnp.float32(0.5)

    p_lo, _, m_lo = apply_guarded_update(
        opt, loss, grads, params, state, guard_controls(),
        grad_fmt="fp8_e5m2", clip_norm=0.0)
    assert float(m_lo["sat_frac"]) > 0.9
    moved_lo = int(np.sum(np.asarray(p_lo["w"]) != 1.0))
    assert moved_lo <= 1  # only the outlier survived the fp8 grid

    p_hi, _, m_hi = apply_guarded_update(
        opt, loss, grads, params, state, guard_controls(grad_bf16=True),
        grad_fmt="fp8_e5m2", clip_norm=0.0)
    moved_hi = int(np.sum(np.asarray(p_hi["w"]) != 1.0))
    assert moved_hi == 257  # bf16 keeps the small mass
    # sat_frac still reports the CONFIGURED tier's loss (the signal that
    # keeps the escalation latched)
    assert float(m_hi["sat_frac"]) > 0.9

    guard = TrainGuard(GuardPolicy(sat_threshold=0.25, sat_after=2))
    st = guard.attach(dict(state))
    guard.observe(0, _metrics(sat=float(m_lo["sat_frac"])), params, st)
    assert not guard.grad_bf16
    guard.observe(1, _metrics(sat=float(m_lo["sat_frac"])), params, st)
    assert guard.grad_bf16 and guard.report()["escalations"] == 1
    assert bool(guard.controls()["grad_bf16"])


# ---------------------------------------------------------------------------
# Chaos harness determinism.
# ---------------------------------------------------------------------------


def test_chaos_plan_schedule_and_values():
    plan = ChaosPlan(grad_faults=(GradFault(step=3, length=2, mode="nan"),
                                  GradFault(step=7, mode="spike",
                                            magnitude=1e20)))
    assert plan.fault_add(2) == 0.0
    assert np.isnan(plan.fault_add(3)) and np.isnan(plan.fault_add(4))
    assert plan.fault_add(5) == 0.0
    assert plan.fault_add(7) == 1e20
    assert np.isinf(GradFault(step=0, mode="inf").value)
    with pytest.raises(ValueError):
        GradFault(step=0, mode="garbage")


def test_corrupt_checkpoint_deterministic(tmp_path):
    from repro.checkpoint import save

    tree = {"w": jnp.arange(64, dtype=jnp.float32),
            "b": jnp.ones((8, 8), jnp.float32)}
    reports = []
    for sub in ("a", "b"):
        root = str(tmp_path / sub)
        save(root, 5, tree)
        reports.append(corrupt_checkpoint(root, 5, mode="truncate", seed=11))
    assert reports[0]["offset"] == reports[1]["offset"]
    assert (reports[0]["path"].split("/")[-1]
            == reports[1]["path"].split("/")[-1])
    assert reports[0]["step"] == reports[1]["step"] == 5


def test_logit_poison_targets_one_step_and_slot():
    chaos = LogitPoison(at_step=2, slots=(1,))
    logits = np.zeros((3, 4), np.float32)
    out = chaos.poison_logits(logits, 1)
    assert np.isfinite(out).all() and out is logits  # untouched step
    out = chaos.poison_logits(logits, 2)
    assert out is not logits                          # copy, not in-place
    assert np.isfinite(logits).all()
    assert np.isnan(out[1, 0]) and np.isfinite(out[[0, 2]]).all()


# ---------------------------------------------------------------------------
# E2E acceptance: ATIS NaN burst — guarded converges, unguarded diverges.
# ---------------------------------------------------------------------------


def _atis_setup():
    from repro.configs.atis_transformer import config_n
    from repro.data import AtisGrammar
    from repro.models import init_params
    from repro.models.classifier import atis_heads_init

    cfg = config_n(2).scaled_down(d_model=128, n_heads=4, d_ff=128,
                                  vocab_size=1000, num_layers=2)
    g = AtisGrammar(seed=1)
    params = {"backbone": init_params(jax.random.PRNGKey(0), cfg),
              "heads": atis_heads_init(jax.random.PRNGKey(1), cfg, 26, 120)}
    return cfg, g, params


def test_atis_nan_burst_guarded_converges_unguarded_diverges():
    """The PR's acceptance test, both directions on the paper's own task:
    a 3-step NaN burst mid-run (a) leaves the guarded run within 5% of the
    fault-free final loss, and (b) destroys the identical run with the
    guard mask off.  (b) is what makes (a) evidence: the fault is strong
    enough to kill the run, and the guard is what saves it."""
    from repro.data import atis_batch
    from repro.models.classifier import atis_loss

    cfg, g, params0 = _atis_setup()
    opt = adamw(2e-3, fused=True)
    step = jax.jit(make_guarded_step(
        lambda p, b: atis_loss(p, cfg, b), opt))
    plan = ChaosPlan(grad_faults=(GradFault(step=20, length=3, mode="nan"),))
    steps = 60

    def run(*, faults: bool, guard_on: bool):
        guard = TrainGuard(GuardPolicy(warmup=4, recover_after=10))
        params = jax.tree.map(jnp.array, params0)
        state = guard.attach(opt.init(params))
        loss = float("nan")
        for i in range(steps):
            batch = {k: jnp.asarray(v)
                     for k, v in atis_batch(g, "train", i, 32).items()}
            fa = plan.fault_add(i) if faults else 0.0
            ctrl = (guard.controls(fault_add=fa) if guard_on
                    else guard_controls(fault_add=fa, guard_on=False))
            params, state, m = step(params, state, batch, ctrl)
            if guard_on:
                params, state, _ = guard.observe(i, m, params, state)
            loss = float(m["loss"])
        return loss, guard.report()

    clean, _ = run(faults=False, guard_on=True)
    faulted, rep = run(faults=True, guard_on=True)
    unguarded, _ = run(faults=True, guard_on=False)

    assert not np.isfinite(unguarded), unguarded   # (b) control diverged
    assert np.isfinite(faulted)
    assert rep["skipped"] == 3                     # the burst was masked
    assert faulted < clean * 1.05, (clean, faulted)  # (a) within 5%
    assert faulted < 8.0  # and it genuinely trained (same bar as tier-1)


# ---------------------------------------------------------------------------
# Serving hardening: poisoned logits evicted, deadlines enforced, e2e.
# ---------------------------------------------------------------------------


def _serve_cfg():
    import dataclasses

    from repro.configs import get_config

    cfg = get_config("llama3-8b").scaled_down()
    return dataclasses.replace(cfg, dtype="float32")


def test_serve_poisoned_slot_evicted_healthy_rows_unaffected():
    """NaN logits in one slot mid-decode: that request is evicted (counted
    as ``poisoned``), the batch keeps decoding, and the surviving
    requests' tokens are IDENTICAL to the unpoisoned run — row-independent
    math plus per-(rid, n) sampling keys."""
    from repro.launch.serve import serve_paged
    from repro.models import init_params

    cfg = _serve_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab_size, size=(n,)).tolist()
               for n in (7, 5, 9)]
    kw = dict(gen=6, max_concurrency=3, page_size=4, fused_decode=False,
              quiet=True)
    clean = serve_paged(cfg, params, prompts, **kw)
    hit = serve_paged(cfg, params, prompts,
                      chaos=LogitPoison(at_step=2, slots=(1,)), **kw)

    rep = hit["report"]
    assert rep["poisoned"] == 1 and rep["evicted"] == 1
    assert rep["finished"] == 2
    by_rid = {r.rid: r for r in hit["requests"]}
    clean_by_rid = {r.rid: r for r in clean["requests"]}
    assert by_rid[1].state == "evicted" and len(by_rid[1].out) < 6
    for rid in (0, 2):
        assert by_rid[rid].state == "finished"
        assert by_rid[rid].out == clean_by_rid[rid].out


def test_serve_deadline_times_out_waiting_request():
    """Oversubscribed queue + TTL: the request that can't get a slot in
    time is timeout-retired (not silently starved), its engine resources
    are never leaked, and the running requests finish normally."""
    from repro.launch.serve import serve_paged
    from repro.models import init_params

    cfg = _serve_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab_size, size=(6,)).tolist()
               for _ in range(3)]
    # gen=4 finishes a running request in 3 scheduler steps — inside the
    # 4-step TTL; the third request is still waiting/just-admitted when
    # its TTL (measured from ARRIVAL, not admission) expires.
    out = serve_paged(cfg, params, prompts, gen=4, max_concurrency=2,
                      page_size=4, fused_decode=False, deadline_steps=4,
                      quiet=True)
    rep = out["report"]
    assert rep["finished"] == 2 and rep["timed_out"] == 1
    assert rep["still_waiting"] == 0
    by_rid = {r.rid: r for r in out["requests"]}
    assert by_rid[2].state == "timeout" and len(by_rid[2].out) < 4


def test_serve_bounded_queue_sheds_overflow():
    from repro.launch.serve import serve_paged
    from repro.models import init_params

    cfg = _serve_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab_size, size=(5,)).tolist()
               for _ in range(4)]
    out = serve_paged(cfg, params, prompts, gen=4, max_concurrency=2,
                      page_size=4, fused_decode=False, max_queue=2,
                      quiet=True)
    rep = out["report"]
    # Every submit happens before the first admit, so exactly queue-bound
    # requests get in and the overflow is shed at the door (conservation:
    # shed requests are retired too, never silently dropped).
    assert rep["shed"] == 2 and rep["finished"] == 2
    assert (rep["finished"] + rep["evicted"] + rep["timed_out"]
            + rep["shed"]) == 4


# ---------------------------------------------------------------------------
# CLI smoke: the full train driver with --guard armed.
# ---------------------------------------------------------------------------


def test_train_cli_guard_smoke(tmp_path):
    from repro.launch.train import main

    out = main(["--arch", "qwen3-8b", "--tt", "--scale-down", "--steps", "8",
                "--batch", "4", "--seq", "64", "--lr", "1e-2", "--guard",
                "--ckpt-dir", str(tmp_path / "ckpt"), "--ckpt-every", "4",
                "--log-every", "4"])
    assert np.isfinite(out["final_loss"])
    assert out["final_loss"] < out["first_loss"]
    g = out["guard"]
    assert g["skipped"] == 0 and g["rollbacks"] == 0
    assert g["lr_scale"] == 1.0 and g["snapshots"] >= 1
