"""Quickstart: the paper's technique in five minutes, on a laptop CPU.

1. A TT-compressed linear layer == its dense reconstruction, at 99x fewer
   parameters (paper Sec. III-B).
2. The three contraction flows (right-to-left / BTT / fused-BTT) are
   bit-compatible; BTT is the fast one (paper Sec. IV).
3. A tensor-compressed transformer (reduced qwen3 config) trains end-to-end
   with SGD directly on the TT cores (paper Sec. III-A).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    tt_forward_btt,
    tt_forward_rl,
    tt_linear_apply,
    tt_linear_init,
    tt_params_count,
    tt_reconstruct,
)
from repro.configs import get_config
from repro.data import lm_batch
from repro.launch.steps import make_train_step
from repro.models import init_params, num_params
from repro.optim import sgd


def main():
    key = jax.random.PRNGKey(0)

    # -- 1. TT linear: same math, 99x fewer parameters --------------------
    p = tt_linear_init(key, 768, 768, d=3, rank=12)
    dense_params = 768 * 768
    print(f"[1] TT(768x768, d=3, r=12): {tt_params_count(p.spec):,} params "
          f"vs dense {dense_params:,} -> {dense_params / tt_params_count(p.spec):.1f}x")
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 768))
    w = tt_reconstruct(p.cores, p.spec)
    err = float(jnp.abs(tt_linear_apply(p, x) - x @ w.T).max())
    print(f"    |TT(x) - W x|_inf = {err:.2e} (same math)")

    # -- 2. Contraction flows: identical values, different cost -----------
    y_rl = tt_forward_rl(p.cores, x, p.spec)
    y_btt = tt_forward_btt(p.cores, x, p.spec)
    print(f"[2] right-to-left vs bidirectional: max diff "
          f"{float(jnp.abs(y_rl - y_btt).max()):.2e}")
    for name, flow in [("right-to-left", "rl"), ("BTT (paper)", "btt_fused")]:
        f = jax.jit(lambda xx, fl=flow: tt_linear_apply(p, xx, flow=fl))
        jax.block_until_ready(f(x))
        t0 = time.perf_counter()
        for _ in range(50):
            out = f(x)
        jax.block_until_ready(out)
        print(f"    {name:14s} {1e6 * (time.perf_counter() - t0) / 50:8.1f} us/fwd")

    # -- 3. Tensor-compressed transformer trains on TT cores --------------
    cfg = get_config("qwen3-8b").scaled_down().with_tt(mode="tt", rank=16,
                                                       embed_rank=16)
    params = init_params(jax.random.PRNGKey(2), cfg)
    print(f"[3] reduced qwen3, TT mode: {num_params(params):,} params")
    opt = sgd(1e-2)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    for i in range(10):
        batch = {k: jnp.asarray(v)
                 for k, v in lm_batch(0, i, 8, 64, cfg.vocab_size).items()}
        params, state, metrics = step(params, state, batch)
        if i in (0, 4, 9):
            print(f"    step {i}: loss {float(metrics['loss']):.4f}")
    assert np.isfinite(float(metrics["loss"]))
    print("quickstart OK")


if __name__ == "__main__":
    main()
