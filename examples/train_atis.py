"""End-to-end driver: the paper's experiment — tensor-compressed transformer
training on (synthetic) ATIS, with the full production substrate engaged:

  * paper model (Table II): 2-encoder, d=768, TT rank 12, TTM rank 30
  * SGD on TT cores (lr 4e-3, the paper's setting), batch configurable
  * deterministic seekable data, async atomic checkpoints, resume,
    straggler monitoring

This is the `(b) end-to-end driver` deliverable: a ~9M-param-class dense
model (36.9 MB, paper Table III) trained in its 1.2 MB tensor-compressed
form for a few hundred steps.  Use ``--scale-down`` for a quick CPU pass.

Run:  PYTHONPATH=src python examples/train_atis.py --steps 200 --scale-down
"""
import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.atis_transformer import config_n
from repro.data import AtisGrammar, atis_batch
from repro.launch.steps import _grads_at_rest
from repro.models import init_params, num_params, param_bytes
from repro.models.classifier import atis_heads_init, atis_loss, atis_metrics
from repro.optim import adamw, master_view, sgd, warmup_cosine
from repro.runtime import StragglerMonitor


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--encoders", type=int, default=2, choices=(2, 4, 6))
    ap.add_argument("--matrix", action="store_true",
                    help="uncompressed baseline (paper's MM rows)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale-down", action="store_true")
    ap.add_argument("--optimizer", choices=("sgd", "adamw"), default="sgd",
                    help="sgd is the paper's setting; adamw enables "
                         "--sketched-opt")
    ap.add_argument("--fused", action="store_true",
                    help="Pallas fused PU-stage kernel for the update")
    ap.add_argument("--sketched-opt", action="store_true",
                    help="AdamW with count-min/count-sketch moments "
                         "refreshed inside the fused PU kernel (implies "
                         "--optimizer adamw; dense m/v never exist in HBM; "
                         "falls back to dense fused AdamW when "
                         "sketch_pu_fits fails)")
    ap.add_argument("--sketch-width", type=int, default=None)
    ap.add_argument("--sketch-depth", type=int, default=None)
    ap.add_argument("--kernel-flow", action="store_true",
                    help="run TT linears through the fused Pallas kernels "
                         "(flow='kernel'; interpret mode off-TPU)")
    ap.add_argument("--fused-bwd", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="with --kernel-flow: fused single-kernel BWD stage "
                         "(--no-fused-bwd = operand-swap + XLA GEMMs; "
                         "unset keeps the config's fused_bwd)")
    ap.add_argument("--fused-attn", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="fused flash attention fwd + single-kernel bwd "
                         "(only (O, m, l) saved per encoder — no S×S "
                         "probabilities; --no-fused-attn = pure-JAX "
                         "blockwise path; unset keeps the config)")
    ap.add_argument("--fused-ffn", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="with --kernel-flow: fused FFN megakernel — both "
                         "TT linears + GELU in one Pallas kernel per "
                         "direction, (K, d_ff) hidden state VMEM-resident, "
                         "backward recomputes it from x (--no-fused-ffn = "
                         "two-call path; unset keeps the config)")
    ap.add_argument("--param-dtype", default=None,
                    choices=("float32", "bfloat16", "int8", "fp8_e4m3"),
                    help="at-rest storage for TT half-factors and the "
                         "fused-update master params (core.quant; fp8 is "
                         "emulated — tiles upcast to f32 in VMEM)")
    ap.add_argument("--act-dtype", default=None,
                    choices=("float32", "bfloat16", "int8", "fp8_e4m3"),
                    help="at-rest storage for the saved backward residuals")
    ap.add_argument("--grad-dtype", default=None,
                    choices=("float32", "bfloat16", "fp8_e5m2"),
                    help="gradient at-rest tier between BWD and PU")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--eval-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = config_n(args.encoders, tt_mode="off" if args.matrix else "tt")
    if args.kernel_flow:
        cfg = cfg.with_tt(flow="kernel")
    if args.fused_bwd is not None:
        cfg = cfg.with_tt(fused_bwd=args.fused_bwd)
    if args.fused_attn is not None:
        cfg = cfg.with_fused_attn(args.fused_attn)
    if args.fused_ffn is not None:
        cfg = cfg.with_fused_ffn(args.fused_ffn)
    if args.param_dtype or args.act_dtype or args.grad_dtype:
        cfg = cfg.with_precision(
            **{k: v for k, v in (("param_dtype", args.param_dtype),
                                 ("act_dtype", args.act_dtype),
                                 ("grad_dtype", args.grad_dtype)) if v})
    if args.scale_down:
        cfg = cfg.scaled_down(d_model=256, n_heads=4, d_ff=256,
                              vocab_size=1000, num_layers=args.encoders,
                              max_seq_len=64)
    lr = args.lr or (4e-3 if args.matrix else 4e-2)

    g = AtisGrammar(seed=args.seed)
    params = {"backbone": init_params(jax.random.PRNGKey(args.seed), cfg),
              "heads": atis_heads_init(jax.random.PRNGKey(args.seed + 1),
                                       cfg, 26, 120)}
    print(f"[atis] {args.encoders}-ENC {'matrix' if args.matrix else 'tensor'}: "
          f"{num_params(params):,} params ({param_bytes(params) / 1e6:.2f} MB)")

    lr_fn = warmup_cosine(lr, max(args.steps // 20, 1), args.steps)
    if args.sketched_opt or args.optimizer == "adamw":
        opt = adamw(lr_fn, fused=args.fused, sketched=args.sketched_opt,
                    sketch_width=args.sketch_width,
                    sketch_depth=args.sketch_depth,
                    param_format=cfg.tt.precision.param_dtype)
    else:
        opt = sgd(lr_fn, fused=args.fused)
    state = opt.init(params)
    # Quantized-master states own the only parameter copy; align step 1's
    # forward with the storage grid (identity for unquantized states).
    params = master_view(state, params)
    if "vs" in state:
        d, w = state["vs"].shape
        print(f"[atis] sketched AdamW: moments as 2x ({d}, {w}) sketches "
              f"({2 * d * w * 4 / 1e3:.1f} kB vs "
              f"{2 * num_params(params) * 4 / 1e6:.2f} MB dense)")

    # Donation lets XLA reuse the param/state memory across the step
    # (no-op on CPU, which cannot donate).
    @partial(jax.jit, donate_argnums=(0, 1))
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: atis_loss(p, cfg, batch))(params)
        grads = _grads_at_rest(grads, cfg)
        params, state = opt.update(grads, params, state, state["step"])
        return params, state, loss

    mgr = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None
    start = 0
    if mgr is not None:
        tmpl = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            (params, state))
        got = mgr.restore_latest(tmpl)
        if got is not None:
            (params, state), start = got
            params = jax.tree.map(jnp.asarray, params)
            state = jax.tree.map(jnp.asarray, state)
            print(f"[atis] resumed at step {start}")

    mon = StragglerMonitor()
    t_start = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in atis_batch(g, "train", i, args.batch).items()}
        t0 = time.time()
        params, state, loss = step(params, state, batch)
        loss = float(loss)
        mon.observe(time.time() - t0)
        if i % args.eval_every == 0 or i == args.steps - 1:
            test = {k: jnp.asarray(v)
                    for k, v in atis_batch(g, "test", 0, 256).items()}
            m = atis_metrics(params, cfg, test)
            print(f"[atis] step {i:5d} loss {loss:.4f} "
                  f"intent_acc {float(m['intent_acc']):.3f} "
                  f"slot_acc {float(m['slot_acc']):.3f}")
            if mgr is not None:
                mgr.save_async(i + 1, (params, state))
    if mgr is not None:
        mgr.wait()
    print(f"[atis] {args.steps - start} steps in {time.time() - t_start:.1f}s; "
          f"straggler flags: {mon.total_flags}")
    return params


if __name__ == "__main__":
    main()
