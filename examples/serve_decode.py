"""Batched serving example: prefill + decode across cache families.

Attention-family archs run the PAGED path (flash-decode Pallas kernel
against a paged KV cache, FIFO continuous batching, per-slot positions);
recurrent-state families (Mamba-2 SSD, RG-LRU hybrid) run the lockstep
dense-cache path.  Shows the serving path the ``decode_32k`` /
``long_500k`` dry-run cells lower, at CPU-friendly scale: reduced configs,
oversubscribed request queue, greedy + temperature sampling, tokens/s +
DECODE-ledger report.

Run:  PYTHONPATH=src python examples/serve_decode.py
      PYTHONPATH=src python examples/serve_decode.py --arch mamba2-130m --tt
"""
import argparse

from repro.launch.serve import main as serve_main


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="default: demo all three cache families")
    ap.add_argument("--tt", action="store_true")
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else [
        "llama3-8b",           # GQA KV cache -> paged continuous batching
        "mamba2-130m",         # SSD recurrent state (O(1) cache, dense path)
        "recurrentgemma-2b",   # hybrid: RG-LRU state + local-attn ring
    ]
    for arch in archs:
        print(f"=== {arch} ===")
        argv2 = ["--arch", arch, "--scale-down", "--batch", "4",
                 "--prompt-len", "48", "--gen", str(args.gen),
                 # oversubscribe the paged path: 4 requests, 2 slots
                 "--max-concurrency", "2", "--ledger"]
        if args.tt:
            # serve the flags the model trains with (PR 1-6 kernel stack)
            argv2 += ["--tt", "--kernel-flow"]
        serve_main(argv2)


if __name__ == "__main__":
    main()
