"""Batched serving example: prefill + KV/state-cache decode across model
families (attention KV cache, Mamba-2 SSD state, RG-LRU window+state).

Shows the serving path the ``decode_32k`` / ``long_500k`` dry-run cells
lower, at CPU-friendly scale: reduced configs, batch of concurrent
requests, greedy + temperature sampling, tokens/s report.

Run:  PYTHONPATH=src python examples/serve_decode.py
      PYTHONPATH=src python examples/serve_decode.py --arch mamba2-130m --tt
"""
import argparse

from repro.launch.serve import main as serve_main


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="default: demo all three cache families")
    ap.add_argument("--tt", action="store_true")
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else [
        "llama3-8b",           # GQA KV cache
        "mamba2-130m",         # SSD recurrent state (O(1) cache)
        "recurrentgemma-2b",   # hybrid: RG-LRU state + local-attn ring buffer
    ]
    for arch in archs:
        print(f"=== {arch} ===")
        argv2 = ["--arch", arch, "--scale-down", "--batch", "4",
                 "--prompt-len", "48", "--gen", str(args.gen)]
        if args.tt:
            argv2.append("--tt")
        serve_main(argv2)


if __name__ == "__main__":
    main()
