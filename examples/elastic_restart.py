"""Fault-tolerance showcase: train -> checkpoint -> RESHAPE THE CLUSTER ->
resume bit-exact on a different mesh.

Simulates the 1000-node reality where a pod is preempted mid-run: the job
restarts on a different topology, re-derives every sharding from the new
mesh, restores the checkpoint, and the deterministic seekable data pipeline
realigns to the exact batch stream — losses after the re-mesh continue the
same trajectory.

This example spawns itself (subprocess) with 8 placeholder devices so the
mesh change is real: phase A trains on (data=4, model=2), phase B resumes
the same run on (data=2, model=4).

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""
import json
import os
import subprocess
import sys
import tempfile

PHASE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import lm_batch
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import sgd
from repro.runtime import (batch_specs, named_sharding_tree, opt_state_specs,
                           param_specs)
from repro.core.meshctx import activation_mesh

data_ax, model_ax, start, steps, ckpt = (
    int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]),
    sys.argv[5])
cfg = get_config("qwen3-8b").scaled_down().with_tt(mode="tt", rank=8,
                                                   embed_rank=8)
mesh = jax.make_mesh((data_ax, model_ax), ("data", "model"))
opt = sgd(1e-2)
train_step = make_train_step(cfg, opt)

params = init_params(jax.random.PRNGKey(0), cfg)
opt_state = opt.init(params)
pspec = param_specs(cfg, params, mesh)
sspec = opt_state_specs(cfg, opt_state, pspec, mesh)
psh, ssh = named_sharding_tree(mesh, pspec), named_sharding_tree(mesh, sspec)

mgr = CheckpointManager(ckpt, keep=2)
tmpl = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                    (params, opt_state))
got = mgr.restore_latest(tmpl)
if got is not None:
    (params, opt_state), start_found = got
    assert start_found == start, (start_found, start)

# ELASTIC: device_put under the *current* mesh's freshly derived specs.
params = jax.tree.map(jax.device_put, params, psh)
opt_state = jax.tree.map(jax.device_put, opt_state, ssh)

sample = lm_batch(0, 0, 8, 64, cfg.vocab_size)
bsh = named_sharding_tree(mesh, batch_specs(sample, mesh))
with activation_mesh(mesh):
    step = jax.jit(train_step, in_shardings=(psh, ssh, bsh),
                   out_shardings=(psh, ssh, None), donate_argnums=(0, 1))
    losses = []
    for i in range(start, start + steps):
        batch = jax.tree.map(jax.device_put,
                             {k: jnp.asarray(v) for k, v in
                              lm_batch(0, i, 8, 64, cfg.vocab_size).items()},
                             bsh)
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
mgr.save_blocking(start + steps, (params, opt_state))
print("LOSSES", json.dumps(losses))
"""


def run_phase(data_ax, model_ax, start, steps, ckpt):
    env = {**os.environ,
           "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}
    r = subprocess.run(
        [sys.executable, "-c", PHASE, str(data_ax), str(model_ax),
         str(start), str(steps), ckpt],
        env=env, capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(r.stdout + r.stderr)
    line = [l for l in r.stdout.splitlines() if l.startswith("LOSSES")][0]
    return json.loads(line[len("LOSSES "):])


def main():
    with tempfile.TemporaryDirectory() as ckpt:
        print("[elastic] phase A: mesh (data=4, model=2), steps 0-10")
        la = run_phase(4, 2, 0, 10, ckpt)
        print(f"[elastic]   losses {la[0]:.4f} -> {la[-1]:.4f}")
        print("[elastic] phase B: RE-MESH to (data=2, model=4), resume at 10")
        lb = run_phase(2, 4, 10, 10, ckpt)
        print(f"[elastic]   losses {lb[0]:.4f} -> {lb[-1]:.4f}")
        assert lb[0] < la[0], "resumed run must continue, not restart"
        print("[elastic] OK: training continued across the topology change")


if __name__ == "__main__":
    main()
