"""Per-training-stage on-chip residency ledger (paper Sec. III-A / Table IV).

The paper's headline hardware claim is an *on-chip-memory-only framework for
each stage in training*: forward (FWD), backward (BWD), and parameter update
(PU) all run against a <6 MB BRAM + 22.5 MB URAM budget on the ZCU102.  This
module makes that claim *checkable in software*: for a model config it
builds, per stage, the list of buffers that must be live at once, maps each
onto the paper's two pools, and flags the peak against the budget envelope.

Pools (the TPU/VMEM analogue keeps the paper's split):

* ``bram`` — persistent, parameter-like residency: TT/TTM cores, biases,
  and optimizer moments.  The paper streams these from BRAM every cycle
  (Eqs. (22)-(25) size the blocks; ``cost_model.bram_blocks`` models them).
* ``uram`` — transient, stage-scoped residency: activations/residuals,
  gradients, and contraction intermediates.  These are the K-sized buffers
  the paper's URAM holds between stages.

Byte counts come from two places, both already validated elsewhere:

* exact pytree accounting (``jax.eval_shape`` over ``init_params`` /
  ``opt.init``) for parameters, moments, and gradients;
* the paper's closed forms in ``cost_model`` (Eq. (21) ``mem_btt``) for the
  contraction intermediates, evaluated over the actual ``TTSpec``s found in
  the parameter tree — so ledger totals agree with the cost model by
  construction (asserted in tests/test_fused_update.py).

Activation residuals are first-order: the fused BTT VJP saves only each
layer's *inputs* (see ``core.tt_linear._btt_fused_fwd``), so the ledger
counts one ``(K, N)`` input per TT linear plus the attention residuals —
the autodiff-saved S×S probabilities on the blockwise path, or only
``(O, m, l)`` per layer with ``cfg.fused_attn`` (the fused flash backward
recomputes probability tiles in VMEM; ``attn_residual_bytes`` is the single
source for both numbers, and the ledger gates on the same
``attn_bwd_vmem_fits`` the op dispatches on).  Shared inputs (Q/K/V
projections read the same ``x``) are counted once per projection — a
deliberate over-count, i.e. the "fits" verdict is conservative.

FFN blocks follow the same contract: with ``cfg.fused_ffn`` on the kernel
flow and the block passing ``models.layers.ffn_fused_eligible`` — the
EXACT predicate function ``mlp_apply``/``moe._expert_ffn_apply`` dispatch
on (all-TT, bias-free, no model-parallel mesh, VMEM fit at the launch's
own K) — the ledger drops the
down projection's ``(K, d_ff)`` saved input and the activation pre-images
(``ffn_hidden`` row) and instead reports the megakernel's tile-derived
working set (``ffn_kernel_vmem`` row) — FFN residuals are O(K·d_model),
never O(K·d_ff), exactly what the op actually saves.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .cost_model import mem_btt
from .tt import TTSpec
from .tt_linear import TTLinearParams
from .ttm_embedding import TTMEmbeddingParams

__all__ = [
    "BRAM_BUDGET_BYTES",
    "URAM_BUDGET_BYTES",
    "LedgerEntry",
    "StageLedger",
    "training_step_ledger",
    "pipeline_ledger_rows",
    "decode_step_ledger",
    "budget_report",
    "format_report",
    "ledger_rows",
    "decode_ledger_rows",
]

BRAM_BUDGET_BYTES = 6 * 2**20            # paper: <6 MB BRAM
URAM_BUDGET_BYTES = int(22.5 * 2**20)    # paper: 22.5 MB URAM
STAGES = ("FWD", "BWD", "PU")


@dataclasses.dataclass(frozen=True)
class LedgerEntry:
    name: str
    nbytes: int
    pool: str  # "bram" | "uram"
    note: str = ""


@dataclasses.dataclass(frozen=True)
class StageLedger:
    stage: str
    entries: tuple[LedgerEntry, ...]

    @property
    def total_bytes(self) -> int:
        return sum(e.nbytes for e in self.entries)

    def pool_bytes(self, pool: str) -> int:
        return sum(e.nbytes for e in self.entries if e.pool == pool)

    def entry(self, name: str) -> LedgerEntry:
        for e in self.entries:
            if e.name == name:
                return e
        raise KeyError(name)


# ---------------------------------------------------------------------------
# Pytree accounting helpers.
# ---------------------------------------------------------------------------


def _tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))


def _tree_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def _collect_modules(params) -> tuple[list[TTLinearParams], list[TTMEmbeddingParams]]:
    """All TT linear / TTM embedding modules in a parameter pytree (the
    dataclass nodes survive ``jax.eval_shape``; specs are static aux)."""
    tts: list[TTLinearParams] = []
    ttms: list[TTMEmbeddingParams] = []

    def visit(node):
        if isinstance(node, TTLinearParams):
            tts.append(node)
        elif isinstance(node, TTMEmbeddingParams):
            ttms.append(node)
        return node

    jax.tree.map(visit, params,
                 is_leaf=lambda n: isinstance(n, (TTLinearParams,
                                                  TTMEmbeddingParams)))
    return tts, ttms


def _stacked_multiplier(module) -> int:
    """Layer-stacked modules (vmapped cycles) carry a leading stack dim on
    every core; the spec describes ONE layer.  Infer the multiplier."""
    core = module.cores[0]
    spec_rank0 = module.spec.core_shapes()[0]
    return int(core.shape[0]) if len(core.shape) == len(spec_rank0) + 1 else 1


def _btt_kernel_vmem_bytes(spec: TTSpec, itemsize: int, K: int) -> int:
    """VMEM working set of one ``btt_linear_pallas`` grid step — the
    kernel's own tile chooser (with the step's actual K), so ledger and
    kernel cannot drift."""
    from repro.kernels.btt_linear import choose_tiles

    return choose_tiles(spec.out_dim, spec.mid_rank, itemsize, K=K)[4]


def _btt_bwd_kernel_vmem_bytes(spec: TTSpec, itemsize: int, K: int,
                               fused: bool) -> int:
    """VMEM working set of the BWD-stage launch for one layer — the fused
    ``btt_backward_pallas`` kernel's when ``cfg.tt.fused_bwd`` and it fits
    the budget (the path ``kernels.ops`` takes), else the operand-swap
    forward launch's.  Derived by the same chooser the kernel launches
    with, so ledger and tiles cannot drift (the FWD stage makes the
    identical promise)."""
    from repro.kernels.btt_backward import bwd_stage_vmem_bytes

    return bwd_stage_vmem_bytes(spec.out_dim, spec.in_dim, spec.mid_rank,
                                itemsize, K=K, fused=fused)


def _pu_kernel_vmem_bytes(n_params: int, n_bufs: int) -> int:
    """VMEM working set of one fused-update grid step: ``n_bufs`` blocks of
    (block_rows, lanes) f32 (params + grads + moments, outputs aliased)."""
    from repro.kernels.fused_update import pu_block_shape

    br, _, lanes = pu_block_shape(n_params)
    return n_bufs * br * lanes * 4


def _attn_kernel_vmem_bytes(cfg, seq: int, itemsize: int, stage: str) -> int:
    """VMEM working set of the attention-stage flash launch — derived from
    the BACKWARD kernel's own tile chooser (``choose_attn_tiles``), so
    ledger and launched tiles cannot drift; 0 when ``fused_attn`` is off or
    the shape falls back to the pure-JAX blockwise path."""
    from repro.kernels.flash_backward import attn_stage_vmem_bytes

    return attn_stage_vmem_bytes(seq, cfg.d_head, itemsize,
                                 stage=stage, fused=cfg.fused_attn)


# ---------------------------------------------------------------------------
# FFN blocks (structural walk: up/down[/gate] triples in mlp and MoE dicts).
# ---------------------------------------------------------------------------


def _collect_ffn_blocks(params) -> list[dict]:
    """Every FFN block in a parameter pytree: dicts holding an
    ``up``/``down`` (and optionally ``gate``) projection triple — plain
    MLPs, per-expert MoE stacks, and MoE shared experts alike."""
    blocks: list[dict] = []

    def walk(node):
        if isinstance(node, dict):
            if "up" in node and "down" in node:
                blocks.append(node)
                if isinstance(node.get("shared"), dict):
                    walk(node["shared"])
                return
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(params)
    return blocks


def _ffn_block_mult(m: TTLinearParams) -> int:
    """Stack multiplier of one FFN projection: the product of all leading
    dims beyond the spec's own core rank (cycle-stacked layers contribute
    one axis, vmapped MoE experts another)."""
    core = m.cores[0]
    base = len(m.spec.core_shapes()[0])
    return int(np.prod(core.shape[: len(core.shape) - base])) or 1


def _ffn_block_dims(blk: dict):
    """(M, N, F, R1, R2, Rg, gated, mult) for an all-TT block, else None."""
    up, down = blk["up"], blk["down"]
    gate = blk.get("gate")
    mods = (up, down) if gate is None else (up, down, gate)
    if not all(isinstance(m, TTLinearParams) for m in mods):
        return None
    return (down.spec.out_dim, up.spec.in_dim, up.spec.out_dim,
            up.spec.mid_rank, down.spec.mid_rank,
            gate.spec.mid_rank if gate is not None else 0,
            gate is not None, _ffn_block_mult(down))


# ---------------------------------------------------------------------------
# The ledger.
# ---------------------------------------------------------------------------


def training_step_ledger(cfg, optimizer: str = "sgd", *, momentum: float = 0.0,
                         batch: int = 1, seq: int = 32,
                         sketched: bool = False,
                         sketch_width: int | None = None,
                         sketch_depth: int | None = None,
                         partition=None) -> dict[str, StageLedger]:
    """Per-stage (FWD/BWD/PU) peak-residency ledgers for one training step.

    ``optimizer`` sizes the moment buffers: "sgd" (none, or one with
    ``momentum``) or "adamw" (two).  ``sketched=True`` (adamw only) charges
    the count-min/count-sketch moment state instead of the dense buffers —
    by CONSTRUCTION of the same ``optim.adamw(sketched=True)`` init the
    training step runs (the state layout from ``jax.eval_shape`` IS the
    dispatch decision, including the ``sketch_pu_fits`` fallback), so the
    ledger cannot drift from the op.  ``batch=1, seq=32`` is the paper's
    regime (Sec. VI).  Everything is derived from ``jax.eval_shape`` — no
    device memory is allocated.

    ``partition`` (optional ``runtime.pipeline.StagePartition``) reports
    PER-DEVICE residency for the pipeline × row-TP × DP training step:
    params/grads/moments stay whole (the tree replicates — it is MBs under
    TT compression), kernel-launch rows shrink to one microbatch's row
    shard (``ceil(batch / (dp·tp·microbatches)) · seq`` — the exact K the
    per-device dispatch predicates and tile choosers see inside shard_map),
    stacked-layer residuals scale by this stage's cycle fraction, and the
    GPipe handoff carries get their own uram row.  ``None`` is exactly the
    single-device ledger.
    """
    from repro.core import quant as _q
    from repro.models.transformer import init_params
    from repro.optim import adamw as _adamw, sgd as _sgd

    if partition is not None:
        from repro.runtime.pipeline import cycles_per_stage

        n_cycles = cfg.num_layers // max(len(cfg.hybrid_pattern), 1)
        stage_frac = cycles_per_stage(cfg, partition.stages) / n_cycles
        b_loc = -(-batch // (partition.dp * partition.tp))
        b_mb = -(-b_loc // partition.microbatches)
    else:
        stage_frac = 1.0
        b_loc = b_mb = batch
    # Two row counts: K is what one kernel LAUNCH sees (a single
    # microbatch's row shard — the dispatch predicates' argument); K_res is
    # what stays RESIDENT (at the GPipe peak every in-flight microbatch's
    # residuals are live, so residency uses the whole local batch).
    K = b_mb * seq
    K_res = b_loc * seq
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    prec = cfg.tt.precision
    param_fmt = prec.param_dtype
    act_fmt = prec.resolved_act(cfg.dtype)
    grad_fmt = prec.grad_dtype
    if optimizer == "adamw":
        opt = _adamw(1e-3, sketched=sketched, sketch_width=sketch_width,
                     sketch_depth=sketch_depth, param_format=param_fmt)
    else:
        opt = _sgd(1e-3, momentum)
    opt_state = jax.eval_shape(opt.init, params)

    # Two itemsizes per tier: compute (kernel-VMEM rows, contraction
    # transients — f32 accumulator chains regardless of storage) and
    # AT-REST storage (what HBM holds between stages: core.quant formats).
    act_itemsize = jnp.dtype(cfg.dtype).itemsize
    act_store = _q.itemsize(act_fmt)
    params_bytes = _tree_bytes(params)
    n_params = _tree_count(params)
    # Gradient at-rest tier between BWD and PU (steps._grads_at_rest).
    grads_bytes = n_params * _q.itemsize(grad_fmt)
    moments_bytes = _tree_bytes(opt_state) - 4  # minus the int32 step scalar
    # Quantized-master state: (pq, ps) ARE the parameters — split them out
    # of the moment accounting and charge them as the PU params row.
    if isinstance(opt_state, dict) and "pq" in opt_state:
        pu_params_bytes = (int(np.prod(opt_state["pq"].shape))
                           * jnp.dtype(opt_state["pq"].dtype).itemsize
                           + int(np.prod(opt_state["ps"].shape)) * 4)
        moments_bytes -= pu_params_bytes
        pu_params_note = (f"quantized master ({param_fmt} packed + "
                          "per-block f32 scales; SR re-round in-kernel)")
    else:
        pu_params_bytes = params_bytes
        pu_params_note = "updated in place"

    tts, ttms = _collect_modules(params)
    specs = [m.spec for m in tts]
    # FWD/BWD weight tier: half-factors at the param storage format (one
    # f32 scale per half-factor — each IS a single VMEM tile); identity
    # when param_dtype is the compute dtype.
    if param_fmt not in ("float32", cfg.dtype):
        fwd_params_bytes = _q.quantized_bytes(
            n_params, param_fmt, n_scales=2 * max(len(tts), 1))
        fwd_params_note = (f"TT cores + norms at rest in {param_fmt} "
                           "(kernels dequantize tiles in VMEM)")
    else:
        fwd_params_bytes = params_bytes
        fwd_params_note = "TT/TTM cores + biases + norms (eval_shape-exact)"

    # Contraction intermediates (paper Eq. (21)): layers run sequentially,
    # so the live set is the *largest* layer's, not the sum.
    tt_inter_peak = max(
        (mem_btt(s, K) * act_itemsize for s in specs), default=0)

    # FFN blocks: with cfg.fused_ffn on the kernel flow and the block
    # passing THE dispatch predicate itself — models.layers.
    # ffn_fused_eligible, the exact function mlp_apply/_expert_ffn_apply
    # gate on (all-TT, bias-free, no model-parallel mesh, megakernel
    # working set inside the VMEM budget) — the hidden state is recomputed
    # in VMEM, so the down projection's (K, d_ff) input and the activation
    # pre-images are never saved.  Otherwise the two-call path saves both.
    from repro.kernels.btt_ffn import (
        ffn_residual_bytes,
        ffn_stage_vmem_bytes,
    )
    from repro.models.layers import ffn_fused_eligible

    ffn_hidden_bytes = 0
    ffn_fwd_vmem = 0
    ffn_bwd_vmem = 0
    ffn_fused_any = False
    excluded_down_ids: set[int] = set()
    for blk in _collect_ffn_blocks(params):
        dims = _ffn_block_dims(blk)
        if dims is None:
            continue
        M_, N_, F_, R1, R2, Rg, gated, mult = dims
        # The row count the model actually dispatches with: MoE expert
        # blocks (the dict also carries the router) run per expert on the
        # capacity-dispatched (G*cap) tokens, not on batch*seq — the
        # predicate and tile chooser must see the launch's own K or the
        # ledger drifts from moe._expert_ffn_apply.
        if "router" in blk and cfg.moe is not None:
            cap = int(math.ceil(seq * cfg.moe.top_k / cfg.moe.num_experts
                                * cfg.moe.capacity_factor))
            k_blk = b_mb * cap
        else:
            k_blk = K
        # Same gate the model applies: fused_ffn refines the kernel flow
        # only, and the block must pass the dispatch predicate.
        fused_eff = (cfg.fused_ffn and cfg.tt.flow == "kernel"
                     and ffn_fused_eligible(blk["up"], blk["down"],
                                            blk.get("gate"), K=k_blk))
        if fused_eff:
            ffn_fused_any = True
            excluded_down_ids.add(id(blk["down"]))
            ffn_fwd_vmem = max(ffn_fwd_vmem, ffn_stage_vmem_bytes(
                M_, N_, F_, R1, R2, Rg, act_itemsize, K=k_blk,
                stage="FWD"))
            ffn_bwd_vmem = max(ffn_bwd_vmem, ffn_stage_vmem_bytes(
                M_, N_, F_, R1, R2, Rg, act_itemsize, K=k_blk,
                stage="BWD"))
        else:
            # Pre-activation residuals only: the down projection's saved
            # (K, F) input is charged by the per-TT-linear loop below (at
            # the ledger's K convention), so subtract its term from the
            # closed form to avoid counting it twice.  Residency counts the
            # whole local batch (K_res) and only this stage's share of
            # stacked layers.
            eff_mult = mult if mult == 1 else max(round(mult * stage_frac), 1)
            ffn_hidden_bytes += eff_mult * (
                ffn_residual_bytes(K_res, F_, act_store, gated=gated,
                                   fused=False)
                - K_res * F_ * act_store)

    # Residuals the fused VJP saves for BWD: one (K, N) input per TT-linear
    # application (stacked modules apply once per stacked layer).  Down
    # projections of megakernel-dispatched FFN blocks save NOTHING — their
    # input is the VMEM-recomputed hidden state.
    n_tt_apps = 0
    resid_bytes = 0
    for m in tts:
        if id(m) in excluded_down_ids:
            continue
        mult = _stacked_multiplier(m)
        # Stacked (layer-cycle) modules: this stage holds only its cycle
        # slice; top-level modules (head/intent) apply once per device.
        eff_mult = mult if mult == 1 else max(round(mult * stage_frac), 1)
        n_tt_apps += eff_mult
        resid_bytes += eff_mult * K_res * m.spec.in_dim * act_store
    # Attention residuals, per layer: the autodiff-saved (B, h, S, S)
    # probabilities on the blockwise path, or only (O, m, l) with
    # fused_attn — gated on the SAME attn_bwd_vmem_fits the op dispatches
    # on, so the ledger reports the path actually taken.
    from repro.kernels.flash_backward import (
        attn_bwd_vmem_fits,
        attn_residual_bytes,
    )

    n_layers = max(round(cfg.num_layers * stage_frac), 1)
    attn_fused_eff = cfg.fused_attn and attn_bwd_vmem_fits(
        seq, cfg.d_head, act_itemsize)
    attn_resid = n_layers * attn_residual_bytes(
        b_loc, cfg.n_heads, seq, cfg.d_head, act_store,
        fused=attn_fused_eff)
    attn_note = ("(O, m, l) per layer — flash bwd recomputes probability "
                 "tiles in VMEM; no S×S residual"
                 if attn_fused_eff else
                 "autodiff-saved S×S attention probabilities per layer")
    # Embedding output + positional sum, the first saved activation
    # (one per TTM/dense embedding module).  Under a pipeline partition
    # every stage embeds (uniform SPMD program), so the row stays whole.
    embed_act = max(len(ttms), 1) * K_res * cfg.d_model * act_store
    resid_total = resid_bytes + embed_act
    # GPipe handoff carries: the tick scan saves one (b_mb, seq, d_model)
    # boundary activation per tick for its backward.
    if partition is not None and partition.stages > 1:
        carry_bytes = (partition.ticks * b_mb * seq * cfg.d_model
                       * act_store)
        carry_note = (f"ppermute handoffs: {partition.ticks} tick(s) x "
                      f"({b_mb}, {seq}, {cfg.d_model}) saved for BWD")
    else:
        carry_bytes = 0
        carry_note = "no pipeline stages (single-stage schedule)"

    fwd_kernel_vmem = max(
        (_btt_kernel_vmem_bytes(s, act_itemsize, K) for s in specs),
        default=0)
    bwd_kernel_vmem = max(
        (_btt_bwd_kernel_vmem_bytes(s, act_itemsize, K, cfg.tt.fused_bwd)
         for s in specs),
        default=0)
    attn_fwd_vmem = _attn_kernel_vmem_bytes(cfg, seq, act_itemsize, "FWD")
    attn_bwd_vmem = _attn_kernel_vmem_bytes(cfg, seq, act_itemsize, "BWD")
    # Live VMEM blocks per fused_update grid step = the input buffer list
    # (outputs are aliased onto inputs): (p, g) / (p, mu, g) / (p, m, v, g).
    # On the sketched path the working set comes from the sketched kernel's
    # own residency helper instead (param + grad blocks + all six resident
    # (depth, width) sketch blocks) — gated on the state layout eval_shape
    # produced, i.e. the exact sketch_pu_fits verdict the op dispatches on.
    sketched_eff = isinstance(opt_state, dict) and "vs" in opt_state
    if sketched_eff:
        from repro.kernels.fused_update import sketch_pu_vmem_bytes

        s_depth, s_width = opt_state["vs"].shape
        pu_kernel_vmem = sketch_pu_vmem_bytes(
            n_params, s_width, s_depth, itemsize=act_itemsize)
        pu_vmem_note = (f"sketched_adamw_update: p+g blocks + 6 resident "
                        f"({s_depth}, {s_width}) sketch blocks")
        moments_note = (f"count-min/count-sketch moments "
                        f"({s_depth}x{s_width} x2, sketch_pu_fits-gated)")
    else:
        n_pu_bufs = {"sgd": 3 if momentum else 2, "adamw": 4}[optimizer]
        pu_kernel_vmem = _pu_kernel_vmem_bytes(n_params, n_pu_bufs)
        pu_vmem_note = f"fused_update: {n_pu_bufs} live blocks per grid step"
        moments_note = f"{optimizer} optimizer state (eval_shape-exact)"

    ffn_hidden_note = (
        "megakernel recomputes the hidden tile in VMEM — no pre-activation "
        "or hidden residual" if ffn_fused_any and ffn_hidden_bytes == 0 else
        "activation pre-images saved between the two-call FFN launches")
    fwd = StageLedger("FWD", (
        LedgerEntry("params", fwd_params_bytes, "bram", fwd_params_note),
        LedgerEntry("residuals", resid_total, "uram",
                    f"fused-VJP saved inputs ({n_tt_apps} TT apps) "
                    "+ embed"),
        LedgerEntry("attn_residuals", attn_resid, "uram", attn_note),
        LedgerEntry("ffn_hidden", ffn_hidden_bytes, "uram",
                    ffn_hidden_note),
        LedgerEntry("tt_intermediates", tt_inter_peak, "uram",
                    "paper Eq. (21) mem_btt, max over layers"),
        LedgerEntry("kernel_vmem", fwd_kernel_vmem, "uram",
                    "btt_linear_pallas working set, largest layer"),
        LedgerEntry("attn_kernel_vmem", attn_fwd_vmem, "uram",
                    "flash_attention_pallas working set (fused_attn)"
                    if attn_fused_eff else
                    "no flash launch (blockwise path)"),
        LedgerEntry("ffn_kernel_vmem", ffn_fwd_vmem, "uram",
                    "btt_ffn_pallas working set (choose_ffn_tiles-derived), "
                    "largest block" if ffn_fused_any else
                    "no megakernel launch (two-call path)"),
        LedgerEntry("pipeline_carries", carry_bytes, "uram", carry_note),
    ))
    grads_note = ("f32 accumulators" if grad_fmt == "float32" else
                  f"gradient at-rest tier in {grad_fmt} "
                  "(steps cast at the BWD->PU boundary)")
    bwd = StageLedger("BWD", (
        LedgerEntry("params", fwd_params_bytes, "bram",
                    "re-read for half-factor rebuild"),
        LedgerEntry("residuals", resid_total, "uram",
                    "consumed as BWD walks the graph"),
        LedgerEntry("attn_residuals", attn_resid, "uram", attn_note),
        LedgerEntry("ffn_hidden", ffn_hidden_bytes, "uram",
                    ffn_hidden_note),
        LedgerEntry("grads", grads_bytes, "uram", grads_note),
        LedgerEntry("tt_intermediates", tt_inter_peak, "uram",
                    "t = x @ B^T recomputed per layer (never stored)"),
        LedgerEntry("kernel_vmem", bwd_kernel_vmem, "uram",
                    ("btt_backward_pallas working set (gx/ga/gb one pass), "
                     "largest layer") if cfg.tt.fused_bwd else
                    "operand-swap btt_linear_pallas working set "
                    "(fused_bwd=False)"),
        LedgerEntry("attn_kernel_vmem", attn_bwd_vmem, "uram",
                    "flash_attention_bwd_pallas working set "
                    "(choose_attn_tiles-derived: dQ/dK/dV one pass)"
                    if attn_fused_eff else
                    "no flash launch (blockwise path)"),
        LedgerEntry("ffn_kernel_vmem", ffn_bwd_vmem, "uram",
                    "btt_ffn_bwd_pallas working set (hidden recomputed in "
                    "VMEM; gx + all half-factor grads one pass)"
                    if ffn_fused_any else
                    "no megakernel launch (two-call path)"),
        LedgerEntry("pipeline_carries", carry_bytes, "uram", carry_note),
    ))
    pu = StageLedger("PU", (
        LedgerEntry("params", pu_params_bytes, "bram", pu_params_note),
        LedgerEntry("moments", moments_bytes, "bram", moments_note),
        LedgerEntry("grads", grads_bytes, "uram", "consumed by the update"),
        LedgerEntry("kernel_vmem", pu_kernel_vmem, "uram", pu_vmem_note),
    ))
    return {"FWD": fwd, "BWD": bwd, "PU": pu}


def decode_step_ledger(cfg, *, batch: int = 1, max_len: int = 128,
                       page_size: int = 64,
                       fused: bool = True) -> StageLedger:
    """DECODE-stage peak residency for one continuous-batched serving step.

    Serving inverts the training split: weights stay the persistent (bram)
    pool exactly as in training, but the GROWING state is now the paged KV
    pool, sized by the same ``runtime.kv_cache`` layout the
    ``PagedDecodeEngine`` allocates (groups from the engine's own
    ``_layout``, page count from ``max_pages_per_request``) — ledger and
    allocator cannot drift.  Kernel-VMEM rows are gated on the SAME
    ``decode_*_vmem_fits`` predicates ``kernels.ops`` dispatches the decode
    specializations on.  Only attention-family configs page
    (``paged_supported``); others raise.
    """
    from repro.kernels.btt_ffn import decode_ffn_stage_vmem_bytes
    from repro.kernels.btt_linear import decode_linear_stage_vmem_bytes
    from repro.kernels.flash_decode import decode_attn_stage_vmem_bytes
    from repro.models.transformer import init_params
    from repro.runtime.decode_engine import _layout, paged_supported
    from repro.runtime.kv_cache import kv_pool_bytes, max_pages_per_request

    if not paged_supported(cfg):
        raise ValueError(f"decode ledger needs attention-family blocks, "
                         f"got {cfg.hybrid_pattern}")
    from repro.core import quant as _q

    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    act_itemsize = jnp.dtype(cfg.dtype).itemsize
    params_bytes = _tree_bytes(params)
    param_fmt = cfg.tt.precision.param_dtype
    if param_fmt not in ("float32", cfg.dtype):
        # Serving tier: weights at rest in the param format (the decode ops
        # round-trip through it — core.quant.cast_format).
        n_w = _tree_count(params)
        n_tt = len(_collect_modules(params)[0])
        params_bytes = _q.quantized_bytes(n_w, param_fmt,
                                          n_scales=2 * max(n_tt, 1))
        params_note = f"weights at rest in {param_fmt} (decode round-trips)"
    else:
        params_note = "TT/TTM cores + biases + norms (eval_shape-exact)"
    B = batch

    # Paged KV pools, one per window group — the engine's own layout.
    n_cycles, _, _, n_pat, n_tail, windows = _layout(cfg)
    kv_bytes = 0
    for gid, window in windows.items():
        n_layers = n_cycles * n_pat.get(gid, 0) + n_tail.get(gid, 0)
        np_max = max_pages_per_request(max_len, page_size, window)
        kv_bytes += kv_pool_bytes(n_layers, 1 + B * np_max, cfg.n_kv_heads,
                                  page_size, cfg.d_head, act_itemsize)

    # Transient per-step activations: residual stream + norm temp + the
    # q/k/v/attn-out columns of the live layer (layers run sequentially).
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    act_bytes = B * (3 * cfg.d_model + (2 * H + 2 * KV) * dh) * act_itemsize
    logits_bytes = B * cfg.vocab_padded * act_itemsize

    tts, _ = _collect_modules(params)
    lin_vmem = max(
        (decode_linear_stage_vmem_bytes(m.spec.out_dim, m.spec.mid_rank,
                                        act_itemsize, B=B, fused=fused)
         for m in tts), default=0)
    G = H // KV
    attn_vmem = decode_attn_stage_vmem_bytes(G, dh, page_size, act_itemsize,
                                             fused=fused)
    ffn_vmem = 0
    ffn_hidden = 0
    for blk in _collect_ffn_blocks(params):
        dims = _ffn_block_dims(blk)
        if dims is None or not (fused and cfg.fused_ffn
                                and cfg.tt.flow == "kernel"):
            F = (dims[2] if dims is not None
                 else getattr(cfg, "d_ff", cfg.d_model * 4))
            ffn_hidden = max(ffn_hidden, B * F * act_itemsize)
            continue
        M_, N_, F_, R1, R2, Rg, _, _ = dims
        v = decode_ffn_stage_vmem_bytes(M_, N_, F_, R1, R2, Rg,
                                        act_itemsize, B=B, fused=True)
        if v:
            ffn_vmem = max(ffn_vmem, v)
        else:
            ffn_hidden = max(ffn_hidden, B * F_ * act_itemsize)

    return StageLedger("DECODE", (
        LedgerEntry("params", params_bytes, "bram", params_note),
        LedgerEntry("kv_pages", kv_bytes, "uram",
                    f"paged KV pools ({len(windows)} group(s), "
                    f"page={page_size}, {B} slot(s), max_len={max_len})"),
        LedgerEntry("activations", act_bytes, "uram",
                    "residual stream + live layer's q/k/v/o columns"),
        LedgerEntry("logits", logits_bytes, "uram",
                    "one decode step's (B, Vp) logits"),
        LedgerEntry("attn_kernel_vmem", attn_vmem, "uram",
                    "flash_decode_pallas working set "
                    "(choose_decode_attn_tiles-derived)" if attn_vmem else
                    "no flash-decode launch (paged pure-JAX ref)"),
        LedgerEntry("kernel_vmem", lin_vmem, "uram",
                    "btt_linear_decode_pallas working set, largest layer"
                    if lin_vmem else "no decode TT-linear launch"),
        LedgerEntry("ffn_kernel_vmem", ffn_vmem, "uram",
                    "btt_ffn_decode_pallas working set "
                    "(choose_decode_ffn_tiles-derived)" if ffn_vmem else
                    "no decode megakernel launch"),
        LedgerEntry("ffn_hidden", ffn_hidden, "uram",
                    "two-call FFN hidden column (no megakernel)"
                    if ffn_hidden else
                    "hidden state VMEM-resident in the megakernel"),
    ))


def decode_ledger_rows(cfg, prefix: str, *, batch: int = 1,
                       max_len: int = 128, page_size: int = 64,
                       fused: bool = True) -> list[tuple[str, float, str]]:
    """Benchmark rows for one serving config: DECODE-stage MB + fits flag
    against the paper's envelope (bram = weights, uram = KV pages +
    transients) — shared by bench_decode and launch.serve."""
    led = decode_step_ledger(cfg, batch=batch, max_len=max_len,
                             page_size=page_size, fused=fused)
    mb = 1 / 2**20
    bram = led.pool_bytes("bram")
    uram = led.pool_bytes("uram")
    fits = bram <= BRAM_BUDGET_BYTES and uram <= URAM_BUDGET_BYTES
    return [
        (f"{prefix}/DECODE_mb", led.total_bytes * mb,
         f"bram {bram * mb:.3f} MB + uram {uram * mb:.3f} MB"),
        (f"{prefix}/fits", 1.0 if fits else 0.0,
         f"peak bram {bram * mb:.2f}/6.0 MB; uram {uram * mb:.2f}/22.5 MB; "
         f"batch={batch} max_len={max_len} page={page_size}"),
    ]


def budget_report(ledgers: dict[str, StageLedger]) -> dict[str, Any]:
    """Peak per-pool residency across stages vs the paper's envelope."""
    bram_peak = max(ledgers[s].pool_bytes("bram") for s in STAGES)
    uram_peak = max(ledgers[s].pool_bytes("uram") for s in STAGES)
    return {
        "bram_peak_bytes": bram_peak,
        "uram_peak_bytes": uram_peak,
        "bram_budget_bytes": BRAM_BUDGET_BYTES,
        "uram_budget_bytes": URAM_BUDGET_BYTES,
        "fits_bram": bram_peak <= BRAM_BUDGET_BYTES,
        "fits_uram": uram_peak <= URAM_BUDGET_BYTES,
        "fits": (bram_peak <= BRAM_BUDGET_BYTES
                 and uram_peak <= URAM_BUDGET_BYTES),
        "peak_stage_bytes": {s: ledgers[s].total_bytes for s in STAGES},
    }


def ledger_rows(cfg, optimizer: str, prefix: str, *, momentum: float = 0.0,
                sketched: bool = False, batch: int = 1, seq: int = 32,
                partition=None,
                fits_note: str = "") -> list[tuple[str, float, str]]:
    """Benchmark rows for one config: per-stage MB + a fits flag.

    Shared by bench_memory and bench_pu so the emitted names/notes cannot
    diverge.  Notes are CSV-safe ("; "-separated — benchmarks.run emits
    bare 3-column ``name,value,note`` lines).  With ``partition`` the rows
    are PER-DEVICE (see ``training_step_ledger``).
    """
    led = training_step_ledger(cfg, optimizer, momentum=momentum,
                               sketched=sketched, batch=batch, seq=seq,
                               partition=partition)
    rep = budget_report(led)
    mb = 1 / 2**20
    out: list[tuple[str, float, str]] = []
    for stage in STAGES:
        out.append((
            f"{prefix}/{stage}_mb", led[stage].total_bytes * mb,
            f"bram {led[stage].pool_bytes('bram') * mb:.3f} MB + "
            f"uram {led[stage].pool_bytes('uram') * mb:.3f} MB"))
    note = (f"peak bram {rep['bram_peak_bytes'] * mb:.2f}/6.0 MB; "
            f"uram {rep['uram_peak_bytes'] * mb:.2f}/22.5 MB")
    if fits_note:
        note += f"; {fits_note}"
    out.append((f"{prefix}/fits", 1.0 if rep["fits"] else 0.0, note))
    return out


def pipeline_ledger_rows(cfg, partition, optimizer: str, prefix: str, *,
                         momentum: float = 0.0, sketched: bool = False,
                         batch: int | None = None,
                         seq: int = 32) -> list[tuple[str, float, str]]:
    """Per-device ledger rows for one pipeline × row-TP × DP partition.

    ``batch`` defaults to one row per (dp × tp × microbatch) slot — the
    smallest batch the partition can run — matching the paper's batch=1
    single-device regime scaled to the mesh.  Shared by bench_training's
    ``--devices`` mode and tests/test_pipeline.py.
    """
    if batch is None:
        batch = partition.dp * partition.tp * partition.microbatches
    return ledger_rows(
        cfg, optimizer, prefix, momentum=momentum, sketched=sketched,
        batch=batch, seq=seq, partition=partition,
        fits_note=(f"per-device: stages={partition.stages} "
                   f"dp={partition.dp} tp={partition.tp} "
                   f"mb={partition.microbatches} batch={batch} seq={seq}"))


def format_report(ledgers: dict[str, StageLedger]) -> str:
    """Human-readable ledger table (used by benchmarks and docs examples)."""
    rep = budget_report(ledgers)
    mb = 1 / 2**20
    lines = []
    for s in STAGES:
        led = ledgers[s]
        lines.append(f"{s}: {led.total_bytes * mb:.3f} MB "
                     f"(bram {led.pool_bytes('bram') * mb:.3f}, "
                     f"uram {led.pool_bytes('uram') * mb:.3f})")
        for e in led.entries:
            lines.append(f"    {e.name:<18} {e.nbytes * mb:8.3f} MB "
                         f"[{e.pool}]  {e.note}")
    lines.append(
        f"peak: bram {rep['bram_peak_bytes'] * mb:.3f}/"
        f"{rep['bram_budget_bytes'] * mb:.1f} MB "
        f"({'OK' if rep['fits_bram'] else 'OVER'}), "
        f"uram {rep['uram_peak_bytes'] * mb:.3f}/"
        f"{rep['uram_budget_bytes'] * mb:.1f} MB "
        f"({'OK' if rep['fits_uram'] else 'OVER'})")
    return "\n".join(lines)
