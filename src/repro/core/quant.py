"""Quantized-at-rest storage tier: int8 / fp8 formats, per-tensor scales,
and deterministic counter-based stochastic rounding.

The paper trains at FP32; its direct ancestor (arXiv 2104.03420, "A
Low-Precision Tensor Method") shows tensorized training survives
low-bitwidth storage because every contraction keeps a high-precision
accumulator chain.  This module is the substrate for that tier here:

* **Formats** — ``int8`` (symmetric, per-tensor max-abs scale, qmax 127),
  ``fp8_e4m3`` (weights; qmax 448) and ``fp8_e5m2`` (gradients; qmax
  57344) via the native JAX fp8 dtypes, plus the cast-only ``bfloat16``
  and identity ``float32``.  fp8 matmuls are *emulated*: kernels upcast
  the stored tiles to f32 in VMEM before the MXU dot — the contract the
  fused kernels implement ("dequantize weight tiles into VMEM registers,
  keep f32 accumulator chains").

* **Quantize/dequantize** — ``quantize`` is round-to-nearest (used at the
  custom-VJP boundaries, where determinism against the oracle matters);
  stochastic rounding is reserved for the parameter update, where the
  rounding bias would otherwise accumulate step over step.

* **Stochastic rounding** — counter-based (a splitmix/xxhash-style integer
  mix of ``(element index, step, block id)``), NOT a stateful PRNG: the
  same (step, block) always produces the same rounding decisions, so a
  training run resumed from a checkpoint replays bit-identical updates.
  The same helper runs inside Pallas kernel bodies (interpret mode
  included) and on the host, which is what the unbiasedness/determinism
  property tests exercise.

Scale granularity: per-tensor for the half-factors (each half-factor IS a
single VMEM-resident tile in the fused kernels, so per-tensor == per-tile
there) and per-packed-block for the fused-update master parameters (one
f32 scale per ``(BLOCK_ROWS, LANES)`` tile of the packed PU layout).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "QuantFormat", "FORMATS", "HAVE_FP8",
    "resolve", "itemsize", "needs_scale", "storage_dtype", "qmax",
    "quantize", "dequantize", "cast_format", "lost_fraction",
    "counter_bits", "counter_uniform", "stochastic_round",
    "quantized_bytes",
]

# fp8 dtypes ship with jax's ml_dtypes dependency; gate anyway so the
# module degrades to int8-only on builds without them (no new installs).
HAVE_FP8 = hasattr(jnp, "float8_e4m3fn") and hasattr(jnp, "float8_e5m2")


@dataclasses.dataclass(frozen=True)
class QuantFormat:
    name: str
    itemsize: int
    qmax: float | None      # None = cast-only (no scale)
    dtype_name: str         # attribute on jnp

    @property
    def dtype(self):
        return getattr(jnp, self.dtype_name)

    @property
    def needs_scale(self) -> bool:
        return self.qmax is not None


FORMATS: dict[str, QuantFormat] = {
    "float32": QuantFormat("float32", 4, None, "float32"),
    "bfloat16": QuantFormat("bfloat16", 2, None, "bfloat16"),
    "int8": QuantFormat("int8", 1, 127.0, "int8"),
}
if HAVE_FP8:
    FORMATS["fp8_e4m3"] = QuantFormat("fp8_e4m3", 1, 448.0, "float8_e4m3fn")
    FORMATS["fp8_e5m2"] = QuantFormat("fp8_e5m2", 1, 57344.0, "float8_e5m2")


def resolve(fmt: str) -> QuantFormat:
    if fmt not in FORMATS:
        known = sorted(FORMATS)
        hint = ("" if HAVE_FP8 else
                " (fp8 formats unavailable: this jax lacks fp8 dtypes)")
        raise ValueError(f"unknown precision format {fmt!r}; known "
                         f"{known}{hint}")
    return FORMATS[fmt]


def itemsize(fmt: str) -> int:
    return resolve(fmt).itemsize


def needs_scale(fmt: str) -> bool:
    return resolve(fmt).needs_scale


def storage_dtype(fmt: str):
    return resolve(fmt).dtype


def qmax(fmt: str) -> float:
    q = resolve(fmt).qmax
    if q is None:
        raise ValueError(f"{fmt} is cast-only; it has no quantization range")
    return q


# ---------------------------------------------------------------------------
# Per-tensor quantize / dequantize (round-to-nearest; VJP-boundary path).
# ---------------------------------------------------------------------------

_TINY = 1e-30  # scale floor: all-zero tensors quantize to zeros at scale 1/qmax


def quantize(x: jax.Array, fmt: str) -> tuple[jax.Array, jax.Array]:
    """``x -> (q, scale)`` with symmetric per-tensor max-abs scaling.

    ``scale`` is a () f32 array such that ``q * scale ~= x``; cast-only
    formats return ``scale = 1``.  int8 rounds to nearest (ties away from
    zero, ``jnp.round``); fp8 uses the dtype's native nearest conversion.
    """
    f = resolve(fmt)
    if not f.needs_scale:
        return x.astype(f.dtype), jnp.float32(1.0)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = (jnp.maximum(amax, _TINY) / f.qmax).astype(jnp.float32)
    z = x.astype(jnp.float32) / scale
    if f.name == "int8":
        q = jnp.clip(jnp.round(z), -f.qmax, f.qmax).astype(jnp.int8)
    else:
        q = jnp.clip(z, -f.qmax, f.qmax).astype(f.dtype)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array,
               dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def cast_format(x: jax.Array, fmt: str) -> jax.Array:
    """Round-trip ``x`` through the at-rest storage format (cast-only
    formats: one cast down and back).  Used for gradient at-rest storage,
    where fp8_e5m2's wide exponent makes it self-describing (no scale)."""
    f = resolve(fmt)
    if f.name == "float32":
        return x
    if f.needs_scale:
        q, s = quantize(x, fmt)
        return dequantize(q, s, x.dtype)
    return x.astype(f.dtype).astype(x.dtype)


def lost_fraction(x: jax.Array, roundtripped: jax.Array) -> jax.Array:
    """Fraction of nonzero elements of ``x`` that the at-rest round trip
    mapped to exactly zero — the quant-saturation sentinel.

    Per-tensor max-abs scaling means no element ever literally clips at
    qmax (the scale is defined by the max); the real failure mode of a
    scaled format is the dual: one outlier inflates ``amax`` until the
    bulk of the tensor underflows the storage grid and rounds to 0.  A
    gradient tensor whose mass vanishes this way contributes nothing to
    the update — ``runtime.guard`` watches this fraction and escalates
    the grad tier (fp8_e5m2 -> bf16) before training silently stalls.
    Returns a () f32 in [0, 1].
    """
    nz = x != 0
    lost = nz & (roundtripped == 0)
    return (jnp.sum(lost).astype(jnp.float32)
            / jnp.maximum(jnp.sum(nz), 1).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Counter-based stochastic rounding (deterministic in (idx, step, block)).
# ---------------------------------------------------------------------------

_M1 = np.uint32(2654435761)   # Knuth multiplicative hash
_M2 = np.uint32(2246822519)   # xxhash PRIME32_2
_M3 = np.uint32(3266489917)   # xxhash PRIME32_3


def counter_bits(idx: jax.Array, step, block) -> jax.Array:
    """uint32 hash of ``(element index, step, block id)`` — the stochastic
    rounding noise source.  Pure integer arithmetic (wrapping uint32), so
    it evaluates identically inside a Pallas kernel body, under interpret
    mode, and on the host; and it is a pure function of its arguments, so
    updates replay bit-identically across checkpoint resume."""
    step = jnp.asarray(step).astype(jnp.uint32)
    block = jnp.asarray(block).astype(jnp.uint32)
    x = idx.astype(jnp.uint32) * _M1
    x = x ^ (step * _M2) ^ (block * _M3)
    x = x ^ (x >> 15)
    x = x * _M2
    x = x ^ (x >> 13)
    x = x * _M3
    x = x ^ (x >> 16)
    return x


def counter_uniform(idx: jax.Array, step, block) -> jax.Array:
    """f32 uniforms in [0, 1) from the counter hash (top 24 bits)."""
    bits = counter_bits(idx, step, block)
    return (bits >> np.uint32(8)).astype(jnp.float32) * np.float32(2.0**-24)


# f32 mantissa bits to drop when truncating to each fp8 format's grid.
_FP8_DROP = {"fp8_e4m3": 20, "fp8_e5m2": 21}


def stochastic_round(z: jax.Array, fmt: str, step, block) -> jax.Array:
    """Stochastically round ``z`` (already divided by its scale, so
    ``|z| <= qmax``) onto the storage grid of ``fmt``.

    int8: ``floor(z + u)`` with u ~ U[0,1) — the classic unbiased SR.
    fp8:  add the uniform's bits below the kept mantissa and truncate
          (bit-pattern monotonicity makes carry propagation into the
          exponent do the right thing for normal floats), then cast.
    Both are deterministic in ``(element index, step, block)``.
    """
    f = resolve(fmt)
    if not f.needs_scale:
        raise ValueError(f"stochastic_round targets a scaled format, "
                         f"not {fmt}")
    if z.ndim == 2:
        # The kernel-body case: row-major flat index from 2-D iotas (TPU
        # has no 1-D iota).
        r, c = z.shape
        idx = (jax.lax.broadcasted_iota(jnp.int32, (r, c), 0) * c
               + jax.lax.broadcasted_iota(jnp.int32, (r, c), 1))
    else:
        n = max(int(np.prod(z.shape)), 1) if z.ndim else 1
        idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1).reshape(z.shape)
    bits = counter_bits(idx, step, block)
    z = jnp.clip(z.astype(jnp.float32), -f.qmax, f.qmax)
    if f.name == "int8":
        u = (bits >> np.uint32(8)).astype(jnp.float32) * np.float32(2.0**-24)
        return jnp.clip(jnp.floor(z + u), -f.qmax, f.qmax).astype(jnp.int8)
    drop = _FP8_DROP[f.name]
    mask = np.uint32((1 << drop) - 1)
    zb = jax.lax.bitcast_convert_type(z, jnp.uint32)
    zb = (zb + (bits & mask)) & ~mask
    zr = jax.lax.bitcast_convert_type(zb, jnp.float32)
    return jnp.clip(zr, -f.qmax, f.qmax).astype(f.dtype)


# ---------------------------------------------------------------------------
# At-rest byte accounting (ledger/cost-model hook).
# ---------------------------------------------------------------------------


def quantized_bytes(n_elems: int, fmt: str, *, n_scales: int = 1) -> int:
    """Bytes ``n_elems`` occupy at rest in ``fmt``, including the f32
    scale sidecar for scaled formats (``n_scales`` = per-tensor count or
    per-block count for the packed PU layout)."""
    f = resolve(fmt)
    extra = 4 * n_scales if f.needs_scale else 0
    return n_elems * f.itemsize + extra
