"""Tensor-train (TT) and tensor-train-matrix (TTM) parameter structures.

This module implements the paper's parameterizations (Sec. II-B/II-C):

* A weight matrix ``W (M, N)`` with ``M = prod(m_i)``, ``N = prod(n_i)`` is
  stored as ``2d`` TT cores ``G_k``:
      ``G_k in (r_{k-1}, m_k, r_k)`` for ``k in [1, d]``  (output side)
      ``G_{d+k} in (r_{d+k-1}, n_k, r_{d+k})``            (input side)
  with ``r_0 = r_{2d} = 1`` (paper Eq. (7)).

* An embedding table ``E (V, H)`` is stored as ``d`` TTM cores
  ``F_k in (r_{k-1}, v_k, h_k, r_k)`` (paper Eq. (8)).

Cores are plain ``jnp`` arrays inside dataclass pytrees so they are directly
shardable/optimizable. All shape metadata lives in static (hashable) spec
dataclasses, keeping jit caches clean.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "TTSpec",
    "TTMSpec",
    "factorize",
    "tt_init",
    "ttm_init",
    "tt_reconstruct",
    "ttm_reconstruct",
    "tt_half_factors",
    "tt_params_count",
    "ttm_params_count",
]


def factorize(n: int, d: int, max_pad: int = 4096) -> tuple[tuple[int, ...], int]:
    """Find a balanced ``d``-way factorization of the smallest ``n' >= n``.

    Returns ``(factors, n_padded)`` with ``prod(factors) == n_padded`` and the
    factors as equal as possible (best for TT compression: cost scales with
    ``max_i f_i``).  Used to tensorize arbitrary model dims (4096 -> 16,16,16;
    50280 -> padded 50400 -> (35, 36, 40), ...).
    """
    if d <= 0:
        raise ValueError(f"d must be positive, got {d}")
    if n <= 0:
        raise ValueError(f"n must be positive, got {n}")
    if d == 1:
        return (n,), n

    def best_factorization(m: int) -> tuple[int, ...] | None:
        # Greedy-balanced exact factorization via DFS on the divisor lattice.
        target = m ** (1.0 / d)
        best: list[tuple[float, tuple[int, ...]]] = []

        def dfs(remaining: int, k: int, acc: tuple[int, ...], lo: int) -> None:
            if k == 1:
                if remaining >= lo:
                    fac = acc + (remaining,)
                    spread = max(fac) / max(min(fac), 1)
                    best.append((spread, fac))
                return
            f = lo
            # factors ascending to dedupe permutations
            while f ** k <= remaining:
                if remaining % f == 0:
                    dfs(remaining // f, k - 1, acc + (f,), f)
                f += 1

        dfs(m, d, (), 2)
        if not best:
            return None
        best.sort(key=lambda t: (t[0], t[1]))
        _ = target  # balance is captured by spread
        return best[0][1]

    for pad in range(0, max_pad + 1):
        fac = best_factorization(n + pad)
        if fac is not None and max(fac) / min(fac) <= 8.0:
            return tuple(sorted(fac, reverse=True)), n + pad
    # Fall back: accept any exact factorization within the pad budget.
    for pad in range(0, max_pad + 1):
        fac = best_factorization(n + pad)
        if fac is not None:
            return tuple(sorted(fac, reverse=True)), n + pad
    raise ValueError(f"could not factorize {n} into {d} factors within pad {max_pad}")


@dataclasses.dataclass(frozen=True)
class TTSpec:
    """Static description of a TT-factorized matrix ``W (M, N) = out x in``.

    ``clamp_ranks=True`` (default) clamps interior ranks to the dense
    boundary (no wasted parameters at chain ends).  The paper's formulas and
    its ATIS model use UNIFORM interior ranks (G_1 is (1, 8, 12) even though
    12 > 8) — set ``clamp_ranks=False`` for paper-exact cost accounting.
    """

    out_factors: tuple[int, ...]  # (m_1, ..., m_d)
    in_factors: tuple[int, ...]  # (n_1, ..., n_d)
    rank: int  # uniform internal TT rank r
    clamp_ranks: bool = True

    @property
    def d(self) -> int:
        return len(self.out_factors)

    @property
    def out_dim(self) -> int:
        return int(np.prod(self.out_factors))

    @property
    def in_dim(self) -> int:
        return int(np.prod(self.in_factors))

    @property
    def ranks(self) -> tuple[int, ...]:
        """Full rank tuple (r_0, ..., r_{2d})."""
        dims = list(self.out_factors) + list(self.in_factors)
        n = len(dims)
        rs = [1] * (n + 1)
        for k in range(1, n):
            if self.clamp_ranks:
                left = int(np.prod(dims[:k]))
                right = int(np.prod(dims[k:]))
                rs[k] = min(self.rank, left, right)
            else:
                rs[k] = self.rank
        return tuple(rs)

    def core_shapes(self) -> tuple[tuple[int, int, int], ...]:
        dims = list(self.out_factors) + list(self.in_factors)
        rs = self.ranks
        return tuple((rs[k], dims[k], rs[k + 1]) for k in range(len(dims)))

    @property
    def mid_rank(self) -> int:
        """The rank r_d connecting the output-side and input-side chains."""
        return self.ranks[self.d]

    @classmethod
    def from_dims(cls, out_dim: int, in_dim: int, d: int, rank: int) -> "TTSpec":
        mf, mp = factorize(out_dim, d)
        nf, npad = factorize(in_dim, d)
        if mp != out_dim or npad != in_dim:
            raise ValueError(
                f"dims ({out_dim},{in_dim}) need padding to ({mp},{npad}); "
                "pad at the model level before building a TTSpec"
            )
        return cls(out_factors=mf, in_factors=nf, rank=rank)


@dataclasses.dataclass(frozen=True)
class TTMSpec:
    """Static description of a TTM-factorized table ``E (V, H)``."""

    vocab_factors: tuple[int, ...]  # (v_1, ..., v_d)
    hidden_factors: tuple[int, ...]  # (h_1, ..., h_d)
    rank: int

    @property
    def d(self) -> int:
        return len(self.vocab_factors)

    @property
    def vocab_dim(self) -> int:
        return int(np.prod(self.vocab_factors))

    @property
    def hidden_dim(self) -> int:
        return int(np.prod(self.hidden_factors))

    @property
    def ranks(self) -> tuple[int, ...]:
        d = self.d
        rs = [1] * (d + 1)
        for k in range(1, d):
            left = int(np.prod([v * h for v, h in zip(self.vocab_factors[:k], self.hidden_factors[:k])]))
            right = int(np.prod([v * h for v, h in zip(self.vocab_factors[k:], self.hidden_factors[k:])]))
            rs[k] = min(self.rank, left, right)
        return tuple(rs)

    def core_shapes(self) -> tuple[tuple[int, int, int, int], ...]:
        rs = self.ranks
        return tuple(
            (rs[k], self.vocab_factors[k], self.hidden_factors[k], rs[k + 1])
            for k in range(self.d)
        )


def _chain_variance_std(shapes: Sequence[tuple[int, ...]], contracted: Sequence[int],
                        target_std: float) -> float:
    """Per-core std so the reconstructed chain has ``target_std``.

    For a chain product of independent zero-mean cores, the element variance of
    the result is ``prod(core_var) * prod(contracted_dims)``.  Solving for a
    uniform per-core std ``s``:  ``s = (target_std^2 / prod(contracted)) ^ (1/(2n))``.
    """
    n = len(shapes)
    contracted_prod = float(np.prod([max(c, 1) for c in contracted])) if contracted else 1.0
    var = (target_std**2) / contracted_prod
    return float(var ** (1.0 / (2 * n)))


def tt_init(key: jax.Array, spec: TTSpec, dtype=jnp.float32,
            target_std: float | None = None) -> list[jax.Array]:
    """Initialize TT cores so ``reconstruct(cores)`` ~ Glorot-normal W."""
    if target_std is None:
        target_std = math.sqrt(2.0 / (spec.in_dim + spec.out_dim))
    shapes = spec.core_shapes()
    contracted = list(spec.ranks[1:-1])
    s = _chain_variance_std(shapes, contracted, target_std)
    keys = jax.random.split(key, len(shapes))
    return [jax.random.normal(k, sh, dtype) * jnp.asarray(s, dtype) for k, sh in zip(keys, shapes)]


def ttm_init(key: jax.Array, spec: TTMSpec, dtype=jnp.float32,
             target_std: float = 0.02) -> list[jax.Array]:
    shapes = spec.core_shapes()
    contracted = list(spec.ranks[1:-1])
    s = _chain_variance_std(shapes, contracted, target_std)
    keys = jax.random.split(key, len(shapes))
    return [jax.random.normal(k, sh, dtype) * jnp.asarray(s, dtype) for k, sh in zip(keys, shapes)]


def tt_half_factors(cores: Sequence[jax.Array], spec: TTSpec) -> tuple[jax.Array, jax.Array]:
    """Build the two BTT half-factors (paper Sec. IV-B, Fig. 5 bottom).

    Returns ``A (M, r_d)`` (contraction of output-side cores ``G_1..G_d``) and
    ``B (r_d, N)`` (contraction of input-side cores ``G_{d+1}..G_{2d}``).
    These builds are K-independent: their cost does not scale with batchxseq.

    Both chains are built from their boundary (rank-1) ends inward toward the
    middle rank — the order implied by paper Eq. (20): no build step carries
    ``r_d`` until the chain reaches it, which is what makes the build terms
    rank-quadratic rather than rank-cubic.
    """
    d = spec.d
    out_cores, in_cores = cores[:d], cores[d:]
    # A: chain G_1 (1, m_1, r_1) -> G_2 -> ... -> (M, r_d); boundary r_0 = 1.
    a = out_cores[0].reshape(out_cores[0].shape[1], out_cores[0].shape[2])
    for g in out_cores[1:]:
        # (M_part, r) x (r, m_k, r') -> (M_part * m_k, r')
        a = jnp.einsum("pr,rms->pms", a, g, optimize=True)
        a = a.reshape(a.shape[0] * a.shape[1], a.shape[2])
    # B: chain G_{2d} (r_{2d-1}, n_d, 1) <- ... <- G_{d+1} -> (r_d, N);
    # boundary r_{2d} = 1, iterating right-to-left.
    last = in_cores[-1]
    acc = last.reshape(last.shape[0], last.shape[1] * last.shape[2])  # (r, n_d)
    for g in in_cores[-2::-1]:
        # (r, n_k, r') x (r', N_tail) -> (r, n_k * N_tail)
        acc = jnp.einsum("rns,st->rnt", g, acc, optimize=True)
        acc = acc.reshape(acc.shape[0], acc.shape[1] * acc.shape[2])
    return a, acc


def tt_reconstruct(cores: Sequence[jax.Array], spec: TTSpec) -> jax.Array:
    """Dense ``W (M, N)`` from TT cores (test oracle; never used at scale)."""
    a, b = tt_half_factors(cores, spec)
    return a @ b


def ttm_reconstruct(cores: Sequence[jax.Array], spec: TTMSpec) -> jax.Array:
    """Dense ``E (V, H)`` from TTM cores (test oracle)."""
    acc = cores[0]  # (1, v1, h1, r1)
    acc = acc.reshape(acc.shape[1], acc.shape[2], acc.shape[3])  # (v, h, r)
    for f in cores[1:]:
        # (V_p, H_p, r) x (r, v_k, h_k, r') -> (V_p*v_k, H_p*h_k, r')
        acc = jnp.einsum("vhr,rwgs->vwhgs", acc, f, optimize=True)
        acc = acc.reshape(acc.shape[0] * acc.shape[1], acc.shape[2] * acc.shape[3], acc.shape[4])
    return acc.reshape(acc.shape[0], acc.shape[1])


def tt_params_count(spec: TTSpec) -> int:
    return int(sum(np.prod(s) for s in spec.core_shapes()))


def ttm_params_count(spec: TTMSpec) -> int:
    return int(sum(np.prod(s) for s in spec.core_shapes()))
