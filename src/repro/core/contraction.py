"""Tensor-network contraction flows for TT linear layers and TTM embeddings.

Implements the paper's two contraction orders for a TT-format linear layer
``y = W x`` (Sec. IV):

* ``tt_forward_rl``  — the *right-to-left* sequential flow used by prior
  inference accelerators (TIE, ETTE).  Every one of the ``2d`` steps carries
  the activation dimension ``K = batch*seq`` (paper Eq. (18)/(19)).
* ``tt_forward_btt`` — the paper's *bidirectional* flow: input-side and
  output-side cores are contracted toward the middle first (K-independent),
  yielding half-factors ``A (M, r_d)`` / ``B (r_d, N)``, then
  ``Y = A @ (B @ X)`` — two MXU-friendly GEMMs (paper Eq. (20)/(21)).

Both produce bit-identical math (contraction order never changes the result,
only cost), which the tests assert against the dense reconstruction oracle.

Also implements TTM embedding lookup (paper Eq. (17)) and a first-principles
contraction-cost calculator used by ``core.cost_model`` and the benchmarks.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .tt import TTMSpec, TTSpec, tt_half_factors

__all__ = [
    "tt_forward_rl",
    "tt_forward_btt",
    "ttm_lookup",
    "token_digits",
    "ContractionCost",
    "rl_contraction_cost",
    "btt_contraction_cost",
    "dense_matmul_cost",
]


def tt_forward_rl(cores: Sequence[jax.Array], x: jax.Array, spec: TTSpec) -> jax.Array:
    """Right-to-left TT contraction: ``y (K, M) = W x`` for ``x (K, N)``.

    Faithful to the prior-work flow the paper compares against: contract the
    input tensor with ``G_{2d}``, then ``G_{2d-1}``, ..., finally ``G_1``.
    Every intermediate carries K.
    """
    d = spec.d
    k = x.shape[0]
    nf = spec.in_factors
    # x -> (K, n_1, ..., n_d)
    t = x.reshape((k,) + tuple(nf))
    # Input-side cores, right to left: G_{2d} .. G_{d+1}
    # After step j (contracting n_{d-j+1}): t has shape (K, n_1..n_{d-j}, r)
    t = jnp.einsum("...n,rnq->...rq", t, cores[2 * d - 1], optimize=True)  # q = r_{2d} = 1
    t = t[..., 0]  # (K, n_1..n_{d-1}, r_{2d-1})
    for j in range(d - 2, -1, -1):
        g = cores[d + j]  # (r_{d+j}, n_{j+1}, r_{d+j+1})
        t = jnp.einsum("...nr,snr->...s", t, g, optimize=True)
    # t: (K, r_d)
    # Output-side cores, right to left: G_d .. G_1; builds up m-axes.
    for j in range(d - 1, -1, -1):
        g = cores[j]  # (r_j, m_{j+1}, r_{j+1})
        t = jnp.einsum("k...r,smr->k...ms", t, g, optimize=True)
    t = t[..., 0]  # drop r_0 = 1 -> (K, m_d, ..., m_1)? axes built innermost-last
    # Axes come out as (K, m_d, m_{d-1}, ..., m_1); transpose to (K, m_1..m_d).
    perm = (0,) + tuple(range(t.ndim - 1, 0, -1))
    t = jnp.transpose(t, perm)
    return t.reshape(k, spec.out_dim)


def tt_forward_btt(cores: Sequence[jax.Array], x: jax.Array, spec: TTSpec) -> jax.Array:
    """Bidirectional TT contraction (the paper's BTT): ``y = A @ (B @ x)``.

    ``x (K, N) -> (K, M)``.  The half-factor builds are K-independent; the
    only K-scaled work is two dense GEMMs with inner dims ``N`` and ``r_d`` —
    the MXU-friendly form (see DESIGN.md hardware-adaptation notes).
    """
    a, b = tt_half_factors(cores, spec)  # (M, r_d), (r_d, N)
    t = x @ b.T  # (K, r_d)
    return t @ a.T  # (K, M)


def token_digits(ids: jax.Array, vocab_factors: Sequence[int]) -> jax.Array:
    """Mixed-radix decomposition of token ids onto the TTM vocab factors.

    ``ids (...,) -> (..., d)`` with ``ids = sum_k digits[k] * stride_k`` where
    the first factor is the most significant (row-major layout of the vocab
    axis), matching ``ttm_reconstruct``'s Kronecker ordering.
    """
    digits = []
    rem = ids
    for f in vocab_factors[::-1]:
        digits.append(rem % f)
        rem = rem // f
    return jnp.stack(digits[::-1], axis=-1)


def ttm_lookup(cores: Sequence[jax.Array], ids: jax.Array, spec: TTMSpec) -> jax.Array:
    """TTM embedding lookup (paper Eq. (17)).

    For each token, select slice ``F_k[:, j_k, :, :]`` from every core and
    chain-multiply; no dense ``(V, H)`` table ever materializes.  ``ids`` may
    have any batch shape; returns ``ids.shape + (H,)``.
    """
    batch_shape = ids.shape
    flat = ids.reshape(-1)
    dg = token_digits(flat, spec.vocab_factors)  # (K, d)
    # First core: (1, v_1, h_1, r_1) -> gather -> (K, h_1, r_1)
    acc = jnp.take(cores[0], dg[:, 0], axis=1)[0]  # (K, h1, r1)
    for k in range(1, spec.d):
        fk = jnp.take(cores[k], dg[:, k], axis=1)  # (r_{k-1}, K, h_k, r_k)
        acc = jnp.einsum("kpr,rkns->kpns", acc, fk, optimize=True)
        acc = acc.reshape(acc.shape[0], acc.shape[1] * acc.shape[2], acc.shape[3])
    out = acc.reshape(flat.shape[0], spec.hidden_dim)
    return out.reshape(batch_shape + (spec.hidden_dim,))


# ---------------------------------------------------------------------------
# First-principles contraction cost calculator.
#
# Each contraction step of tensors S (with dims Ds) and T (dims Dt) over a
# contracted set C costs ``prod(output dims) * prod(C)`` multiplies and
# produces an intermediate of ``prod(output dims)`` elements.  This is the
# model behind the paper's Eqs. (18)-(21); we compute it step-by-step from the
# actual flows so benchmarks can validate the closed forms.
# ---------------------------------------------------------------------------


class ContractionCost:
    """Accumulates multiplies and intermediate-element counts over a flow."""

    def __init__(self) -> None:
        self.muls = 0
        self.intermediates: list[int] = []

    def step(self, out_elems: int, contracted: int) -> None:
        self.muls += out_elems * contracted
        self.intermediates.append(out_elems)

    @property
    def peak_intermediate(self) -> int:
        return max(self.intermediates) if self.intermediates else 0

    @property
    def total_intermediate(self) -> int:
        # Paper's training memory model: *all* intermediates are stored for
        # reuse in backprop, except the final output (Sec. IV-A).
        return sum(self.intermediates[:-1]) if self.intermediates else 0


def rl_contraction_cost(spec: TTSpec, K: int) -> ContractionCost:
    """Cost of the right-to-left flow (validates paper Eq. (18)/(19))."""
    c = ContractionCost()
    rs = spec.ranks
    nf, mf = spec.in_factors, spec.out_factors
    d = spec.d
    # Input side: contract n_d, then n_{d-1}, ... n_1.
    # State after contracting j factors: (K, n_1..n_{d-j}, r_{2d-j})
    for j in range(1, d + 1):
        lead = int(np.prod(nf[: d - j])) if d - j > 0 else 1
        out = K * lead * rs[2 * d - j]
        c.step(out, nf[d - j] * rs[2 * d - j + 1])
    # Output side: contract r_d with G_d, ..., r_1 with G_1, building m axes.
    # State after j output steps: (K, m_{d-j+1}..m_d, r_{d-j})
    for j in range(1, d + 1):
        ms = int(np.prod(mf[d - j:]))
        out = K * ms * rs[d - j]
        c.step(out, rs[d - j + 1])
    return c


def btt_contraction_cost(spec: TTSpec, K: int) -> ContractionCost:
    """Cost of the bidirectional flow (validates paper Eq. (20)/(21))."""
    c = ContractionCost()
    rs = spec.ranks
    nf, mf = spec.in_factors, spec.out_factors
    d = spec.d
    # Build B (r_d, N): chain input-side cores right-to-left (boundary-inward;
    # no step carries r_d until the chain reaches it — see tt_half_factors).
    for j in range(1, d):
        n_tail = int(np.prod(nf[d - j - 1:]))
        out = rs[2 * d - j - 1] * n_tail
        c.step(out, rs[2 * d - j])
    # Build A (M, r_d): chain output-side cores left-to-right (boundary-inward).
    for j in range(1, d):
        m_part = int(np.prod(mf[: j + 1]))
        out = m_part * rs[j + 1]
        c.step(out, rs[j])
    # Z2 = B @ X : (r_d, K), contract N.
    c.step(rs[d] * K, spec.in_dim)
    # Y = A @ Z2 : (M, K), contract r_d.
    c.step(spec.out_dim * K, rs[d])
    return c


def dense_matmul_cost(M: int, N: int, K: int) -> ContractionCost:
    c = ContractionCost()
    c.step(M * K, N)
    return c
