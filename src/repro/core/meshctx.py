"""Activation-sharding hints: a mesh context for model-internal constraints.

Model code stays mesh-agnostic; drivers (dryrun/train/serve) install the
active mesh here, and hot spots call ``constrain(x, *spec)`` to pin the
sharding of *transient* activations whose layout GSPMD cannot infer from
parameters alone (e.g. the transiently-reconstructed TTM embedding table,
which descends from replicated cores but must be vocab-sharded).  With no
mesh installed — unit tests, single-device runs — ``constrain`` is a no-op.
"""
from __future__ import annotations

import contextlib
from typing import Iterator

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["activation_mesh", "constrain", "current_mesh"]

_ACTIVE: list[Mesh] = []


@contextlib.contextmanager
def activation_mesh(mesh: Mesh) -> Iterator[None]:
    _ACTIVE.append(mesh)
    try:
        yield
    finally:
        _ACTIVE.pop()


def current_mesh() -> Mesh | None:
    return _ACTIVE[-1] if _ACTIVE else None


def constrain(x: jax.Array, *spec) -> jax.Array:
    """``with_sharding_constraint(x, P(*spec))`` under the active mesh.

    Each spec entry is an axis name, a tuple of axis names, or None.  Axis
    names missing from the mesh (or that do not divide the dim) degrade to
    None; with no active mesh the array passes through unchanged.
    """
    mesh = current_mesh()
    if mesh is None:
        return x

    def resolve(dim, ax):
        if ax is None:
            return None
        axes = ax if isinstance(ax, tuple) else (ax,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if not axes:
            return None
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if dim % n != 0:
            return None
        return axes if len(axes) > 1 else axes[0]

    fixed = [resolve(d, a) for d, a in zip(x.shape, spec)]
    fixed += [None] * (len(x.shape) - len(fixed))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))
