"""Activation-sharding hints: a mesh context for model-internal constraints.

Model code stays mesh-agnostic; drivers (dryrun/train/serve) install the
active mesh here, and hot spots call ``constrain(x, *spec)`` to pin the
sharding of *transient* activations whose layout GSPMD cannot infer from
parameters alone (e.g. the transiently-reconstructed TTM embedding table,
which descends from replicated cores but must be vocab-sharded).  With no
mesh installed — unit tests, single-device runs — ``constrain`` is a no-op.

Two interpretations of the "model" axis coexist:

* Megatron column-TP (default): "model" cuts the FFN hidden dim / head dim.
  Fused megakernels are ineligible (the hidden state must shard).
* Row-TP (``activation_mesh(mesh, model_rows=True)``): "model" shards the
  leading batch×seq *row* dim of activations, like an extra DP axis for
  activations, while the tiny TT cores stay replicated.  Fused kernels stay
  fused — each device launches them on its row shard — and dispatch
  predicates must evaluate *local* row counts: ``row_shards()`` is the
  single source for that divisor (threaded through ``kernels.ops`` as
  ``shard_dims``).  shard_map bodies see local shapes already and install
  no mesh, so they get ``row_shards() == 1`` — correct by construction.
"""
from __future__ import annotations

import contextlib
from typing import Iterator

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["activation_mesh", "constrain", "current_mesh",
           "model_axis_rowwise", "row_shards"]

_ACTIVE: list[tuple[Mesh, bool]] = []

_ROW_AXES = ("pod", "data")


@contextlib.contextmanager
def activation_mesh(mesh: Mesh, *, model_rows: bool = False) -> Iterator[None]:
    _ACTIVE.append((mesh, model_rows))
    try:
        yield
    finally:
        _ACTIVE.pop()


def current_mesh() -> Mesh | None:
    return _ACTIVE[-1][0] if _ACTIVE else None


def model_axis_rowwise() -> bool:
    """True when the installed mesh declares "model" a row (batch) axis."""
    return _ACTIVE[-1][1] if _ACTIVE else False


def row_shards() -> int:
    """How many ways the leading batch×seq rows of activations are sharded.

    The product of the DP axes ("pod", "data") of the active mesh, times
    "model" when it is declared row-wise.  1 with no mesh — which is also
    what shard_map bodies see (they trace on local shapes and install no
    mesh), so per-shard dispatch predicates are correct in both regimes.
    """
    if not _ACTIVE:
        return 1
    mesh, model_rows = _ACTIVE[-1]
    n = 1
    for a in _ROW_AXES:
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    if model_rows and "model" in mesh.axis_names:
        n *= mesh.shape["model"]
    return n


def constrain(x: jax.Array, *spec) -> jax.Array:
    """``with_sharding_constraint(x, P(*spec))`` under the active mesh.

    Each spec entry is an axis name, a tuple of axis names, or None.  Axis
    names missing from the mesh (or that do not divide the dim) degrade to
    None; with no active mesh the array passes through unchanged.  Under a
    row-wise "model" declaration, "model" entries on feature dims are
    re-routed onto the leading (row) dim — call sites keep their Megatron
    specs and the context decides the interpretation.
    """
    mesh = current_mesh()
    if mesh is None:
        return x

    spec = list(spec)
    if model_axis_rowwise() and spec:
        def strip(ax):
            if ax == "model":
                return None
            if isinstance(ax, tuple):
                kept = tuple(a for a in ax if a != "model")
                return kept if len(kept) > 1 else (kept[0] if kept else None)
            return ax

        had_model = any(
            ax == "model" or (isinstance(ax, tuple) and "model" in ax)
            for ax in spec)
        spec = [strip(ax) for ax in spec]
        if had_model:
            head = spec[0]
            head = (head if isinstance(head, tuple)
                    else (() if head is None else (head,)))
            if "model" not in head:
                spec[0] = head + ("model",)

    def resolve(dim, ax):
        if ax is None:
            return None
        axes = ax if isinstance(ax, tuple) else (ax,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if not axes:
            return None
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if dim % n != 0:
            return None
        return axes if len(axes) > 1 else axes[0]

    fixed = [resolve(d, a) for d, a in zip(x.shape, spec)]
    fixed += [None] * (len(x.shape) - len(fixed))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))
