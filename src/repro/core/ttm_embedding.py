"""TTM-format embedding table (paper Sec. III-C), with scale-aware execution.

The vocab dictionary ``E (V, H)`` is stored as ``d`` TTM cores.  Two lookup
strategies, chosen by token count (``strategy="auto"``):

* ``gather`` — the paper's flow: select one ``(r, h_k, r)`` slice per core
  per token and chain-multiply.  Per-token data touched is ``O(r^2 h)``
  elements — free on the paper's FPGA (slices stream from BRAM) and inside
  our Pallas kernel (VMEM-resident cores), but an HBM *read amplification*
  of ``r^2 h / H`` vs a dense row in the pure-JAX path.  Right choice for
  decode (K ≤ hundreds).
* ``reconstruct`` — build the dense table **transiently** (an activation,
  never a parameter: ``V·H·r`` FLOPs, ``V·H`` bytes, vocab-sharded under
  TP) and do a standard embedding gather.  Traffic collapses to
  dense-embedding levels while the *trainable state* stays ~100x
  compressed.  Right choice for training/prefill.  Crossover:
  ``K > V·H / (r^2·h)`` (a few thousand tokens at arch scale) — measured
  10x memory-term reduction on the qwen3 train cell (EXPERIMENTS.md §Perf).

Backward (core gradients, paper Eq. (12)) falls out of autodiff through
either path: scatter-add onto slices (gather) or the table-cotangent chain
contraction (reconstruct).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .contraction import ttm_lookup
from .tt import TTMSpec, factorize, ttm_init, ttm_reconstruct

__all__ = ["TTMEmbeddingParams", "ttm_embedding_init", "ttm_embedding_apply",
           "make_ttm_spec", "ttm_strategy_crossover"]


def make_ttm_spec(vocab: int, hidden: int, d: int, rank: int) -> TTMSpec:
    vf, _ = factorize(vocab, d)
    hf, _ = factorize(hidden, d)
    return TTMSpec(vocab_factors=vf, hidden_factors=hf, rank=rank)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class TTMEmbeddingParams:
    cores: list[jax.Array]
    spec: TTMSpec
    vocab: int   # logical vocab (<= spec.vocab_dim)
    hidden: int  # logical hidden (<= spec.hidden_dim)

    def tree_flatten_with_keys(self):
        return ((jax.tree_util.GetAttrKey("cores"), self.cores),), \
            (self.spec, self.vocab, self.hidden)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (cores,) = children
        return cls(cores=list(cores), spec=aux[0], vocab=aux[1], hidden=aux[2])


def ttm_embedding_init(key: jax.Array, vocab: int, hidden: int, *, d: int,
                       rank: int, dtype=jnp.float32,
                       target_std: float = 0.02) -> TTMEmbeddingParams:
    spec = make_ttm_spec(vocab, hidden, d, rank)
    return TTMEmbeddingParams(cores=ttm_init(key, spec, dtype, target_std),
                              spec=spec, vocab=vocab, hidden=hidden)


def ttm_strategy_crossover(spec: TTMSpec) -> int:
    """Token count above which transient reconstruction beats per-token
    gather on HBM traffic: K·r²·h_mean > V·H."""
    rs = spec.ranks
    r2h = sum(rs[k] * spec.hidden_factors[k] * rs[k + 1]
              for k in range(spec.d))
    return max(int(spec.vocab_dim * spec.hidden_dim / max(r2h, 1)), 1)


def ttm_embedding_apply(params: TTMEmbeddingParams, ids: jax.Array, *,
                        strategy: str = "auto") -> jax.Array:
    """``ids (...,) int -> embeddings (..., hidden)``."""
    if strategy == "auto":
        strategy = ("reconstruct"
                    if int(np.prod(ids.shape)) > ttm_strategy_crossover(params.spec)
                    else "gather")
    if strategy == "reconstruct":
        from .meshctx import constrain
        table = constrain(ttm_reconstruct(params.cores, params.spec),
                          "model", None)  # vocab-sharded transient table
        out = jnp.take(table, ids, axis=0)
    else:
        out = ttm_lookup(params.cores, ids, params.spec)
    if params.hidden != params.spec.hidden_dim:
        out = out[..., : params.hidden]
    return out
