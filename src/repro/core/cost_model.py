"""Analytic computing/memory cost models (paper Sec. IV + Sec. V-C).

Three layers of modeling, each validated by `benchmarks/bench_cost_model.py`:

1. Closed-form multiply / intermediate-memory counts for the right-to-left TT
   flow (paper Eqs. (18)/(19)) and the bidirectional BTT flow (Eqs. (20)/(21)).
   These are transcribed exactly as printed.
2. A first-principles step-by-step calculator (`core.contraction`) that walks
   the actual flows; the benchmark asserts (1) == (2).
3. The BRAM allocation model (Eqs. (22)-(25)) with the tensor-core grouping
   strategy, plus the TPU analogue: (8, 128) tile-padding waste of individually
   stored cores vs. packed/stacked core buffers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from .tt import TTMSpec, TTSpec

__all__ = [
    "mul_tt_rl",
    "mem_tt_rl",
    "mul_btt",
    "mem_btt",
    "mul_dense",
    "mem_dense_weights",
    "ttm_forward_cost",
    "BRAM_BITS",
    "BRAM_WIDTHS",
    "bram_blocks",
    "bram_efficiency",
    "sublane",
    "tpu_tile_padded_bytes",
    "tpu_packing_efficiency",
]

LANE = 128


def sublane(itemsize: int) -> int:
    """TPU sublane granule (second-minor tile dim) for a dtype's itemsize:
    f32 8, bf16 16, int8/fp8 32 — the (sublane, 128) native tile.

    THE shared source of this formula: the kernel tile choosers
    (``btt_linear``/``btt_ffn`` decode granules, ``fused_update``'s packed
    buffer padding) and the tile-padding byte models below all call this
    instead of re-deriving the dict locally.
    """
    return {4: 8, 2: 16, 1: 32}.get(int(itemsize), 8)


# ---------------------------------------------------------------------------
# Paper Eqs. (18)-(21), transcribed directly.  Index conventions follow the
# paper: cores G_1..G_{2d}, ranks r_0..r_{2d}; m_i are output factors, n_i
# input factors; K = batch * seq.
# ---------------------------------------------------------------------------


def _rmn(spec: TTSpec):
    rs = spec.ranks
    m = (0,) + tuple(spec.out_factors)  # 1-indexed
    n = (0,) + tuple(spec.in_factors)
    return rs, m, n


def mul_tt_rl(spec: TTSpec, K: int) -> int:
    """Paper Eq. (18): multiplies of the right-to-left TT forward."""
    rs, m, n = _rmn(spec)
    d = spec.d
    total = 0
    for k in range(d):
        t1 = rs[2 * d - k - 1] * rs[2 * d - k] * int(np.prod(n[1 : d - k + 1]))
        t2 = rs[d - k - 1] * rs[d - k] * int(np.prod(m[d - k : d + 1]))
        total += t1 + t2
    return K * total


def mem_tt_rl(spec: TTSpec, K: int) -> int:
    """Paper Eq. (19): intermediate elements stored by the RL flow."""
    rs, m, n = _rmn(spec)
    d = spec.d
    total = K * rs[d]
    for k in range(d - 1):
        t1 = rs[2 * d - k - 1] * int(np.prod(n[1 : d - k]))
        t2 = rs[d - k - 1] * int(np.prod(m[d - k : d + 1]))
        total += K * (t1 + t2)
    return total


def mul_btt(spec: TTSpec, K: int) -> int:
    """Paper Eq. (20): multiplies of the bidirectional (BTT) forward."""
    rs, m, n = _rmn(spec)
    d = spec.d
    total = 0
    for k in range(d - 1):
        t1 = rs[2 * d - k - 1] * rs[2 * d - k - 2] * int(np.prod(n[d - k - 1 : d + 1]))
        t2 = rs[k + 1] * rs[k + 2] * int(np.prod(m[1 : k + 3]))
        total += t1 + t2
    total += K * rs[d] * (spec.out_dim + spec.in_dim)
    return total


def mem_btt(spec: TTSpec, K: int) -> int:
    """Paper Eq. (21): intermediate elements stored by the BTT flow."""
    rs, m, n = _rmn(spec)
    d = spec.d
    total = K * rs[d]
    for k in range(d - 1):
        t1 = rs[2 * d - k - 2] * int(np.prod(n[d - k - 1 : d + 1]))
        t2 = rs[k + 1] * int(np.prod(m[1 : k + 3]))
        total += t1 + t2
    return total


def mul_dense(M: int, N: int, K: int) -> int:
    return M * N * K


def mem_dense_weights(M: int, N: int) -> int:
    return M * N


def ttm_forward_cost(spec: TTMSpec, K: int) -> tuple[int, int]:
    """(multiplies, intermediate elements) of a TTM chained lookup for K
    tokens — first-principles over the flow in ``contraction.ttm_lookup``."""
    rs = spec.ranks
    muls = 0
    mem = 0
    h_part = spec.hidden_factors[0]
    for k in range(1, spec.d):
        out = K * h_part * spec.hidden_factors[k] * rs[k + 1]
        muls += out * rs[k]
        h_part *= spec.hidden_factors[k]
        if k < spec.d - 1:
            mem += out
    return muls, mem


# ---------------------------------------------------------------------------
# BRAM model (paper Sec. V-C, Eqs. (22)-(25)).
# ---------------------------------------------------------------------------

BRAM_BITS = 36 * 1024  # C = 36,864 bits per BRAM36 block
BRAM_WIDTHS = (1, 2, 4, 9, 18, 36, 72)  # configurable widths W; D = C / W


def bram_blocks(n_cores: int, depth_elems: int, r: int, *, bw: int = 32,
                strategy: str = "reshape", group: int = 1,
                width: int | None = None) -> int:
    """Number of BRAM36 blocks to store ``n_cores`` TT cores.

    Each core reshaped 2-D: logical width supports ``r`` parallel rank reads
    of ``bw``-bit words; logical depth is ``depth_elems`` (= n*r for a core
    (r, n, r) streamed along rank).  ``group`` cores are concatenated along
    depth per the paper's grouping (Eqs. (24)/(25)); ``group=1`` reproduces
    Eqs. (22)/(23)).
    """
    if strategy not in ("partition", "reshape"):
        raise ValueError(strategy)
    widths = BRAM_WIDTHS if width is None else (width,)
    n_groups = math.ceil(n_cores / group)
    best = None
    for w in widths:
        d_cap = BRAM_BITS // w
        if strategy == "partition":
            n_w = r * math.ceil(bw / w)
        else:
            n_w = math.ceil(bw * r / w)
        n_d = math.ceil(group * depth_elems / d_cap)
        total = n_groups * n_w * n_d
        if best is None or total < best:
            best = total
    return int(best)


def bram_efficiency(n_cores: int, depth_elems: int, r: int, *, bw: int = 32,
                    strategy: str = "reshape", group: int = 1) -> float:
    """eta = ideal bits / allocated bits (paper Fig. 11/12)."""
    ideal_bits = n_cores * depth_elems * r * bw
    blocks = bram_blocks(n_cores, depth_elems, r, bw=bw, strategy=strategy, group=group)
    return ideal_bits / (blocks * BRAM_BITS)


# ---------------------------------------------------------------------------
# TPU analogue: (sublane, lane) tile padding waste, individually stored cores
# vs. packed stacks.  A TPU array is laid out in (8, 128) f32 tiles (16, 128)
# for bf16; tiny trailing dims waste lanes exactly like fixed-size BRAM blocks
# waste depth.
# ---------------------------------------------------------------------------


def tpu_tile_padded_bytes(shape: Sequence[int], dtype_bytes: int = 4) -> int:
    """Bytes the array occupies in HBM/VMEM after (8, 128)-tile padding of the
    two minor dims ((16,128) for 2-byte dtypes)."""
    if len(shape) == 0:
        return dtype_bytes
    sub = sublane(dtype_bytes)
    dims = list(shape)
    if len(dims) == 1:
        dims = [1] + dims
    minor = math.ceil(dims[-1] / LANE) * LANE
    second = math.ceil(dims[-2] / sub) * sub
    lead = int(np.prod(dims[:-2])) if len(dims) > 2 else 1
    return lead * second * minor * dtype_bytes


def tpu_packing_efficiency(core_shapes: Sequence[tuple[int, ...]],
                           n_layers: int, dtype_bytes: int = 4) -> tuple[float, float]:
    """(eta_individual, eta_packed) for storing ``n_layers`` copies of the
    given cores individually vs. flat-packed into one buffer per core index —
    the TPU analogue of the paper's tensor grouping (Eqs. 24/25).

    Flat packing concatenates the L stacked copies element-contiguously and
    pads once to an (8, 128) tile, exactly like the paper concatenates
    K = (d-1)L cores along BRAM depth; the kernel reshapes on VMEM load
    (HBM->VMEM DMA is layout-flexible), so compute is unaffected."""
    ideal = n_layers * sum(int(np.prod(s)) for s in core_shapes) * dtype_bytes
    indiv = n_layers * sum(tpu_tile_padded_bytes(s, dtype_bytes) for s in core_shapes)
    tile = sublane(dtype_bytes) * LANE * dtype_bytes
    packed = sum(
        math.ceil(n_layers * int(np.prod(s)) * dtype_bytes / tile) * tile
        for s in core_shapes
    )
    return ideal / indiv, ideal / packed
