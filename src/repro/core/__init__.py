"""Core: the paper's contribution — TT/TTM-compressed training with
bidirectional contraction, fused backward, and memory-packing models."""
from .contraction import (
    ContractionCost,
    btt_contraction_cost,
    dense_matmul_cost,
    rl_contraction_cost,
    tt_forward_btt,
    tt_forward_rl,
    ttm_lookup,
)
from .tt import (
    TTMSpec,
    TTSpec,
    factorize,
    tt_half_factors,
    tt_init,
    tt_params_count,
    tt_reconstruct,
    ttm_init,
    ttm_params_count,
    ttm_reconstruct,
)
from .memory_ledger import (
    BRAM_BUDGET_BYTES,
    URAM_BUDGET_BYTES,
    StageLedger,
    budget_report,
    format_report,
    ledger_rows,
    training_step_ledger,
)
from .tt_linear import (
    FLOWS,
    TTLinearParams,
    make_tt_spec,
    tt_linear_apply,
    tt_linear_init,
)
from .ttm_embedding import (
    TTMEmbeddingParams,
    make_ttm_spec,
    ttm_embedding_apply,
    ttm_embedding_init,
)

__all__ = [
    "TTSpec", "TTMSpec", "factorize",
    "tt_init", "ttm_init", "tt_reconstruct", "ttm_reconstruct",
    "tt_half_factors", "tt_params_count", "ttm_params_count",
    "tt_forward_rl", "tt_forward_btt", "ttm_lookup",
    "ContractionCost", "rl_contraction_cost", "btt_contraction_cost",
    "dense_matmul_cost",
    "TTLinearParams", "tt_linear_init", "tt_linear_apply", "FLOWS",
    "make_tt_spec", "make_ttm_spec",
    "TTMEmbeddingParams", "ttm_embedding_init", "ttm_embedding_apply",
    "BRAM_BUDGET_BYTES", "URAM_BUDGET_BYTES", "StageLedger",
    "training_step_ledger", "budget_report", "format_report", "ledger_rows",
]
