"""TT-format linear layer with the paper's three execution flows.

* ``flow="rl"``        — right-to-left sequential contraction (prior work:
                         TIE/ETTE-style inference accelerators).
* ``flow="btt"``       — bidirectional contraction, plain autodiff. JAX will
                         store the forward intermediates (incl. the K-sized
                         ``B @ x``) for the backward pass.
* ``flow="btt_fused"`` — bidirectional contraction with a custom VJP that
                         implements the paper's *fused backward* (Sec. V-B2):
                         nothing K-sized is saved; the backward rebuilds the
                         half-factors and recomputes ``t = x @ B^T``, then
                         forms core gradients through the (tiny) half-factor
                         builds.  This is the TPU analogue of the MUL2/MUL3
                         fine-grained fusion: intermediate gradient tensors
                         (the paper's Z'_3) never round-trip through HBM.

The custom VJP computes exactly the gradients of paper Eqs. (10)/(11)/(16) —
verified against autodiff-through-dense-reconstruction in the tests.

Logical (model) dims may be smaller than the tensorized dims when
``factorize`` had to pad; ``tt_linear_apply`` zero-pads inputs / slices
outputs transparently.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .contraction import tt_forward_btt, tt_forward_rl
from .tt import TTSpec, factorize, tt_half_factors, tt_init

__all__ = ["TTLinearParams", "tt_linear_init", "tt_linear_apply", "FLOWS",
           "make_tt_spec"]

# "kernel" routes through the fused Pallas forward (kernels/ops.py) with the
# same custom-VJP backward; on non-TPU backends it runs in interpret mode.
FLOWS = ("rl", "btt", "btt_fused", "kernel")


def make_tt_spec(out_dim: int, in_dim: int, d: int, rank: int,
                 clamp_ranks: bool = True) -> TTSpec:
    """TTSpec for possibly-unfactorizable dims (pads internally)."""
    mf, _ = factorize(out_dim, d)
    nf, _ = factorize(in_dim, d)
    return TTSpec(out_factors=mf, in_factors=nf, rank=rank,
                  clamp_ranks=clamp_ranks)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class TTLinearParams:
    """Pytree of TT cores (+ optional dense bias); spec/dims are static aux."""

    cores: list[jax.Array]
    bias: jax.Array | None
    spec: TTSpec
    out_dim: int  # logical output dim (<= spec.out_dim)
    in_dim: int   # logical input dim (<= spec.in_dim)

    def tree_flatten_with_keys(self):
        return ((jax.tree_util.GetAttrKey("cores"), self.cores),
                (jax.tree_util.GetAttrKey("bias"), self.bias)), \
            (self.spec, self.out_dim, self.in_dim)

    @classmethod
    def tree_unflatten(cls, aux, children):
        cores, bias = children
        return cls(cores=list(cores), bias=bias, spec=aux[0],
                   out_dim=aux[1], in_dim=aux[2])


def tt_linear_init(key: jax.Array, out_dim: int, in_dim: int, *, d: int,
                   rank: int, use_bias: bool = False, dtype=jnp.float32,
                   clamp_ranks: bool = True) -> TTLinearParams:
    spec = make_tt_spec(out_dim, in_dim, d, rank, clamp_ranks)
    cores = tt_init(key, spec, dtype)
    bias = jnp.zeros((out_dim,), dtype) if use_bias else None
    return TTLinearParams(cores=cores, bias=bias, spec=spec,
                          out_dim=out_dim, in_dim=in_dim)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _btt_fused(cores: tuple, x: jax.Array, spec: TTSpec) -> jax.Array:
    return tt_forward_btt(cores, x, spec)


def _btt_fused_fwd(cores, x, spec):
    # Residuals: cores and x only.  No K-sized intermediate is saved — the
    # paper's operation-fusion memory profile (O(r) extra state per layer).
    y = tt_forward_btt(cores, x, spec)
    return y, (cores, x)


def _btt_fused_bwd(spec, residuals, gy):
    cores, x = residuals
    d = spec.d

    def build(oc, ic):
        return tt_half_factors(list(oc) + list(ic), spec)

    (a, b), build_vjp = jax.vjp(build, tuple(cores[:d]), tuple(cores[d:]))
    t = x @ b.T            # (K, r_d)   recomputed, not stored
    gt = gy @ a            # (K, r_d)
    gx = gt @ b            # (K, N)     = B^T A^T y'  (paper Eq. (16))
    ga = gy.T @ t          # (M, r_d)   dL/dA
    gb = gt.T @ x          # (r_d, N)   dL/dB
    g_out, g_in = build_vjp((ga, gb))  # chain into per-core grads (Eqs. 10/11)
    return (tuple(g_out) + tuple(g_in), gx)


_btt_fused.defvjp(_btt_fused_fwd, _btt_fused_bwd)


def tt_linear_apply(params: TTLinearParams, x: jax.Array, *,
                    flow: str = "btt_fused",
                    fused_bwd: bool = True,
                    precision=None) -> jax.Array:
    """Apply ``y = W x + b`` with W in TT format.  ``x (..., N) -> (..., M)``.

    ``fused_bwd`` only affects ``flow="kernel"``: True (default) runs the
    BWD stage as the single fused Pallas kernel (``kernels.btt_backward``),
    False forces the operand-swap + XLA-GEMM reference backward.
    ``precision`` (a ``PrecisionConfig``) likewise only affects
    ``flow="kernel"`` — the pure-JAX flows stay f32 references.
    """
    spec = params.spec
    lead = x.shape[:-1]
    xk = x.reshape(-1, x.shape[-1])
    if params.in_dim != spec.in_dim:
        xk = jnp.pad(xk, ((0, 0), (0, spec.in_dim - params.in_dim)))
    if flow == "rl":
        y = tt_forward_rl(params.cores, xk, spec)
    elif flow == "btt":
        y = tt_forward_btt(params.cores, xk, spec)
    elif flow == "btt_fused":
        y = _btt_fused(tuple(params.cores), xk, spec)
    elif flow == "kernel":
        from repro.kernels.ops import btt_linear_op  # lazy: pallas import
        y = btt_linear_op(params.cores, xk, spec, use_kernel=True,
                          fused_bwd=fused_bwd, precision=precision)
    else:
        raise ValueError(f"unknown flow {flow!r}; expected one of {FLOWS}")
    if params.out_dim != spec.out_dim:
        y = y[:, : params.out_dim]
    y = y.reshape(lead + (params.out_dim,))
    if params.bias is not None:
        y = y + params.bias
    return y
