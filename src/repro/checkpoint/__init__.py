"""Fault-tolerant checkpointing: atomic, async, keep-k, elastic restore."""
from .checkpoint import (
    CheckpointManager,
    latest_step,
    list_steps,
    restore,
    save,
)

__all__ = ["CheckpointManager", "save", "restore", "latest_step", "list_steps"]
