"""Fault-tolerant checkpointing: atomic, async, keep-k, CRC-verified,
elastic restore."""
from .checkpoint import (
    CheckpointCorruptError,
    CheckpointManager,
    latest_step,
    list_steps,
    restore,
    restore_latest_valid,
    save,
    verify_step,
)

__all__ = ["CheckpointManager", "CheckpointCorruptError", "save", "restore",
           "restore_latest_valid", "verify_step", "latest_step",
           "list_steps"]
