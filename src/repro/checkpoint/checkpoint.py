"""Atomic, async, keep-k checkpointing with elastic (cross-mesh) restore.

Layout::

    <dir>/
      manifest.json            {"latest": 400, "steps": [200, 300, 400]}
      step_00000400/
        meta.json              paths, shapes, dtypes (human-auditable)
        leaf_00000.npy ...     one array per pytree leaf, key-path order

Guarantees:
  * **Atomic**: a step directory appears only via ``os.replace`` of a fully
    written+fsynced temp dir; the manifest is updated only after the rename.
    A crash mid-save leaves the previous checkpoint untouched.
  * **Async**: ``save(..., blocking=False)`` snapshots device arrays to host
    (the only synchronous part) and writes in a background thread; training
    continues.  ``wait()`` joins before the next save or at exit.
  * **Keep-k**: older step dirs are pruned after a successful save.
  * **Elastic restore**: leaves come back as host numpy; the caller
    device_puts them under specs derived for the *current* mesh
    (runtime.elastic.replan_for_mesh), so restarting on a different topology
    is the normal path, not a special case.

Restore takes a *template* pytree (from ``jax.eval_shape`` of the init
function) — this keeps arbitrary custom pytree nodes (TT cores, dataclasses)
out of the serialization format entirely.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "list_steps", "CheckpointManager"]

_MANIFEST = "manifest.json"


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def _write_manifest(root: str, steps: list[int]) -> None:
    tmp = os.path.join(root, _MANIFEST + ".tmp")
    with open(tmp, "w") as f:
        json.dump({"latest": steps[-1] if steps else None, "steps": steps}, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(root, _MANIFEST))


def list_steps(root: str) -> list[int]:
    mf = os.path.join(root, _MANIFEST)
    if not os.path.exists(mf):
        return []
    with open(mf) as f:
        return sorted(json.load(f)["steps"])


def latest_step(root: str) -> int | None:
    steps = list_steps(root)
    return steps[-1] if steps else None


def _write_step(root: str, step: int, leaves: list[np.ndarray],
                paths: list[str], keep: int | None) -> None:
    os.makedirs(root, exist_ok=True)
    final = _step_dir(root, step)
    tmp = tempfile.mkdtemp(dir=root, prefix=".tmp_save_")
    try:
        meta = {
            "step": step,
            "leaves": [
                {"path": p, "shape": list(a.shape), "dtype": str(a.dtype)}
                for p, a in zip(paths, leaves)
            ],
        }
        for i, a in enumerate(leaves):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), a)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    steps = sorted(set(list_steps(root)) | {step})
    if keep is not None and len(steps) > keep:
        for s in steps[:-keep]:
            shutil.rmtree(_step_dir(root, s), ignore_errors=True)
        steps = steps[-keep:]
    _write_manifest(root, steps)


def save(root: str, step: int, tree: Any, *, keep: int | None = None,
         blocking: bool = True) -> threading.Thread | None:
    """Checkpoint ``tree`` at ``step``.  Non-blocking returns the writer
    thread (already started); join it (or use CheckpointManager) before
    depending on the file."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    paths = [_path_str(p) for p, _ in flat]
    # Snapshot to host — after this, device buffers may be donated/mutated.
    leaves = [np.asarray(jax.device_get(x)) for _, x in flat]
    if blocking:
        _write_step(root, step, leaves, paths, keep)
        return None
    t = threading.Thread(target=_write_step,
                         args=(root, step, leaves, paths, keep), daemon=True)
    t.start()
    return t


def restore(root: str, template: Any, step: int | None = None) -> tuple[Any, int]:
    """Load a checkpoint into the structure of ``template`` (host numpy
    leaves).  Returns (tree, step).  Shape/dtype mismatches raise — elastic
    restarts reshape *sharding*, never array shapes."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = _step_dir(root, step)
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    if len(flat) != len(meta["leaves"]):
        raise ValueError(
            f"template has {len(flat)} leaves, checkpoint {len(meta['leaves'])}")
    leaves = []
    for i, ((path, tmpl), rec) in enumerate(zip(flat, meta["leaves"])):
        p = _path_str(path)
        if p != rec["path"]:
            raise ValueError(f"leaf {i}: template path {p} != saved {rec['path']}")
        arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
        if list(arr.shape) != list(tmpl.shape):
            raise ValueError(f"{p}: shape {arr.shape} != template {tmpl.shape}")
        leaves.append(arr.astype(tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class CheckpointManager:
    """Owns async writes + cadence for a training loop."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._pending: threading.Thread | None = None

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()  # one writer in flight at a time
        self._pending = save(self.root, step, tree, keep=self.keep,
                             blocking=False)

    def save_blocking(self, step: int, tree: Any) -> None:
        self.wait()
        save(self.root, step, tree, keep=self.keep, blocking=True)

    def restore_latest(self, template: Any) -> tuple[Any, int] | None:
        if latest_step(self.root) is None:
            return None
        return restore(self.root, template)
