"""Atomic, async, keep-k checkpointing with elastic (cross-mesh) restore.

Layout::

    <dir>/
      manifest.json            {"latest": 400, "steps": [200, 300, 400]}
      step_00000400/
        meta.json              paths, shapes, dtypes (human-auditable)
        leaf_00000.npy ...     one array per pytree leaf, key-path order

Guarantees:
  * **Atomic**: a step directory appears only via ``os.replace`` of a fully
    written+fsynced temp dir; the manifest is updated only after the rename.
    A crash mid-save leaves the previous checkpoint untouched.
  * **Async**: ``save(..., blocking=False)`` snapshots device arrays to host
    (the only synchronous part) and writes in a background thread; training
    continues.  ``wait()`` joins before the next save or at exit and
    RE-RAISES any exception the writer thread died with — a failed write
    must never let training continue believing the checkpoint exists.
  * **Keep-k**: older step dirs are pruned after a successful save.
  * **Integrity**: every leaf's serialized bytes carry a CRC32 in
    ``meta.json``; ``restore`` verifies before deserializing (a flipped
    byte or truncated file raises :class:`CheckpointCorruptError`, never
    returns silently wrong tensors), and :func:`restore_latest_valid`
    walks the manifest newest->oldest past corrupt/missing steps — the
    recovery path for bit rot or power loss after the atomic rename.
  * **Elastic restore**: leaves come back as host numpy; the caller
    device_puts them under specs derived for the *current* mesh
    (runtime.elastic.replan_for_mesh), so restarting on a different topology
    is the normal path, not a special case.

Restore takes a *template* pytree (from ``jax.eval_shape`` of the init
function) — this keeps arbitrary custom pytree nodes (TT cores, dataclasses)
out of the serialization format entirely.
"""
from __future__ import annotations

import io
import json
import os
import shutil
import tempfile
import threading
import zlib
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "restore_latest_valid", "verify_step",
           "latest_step", "list_steps", "CheckpointManager",
           "CheckpointCorruptError"]


class CheckpointCorruptError(ValueError):
    """A leaf file failed CRC verification (or is missing/unreadable)."""

_MANIFEST = "manifest.json"


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def _write_manifest(root: str, steps: list[int]) -> None:
    tmp = os.path.join(root, _MANIFEST + ".tmp")
    with open(tmp, "w") as f:
        json.dump({"latest": steps[-1] if steps else None, "steps": steps}, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(root, _MANIFEST))


def list_steps(root: str) -> list[int]:
    mf = os.path.join(root, _MANIFEST)
    if not os.path.exists(mf):
        return []
    with open(mf) as f:
        return sorted(json.load(f)["steps"])


def latest_step(root: str) -> int | None:
    steps = list_steps(root)
    return steps[-1] if steps else None


def _write_step(root: str, step: int, leaves: list[np.ndarray],
                paths: list[str], keep: int | None) -> None:
    os.makedirs(root, exist_ok=True)
    final = _step_dir(root, step)
    tmp = tempfile.mkdtemp(dir=root, prefix=".tmp_save_")
    try:
        recs = []
        for i, (p, a) in enumerate(zip(paths, leaves)):
            # Serialize to memory once: the CRC covers the exact bytes on
            # disk (npy header included), so restore verifies the file
            # without a second parse.
            buf = io.BytesIO()
            np.save(buf, a)
            data = buf.getvalue()
            with open(os.path.join(tmp, f"leaf_{i:05d}.npy"), "wb") as f:
                f.write(data)
            recs.append({"path": p, "shape": list(a.shape),
                         "dtype": str(a.dtype),
                         "crc32": zlib.crc32(data)})
        meta = {"step": step, "leaves": recs}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    steps = sorted(set(list_steps(root)) | {step})
    if keep is not None and len(steps) > keep:
        for s in steps[:-keep]:
            shutil.rmtree(_step_dir(root, s), ignore_errors=True)
        steps = steps[-keep:]
    _write_manifest(root, steps)


def save(root: str, step: int, tree: Any, *, keep: int | None = None,
         blocking: bool = True) -> threading.Thread | None:
    """Checkpoint ``tree`` at ``step``.  Non-blocking returns the writer
    thread (already started); join it (or use CheckpointManager) before
    depending on the file.  The thread carries any writer exception in
    ``thread.ckpt_error`` (a one-element list) — joiners must check it
    (``CheckpointManager.wait`` re-raises)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    paths = [_path_str(p) for p, _ in flat]
    # Snapshot to host — after this, device buffers may be donated/mutated.
    leaves = [np.asarray(jax.device_get(x)) for _, x in flat]
    if blocking:
        _write_step(root, step, leaves, paths, keep)
        return None

    box: list[BaseException] = []

    def run():
        try:
            # Resolve the module global at call time (chaos patches it).
            _write_step(root, step, leaves, paths, keep)
        except BaseException as e:  # noqa: BLE001 — captured, re-raised later
            box.append(e)

    t = threading.Thread(target=run, daemon=True)
    t.ckpt_error = box  # type: ignore[attr-defined]
    t.ckpt_step = step  # type: ignore[attr-defined]
    t.start()
    return t


def restore(root: str, template: Any, step: int | None = None) -> tuple[Any, int]:
    """Load a checkpoint into the structure of ``template`` (host numpy
    leaves).  Returns (tree, step).  Shape/dtype mismatches raise — elastic
    restarts reshape *sharding*, never array shapes."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = _step_dir(root, step)
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    if len(flat) != len(meta["leaves"]):
        raise ValueError(
            f"template has {len(flat)} leaves, checkpoint {len(meta['leaves'])}")
    leaves = []
    for i, ((path, tmpl), rec) in enumerate(zip(flat, meta["leaves"])):
        p = _path_str(path)
        if p != rec["path"]:
            raise ValueError(f"leaf {i}: template path {p} != saved {rec['path']}")
        fp = os.path.join(d, f"leaf_{i:05d}.npy")
        with open(fp, "rb") as f:
            data = f.read()
        crc = rec.get("crc32")  # absent in pre-integrity checkpoints
        if crc is not None and zlib.crc32(data) != crc:
            raise CheckpointCorruptError(
                f"{p}: CRC mismatch in step {step} ({fp}) — leaf bytes "
                f"corrupted on disk")
        arr = np.load(io.BytesIO(data))
        if list(arr.shape) != list(tmpl.shape):
            raise ValueError(f"{p}: shape {arr.shape} != template {tmpl.shape}")
        leaves.append(arr.astype(tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def verify_step(root: str, step: int) -> bool:
    """True iff step ``step``'s files are present and every leaf's bytes
    match its recorded CRC (pre-integrity checkpoints: presence only)."""
    d = _step_dir(root, step)
    try:
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        for i, rec in enumerate(meta["leaves"]):
            with open(os.path.join(d, f"leaf_{i:05d}.npy"), "rb") as f:
                data = f.read()
            crc = rec.get("crc32")
            if crc is not None and zlib.crc32(data) != crc:
                return False
    except (OSError, ValueError, KeyError, EOFError):
        return False
    return True


def restore_latest_valid(root: str, template: Any, *,
                         repair: bool = True) -> tuple[tuple[Any, int], Any] | None:
    """Restore the NEWEST step that loads cleanly, walking the manifest
    backwards past corrupt/missing/truncated steps.

    Returns ``((tree, step), skipped)`` with ``skipped`` the list of bad
    step numbers that were passed over, or ``None`` when no step is
    restorable.  With ``repair=True`` (default) the bad step dirs are
    removed and the manifest rewritten WITHOUT them — but only when a
    valid step was found: if nothing restores (e.g. a wrong template),
    the files on disk are left exactly as they were.
    """
    steps = list_steps(root)
    skipped: list[int] = []
    for step in reversed(steps):
        try:
            tree, got = restore(root, template, step)
        except (OSError, ValueError, KeyError, EOFError):
            # ValueError covers CheckpointCorruptError and
            # json.JSONDecodeError; OSError covers missing files/dirs;
            # EOFError covers npy truncated inside the header.
            skipped.append(step)
            continue
        if repair and skipped:
            for s in skipped:
                shutil.rmtree(_step_dir(root, s), ignore_errors=True)
            _write_manifest(root, [s for s in steps if s not in skipped])
        return (tree, got), skipped
    return None


class CheckpointManager:
    """Owns async writes + cadence for a training loop."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._pending: threading.Thread | None = None

    def wait(self) -> None:
        """Join the in-flight writer; RE-RAISE its exception if it died.

        Before this check, a daemon-thread write failure (disk full,
        permissions, injected crash) was silently lost and training kept
        running believing the checkpoint existed."""
        if self._pending is not None:
            t, self._pending = self._pending, None
            t.join()
            box = getattr(t, "ckpt_error", None)
            if box:
                step = getattr(t, "ckpt_step", "?")
                raise RuntimeError(
                    f"async checkpoint write for step {step} failed"
                ) from box[0]

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()  # one writer in flight at a time
        self._pending = save(self.root, step, tree, keep=self.keep,
                             blocking=False)

    def save_blocking(self, step: int, tree: Any) -> None:
        self.wait()
        save(self.root, step, tree, keep=self.keep, blocking=True)

    def restore_latest(self, template: Any) -> tuple[Any, int] | None:
        if latest_step(self.root) is None:
            return None
        return restore(self.root, template)

    def restore_latest_valid(self, template: Any,
                             *, repair: bool = True) -> tuple[Any, int] | None:
        """Newest step that passes CRC + structure checks (walking past
        corrupt/missing steps, repairing the manifest); None if none."""
        got = restore_latest_valid(self.root, template, repair=repair)
        if got is None:
            return None
        (tree, step), _skipped = got
        return tree, step
