"""Version compatibility shims for the JAX API surface this repo targets.

The codebase is written against the modern ``jax.shard_map`` entry point
(with its ``check_vma`` replication-checker flag); older installs (<= 0.4.x)
only ship ``jax.experimental.shard_map.shard_map`` with the flag spelled
``check_rep``.  Everything that shard_maps goes through this wrapper so both
generations of JAX run the same code.
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as _pltpu

__all__ = ["shard_map", "tpu_compiler_params", "axis_size"]

# Renamed TPUCompilerParams -> CompilerParams across JAX releases.
tpu_compiler_params = getattr(_pltpu, "CompilerParams", None) or \
    _pltpu.TPUCompilerParams


def axis_size(axis_name) -> int:
    """Static mapped-axis size: ``jax.lax.axis_size`` where available,
    ``jax.core.axis_frame`` on older JAX (returns the size directly on
    ~0.4.36+, an AxisEnvFrame with ``.size`` before that)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)
    return frame if isinstance(frame, int) else frame.size


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` on old."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)
