"""Step-function builders: train_step / prefill / decode, + dry-run inputs.

These close over (cfg, optimizer) and expose pure functions ready for
``jax.jit`` with explicit in/out shardings (derived by runtime.sharding).
The same builders serve the CPU examples (tiny configs, host mesh) and the
512-chip dry-run (full configs, production mesh) — there is no separate
"distributed" code path.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import cache_struct, forward, loss_fn
from repro.optim.optimizers import Optimizer, clip_by_global_norm

__all__ = [
    "make_train_step", "make_ddp_train_step", "make_pipeline_train_step",
    "make_prefill", "make_decode_step",
    "make_inputs", "abstract_train_state", "prepare_decode_cache",
]


# ---------------------------------------------------------------------------
# Training.
# ---------------------------------------------------------------------------


def _global_grad_norm(grads) -> jax.Array:
    """f32 global L2 norm — the reported metric when clipping is off.

    Shared by every step builder so ``grad_norm`` means the same thing
    with and without ``clip_norm`` (ddp used to report a hard 0.0)."""
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads)))


def _grads_at_rest(grads, cfg: ModelConfig):
    """BWD→PU boundary storage: round-trip every gradient leaf through
    ``cfg.tt.precision.grad_dtype`` (``core.quant.cast_format``) — what the
    gradient buffer holds in HBM between the backward and the update.
    fp8_e5m2's wide exponent makes it self-describing (no scale); int8 is
    rejected up front (its dynamic range collapses under one scale)."""
    gfmt = cfg.tt.precision.grad_dtype
    if gfmt == "float32":
        return grads
    if gfmt == "int8":
        raise ValueError("grad_dtype='int8' is unsupported: gradient "
                         "dynamic range collapses under a per-tensor "
                         "scale; use 'bfloat16' or 'fp8_e5m2'")
    from repro.core import quant

    return jax.tree.map(lambda g: quant.cast_format(g, gfmt), grads)


def make_train_step(cfg: ModelConfig, opt: Optimizer, *, microbatches: int = 1,
                    clip_norm: float = 1.0, remat: bool = True,
                    batch_constraint=None, fused_bwd: bool | None = None,
                    fused_attn: bool | None = None,
                    fused_ffn: bool | None = None,
                    guard: bool = False):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches > 1`` accumulates gradients over leading batch splits in a
    scan; XLA overlaps each microbatch's DP all-reduce with the next
    microbatch's backward (the grads are produced inside the scan body).
    Per-microbatch losses AND gradients are weighted by each microbatch's
    mask token count (token count when no mask) — ``loss_fn`` normalizes
    per microbatch by its own mask sum, so an unweighted mean would drift
    from the single-batch loss whenever masks are ragged across splits.

    ``batch_constraint`` (optional): applied to the reshaped
    ``(microbatches, B/mb, ...)`` batch — the reshape has no sharding
    lineage for its new leading axis, so without an explicit constraint
    GSPMD may drop the DP sharding of the per-microbatch batch (observed:
    16x activation memory on the 400B MoE cell).

    The PU stage is whatever ``opt.update`` lowers to: construct the
    optimizer with ``fused=True`` (optim.optimizers) to run it as the
    Pallas fused-update kernel, or ``adamw(sketched=True)`` to hold the
    Adam moments as hash sketches refreshed inside that kernel (dense m/v
    never exist in HBM; the init-time ``sketch_pu_fits`` fallback means the
    state layout, not this builder, decides the path).  Callers should jit
    the returned step with
    ``donate_argnums=(0, 1)`` (as launch.train does) so XLA can reuse the
    donated param/state memory across the step (the kernel's own aliasing
    is at the packed-buffer level — see kernels.fused_update).

    ``fused_bwd`` (optional) overrides ``cfg.tt.fused_bwd`` for this step:
    with ``flow="kernel"``, True runs the BWD stage as the single fused
    Pallas kernel (``kernels.btt_backward``), False the operand-swap +
    XLA-GEMM reference path.  ``None`` keeps the config's setting.

    ``fused_attn`` (optional) likewise overrides ``cfg.fused_attn``: True
    runs training attention as the fused flash forward + single-kernel
    flash backward (only ``(O, m, l)`` saved per layer — no S×S
    probabilities), False the pure-JAX blockwise path under autodiff.

    ``fused_ffn`` (optional) likewise overrides ``cfg.fused_ffn``: with
    ``flow="kernel"``, True runs every eligible TT FFN block (incl.
    per-expert MoE FFNs) as the fused megakernel — both TT linears +
    activation in one Pallas kernel per direction, hidden state
    VMEM-resident, backward recomputing it from the layer input; False
    the two-call (three when gated) path.

    ``guard=True`` changes the signature to ``(params, opt_state, batch,
    ctrl) -> (params, opt_state, metrics)`` and routes the tail of the
    step through ``runtime.guard.apply_guarded_update``: one fused
    norm/all-finite reduction, the grad-tier escalation select, and the
    skip-step mask that keeps params AND the full optimizer state (dense,
    sketched, quant-master) untouched on a non-finite step.  ``ctrl``
    comes from ``TrainGuard.controls()`` (or ``guard_controls()``);
    metrics gain ``nonfinite``/``sat_frac``/``applied``.  The pipeline
    and DDP builders do not take a guard (their shard_map bodies own the
    collectives); ``launch.train`` rejects the combination.
    """
    if fused_bwd is not None:
        cfg = cfg.with_tt(fused_bwd=fused_bwd)
    if fused_attn is not None:
        cfg = cfg.with_fused_attn(fused_attn)
    if fused_ffn is not None:
        cfg = cfg.with_fused_ffn(fused_ffn)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, cfg, batch, remat=remat)

    def loss_and_grads(params, batch):
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            if batch_constraint is not None:
                mb = batch_constraint(mb)
            # Derive the f32 accumulator FROM params (p * 0) so it inherits
            # the parameter sharding — a bare jnp.zeros has no sharding
            # lineage and GSPMD may replicate 400B-class f32 accumulators.
            acc0 = jax.tree.map(
                lambda p: (p * 0).astype(jnp.float32), params)

            def body(acc, one):
                l, g = grads_of(params, one)
                m = one.get("mask")
                w = (m.astype(jnp.float32).sum() if m is not None
                     else jnp.asarray(float(one["labels"].size), jnp.float32))
                # g is d(nll_i/w_i)/dp — scale back to the nll_i gradient
                # so the accumulated sum divides by the GLOBAL token count.
                acc = jax.tree.map(
                    lambda a, gg: a + w * gg.astype(jnp.float32), acc, g)
                return acc, (l, w)

            grads, (losses, ws) = jax.lax.scan(body, acc0, mb)
            wsum = jnp.maximum(ws.sum(), 1.0)
            grads = jax.tree.map(lambda g: g / wsum, grads)
            loss = (losses * ws).sum() / wsum
        return loss, grads

    if guard:
        from repro.runtime.guard import apply_guarded_update

        def guarded_step(params, opt_state, batch, ctrl):
            loss, grads = loss_and_grads(params, batch)
            # The guarded tail owns the grad-tier cast (it needs both the
            # configured tier and the bf16 escalation in the graph) and
            # the clip (it reuses the finite-probe reduction as the norm).
            return apply_guarded_update(
                opt, loss, grads, params, opt_state, ctrl,
                grad_fmt=cfg.tt.precision.grad_dtype, clip_norm=clip_norm)

        return guarded_step

    def train_step(params, opt_state, batch):
        loss, grads = loss_and_grads(params, batch)
        grads = _grads_at_rest(grads, cfg)
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = _global_grad_norm(grads)
        params, opt_state = opt.update(grads, params, opt_state,
                                       opt_state["step"])
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_ddp_train_step(cfg: ModelConfig, opt: Optimizer, mesh, *,
                        compress: bool = True, clip_norm: float = 1.0):
    """Pure data-parallel step via shard_map with an int8 ring all-reduce.

    The natural pairing for the paper's technique: TT params are MBs and
    replicate for free, so DP is the whole story — and the gradient
    all-reduce (already 30-52x smaller from compression of the *model*)
    travels int8 with error feedback (runtime/compress.py) for another 4x.

    State: (params, opt_state, ef_residuals).  Returns a jitted callable
    ``(params, opt_state, ef, batch) -> (params, opt_state, ef, metrics)``.

    A ``fused=True`` optimizer composes with this path: params are
    replicated per-shard inside shard_map, so the fused PU kernel runs on
    each device's full (tiny, TT-compressed) parameter set — args 0/1 are
    donated below so XLA can reuse their memory across the step.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.runtime.compress import compressed_allreduce_mean, ef_compress_tree

    def step(params, opt_state, ef, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if compress:
            grads, ef = ef_compress_tree(grads, ef)
            grads = jax.tree.map(
                lambda g: compressed_allreduce_mean(g, "data"), grads)
        else:
            grads = jax.tree.map(
                lambda g: jax.lax.pmean(g, "data"), grads)
        loss = jax.lax.pmean(loss, "data")
        grads = _grads_at_rest(grads, cfg)
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = _global_grad_norm(grads)
        params, opt_state = opt.update(grads, params, opt_state,
                                       opt_state["step"])
        return params, opt_state, ef, {"loss": loss, "grad_norm": gnorm}

    rep = P()
    batch_spec = P("data")
    mapped = shard_map(
        step, mesh=mesh,
        in_specs=(rep, rep, rep, batch_spec),
        out_specs=(rep, rep, rep, rep),
        check_vma=False,  # ring ppermute breaks the replication checker
    )
    return jax.jit(mapped, donate_argnums=(0, 1, 2))


def make_pipeline_train_step(cfg: ModelConfig, opt: Optimizer, mesh, *,
                             microbatches: int = 1, clip_norm: float = 1.0,
                             remat: bool = True,
                             fused_bwd: bool | None = None,
                             fused_attn: bool | None = None,
                             fused_ffn: bool | None = None):
    """Pipeline × row-TP × DP training via shard_map, fused kernels fused.

    ``mesh`` must carry the ("stage", "data", "model") axes
    (``launch.mesh.make_host_mesh(stage=...)`` or
    ``runtime.pipeline.make_pipeline_mesh``).  Params and optimizer state
    replicate on every device — TT compression makes the whole tree MBs,
    so replication is free and there is no weight-sharding story to
    maintain; what scales out is COMPUTE: "stage" pipelines contiguous
    layer cycles GPipe-style over ``microbatches`` (ppermute handoff,
    fill/drain in one lax.scan — see runtime.pipeline), while "data" and
    "model" both shard activation rows ("model" is row-wise TP: each
    device launches the fused FFN/attention/BWD Pallas kernels on its own
    row shard, so the VMEM dispatch predicates see local shapes and
    fusion survives the mesh).  Gradients psum over all three axes and
    every device runs the identical optimizer update, keeping params
    replicated bit-for-bit.

    The global batch must divide by dp × tp × microbatches.  Loss is the
    global mask-weighted mean, so metrics match ``make_train_step`` on the
    same batch to f32 accumulation-order tolerance (asserted per step in
    tests/test_pipeline.py).  ``fused_*`` override the config knobs as in
    ``make_train_step``.  Returns a jitted
    ``(params, opt_state, batch) -> (params, opt_state, metrics)`` with
    args 0/1 donated.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.runtime.pipeline import (
        StagePartition,
        cycles_per_stage,
        pipeline_loss_and_grads,
    )

    if fused_bwd is not None:
        cfg = cfg.with_tt(fused_bwd=fused_bwd)
    if fused_attn is not None:
        cfg = cfg.with_fused_attn(fused_attn)
    if fused_ffn is not None:
        cfg = cfg.with_fused_ffn(fused_ffn)

    part = StagePartition.from_mesh(mesh, microbatches)
    cycles_per_stage(cfg, part.stages)  # validate the layer split up front

    def step(params, opt_state, batch):
        loss, grads = pipeline_loss_and_grads(params, cfg, batch, part,
                                              remat=remat)
        grads = _grads_at_rest(grads, cfg)
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = _global_grad_norm(grads)
        params, opt_state = opt.update(grads, params, opt_state,
                                       opt_state["step"])
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    rep = P()
    batch_spec = P(("data", "model"))  # rows split over DP × row-TP
    mapped = shard_map(
        step, mesh=mesh,
        in_specs=(rep, rep, batch_spec),
        out_specs=(rep, rep, rep),
        check_vma=False,  # stage ppermute breaks the replication checker
    )
    return jax.jit(mapped, donate_argnums=(0, 1))


def abstract_train_state(cfg: ModelConfig, opt: Optimizer):
    """(params, opt_state) as ShapeDtypeStructs — dry-run stand-ins."""
    from repro.models.transformer import init_params
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    opt_state = jax.eval_shape(opt.init, params)
    return params, opt_state


# ---------------------------------------------------------------------------
# Serving.
# ---------------------------------------------------------------------------


def make_prefill(cfg: ModelConfig):
    """(params, batch) -> (last_logits (B, 1, Vp), cache)."""

    def prefill(params, batch):
        logits, cache = forward(params, cfg, batch["tokens"],
                                patches=batch.get("patches"),
                                mode="prefill", remat=False)
        return logits[:, -1:, :], cache

    return prefill


def make_decode_step(cfg: ModelConfig):
    """(params, cache, tokens (B,1), pos ()) -> (logits (B,1,Vp), cache)."""

    def decode_step(params, cache, tokens, pos):
        logits, new_cache = forward(params, cfg, tokens, cache=cache,
                                    mode="decode", pos=pos, remat=False)
        return logits, new_cache

    return decode_step


def prepare_decode_cache(cfg: ModelConfig, prefill_cache: Any, prefill_len: int,
                         max_len: int, *, kv_repeat: int = 1) -> Any:
    """Convert a prefill cache into the decode layout.

    Attention KV: repeat heads to the TP degree, place into a zeroed
    ``max_len`` buffer (ring placement for windowed layers).  SSM / RG-LRU
    states come out of prefill already decode-ready and pass through.
    """
    def fix(leaf):
        if not isinstance(leaf, dict):
            return leaf
        return leaf

    def fix_kv(k: jax.Array, window: int | None) -> jax.Array:
        B, S, KV, dh = k.shape
        if kv_repeat > 1:
            k = jnp.repeat(k, kv_repeat, axis=2)
            KV *= kv_repeat
        if window is None:
            buf = jnp.zeros((B, max_len, KV, dh), k.dtype)
            return jax.lax.dynamic_update_slice(buf, k, (0, 0, 0, 0))
        w = min(window, max_len)
        buf = jnp.zeros((B, w, KV, dh), k.dtype)
        take = min(S, w)
        tail = k[:, S - take:, :, :]
        slots = (jnp.arange(S - take, S) % w)
        return buf.at[:, slots].set(tail)

    def walk(tree, kinds):
        out = []
        for blk, kind in zip(tree, kinds):
            if blk is None:
                out.append(None)
            elif "k" in blk and "v" in blk:
                window = cfg.window if kind == "attn_local" else None
                out.append({"k": fix_kv(blk["k"], window),
                            "v": fix_kv(blk["v"], window)})
            else:
                out.append(fix(blk))
        return tuple(out)

    pat = cfg.hybrid_pattern
    n_cycles = cfg.num_layers // len(pat)
    tail_kinds = pat[: cfg.num_layers - n_cycles * len(pat)]
    new = {"layers": None, "tail": ()}
    if prefill_cache["layers"] is not None:
        # stacked leaves have a leading cycle dim — vmap the fix over it
        def fix_stacked(blk, kind):
            if blk is None:
                return None
            if isinstance(blk, dict) and "k" in blk:
                window = cfg.window if kind == "attn_local" else None
                return {"k": jax.vmap(lambda a: fix_kv(a, window))(blk["k"]),
                        "v": jax.vmap(lambda a: fix_kv(a, window))(blk["v"])}
            return blk
        new["layers"] = tuple(
            fix_stacked(blk, kind)
            for blk, kind in zip(prefill_cache["layers"], pat))
    new["tail"] = walk(prefill_cache["tail"], tail_kinds)
    return new


# ---------------------------------------------------------------------------
# Dry-run inputs (ShapeDtypeStruct stand-ins; no allocation).
# ---------------------------------------------------------------------------


def make_inputs(cfg: ModelConfig, shape: ShapeConfig, *,
                kv_repeat: int = 1) -> dict:
    """Abstract inputs for one (arch x shape) cell.

    train:   {batch: {tokens, labels, mask [, patches]}}
    prefill: {batch: {tokens [, patches]}}
    decode:  {cache, tokens (B, 1), pos ()}   (serve_step: one new token
             against a seq_len cache — never a train_step)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
            "mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
        }
        if cfg.frontend == "patch":
            batch["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.d_model), dt)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.frontend == "patch":
            batch["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.d_model), dt)
        return {"batch": batch}
    if shape.kind == "decode":
        cache = cache_struct(cfg, B, S, kv_repeat=kv_repeat)
        return {
            "cache": cache,
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
        }
    raise ValueError(shape.kind)
