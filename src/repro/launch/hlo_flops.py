"""Trip-count-aware FLOP / byte / collective accounting over compiled HLO.

Why this exists: ``compiled.cost_analysis()`` counts every ``while`` body
ONCE, but our models scan over layer cycles, microbatches and attention
chunks — so its ``flops`` under-counts by the product of trip counts (24-64x
observed), and the same defect hides collectives executed inside scan bodies.
This walker parses the partitioned HLO text and aggregates:

  * flops:  2·M·N·K per dot (operand shapes resolved by name), 1/elem for
            arithmetic elementwise ops, recursing through fusions / calls /
            conditionals, and multiplying while bodies by their trip count
            (parsed from the loop-condition constant).
  * bytes:  at fusion granularity — sum of (result + operands) for each
            non-nested op in ENTRY / while bodies.  This approximates HBM
            traffic the way XLA's own model does (fusion internals stay in
            registers/VMEM).
  * collectives: result bytes + estimated wire bytes per op type x trips
            (replica-group size parsed per op).

All numbers are per device: the partitioned module is the per-device program.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["analyze_hlo", "HloStats"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# Header params may be tuple-typed (nested parens) — anchor on `-> ... {`.
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.-]+)\s*=\s*(.*?)\s*([a-z][\w-]*)\((.*)$")
_SHAPE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w.-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.-]+)")
_COND = re.compile(r"condition=%?([\w.-]+)")
_BODY = re.compile(r"body=%?([\w.-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_BRACED = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "cbrt", "negate", "abs", "sine", "cosine", "logistic",
    "floor", "ceil", "round-nearest-afz", "atan2", "remainder", "erf",
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


@dataclasses.dataclass
class _Instr:
    name: str
    result_bytes: int
    shape_dims: tuple[int, ...] | None  # first array shape (dots etc.)
    opcode: str
    operands: list[str]
    tail: str  # raw text after the opcode's '(' (attrs included)


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_result_bytes: float = 0.0
    dus_update_bytes: float = 0.0  # in-place update slices (aliasing hint)
    collective_counts: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "HloStats", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.transcendentals += mult * other.transcendentals
        self.collective_wire_bytes += mult * other.collective_wire_bytes
        self.collective_result_bytes += mult * other.collective_result_bytes
        self.dus_update_bytes += mult * other.dus_update_bytes
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + mult * v

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "transcendentals": self.transcendentals,
            "collective_wire_bytes": self.collective_wire_bytes,
            "collective_result_bytes": self.collective_result_bytes,
            "collective_counts": {k: float(v) for k, v in
                                  self.collective_counts.items()},
        }


def _shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(text: str) -> tuple[int, ...] | None:
    m = _SHAPE.search(text)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


def _parse(text: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    cur: list[_Instr] | None = None
    for line in text.splitlines():
        mstart = _COMP_START.match(line)
        if mstart and not line.lstrip().startswith("%param"):
            cur = []
            comps[mstart.group(1)] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        name, result_txt, opcode, rest = mi.groups()
        # operands live in the first balanced paren group of `rest`
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_txt = rest[:end]
        tail = rest[end:]
        cur.append(_Instr(
            name=name,
            result_bytes=_shapes_bytes(result_txt),
            shape_dims=_first_shape(result_txt),
            opcode=opcode,
            operands=_OPERAND.findall(operand_txt),
            tail=operand_txt + tail,
        ))
    return comps


def _trip_count(comps: dict, cond_name: str) -> int:
    """The loop bound is the largest integer constant in the condition."""
    best = 1
    for ins in comps.get(cond_name, ()):
        for m in _CONST_INT.finditer(ins.tail):
            best = max(best, int(m.group(1)))
        if ins.opcode == "constant":
            # operand parens already stripped: tail is e.g. "24)"
            m = re.search(r"(\d+)", ins.tail)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _group_size(tail: str) -> int:
    m = _GROUPS_IOTA.search(tail)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_BRACED.search(tail)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2


# Dtype-emulation artifacts: XLA:CPU lowers bf16 compute as
# convert-to-f32 -> f32 op -> convert-back, materializing whole-buffer f32
# copies of weights and KV caches that the TPU target (native bf16 MXU)
# never creates.  These opcodes are transparent for byte accounting.
_TRANSPARENT = ("convert", "bitcast", "copy", "reshape")


def _sliced_params(comps: dict, name: str) -> dict[int, int]:
    """For a fused computation: parameter index -> bytes actually touched,
    for parameters that are only read through dynamic-slice (or updated via
    dynamic-update-slice), possibly behind transparent dtype converts.  Used
    to avoid charging a whole scan-stacked buffer for every iteration."""
    instrs = comps.get(name, ())
    by_name = {i.name: i for i in instrs}
    param_idx: dict[str, int] = {}
    for ins in instrs:
        if ins.opcode == "parameter":
            m = re.search(r"(\d+)", ins.tail)
            if m:
                param_idx[ins.name] = int(m.group(1))
    # Propagate param identity through transparent ops (same element count).
    alias_of: dict[str, str] = {}

    def root_param(nm: str) -> str | None:
        seen = set()
        while nm in alias_of and nm not in seen:
            seen.add(nm)
            nm = alias_of[nm]
        return nm if nm in param_idx else None

    for ins in instrs:
        if ins.opcode in _TRANSPARENT and ins.operands:
            alias_of[ins.name] = ins.operands[0]
    touched: dict[int, int] = {}
    whole: set[int] = set()
    for ins in instrs:
        if ins.opcode in _TRANSPARENT:
            continue
        for pos, opnd in enumerate(ins.operands):
            src = root_param(opnd) or (opnd if opnd in param_idx else None)
            if src is None:
                continue
            idx = param_idx[src]
            if ins.opcode == "dynamic-slice" and pos == 0:
                touched[idx] = touched.get(idx, 0) + ins.result_bytes
            elif ins.opcode == "dynamic-update-slice" and pos == 0:
                upd = by_name.get(ins.operands[1]) if len(ins.operands) > 1 else None
                touched[idx] = touched.get(idx, 0) + (upd.result_bytes if upd else 0)
            else:
                whole.add(idx)
    return {i: b for i, b in touched.items() if i not in whole}


def _is_transparent_fusion(comps: dict, name: str) -> bool:
    """True if the fused computation only converts/copies (dtype emulation)."""
    for ins in comps.get(name, ()):
        if ins.opcode in ("parameter", "constant", "tuple",
                          "get-tuple-element") or ins.opcode in _TRANSPARENT:
            continue
        return False
    return True


def _comp_stats(comps: dict, name: str, memo: dict, *,
                top_level: bool) -> HloStats:
    key = (name, top_level)
    if key in memo:
        return memo[key]
    memo[key] = HloStats()  # cycle guard
    stats = HloStats()
    by_name = {i.name: i for i in comps.get(name, ())}
    for ins in comps.get(name, ()):
        op = ins.opcode
        if op == "dot":
            mC = _LHS_C.search(ins.tail)
            contract = 1
            if mC and ins.operands:
                lhs = by_name.get(ins.operands[0])
                if lhs is not None and lhs.shape_dims is not None and mC.group(1):
                    for idx in mC.group(1).split(","):
                        i = int(idx)
                        if i < len(lhs.shape_dims):
                            contract *= lhs.shape_dims[i]
            out_elems = 1
            for d in (ins.shape_dims or ()):
                out_elems *= d
            stats.flops += 2.0 * out_elems * contract
            if top_level:
                stats.bytes += ins.result_bytes + sum(
                    by_name[o].result_bytes for o in ins.operands
                    if o in by_name)
        elif op in _ELEMENTWISE:
            out_elems = 1
            for d in (ins.shape_dims or ()):
                out_elems *= d
            stats.flops += out_elems
            if op in ("exponential", "tanh", "log", "logistic", "erf",
                      "sine", "cosine", "power"):
                stats.transcendentals += out_elems
        elif op == "fusion":
            mc = _CALLS.search(ins.tail)
            inner = None
            sliced: dict[int, int] = {}
            if mc:
                inner = _comp_stats(comps, mc.group(1), memo, top_level=False)
                stats.add(inner)
                sliced = _sliced_params(comps, mc.group(1))
            if top_level:
                if mc and _is_transparent_fusion(comps, mc.group(1)):
                    continue  # dtype-emulation fusion: no TPU traffic
                reads = 0
                for idx, opnd in enumerate(ins.operands):
                    b = by_name[opnd].result_bytes if opnd in by_name else 0
                    reads += sliced.get(idx, b)
                if inner is not None and inner.dus_update_bytes > 0 and \
                        ins.result_bytes > 2 * inner.dus_update_bytes:
                    # root is an in-place slab update: write = the slice
                    writes = inner.dus_update_bytes
                else:
                    writes = ins.result_bytes
                stats.bytes += reads + writes
        elif op == "while":
            mb, mcond = _BODY.search(ins.tail), _COND.search(ins.tail)
            trips = _trip_count(comps, mcond.group(1)) if mcond else 1
            if mb:
                body = _comp_stats(comps, mb.group(1), memo, top_level=True)
                stats.add(body, mult=trips)
        elif op == "conditional":
            mbr = _BRANCHES.search(ins.tail)
            if mbr:
                branches = _OPERAND.findall(mbr.group(1))
                if branches:
                    subs = [_comp_stats(comps, b, memo, top_level=top_level)
                            for b in branches]
                    best = max(subs, key=lambda s: s.flops)
                    stats.add(best)
        elif op in ("call", "async-start"):
            mc = _CALLS.search(ins.tail)
            if mc:
                stats.add(_comp_stats(comps, mc.group(1), memo,
                                      top_level=top_level))
        elif any(op.startswith(c) for c in _COLLECTIVES) and \
                not op.endswith("-done"):
            base = next(c for c in _COLLECTIVES if op.startswith(c))
            k = _group_size(ins.tail)
            rb = ins.result_bytes
            if base == "all-reduce":
                wb = 2.0 * rb * (k - 1) / k
            elif base == "all-gather":
                wb = rb * (k - 1) / k
            elif base == "reduce-scatter":
                wb = float(rb * (k - 1))
            elif base == "all-to-all":
                wb = rb * (k - 1) / k
            else:  # collective-permute
                wb = float(rb)
            stats.collective_wire_bytes += wb
            stats.collective_result_bytes += rb
            stats.collective_counts[base] = \
                stats.collective_counts.get(base, 0) + 1
            if top_level:
                stats.bytes += 2 * rb
        elif op == "dynamic-update-slice":
            # In-place update: traffic is the slice (read+write), not the
            # aliased full buffer — critical for scan-stacked caches where
            # the full-buffer convention over-counts by the trip count.
            upd = by_name.get(ins.operands[1]) if len(ins.operands) > 1 else None
            upd_b = upd.result_bytes if upd else 0
            stats.dus_update_bytes += upd_b
            if top_level:
                stats.bytes += 2 * upd_b
        elif top_level and op not in ("parameter", "constant", "tuple",
                                      "get-tuple-element", "bitcast",
                                      "convert", "copy", "reshape"):
            stats.bytes += ins.result_bytes
    memo[key] = stats
    return stats


def analyze_hlo(text: str) -> HloStats:
    comps = _parse(text)
    # entry computation: the one named on the ENTRY line
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_START.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: the largest computation
        entry = max(comps, key=lambda k: len(comps[k]))
    return _comp_stats(comps, entry, {}, top_level=True)
