"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set XLA_FLAGS
*before* the first jax device query, and smoke tests must keep seeing one
CPU device.

Mesh topology (TPU v5e pods):
  single-pod:  (data=16, model=16)           — 256 chips
  multi-pod:   (pod=2, data=16, model=16)    — 512 chips; "pod" is an outer
               DP axis whose gradient all-reduce crosses the inter-pod links
               (DCN/optical); the dry-run proves the partitioner threads it.
The sharding rule engine (runtime.sharding) is axis-name driven, so larger
meshes (more pods, separate "expert"/"seq" axes) need no model-code changes.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = ((16, 16), ("data", "model"))
MULTI_POD = ((2, 16, 16), ("pod", "data", "model"))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, stage: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples).

    Axis requests are clamped and validated, not trusted: zero/negative
    requests clamp to 1 (``data=0`` used to ZeroDivisionError on the
    ``n // data`` fit check), oversubscribed requests shrink model-first
    then data to fit the device count, and every axis ends up >= 1.

    ``stage > 1`` builds the pipeline topology ("stage", "data", "model")
    used by ``launch.steps.make_pipeline_train_step``.  Unlike data/model,
    a stage request that cannot be satisfied RAISES instead of clamping:
    silently running a different pipeline depth than requested would change
    the training program, not just its layout.
    """
    n = len(jax.devices())
    stage = max(int(stage), 1)
    if stage > n or n % stage:
        raise ValueError(
            f"stage={stage} does not divide the {n} available device(s)")
    avail = n // stage
    data = min(max(int(data), 1), avail)
    model = min(max(int(model), 1), max(avail // data, 1))
    if stage > 1:
        return jax.make_mesh((stage, data, model),
                             ("stage", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
