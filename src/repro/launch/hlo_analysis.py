"""HLO post-processing: collective-bytes accounting + roofline terms.

``cost_analysis()`` has no collective traffic entry, so the collective
roofline term is derived by parsing the compiled (SPMD-partitioned,
per-device) HLO text and summing the sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Per-op accounting (per device).  The partitioned HLO names operands without
shapes, so sizes are derived from the *result* shape plus the replica-group
size ``k`` parsed from ``replica_groups``:
  * all-reduce        wire = 2·R·(k-1)/k   (ring reduce-scatter + all-gather)
  * all-gather        wire =   R·(k-1)/k   (operand is R/k)
  * reduce-scatter    wire =   R·(k-1)     (operand is R·k)
  * all-to-all        wire =   R·(k-1)/k
  * collective-permute wire =  R            (one hop send)

Hardware constants for TPU v5e are in ``V5E``.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["V5E", "CollectiveStats", "parse_collectives", "roofline_terms"]


@dataclasses.dataclass(frozen=True)
class V5E:
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link (~per chip effective)


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*[^=]*?\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    result_bytes: dict
    operand_bytes: dict
    wire_bytes: dict

    @property
    def total_wire_bytes(self) -> int:
        return sum(self.wire_bytes.values())

    @property
    def total_result_bytes(self) -> int:
        return sum(self.result_bytes.values())

    def as_dict(self) -> dict:
        return {
            "counts": self.counts,
            "result_bytes": self.result_bytes,
            "operand_bytes": self.operand_bytes,
            "wire_bytes": self.wire_bytes,
            "total_wire_bytes": self.total_wire_bytes,
        }


_GROUPS_BRACED_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)       # [n_groups, group_size]<=[N]
    m = _GROUPS_BRACED_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    res_b: dict[str, int] = {}
    opd_b: dict[str, int] = {}
    wire_b: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        head = line[: m.start(1)]
        rb = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        if rb == 0:
            continue
        k = _group_size(line)
        if op == "all-reduce":
            ob = rb
            wb = int(2 * rb * (k - 1) / k)
        elif op == "all-gather":
            ob = rb // k
            wb = int(rb * (k - 1) / k)
        elif op == "reduce-scatter":
            ob = rb * k
            wb = rb * (k - 1)
        elif op == "all-to-all":
            ob = rb
            wb = int(rb * (k - 1) / k)
        else:                                # collective-permute (one hop)
            ob = rb
            wb = rb
        counts[op] = counts.get(op, 0) + 1
        res_b[op] = res_b.get(op, 0) + rb
        opd_b[op] = opd_b.get(op, 0) + ob
        wire_b[op] = wire_b.get(op, 0) + wb
    return CollectiveStats(counts, res_b, opd_b, wire_b)


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   wire_bytes_per_device: float, hw: V5E = V5E()) -> dict:
    """The three §Roofline terms, in seconds (per device == per step since
    the partitioned module is per-device)."""
    t_compute = flops_per_device / hw.peak_flops
    t_memory = bytes_per_device / hw.hbm_bw
    t_collective = wire_bytes_per_device / hw.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k] if k.endswith("_s") else -1)
    return terms
