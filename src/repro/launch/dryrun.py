import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent without TPUs.

For every (architecture x input-shape) cell this driver:
  1. builds the production mesh — (16, 16) single-pod or (2, 16, 16)
     multi-pod — over 512 placeholder host devices,
  2. derives parameter / optimizer / batch / cache shardings from the rule
     engine (runtime.sharding),
  3. ``jax.jit(step).lower(**ShapeDtypeStruct inputs).compile()`` — no
     buffer is ever allocated,
  4. records ``memory_analysis()`` (fits-per-device proof),
     ``cost_analysis()`` (FLOPs / bytes for §Roofline) and the
     collective-bytes breakdown parsed from the partitioned HLO,
  5. writes one JSON artifact per cell under ``artifacts/dryrun/``.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --tt --multi-pod
  python -m repro.launch.dryrun --all              # every supported cell
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, list_archs
from repro.core.meshctx import activation_mesh
from repro.launch.hlo_analysis import parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    abstract_train_state,
    make_decode_step,
    make_inputs,
    make_prefill,
    make_train_step,
)
from repro.models.transformer import init_params
from repro.optim import sgd
from repro.runtime.sharding import (
    batch_specs,
    cache_specs,
    kv_repeat_for_mesh,
    named_sharding_tree,
    opt_state_specs,
    param_specs,
)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _mem_dict(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = int(v)
    except Exception as e:  # noqa: BLE001 — backend-dependent availability
        out["error"] = repr(e)
    return out


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and not k.startswith("utilization")}
    except Exception as e:  # noqa: BLE001
        return {"error": repr(e)}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, tt: bool,
             out_dir: str, microbatches: int = 1, save_hlo: bool = False) -> dict:
    cfg = get_config(arch)
    if tt:
        cfg = cfg.with_tt(mode="tt")
    shape = SHAPES[shape_name]
    if shape.kind != "train":
        microbatches = 1  # gradient accumulation is a train-only knob
    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "tt_mode": cfg.tt.mode, "dtype": cfg.dtype,
        "mesh": "pod2_data16_model16" if multi_pod else "data16_model16",
        "microbatches": microbatches,
    }
    if shape_name not in cfg.supported_shapes:
        rec["status"] = "skipped"
        rec["skip_reason"] = cfg.skip_notes or "unsupported shape"
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    kvr = kv_repeat_for_mesh(cfg, mesh)
    inputs = make_inputs(cfg, shape, kv_repeat=kvr)
    t0 = time.time()
    with activation_mesh(mesh):
        lowered = _lower_cell(cfg, shape, mesh, kvr, inputs, microbatches)
    rec["lower_s"] = round(time.time() - t0, 2)

    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)
    rec["status"] = "ok"
    rec["devices"] = int(np.prod(list(mesh.shape.values())))
    rec["kv_repeat"] = kvr
    rec["memory_analysis"] = _mem_dict(compiled)
    rec["cost_analysis"] = _cost_dict(compiled)
    hlo = compiled.as_text()
    rec["collectives"] = parse_collectives(hlo).as_dict()
    rec["hlo_lines"] = hlo.count("\n")
    if save_hlo:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, _cell_name(rec) + ".hlo.txt"), "w") as f:
            f.write(hlo)
    return rec


def _lower_cell(cfg, shape, mesh, kvr, inputs, microbatches):
    if shape.kind == "train":
        opt = sgd(1e-3)  # paper-faithful PU stage; zero optimizer state
        params_s, opt_s = abstract_train_state(cfg, opt)
        pspec = param_specs(cfg, params_s, mesh)
        sspec = opt_state_specs(cfg, opt_s, pspec, mesh)
        bspec = batch_specs(inputs["batch"], mesh)

        def mb_constraint(tree, _bspec=bspec, _mesh=mesh):
            return jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, NamedSharding(_mesh, P(None, *tuple(s)))),
                tree, _bspec)

        fn = make_train_step(cfg, opt, microbatches=microbatches,
                             batch_constraint=mb_constraint)
        jitted = jax.jit(
            fn,
            in_shardings=(named_sharding_tree(mesh, pspec),
                          named_sharding_tree(mesh, sspec),
                          named_sharding_tree(mesh, bspec)),
            out_shardings=(named_sharding_tree(mesh, pspec),
                           named_sharding_tree(mesh, sspec),
                           NamedSharding(mesh, P())),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_s, opt_s, inputs["batch"])
    elif shape.kind == "prefill":
        params_s = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        pspec = param_specs(cfg, params_s, mesh)
        bspec = batch_specs(inputs["batch"], mesh)
        fn = make_prefill(cfg)
        jitted = jax.jit(
            fn,
            in_shardings=(named_sharding_tree(mesh, pspec),
                          named_sharding_tree(mesh, bspec)),
        )
        lowered = jitted.lower(params_s, inputs["batch"])
    else:  # decode
        params_s = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        pspec = param_specs(cfg, params_s, mesh)
        cspec = cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        tspec = batch_specs({"tokens": inputs["tokens"]}, mesh)["tokens"]
        fn = make_decode_step(cfg)
        jitted = jax.jit(
            fn,
            in_shardings=(named_sharding_tree(mesh, pspec),
                          named_sharding_tree(mesh, cspec),
                          NamedSharding(mesh, tspec),
                          NamedSharding(mesh, P())),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_s, inputs["cache"], inputs["tokens"],
                               inputs["pos"])
    return lowered


def _cell_name(rec: dict) -> str:
    tt = "tt" if rec["tt_mode"] == "tt" else "dense"
    mp = "mp2" if rec["multi_pod"] else "sp"
    mb = f"_mb{rec['microbatches']}" if rec.get("microbatches", 1) != 1 else ""
    return f"{rec['arch']}__{rec['shape']}__{tt}__{mp}{mb}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tt", action="store_true",
                    help="enable the paper's TT/TTM compression")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in list_archs():
            if arch == "atis-transformer":
                continue  # paper model exercised by benchmarks, not the grid
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        try:
            rec = run_cell(arch, shape, multi_pod=args.multi_pod, tt=args.tt,
                           out_dir=args.out, microbatches=args.microbatches,
                           save_hlo=args.save_hlo)
        except Exception:  # noqa: BLE001
            rec = {"arch": arch, "shape": shape, "multi_pod": args.multi_pod,
                   "tt_mode": "tt" if args.tt else "off", "status": "error",
                   "microbatches": args.microbatches,
                   "traceback": traceback.format_exc()}
            failures += 1
        path = os.path.join(args.out, _cell_name(rec) + ".json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            ca = rec["cost_analysis"]
            extra = (f" flops={ca.get('flops', 0):.3e}"
                     f" lower={rec['lower_s']}s compile={rec['compile_s']}s")
        elif status == "skipped":
            extra = f" ({rec['skip_reason'][:60]})"
        print(f"[{status:7s}] {_cell_name(rec)}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
