"""Training driver: data pipeline -> sharded train loop -> checkpoints.

Runs anywhere: the same loop drives a reduced config on the host CPU (CI,
examples) and a full config on a TPU pod slice — only the mesh and config
change.  Demonstrates the full fault-tolerance story:

  * deterministic seekable data (batch = f(seed, step)) — restart-exact
  * async atomic checkpoints with keep-k + adaptive cadence + per-leaf CRC
  * straggler monitor on per-step wall time
  * resume: picks up at the newest VALID checkpoint step (corrupt steps
    are skipped and pruned), data stream realigns
  * ``--guard``: numerics sentry + skip/backoff/rollback escalation
    (runtime.guard), chaos-tested in tests/test_robustness.py

Usage (CPU example — reduced qwen3 with the paper's TT compression):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --tt \
      --steps 50 --batch 8 --seq 128 --scale-down --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import lm_batch
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_pipeline_train_step, make_train_step
from repro.models.transformer import init_params, num_params, param_bytes
from repro.optim import adamw, master_view, sgd, warmup_cosine
from repro.runtime import (
    CheckpointCadence,
    StragglerMonitor,
    batch_specs,
    named_sharding_tree,
    opt_state_specs,
    param_specs,
)


def build(args):
    cfg = get_config(args.arch)
    if args.scale_down:
        cfg = cfg.scaled_down()
    if args.tt:
        cfg = cfg.with_tt(mode="tt", rank=args.tt_rank,
                          embed_rank=args.tt_rank)
    if args.kernel_flow:
        cfg = cfg.with_tt(flow="kernel")
    if args.fused_attn is not None:
        cfg = cfg.with_fused_attn(args.fused_attn)
    if args.fused_ffn is not None:
        cfg = cfg.with_fused_ffn(args.fused_ffn)
    if args.fp32:
        import dataclasses
        cfg = dataclasses.replace(cfg, dtype="float32")
    if args.param_dtype or args.act_dtype or args.grad_dtype:
        cfg = cfg.with_precision(
            **{k: v for k, v in (("param_dtype", args.param_dtype),
                                 ("act_dtype", args.act_dtype),
                                 ("grad_dtype", args.grad_dtype)) if v})
    return cfg


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--tt", action="store_true")
    ap.add_argument("--tt-rank", type=int, default=16)
    ap.add_argument("--scale-down", action="store_true")
    ap.add_argument("--fp32", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", choices=("sgd", "adamw"), default="adamw")
    ap.add_argument("--fused", action="store_true",
                    help="run the PU stage as the Pallas fused-update "
                         "kernel (interpret mode off-TPU)")
    ap.add_argument("--kernel-flow", action="store_true",
                    help="run TT linears through the fused Pallas kernels "
                         "(flow='kernel'; interpret mode off-TPU)")
    ap.add_argument("--fused-bwd", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="with --kernel-flow: run the BWD stage as the "
                         "single fused Pallas kernel (--no-fused-bwd "
                         "forces the operand-swap + XLA-GEMM path; "
                         "unset keeps the config's fused_bwd)")
    ap.add_argument("--fused-attn", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="run training attention as the fused flash "
                         "forward + single-kernel flash backward (only "
                         "(O, m, l) saved per layer; --no-fused-attn "
                         "forces the pure-JAX blockwise path; unset keeps "
                         "the config's fused_attn)")
    ap.add_argument("--fused-ffn", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="with --kernel-flow: run eligible TT FFN blocks "
                         "as the fused megakernel (both TT linears + "
                         "activation in one Pallas kernel per direction; "
                         "hidden state never leaves VMEM; --no-fused-ffn "
                         "forces the two-call path; unset keeps the "
                         "config's fused_ffn)")
    ap.add_argument("--sketched-opt", action="store_true",
                    help="with --optimizer adamw: hold the Adam moments as "
                         "count-min/count-sketch hash sketches refreshed "
                         "inside the fused PU kernel — dense m/v never "
                         "exist in HBM (falls back to dense fused AdamW "
                         "when the sketch fails sketch_pu_fits)")
    ap.add_argument("--sketch-width", type=int, default=None,
                    help="sketch buckets per row (power of two; default "
                         "default_sketch_width: ~n_params/(8*depth))")
    ap.add_argument("--sketch-depth", type=int, default=None,
                    help="sketch hash rows (default 3)")
    ap.add_argument("--param-dtype", default=None,
                    choices=("float32", "bfloat16", "int8", "fp8_e4m3"),
                    help="at-rest storage for TT half-factors AND the "
                         "fused-update master parameters (core.quant): "
                         "scaled formats dequantize inside the kernels and "
                         "re-round stochastically at the update write; "
                         "fp8 is emulated (tiles upcast to f32 in VMEM "
                         "before the dot)")
    ap.add_argument("--act-dtype", default=None,
                    choices=("float32", "bfloat16", "int8", "fp8_e4m3"),
                    help="at-rest storage for the saved backward residuals "
                         "(TT layer inputs; flash (q, k, v, o)); unset "
                         "follows the model compute dtype")
    ap.add_argument("--grad-dtype", default=None,
                    choices=("float32", "bfloat16", "fp8_e5m2"),
                    help="gradient at-rest storage between BWD and PU "
                         "(fp8_e5m2 is self-describing — no scale; int8 "
                         "is rejected)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--guard", action="store_true",
                    help="arm the training guard (runtime.guard): one "
                         "fused all-finite + grad-norm probe inside the "
                         "jitted step, EWMA loss/grad-norm spike "
                         "detection, and the skip-step -> lr-backoff -> "
                         "rollback escalation ladder; quant-saturation "
                         "sentinel auto-escalates the grad tier "
                         "fp8_e5m2->bf16 (single-device loop only)")
    ap.add_argument("--rollback-after", type=int, default=4,
                    help="with --guard: consecutive bad steps before "
                         "rolling back to the last-good snapshot / newest "
                         "valid checkpoint")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="0 = adaptive cadence")
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--pipeline-stages", type=int, default=1,
                    help="GPipe pipeline stages over the layer stack "
                         "(shard_map on a 'stage' mesh axis; params stay "
                         "replicated, activations hand off via ppermute). "
                         ">1 switches to make_pipeline_train_step")
    ap.add_argument("--tp", type=int, default=1,
                    help="with --pipeline-stages: row-wise tensor-parallel "
                         "shards on the 'model' axis (activation rows "
                         "split; TT cores replicated so fused kernels "
                         "stay fused)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = build(args)
    pipelined = args.pipeline_stages > 1 or args.tp > 1
    if args.guard and pipelined:
        ap.error("--guard supports the single-device loop only (the "
                 "pipeline/TP shard_map bodies own their collectives)")
    if pipelined:
        mesh = make_host_mesh(args.data_axis, args.tp,
                              stage=args.pipeline_stages)
    else:
        mesh = make_host_mesh(args.data_axis, args.model_axis)
    vocab = cfg.vocab_size

    lr = warmup_cosine(args.lr, max(args.steps // 20, 1), args.steps)
    opt = (sgd(lr, fused=args.fused) if args.optimizer == "sgd"
           else adamw(lr, fused=args.fused, sketched=args.sketched_opt,
                      sketch_width=args.sketch_width,
                      sketch_depth=args.sketch_depth,
                      param_format=cfg.tt.precision.param_dtype))

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    opt_state = opt.init(params)
    # Quantized-master states own the only parameter copy; align step 1's
    # forward with the storage grid (identity for unquantized states).
    params = master_view(opt_state, params)
    guard = None
    if args.guard:
        from repro.runtime.guard import GuardPolicy, TrainGuard
        guard = TrainGuard(GuardPolicy(rollback_after=args.rollback_after))
        # The lr_scale leaf rides in the optimizer state (checkpointed,
        # sharded replicated) so backoff/recovery never retraces the step.
        opt_state = guard.attach(opt_state)
    print(f"[train] arch={cfg.name} tt={cfg.tt.mode} params={num_params(params):,} "
          f"({param_bytes(params)/1e6:.1f} MB) mesh={dict(mesh.shape)}")

    if pipelined:
        # shard_map owns the partitioning: params/opt state replicated,
        # batch rows split over ("data", "model").  No GSPMD specs or
        # device_put — the jitted step shards its own inputs.
        psh = ssh = bsh = None
        step_fn = make_pipeline_train_step(
            cfg, opt, mesh, microbatches=args.microbatches,
            fused_bwd=args.fused_bwd)
    else:
        train_step = make_train_step(cfg, opt,
                                     microbatches=args.microbatches,
                                     fused_bwd=args.fused_bwd,
                                     guard=args.guard)
        pspec = param_specs(cfg, params, mesh)
        sspec = opt_state_specs(cfg, opt_state, pspec, mesh)
        sample = lm_batch(args.seed, 0, args.batch, args.seq, vocab)
        bspec = batch_specs(sample, mesh)
        psh = named_sharding_tree(mesh, pspec)
        ssh = named_sharding_tree(mesh, sspec)
        bsh = named_sharding_tree(mesh, bspec)
        params = jax.tree.map(jax.device_put, params, psh)
        opt_state = jax.tree.map(jax.device_put, opt_state, ssh)

        if args.guard:
            # ctrl scalars replicate (no in_sharding constraint needed).
            step_fn = jax.jit(train_step,
                              in_shardings=(psh, ssh, bsh, None),
                              out_shardings=(psh, ssh, None),
                              donate_argnums=(0, 1))
        else:
            step_fn = jax.jit(train_step, in_shardings=(psh, ssh, bsh),
                              out_shardings=(psh, ssh, None),
                              donate_argnums=(0, 1))

    start = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)

        def template():
            p = init_params(jax.random.PRNGKey(args.seed), cfg)
            s = opt.init(p)
            return (p, guard.attach(s) if guard is not None else s)

        tmpl = jax.eval_shape(template)
        if guard is not None:
            guard.manager, guard.template = mgr, tmpl
        # Walks past corrupt/truncated steps (CRC-verified) instead of
        # crashing on a bad latest checkpoint; repairs the manifest.
        got = mgr.restore_latest_valid(tmpl)
        if got is not None:
            (params_h, opt_h), start = got
            if psh is None:
                params, opt_state = params_h, opt_h
            else:
                params = jax.tree.map(jax.device_put, params_h, psh)
                opt_state = jax.tree.map(jax.device_put, opt_h, ssh)
            print(f"[train] resumed from step {start}")

    monitor = StragglerMonitor()
    cadence = CheckpointCadence(base_interval=max(args.steps // 4, 1),
                                min_interval=max(args.steps // 10, 1))
    losses = []
    next_ckpt = None
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 lm_batch(args.seed, step, args.batch, args.seq, vocab).items()}
        if bsh is not None:
            batch = jax.tree.map(jax.device_put, batch, bsh)
        t0 = time.time()
        if guard is not None:
            params, opt_state, metrics = step_fn(params, opt_state, batch,
                                                 guard.controls())
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        flagged = monitor.observe(dt)
        action = "ok"
        if guard is not None:
            params, opt_state, action = guard.observe(step, metrics, params,
                                                      opt_state)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            tag = "" if action == "ok" else f"  GUARD:{action.upper()}"
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"{dt*1e3:7.1f} ms{'  STRAGGLER' if flagged else ''}{tag}")
        if mgr is not None:
            interval = args.ckpt_every or cadence.interval(monitor)
            if next_ckpt is None:
                next_ckpt = step + interval
            if step + 1 >= next_ckpt or step == args.steps - 1:
                mgr.save_async(step + 1, (params, opt_state))
                next_ckpt = step + 1 + interval
    if mgr is not None:
        mgr.wait()
    out = {"final_loss": losses[-1] if losses else None,
           "first_loss": losses[0] if losses else None,
           "straggler_flags": monitor.total_flags}
    if guard is not None:
        out["guard"] = guard.report()
    return out


if __name__ == "__main__":
    out = main()
    print(f"[train] done: first={out['first_loss']:.4f} "
          f"final={out['final_loss']:.4f}")
