"""Serving driver: continuous-batched paged decode (attention families) or
lockstep dense-cache decode (ssm/rec hybrids).

Attention-family configs (every block kind in {attn, attn_moe, attn_local})
run the PAGED path — the serving stack this repo's decode kernels target:

  * ``runtime.PagedDecodeEngine`` — flash-decode Pallas attention against a
    paged KV cache, decode-shape BTT linear/FFN kernels, per-slot positions;
  * ``runtime.Scheduler`` — FIFO continuous batching: solo prefill on
    admission, one batched decode step over every running slot, retirement
    on EOS/budget, the freed slot refilled from the queue head.

Families with recurrent state (ssm/rec hybrids) keep the legacy lockstep
path: batched prefill, cache conversion to the decode layout (ring
placement for windowed layers, KV-head repeat to the TP degree),
token-by-token decode.

Usage (CPU examples):
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --tt \
      --kernel-flow --scale-down --batch 4 --prompt-len 64 --gen 32
  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
      --scale-down --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import lm_batch
from repro.kernels.flash_decode import DEFAULT_PAGE_SIZE
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_decode_step, make_prefill, prepare_decode_cache
from repro.models.transformer import init_params, num_params
from repro.runtime import kv_repeat_for_mesh
from repro.runtime.decode_engine import (PagedDecodeEngine,
                                         finite_logit_rows, paged_supported)
from repro.runtime.scheduler import Request, Scheduler


def build(args):
    """Same config construction as ``launch.train.build`` — serving runs
    the flags it was trained with (tt rank, kernel flow, fused attn/ffn)."""
    cfg = get_config(args.arch)
    if args.scale_down:
        cfg = cfg.scaled_down()
    if args.tt:
        cfg = cfg.with_tt(mode="tt", rank=args.tt_rank,
                          embed_rank=args.tt_rank)
    if args.kernel_flow:
        cfg = cfg.with_tt(flow="kernel")
    if args.fused_attn is not None:
        cfg = cfg.with_fused_attn(args.fused_attn)
    if args.fused_ffn is not None:
        cfg = cfg.with_fused_ffn(args.fused_ffn)
    if args.fp32:
        cfg = dataclasses.replace(cfg, dtype="float32")
    return cfg


def _sampler(args, vocab: int):
    """Per-request sampling closure.  The key folds in (rid, n_generated)
    only — NEVER the slot or batch composition — so a request's sampled
    stream is identical whether it decodes solo or continuously batched."""
    base = jax.random.PRNGKey(args.seed + 1)

    def sample(logits_row, rid: int, n: int) -> int:
        lg = jnp.asarray(logits_row)[:vocab].astype(jnp.float32)
        if args.temperature <= 0:
            return int(jnp.argmax(lg))
        k = jax.random.fold_in(jax.random.fold_in(base, rid), n)
        return int(jax.random.categorical(k, lg / args.temperature))

    return sample


# ---------------------------------------------------------------------------
# Paged continuous-batching path.
# ---------------------------------------------------------------------------


def serve_paged(cfg, params, prompts, *, gen: int, max_concurrency: int,
                page_size: int = DEFAULT_PAGE_SIZE, fused_decode: bool = True,
                sample=None, eos_id: int | None = None,
                max_len: int | None = None, interpret: bool | None = None,
                max_queue: int | None = None,
                deadline_steps: int | None = None,
                chaos=None, quiet: bool = False) -> dict:
    """Run ``prompts`` (list of token lists) through the scheduler + paged
    engine until every request retires.  Reusable from tests/benchmarks;
    ``main`` wraps it with flag parsing.

    Hardening knobs: ``max_queue`` bounds the waiting queue (overflow is
    shed at submit), ``deadline_steps`` is the per-request TTL in
    scheduler steps (expired requests are timeout-evicted and their slot
    released), and any slot whose logits come back non-finite — a
    numerics fault or a poisoned request — is evicted instead of crashing
    the batch (``poisoned`` in the report).  ``chaos`` is an optional
    fault injector with a ``poison_logits(logits, decode_step)`` method
    (``runtime.chaos.LogitPoison``)."""
    if sample is None:
        def sample(lg, rid, n):  # greedy default
            return int(jnp.argmax(jnp.asarray(lg).astype(jnp.float32)))
    if max_len is None:
        max_len = max(len(p) for p in prompts) + gen
    eng = PagedDecodeEngine(cfg, params, page_size=page_size,
                            max_concurrency=max_concurrency, max_len=max_len,
                            fused_decode=fused_decode, interpret=interpret)
    sched = Scheduler(max_concurrency, max_queue=max_queue,
                      default_deadline=deadline_steps)
    sched.submit_all([Request(rid=i, prompt=list(map(int, p)), max_new=gen,
                              eos_id=eos_id) for i, p in enumerate(prompts)])

    t0 = time.time()
    t_prefill = 0.0
    decode_steps = 0
    poisoned = 0
    while sched.has_work():
        for req, slot in sched.expire():
            if slot is not None:  # was running: free its KV pages
                eng.release(slot)
        for req in sched.admit(
                can_admit=lambda r: eng.can_admit(len(r.prompt))):
            tp = time.time()
            lg = eng.prefill(req.slot, req.prompt)
            jax.block_until_ready(lg)
            t_prefill += time.time() - tp
            slot = req.slot
            if not finite_logit_rows(np.asarray(lg)[None])[0]:
                sched.evict(slot)
                eng.release(slot)
                poisoned += 1
                continue
            if sched.observe(slot, sample(lg, req.rid, 0)) is not None:
                eng.release(slot)
        running = sched.running()
        if running:
            toks = np.zeros((max_concurrency,), np.int32)
            poss = np.zeros((max_concurrency,), np.int32)
            for r in running:
                toks[r.slot] = r.out[-1]
                poss[r.slot] = len(r.prompt) + len(r.out) - 1
            logits = eng.decode_step(toks, poss)
            logits = np.asarray(logits)
            if chaos is not None:
                logits = chaos.poison_logits(logits, decode_steps)
            decode_steps += 1
            finite = finite_logit_rows(logits)
            for r in list(running):
                slot = r.slot
                if not finite[slot]:
                    # Poisoned slot: evict this request, keep the batch
                    # alive — the other lanes' math is row-independent,
                    # so their tokens are unaffected.
                    sched.evict(slot)
                    eng.release(slot)
                    poisoned += 1
                    continue
                tok = sample(logits[slot], r.rid, len(r.out))
                if sched.observe(slot, tok) is not None:
                    eng.release(slot)
        sched.end_step()

    t_total = time.time() - t0
    t_decode = max(t_total - t_prefill, 1e-9)
    rep = sched.report()
    rep["decode_steps"] = decode_steps
    rep["poisoned"] = poisoned
    by_rid = sorted(sched.retired, key=lambda r: r.rid)
    toks_per_s = rep["tokens_out"] / t_decode
    if not quiet:
        print(f"[serve] paged: {rep['finished']} finished, "
              f"{rep['evicted']} evicted, {rep['timed_out']} timed out, "
              f"{rep['shed']} shed in {rep['steps']} steps "
              f"({decode_steps} decode); prefill {t_prefill*1e3:.0f} ms, "
              f"decode {t_decode*1e3:.0f} ms ({toks_per_s:.1f} tok/s); "
              f"max wait {rep['max_wait_steps']} steps")
    return {
        "requests": by_rid,
        "tokens": np.asarray([r.out for r in by_rid
                              if len(r.out) == gen], np.int32),
        "t_prefill": t_prefill,
        "t_decode": t_decode,
        "tokens_per_sec": toks_per_s,
        "report": rep,
        "engine": eng,
        "mode": "paged",
    }


def _main_paged(cfg, args) -> dict:
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    B, P = args.batch, args.prompt_len
    mc = args.max_concurrency or B
    max_len = P + args.gen
    print(f"[serve] arch={cfg.name} tt={cfg.tt.mode} "
          f"params={num_params(params):,} mode=paged "
          f"fused_decode={args.fused_decode} page={args.page_size} "
          f"concurrency={mc}")
    prompts = np.asarray(
        lm_batch(args.seed, 0, B, P, cfg.vocab_size)["tokens"])
    out = serve_paged(cfg, params, [p.tolist() for p in prompts],
                      gen=args.gen, max_concurrency=mc,
                      page_size=args.page_size,
                      fused_decode=args.fused_decode,
                      sample=_sampler(args, cfg.vocab_size),
                      max_len=max_len, max_queue=args.max_queue,
                      deadline_steps=args.deadline_steps)
    if args.ledger:
        from repro.core.memory_ledger import decode_step_ledger

        led = decode_step_ledger(cfg, batch=mc, max_len=max_len,
                                 page_size=args.page_size,
                                 fused=args.fused_decode)
        mb = 1 / 2**20
        print(f"[serve] DECODE ledger {led.total_bytes*mb:.3f} MB "
              f"(bram {led.pool_bytes('bram')*mb:.3f}, "
              f"uram {led.pool_bytes('uram')*mb:.3f}):")
        for e in led.entries:
            print(f"    {e.name:<18} {e.nbytes*mb:8.3f} MB [{e.pool}]  "
                  f"{e.note}")
    gen = out["tokens"]
    if gen.size:
        print(f"[serve] sample generation (request 0): "
              f"{gen[0][:16].tolist()}")
        assert np.isfinite(gen).all()
    return out


# ---------------------------------------------------------------------------
# Legacy lockstep dense-cache path (ssm/rec hybrid families).
# ---------------------------------------------------------------------------


def _main_dense(cfg, args) -> dict:
    mesh = make_host_mesh()
    kvr = kv_repeat_for_mesh(cfg, mesh)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    print(f"[serve] arch={cfg.name} tt={cfg.tt.mode} "
          f"params={num_params(params):,} mode=dense kv_repeat={kvr}")

    B, P = args.batch, args.prompt_len
    max_len = P + args.gen
    prompts = lm_batch(args.seed, 0, B, P, cfg.vocab_size)["tokens"]
    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.frontend == "patch":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(1), (B, min(cfg.frontend_len, P), cfg.d_model),
            jnp.dtype(cfg.dtype))

    prefill = jax.jit(make_prefill(cfg))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    last_logits, pcache = prefill(params, batch)
    cache = prepare_decode_cache(cfg, pcache, P, max_len, kv_repeat=kvr)
    t_prefill = time.time() - t0

    sample = _sampler(args, cfg.vocab_size)

    def sample_batch(logits, n):
        return jnp.asarray([[sample(logits[b, -1], b, n)]
                            for b in range(B)], jnp.int32)

    tok = sample_batch(last_logits, 0)
    out_tokens = [np.asarray(tok)]
    t1 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(P + i, jnp.int32))
        tok = sample_batch(logits, i + 1)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t1
    gen = np.concatenate(out_tokens, axis=1)
    print(f"[serve] prefill {B}x{P} in {t_prefill*1e3:.0f} ms; "
          f"decoded {args.gen} tokens in {t_decode*1e3:.0f} ms "
          f"({args.gen * B / max(t_decode, 1e-9):.1f} tok/s)")
    print(f"[serve] sample generation (batch 0): {gen[0][:16].tolist()}")
    assert np.isfinite(gen).all()
    return {"tokens": gen, "t_prefill": t_prefill, "t_decode": t_decode,
            "tokens_per_sec": args.gen * B / max(t_decode, 1e-9),
            "mode": "dense"}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--tt", action="store_true")
    ap.add_argument("--tt-rank", type=int, default=16)
    ap.add_argument("--scale-down", action="store_true")
    ap.add_argument("--fp32", action="store_true")
    ap.add_argument("--kernel-flow", action="store_true",
                    help="TT contractions through the Pallas kernel flow")
    ap.add_argument("--fused-attn", action=argparse.BooleanOptionalAction,
                    default=None)
    ap.add_argument("--fused-ffn", action=argparse.BooleanOptionalAction,
                    default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fused-decode", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="decode-shape Pallas kernels (flash-decode "
                         "attention + BTT decode tiles); off = paged "
                         "pure-JAX reference path")
    ap.add_argument("--page-size", type=int, default=DEFAULT_PAGE_SIZE)
    ap.add_argument("--max-concurrency", type=int, default=None,
                    help="decode slots (default: --batch)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the scheduler's waiting queue: a submit "
                         "that would overflow it is shed immediately "
                         "(counted in the report) instead of queueing "
                         "unboundedly under overload")
    ap.add_argument("--deadline-steps", type=int, default=None,
                    help="per-request TTL in scheduler steps: requests "
                         "not finished within the deadline of arrival "
                         "are timeout-evicted (waiting or running) and "
                         "their KV pages freed")
    ap.add_argument("--ledger", action="store_true",
                    help="print the DECODE-stage memory ledger")
    args = ap.parse_args(argv)

    cfg = build(args)
    if paged_supported(cfg):
        return _main_paged(cfg, args)
    return _main_dense(cfg, args)


if __name__ == "__main__":
    main()
