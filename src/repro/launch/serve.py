"""Serving driver: batched prefill + decode against a KV/state cache.

Demonstrates the inference path end-to-end on any backend:
  * batched prefill over the prompt,
  * cache conversion to the decode layout (ring placement for windowed
    layers, KV-head repeat to the TP degree),
  * token-by-token decode with greedy or temperature sampling.

Usage (CPU example — reduced recurrentgemma, hybrid cache):
  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
      --scale-down --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import lm_batch
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_decode_step, make_prefill, prepare_decode_cache
from repro.models.transformer import init_params, num_params
from repro.runtime import kv_repeat_for_mesh


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--tt", action="store_true")
    ap.add_argument("--scale-down", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.scale_down:
        cfg = cfg.scaled_down()
    if args.tt:
        cfg = cfg.with_tt(mode="tt", rank=16, embed_rank=16)
    mesh = make_host_mesh()
    kvr = kv_repeat_for_mesh(cfg, mesh)

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    print(f"[serve] arch={cfg.name} tt={cfg.tt.mode} "
          f"params={num_params(params):,} kv_repeat={kvr}")

    B, P = args.batch, args.prompt_len
    max_len = P + args.gen
    prompts = lm_batch(args.seed, 0, B, P, cfg.vocab_size)["tokens"]
    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.frontend == "patch":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(1), (B, min(cfg.frontend_len, P), cfg.d_model),
            jnp.dtype(cfg.dtype))

    prefill = jax.jit(make_prefill(cfg))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    last_logits, pcache = prefill(params, batch)
    cache = prepare_decode_cache(cfg, pcache, P, max_len, kv_repeat=kvr)
    t_prefill = time.time() - t0

    def sample(logits, key):
        logits = logits[:, -1, : cfg.vocab_size].astype(jnp.float32)
        if args.temperature <= 0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(
            key, logits / args.temperature, axis=-1)[:, None].astype(jnp.int32)

    key = jax.random.PRNGKey(args.seed + 1)
    tok = sample(last_logits, key)
    out_tokens = [np.asarray(tok)]
    t1 = time.time()
    for i in range(args.gen - 1):
        key, sub = jax.random.split(key)
        logits, cache = decode(params, cache, tok, jnp.asarray(P + i, jnp.int32))
        tok = sample(logits, sub)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t1
    gen = np.concatenate(out_tokens, axis=1)
    print(f"[serve] prefill {B}x{P} in {t_prefill*1e3:.0f} ms; "
          f"decoded {args.gen} tokens in {t_decode*1e3:.0f} ms "
          f"({args.gen * B / max(t_decode, 1e-9):.1f} tok/s)")
    print(f"[serve] sample generation (batch 0): {gen[0][:16].tolist()}")
    assert np.isfinite(gen).all()
    return {"tokens": gen, "t_prefill": t_prefill, "t_decode": t_decode}


if __name__ == "__main__":
    main()
