"""Unified decoder model covering all assigned families.

One scanned stack handles dense / MoE / SSM / hybrid / audio / VLM configs:
``cfg.hybrid_pattern`` gives the repeating cycle of block kinds
(e.g. ``("rec","rec","attn_local")`` for recurrentgemma,
``("attn","attn_moe")`` for llama4); layers are scanned over whole cycles
(stacked params — O(1) HLO size regardless of depth) with any remainder
layers unrolled as a tail.

Three entry points, matching the assigned shape kinds:
  * ``train_step_fn``   — fwd + bwd + optimizer update (train_4k)
  * ``prefill_fn``      — forward over the prompt, emits logits + cache
  * ``decode_step_fn``  — one token against the cache (decode_32k/long_500k)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import decode_attention, train_attention
from repro.models.layers import (
    embedding_apply,
    linear_apply,
    make_embedding,
    make_linear,
    mlp_apply,
    make_mlp,
    rms_norm,
    rope,
)
from repro.core.meshctx import constrain as meshctx_constrain
from repro.core.tt import ttm_reconstruct
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import mamba2_apply, mamba2_init, rglru_apply, rglru_init

__all__ = [
    "init_params", "forward", "loss_fn", "lm_head", "token_nll",
    "init_cache", "cache_struct",
    "map_cache", "cache_descriptors", "CacheLeaf",
    "block_init", "block_apply", "num_params", "param_bytes",
]


# ---------------------------------------------------------------------------
# Blocks.
# ---------------------------------------------------------------------------


def _attn_init(key: jax.Array, cfg: ModelConfig, *, local: bool) -> dict:
    q_dim, kv_dim, d = cfg.attn_dims
    d_head = cfg.d_head if not local else cfg.d_head
    ks = jax.random.split(key, 5)
    p = {
        "q": make_linear(ks[0], q_dim, d, cfg, "attn", use_bias=cfg.qkv_bias),
        "k": make_linear(ks[1], kv_dim, d, cfg, "attn", use_bias=cfg.qkv_bias),
        "v": make_linear(ks[2], kv_dim, d, cfg, "attn", use_bias=cfg.qkv_bias),
        "o": make_linear(ks[3], d, q_dim, cfg, "attn"),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((d_head,), jnp.dtype(cfg.dtype))
        p["k_norm"] = jnp.zeros((d_head,), jnp.dtype(cfg.dtype))
    return p


def _attn_apply(p: dict, x: jax.Array, cfg: ModelConfig, *, window: int | None,
                cache: dict | None, mode: str, pos, delta_cache: bool = False):
    """Returns (out, new_cache).  ``delta_cache``: decode returns only the
    newly written KV column {"k","v" (B,1,KV,dh)} instead of the full
    updated cache — the caller scatters it into its stacked buffer so one
    decode step writes O(B·KV·dh) bytes, not O(B·S·KV·dh)."""
    B, S, _ = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    flow, fb, prec = cfg.tt.flow, cfg.tt.fused_bwd, cfg.tt.precision
    # Head-dim TP cut point (see mlp_apply note re: replicated TT factors).
    q = meshctx_constrain(linear_apply(p["q"], x, flow=flow, fused_bwd=fb,
                                       precision=prec),
                          ("pod", "data"), None, "model").reshape(B, S, H, dh)
    k = meshctx_constrain(linear_apply(p["k"], x, flow=flow, fused_bwd=fb,
                                       precision=prec),
                          ("pod", "data"), None, "model").reshape(B, S, KV, dh)
    v = meshctx_constrain(linear_apply(p["v"], x, flow=flow, fused_bwd=fb,
                                       precision=prec),
                          ("pod", "data"), None, "model").reshape(B, S, KV, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_embed == "rope":
        if mode == "decode":
            positions = jnp.broadcast_to(pos[None, None], (B, S))
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if mode == "decode":
        kv_rep = cache["k"].shape[2] // KV
        if kv_rep > 1:
            k = jnp.repeat(k, kv_rep, axis=2)
            v = jnp.repeat(v, kv_rep, axis=2)
        slot = pos % cache["k"].shape[1] if window is not None else pos
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        out = decode_attention(q, kc, vc, pos + 1, window=window)
        new_cache = {"k": k, "v": v} if delta_cache else {"k": kc, "v": vc}
    else:
        qc = cfg.attn_q_chunk or S
        kc = cfg.attn_kv_chunk or S
        out = train_attention(q, k, v, causal=cfg.causal, window=window,
                              q_chunk=qc, kv_chunk=kc,
                              fused=cfg.fused_attn, precision=prec)
        if mode == "prefill":
            new_cache = {"k": k, "v": v}
    out = out.reshape(B, S, H * dh)
    return linear_apply(p["o"], out, flow=flow, fused_bwd=fb,
                        precision=prec), new_cache


def block_init(key: jax.Array, kind: str, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    p: dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), dtype)}
    if kind in ("attn", "attn_moe", "attn_local"):
        p["attn"] = _attn_init(ks[0], cfg, local=kind == "attn_local")
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        if kind == "attn_moe":
            p["moe"] = moe_init(ks[1], cfg)
        else:
            p["mlp"] = make_mlp(ks[1], cfg)
    elif kind == "ssm":
        p["mixer"] = mamba2_init(ks[0], cfg)
    elif kind == "rec":
        p["mixer"] = rglru_init(ks[0], cfg)
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        p["mlp"] = make_mlp(ks[1], cfg)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


def block_apply(kind: str, p: dict, x: jax.Array, cfg: ModelConfig, *,
                cache: dict | None, mode: str, pos,
                delta_cache: bool = False) -> tuple[jax.Array, dict | None]:
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind in ("attn", "attn_moe", "attn_local"):
        window = cfg.window if kind == "attn_local" else None
        out, new_cache = _attn_apply(p["attn"], h, cfg, window=window,
                                     cache=cache, mode=mode, pos=pos,
                                     delta_cache=delta_cache)
        x = x + out
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        if kind == "attn_moe":
            x = x + moe_apply(p["moe"], h2, cfg)
        else:
            x = x + mlp_apply(p["mlp"], h2, cfg)
    elif kind == "ssm":
        out, new_cache = mamba2_apply(p["mixer"], h, cfg, cache, mode=mode)
        x = x + out
    elif kind == "rec":
        out, new_cache = rglru_apply(p["mixer"], h, cfg, cache, mode=mode)
        x = x + out
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h2, cfg)
    else:
        raise ValueError(kind)
    return x, new_cache


# ---------------------------------------------------------------------------
# Stacking: full cycles scanned, remainder unrolled.
# ---------------------------------------------------------------------------


def _cycle_layout(cfg: ModelConfig) -> tuple[int, tuple[str, ...]]:
    pat = cfg.hybrid_pattern
    n_cycles = cfg.num_layers // len(pat)
    tail = cfg.hybrid_pattern[: cfg.num_layers - n_cycles * len(pat)]
    return n_cycles, tail


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    n_cycles, tail = _cycle_layout(cfg)
    pat = cfg.hybrid_pattern
    k_embed, k_layers, k_tail, k_head, k_pos = jax.random.split(key, 5)
    dtype = jnp.dtype(cfg.dtype)

    cycle_keys = jax.random.split(k_layers, n_cycles)

    def one_cycle(ck):
        kks = jax.random.split(ck, len(pat))
        return tuple(block_init(kk, kind, cfg) for kk, kind in zip(kks, pat))

    stacked = jax.vmap(one_cycle)(cycle_keys) if n_cycles > 0 else None

    params: dict[str, Any] = {
        "embed": make_embedding(k_embed, cfg),
        "layers": stacked,
        "tail": tuple(
            block_init(kk, kind, cfg)
            for kk, kind in zip(jax.random.split(k_tail, max(len(tail), 1)), tail)
        ),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = make_linear(k_head, cfg.vocab_padded, cfg.d_model, cfg, "head")
    if cfg.pos_embed == "learned":
        params["pos_table"] = (
            jax.random.normal(k_pos, (cfg.max_seq_len, cfg.d_model), dtype) * 0.02)
    if cfg.frontend == "patch":
        # Stub frontend: a dense projection of precomputed patch embeddings.
        params["patch_proj"] = make_linear(k_pos, cfg.d_model, cfg.d_model, cfg, "none")
    return params


def _embed_inputs(params: dict, cfg: ModelConfig, tokens: jax.Array,
                  patches: jax.Array | None, pos_offset) -> jax.Array:
    h = embedding_apply(params["embed"], tokens)
    if cfg.frontend == "patch" and patches is not None:
        pe = linear_apply(params["patch_proj"], patches, flow=cfg.tt.flow,
                          fused_bwd=cfg.tt.fused_bwd)
        h = jnp.concatenate([pe, h[:, patches.shape[1]:, :]], axis=1)
    if cfg.pos_embed == "learned":
        S = tokens.shape[1]
        idx = pos_offset + jnp.arange(S)
        h = h + jnp.take(params["pos_table"], idx, axis=0)[None]
    elif cfg.pos_embed == "sinusoidal":
        S = tokens.shape[1]
        d = cfg.d_model
        pos = (pos_offset + jnp.arange(S))[:, None].astype(jnp.float32)
        div = jnp.exp(jnp.arange(0, d, 2, jnp.float32) * (-jnp.log(10000.0) / d))
        pe = jnp.zeros((S, d), jnp.float32)
        pe = pe.at[:, 0::2].set(jnp.sin(pos * div)).at[:, 1::2].set(jnp.cos(pos * div))
        h = h + pe.astype(h.dtype)[None]
    return h


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
            patches: jax.Array | None = None, cache: Any = None,
            mode: str = "train", pos=0, remat: bool = True,
            features_only: bool = False):
    """Full model forward.

    mode="train":   tokens (B, S) -> logits (B, S, Vp); cache unused.
    mode="prefill": also returns per-layer cache for subsequent decode.
    mode="decode":  tokens (B, 1), cache required, ``pos`` scalar position.
    Returns (logits, new_cache).
    """
    n_cycles, tail = _cycle_layout(cfg)
    pat = cfg.hybrid_pattern
    pos = jnp.asarray(pos, jnp.int32)
    h = _embed_inputs(params, cfg, tokens,
                      patches, pos if mode == "decode" else 0)

    has_cache = cache is not None and cache.get("layers") is not None

    if mode == "decode" and has_cache and n_cycles > 0:
        # Decode: carry the WHOLE stacked cache and update each cycle's
        # slice in place (dynamic-slice / dynamic-update-slice on the
        # carry).  Emitting per-cycle caches as scan `ys` instead would
        # re-stack (copy) the full multi-GB cache every decode step; the
        # carried buffer aliases with the donated input cache so only the
        # touched slices move (EXPERIMENTS.md §Perf).
        def _write_block(kind, buf_blk, nc_blk, idx):
            """Scatter one block's cache delta into its stacked buffer."""
            if kind in ("attn", "attn_moe", "attn_local"):
                window = cfg.window if kind == "attn_local" else None
                out = {}
                for key in ("k", "v"):
                    buf = buf_blk[key]            # (L, B, Smax, KV, dh)
                    col = nc_blk[key].astype(buf.dtype)  # (B, 1, KV, dh)
                    slot = pos % buf.shape[2] if window is not None else pos
                    out[key] = jax.lax.dynamic_update_slice(
                        buf, col[None], (idx, 0, slot, 0, 0))
                return out
            return jax.tree.map(
                lambda buf, nc_: jax.lax.dynamic_update_index_in_dim(
                    buf, nc_.astype(buf.dtype), idx, axis=0),
                buf_blk, nc_blk)

        def decode_cycle(carry, layer_params):
            hh, cache_stack, idx = carry
            layer_cache = jax.tree.map(
                lambda buf: jax.lax.dynamic_index_in_dim(
                    buf, idx, axis=0, keepdims=False), cache_stack)
            new_stack = []
            for i, kind in enumerate(pat):
                hh, nc = block_apply(kind, layer_params[i], hh, cfg,
                                     cache=layer_cache[i], mode=mode, pos=pos,
                                     delta_cache=True)
                new_stack.append(_write_block(kind, cache_stack[i], nc, idx))
            return (hh, tuple(new_stack), idx + 1), None

        (h, new_stack_cache, _), _ = jax.lax.scan(
            decode_cycle, (h, cache["layers"], jnp.asarray(0, jnp.int32)),
            params["layers"])
    else:
        def cycle_fn(carry, xs):
            hh = carry
            layer_params, layer_cache = xs if has_cache else (xs, None)
            new_caches = []
            for i, kind in enumerate(pat):
                c_i = None if layer_cache is None else layer_cache[i]
                hh, nc = block_apply(kind, layer_params[i], hh, cfg,
                                     cache=c_i, mode=mode, pos=pos)
                new_caches.append(nc)
            out_cache = tuple(new_caches) if mode != "train" else None
            return hh, out_cache

        cycle = (jax.checkpoint(cycle_fn)
                 if (remat and mode == "train") else cycle_fn)

        if n_cycles > 0:
            xs = (params["layers"], cache["layers"]) if has_cache \
                else params["layers"]
            h, new_stack_cache = jax.lax.scan(cycle, h, xs)
        else:
            new_stack_cache = None

    new_tail_caches = []
    for i, kind in enumerate(tail):
        c_i = None if cache is None else cache["tail"][i]
        h, nc = block_apply(kind, params["tail"][i], h, cfg,
                            cache=c_i, mode=mode, pos=pos)
        new_tail_caches.append(nc)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if features_only:
        return h, None
    logits = lm_head(params, cfg, h)
    new_cache = None
    if mode != "train":
        new_cache = {"layers": new_stack_cache, "tail": tuple(new_tail_caches)}
    return logits, new_cache


def lm_head(params: dict, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    """Final-norm'd features ``h (B, S, d)`` -> logits ``(B, S, Vp)``.

    Shared by ``forward`` and the pipeline's last stage
    (runtime.pipeline), so the tied-TTM reconstruct path and the sharding
    constraints cannot diverge between the two.
    """
    if cfg.tie_embeddings:
        if isinstance(params["embed"], dict):
            table = params["embed"]["table"]
        else:
            # Tied TTM head: materialize the table *transiently* (activation,
            # not a stored param) — the build is O(V·H·r) FLOPs, negligible
            # next to the logits GEMM, and shards on vocab under TP.
            from repro.core.meshctx import constrain
            emb = params["embed"]
            table = constrain(
                ttm_reconstruct(emb.cores, emb.spec),
                "model", None)[: cfg.vocab_padded, : cfg.d_model].astype(h.dtype)
        logits = jnp.einsum("bsd,vd->bsv", h, table,
                            preferred_element_type=jnp.float32).astype(h.dtype)
    else:
        logits = linear_apply(params["head"], h, flow=cfg.tt.flow,
                              fused_bwd=cfg.tt.fused_bwd)
    # Vocab-shard the logits explicitly: with a TT head the weight factors
    # are replicated, so GSPMD has no lineage to shard the (B, S, V) output
    # — unconstrained it replicates ~40 GB/device of logits on 150k-vocab
    # archs (EXPERIMENTS.md §Perf, technique cell iteration).
    return meshctx_constrain(logits, ("pod", "data"), None, "model")


def token_nll(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-token ``-log p(label)`` in f32, TP-safe.

    The gold logit is extracted with a masked sum over the vocab axis (not
    ``take_along_axis``): under TP the vocab axis is sharded, and a gather
    along a sharded axis would make GSPMD all-gather the full (B, S, V)
    logits — the masked sum keeps everything local + one scalar-per-token
    all-reduce.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1)
    return logz - gold


def loss_fn(params: dict, cfg: ModelConfig, batch: dict, *, remat: bool = True):
    """Next-token cross entropy.  batch: tokens (B,S), labels (B,S), mask."""
    logits, _ = forward(params, cfg, batch["tokens"],
                        patches=batch.get("patches"), mode="train", remat=remat)
    nll = token_nll(logits, batch["labels"])
    mask = batch.get("mask")
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = float(nll.size)
    return nll.sum() / denom


# ---------------------------------------------------------------------------
# Cache construction (decode shapes).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CacheLeaf:
    """Descriptor of one cache buffer: shape is WITHOUT the stacked-cycle
    leading dim; role drives the sharding rule (runtime.sharding)."""

    shape: tuple[int, ...]
    dtype: Any
    role: str  # "kv" (B,S,KV,dh) | "conv" (B,W,C) | "state" (B,...) | "vec" (B,D)


def _block_cache_desc(kind: str, cfg: ModelConfig, batch: int, seq_len: int,
                      kv_repeat: int, dtype) -> dict | None:
    if kind in ("attn", "attn_moe", "attn_local"):
        kvh = cfg.n_kv_heads * kv_repeat
        s = seq_len if kind != "attn_local" else min(cfg.window or seq_len, seq_len)
        shape = (batch, s, kvh, cfg.d_head)
        return {"k": CacheLeaf(shape, dtype, "kv"), "v": CacheLeaf(shape, dtype, "kv")}
    if kind == "ssm":
        s = cfg.ssm
        d_in = s.d_inner(cfg.d_model)
        h = s.n_heads(cfg.d_model)
        return {
            "conv": CacheLeaf((batch, s.d_conv - 1, d_in + 2 * s.d_state), dtype, "conv"),
            "ssd": CacheLeaf((batch, h, s.head_dim, s.d_state), jnp.float32, "state"),
        }
    if kind == "rec":
        return {
            "conv": CacheLeaf((batch, 3, cfg.d_model), dtype, "conv"),
            "h": CacheLeaf((batch, cfg.d_model), jnp.float32, "vec"),
        }
    raise ValueError(kind)


def cache_descriptors(cfg: ModelConfig, batch: int, seq_len: int, *,
                      kv_repeat: int = 1, dtype=None):
    """(stacked_desc, tail_desc, n_cycles) — leaves are CacheLeaf (no cycle dim)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    n_cycles, tail = _cycle_layout(cfg)
    pat = cfg.hybrid_pattern
    per_cycle = tuple(
        _block_cache_desc(kind, cfg, batch, seq_len, kv_repeat, dtype)
        for kind in pat) if n_cycles > 0 else None
    tail_desc = tuple(
        _block_cache_desc(kind, cfg, batch, seq_len, kv_repeat, dtype)
        for kind in tail)
    return per_cycle, tail_desc, n_cycles


def _is_cache_leaf(x):
    return isinstance(x, CacheLeaf)


def map_cache(fn, cfg: ModelConfig, batch: int, seq_len: int, *,
              kv_repeat: int = 1, dtype=None):
    """Build a cache-shaped pytree: ``fn(CacheLeaf, stacked_cycles|None)``."""
    per_cycle, tail_desc, n_cycles = cache_descriptors(
        cfg, batch, seq_len, kv_repeat=kv_repeat, dtype=dtype)
    stacked = None
    if per_cycle is not None:
        stacked = jax.tree.map(lambda leaf: fn(leaf, n_cycles), per_cycle,
                               is_leaf=_is_cache_leaf)
    tail = jax.tree.map(lambda leaf: fn(leaf, None), tail_desc,
                        is_leaf=_is_cache_leaf)
    return {"layers": stacked, "tail": tail}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
               kv_repeat: int = 1, dtype=None) -> dict:
    def make(leaf: CacheLeaf, cycles):
        shape = leaf.shape if cycles is None else (cycles,) + leaf.shape
        return jnp.zeros(shape, leaf.dtype)
    return map_cache(make, cfg, batch, seq_len, kv_repeat=kv_repeat, dtype=dtype)


def cache_struct(cfg: ModelConfig, batch: int, seq_len: int, *,
                 kv_repeat: int = 1, dtype=None) -> dict:
    """ShapeDtypeStruct tree (dry-run input stand-in: no allocation)."""
    def make(leaf: CacheLeaf, cycles):
        shape = leaf.shape if cycles is None else (cycles,) + leaf.shape
        return jax.ShapeDtypeStruct(shape, leaf.dtype)
    return map_cache(make, cfg, batch, seq_len, kv_repeat=kv_repeat, dtype=dtype)


# ---------------------------------------------------------------------------
# Introspection.
# ---------------------------------------------------------------------------


def num_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def param_bytes(params) -> int:
    return sum(int(x.size * x.dtype.itemsize) for x in jax.tree.leaves(params))
