"""Shared layer library: unified (dense | TT) linear, norms, RoPE, MLP, embeds.

Every projection in the model zoo goes through ``make_linear``/``linear_apply``
so the paper's technique is a config knob, not a code fork: with
``tt.on(part)`` the projection is TT cores executed with the configured
contraction flow; otherwise a dense matrix (the paper's MM baseline).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.meshctx import constrain
from repro.core.tt_linear import TTLinearParams, tt_linear_apply, tt_linear_init
from repro.core.ttm_embedding import (
    TTMEmbeddingParams,
    ttm_embedding_apply,
    ttm_embedding_init,
)

__all__ = [
    "DenseLinearParams", "make_linear", "linear_apply",
    "rms_norm", "layer_norm", "rope", "rope_slice",
    "make_mlp", "mlp_apply", "tt_ffn_apply", "ffn_fused_eligible",
    "make_embedding", "embedding_apply",
]


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class DenseLinearParams:
    w: jax.Array            # (out, in)
    bias: jax.Array | None

    def tree_flatten_with_keys(self):
        return ((jax.tree_util.GetAttrKey("w"), self.w),
                (jax.tree_util.GetAttrKey("bias"), self.bias)), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def make_linear(key: jax.Array, out_dim: int, in_dim: int, cfg: ModelConfig,
                part: str, *, use_bias: bool = False, dtype=None):
    """Dense or TT linear depending on ``cfg.tt.on(part)``."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    if cfg.tt.on(part):
        return tt_linear_init(key, out_dim, in_dim, d=cfg.tt.d,
                              rank=cfg.tt.rank, use_bias=use_bias, dtype=dtype,
                              clamp_ranks=cfg.tt.clamp_ranks)
    std = (2.0 / (in_dim + out_dim)) ** 0.5
    w = jax.random.normal(key, (out_dim, in_dim), dtype) * jnp.asarray(std, dtype)
    bias = jnp.zeros((out_dim,), dtype) if use_bias else None
    return DenseLinearParams(w=w, bias=bias)


def linear_apply(params, x: jax.Array, *, flow: str = "btt_fused",
                 fused_bwd: bool = True, precision=None) -> jax.Array:
    if isinstance(params, TTLinearParams):
        return tt_linear_apply(params, x, flow=flow, fused_bwd=fused_bwd,
                               precision=precision)
    y = jnp.einsum("...n,mn->...m", x, params.w,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if params.bias is not None:
        y = y + params.bias
    return y


# ---------------------------------------------------------------------------
# Norms (computed in f32, cast back).
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE.
# ---------------------------------------------------------------------------


def _rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply rotary embedding.  ``x (B, S, H, D)``, ``positions (B, S)``."""
    freqs = _rope_freqs(x.shape[-1], theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def rope_slice(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Decode-time rotary for a single position. ``x (B, 1, H, D)``, ``pos (B,)``."""
    return rope(x, pos[:, None], theta)


# ---------------------------------------------------------------------------
# MLP: SwiGLU (gated) or GELU (paper's FFN).
# ---------------------------------------------------------------------------


def make_mlp(key: jax.Array, cfg: ModelConfig, d_ff: int | None = None,
             part: str = "ffn") -> dict:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "up": make_linear(ks[0], d_ff, cfg.d_model, cfg, part),
        "down": make_linear(ks[1], cfg.d_model, d_ff, cfg, part),
    }
    if cfg.mlp_gated:
        p["gate"] = make_linear(ks[2], d_ff, cfg.d_model, cfg, part)
    return p


def _ffn_act(cfg: ModelConfig) -> str:
    # Reject unknown activations rather than guessing: the unfused
    # branches below have their own (mutually inverted) fallbacks, so a
    # silent default here would break fused on/off parity for any future
    # act value.
    if cfg.act not in ("gelu", "silu"):
        raise ValueError(f"fused_ffn supports act in ('gelu', 'silu'); "
                         f"got {cfg.act!r}")
    return cfg.act


def ffn_fused_eligible(up, down, gate, K: int, *,
                       shard_dims: int | None = None) -> bool:
    """True iff this (up, down[, gate]) triple can run as the fused FFN
    megakernel: every projection TT (no dense, no bias), the "model" mesh
    axis (if any) row-wise rather than Megatron column-TP (the megakernel
    computes the whole d_ff per device, so a hidden-dim cut is fatal but a
    row shard is free), and the kernel's working set inside the VMEM
    budget for the *per-device* row count — the SAME ``ffn_vmem_fits``
    predicate ``kernels.ops.btt_ffn_op`` dispatches on and
    ``core.memory_ledger`` gates its FFN rows on.

    ``shard_dims``: how many ways the K rows are sharded across devices;
    defaults to ``meshctx.row_shards()`` (1 with no mesh installed, and 1
    inside shard_map bodies, whose shapes are already local).
    """
    mods = (up, down) if gate is None else (up, down, gate)
    if not all(isinstance(m, TTLinearParams) and m.bias is None
               for m in mods):
        return False
    from repro.core.meshctx import current_mesh, model_axis_rowwise, row_shards

    mesh = current_mesh()
    if (mesh is not None and mesh.shape.get("model", 1) > 1
            and not model_axis_rowwise()):
        # Megatron column-TP: the two-call path's hidden-dim sharding
        # constraint is load-bearing there, so it wins.
        return False
    if shard_dims is None:
        shard_dims = row_shards()
    from repro.kernels.btt_ffn import ffn_vmem_fits  # lazy: pallas import

    k_local = -(-K // max(int(shard_dims), 1))
    itemsize = jnp.dtype(up.cores[0].dtype).itemsize
    return ffn_vmem_fits(
        down.spec.out_dim, up.spec.in_dim, up.spec.out_dim,
        up.spec.mid_rank, down.spec.mid_rank,
        gate.spec.mid_rank if gate is not None else 0, itemsize, K=k_local)


def tt_ffn_apply(up: TTLinearParams, down: TTLinearParams,
                 gate: TTLinearParams | None, x: jax.Array, *, act: str,
                 fused_bwd: bool = True,
                 shard_dims: int | None = None,
                 precision=None) -> jax.Array:
    """Whole TT FFN block through the fused megakernel
    (``kernels.ops.btt_ffn_op``): ``x (..., N) -> (..., M)`` with the
    hidden state VMEM-resident and only ``x`` saved for the backward.
    Callers gate on :func:`ffn_fused_eligible` and pass the same
    ``shard_dims`` so the op's own VMEM gate sees the identical local row
    count; shapes past the VMEM budget fall back to the two-call path
    inside the op."""
    from repro.kernels.ops import btt_ffn_op  # lazy: pallas import

    lead = x.shape[:-1]
    xk = x.reshape(-1, x.shape[-1])
    if up.in_dim != up.spec.in_dim:
        xk = jnp.pad(xk, ((0, 0), (0, up.spec.in_dim - up.in_dim)))
    y = btt_ffn_op(up.cores, down.cores,
                   gate.cores if gate is not None else None, xk,
                   up.spec, down.spec,
                   gate.spec if gate is not None else None, act=act,
                   f_logical=min(up.out_dim, down.in_dim),
                   fused_bwd=fused_bwd, shard_dims=shard_dims,
                   precision=precision)
    return y[:, : down.out_dim].reshape(lead + (down.out_dim,))


def mlp_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    flow, fb, prec = cfg.tt.flow, cfg.tt.fused_bwd, cfg.tt.precision
    gate = p.get("gate") if cfg.mlp_gated else None
    K = 1
    for d in x.shape[:-1]:
        K *= d
    from repro.core.meshctx import row_shards
    sd = row_shards()
    # fused_ffn refines the kernel flow only (like tt.fused_bwd): other
    # flows keep their selected contraction engine untouched.
    if cfg.fused_ffn and flow == "kernel" \
            and ffn_fused_eligible(p["up"], p["down"], gate, K,
                                   shard_dims=sd):
        # Fused megakernel: the (K, d_ff) hidden state never leaves VMEM,
        # so there is nothing hidden-sized to shard (eligibility excludes
        # Megatron column-TP meshes, where the constraint below is
        # load-bearing for compute placement; row-wise "model" axes stay
        # fused — each device launches on its own row shard).
        return tt_ffn_apply(p["up"], p["down"], gate, x,
                            act=_ffn_act(cfg), fused_bwd=fb, shard_dims=sd,
                            precision=prec)
    # Megatron cut point: the hidden dim shards on "model".  Dense weights
    # give GSPMD this lineage for free; TT factors are REPLICATED, so an
    # explicit constraint is required or the whole FFN replicates 16x
    # (EXPERIMENTS.md §Perf, technique-cell iteration).
    up = constrain(linear_apply(p["up"], x, flow=flow, fused_bwd=fb,
                                precision=prec),
                   ("pod", "data"), None, "model")
    if cfg.mlp_gated:
        gate_h = constrain(linear_apply(p["gate"], x, flow=flow, fused_bwd=fb,
                                        precision=prec),
                           ("pod", "data"), None, "model")
        act = jax.nn.silu(gate_h) if cfg.act == "silu" else jax.nn.gelu(gate_h)
        h = act * up
    else:
        h = jax.nn.gelu(up) if cfg.act == "gelu" else jax.nn.silu(up)
    return linear_apply(p["down"], h, flow=flow, fused_bwd=fb, precision=prec)


# ---------------------------------------------------------------------------
# Embedding: dense table or TTM cores.
# ---------------------------------------------------------------------------


def make_embedding(key: jax.Array, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    if cfg.tt.on("embed"):
        return ttm_embedding_init(key, cfg.vocab_padded, cfg.d_model,
                                  d=cfg.tt.d, rank=cfg.tt.embed_rank,
                                  dtype=dtype)
    table = jax.random.normal(key, (cfg.vocab_padded, cfg.d_model), dtype) * 0.02
    return {"table": table}


def embedding_apply(params, ids: jax.Array) -> jax.Array:
    if isinstance(params, TTMEmbeddingParams):
        return ttm_embedding_apply(params, ids)
    return jnp.take(params["table"], ids, axis=0)
