"""Attention: double-blocked (flash-style) causal/windowed attention + decode.

Training/prefill attention has two paths, selected by ``train_attention``:

* ``blockwise_attention`` — pure JAX: an online-softmax scan over KV chunks
  inside a scan over Q chunks, so the score matrix never materializes
  beyond ``(B, kv_heads, groups, q_chunk, kv_chunk)`` — required for the
  32k-prefill cells to fit HBM.  GQA is handled by folding query heads as
  ``(kv_heads, group)`` so no KV repeat is materialized.  Under autodiff
  this path saves the per-chunk probabilities (S×S per head in aggregate)
  and round-trips the scan carry through HBM every KV chunk.
* ``fused=True`` — the fused flash kernels (``kernels.flash_attention`` /
  ``flash_backward`` under ``kernels.ops.flash_mha_op``): forward saves
  only ``(O, m, l)``; the backward recomputes probability tiles in VMEM in
  a single Pallas kernel.  Shapes whose backward working set exceeds the
  kernel VMEM budget silently take the blockwise path.

Decode attends a single query position against a (possibly ring-buffered)
KV cache; KV heads are repeated to the TP degree at cache-layout time by the
caller when ``n_kv < model-axis`` (see runtime.sharding).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["blockwise_attention", "train_attention", "decode_attention"]

NEG_INF = -1e30


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int | None = None,
                        q_chunk: int = 512, kv_chunk: int = 512,
                        q_offset: int = 0) -> jax.Array:
    """``q (B, S, H, D); k, v (B, S, KV, D) -> (B, S, H, D)``.

    ``window``: restrict to a trailing window of that many positions
    (sliding-window / local attention).  ``q_offset``: absolute position of
    q[0] (for chunked prefill against earlier KV).
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    s_kv_real = k.shape[1]
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, s_kv_real)
    # Pad ragged sequence lengths up to the chunk grid; padded KV positions
    # are masked out below (kpos >= s_kv_real), padded Q rows are sliced off.
    s_pad = (-S) % q_chunk
    kv_pad = (-s_kv_real) % kv_chunk
    if s_pad:
        q = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    s_q = S + s_pad
    nq = s_q // q_chunk
    nk = k.shape[1] // kv_chunk

    # (B, S, KV, G, D): queries grouped under their KV head.
    qg = q.reshape(B, s_q, KV, G, D)
    q_chunks = qg.reshape(B, nq, q_chunk, KV, G, D).transpose(1, 0, 2, 3, 4, 5)
    k_chunks = k.reshape(B, nk, kv_chunk, KV, D).transpose(1, 0, 2, 3, 4)
    v_chunks = v.reshape(B, nk, kv_chunk, KV, D).transpose(1, 0, 2, 3, 4)

    def q_block(iq, qc):
        # online softmax over kv chunks
        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        acc0 = jnp.zeros((B, KV, G, q_chunk, D), jnp.float32)
        qpos = q_offset + iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inp):
            m, l, acc = carry
            jk, kc, vc = inp
            kpos = jk * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.broadcast_to(kpos[None, :] < s_kv_real,
                                    (q_chunk, kv_chunk))
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            # p downcast to the KV dtype for the MXU; f32 accumulation.
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bckd->bkgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, acc0),
            (jnp.arange(nk), k_chunks, v_chunks))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B, KV, G, q_chunk, D) -> (B, q_chunk, KV, G, D)
        return out.transpose(0, 3, 1, 2, 4)

    outs = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), q_chunks))
    # (nq, B, q_chunk, KV, G, D) -> (B, S, H, D); padded Q rows sliced off
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, s_q, H, D)
    return out[:, :S].astype(q.dtype)


def train_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    q_chunk: int = 512, kv_chunk: int = 512,
                    fused: bool = False,
                    interpret: bool | None = None,
                    precision=None) -> jax.Array:
    """Training/prefill attention: ``q (B, S, H, D); k, v (B, S, KV, D)``.

    ``fused=True`` routes through the fused flash forward + single-kernel
    backward (``kernels.ops.flash_mha_op``), which itself falls back to
    ``blockwise_attention`` when the shape's backward working set exceeds
    the kernel VMEM budget — so the flag is always safe to set.
    ``precision.act_dtype`` quantizes the fused path's saved
    ``(q, k, v, o)`` residual tier (fused path only — the blockwise
    fallback is the plain-autodiff f32 reference).
    """
    if fused:
        # Lazy import keeps models importable without the kernels package
        # in the dependency path of non-fused configs.
        from repro.kernels.ops import flash_mha_op

        return flash_mha_op(q, k, v, causal=causal, window=window,
                            q_chunk=q_chunk, kv_chunk=kv_chunk,
                            interpret=interpret, precision=precision)
    return blockwise_attention(q, k, v, causal=causal, window=window,
                               q_chunk=q_chunk, kv_chunk=kv_chunk)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cur_pos: jax.Array, *, window: int | None = None) -> jax.Array:
    """One-token attention against a cache.

    ``q (B, 1, H, D)``; ``k_cache, v_cache (B, Smax, KV, D)``; ``cur_pos``
    scalar: number of valid cache entries *including* the current token
    (caller inserts the current k/v before attending).  With ``window`` the
    cache is a ring buffer of size ``Smax = window`` written at
    ``pos % window``; masking handles partial fill.
    """
    B, _, H, D = q.shape
    S = k_cache.shape[1]
    KV = k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bckd->bkgc", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(S)
    if window is None:
        valid = idx < cur_pos
    else:
        # ring buffer: slots [cur_pos - window, cur_pos) are valid
        valid = (idx < cur_pos) | (cur_pos > S)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # AV product in cache dtype with f32 accumulation (MXU-native): an f32
    # upcast of v_cache would materialize a full-cache copy — XLA hoists it
    # out of the layer scan, costing 3x the true decode HBM traffic
    # (EXPERIMENTS.md §Perf iteration 1).
    out = jnp.einsum("bkgc,bckd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)
