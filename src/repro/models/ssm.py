"""Sequence mixers without attention: Mamba-2 SSD and Griffin's RG-LRU.

Both give the `long_500k` cells their sub-quadratic justification: decode
state is O(d_state) per layer regardless of history length.

Mamba-2 follows the SSD (state-space duality) chunked algorithm
[arXiv:2405.21060]: intra-chunk quadratic term + inter-chunk recurrence on
(H, P, N) states, scanned over chunks.  RG-LRU follows Griffin
[arXiv:2402.19427] with a log-space associative scan over the sequence.
All weight projections route through ``make_linear`` so the paper's TT
compression applies to these families too (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.meshctx import constrain
from repro.models.layers import linear_apply, make_linear

__all__ = [
    "causal_conv", "causal_conv_step",
    "mamba2_init", "mamba2_apply",
    "rglru_init", "rglru_apply",
]


# ---------------------------------------------------------------------------
# Depthwise causal conv1d (the short conv both families use).
# ---------------------------------------------------------------------------


def causal_conv(x: jax.Array, kernel: jax.Array) -> jax.Array:
    """``x (B, L, C), kernel (W, C) -> (B, L, C)`` causal depthwise conv."""
    w = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    # accumulate shifted copies — W is tiny (4), cheaper than conv lowering
    out = jnp.zeros_like(x, shape=x.shape)
    for i in range(w):
        out = out + xp[:, i : i + x.shape[1], :] * kernel[i]
    return out


def causal_conv_step(x_new: jax.Array, conv_state: jax.Array,
                     kernel: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Single decode step.  ``x_new (B, C)``, ``conv_state (B, W-1, C)``."""
    w = kernel.shape[0]
    full = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", full, kernel)
    return y, full[:, -(w - 1):, :] if w > 1 else conv_state


# ---------------------------------------------------------------------------
# Mamba-2 (SSD).
# ---------------------------------------------------------------------------


def mamba2_init(key: jax.Array, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    h = s.n_heads(cfg.d_model)
    conv_dim = d_in + 2 * s.d_state
    ks = jax.random.split(key, 6)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "zx_proj": make_linear(ks[0], 2 * d_in, cfg.d_model, cfg, "attn"),
        "bc_proj": make_linear(ks[1], 2 * s.d_state, cfg.d_model, cfg, "attn_small"),
        "dt_proj": make_linear(ks[2], h, cfg.d_model, cfg, "attn_small"),
        "conv_kernel": jax.random.normal(ks[3], (s.d_conv, conv_dim), dtype) * 0.2,
        "A_log": jnp.zeros((h,), jnp.float32) + jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),
        "gate_norm": jnp.zeros((d_in,), dtype),
        "out_proj": make_linear(ks[4], cfg.d_model, d_in, cfg, "attn"),
    }


def _segsum_decay(da_chunk: jax.Array) -> jax.Array:
    """Within-chunk decay matrix ``L[i, j] = exp(sum_{j<t<=i} dA_t)``, i >= j.

    ``da_chunk (..., Q) -> (..., Q, Q)`` lower-triangular (else 0).
    """
    q = da_chunk.shape[-1]
    cs = jnp.cumsum(da_chunk, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # cs_i - cs_j
    mask = jnp.tril(jnp.ones((q, q), bool))
    # Mask the *exponent* (not the value): exp of a huge masked entry would
    # be inf and poison the backward pass through the where.
    diff = jnp.where(mask, diff, -jnp.inf)
    return jnp.exp(diff)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, chunk: int,
                h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """SSD scan.  Shapes: ``x (B,L,H,P)``, ``dt (B,L,H)``, ``a (H,)``,
    ``b, c (B,L,N)`` (single group).  Returns ``(y (B,L,H,P), h_last (B,H,P,N))``.
    """
    B, L, H, P = x.shape
    N = b.shape[-1]
    nc = L // chunk
    assert nc * chunk == L, "chunk must divide seq"
    f32 = jnp.float32
    xd = (x * dt[..., None]).astype(f32)                       # x * dt
    da = (dt * a[None, None, :]).astype(f32)                   # (B,L,H)
    xc = xd.reshape(B, nc, chunk, H, P)
    dac = da.reshape(B, nc, chunk, H)
    bc = b.reshape(B, nc, chunk, N).astype(f32)
    cc = c.reshape(B, nc, chunk, N).astype(f32)

    # Intra-chunk (quadratic within chunk only).
    lmat = _segsum_decay(dac.transpose(0, 1, 3, 2))            # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", cc, bc,
                        preferred_element_type=f32)            # (B,nc,Q,Q)
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", scores, lmat, xc,
                        preferred_element_type=f32)

    # Chunk summaries -> inter-chunk recurrence.
    cs = jnp.cumsum(dac, axis=2)                               # (B,nc,Q,H)
    total = cs[:, :, -1:, :]                                   # (B,nc,1,H)
    decay_states = jnp.exp(total - cs)                         # (B,nc,Q,H)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", bc, decay_states, xc,
                        preferred_element_type=f32)            # (B,nc,H,P,N)
    chunk_decay = jnp.exp(total[:, :, 0, :])                   # (B,nc,H)

    def chunk_step(h, inp):
        dec, st = inp                                          # (B,H), (B,H,P,N)
        h_new = h * dec[..., None, None] + st
        return h_new, h                                        # emit state *before* chunk

    h_init = jnp.zeros((B, H, P, N), f32) if h0 is None else h0.astype(f32)
    h_last, h_prevs = jax.lax.scan(
        chunk_step, h_init,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                 # (B,nc,H,P,N)

    state_decay_out = jnp.exp(cs)                              # (B,nc,Q,H)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cc, h_prevs, state_decay_out,
                       preferred_element_type=f32)
    y = (y_diag + y_off).reshape(B, L, H, P)
    return y.astype(x.dtype), h_last


def _gated_rms(y: jax.Array, z: jax.Array, scale: jax.Array,
               eps: float) -> jax.Array:
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(y.dtype)


def mamba2_apply(p: dict, u: jax.Array, cfg: ModelConfig,
                 cache: dict | None = None, *, mode: str = "train"):
    """Mamba-2 mixer.  ``u (B, L, D)``.  ``mode``: train|prefill|decode.

    Returns ``(y, new_cache)``; cache = {"conv": (B, W-1, C), "ssd": (B,H,P,N)}.
    """
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    h = s.n_heads(cfg.d_model)
    flow, fb = cfg.tt.flow, cfg.tt.fused_bwd
    # channel-dim TP cut point (TT factors are replicated; see layers.py)
    zx = constrain(linear_apply(p["zx_proj"], u, flow=flow, fused_bwd=fb),
                   ("pod", "data"), None, "model")
    z, x0 = jnp.split(zx, 2, axis=-1)
    bc = linear_apply(p["bc_proj"], u, flow=flow, fused_bwd=fb)
    dt_raw = linear_apply(p["dt_proj"], u, flow=flow, fused_bwd=fb)
    xbc = jnp.concatenate([x0, bc], axis=-1)

    new_cache = {}
    if mode == "decode":
        conv_out, new_conv = causal_conv_step(xbc[:, 0], cache["conv"], p["conv_kernel"])
        conv_out = jax.nn.silu(conv_out)[:, None, :]
        new_cache["conv"] = new_conv
    else:
        conv_out = jax.nn.silu(causal_conv(xbc, p["conv_kernel"]))
        # conv cache holds the *raw* inputs (last W-1), not the conv output
        new_cache["conv"] = xbc[:, -(s.d_conv - 1):, :]

    x = conv_out[..., :d_in]
    b = conv_out[..., d_in : d_in + s.d_state]
    c = conv_out[..., d_in + s.d_state :]
    B_, L = x.shape[0], x.shape[1]
    xh = x.reshape(B_, L, h, s.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])

    if mode == "decode":
        h0 = cache["ssd"]
        da = jnp.exp(dt[:, 0] * a[None, :])                    # (B,H)
        upd = jnp.einsum("bn,bhp->bhpn", b[:, 0].astype(jnp.float32),
                         (xh[:, 0] * dt[:, 0, :, None]).astype(jnp.float32))
        h_new = h0 * da[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", c[:, 0].astype(jnp.float32), h_new)
        y = y[:, None].astype(x.dtype)                          # (B,1,H,P)
        new_cache["ssd"] = h_new
    else:
        h0 = cache["ssd"] if cache is not None else None
        y, h_last = ssd_chunked(xh, dt, a, b, c, min(s.chunk, L), h0)
        new_cache["ssd"] = h_last

    y = (y + xh * p["D"][None, None, :, None]).astype(u.dtype)
    y = y.reshape(B_, L, d_in)
    y = _gated_rms(y, z, p["gate_norm"], cfg.norm_eps)
    out = linear_apply(p["out_proj"], y, flow=flow, fused_bwd=fb)
    return out, new_cache


# ---------------------------------------------------------------------------
# RG-LRU (Griffin recurrent block).
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d_rnn = cfg.d_model  # Griffin uses d_rnn ~ 4/3 d_model; we keep d_model
    ks = jax.random.split(key, 6)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "x_proj": make_linear(ks[0], d_rnn, cfg.d_model, cfg, "attn"),
        "gate_proj": make_linear(ks[1], d_rnn, cfg.d_model, cfg, "attn"),
        "conv_kernel": jax.random.normal(ks[2], (4, d_rnn), dtype) * 0.2,
        "a_gate": make_linear(ks[3], d_rnn, d_rnn, cfg, "attn"),
        "i_gate": make_linear(ks[4], d_rnn, d_rnn, cfg, "attn"),
        "lam": jnp.full((d_rnn,), 1.0, jnp.float32),  # Λ: a = sigmoid(Λ)-based decay
        "out_proj": make_linear(ks[5], cfg.d_model, d_rnn, cfg, "attn"),
    }


def _rglru_coeffs(p: dict, x: jax.Array, flow: str, fb: bool = True):
    r = jax.nn.sigmoid(linear_apply(p["a_gate"], x, flow=flow,
                                    fused_bwd=fb).astype(jnp.float32))
    i = jax.nn.sigmoid(linear_apply(p["i_gate"], x, flow=flow,
                                    fused_bwd=fb).astype(jnp.float32))
    log_a = -_RGLRU_C * r * jax.nn.softplus(p["lam"])          # log a_t  (<0)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * i * x.astype(jnp.float32)
    return a, b


def rglru_apply(p: dict, u: jax.Array, cfg: ModelConfig,
                cache: dict | None = None, *, mode: str = "train"):
    """Griffin recurrent block.  cache = {"conv": (B, 3, d), "h": (B, d)}."""
    flow, fb = cfg.tt.flow, cfg.tt.fused_bwd
    x = constrain(linear_apply(p["x_proj"], u, flow=flow, fused_bwd=fb),
                  ("pod", "data"), None, "model")
    g = constrain(linear_apply(p["gate_proj"], u, flow=flow, fused_bwd=fb),
                  ("pod", "data"), None, "model")

    new_cache = {}
    if mode == "decode":
        xc, new_conv = causal_conv_step(x[:, 0], cache["conv"], p["conv_kernel"])
        xc = xc[:, None, :]
        new_cache["conv"] = new_conv
    else:
        xc = causal_conv(x, p["conv_kernel"])
        new_cache["conv"] = x[:, -3:, :]  # raw inputs, not conv output

    a, b = _rglru_coeffs(p, xc, flow, fb)
    if mode == "decode":
        h_prev = cache["h"].astype(jnp.float32)
        h = a[:, 0] * h_prev + b[:, 0]
        new_cache["h"] = h
        hseq = h[:, None, :]
    else:
        if cache is not None:  # continue from carried state (chunked prefill)
            b = b.at[:, 0, :].add(a[:, 0, :] * cache["h"].astype(jnp.float32))
        # associative scan: h_t = a_t h_{t-1} + b_t
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        _, hseq = jax.lax.associative_scan(combine, (a, b), axis=1)
        new_cache["h"] = hseq[:, -1, :]
    y = hseq.astype(u.dtype) * jax.nn.gelu(g)
    return linear_apply(p["out_proj"], y, flow=flow, fused_bwd=fb), new_cache
