"""Model zoo: unified scanned decoder covering all assigned families."""
from .transformer import (
    CacheLeaf,
    block_apply,
    block_init,
    cache_descriptors,
    cache_struct,
    forward,
    init_cache,
    init_params,
    loss_fn,
    map_cache,
    num_params,
    param_bytes,
)

__all__ = [
    "init_params", "forward", "loss_fn", "init_cache", "cache_struct",
    "map_cache", "cache_descriptors", "CacheLeaf",
    "block_init", "block_apply", "num_params", "param_bytes",
]
