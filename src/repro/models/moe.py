"""Mixture-of-Experts layer: top-k routing, capacity dispatch, TT experts.

Dispatch is gather/scatter based (not one-hot-einsum) so HLO FLOPs reflect
useful work: tokens are assigned slot positions inside their expert via a
cumsum over the assignment one-hot, gathered into an ``(E, C, D)`` buffer,
run through per-expert FFNs (dense or TT-compressed — the paper's technique
applied to MoE: per-expert weight state shrinks ~20x, see DESIGN.md), and
scattered back weighted by router gates.  Tokens beyond capacity are dropped
(Switch-style); capacity_factor controls the trade.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.meshctx import constrain
from repro.core.tt_linear import TTLinearParams, tt_linear_apply, tt_linear_init
from repro.models.layers import (
    ffn_fused_eligible,
    make_linear,
    make_mlp,
    mlp_apply,
    tt_ffn_apply,
)

__all__ = ["moe_init", "moe_apply"]


def _expert_linear_init(key, e: int, out_dim: int, in_dim: int, cfg: ModelConfig):
    if cfg.tt.on("ffn"):
        return jax.vmap(
            lambda k: tt_linear_init(k, out_dim, in_dim, d=cfg.tt.d,
                                     rank=cfg.tt.rank, dtype=jnp.dtype(cfg.dtype),
                                     clamp_ranks=cfg.tt.clamp_ranks)
        )(jax.random.split(key, e))
    std = (2.0 / (in_dim + out_dim)) ** 0.5
    w = jax.random.normal(key, (e, out_dim, in_dim), jnp.dtype(cfg.dtype))
    return {"w": w * jnp.asarray(std, w.dtype)}


def _expert_linear_apply(params, x: jax.Array, flow: str,
                         fb: bool = True) -> jax.Array:
    """``x (E, C, in) -> (E, C, out)`` batched over experts."""
    if isinstance(params, TTLinearParams):
        return jax.vmap(lambda p, xe: tt_linear_apply(
            p, xe, flow=flow, fused_bwd=fb))(params, x)
    return jnp.einsum("ecd,efd->ecf", x, params["w"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _expert_ffn_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Per-expert SwiGLU FFN, ``x (E, C, D) -> (E, C, D)``.

    With ``cfg.fused_ffn`` and TT experts whose working set fits the VMEM
    budget (the eligibility predicate is checked once on the per-expert
    spec — it is expert-independent), each expert runs as ONE fused FFN
    megakernel under vmap: its (C, d_expert) hidden state never leaves
    VMEM and the backward recomputes it from the dispatched tokens.
    Otherwise the established three-call path.
    """
    flow, fb = cfg.tt.flow, cfg.tt.fused_bwd
    if cfg.fused_ffn and flow == "kernel" \
            and isinstance(p["up"], TTLinearParams) \
            and ffn_fused_eligible(p["up"], p["down"], p["gate"],
                                   K=x.shape[1]):
        return jax.vmap(lambda up, gate, down, xe: tt_ffn_apply(
            up, down, gate, xe, act="silu", fused_bwd=fb))(
                p["up"], p["gate"], p["down"], x)
    up = _expert_linear_apply(p["up"], x, flow, fb)
    gate = _expert_linear_apply(p["gate"], x, flow, fb)
    h = jax.nn.silu(gate) * up
    return _expert_linear_apply(p["down"], h, flow, fb)


def moe_init(key: jax.Array, cfg: ModelConfig) -> dict:
    m = cfg.moe
    e_pad = m.padded_experts  # dummy experts (never routed) for clean EP
    ks = jax.random.split(key, 5)
    dtype = jnp.dtype(cfg.dtype)
    p = {
        # Router stays dense & f32-critical: it is tiny and routing quality is
        # precision-sensitive.  Router covers only REAL experts.
        "router": jax.random.normal(ks[0], (m.num_experts, cfg.d_model), dtype) * 0.02,
        "up": _expert_linear_init(ks[1], e_pad, m.d_expert, cfg.d_model, cfg),
        "gate": _expert_linear_init(ks[2], e_pad, m.d_expert, cfg.d_model, cfg),
        "down": _expert_linear_init(ks[3], e_pad, cfg.d_model, m.d_expert, cfg),
    }
    if m.shared_d_ff:
        p["shared"] = make_mlp(ks[4], cfg, d_ff=m.shared_d_ff)
    return p


def _experts_fsdp(p: dict) -> bool:
    """Mirrors runtime.sharding._EXPERT_FSDP_BYTES: big dense expert stacks
    are FSDP-sharded over data; activation pins would fight that layout."""
    from repro.core.meshctx import current_mesh
    mesh = current_mesh()
    if mesh is None or isinstance(p["up"], TTLinearParams):
        return False
    w = p["up"]["w"]  # per-layer slice; the runtime rule sees the L-stacked
    tp = mesh.shape.get("model", 1)  # leaf, so compare at ~1/32 the threshold
    return (w.size * w.dtype.itemsize) // max(tp, 1) > (64 << 20)


def _route(xf: jax.Array, router: jax.Array, k: int):
    """Router top-k.  ``xf (..., T, D)`` -> (gates (..., T, k), idx (..., T, k))."""
    logits = jnp.einsum("...td,ed->...te", xf, router,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    return gate_vals, expert_idx


def _moe_grouped(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """GShard-style grouped dispatch: one group per sequence.

    Routing, position-in-expert and the gather/scatter all happen *within* a
    group, so with batch sharded over DP every dispatch op stays local to its
    data shard; the only cross-shard movement is the (G, E, C, D)->(E, G*C, D)
    transpose feeding the model-sharded experts — which GSPMD lowers to the
    canonical MoE all-to-all (visible in the §Roofline collective table).
    Capacity is per group: C = ceil(S * k / E * cf).
    """
    m = cfg.moe
    flow, fb = cfg.tt.flow, cfg.tt.fused_bwd
    G, S, D = x.shape  # group per sequence
    E, k = m.padded_experts, m.top_k  # dispatch over the padded expert dim
    cap = int(math.ceil(S * k / m.num_experts * m.capacity_factor))

    gate_vals, expert_idx = _route(x, p["router"], k)            # (G, S, k)
    flat_e = expert_idx.reshape(G, S * k)
    flat_g = gate_vals.reshape(G, S * k)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)[None], (G, S * k))
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (G, S*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1  # (G, S*k)
    keep = pos_in_e < cap
    pos_w = jnp.where(keep, pos_in_e, cap)                       # cap = drop slot

    gi = jnp.arange(G, dtype=jnp.int32)[:, None]
    dispatch = jnp.full((G, E, cap + 1), S, jnp.int32)
    dispatch = dispatch.at[gi, flat_e, pos_w].set(flat_tok)[:, :, :cap]
    combine = jnp.zeros((G, E, cap + 1), jnp.float32)
    combine = combine.at[gi, flat_e, pos_w].set(flat_g)[:, :, :cap]

    x_pad = jnp.concatenate([x, jnp.zeros((G, 1, D), x.dtype)], axis=1)
    xg = jnp.take_along_axis(
        x_pad, dispatch.reshape(G, E * cap)[..., None], axis=1)  # (G, E*cap, D)
    xg = xg.reshape(G, E, cap, D).transpose(1, 0, 2, 3)          # all-to-all
    # EP cut point: experts on "model", token groups stay on DP — the
    # transpose+reshape has no lineage for either (see layers.py note).
    # Skipped for FSDP-sharded (400B-class) expert stacks: there GSPMD's own
    # layout around the weight all-gathers wins (measured, §Perf iter. 3).
    pin = not _experts_fsdp(p)
    if pin:
        xg = constrain(xg.reshape(E, G * cap, D),
                       "model", ("pod", "data"), None)
    else:
        xg = xg.reshape(E, G * cap, D)

    yg = _expert_ffn_apply(p, xg, cfg)                           # (E, G*cap, D)

    yg = yg.reshape(E, G, cap, D).transpose(1, 0, 2, 3)          # all-to-all back
    if pin:
        yg = constrain(yg, ("pod", "data"), "model", None, None)
    yg = yg * combine[..., None].astype(yg.dtype)                # (G, E, cap, D)
    y = jnp.zeros((G, S + 1, D), yg.dtype)
    y = y.at[gi[..., None], dispatch].add(yg)[:, :S]
    return y


def _moe_global(p: dict, xf: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Single-group dispatch over all T tokens (decode: T is tiny)."""
    m = cfg.moe
    T, D = xf.shape
    y = _moe_grouped(p, xf[None], cfg)[0]
    del T, D, m
    return y


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """``x (B, S, D) -> (B, S, D)``."""
    B, S, D = x.shape
    if S > 1:
        y = _moe_grouped(p, x, cfg)                              # group = sequence
    else:
        y = _moe_global(p, x.reshape(B * S, D), cfg).reshape(B, S, D)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, cfg)
    return y.reshape(B, S, D)
