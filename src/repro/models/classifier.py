"""ATIS task heads (paper Fig. 2 / Table II): intent + slot classifiers.

The paper's classifier is "one or more linear layers followed by a non-linear
activation" applied to the [CLS] hidden state, with the pre-classifier
(768, 768) projection TT-compressed at rank 12 and the *last task-specific
linear kept uncompressed* (Sec. III-A).  We reproduce that structure for both
heads of the ATIS multi-task setup:

  intent: h[CLS] -> TT(768,768) -> tanh -> dense(768, 26)
  slots:  h[t]   -> TT(768,768) -> tanh -> dense(768, 120)   (per position)

The joint loss is the sum of the two cross-entropies (both tasks train
simultaneously, as in the paper's Fig. 13 curves).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import linear_apply, make_linear
from repro.models.transformer import forward

__all__ = ["atis_heads_init", "atis_forward", "atis_loss", "atis_metrics"]


def atis_heads_init(key: jax.Array, cfg: ModelConfig, num_intents: int,
                    num_slots: int) -> dict:
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model

    def dense(k, out_dim, in_dim):
        std = (2.0 / (in_dim + out_dim)) ** 0.5
        return {
            "w": jax.random.normal(k, (out_dim, in_dim), dtype) * jnp.asarray(std, dtype),
            "b": jnp.zeros((out_dim,), dtype),
        }

    return {
        # pre-classifier projections: TT when cfg.tt covers the classifier
        "intent_proj": make_linear(ks[0], d, d, cfg, "ffn"),
        "slot_proj": make_linear(ks[1], d, d, cfg, "ffn"),
        # task-specific last linears: uncompressed per the paper
        "intent_out": dense(ks[2], num_intents, d),
        "slot_out": dense(ks[3], num_slots, d),
    }


def atis_forward(params: dict, cfg: ModelConfig, tokens: jax.Array):
    """Returns (intent_logits (B, I), slot_logits (B, S, L))."""
    h, _ = forward(params["backbone"], cfg, tokens, mode="train",
                   features_only=True, remat=False)
    flow, fb = cfg.tt.flow, cfg.tt.fused_bwd
    cls = h[:, 0, :]  # position 0 acts as [CLS]
    hi = jnp.tanh(linear_apply(params["heads"]["intent_proj"], cls,
                               flow=flow, fused_bwd=fb))
    io = params["heads"]["intent_out"]
    intent_logits = jnp.einsum("bd,cd->bc", hi, io["w"]) + io["b"]
    hs = jnp.tanh(linear_apply(params["heads"]["slot_proj"], h,
                               flow=flow, fused_bwd=fb))
    so = params["heads"]["slot_out"]
    slot_logits = jnp.einsum("bsd,cd->bsc", hs, so["w"]) + so["b"]
    return intent_logits, slot_logits


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def atis_loss(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    il, sl = atis_forward(params, cfg, batch["tokens"])
    return _xent(il, batch["intent"]) + _xent(sl, batch["slots"])


def atis_metrics(params: dict, cfg: ModelConfig, batch: dict) -> dict:
    il, sl = atis_forward(params, cfg, batch["tokens"])
    return {
        "loss": atis_loss(params, cfg, batch),
        "intent_acc": (il.argmax(-1) == batch["intent"]).mean(),
        "slot_acc": (sl.argmax(-1) == batch["slots"]).mean(),
    }
