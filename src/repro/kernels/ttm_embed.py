"""Pallas TPU kernel: TTM embedding lookup (d = 3), gather-free.

The paper's TTM embedding (Sec. III-C) looks up one slice per core per token
and chain-multiplies.  Row gathers are the natural FPGA dataflow but are slow
on TPU; the TPU-native adaptation replaces every gather with a **one-hot
matmul** (MXU-friendly — vocab factors are small, tens of rows), and fuses
the whole d=3 chain in VMEM so no per-token slice ever reaches HBM:

  stage A (MXU): sel1 = onehot(j1) @ F1            (TK, H1·R1)
  stage B (MXU): sel2 = onehot(j2) @ F2'           (TK, R1·H2·R2)
  stage C (VPU): acc  = sum_r1 sel1 ⊙ sel2         (TK, H1·H2, R2)
  stage D (MXU): sel3 = onehot(j3) @ F3'           (TK, R2·H3)
  stage E (VPU): out  = sum_r2 acc ⊙ sel3          (TK, H1·H2·H3)

Stages C/E are rank-contractions batched per token — they cannot be a single
2-D GEMM, so they run as broadcast-multiply-reduce on the VPU (tiny:
``r^2·H`` FLOPs/token).  All three cores stay VMEM-resident for the whole
call — the paper's "all parameters on chip" at kernel granularity.  The
wrapper (``ops.py``) falls back to the pure-JAX path when the cores exceed
the VMEM budget (very large vocab × rank).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

__all__ = ["ttm_embed_pallas", "DEFAULT_TOKENS_BLOCK"]

DEFAULT_TOKENS_BLOCK = 128


def _embed_kernel(oh1_ref, oh2_ref, oh3_ref, f1_ref, f2_ref, f3_ref, out_ref,
                  *, h1: int, h2: int, h3: int, r1: int, r2: int):
    tk = oh1_ref.shape[0]
    f32 = jnp.float32
    # A: (TK, V1) @ (V1, H1*R1)
    sel1 = jnp.dot(oh1_ref[...], f1_ref[...], preferred_element_type=f32)
    # B: (TK, V2) @ (V2, R1*H2*R2)
    sel2 = jnp.dot(oh2_ref[...], f2_ref[...], preferred_element_type=f32)
    # C: contract r1 per token (VPU broadcast-reduce).
    s1 = sel1.reshape(tk, h1, r1, 1, 1)
    s2 = sel2.reshape(tk, 1, r1, h2, r2)
    acc = jnp.sum(s1 * s2, axis=2)                 # (TK, H1, H2, R2)
    # D: (TK, V3) @ (V3, R2*H3)
    sel3 = jnp.dot(oh3_ref[...], f3_ref[...], preferred_element_type=f32)
    # E: contract r2 per token.
    a = acc.reshape(tk, h1 * h2, 1, r2, 1)
    s3 = sel3.reshape(tk, 1, 1, r2, h3)
    out = jnp.sum(a * s3, axis=3)                  # (TK, H1*H2, 1, H3)
    out_ref[...] = out.reshape(tk, h1 * h2 * h3).astype(out_ref.dtype)


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("spec_dims", "tk", "interpret"))
def ttm_embed_pallas(oh: tuple[jax.Array, jax.Array, jax.Array],
                     cores: tuple[jax.Array, jax.Array, jax.Array], *,
                     spec_dims: tuple, tk: int | None = None,
                     interpret: bool = False) -> jax.Array:
    """d=3 TTM lookup.  ``oh[k] (K, v_k)`` one-hot digits (f32/bf16),
    ``cores`` = (F1 (1,v1,h1,r1), F2 (r1,v2,h2,r2), F3 (r2,v3,h3,1)).
    Returns ``(K, h1*h2*h3)``; ``spec_dims = ((v1,v2,v3),(h1,h2,h3),(r1,r2))``.
    """
    (v1, v2, v3), (h1, h2, h3), (r1, r2) = spec_dims
    K = oh[0].shape[0]
    dtype = cores[0].dtype
    tk = tk or DEFAULT_TOKENS_BLOCK
    kp = _round_up(K, tk)
    H = h1 * h2 * h3

    # Flatten cores to 2-D GEMM operands (selection axis first).
    f1 = cores[0].reshape(v1, h1 * r1)
    f2 = jnp.transpose(cores[1], (1, 0, 2, 3)).reshape(v2, r1 * h2 * r2)
    f3 = jnp.transpose(cores[2], (1, 0, 2, 3)).reshape(v3, r2 * h3)

    ohp = [jnp.pad(o, ((0, kp - K), (0, 0))).astype(dtype) for o in oh]

    grid = (kp // tk,)
    out = pl.pallas_call(
        functools.partial(_embed_kernel, h1=h1, h2=h2, h3=h3, r1=r1, r2=r2),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tk, v1), lambda k: (k, 0)),
            pl.BlockSpec((tk, v2), lambda k: (k, 0)),
            pl.BlockSpec((tk, v3), lambda k: (k, 0)),
            pl.BlockSpec((v1, h1 * r1), lambda k: (0, 0)),       # resident
            pl.BlockSpec((v2, r1 * h2 * r2), lambda k: (0, 0)),  # resident
            pl.BlockSpec((v3, r2 * h3), lambda k: (0, 0)),       # resident
        ],
        out_specs=pl.BlockSpec((tk, H), lambda k: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((kp, H), dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(*ohp, f1, f2, f3)
    return out[:K]
