"""Pure-jnp oracles for the Pallas kernels (the ground truth the kernels are
swept against in tests/test_kernels.py).

These are deliberately the simplest possible expressions of the math — no
tiling, no padding, no dtype tricks — so a mismatch always indicts the kernel.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["btt_linear_ref", "btt_t_ref", "btt_backward_ref", "ttm_embed_ref"]


def btt_linear_ref(x: jnp.ndarray, b: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """BTT linear: ``y = (x @ b^T) @ a^T``.

    ``x (K, N)``, ``b (R, N)`` (input half-factor), ``a (M, R)`` (output
    half-factor) -> ``y (K, M)``.  Accumulation in f32, result in x.dtype.
    """
    t = jnp.dot(x, b.T, preferred_element_type=jnp.float32)
    y = jnp.dot(t.astype(a.dtype), a.T, preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def btt_t_ref(x: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """First stage only: ``t = x @ b^T`` in f32 (the VMEM-resident tensor)."""
    return jnp.dot(x, b.T, preferred_element_type=jnp.float32)


def btt_backward_ref(x: jnp.ndarray, gy: jnp.ndarray, b: jnp.ndarray,
                     a: jnp.ndarray
                     ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """BTT backward: ``(gx, ga, gb)`` for ``y = (x @ b^T) @ a^T``.

    ``x (K, N)`` saved input, ``gy (K, M)`` output cotangent, ``b (R, N)``
    / ``a (M, R)`` half-factors -> ``gx (K, N)`` in ``x.dtype``, ``ga
    (M, R)`` / ``gb (R, N)`` in f32.  The intermediates ``t``/``gt`` stay
    f32 through the dependent products — the precision contract the fused
    kernel and the unfused fallback both honor (the final cast to the core
    dtype happens in ``ops.py``, after this math).
    """
    t = jnp.dot(x, b.T, preferred_element_type=jnp.float32)
    gt = jnp.dot(gy, a, preferred_element_type=jnp.float32)
    gx = jnp.dot(gt.astype(b.dtype), b,
                 preferred_element_type=jnp.float32).astype(x.dtype)
    ga = jnp.dot(gy.T.astype(jnp.float32), t,
                 preferred_element_type=jnp.float32)
    gb = jnp.dot(gt.T, x.astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    return gx, ga, gb


def ttm_embed_ref(oh: tuple[jnp.ndarray, ...], cores: tuple[jnp.ndarray, ...]
                  ) -> jnp.ndarray:
    """TTM embedding lookup with one-hot selection (d = len(cores) stages).

    ``oh[k] (K, v_k)`` one-hot token digits; ``cores[k] (r_{k-1}, v_k, h_k,
    r_k)`` -> embeddings ``(K, prod(h_k))``.  Matches
    ``core.contraction.ttm_lookup`` (which gathers instead of one-hot-matmuls).
    """
    f = cores[0]
    acc = jnp.einsum("kv,avhr->khr", oh[0], f.astype(jnp.float32))  # (K,h1,r1)
    for k in range(1, len(cores)):
        sel = jnp.einsum("kv,rvhs->krhs", oh[k], cores[k].astype(jnp.float32))
        acc = jnp.einsum("kpr,krhs->kphs", acc, sel)
        acc = acc.reshape(acc.shape[0], acc.shape[1] * acc.shape[2], acc.shape[3])
    return acc[..., 0]
