"""Pure-jnp oracles for the Pallas kernels (the ground truth the kernels are
swept against in tests/test_kernels.py).

These are deliberately the simplest possible expressions of the math — no
tiling, no padding, no dtype tricks — so a mismatch always indicts the kernel.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .btt_ffn import ACTS as _ACTS  # one activation table: oracle == kernel

__all__ = ["btt_linear_ref", "btt_t_ref", "btt_backward_ref",
           "btt_ffn_ref", "btt_ffn_backward_ref", "ttm_embed_ref",
           "flash_attention_bwd_ref"]


def btt_linear_ref(x: jnp.ndarray, b: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """BTT linear: ``y = (x @ b^T) @ a^T``.

    ``x (K, N)``, ``b (R, N)`` (input half-factor), ``a (M, R)`` (output
    half-factor) -> ``y (K, M)``.  Accumulation in f32, result in x.dtype.
    """
    t = jnp.dot(x, b.T, preferred_element_type=jnp.float32)
    y = jnp.dot(t.astype(a.dtype), a.T, preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def btt_t_ref(x: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """First stage only: ``t = x @ b^T`` in f32 (the VMEM-resident tensor)."""
    return jnp.dot(x, b.T, preferred_element_type=jnp.float32)


def btt_backward_ref(x: jnp.ndarray, gy: jnp.ndarray, b: jnp.ndarray,
                     a: jnp.ndarray
                     ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """BTT backward: ``(gx, ga, gb)`` for ``y = (x @ b^T) @ a^T``.

    ``x (K, N)`` saved input, ``gy (K, M)`` output cotangent, ``b (R, N)``
    / ``a (M, R)`` half-factors -> ``gx (K, N)`` in ``x.dtype``, ``ga
    (M, R)`` / ``gb (R, N)`` in f32.  The intermediates ``t``/``gt`` stay
    f32 through the dependent products — the precision contract the fused
    kernel and the unfused fallback both honor (the final cast to the core
    dtype happens in ``ops.py``, after this math).
    """
    t = jnp.dot(x, b.T, preferred_element_type=jnp.float32)
    gt = jnp.dot(gy, a, preferred_element_type=jnp.float32)
    gx = jnp.dot(gt.astype(b.dtype), b,
                 preferred_element_type=jnp.float32).astype(x.dtype)
    ga = jnp.dot(gy.T.astype(jnp.float32), t,
                 preferred_element_type=jnp.float32)
    gb = jnp.dot(gt.T, x.astype(jnp.float32),
                 preferred_element_type=jnp.float32)
    return gx, ga, gb


def btt_ffn_ref(x, b1, a1, b2, a2, bg=None, ag=None, *,
                act: str = "gelu") -> jnp.ndarray:
    """Fused-FFN forward oracle: the two-call (three when gated) reference
    ``y = down(act(up(x)))`` / ``y = down(act(gate(x)) * up(x))`` issuing
    EXACTLY the megakernel's GEMM + cast sequence, so on unpadded
    single-tile shapes the kernel must match this bit-for-bit."""
    u = btt_linear_ref(x, b1, a1)
    if bg is not None:
        g = btt_linear_ref(x, bg, ag)
        h = _ACTS[act](g) * u
    else:
        h = _ACTS[act](u)
    return btt_linear_ref(h, b2, a2)


def btt_ffn_backward_ref(x, gy, b1, a1, b2, a2, bg=None, ag=None, *,
                         act: str = "gelu") -> tuple:
    """Fused-FFN backward oracle from ``x``/``gy`` only (hidden recomputed,
    like the kernel): ``(gx, ga1, gb1, ga2, gb2[, gag, gbg])`` with the
    half-factor gradients f32, issuing the megakernel's exact contraction
    order — the single-tile bit-equality ground truth."""
    dt = x.dtype
    u = btt_linear_ref(x, b1, a1)
    t1 = btt_t_ref(x, b1)
    if bg is not None:
        g = btt_linear_ref(x, bg, ag)
        tg = btt_t_ref(x, bg)
        h = _ACTS[act](g) * u
    else:
        h = _ACTS[act](u)
    t2 = btt_t_ref(h, b2)
    gt2 = jnp.dot(gy, a2, preferred_element_type=jnp.float32)
    gh = jnp.dot(gt2.astype(b2.dtype), b2,
                 preferred_element_type=jnp.float32).astype(dt)
    ga2 = jnp.dot(gy.T.astype(jnp.float32), t2,
                  preferred_element_type=jnp.float32)
    gb2 = jnp.dot(gt2.T, h.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if bg is not None:
        _, act_vjp = jax.vjp(lambda gg, uu: _ACTS[act](gg) * uu, g, u)
        gg_, gu = act_vjp(gh)
    else:
        _, act_vjp = jax.vjp(_ACTS[act], u)
        (gu,) = act_vjp(gh)
    gt1 = jnp.dot(gu, a1, preferred_element_type=jnp.float32)
    gx = jnp.dot(gt1.astype(b1.dtype), b1,
                 preferred_element_type=jnp.float32).astype(dt)
    ga1 = jnp.dot(gu.T.astype(jnp.float32), t1,
                  preferred_element_type=jnp.float32)
    gb1 = jnp.dot(gt1.T, x.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if bg is not None:
        gtg = jnp.dot(gg_, ag, preferred_element_type=jnp.float32)
        gx = gx + jnp.dot(gtg.astype(bg.dtype), bg,
                          preferred_element_type=jnp.float32).astype(dt)
        gag = jnp.dot(gg_.T.astype(jnp.float32), tg,
                      preferred_element_type=jnp.float32)
        gbg = jnp.dot(gtg.T, x.astype(jnp.float32),
                      preferred_element_type=jnp.float32)
        return gx, ga1, gb1, ga2, gb2, gag, gbg
    return gx, ga1, gb1, ga2, gb2


def flash_attention_bwd_ref(q, k, v, o, m, l, do, *, causal: bool = True,
                            window: int | None = None, group: int = 1
                            ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Flash-attention backward from the saved ``(O, m, l)`` residuals.

    ``q/o/do (BH, S, D)``, ``m/l (BH, S)`` f32, ``k/v (BH/group, S, D)`` ->
    ``(dq, dk, dv)``.  A per-head Python loop issuing EXACTLY the fused
    kernel's contractions in the kernel's accumulation order (group members
    ascending per KV head), so on unpadded single-tile shapes the kernel
    must match this bit-for-bit.  ``D = rowsum(dO ⊙ O)`` — the same
    in-kernel recomputation, not the softmax-VJP ``rowsum(P ⊙ dP)`` form.
    """
    BH, S, D = q.shape
    BKV = k.shape[0]
    scale = 1.0 / math.sqrt(D)
    idx = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= idx[None, :] <= idx[:, None]
    if window is not None:
        mask &= idx[None, :] > idx[:, None] - window

    dq = []
    dk = [jnp.zeros((S, D), jnp.float32) for _ in range(BKV)]
    dv = [jnp.zeros((S, D), jnp.float32) for _ in range(BKV)]
    for hk in range(BKV):
        kf = k[hk].astype(jnp.float32)
        vf = v[hk].astype(jnp.float32)
        for g in range(group):
            h = hk * group + g
            qf = q[h].astype(jnp.float32)
            dof = do[h].astype(jnp.float32)
            of = o[h].astype(jnp.float32)
            mh = m[h][:, None]
            lh = l[h][:, None]
            s = jax.lax.dot_general(
                qf * scale, kf, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            s = jnp.where(mask, s, -1e30)
            p = jnp.exp(s - mh) / jnp.maximum(lh, 1e-30)
            dv[hk] = dv[hk] + jax.lax.dot_general(
                p, dof, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            dp_ = jax.lax.dot_general(
                dof, vf, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            d_row = jnp.sum(dof * of, axis=1, keepdims=True)
            ds = p * (dp_ - d_row) * scale
            dq.append(jax.lax.dot_general(
                ds, kf, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32))
            dk[hk] = dk[hk] + jax.lax.dot_general(
                ds, qf, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    return (jnp.stack(dq).astype(q.dtype),
            jnp.stack(dk).astype(k.dtype),
            jnp.stack(dv).astype(v.dtype))


def ttm_embed_ref(oh: tuple[jnp.ndarray, ...], cores: tuple[jnp.ndarray, ...]
                  ) -> jnp.ndarray:
    """TTM embedding lookup with one-hot selection (d = len(cores) stages).

    ``oh[k] (K, v_k)`` one-hot token digits; ``cores[k] (r_{k-1}, v_k, h_k,
    r_k)`` -> embeddings ``(K, prod(h_k))``.  Matches
    ``core.contraction.ttm_lookup`` (which gathers instead of one-hot-matmuls).
    """
    f = cores[0]
    acc = jnp.einsum("kv,avhr->khr", oh[0], f.astype(jnp.float32))  # (K,h1,r1)
    for k in range(1, len(cores)):
        sel = jnp.einsum("kv,rvhs->krhs", oh[k], cores[k].astype(jnp.float32))
        acc = jnp.einsum("kpr,krhs->kphs", acc, sel)
        acc = acc.reshape(acc.shape[0], acc.shape[1] * acc.shape[2], acc.shape[3])
    return acc[..., 0]
