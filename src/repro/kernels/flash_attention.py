"""Pallas TPU kernel: flash attention (causal / sliding-window, GQA-aware).

Why it exists here: §Roofline shows the prefill/train cells memory-bound,
and loop-nest attribution (EXPERIMENTS.md §Perf) pins most of that traffic
on the pure-JAX blockwise attention — its online-softmax state (m, l, acc)
is a scan carry that XLA round-trips through HBM on every KV chunk.  The
fix is structural: keep the state in VMEM scratch across the KV axis of the
grid, so HBM sees only Q/K/V reads and one O write — the flash-attention
dataflow, here as the TPU analogue of the paper's "intermediates never
leave chip" principle (Sec. V-B2).

Grid = (B·H, S/TQ, S/TK), KV innermost (sequential); GQA without
materializing repeated KV: the K/V BlockSpec index maps query-head ``h`` to
its KV head ``h // group`` — the repeat happens in the index computation,
not in memory.  Fully-masked causal blocks are skipped via ``pl.when``.

``return_residuals=True`` additionally emits the per-row softmax statistics
``(m, l)`` — the residuals the fused backward (``flash_backward.py``)
recomputes probability tiles from, so training never saves the S×S
probability matrix.  Tiles may go as low as 32 rows (sublane granule) so the
paper's S=32 regime launches unpadded on the sequence axis; lane padding of
sub-128 tiles is left to Mosaic.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

__all__ = ["flash_attention_pallas", "DEFAULT_TQ", "DEFAULT_TK"]

DEFAULT_TQ = 256
DEFAULT_TK = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, *refs,
            nk: int, tq: int, tk: int, scale: float, causal: bool,
            window: int | None, s_real: int, emit_stats: bool):
    if emit_stats:
        o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = iq * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
    kpos = ik * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)

    # Skip blocks that the causal mask fully zeroes (window handled by the
    # in-block mask; its dead blocks are rarer and not worth the branch).
    if causal:
        live = ik * tk <= iq * tq + tq - 1       # some kpos <= some qpos
    else:
        live = jnp.asarray(True)

    @pl.when(live)
    def _step():
        q = q_ref[0]                              # (TQ, D)
        k = k_ref[0]                              # (TK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = kpos < s_real
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                       # (TQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                    # (TQ, TK) f32
        corr = jnp.exp(m_prev - m_new)            # (TQ, 1)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0]                              # (TK, D)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)
        if emit_stats:
            mo_ref[0] = m_ref[...][:, 0]
            lo_ref[0] = l_ref[...][:, 0]


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "group", "tq", "tk", "interpret",
    "return_residuals"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           group: int = 1, tq: int | None = None,
                           tk: int | None = None,
                           interpret: bool = False,
                           return_residuals: bool = False):
    """``q (BH, S, D); k, v (BH/group, S, D) -> o (BH, S, D)``.

    ``group`` = GQA group size (query heads per KV head); the K/V block
    index maps ``h -> h // group`` so repeated KV never materializes.
    S is padded to the tile grid; padded KV columns are masked, padded Q
    rows sliced off.

    ``return_residuals=True`` returns ``(o, m, l)`` with ``m, l (BH, S)``
    f32 — the per-row softmax max / normalizer the fused backward kernel
    needs to recompute probability tiles without the S×S matrix.
    """
    BH, S, D = q.shape
    scale = 1.0 / math.sqrt(D)
    tq = tq or min(DEFAULT_TQ, _round_up(S, 128))
    tk = tk or min(DEFAULT_TK, _round_up(S, 128))
    sp = _round_up(S, max(tq, tk))
    dp_ = _round_up(D, 128)
    qp = jnp.pad(q, ((0, 0), (0, sp - S), (0, dp_ - D)))
    kp = jnp.pad(k, ((0, 0), (0, sp - S), (0, dp_ - D)))
    vp = jnp.pad(v, ((0, 0), (0, sp - S), (0, dp_ - D)))
    nq, nk = sp // tq, sp // tk
    grid = (BH, nq, nk)

    out_specs = [pl.BlockSpec((1, tq, dp_), lambda h, i, j: (h, i, 0))]
    out_shape = [jax.ShapeDtypeStruct((BH, sp, dp_), q.dtype)]
    if return_residuals:
        out_specs += [pl.BlockSpec((1, tq), lambda h, i, j: (h, i)),
                      pl.BlockSpec((1, tq), lambda h, i, j: (h, i))]
        out_shape += [jax.ShapeDtypeStruct((BH, sp), jnp.float32),
                      jax.ShapeDtypeStruct((BH, sp), jnp.float32)]

    res = pl.pallas_call(
        functools.partial(_kernel, nk=nk, tq=tq, tk=tk, scale=scale,
                          causal=causal, window=window, s_real=S,
                          emit_stats=return_residuals),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, dp_), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, tk, dp_), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, tk, dp_), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),     # m
            pltpu.VMEM((tq, 1), jnp.float32),     # l
            pltpu.VMEM((tq, dp_), jnp.float32),   # acc
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qp, kp, vp)
    if return_residuals:
        out, m, l = res
        return out[:, :S, :D], m[:, :S], l[:, :S]
    return res[0][:, :S, :D]
