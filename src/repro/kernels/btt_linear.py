"""Pallas TPU kernel: fused bidirectional-TT (BTT) linear forward.

The paper's BTT contraction reduces a TT linear layer to
``y = A @ (B @ x)`` with tiny half-factors ``A (M, r)`` / ``B (r, N)``
(Sec. IV-B).  On FPGA the intermediate ``Z_2 = B @ x`` lives in on-chip
BRAM between the MUL1 and MUL2 engines.  The TPU analogue implemented here:
one ``pallas_call`` computes both GEMMs per output tile with the ``(TK, r)``
intermediate held in a **VMEM scratch accumulator** — it never round-trips
through HBM, exactly the paper's on-chip-only dataflow.

Tiling (BlockSpec):
  grid = (K / TK, N / TN); iteration is row-major so the N axis is innermost.
  x block  (TK, TN)   — streamed from HBM
  b block  (R,  TN)   — input half-factor, R = padded rank (lane-aligned)
  a block  (M,  R)    — output half-factor, fully VMEM-resident (it is tiny:
                        M·r ≤ a few MB — this residency is the kernel-level
                        expression of the paper's "all parameters on chip")
  y block  (TK, M)    — written once per K row-block
  t scratch (TK, R) f32 — the fused intermediate (paper's Z_2)

Per grid step: ``t += x_blk @ b_blk^T`` (MXU GEMM 1); on the last N block,
``y = t @ a^T`` (MXU GEMM 2).  Both contractions hit the MXU with
hardware-aligned shapes; this is the "few large matmuls, not 2d skinny ones"
adaptation recorded in DESIGN.md.

The same kernel computes the backward data gradient by operand swap:
``gx = (gy @ A) @ B = btt(gy, b=A^T, a=B^T)`` — see ``ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.cost_model import sublane as _sublane

__all__ = ["btt_linear_pallas", "choose_tiles", "DEFAULT_TK", "DEFAULT_TN",
           "btt_linear_decode_pallas", "choose_decode_tiles",
           "decode_linear_vmem_fits", "decode_linear_stage_vmem_bytes",
           "fused_decode_linear_hbm_bytes", "unfused_decode_linear_hbm_bytes"]

DEFAULT_TK = 256
DEFAULT_TN = 512
VMEM_BUDGET = 12 * 1024 * 1024


def choose_tiles(M: int, R: int, itemsize: int, *, tk: int | None = None,
                 tn: int | None = None,
                 K: int | None = None) -> tuple[int, int, int, int, int]:
    """(tk, tn, mp, rp, vmem_bytes): tile sizes + padded dims + the per-grid-
    step VMEM working set, shrinking ``tk`` until it fits VMEM_BUDGET.

    ``K`` (the paper's batch x seq, tiny in the on-FPGA regime: 32) caps
    ``tk`` at the sublane-aligned row count actually present, so a K=32
    launch doesn't pad to — and stream — a 256-row block (8x the real
    traffic and residency).

    Single source of truth for the kernel's residency: ``btt_linear_pallas``
    launches with these tiles and ``core.memory_ledger`` reports the same
    ``vmem_bytes`` — the two cannot drift.
    """
    tk = tk or DEFAULT_TK
    tn = tn or DEFAULT_TN
    if K is not None:
        # 32-row alignment satisfies every dtype's sublane tile (f32 8,
        # bf16 16, int8 32).
        tk = min(tk, _round_up(K, 32))
    mp = _round_up(M, 128)
    rp = _round_up(R, 128)

    # y block (tk, mp) + a (mp, rp) + x (tk, tn) + b (rp, tn) + t (tk, rp) f32
    def vmem(tk_):
        return (tk_ * mp * itemsize + mp * rp * itemsize + tk_ * tn * itemsize
                + rp * tn * itemsize + tk_ * rp * 4)

    while tk > 64 and vmem(tk) > VMEM_BUDGET:
        tk //= 2
    return tk, tn, mp, rp, vmem(tk)


def _fwd_kernel(x_ref, b_ref, a_ref, y_ref, t_ref, *, n_blocks: int):
    """Grid (nK, nN); see module docstring for block shapes."""
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _zero():
        t_ref[...] = jnp.zeros_like(t_ref)

    # GEMM 1: accumulate the fused intermediate t = x @ b^T in f32.
    t_ref[...] += jax.lax.dot_general(
        x_ref[...], b_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(n == n_blocks - 1)
    def _emit():
        # GEMM 2: y = t @ a^T, emitted once per K row-block.
        y_ref[...] = jax.lax.dot_general(
            t_ref[...].astype(a_ref.dtype), a_ref[...],
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(y_ref.dtype)


def _fwd_kernel_q(s_ref, x_ref, b_ref, a_ref, y_ref, t_ref, *,
                  n_blocks: int):
    """Quantized-operand forward: identical dataflow to ``_fwd_kernel``
    but x/b/a arrive in their storage dtypes (int8 / fp8 / anything) with
    per-tensor scales ``s = [s_x, s_b, s_a]`` in SMEM; tiles dequantize to
    f32 *in VMEM* before each MXU dot — the low-precision tensors never
    exist densely in f32 in HBM, and the accumulator chain stays f32
    (fp8 dots are thereby emulated on backends without native fp8 MXU
    support)."""
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _zero():
        t_ref[...] = jnp.zeros_like(t_ref)

    t_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * (s_ref[0, 0] * s_ref[0, 1])

    @pl.when(n == n_blocks - 1)
    def _emit():
        a = a_ref[...].astype(jnp.float32) * s_ref[0, 2]
        y_ref[...] = jax.lax.dot_general(
            t_ref[...], a,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(y_ref.dtype)


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(jax.jit,
                   static_argnames=("tk", "tn", "interpret", "out_dtype"))
def btt_linear_pallas(x: jax.Array, b: jax.Array, a: jax.Array, *,
                      scales: jax.Array | None = None, out_dtype=None,
                      tk: int | None = None, tn: int | None = None,
                      interpret: bool = False) -> jax.Array:
    """``y (K, M) = (x (K, N) @ b(R, N)^T) @ a(M, R)^T`` via one fused kernel.

    Pads every dim to hardware tiles (K, N to the block sizes; R, M to 128
    lanes); zero padding is exact for this bilinear map.  ``interpret=True``
    runs the kernel body in Python on CPU (used for all validation here —
    TPU v5e is the *target*).

    ``scales`` (a (1, 3) f32 array ``[s_x, s_b, s_a]``) switches to the
    quantized-operand kernel: x/b/a stream in their storage dtypes and
    dequantize tile-by-tile in VMEM (``_fwd_kernel_q``); ``out_dtype``
    then names the compute dtype of ``y`` (default ``x.dtype`` — wrong for
    int8 inputs, so quantized callers pass it).
    """
    K, N = x.shape
    R, _ = b.shape
    M, _ = a.shape
    out_dtype = out_dtype or x.dtype

    # --- choose tiles under a VMEM budget -------------------------------
    itemsize = max(jnp.dtype(v.dtype).itemsize for v in (x, b, a))
    tk, tn, mp, rp, _ = choose_tiles(M, R, itemsize, tk=tk, tn=tn, K=K)

    kp = _round_up(K, tk)
    np_ = _round_up(N, tn)
    xp = jnp.pad(x, ((0, kp - K), (0, np_ - N)))
    bp = jnp.pad(b, ((0, rp - R), (0, np_ - N)))
    ap = jnp.pad(a, ((0, mp - M), (0, rp - R)))

    n_blocks = np_ // tn
    grid = (kp // tk, n_blocks)

    data_specs = [
        pl.BlockSpec((tk, tn), lambda k, n: (k, n)),   # x
        pl.BlockSpec((rp, tn), lambda k, n: (0, n)),   # b
        pl.BlockSpec((mp, rp), lambda k, n: (0, 0)),   # a (resident)
    ]
    if scales is None:
        kern = functools.partial(_fwd_kernel, n_blocks=n_blocks)
        in_specs, operands = data_specs, (xp, bp, ap)
    else:
        kern = functools.partial(_fwd_kernel_q, n_blocks=n_blocks)
        in_specs = [pl.BlockSpec((1, 3), lambda k, n: (0, 0),
                                 memory_space=pltpu.SMEM)] + data_specs
        operands = (scales.astype(jnp.float32).reshape(1, 3), xp, bp, ap)

    y = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tk, mp), lambda k, n: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((kp, mp), out_dtype),
        scratch_shapes=[pltpu.VMEM((tk, rp), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return y[:K, :M]


# ---------------------------------------------------------------------------
# Decode specialization: one token per stream, half-factors pinned.
# ---------------------------------------------------------------------------
#
# At decode time K is the number of concurrent streams (1-16 in the serving
# regime), not batch x seq — the training chooser's 32-row granule would pad
# a batch-1 stream to 32 streamed rows.  The decode chooser pads only to the
# dtype's true sublane tile (f32 8 / bf16 16 / int8 32) and, because the
# half-factors don't change between steps, treats them as VMEM-PINNED: the
# analytic byte model amortizes their fetch over ``steps`` decode steps,
# which is what the serve loop's jitted step achieves by re-passing the same
# device-resident arrays.


def choose_decode_tiles(M: int, R: int, itemsize: int, *, B: int,
                        tn: int | None = None
                        ) -> tuple[int, int, int, int, int]:
    """(tk, tn, mp, rp, vmem_bytes) for a decode-shape launch: ``tk`` is the
    stream count padded to the dtype sublane tile (TK=1-row tiles, hardware
    granule permitting) and ``tn`` shrinks to fit instead.

    Same single-source-of-truth contract as :func:`choose_tiles`: the decode
    kernel launches with these tiles, ``ops`` gates on
    :func:`decode_linear_vmem_fits`, and the ledger's DECODE rows report the
    same ``vmem_bytes``.
    """
    tk = _round_up(B, _sublane(itemsize))
    tn = tn or DEFAULT_TN
    mp = _round_up(M, 128)
    rp = _round_up(R, 128)

    def vmem(tn_):
        return (tk * mp * itemsize + mp * rp * itemsize + tk * tn_ * itemsize
                + rp * tn_ * itemsize + tk * rp * 4)

    while tn > 128 and vmem(tn) > VMEM_BUDGET:
        tn //= 2
    return tk, tn, mp, rp, vmem(tn)


def decode_linear_vmem_fits(M: int, R: int, itemsize: int, *, B: int,
                            budget: int | None = None) -> bool:
    budget = budget or VMEM_BUDGET
    return choose_decode_tiles(M, R, itemsize, B=B)[4] <= budget


def decode_linear_stage_vmem_bytes(M: int, R: int, itemsize: int, *, B: int,
                                   fused: bool = True,
                                   budget: int | None = None) -> int:
    """VMEM working set a decode TT-linear launch holds (0 when unfused or
    over budget — the fallback two-call path keeps no scratch)."""
    if not fused or not decode_linear_vmem_fits(M, R, itemsize, B=B,
                                                budget=budget):
        return 0
    return choose_decode_tiles(M, R, itemsize, B=B)[4]


@functools.partial(jax.jit, static_argnames=("interpret",))
def btt_linear_decode_pallas(x: jax.Array, b: jax.Array, a: jax.Array, *,
                             interpret: bool = False) -> jax.Array:
    """Decode-shape ``btt_linear_pallas``: same fused dataflow, row tiles at
    the dtype sublane granule so a handful of streams doesn't pad to a
    training-size 32-row block."""
    K = x.shape[0]
    R = b.shape[0]
    M = a.shape[0]
    itemsize = jnp.dtype(x.dtype).itemsize
    tk, tn, _, _, _ = choose_decode_tiles(M, R, itemsize, B=K)
    return btt_linear_pallas(x, b, a, tk=tk, tn=tn, interpret=interpret)


def fused_decode_linear_hbm_bytes(B: int, M: int, N: int, R: int,
                                  itemsize: int, *, steps: int = 1) -> int:
    """HBM bytes ONE decode step of the fused TT linear moves, half-factor
    fetches amortized over ``steps`` pinned decode steps.  Per step only the
    (tk, N) activation row goes in and the (tk, M) row comes out; the
    intermediate lives in VMEM scratch."""
    tk, tn, mp, rp, _ = choose_decode_tiles(M, R, itemsize, B=B)
    np_ = _round_up(N, tn)
    io = tk * np_ * itemsize + tk * mp * itemsize
    factors = (rp * np_ + mp * rp) * itemsize
    return io + -(-factors // steps)


def unfused_decode_linear_hbm_bytes(B: int, M: int, N: int, R: int,
                                    itemsize: int) -> int:
    """HBM bytes of the unfused two-GEMM decode path: training-granule
    (32-row) launch padding, the ``(K, R)`` intermediate round-tripping HBM
    between the GEMMs, half-factors re-fetched every step (XLA pins nothing
    across dispatches)."""
    kp = _round_up(B, 32)
    rp = _round_up(R, 128)
    mp = _round_up(M, 128)
    np_ = _round_up(N, 128)
    g1 = kp * np_ * itemsize + rp * np_ * itemsize + kp * rp * itemsize
    g2 = kp * rp * itemsize + mp * rp * itemsize + kp * mp * itemsize
    return g1 + g2
