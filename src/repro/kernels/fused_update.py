"""Pallas TPU kernels: fused parameter-update (PU) stage.

The paper's framework keeps *every* training stage on chip (Sec. III-A):
FWD, BWD, and the parameter update (step 3, "PU") all run against the
BRAM/URAM budget.  FWD/BWD are already fused (``btt_linear.py`` +
the custom VJP in ``ops.py``); this module fuses the third stage.  The idiom
follows Count-Sketch Optimizers' dense path — "update the auxiliary
variables and perform the gradient update in a single fused kernel" — so a
training step touches each optimizer buffer exactly once.

Why fuse an elementwise update?  Unfused, an AdamW step is ~10 XLA HLOs per
parameter leaf; each moment buffer round-trips HBM<->VMEM several times
(read m, write m', read m' again for the step, ...).  Fused, the kernel
tiles **flattened** parameter / gradient / moment buffers through VMEM once:
per grid step it reads one (rows, lanes) block of each operand, computes the
entire update (moment EMAs, bias correction, weight decay, parameter delta)
in registers/VMEM f32, and writes the block back.  ``input_output_aliases``
makes the update in-place at the *packed-buffer* level — the kernel itself
never double-buffers optimizer state, which matters when the budget is a
few MB of on-chip SRAM.  The pack/unpack reshapes around the kernel are
ordinary XLA ops: leaves still round-trip into the packed layout each step
(XLA fuses but does not alias through concatenate/pad), so end-to-end
leaf-level aliasing awaits storing optimizer state flat-packed between
steps — noted as future work in docs/memory_optimizations.md.

Layout: each dtype-group of leaves is raveled and concatenated into one 1-D
buffer, zero-padded to a (rows, LANES) tile grid — one kernel launch per
*training step*, not per core.  This is the PU analogue of the packed core
buffers in ``core.cost_model.tpu_packing_efficiency``: TT cores are tiny
(a (12, 8, 12) core wastes >90% of an (8, 128) tile stored alone), so the
flat packing is also what makes the PU stage's VMEM residency minimal.

All kernels run ``interpret=True`` on CPU (the validation path, like every
other kernel here); TPU is the target.  Pure-JAX fallbacks live in
``optim.optimizers`` (``fused=False``).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quant as _quant
from repro.core.cost_model import sublane as _cm_sublane

__all__ = [
    "fused_sgd_update",
    "fused_adamw_update",
    "fused_adamw_update_quant",
    "quant_master_pack",
    "quant_master_unpack",
    "quant_pu_hbm_bytes",
    "sketched_adamw_update",
    "sketched_adamw_update_quant",
    "pack_leaves",
    "unpack_leaves",
    "pu_block_shape",
    "fused_pu_hbm_bytes",
    "unfused_pu_hbm_bytes",
    "sketched_pu_hbm_bytes",
    "sketch_bucket_ids",
    "sketch_signs",
    "sketch_state_bytes",
    "sketch_pu_vmem_bytes",
    "sketch_pu_fits",
    "default_sketch_width",
    "SKETCH_DEPTH_DEFAULT",
]

LANES = 1024          # minor dim of the flattened tile grid (8 x 128 lanes)
BLOCK_ROWS = 256      # rows per grid step: (256, 1024) f32 block = 1 MB


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def pu_block_shape(n_elems: int) -> tuple[int, int, int]:
    """(block_rows, padded_rows, lanes) for a flat buffer of ``n_elems``.

    Small buffers (the whole ATIS TT model is ~0.3M elements) collapse to a
    single sublane-aligned block; large ones stream BLOCK_ROWS-row tiles.
    """
    lanes = LANES if n_elems >= LANES else 128
    rows = max(1, -(-n_elems // lanes))
    br = min(BLOCK_ROWS, _round_up(rows, 8))
    return br, _round_up(rows, br), lanes


def pack_leaves(leaves: Sequence[jax.Array], dtype, rows_p: int,
                lanes: int) -> jax.Array:
    """Ravel+concat ``leaves`` into one padded (rows_p, lanes) buffer."""
    flat = jnp.concatenate([jnp.ravel(x).astype(dtype) for x in leaves])
    return jnp.pad(flat, (0, rows_p * lanes - flat.size)).reshape(rows_p, lanes)


def unpack_leaves(buf: jax.Array, shapes: Sequence[tuple[int, ...]],
                  dtypes: Sequence[Any]) -> list[jax.Array]:
    """Inverse of :func:`pack_leaves` (slices are static; XLA fuses them)."""
    flat = buf.reshape(-1)
    sizes = [int(np.prod(s)) for s in shapes]
    offs = np.concatenate([[0], np.cumsum(sizes)])
    return [
        jax.lax.slice(flat, (int(offs[i]),), (int(offs[i + 1]),))
        .reshape(shapes[i]).astype(dtypes[i])
        for i in range(len(shapes))
    ]


# ---------------------------------------------------------------------------
# Kernel bodies.  Grid is 1-D over row blocks; scalars ride in SMEM as a
# (1, k) f32 vector (TPU scalars must be 2-D); hyperparameters that are
# Python floats are baked in as compile-time constants via partial.
# ---------------------------------------------------------------------------


def _sgd_kernel(scal_ref, p_ref, g_ref, o_ref):
    lr = scal_ref[0, 0]
    p = p_ref[...].astype(jnp.float32)
    o_ref[...] = (p - lr * g_ref[...]).astype(o_ref.dtype)


def _sgd_momentum_kernel(scal_ref, p_ref, mu_ref, g_ref, o_ref, omu_ref, *,
                         momentum: float):
    lr = scal_ref[0, 0]
    mu = momentum * mu_ref[...] + g_ref[...]
    p = p_ref[...].astype(jnp.float32)
    omu_ref[...] = mu
    o_ref[...] = (p - lr * mu).astype(o_ref.dtype)


def _adamw_kernel(scal_ref, p_ref, m_ref, v_ref, g_ref,
                  o_ref, om_ref, ov_ref, *,
                  b1: float, b2: float, eps: float, weight_decay: float):
    lr = scal_ref[0, 0]
    t = scal_ref[0, 1]
    # Bias correction computed IN-KERNEL from the step scalar.
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    g = g_ref[...]
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * jnp.square(g)
    p = p_ref[...].astype(jnp.float32)
    step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    if weight_decay:
        step = step + lr * weight_decay * p
    om_ref[...] = m
    ov_ref[...] = v
    o_ref[...] = (p - step).astype(o_ref.dtype)


def _pu_call(kernel, scal: jax.Array, bufs: Sequence[jax.Array],
             n_outs: int, br: int, interpret: bool) -> tuple[jax.Array, ...]:
    """Launch a PU kernel over flat (rows_p, lanes) buffers.

    ``bufs`` order is (aliased..., grads): param buffer first (its dtype is
    the first output's dtype), then f32 moment buffers, grads last.  The
    first ``n_outs`` bufs are aliased to the outputs, so donated inputs
    update in place.  ``br`` is the block-row count from the same
    ``pu_block_shape`` call that sized the buffers (rows_p % br == 0).
    """
    rows_p, lanes = bufs[0].shape
    grid = (rows_p // br,)
    blk = pl.BlockSpec((br, lanes), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0),
                               memory_space=pltpu.SMEM)]
        + [blk] * len(bufs),
        out_specs=[blk] * n_outs,
        out_shape=[jax.ShapeDtypeStruct(b.shape, b.dtype)
                   for b in bufs[:n_outs]],
        # scal is input 0; alias param/state inputs onto the outputs.
        input_output_aliases={1 + i: i for i in range(n_outs)},
        interpret=interpret,
    )(scal, *bufs)
    return tuple(out)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _dtype_groups(leaves: Sequence[jax.Array]) -> list[list[int]]:
    """Indices of ``leaves`` grouped by dtype (one kernel launch per group)."""
    groups: dict[Any, list[int]] = {}
    for i, x in enumerate(leaves):
        groups.setdefault(jnp.dtype(x.dtype), []).append(i)
    return list(groups.values())


def _scal(lr_t, t=0.0) -> jax.Array:
    return jnp.stack([jnp.asarray(lr_t, jnp.float32),
                      jnp.asarray(t, jnp.float32)]).reshape(1, 2)


# ---------------------------------------------------------------------------
# Public pytree-level entry points.
# ---------------------------------------------------------------------------


def fused_sgd_update(params, grads, lr_t, *, momentum: float = 0.0,
                     mu=None, interpret: bool | None = None):
    """One fused SGD(+momentum) PU stage over a parameter pytree.

    Returns ``new_params`` (momentum == 0) or ``(new_params, new_mu)``.
    Numerics match the pure-JAX path in ``optim.optimizers.sgd`` (all math
    in f32, params cast back to their storage dtype).
    """
    if interpret is None:
        interpret = _interpret_default()
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    mu_leaves = treedef.flatten_up_to(mu) if mu is not None else None
    new_p: list = [None] * len(p_leaves)
    new_mu: list = [None] * len(p_leaves)
    scal = _scal(lr_t)
    for idx in _dtype_groups(p_leaves):
        group = [p_leaves[i] for i in idx]
        n = sum(int(np.prod(x.shape)) for x in group)
        br, rows_p, lanes = pu_block_shape(n)
        pdt = group[0].dtype
        pb = pack_leaves(group, pdt, rows_p, lanes)
        gb = pack_leaves([g_leaves[i] for i in idx], jnp.float32, rows_p, lanes)
        shapes = [x.shape for x in group]
        if momentum == 0.0:
            (ob,) = _pu_call(_sgd_kernel, scal, [pb, gb], 1, br, interpret)
            outs = unpack_leaves(ob, shapes, [pdt] * len(group))
            for j, i in enumerate(idx):
                new_p[i] = outs[j]
        else:
            mb = pack_leaves([mu_leaves[i] for i in idx], jnp.float32,
                             rows_p, lanes)
            kern = functools.partial(_sgd_momentum_kernel, momentum=momentum)
            ob, omb = _pu_call(kern, scal, [pb, mb, gb], 2, br, interpret)
            outs = unpack_leaves(ob, shapes, [pdt] * len(group))
            mouts = unpack_leaves(omb, shapes, [jnp.float32] * len(group))
            for j, i in enumerate(idx):
                new_p[i], new_mu[i] = outs[j], mouts[j]
    params_out = jax.tree.unflatten(treedef, new_p)
    if momentum == 0.0:
        return params_out
    return params_out, jax.tree.unflatten(treedef, new_mu)


def fused_adamw_update(params, grads, m, v, lr_t, t, *, b1: float,
                       b2: float, eps: float, weight_decay: float,
                       interpret: bool | None = None):
    """One fused AdamW PU stage: ``(new_params, new_m, new_v)``.

    ``t`` is the 1-based step (bias correction is computed in-kernel from
    it); hyperparameters are compile-time constants.
    """
    if interpret is None:
        interpret = _interpret_default()
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(m)
    v_leaves = treedef.flatten_up_to(v)
    new_p: list = [None] * len(p_leaves)
    new_m: list = [None] * len(p_leaves)
    new_v: list = [None] * len(p_leaves)
    scal = _scal(lr_t, t)
    kern = functools.partial(_adamw_kernel, b1=b1, b2=b2, eps=eps,
                             weight_decay=weight_decay)
    for idx in _dtype_groups(p_leaves):
        group = [p_leaves[i] for i in idx]
        n = sum(int(np.prod(x.shape)) for x in group)
        br, rows_p, lanes = pu_block_shape(n)
        pdt = group[0].dtype
        pb = pack_leaves(group, pdt, rows_p, lanes)
        mb = pack_leaves([m_leaves[i] for i in idx], jnp.float32, rows_p, lanes)
        vb = pack_leaves([v_leaves[i] for i in idx], jnp.float32, rows_p, lanes)
        gb = pack_leaves([g_leaves[i] for i in idx], jnp.float32, rows_p, lanes)
        ob, omb, ovb = _pu_call(kern, scal, [pb, mb, vb, gb], 3, br, interpret)
        shapes = [x.shape for x in group]
        outs = unpack_leaves(ob, shapes, [pdt] * len(group))
        mouts = unpack_leaves(omb, shapes, [jnp.float32] * len(group))
        vouts = unpack_leaves(ovb, shapes, [jnp.float32] * len(group))
        for j, i in enumerate(idx):
            new_p[i], new_m[i], new_v[i] = outs[j], mouts[j], vouts[j]
    return (jax.tree.unflatten(treedef, new_p),
            jax.tree.unflatten(treedef, new_m),
            jax.tree.unflatten(treedef, new_v))


# ---------------------------------------------------------------------------
# Quantized-master AdamW: int8/fp8 params at rest, f32 step in VMEM.
#
# With a quantized storage tier (``core.quant``) the fused PU stage keeps
# the *master* copy of the parameters in int8 / fp8_e4m3 — the only copy;
# there is no shadow f32 master in HBM.  The packed (rows_p, LANES) buffer
# carries one f32 scale per (br, LANES) grid block (the "per_tile"
# granularity of ``PrecisionConfig``), so each kernel step is closed over a
# single block: dequantize the block into VMEM f32, run the identical
# AdamW math as ``_adamw_kernel``, compute the block's new max-abs scale
# IN-KERNEL, and stochastically round the updated block back onto the
# storage grid (``quant.stochastic_round``, counter-keyed by
# (element, step, block id) — bit-reproducible across checkpoint resume).
# Moments stay f32 (or sketched — orthogonal): the round-off each step is
# confined to the parameter write, where SR keeps it zero-mean.
# ---------------------------------------------------------------------------


def _adamw_quant_kernel(scal_ref, pq_ref, ps_ref, m_ref, v_ref, g_ref,
                        oq_ref, ops_ref, om_ref, ov_ref, *,
                        b1: float, b2: float, eps: float,
                        weight_decay: float, fmt: str):
    """One packed block of the quantized-master AdamW PU stage."""
    lr = scal_ref[0, 0]
    t = scal_ref[0, 1]
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    g = g_ref[...]
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * jnp.square(g)
    # In-VMEM dequant of the master block: int8/fp8 tile -> f32 registers.
    p = pq_ref[...].astype(jnp.float32) * ps_ref[0, 0]
    step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    if weight_decay:
        step = step + lr * weight_decay * p
    p_new = p - step
    f = _quant.resolve(fmt)
    s_new = jnp.maximum(jnp.max(jnp.abs(p_new)), _quant._TINY) / f.qmax
    om_ref[...] = m
    ov_ref[...] = v
    ops_ref[0, 0] = s_new
    oq_ref[...] = _quant.stochastic_round(
        p_new / s_new, fmt, t.astype(jnp.int32), pl.program_id(0))


def quant_master_pack(leaves: Sequence[jax.Array], fmt: str
                      ) -> tuple[jax.Array, jax.Array]:
    """Pack param ``leaves`` into the quantized master state ``(pq, ps)``:
    ``pq`` a (rows_p, LANES) storage-dtype buffer, ``ps`` (n_blocks, 1) f32
    per-block scales — the layout the quant PU kernel streams.  Initial
    quantization is round-to-nearest (no step counter exists yet)."""
    f = _quant.resolve(fmt)
    n = sum(int(np.prod(x.shape)) for x in leaves)
    br, rows_p, lanes = pu_block_shape(n)
    pb = pack_leaves(leaves, jnp.float32, rows_p, lanes)
    n_blocks = rows_p // br
    blocks = pb.reshape(n_blocks, br * lanes)
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    ps = (jnp.maximum(amax, _quant._TINY) / f.qmax).astype(jnp.float32)
    z = jnp.clip(blocks / ps, -f.qmax, f.qmax)
    q = jnp.round(z) if f.name == "int8" else z
    pq = q.astype(f.dtype).reshape(rows_p, lanes)
    return pq, ps


def quant_master_unpack(pq: jax.Array, ps: jax.Array,
                        shapes: Sequence[tuple[int, ...]],
                        dtypes: Sequence[Any]) -> list[jax.Array]:
    """Dequantized (compute-dtype) leaf views of the master ``(pq, ps)`` —
    what the FWD/BWD stages consume.  Inverse of :func:`quant_master_pack`
    up to the storage grid's round-off."""
    rows_p, lanes = pq.shape
    n_blocks = ps.shape[0]
    br = rows_p // n_blocks
    pb = (pq.astype(jnp.float32).reshape(n_blocks, br * lanes)
          * ps).reshape(rows_p, lanes)
    return unpack_leaves(pb, shapes, dtypes)


def fused_adamw_update_quant(pq, ps, mb, vb, gb, lr_t, t, *, fmt: str,
                             b1: float, b2: float, eps: float,
                             weight_decay: float,
                             interpret: bool | None = None):
    """One quantized-master AdamW PU step over packed buffers:
    ``(new_pq, new_ps, new_mb, new_vb)``.

    ``pq``/``ps`` from :func:`quant_master_pack`; ``mb``/``vb``/``gb`` are
    (rows_p, LANES) f32 packed moment/grad buffers (``pack_leaves``).  The
    master is dequantized, updated, re-scaled and stochastically re-rounded
    entirely inside the kernel — no dense f32 parameter buffer touches HBM.
    """
    if interpret is None:
        interpret = _interpret_default()
    rows_p, lanes = pq.shape
    n_blocks = ps.shape[0]
    br = rows_p // n_blocks
    grid = (n_blocks,)
    blk = pl.BlockSpec((br, lanes), lambda i: (i, 0))
    sblk = pl.BlockSpec((1, 1), lambda i: (i, 0))
    kern = functools.partial(_adamw_quant_kernel, b1=b1, b2=b2, eps=eps,
                             weight_decay=weight_decay, fmt=fmt)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0),
                               memory_space=pltpu.SMEM),
                  blk, sblk, blk, blk, blk],
        out_specs=[blk, sblk, blk, blk],
        out_shape=[jax.ShapeDtypeStruct(pq.shape, pq.dtype),
                   jax.ShapeDtypeStruct(ps.shape, ps.dtype),
                   jax.ShapeDtypeStruct(mb.shape, mb.dtype),
                   jax.ShapeDtypeStruct(vb.shape, vb.dtype)],
        input_output_aliases={1: 0, 2: 1, 3: 2, 4: 3},
        interpret=interpret,
    )(_scal(lr_t, t), pq, ps, mb, vb, gb)
    return tuple(out)


def quant_pu_hbm_bytes(n_params: int, fmt: str) -> int:
    """HBM bytes of one quantized-master AdamW PU step: the packed master
    streams at the storage itemsize (read + aliased write) plus its scale
    sidecar; moments and grads stay f32 as in ``fused_pu_hbm_bytes``."""
    its = _quant.itemsize(fmt)
    br, rows_p, lanes = pu_block_shape(n_params)
    n_pad = rows_p * lanes
    n_blocks = rows_p // br
    reads = n_pad * (its + 4 + 4 * 2) + 4 * n_blocks
    writes = n_pad * (its + 4 * 2) + 4 * n_blocks
    return reads + writes


# ---------------------------------------------------------------------------
# Sketch-compressed AdamW (Count-Sketch Optimizers' fused-kernel idea).
#
# Dense AdamW's two f32 moment buffers are 2x the parameter footprint — the
# dominant PU-stage cost against the paper's on-chip budget.  Following
# "Memory-Constrained Optimization via Count-Sketches", the moments are held
# as d x w hash sketches (w << n_params) and BOTH the sketch refresh and the
# parameter update happen inside one Pallas kernel, so the dense ``m``/``v``
# buffers never exist in HBM:
#
# * second moment ``v`` (nonnegative): a count-MIN sketch with a
#   *conservative* refresh — per step every cell is overwritten with the
#   MAX over its colliding parameters of the decayed estimate
#   ``b2 * est_v + (1 - b2) * g^2``; queries take the MIN over the d rows.
#   By induction the estimate never under-shoots the dense ``v``
#   (the CMS overestimate invariant, asserted elementwise in
#   tests/test_sketched_update.py), so sketching can only *shrink* step
#   sizes — the safe direction for Adam.
# * first moment ``m`` (signed): a count-sketch updated in the LINEAR
#   form — the EMA is linear, so the sketch itself can be the EMA: cells
#   decay by ``b1`` once per step and accumulate only
#   ``sign_r(i) * (1 - b1) * g_i``.  Each cell then holds exactly the
#   signed sum of its colliders' true dense ``m``; queries take the MEDIAN
#   over rows of the sign-corrected cells (the classical unbiased
#   estimator) and collision noise is zero-mean.  Crucially the sketch
#   state never depends on its own queries — rewriting full estimates
#   ``b1 * est_m + (1-b1) g`` into cells instead would feed ~sqrt(#colliders)
#   query noise back through ``b1`` and amplify it exponentially.
#
# Per grid step the kernel hashes the block's flat parameter indices
# (multiplicative hashing, compile-time odd constants — the identical
# functions are exported below so the NumPy oracle in the tests computes
# the very same buckets), queries the previous step's sketches, applies the
# bias-corrected update to the parameter block, and scatters the refreshed
# estimates into the new sketches, which live in VMEM-resident output
# blocks (constant index map) flushed to HBM once per launch.  The gather/
# scatter run as jnp take/segment ops in the kernel body — exact in
# interpret mode (the validation path, as everywhere in this package); the
# native TPU lowering is the one-hot/MXU idiom ``ttm_embed.py`` already
# uses for its gather-free lookup.
# ---------------------------------------------------------------------------

SKETCH_DEPTH_DEFAULT = 3

# Odd multiplicative-hash constants per sketch row (Knuth/Murmur-style).
# Deterministic module-level tables: the kernel, the pure-JAX oracle, and a
# restored checkpoint all hash identically by construction.
_HASH_MULT = 2654435761        # 2^32 / golden ratio, odd
_HASH_ADD = 0x85EBCA77
_SIGN_MULT = 0xC2B2AE3D
_SIGN_ADD = 0x27D4EB2F


def _hash_consts(depth: int, mult: int, add: int):
    ms = [(mult * (2 * r + 3)) & 0xFFFFFFFF | 1 for r in range(depth)]
    bs = [(add * (r + 1)) & 0xFFFFFFFF for r in range(depth)]
    return ms, bs


def sketch_bucket_ids(idx, depth: int, width: int):
    """(depth, *idx.shape) int32 bucket ids in [0, width) for flat parameter
    indices ``idx`` — multiplicative hashing on uint32 with the top
    log2(width) bits.  ``width`` must be a power of two.  This is THE hash
    the kernel uses; the tests' dense NumPy oracle calls it too."""
    if width & (width - 1) or width <= 0:
        raise ValueError(f"sketch width must be a power of two, got {width}")
    shift = 32 - int(math.log2(width))
    u = jnp.asarray(idx).astype(jnp.uint32) + jnp.uint32(1)
    ms, bs = _hash_consts(depth, _HASH_MULT, _HASH_ADD)
    return jnp.stack([
        ((u * jnp.uint32(ms[r]) + jnp.uint32(bs[r]))
         >> jnp.uint32(shift)).astype(jnp.int32)
        for r in range(depth)])


def sketch_signs(idx, depth: int):
    """(depth, *idx.shape) f32 in {-1, +1}: the count-sketch sign hashes for
    the first-moment rows (top bit of an independent multiplicative hash)."""
    u = jnp.asarray(idx).astype(jnp.uint32) + jnp.uint32(1)
    ms, bs = _hash_consts(depth, _SIGN_MULT, _SIGN_ADD)
    return jnp.stack([
        1.0 - 2.0 * ((u * jnp.uint32(ms[r]) + jnp.uint32(bs[r]))
                     >> jnp.uint32(31)).astype(jnp.float32)
        for r in range(depth)])


def default_sketch_width(n_params: int, depth: int = SKETCH_DEPTH_DEFAULT) -> int:
    """Largest power-of-two width with ``depth * width <= n_params / 8``
    (floor 128): both sketches together are then <= 1/8 of ONE dense moment
    buffer, i.e. >= 16x under dense AdamW's two.  Capped so the kernel's six
    resident (depth, width) sketch blocks stay within half the VMEM budget —
    the default width never fails ``sketch_pu_fits`` on VMEM grounds."""
    from .btt_linear import VMEM_BUDGET

    target = max(n_params // (8 * max(depth, 1)), 1)
    cap = max(VMEM_BUDGET // (2 * 6 * max(depth, 1) * 4), 128)
    target = min(target, cap)
    return max(1 << (target.bit_length() - 1), 128)


def sketch_state_bytes(depth: int, width: int) -> int:
    """HBM-persistent optimizer state of the sketched path: two f32
    (depth, width) sketches (vs + ms) — vs dense AdamW's 2 * n_params f32."""
    return 2 * depth * width * 4


def sketch_pu_vmem_bytes(n_params: int, width: int,
                         depth: int = SKETCH_DEPTH_DEFAULT, *,
                         itemsize: int = 4) -> int:
    """VMEM working set of one sketched-update grid step: the param block
    (storage dtype) + grad block (f32) + two f32 index/estimate temporaries,
    plus all six sketch blocks live across the launch (old vs/ms in, seed
    vs/ms in, new vs/ms resident output).  The single residency source for
    the ledger's sketched PU rows (like ``pu_block_shape`` for the dense
    kernel)."""
    br, _, lanes = pu_block_shape(n_params)
    return br * lanes * (itemsize + 4 + 8) + 6 * depth * width * 4


def sketch_pu_fits(n_params: int, width: int,
                   depth: int = SKETCH_DEPTH_DEFAULT, *,
                   itemsize: int = 4) -> bool:
    """The dispatch predicate ``optim.adamw(sketched=True)`` gates on (and
    the memory ledger with it — same function, no drift): the kernel's
    working set must fit the VMEM budget AND the sketch state must be at
    least 4x smaller than the dense moments it replaces (tiny trees fall
    back to dense fused AdamW — a 128-wide sketch saves nothing there)."""
    from .btt_linear import VMEM_BUDGET

    return (sketch_pu_vmem_bytes(n_params, width, depth,
                                 itemsize=itemsize) <= VMEM_BUDGET
            and 4 * sketch_state_bytes(depth, width) <= 2 * n_params * 4)


def _sketched_math(scal_ref, vso_ref, mso_ref, vsd_ref, msd_ref, g_ref,
                   ovs_ref, oms_ref, p, br: int, lanes: int, *,
                   b1: float, b2: float, eps: float, weight_decay: float,
                   depth: int, width: int, n_valid: int, base: int):
    """Shared body of the sketched PU kernels: query the old sketches,
    refresh the new ones, and return the updated flat f32 parameter block.

    ``base`` is the global flat offset of this launch's dtype group and
    ``n_valid`` its true element count; padded lanes hash to masked
    (identity) contributions so they never pollute a bucket.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        # Seed the new sketches: zeros for the step's first dtype group,
        # the previous group's partial sketches otherwise.
        ovs_ref[...] = vsd_ref[...]
        oms_ref[...] = msd_ref[...]

    lr = scal_ref[0, 0]
    t = scal_ref[0, 1]
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    rows = jax.lax.broadcasted_iota(jnp.int32, (br, lanes), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (br, lanes), 1)
    local = (rows * lanes + cols + i * br * lanes).reshape(-1)
    valid = local < n_valid
    idx = local + base
    h = sketch_bucket_ids(idx, depth, width)         # (depth, n_blk)
    s = sketch_signs(idx, depth)
    vs_old = vso_ref[...]
    ms_old = mso_ref[...]
    # Query last step's estimates: min over rows (count-min, v) and median
    # over sign-corrected rows (count-sketch, m).
    est_v = jnp.min(jnp.stack(
        [jnp.take(vs_old[r], h[r]) for r in range(depth)]), axis=0)
    est_m = jnp.sort(jnp.stack(
        [jnp.take(ms_old[r], h[r]) * s[r] for r in range(depth)]),
        axis=0)[(depth - 1) // 2]
    g = g_ref[...].reshape(-1)
    m_new = b1 * est_m + (1.0 - b1) * g
    v_new = b2 * est_v + (1.0 - b2) * jnp.square(g)
    # Refresh the sketches: conservative overwrite (max of decayed
    # estimates) for v, signed accumulation for m; masked elements
    # contribute the scatter identity (0 — v_new >= 0 always).
    v_c = jnp.where(valid, v_new, 0.0)
    zero_w = jnp.zeros((width,), jnp.float32)
    for r in range(depth):
        ovs_ref[r, :] = jnp.maximum(ovs_ref[r, :], zero_w.at[h[r]].max(v_c))
        # linear count-sketch refresh: only the gradient increment — the b1
        # decay of the cells happens once per step in the host-side seed.
        oms_ref[r, :] = oms_ref[r, :] + zero_w.at[h[r]].add(
            jnp.where(valid, s[r] * (1.0 - b1) * g, 0.0))
    step = lr * (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    if weight_decay:
        step = step + lr * weight_decay * p
    return p - step


def _sketched_adamw_kernel(scal_ref, p_ref, vso_ref, mso_ref, vsd_ref,
                           msd_ref, g_ref, o_ref, ovs_ref, oms_ref, *,
                           b1: float, b2: float, eps: float,
                           weight_decay: float, depth: int, width: int,
                           n_valid: int, base: int):
    """One (br, lanes) block of the sketched PU stage (f32 master)."""
    br, lanes = p_ref.shape
    p = p_ref[...].astype(jnp.float32).reshape(-1)
    p_new = _sketched_math(
        scal_ref, vso_ref, mso_ref, vsd_ref, msd_ref, g_ref, ovs_ref,
        oms_ref, p, br, lanes, b1=b1, b2=b2, eps=eps,
        weight_decay=weight_decay, depth=depth, width=width,
        n_valid=n_valid, base=base)
    o_ref[...] = p_new.reshape(br, lanes).astype(o_ref.dtype)


def _sketched_adamw_quant_kernel(scal_ref, pq_ref, ps_ref, vso_ref, mso_ref,
                                 vsd_ref, msd_ref, g_ref, oq_ref, ops_ref,
                                 ovs_ref, oms_ref, *, b1: float, b2: float,
                                 eps: float, weight_decay: float, depth: int,
                                 width: int, n_valid: int, base: int,
                                 fmt: str):
    """Sketched PU block with a quantized (int8/fp8) master: in-VMEM
    dequant on entry, in-kernel rescale + stochastic re-round on exit —
    composes the two HBM compressions (sketched moments, quantized
    params) in one kernel pass."""
    br, lanes = pq_ref.shape
    p = (pq_ref[...].astype(jnp.float32) * ps_ref[0, 0]).reshape(-1)
    p_new = _sketched_math(
        scal_ref, vso_ref, mso_ref, vsd_ref, msd_ref, g_ref, ovs_ref,
        oms_ref, p, br, lanes, b1=b1, b2=b2, eps=eps,
        weight_decay=weight_decay, depth=depth, width=width,
        n_valid=n_valid, base=base)
    f = _quant.resolve(fmt)
    s_new = jnp.maximum(jnp.max(jnp.abs(p_new)), _quant._TINY) / f.qmax
    ops_ref[0, 0] = s_new
    oq_ref[...] = _quant.stochastic_round(
        (p_new / s_new).reshape(br, lanes), fmt,
        scal_ref[0, 1].astype(jnp.int32), pl.program_id(0))


def _sketched_call(kern, scal, pb, gb, vs_old, ms_old, vs_seed, ms_seed,
                   br: int, interpret: bool):
    """Launch the sketched kernel over one packed dtype group.  The param
    buffer is aliased in place; the (depth, width) sketch blocks have a
    constant index map — VMEM-resident across the (sequential) grid,
    flushed to HBM once, exactly like btt_backward's gA/gB accumulators."""
    rows_p, lanes = pb.shape
    grid = (rows_p // br,)
    blk = pl.BlockSpec((br, lanes), lambda i: (i, 0))
    skb = pl.BlockSpec(vs_old.shape, lambda i: (0, 0))
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0),
                               memory_space=pltpu.SMEM),
                  blk, skb, skb, skb, skb, blk],
        out_specs=[blk, skb, skb],
        out_shape=[jax.ShapeDtypeStruct(pb.shape, pb.dtype),
                   jax.ShapeDtypeStruct(vs_old.shape, vs_old.dtype),
                   jax.ShapeDtypeStruct(ms_old.shape, ms_old.dtype)],
        input_output_aliases={1: 0},
        interpret=interpret,
    )(scal, pb, vs_old, ms_old, vs_seed, ms_seed, gb)
    return tuple(out)


def sketched_adamw_update(params, grads, vs, ms, lr_t, t, *, b1: float,
                          b2: float, eps: float, weight_decay: float,
                          interpret: bool | None = None):
    """One sketched-AdamW PU stage: ``(new_params, new_vs, new_ms)``.

    ``vs``/``ms`` are the (depth, width) f32 count-min / count-sketch
    moment sketches from the previous step (zeros at step 0 — matching
    dense AdamW's zero-initialized moments).  Per dtype group one kernel
    launch queries the old sketches, updates the parameters, and scatters
    the refreshed estimates into the new ones; groups chain through the
    seed operands so the final sketches cover the whole tree.  Flat
    parameter indices are global across the concatenated group layout, so
    the hash assignment is stable across steps and checkpoints.
    """
    if interpret is None:
        interpret = _interpret_default()
    depth, width = vs.shape
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    new_p: list = [None] * len(p_leaves)
    scal = _scal(lr_t, t)
    kern = functools.partial(
        _sketched_adamw_kernel, b1=b1, b2=b2, eps=eps,
        weight_decay=weight_decay, depth=depth, width=width)
    vs_seed = jnp.zeros_like(vs)
    # m-sketch EMA decay is applied ONCE per step here; kernels then only
    # scatter-add the (1 - b1)-scaled signed gradient increments.
    ms_seed = b1 * ms
    base = 0
    for idx in _dtype_groups(p_leaves):
        group = [p_leaves[i] for i in idx]
        n = sum(int(np.prod(x.shape)) for x in group)
        br, rows_p, lanes = pu_block_shape(n)
        pdt = group[0].dtype
        pb = pack_leaves(group, pdt, rows_p, lanes)
        gb = pack_leaves([g_leaves[i] for i in idx], jnp.float32, rows_p,
                         lanes)
        ob, vs_seed, ms_seed = _sketched_call(
            functools.partial(kern, n_valid=n, base=base),
            scal, pb, gb, vs, ms, vs_seed, ms_seed, br, interpret)
        outs = unpack_leaves(ob, [x.shape for x in group],
                             [pdt] * len(group))
        for j, i in enumerate(idx):
            new_p[i] = outs[j]
        base += n
    return jax.tree.unflatten(treedef, new_p), vs_seed, ms_seed


def sketched_adamw_update_quant(pq, ps, vs, ms, gb, n_valid: int, lr_t, t,
                                *, fmt: str, b1: float, b2: float,
                                eps: float, weight_decay: float,
                                interpret: bool | None = None):
    """Sketched-AdamW PU step over a quantized packed master:
    ``(new_pq, new_ps, new_vs, new_ms)``.

    The quantized master is a single packed buffer (``quant_master_pack``),
    so unlike :func:`sketched_adamw_update` there is exactly one launch
    (``base = 0``); ``n_valid`` is the true (unpadded) element count and
    ``gb`` the (rows_p, LANES) f32 packed gradient buffer.
    """
    if interpret is None:
        interpret = _interpret_default()
    depth, width = vs.shape
    rows_p, lanes = pq.shape
    n_blocks = ps.shape[0]
    br = rows_p // n_blocks
    kern = functools.partial(
        _sketched_adamw_quant_kernel, b1=b1, b2=b2, eps=eps,
        weight_decay=weight_decay, depth=depth, width=width,
        n_valid=n_valid, base=0, fmt=fmt)
    blk = pl.BlockSpec((br, lanes), lambda i: (i, 0))
    sblk = pl.BlockSpec((1, 1), lambda i: (i, 0))
    skb = pl.BlockSpec(vs.shape, lambda i: (0, 0))
    out = pl.pallas_call(
        kern,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0),
                               memory_space=pltpu.SMEM),
                  blk, sblk, skb, skb, skb, skb, blk],
        out_specs=[blk, sblk, skb, skb],
        out_shape=[jax.ShapeDtypeStruct(pq.shape, pq.dtype),
                   jax.ShapeDtypeStruct(ps.shape, ps.dtype),
                   jax.ShapeDtypeStruct(vs.shape, vs.dtype),
                   jax.ShapeDtypeStruct(ms.shape, ms.dtype)],
        input_output_aliases={1: 0, 2: 1},
        # seed sketches (zeros / b1-decayed) ride as the vsd/msd operands.
        interpret=interpret,
    )(_scal(lr_t, t), pq, ps, vs, ms, jnp.zeros_like(vs), b1 * ms, gb)
    return tuple(out)


# ---------------------------------------------------------------------------
# Analytic HBM-traffic models (shared by benchmarks and the run.py --check
# regression guard).
# ---------------------------------------------------------------------------


def _moment_buffers(optimizer: str, momentum: float = 0.0) -> int:
    if optimizer == "adamw":
        return 2
    return 1 if momentum else 0


def _tile_padded_elems(shape: tuple, itemsize: int) -> int:
    """HBM footprint of one leaf stored alone: XLA pads a TPU array's
    minor two dims to the dtype's (sublane, 128) tile.  1-D leaves are
    modeled lane-padded only — generous to the unfused side."""
    if len(shape) == 0:
        return 1
    if len(shape) == 1:
        return _round_up(int(shape[0]), 128)
    sub = _cm_sublane(itemsize)  # f32 8, bf16 16, int8 32 (shared source)
    lead = 1
    for d in shape[:-2]:
        lead *= int(d)
    return lead * _round_up(int(shape[-2]), sub) * _round_up(int(shape[-1]),
                                                             128)


def fused_pu_hbm_bytes(leaves, optimizer: str, *,
                       momentum: float = 0.0) -> int:
    """HBM bytes of one fused PU step over ``leaves`` (arrays or
    ShapeDtypeStructs): per dtype group, every packed buffer (params,
    grads f32, moments f32) is read once and the param/moment buffers
    written once through ``input_output_aliases`` — the dense flat packing
    is the paper's grouped BRAM storage (Eqs. (24)/(25)): <1 block of
    padding per group instead of per-leaf tile waste."""
    n_m = _moment_buffers(optimizer, momentum)
    groups: dict = {}
    for x in leaves:
        dt = jnp.dtype(x.dtype)
        groups.setdefault(dt, 0)
        groups[dt] += int(np.prod(x.shape))
    total = 0
    for dt, n in groups.items():
        _, rows_p, lanes = pu_block_shape(n)
        n_pad = rows_p * lanes
        reads = n_pad * (dt.itemsize + 4 + 4 * n_m)
        writes = n_pad * (dt.itemsize + 4 * n_m)
        total += reads + writes
    return total


def unfused_pu_hbm_bytes(leaves, optimizer: str, *,
                         momentum: float = 0.0) -> int:
    """HBM bytes of the per-leaf XLA update: the same read/write counts as
    the fused model (generous — perfect elementwise fusion, each buffer
    touched once), but every leaf at its OWN tile-padded footprint: TT
    cores are tiny, so storing them alone wastes most of each (8, 128)
    tile (the waste ``core.cost_model.tpu_packing_efficiency`` measures
    and the packed layout exists to eliminate)."""
    n_m = _moment_buffers(optimizer, momentum)
    total = 0
    for x in leaves:
        its = jnp.dtype(x.dtype).itemsize
        n_pad = _tile_padded_elems(tuple(x.shape), its)
        n_pad_f32 = _tile_padded_elems(tuple(x.shape), 4)
        reads = n_pad * its + n_pad_f32 * (4 + 4 * n_m)
        writes = n_pad * its + n_pad_f32 * 4 * n_m
        total += reads + writes
    return total

def sketched_pu_hbm_bytes(leaves, *, depth: int = SKETCH_DEPTH_DEFAULT,
                          width: int | None = None) -> int:
    """HBM bytes of one *sketched* AdamW PU step: per dtype group the packed
    params (read + aliased write) and f32 grads (read) stream once, and per
    launch the four (depth, width) sketch operands (old vs/ms + seed vs/ms)
    are read and the two new ones written — the dense moment traffic
    (8 bytes/elem read + 8 written in ``fused_pu_hbm_bytes``) is gone
    entirely, replaced by O(depth * width) per launch."""
    groups: dict = {}
    for x in leaves:
        dt = jnp.dtype(x.dtype)
        groups.setdefault(dt, 0)
        groups[dt] += int(np.prod(x.shape))
    if width is None:
        width = default_sketch_width(sum(groups.values()), depth)
    total = 0
    for dt, n in groups.items():
        _, rows_p, lanes = pu_block_shape(n)
        n_pad = rows_p * lanes
        total += n_pad * (dt.itemsize + 4)      # read params + grads
        total += n_pad * dt.itemsize            # write params
        total += 6 * depth * width * 4          # 4 sketch reads + 2 writes
    return total
