"""Pallas TPU kernels: fused parameter-update (PU) stage.

The paper's framework keeps *every* training stage on chip (Sec. III-A):
FWD, BWD, and the parameter update (step 3, "PU") all run against the
BRAM/URAM budget.  FWD/BWD are already fused (``btt_linear.py`` +
the custom VJP in ``ops.py``); this module fuses the third stage.  The idiom
follows Count-Sketch Optimizers' dense path — "update the auxiliary
variables and perform the gradient update in a single fused kernel" — so a
training step touches each optimizer buffer exactly once.

Why fuse an elementwise update?  Unfused, an AdamW step is ~10 XLA HLOs per
parameter leaf; each moment buffer round-trips HBM<->VMEM several times
(read m, write m', read m' again for the step, ...).  Fused, the kernel
tiles **flattened** parameter / gradient / moment buffers through VMEM once:
per grid step it reads one (rows, lanes) block of each operand, computes the
entire update (moment EMAs, bias correction, weight decay, parameter delta)
in registers/VMEM f32, and writes the block back.  ``input_output_aliases``
makes the update in-place at the *packed-buffer* level — the kernel itself
never double-buffers optimizer state, which matters when the budget is a
few MB of on-chip SRAM.  The pack/unpack reshapes around the kernel are
ordinary XLA ops: leaves still round-trip into the packed layout each step
(XLA fuses but does not alias through concatenate/pad), so end-to-end
leaf-level aliasing awaits storing optimizer state flat-packed between
steps — noted as future work in docs/memory_optimizations.md.

Layout: each dtype-group of leaves is raveled and concatenated into one 1-D
buffer, zero-padded to a (rows, LANES) tile grid — one kernel launch per
*training step*, not per core.  This is the PU analogue of the packed core
buffers in ``core.cost_model.tpu_packing_efficiency``: TT cores are tiny
(a (12, 8, 12) core wastes >90% of an (8, 128) tile stored alone), so the
flat packing is also what makes the PU stage's VMEM residency minimal.

All kernels run ``interpret=True`` on CPU (the validation path, like every
other kernel here); TPU is the target.  Pure-JAX fallbacks live in
``optim.optimizers`` (``fused=False``).
"""
from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "fused_sgd_update",
    "fused_adamw_update",
    "pack_leaves",
    "unpack_leaves",
    "pu_block_shape",
    "fused_pu_hbm_bytes",
    "unfused_pu_hbm_bytes",
]

LANES = 1024          # minor dim of the flattened tile grid (8 x 128 lanes)
BLOCK_ROWS = 256      # rows per grid step: (256, 1024) f32 block = 1 MB


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def pu_block_shape(n_elems: int) -> tuple[int, int, int]:
    """(block_rows, padded_rows, lanes) for a flat buffer of ``n_elems``.

    Small buffers (the whole ATIS TT model is ~0.3M elements) collapse to a
    single sublane-aligned block; large ones stream BLOCK_ROWS-row tiles.
    """
    lanes = LANES if n_elems >= LANES else 128
    rows = max(1, -(-n_elems // lanes))
    br = min(BLOCK_ROWS, _round_up(rows, 8))
    return br, _round_up(rows, br), lanes


def pack_leaves(leaves: Sequence[jax.Array], dtype, rows_p: int,
                lanes: int) -> jax.Array:
    """Ravel+concat ``leaves`` into one padded (rows_p, lanes) buffer."""
    flat = jnp.concatenate([jnp.ravel(x).astype(dtype) for x in leaves])
    return jnp.pad(flat, (0, rows_p * lanes - flat.size)).reshape(rows_p, lanes)


def unpack_leaves(buf: jax.Array, shapes: Sequence[tuple[int, ...]],
                  dtypes: Sequence[Any]) -> list[jax.Array]:
    """Inverse of :func:`pack_leaves` (slices are static; XLA fuses them)."""
    flat = buf.reshape(-1)
    sizes = [int(np.prod(s)) for s in shapes]
    offs = np.concatenate([[0], np.cumsum(sizes)])
    return [
        jax.lax.slice(flat, (int(offs[i]),), (int(offs[i + 1]),))
        .reshape(shapes[i]).astype(dtypes[i])
        for i in range(len(shapes))
    ]


# ---------------------------------------------------------------------------
# Kernel bodies.  Grid is 1-D over row blocks; scalars ride in SMEM as a
# (1, k) f32 vector (TPU scalars must be 2-D); hyperparameters that are
# Python floats are baked in as compile-time constants via partial.
# ---------------------------------------------------------------------------


def _sgd_kernel(scal_ref, p_ref, g_ref, o_ref):
    lr = scal_ref[0, 0]
    p = p_ref[...].astype(jnp.float32)
    o_ref[...] = (p - lr * g_ref[...]).astype(o_ref.dtype)


def _sgd_momentum_kernel(scal_ref, p_ref, mu_ref, g_ref, o_ref, omu_ref, *,
                         momentum: float):
    lr = scal_ref[0, 0]
    mu = momentum * mu_ref[...] + g_ref[...]
    p = p_ref[...].astype(jnp.float32)
    omu_ref[...] = mu
    o_ref[...] = (p - lr * mu).astype(o_ref.dtype)


def _adamw_kernel(scal_ref, p_ref, m_ref, v_ref, g_ref,
                  o_ref, om_ref, ov_ref, *,
                  b1: float, b2: float, eps: float, weight_decay: float):
    lr = scal_ref[0, 0]
    t = scal_ref[0, 1]
    # Bias correction computed IN-KERNEL from the step scalar.
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    g = g_ref[...]
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * jnp.square(g)
    p = p_ref[...].astype(jnp.float32)
    step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    if weight_decay:
        step = step + lr * weight_decay * p
    om_ref[...] = m
    ov_ref[...] = v
    o_ref[...] = (p - step).astype(o_ref.dtype)


def _pu_call(kernel, scal: jax.Array, bufs: Sequence[jax.Array],
             n_outs: int, br: int, interpret: bool) -> tuple[jax.Array, ...]:
    """Launch a PU kernel over flat (rows_p, lanes) buffers.

    ``bufs`` order is (aliased..., grads): param buffer first (its dtype is
    the first output's dtype), then f32 moment buffers, grads last.  The
    first ``n_outs`` bufs are aliased to the outputs, so donated inputs
    update in place.  ``br`` is the block-row count from the same
    ``pu_block_shape`` call that sized the buffers (rows_p % br == 0).
    """
    rows_p, lanes = bufs[0].shape
    grid = (rows_p // br,)
    blk = pl.BlockSpec((br, lanes), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0),
                               memory_space=pltpu.SMEM)]
        + [blk] * len(bufs),
        out_specs=[blk] * n_outs,
        out_shape=[jax.ShapeDtypeStruct(b.shape, b.dtype)
                   for b in bufs[:n_outs]],
        # scal is input 0; alias param/state inputs onto the outputs.
        input_output_aliases={1 + i: i for i in range(n_outs)},
        interpret=interpret,
    )(scal, *bufs)
    return tuple(out)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _dtype_groups(leaves: Sequence[jax.Array]) -> list[list[int]]:
    """Indices of ``leaves`` grouped by dtype (one kernel launch per group)."""
    groups: dict[Any, list[int]] = {}
    for i, x in enumerate(leaves):
        groups.setdefault(jnp.dtype(x.dtype), []).append(i)
    return list(groups.values())


def _scal(lr_t, t=0.0) -> jax.Array:
    return jnp.stack([jnp.asarray(lr_t, jnp.float32),
                      jnp.asarray(t, jnp.float32)]).reshape(1, 2)


# ---------------------------------------------------------------------------
# Public pytree-level entry points.
# ---------------------------------------------------------------------------


def fused_sgd_update(params, grads, lr_t, *, momentum: float = 0.0,
                     mu=None, interpret: bool | None = None):
    """One fused SGD(+momentum) PU stage over a parameter pytree.

    Returns ``new_params`` (momentum == 0) or ``(new_params, new_mu)``.
    Numerics match the pure-JAX path in ``optim.optimizers.sgd`` (all math
    in f32, params cast back to their storage dtype).
    """
    if interpret is None:
        interpret = _interpret_default()
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    mu_leaves = treedef.flatten_up_to(mu) if mu is not None else None
    new_p: list = [None] * len(p_leaves)
    new_mu: list = [None] * len(p_leaves)
    scal = _scal(lr_t)
    for idx in _dtype_groups(p_leaves):
        group = [p_leaves[i] for i in idx]
        n = sum(int(np.prod(x.shape)) for x in group)
        br, rows_p, lanes = pu_block_shape(n)
        pdt = group[0].dtype
        pb = pack_leaves(group, pdt, rows_p, lanes)
        gb = pack_leaves([g_leaves[i] for i in idx], jnp.float32, rows_p, lanes)
        shapes = [x.shape for x in group]
        if momentum == 0.0:
            (ob,) = _pu_call(_sgd_kernel, scal, [pb, gb], 1, br, interpret)
            outs = unpack_leaves(ob, shapes, [pdt] * len(group))
            for j, i in enumerate(idx):
                new_p[i] = outs[j]
        else:
            mb = pack_leaves([mu_leaves[i] for i in idx], jnp.float32,
                             rows_p, lanes)
            kern = functools.partial(_sgd_momentum_kernel, momentum=momentum)
            ob, omb = _pu_call(kern, scal, [pb, mb, gb], 2, br, interpret)
            outs = unpack_leaves(ob, shapes, [pdt] * len(group))
            mouts = unpack_leaves(omb, shapes, [jnp.float32] * len(group))
            for j, i in enumerate(idx):
                new_p[i], new_mu[i] = outs[j], mouts[j]
    params_out = jax.tree.unflatten(treedef, new_p)
    if momentum == 0.0:
        return params_out
    return params_out, jax.tree.unflatten(treedef, new_mu)


def fused_adamw_update(params, grads, m, v, lr_t, t, *, b1: float,
                       b2: float, eps: float, weight_decay: float,
                       interpret: bool | None = None):
    """One fused AdamW PU stage: ``(new_params, new_m, new_v)``.

    ``t`` is the 1-based step (bias correction is computed in-kernel from
    it); hyperparameters are compile-time constants.
    """
    if interpret is None:
        interpret = _interpret_default()
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(m)
    v_leaves = treedef.flatten_up_to(v)
    new_p: list = [None] * len(p_leaves)
    new_m: list = [None] * len(p_leaves)
    new_v: list = [None] * len(p_leaves)
    scal = _scal(lr_t, t)
    kern = functools.partial(_adamw_kernel, b1=b1, b2=b2, eps=eps,
                             weight_decay=weight_decay)
    for idx in _dtype_groups(p_leaves):
        group = [p_leaves[i] for i in idx]
        n = sum(int(np.prod(x.shape)) for x in group)
        br, rows_p, lanes = pu_block_shape(n)
        pdt = group[0].dtype
        pb = pack_leaves(group, pdt, rows_p, lanes)
        mb = pack_leaves([m_leaves[i] for i in idx], jnp.float32, rows_p, lanes)
        vb = pack_leaves([v_leaves[i] for i in idx], jnp.float32, rows_p, lanes)
        gb = pack_leaves([g_leaves[i] for i in idx], jnp.float32, rows_p, lanes)
        ob, omb, ovb = _pu_call(kern, scal, [pb, mb, vb, gb], 3, br, interpret)
        shapes = [x.shape for x in group]
        outs = unpack_leaves(ob, shapes, [pdt] * len(group))
        mouts = unpack_leaves(omb, shapes, [jnp.float32] * len(group))
        vouts = unpack_leaves(ovb, shapes, [jnp.float32] * len(group))
        for j, i in enumerate(idx):
            new_p[i], new_m[i], new_v[i] = outs[j], mouts[j], vouts[j]
    return (jax.tree.unflatten(treedef, new_p),
            jax.tree.unflatten(treedef, new_m),
            jax.tree.unflatten(treedef, new_v))


# ---------------------------------------------------------------------------
# Analytic HBM-traffic models (shared by benchmarks and the run.py --check
# regression guard).
# ---------------------------------------------------------------------------


def _moment_buffers(optimizer: str, momentum: float = 0.0) -> int:
    if optimizer == "adamw":
        return 2
    return 1 if momentum else 0


def _tile_padded_elems(shape: tuple, itemsize: int) -> int:
    """HBM footprint of one leaf stored alone: XLA pads a TPU array's
    minor two dims to the dtype's (sublane, 128) tile.  1-D leaves are
    modeled lane-padded only — generous to the unfused side."""
    if len(shape) == 0:
        return 1
    if len(shape) == 1:
        return _round_up(int(shape[0]), 128)
    sub = max(8, 32 // max(itemsize, 1))  # f32 8, bf16 16, int8 32
    lead = 1
    for d in shape[:-2]:
        lead *= int(d)
    return lead * _round_up(int(shape[-2]), sub) * _round_up(int(shape[-1]),
                                                             128)


def fused_pu_hbm_bytes(leaves, optimizer: str, *,
                       momentum: float = 0.0) -> int:
    """HBM bytes of one fused PU step over ``leaves`` (arrays or
    ShapeDtypeStructs): per dtype group, every packed buffer (params,
    grads f32, moments f32) is read once and the param/moment buffers
    written once through ``input_output_aliases`` — the dense flat packing
    is the paper's grouped BRAM storage (Eqs. (24)/(25)): <1 block of
    padding per group instead of per-leaf tile waste."""
    n_m = _moment_buffers(optimizer, momentum)
    groups: dict = {}
    for x in leaves:
        dt = jnp.dtype(x.dtype)
        groups.setdefault(dt, 0)
        groups[dt] += int(np.prod(x.shape))
    total = 0
    for dt, n in groups.items():
        _, rows_p, lanes = pu_block_shape(n)
        n_pad = rows_p * lanes
        reads = n_pad * (dt.itemsize + 4 + 4 * n_m)
        writes = n_pad * (dt.itemsize + 4 * n_m)
        total += reads + writes
    return total


def unfused_pu_hbm_bytes(leaves, optimizer: str, *,
                         momentum: float = 0.0) -> int:
    """HBM bytes of the per-leaf XLA update: the same read/write counts as
    the fused model (generous — perfect elementwise fusion, each buffer
    touched once), but every leaf at its OWN tile-padded footprint: TT
    cores are tiny, so storing them alone wastes most of each (8, 128)
    tile (the waste ``core.cost_model.tpu_packing_efficiency`` measures
    and the packed layout exists to eliminate)."""
    n_m = _moment_buffers(optimizer, momentum)
    total = 0
    for x in leaves:
        its = jnp.dtype(x.dtype).itemsize
        n_pad = _tile_padded_elems(tuple(x.shape), its)
        n_pad_f32 = _tile_padded_elems(tuple(x.shape), 4)
        reads = n_pad * its + n_pad_f32 * (4 + 4 * n_m)
        writes = n_pad * its + n_pad_f32 * 4 * n_m
        total += reads + writes
    return total
