"""Pallas TPU kernel: fused flash-attention backward — dQ/dK/dV in ONE
``pallas_call``.

The forward (``flash_attention.py``) keeps the online-softmax state in VMEM
so HBM never sees the S×S score matrix.  Plain autodiff through the pure-JAX
``blockwise_attention`` undoes that win for training: it saves the per-chunk
attention probabilities (S×S per head in aggregate — FTRANS identifies this
as the dominant off-chip tensor in transformer accelerators) and round-trips
the scan carry through HBM on every KV chunk.  This kernel closes the
backward half of the story: with only ``(O, m, l)`` saved by the forward, it
recomputes each probability tile from the softmax statistics in VMEM and
produces all three gradients in a single pass:

    P  = exp(S∘mask − m) / l            recomputed tile, never stored
    dV = Pᵀ dO                           accumulated per KV head
    dP = dO Vᵀ
    D  = rowsum(dO ⊙ O)                  computed in-kernel, per tile
    dS = P ∘ (dP − D)
    dQ = scale · dS K                    accumulated per Q block
    dK = scale · dSᵀ Q                   accumulated per KV head

Grid = (B·KVh, G·S/TQ, S/TK) with the KV axis innermost; axis 1 enumerates
``t = g·nq + iq`` — every (group member, Q block) pair of one KV head:

  q/do/o block (1, TQ, D)  — index ``(h·G + t//nq, t%nq)``: fetched once per
                             ``t`` (constant across the inner KV axis)
  m/l block    (1, TQ) f32 — the forward's saved softmax statistics
  k/v block    (1, TK, D)  — streamed along the inner axis
  dq block     (1, TQ, D) f32 — index constant across the inner axis: the
                             block stays in VMEM, accumulates over KV steps,
                             and is flushed to HBM exactly once per ``t``
  dk/dv block  (1, S, D) f32 — index map constant in ``(t, ik)``: the WHOLE
                             per-KV-head gradient stays VMEM-resident for
                             all G·nq·nk steps of its head and flushes once
                             — the GQA head-group reduction happens in the
                             index map (``h //``-free: axis 0 *is* the KV
                             head), not by materializing repeated KV or
                             per-Q-head partials in memory.

Fully-masked blocks (causal: all ``kpos > qpos``; sliding window: all
``kpos <= qpos − w``; padded KV tail) are skipped via ``pl.when`` — the
zero-init and flush logic stays outside the gate so accumulators are
well-defined even when a row's last KV block is dead.

``choose_attn_tiles`` is the single source of truth for the launch's VMEM
residency: the kernel launches with its tiles and ``core.memory_ledger``
reports the same byte count, so ledger and launched tiles cannot drift (the
same promise ``btt_linear.choose_tiles`` / ``btt_backward.choose_bwd_tiles``
make for the TT stages).  Shapes whose working set exceeds the budget —
dK/dV residency grows with S — fall back to ``blockwise_attention`` at the
op level (``ops.flash_mha_op``).

Tiles go down to 32 rows (the f32 sublane granule) so the paper's S=32
training regime launches without sequence padding; sub-128 lane tiles are
legal for the (1, T, D) blocks (T is a sublane dim there) and Mosaic pads
the (TQ, TK) score-tile lanes in-register.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params

from .btt_linear import VMEM_BUDGET, _round_up
from .flash_attention import DEFAULT_TK, DEFAULT_TQ, NEG_INF

__all__ = [
    "flash_attention_bwd_pallas",
    "choose_attn_tiles",
    "attn_bwd_vmem_fits",
    "attn_stage_vmem_bytes",
    "attn_residual_bytes",
    "attn_flops",
    "fused_attn_hbm_bytes",
    "unfused_attn_hbm_bytes",
    "DEFAULT_TQ",
    "DEFAULT_TK",
]


# ---------------------------------------------------------------------------
# Tile chooser — the single residency source for kernel, op gate, and ledger.
# ---------------------------------------------------------------------------


def choose_attn_tiles(S: int, D: int, itemsize: int, *,
                      tq: int | None = None, tk: int | None = None,
                      budget: int | None = None
                      ) -> tuple[int, int, int, int, int]:
    """(tq, tk, sp, dp, vmem_bytes) for the fused attention backward.

    Tiles start at ``min(256, round_up(S, 32))`` — the 32-row granule keeps
    the paper's S=32 regime unpadded on the sequence axis — and the larger
    tile halves until the working set fits the budget.  The dk/dv residency
    (``2·sp·dp·4``) scales with S, not the tiles, so long sequences may
    never fit: callers gate on :func:`attn_bwd_vmem_fits` and fall back to
    the pure-JAX blockwise path.  (The per-step working set is independent
    of the GQA group size — the group only multiplies the grid.)
    """
    budget = budget or VMEM_BUDGET
    tq = tq or min(DEFAULT_TQ, _round_up(S, 32))
    tk = tk or min(DEFAULT_TK, _round_up(S, 32))
    dp = _round_up(D, 128)

    # q/do/o blocks + m/l + k/v blocks + dq f32 accumulator block
    # + dk/dv resident f32 accumulators + s/dp/ds (tq, tk) f32 score tiles
    def vmem(tq_, tk_):
        sp_ = _round_up(S, max(tq_, tk_))
        return (3 * tq_ * dp * itemsize + 2 * tq_ * 4
                + 2 * tk_ * dp * itemsize + tq_ * dp * 4
                + 2 * sp_ * dp * 4 + 3 * tq_ * tk_ * 4)

    while max(tq, tk) > 128 and vmem(tq, tk) > budget:
        if tq >= tk:
            tq //= 2
        else:
            tk //= 2
    sp = _round_up(S, max(tq, tk))
    if sp % tq or sp % tk:
        # Only reachable with caller-supplied tiles: auto-chosen tiles
        # start equal and halve, so each always divides the other.  A
        # non-dividing tile would silently drop tail blocks from the grid.
        raise ValueError(
            f"tiles ({tq}, {tk}) do not both divide padded S={sp}")
    return tq, tk, sp, dp, vmem(tq, tk)


def attn_bwd_vmem_fits(S: int, D: int, itemsize: int, *,
                       budget: int | None = None) -> bool:
    """True iff the fused attention BWD working set fits the VMEM budget."""
    budget = budget or VMEM_BUDGET
    return choose_attn_tiles(S, D, itemsize, budget=budget)[4] <= budget


def attn_stage_vmem_bytes(S: int, D: int, itemsize: int, *,
                          stage: str = "BWD", fused: bool = True,
                          budget: int | None = None) -> int:
    """VMEM working set the attention stage ACTUALLY launches: the fused
    kernel's (backward-chooser-derived) when ``fused`` and it fits, else 0
    (the fallback is the pure-JAX blockwise path — no Pallas launch).
    ``core.memory_ledger`` reports exactly this number per stage."""
    if not fused or not attn_bwd_vmem_fits(S, D, itemsize, budget=budget):
        return 0
    tq, tk, sp, dp, bwd_vmem = choose_attn_tiles(S, D, itemsize,
                                                 budget=budget)
    if stage == "BWD":
        return bwd_vmem
    # FWD: q + k + v + o blocks, m/l/acc scratch, one (tq, tk) score tile.
    return (2 * tq * dp * itemsize + 2 * tk * dp * itemsize
            + tq * (dp + 2) * 4 + tq * tk * 4)


def attn_residual_bytes(B: int, H: int, S: int, D: int, itemsize: int, *,
                        fused: bool) -> int:
    """Bytes ONE attention layer saves for its backward.

    Fused: ``(O, m, l)`` — O in the activation dtype plus two f32 rows of
    softmax statistics (O doubles as the o-projection's input residual, so
    charging it here over-counts — the conservative direction the ledger
    documents).  Unfused: the autodiff-saved S×S attention probabilities.
    """
    if fused:
        return B * H * S * D * itemsize + 2 * B * H * S * 4
    return B * H * S * S * itemsize


# ---------------------------------------------------------------------------
# The kernel.
# ---------------------------------------------------------------------------


def _bwd_kernel(q_ref, do_ref, o_ref, m_ref, l_ref, k_ref, v_ref,
                dq_ref, dk_ref, dv_ref, *, nq: int, nk: int, tq: int,
                tk: int, scale: float, causal: bool, window: int | None,
                s_real: int):
    """Grid (BKVh, G·nq, nk); see module docstring for block shapes."""
    t = pl.program_id(1)
    ik = pl.program_id(2)
    iq = jax.lax.rem(t, nq)

    @pl.when((t == 0) & (ik == 0))
    def _zero_dkv():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    @pl.when(ik == 0)
    def _zero_dq():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    # Dead-block skipping: padded KV tail, causal (no kpos <= qpos), and
    # sliding window (no kpos > qpos - w) blocks contribute nothing.
    live = ik * tk < s_real
    if causal:
        live &= ik * tk <= iq * tq + tq - 1
    if window is not None:
        live &= ik * tk + tk - 1 > iq * tq - window

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)              # (TQ, D)
        do = do_ref[0].astype(jnp.float32)
        o = o_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)              # (TK, D)
        v = v_ref[0].astype(jnp.float32)
        m = m_ref[0][:, None]                         # (TQ, 1) f32
        l = l_ref[0][:, None]

        qpos = iq * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
        kpos = ik * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)

        # Scale folded into the Q operand (not a post-dot multiply): a
        # `dot*scale - m` chain invites XLA to fuse mul+sub into an FMA
        # whenever the mask `where` constant-folds away, breaking the
        # bit-for-bit single-tile contract with the reference.
        s = jax.lax.dot_general(
            q * scale, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        mask = kpos < s_real
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - m) / jnp.maximum(l, 1e-30)    # normalized probs

        col = pl.multiple_of(ik * tk, tk)
        dv_ref[0, pl.ds(col, tk), :] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        dp_ = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # (TQ, TK)
        d_row = jnp.sum(do * o, axis=1, keepdims=True)  # D = rowsum(dO⊙O)
        # Scale folded into dS once (not into the dQ/dK epilogues, where
        # XLA could fuse it into the accumulate as an FMA and break the
        # bit-for-bit single-tile contract with the reference).
        ds = p * (dp_ - d_row) * scale

        dq_ref[0] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dk_ref[0, pl.ds(col, tk), :] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "group", "tq", "tk", "interpret"))
def flash_attention_bwd_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                               o: jax.Array, m: jax.Array, l: jax.Array,
                               do: jax.Array, *, causal: bool = True,
                               window: int | None = None, group: int = 1,
                               tq: int | None = None, tk: int | None = None,
                               interpret: bool = False
                               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused BWD stage: ``(dq (BH,S,D), dk, dv (BH/group,S,D))``.

    ``q/o/do (BH, S, D)``, ``m/l (BH, S)`` f32 (the forward's residuals),
    ``k/v (BH/group, S, D)``.  All dims padded to the chooser's tiles;
    padded Q rows carry ``do = 0`` so every padded contribution vanishes
    exactly.  ``interpret=True`` runs the kernel body in Python on CPU —
    the validation path, as for every kernel in this package.
    """
    BH, S, D = q.shape
    BKV = k.shape[0]
    scale = 1.0 / math.sqrt(D)
    itemsize = jnp.dtype(q.dtype).itemsize
    tq, tk, sp, dp, _ = choose_attn_tiles(S, D, itemsize, tq=tq, tk=tk)

    def pad3(x):
        return jnp.pad(x, ((0, 0), (0, sp - S), (0, dp - x.shape[2])))

    qp, dop, op = pad3(q), pad3(do), pad3(o)
    kp, vp = pad3(k), pad3(v)
    mp = jnp.pad(m.astype(jnp.float32), ((0, 0), (0, sp - S)))
    lp = jnp.pad(l.astype(jnp.float32), ((0, 0), (0, sp - S)))

    nq, nk = sp // tq, sp // tk
    grid = (BKV, group * nq, nk)

    def q_map(h, t, j, g=group, nq_=nq):
        return (h * g + t // nq_, t % nq_, 0)

    def stat_map(h, t, j, g=group, nq_=nq):
        return (h * g + t // nq_, t % nq_)

    dq, dk, dv = pl.pallas_call(
        functools.partial(_bwd_kernel, nq=nq, nk=nk, tq=tq, tk=tk,
                          scale=scale, causal=causal, window=window,
                          s_real=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, dp), q_map),               # q
            pl.BlockSpec((1, tq, dp), q_map),               # do
            pl.BlockSpec((1, tq, dp), q_map),               # o
            pl.BlockSpec((1, tq), stat_map),                # m
            pl.BlockSpec((1, tq), stat_map),                # l
            pl.BlockSpec((1, tk, dp), lambda h, t, j: (h, j, 0)),   # k
            pl.BlockSpec((1, tk, dp), lambda h, t, j: (h, j, 0)),   # v
        ],
        out_specs=[
            pl.BlockSpec((1, tq, dp), q_map),               # dq (per-t acc)
            pl.BlockSpec((1, sp, dp), lambda h, t, j: (h, 0, 0)),   # dk
            pl.BlockSpec((1, sp, dp), lambda h, t, j: (h, 0, 0)),   # dv
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, sp, dp), jnp.float32),
            jax.ShapeDtypeStruct((BKV, sp, dp), jnp.float32),
            jax.ShapeDtypeStruct((BKV, sp, dp), jnp.float32),
        ],
        # Axis 0 (KV heads) owns disjoint accumulators -> parallel; axes
        # 1/2 carry accumulation state (dk/dv revisit across t, dq across
        # ik) and must stay sequential.
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(qp, dop, op, mp, lp, kp, vp)
    return (dq[:, :S, :D].astype(q.dtype),
            dk[:, :S, :D].astype(k.dtype),
            dv[:, :S, :D].astype(v.dtype))


# ---------------------------------------------------------------------------
# Analytic FLOP / HBM-traffic models (shared by benchmarks, tests, ledger).
# ---------------------------------------------------------------------------


def _live_elems(S: int, causal: bool, window: int | None) -> int:
    """Number of unmasked (q, k) score positions."""
    if not causal and window is None:
        return S * S
    total = 0
    for i in range(S):
        lo = 0 if window is None else max(0, i - window + 1)
        hi = i if causal else S - 1
        total += max(0, hi - lo + 1)
    return total


def attn_flops(B: int, H: int, S: int, D: int, *, causal: bool = True,
               window: int | None = None) -> int:
    """FLOPs of one attention layer's fwd+bwd over the unmasked region:
    2 matmuls forward (QKᵀ, PV) + 4 backward (dV, dP, dQ, dK), each
    2·D FLOPs per live score element."""
    return B * H * _live_elems(S, causal, window) * 2 * D * 6


def fused_attn_hbm_bytes(B: int, H: int, KV: int, S: int, D: int,
                         itemsize: int, *, causal: bool = True,
                         window: int | None = None) -> int:
    """HBM bytes moved by one fused fwd + bwd launch pair (tile-derived).

    Forward: q read once, k/v refetched per (iq, ik) grid step (BlockSpec
    DMAs run even for ``pl.when``-skipped blocks), o/m/l written once.
    Backward: q/do/o/m/l read once per ``t`` (their index is constant
    across the inner KV axis), k/v refetched per step, dq written once per
    Q block, dk/dv flushed once per KV head.  No S×S tensor appears on
    either side.  Padded bytes are real bytes on the wire.
    """
    tq, tk, sp, dp, _ = choose_attn_tiles(S, D, itemsize)
    nq, nk = sp // tq, sp // tk
    BH, BKV = B * H, B * KV
    fwd = (BH * sp * dp * itemsize                  # q read once
           + BH * nq * nk * 2 * tk * dp * itemsize  # k/v refetched
           + BH * sp * dp * itemsize                # o written
           + 2 * BH * sp * 4)                       # m, l written
    bwd = (3 * BH * sp * dp * itemsize              # q, do, o read
           + 2 * BH * sp * 4                        # m, l read
           + BH * nq * nk * 2 * tk * dp * itemsize  # k/v refetched
           + BH * sp * dp * 4                       # dq written (f32)
           + 2 * BKV * sp * dp * 4)                 # dk/dv flushed once
    return fwd + bwd


def unfused_attn_hbm_bytes(B: int, H: int, KV: int, S: int, D: int,
                           itemsize: int, *, q_chunk: int = 512,
                           kv_chunk: int = 1024) -> int:
    """HBM bytes moved by ``blockwise_attention`` + plain autodiff.

    Counts, generously to XLA (each tensor once per producing/consuming
    pass, no re-reads): the raw q/k/v reads and o write; the chunk-restack
    copies (reshape+transpose into scan operands — real layout-changing
    copies, forward and again for their cotangents in backward); the
    online-softmax scan carry ``(m, l, acc)`` round-tripping HBM once per
    KV chunk (the traffic the kernel exists to kill); and the
    autodiff-saved per-chunk probabilities — S×S per head in aggregate —
    written by the forward and read back by the backward.
    """
    # Configs document 0 as "single block" (see ModelConfig.attn_q_chunk);
    # normalize the same way blockwise_attention's caller does.
    qc = min(q_chunk, S) or S
    kvc = min(kv_chunk, S) or S
    sq = _round_up(S, qc)
    skv = _round_up(S, kvc)
    nk = skv // kvc
    qkv = B * sq * H * D + 2 * B * skv * KV * D     # chunked operand elems
    raw = B * S * H * D + 2 * B * S * KV * D
    carry = 2 * nk * B * H * sq * (D + 2) * 4       # (m,l,acc) w+r per chunk
    probs = B * H * sq * skv * itemsize             # saved S×S probabilities
    fwd = (raw * itemsize + 2 * qkv * itemsize + carry + probs
           + B * S * H * D * itemsize)              # o written
    bwd = (probs + B * S * H * D * itemsize         # probs + do read
           + 3 * qkv * itemsize                     # chunk reads + cot w+r
           + carry
           + raw * 4)                               # dq/dk/dv written f32
    return fwd + bwd
