"""Pallas TPU kernels for the paper's compute hot-spots.

``btt_linear``      — fused two-GEMM BTT linear (VMEM-resident intermediate).
``ttm_embed``       — gather-free d=3 TTM embedding lookup (one-hot MXU GEMMs).
``flash_attention`` — causal/windowed GQA flash attention (online-softmax
                      state in VMEM scratch; closes the 86%-of-traffic gap
                      the pure-JAX blockwise path leaves on prefill cells).
``fused_update``    — fused parameter-update (PU) stage: SGD(+momentum) /
                      AdamW over flattened parameter buffers in one pass,
                      moments updated in place (paper Sec. III-A step 3).
``ops``        — jit wrappers + fused custom VJP + pure-JAX fallbacks.
``ref``        — pure-jnp oracles the kernels are swept against.
"""
from .btt_linear import btt_linear_pallas
from .flash_attention import flash_attention_pallas
from .fused_update import fused_adamw_update, fused_sgd_update
from .ops import btt_linear_op, kernel_interpret_default, ttm_embed_op
from .ref import btt_linear_ref, btt_t_ref, ttm_embed_ref
from .ttm_embed import ttm_embed_pallas

__all__ = [
    "btt_linear_pallas", "ttm_embed_pallas", "flash_attention_pallas",
    "btt_linear_op", "ttm_embed_op", "kernel_interpret_default",
    "btt_linear_ref", "btt_t_ref", "ttm_embed_ref",
    "fused_sgd_update", "fused_adamw_update",
]
