"""Pallas TPU kernels for the paper's compute hot-spots.

``btt_linear``      — fused two-GEMM BTT linear (VMEM-resident intermediate).
``btt_backward``    — fused BWD stage: gx/ga/gb in one pass, t/gt recomputed
                      into VMEM scratch, ga/gb accumulated on chip
                      (paper Eqs. (10)/(11)/(16); zero HBM intermediates).
``btt_ffn``         — fused tensorized-FFN megakernel: both (three when
                      gated) TT linears + activation in ONE pallas_call per
                      direction; the (K, d_ff) hidden state lives only in
                      VMEM scratch, and the backward recomputes it from x
                      (FFN residuals shrink to the layer input).
``ttm_embed``       — gather-free d=3 TTM embedding lookup (one-hot MXU GEMMs).
``flash_attention`` — causal/windowed GQA flash attention (online-softmax
                      state in VMEM scratch; closes the 86%-of-traffic gap
                      the pure-JAX blockwise path leaves on prefill cells).
``flash_backward``  — fused flash-attention backward: dQ/dK/dV in one pass,
                      probability tiles recomputed from the saved (m, l)
                      statistics in VMEM — the S×S matrix never exists,
                      forward or backward.
``fused_update``    — fused parameter-update (PU) stage: SGD(+momentum) /
                      AdamW over flattened parameter buffers in one pass,
                      moments updated in place (paper Sec. III-A step 3).
``flash_decode``    — serving-side flash attention: single-query-row tiles
                      streamed against a PAGED KV cache (page-table-indirect
                      index maps, GQA head-grouping, online-softmax state in
                      VMEM) — only resident pages are ever read.
``ops``        — jit wrappers + fused custom VJP + pure-JAX fallbacks.
``ref``        — pure-jnp oracles the kernels are swept against.
"""
from .btt_backward import (
    btt_backward_pallas,
    bwd_vmem_fits,
    choose_bwd_tiles,
    fused_bwd_hbm_bytes,
    unfused_bwd_hbm_bytes,
)
from .btt_ffn import (
    btt_ffn_bwd_pallas,
    btt_ffn_pallas,
    choose_ffn_tiles,
    ffn_residual_bytes,
    ffn_vmem_fits,
    fused_ffn_hbm_bytes,
    unfused_ffn_hbm_bytes,
)
from .btt_linear import btt_linear_pallas
from .flash_attention import flash_attention_pallas
from .flash_backward import (
    attn_bwd_vmem_fits,
    attn_residual_bytes,
    choose_attn_tiles,
    flash_attention_bwd_pallas,
    fused_attn_hbm_bytes,
    unfused_attn_hbm_bytes,
)
from .flash_decode import (
    choose_decode_attn_tiles,
    decode_attn_vmem_fits,
    flash_decode_pallas,
    fused_decode_attn_hbm_bytes,
    paged_decode_ref,
    unfused_decode_attn_hbm_bytes,
)
from .fused_update import fused_adamw_update, fused_sgd_update
from .ops import (
    btt_ffn_decode_op,
    btt_ffn_op,
    btt_linear_decode_op,
    btt_linear_op,
    flash_decode_op,
    flash_mha_op,
    kernel_interpret_default,
    ttm_embed_op,
)
from .ref import (
    btt_backward_ref,
    btt_ffn_backward_ref,
    btt_ffn_ref,
    btt_linear_ref,
    btt_t_ref,
    flash_attention_bwd_ref,
    ttm_embed_ref,
)
from .ttm_embed import ttm_embed_pallas

__all__ = [
    "btt_linear_pallas", "btt_backward_pallas", "ttm_embed_pallas",
    "btt_ffn_pallas", "btt_ffn_bwd_pallas",
    "flash_attention_pallas", "flash_attention_bwd_pallas",
    "btt_linear_op", "btt_ffn_op", "ttm_embed_op", "flash_mha_op",
    "kernel_interpret_default",
    "btt_linear_ref", "btt_t_ref", "btt_backward_ref",
    "btt_ffn_ref", "btt_ffn_backward_ref", "ttm_embed_ref",
    "flash_attention_bwd_ref",
    "fused_sgd_update", "fused_adamw_update",
    "choose_bwd_tiles", "bwd_vmem_fits",
    "fused_bwd_hbm_bytes", "unfused_bwd_hbm_bytes",
    "choose_ffn_tiles", "ffn_vmem_fits", "ffn_residual_bytes",
    "fused_ffn_hbm_bytes", "unfused_ffn_hbm_bytes",
    "choose_attn_tiles", "attn_bwd_vmem_fits", "attn_residual_bytes",
    "fused_attn_hbm_bytes", "unfused_attn_hbm_bytes",
    "flash_decode_pallas", "paged_decode_ref", "flash_decode_op",
    "btt_linear_decode_op", "btt_ffn_decode_op",
    "choose_decode_attn_tiles", "decode_attn_vmem_fits",
    "fused_decode_attn_hbm_bytes", "unfused_decode_attn_hbm_bytes",
]
