"""Pallas TPU kernel: fused BTT backward — the paper's bi-directional BWD
stage (Eqs. (10)/(11)/(16)) as ONE ``pallas_call``.

The forward (``btt_linear.py``) computes ``y = (x @ B^T) @ A^T`` with the
``(TK, r)`` intermediate resident in VMEM.  Its VJP needs five contractions:

    t  = x  @ B^T      (K, r)   recomputed — never saved by the forward
    gt = gy @ A        (K, r)
    gx = gt @ B        (K, N)   paper Eq. (16), the data gradient
    gA = gy^T @ t      (M, r)   paper Eq. (10) (half-factor cotangent)
    gB = gt^T @ x      (r, N)   paper Eq. (11)

Issued as separate XLA GEMMs, the two K-sized intermediates ``t``/``gt``
round-trip HBM four times — exactly the off-chip traffic the paper's
on-chip BWD dataflow eliminates (its Z'_3 stays in BRAM between the MUL2
and MUL3 engines).  This kernel keeps them in VMEM scratch and produces all
three gradients in a single pass over ``x``/``gy``.

Tiling (BlockSpec; grid = (K/TK, N/TN), row-major so N is innermost):

  x block   (TK, TN)     — streamed from HBM, read ONCE
  gy block  (TK, MP)     — one fetch per K row-block (constant across N)
  b block   (RP, TN)     — input half-factor column block
  a block   (MP, RP)     — output half-factor, fully VMEM-resident
  gx block  (TK, TN)     — streamed out, written once
  ga block  (MP, RP) f32 — index map is constant (0, 0): the block is
  gb block  (RP, NP) f32   revisited every grid step, so Pallas keeps it in
                           VMEM for the whole (sequential) grid and flushes
                           to HBM exactly once at the end — the same
                           revisiting-accumulator pattern as the forward
                           kernel's scratch ``t``, now applied to outputs.
  t, gt scratch (TK, RP) f32 — the fused intermediates (paper's Z_2 / Z'_3)

Per grid step (k, n): at ``n == 0`` compute ``gt = gy @ a`` and zero ``t``;
every step accumulate ``t += x @ b^T``, emit ``gx = gt @ b`` for this column
block, and accumulate ``gb[:, n] += gt^T @ x``; on the last N block fold the
completed ``t`` into ``ga += gy^T @ t``.  No K-sized tensor ever leaves
VMEM; the only HBM intermediates of the whole BWD stage are the gradients
themselves.

``ga``/``gb`` accumulate and return in f32 (cast to the core dtype happens
once, at the very end, in ``ops.py``) — the bf16 round-trip the unfused
path used to take between ``t`` and the dependent products does not exist
here.

Shapes whose residency exceeds the VMEM budget (``bwd_vmem_fits``) fall
back to the reference path in ``ops.py``; the memory ledger reports the
same ``choose_bwd_tiles`` working set, so the two cannot drift.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

from .btt_linear import DEFAULT_TK, DEFAULT_TN, VMEM_BUDGET, _round_up

__all__ = [
    "btt_backward_pallas",
    "choose_bwd_tiles",
    "bwd_vmem_fits",
    "bwd_stage_vmem_bytes",
    "fused_bwd_hbm_bytes",
    "unfused_bwd_hbm_bytes",
    "bwd_flops",
]


def choose_bwd_tiles(M: int, N: int, R: int, itemsize: int, *,
                     tk: int | None = None, tn: int | None = None,
                     K: int | None = None
                     ) -> tuple[int, int, int, int, int, int]:
    """(tk, tn, mp, rp, np, vmem_bytes) for the fused BWD kernel.

    Single source of truth for the BWD stage's residency: the kernel
    launches with these tiles and ``core.memory_ledger`` reports the same
    ``vmem_bytes`` — ledger and launched tiles cannot drift (the FWD stage
    makes the identical promise through ``btt_linear.choose_tiles``).

    ``K`` caps ``tk`` at the sublane-aligned row count actually present
    (the paper's regime is K=32 — padding it to a 256-row block would 8x
    the streamed traffic and residency).  Lane-aligned ``N`` up to two
    default tiles runs as a single N block (zero column padding on the
    paper's 768-wide layers).  ``tk`` then shrinks until the working set
    fits VMEM_BUDGET; the half-factor blocks (``a``, ``ga``) and the
    full-width ``gb`` accumulator do not scale with ``tk``, so oversized
    layers may never fit — callers gate on :func:`bwd_vmem_fits` and fall
    back to the unfused path.
    """
    tk = tk or DEFAULT_TK
    if K is not None:
        tk = min(tk, _round_up(K, 32))  # 32: every dtype's sublane tile
    if tn is None:
        tn = (_round_up(N, 128) if N <= 2 * DEFAULT_TN else DEFAULT_TN)
    mp = _round_up(M, 128)
    rp = _round_up(R, 128)
    np_ = _round_up(N, tn)

    # gy (tk, mp) + a (mp, rp) + x (tk, tn) + b (rp, tn) + gx (tk, tn)
    # + ga (mp, rp) f32 + gb (rp, np) f32 + t/gt scratch (tk, rp) f32 each
    def vmem(tk_):
        return (tk_ * mp * itemsize + mp * rp * itemsize
                + tk_ * tn * itemsize + rp * tn * itemsize
                + tk_ * tn * itemsize
                + mp * rp * 4 + rp * np_ * 4
                + 2 * tk_ * rp * 4)

    while tk > 64 and vmem(tk) > VMEM_BUDGET:
        tk //= 2
    return tk, tn, mp, rp, np_, vmem(tk)


def bwd_vmem_fits(M: int, N: int, R: int, itemsize: int,
                  K: int | None = None) -> bool:
    """True iff the fused BWD working set fits the kernel VMEM budget."""
    return choose_bwd_tiles(M, N, R, itemsize, K=K)[5] <= VMEM_BUDGET


def bwd_stage_vmem_bytes(M: int, N: int, R: int, itemsize: int,
                         K: int | None = None, *,
                         fused: bool = True) -> int:
    """VMEM working set the BWD stage ACTUALLY launches for this layer:
    the fused kernel's when ``fused`` and it fits the budget (the path
    ``ops.py`` takes), else the operand-swap forward launch's
    (``btt_linear_pallas(gy, A^T, B^T)`` — output dim N, rank R).
    ``fused=False`` mirrors ``fused_bwd=False`` at the op level.
    ``core.memory_ledger`` reports exactly this number, so the ledger and
    the launched tiles cannot drift.
    """
    if fused:
        vm = choose_bwd_tiles(M, N, R, itemsize, K=K)[5]
        if vm <= VMEM_BUDGET:
            return vm
    from .btt_linear import choose_tiles

    return choose_tiles(N, R, itemsize, K=K)[4]


def _bwd_kernel(x_ref, gy_ref, b_ref, a_ref, gx_ref, ga_ref, gb_ref,
                t_ref, gt_ref, *, n_blocks: int, tn: int):
    """Grid (nK, nN); see module docstring for block shapes."""
    k = pl.program_id(0)
    n = pl.program_id(1)

    @pl.when((k == 0) & (n == 0))
    def _zero_accumulators():
        ga_ref[...] = jnp.zeros_like(ga_ref)
        gb_ref[...] = jnp.zeros_like(gb_ref)

    @pl.when(n == 0)
    def _row_start():
        t_ref[...] = jnp.zeros_like(t_ref)
        # gt = gy @ a, once per K row-block (the gy block is constant
        # across the inner N loop).
        gt_ref[...] = jax.lax.dot_general(
            gy_ref[...], a_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    # t += x @ b^T  (same MXU GEMM as the forward's stage 1).
    t_ref[...] += jax.lax.dot_general(
        x_ref[...], b_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # gx tile: gt @ b — paper Eq. (16) by operand swap, streamed out.
    gx_ref[...] = jax.lax.dot_general(
        gt_ref[...].astype(b_ref.dtype), b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(gx_ref.dtype)

    # gb column block: gt^T @ x, accumulated across the K grid in the
    # VMEM-resident f32 output block (x promoted to f32 — the whole
    # core-gradient chain stays f32 until the final cast in ops.py).
    col = pl.multiple_of(n * tn, tn)
    gb_ref[:, pl.ds(col, tn)] += jax.lax.dot_general(
        gt_ref[...], x_ref[...].astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(n == n_blocks - 1)
    def _fold_ga():
        # t is complete for this K row-block: ga += gy^T @ t.
        ga_ref[...] += jax.lax.dot_general(
            gy_ref[...].astype(jnp.float32), t_ref[...],
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


def _bwd_kernel_q(s_ref, x_ref, gy_ref, b_ref, a_ref, gx_ref, ga_ref,
                  gb_ref, t_ref, gt_ref, *, n_blocks: int, tn: int):
    """Quantized-operand BWD: x/b/a arrive in storage dtypes with SMEM
    scales ``s = [s_x, s_b, s_a]`` (``gy`` is the compute-dtype cotangent)
    and dequantize tile-by-tile in VMEM.  The gradients are those of the
    DEQUANTIZED operands (straight-through: rounding treated as identity),
    so every product below is against ``s * q`` and the f32 accumulator
    chain of ``_bwd_kernel`` is preserved."""
    k = pl.program_id(0)
    n = pl.program_id(1)

    @pl.when((k == 0) & (n == 0))
    def _zero_accumulators():
        ga_ref[...] = jnp.zeros_like(ga_ref)
        gb_ref[...] = jnp.zeros_like(gb_ref)

    s_x = s_ref[0, 0]
    s_b = s_ref[0, 1]
    b_f = b_ref[...].astype(jnp.float32)
    x_f = x_ref[...].astype(jnp.float32)

    @pl.when(n == 0)
    def _row_start():
        t_ref[...] = jnp.zeros_like(t_ref)
        # gt = gy @ (s_a * a), once per K row-block.
        gt_ref[...] = jax.lax.dot_general(
            gy_ref[...].astype(jnp.float32),
            a_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * s_ref[0, 2]

    # t += (s_x x) @ (s_b b)^T — t accumulates the DEQUANTIZED intermediate.
    t_ref[...] += jax.lax.dot_general(
        x_f, b_f,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * (s_x * s_b)

    # gx tile: gt @ (s_b b), streamed out in the compute dtype.
    gx_ref[...] = (jax.lax.dot_general(
        gt_ref[...], b_f,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * s_b).astype(gx_ref.dtype)

    # gb column block: gt^T @ (s_x x), f32-resident accumulator.
    col = pl.multiple_of(n * tn, tn)
    gb_ref[:, pl.ds(col, tn)] += jax.lax.dot_general(
        gt_ref[...], x_f,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * s_x

    @pl.when(n == n_blocks - 1)
    def _fold_ga():
        # t already carries both scales: ga += gy^T @ t unchanged.
        ga_ref[...] += jax.lax.dot_general(
            gy_ref[...].astype(jnp.float32), t_ref[...],
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


@functools.partial(jax.jit,
                   static_argnames=("tk", "tn", "interpret", "out_dtype"))
def btt_backward_pallas(x: jax.Array, gy: jax.Array, b: jax.Array,
                        a: jax.Array, *, scales: jax.Array | None = None,
                        out_dtype=None, tk: int | None = None,
                        tn: int | None = None, interpret: bool = False
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused BWD stage: ``(gx (K, N), ga (M, R) f32, gb (R, N) f32)``.

    ``x (K, N)`` is the saved layer input, ``gy (K, M)`` the output
    cotangent, ``b (R, N)`` / ``a (M, R)`` the rebuilt half-factors.  All
    dims are padded to hardware tiles; zero padding is exact for every
    contraction here (padded rows/cols of x, gy, a, b are zero, so they
    contribute nothing to any product).  ``interpret=True`` runs the kernel
    body in Python on CPU — the validation path, as for every kernel in
    this package.

    ``scales`` ((1, 3) f32 ``[s_x, s_b, s_a]``) switches to the
    quantized-operand kernel (``_bwd_kernel_q``): x/b/a stream in storage
    dtypes, dequantize in VMEM, and the returned gradients are w.r.t. the
    dequantized operands; ``out_dtype`` names ``gx``'s compute dtype.
    """
    K, N = x.shape
    _, M = gy.shape
    R, _ = b.shape
    out_dtype = out_dtype or x.dtype

    itemsize = max(jnp.dtype(v.dtype).itemsize for v in (x, gy, b, a))
    tk, tn, mp, rp, np_, _ = choose_bwd_tiles(M, N, R, itemsize, tk=tk,
                                              tn=tn, K=K)

    kp = _round_up(K, tk)
    xp = jnp.pad(x, ((0, kp - K), (0, np_ - N)))
    gyp = jnp.pad(gy, ((0, kp - K), (0, mp - M)))
    bp = jnp.pad(b, ((0, rp - R), (0, np_ - N)))
    ap = jnp.pad(a, ((0, mp - M), (0, rp - R)))

    n_blocks = np_ // tn
    grid = (kp // tk, n_blocks)

    data_specs = [
        pl.BlockSpec((tk, tn), lambda k, n: (k, n)),    # x
        pl.BlockSpec((tk, mp), lambda k, n: (k, 0)),    # gy
        pl.BlockSpec((rp, tn), lambda k, n: (0, n)),    # b
        pl.BlockSpec((mp, rp), lambda k, n: (0, 0)),    # a (resident)
    ]
    if scales is None:
        kern = functools.partial(_bwd_kernel, n_blocks=n_blocks, tn=tn)
        in_specs, operands = data_specs, (xp, gyp, bp, ap)
    else:
        kern = functools.partial(_bwd_kernel_q, n_blocks=n_blocks, tn=tn)
        in_specs = [pl.BlockSpec((1, 3), lambda k, n: (0, 0),
                                 memory_space=pltpu.SMEM)] + data_specs
        operands = (scales.astype(jnp.float32).reshape(1, 3),
                    xp, gyp, bp, ap)

    gx, ga, gb = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((tk, tn), lambda k, n: (k, n)),    # gx
            pl.BlockSpec((mp, rp), lambda k, n: (0, 0)),    # ga (accumulator)
            pl.BlockSpec((rp, np_), lambda k, n: (0, 0)),   # gb (accumulator)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((kp, np_), out_dtype),
            jax.ShapeDtypeStruct((mp, rp), jnp.float32),
            jax.ShapeDtypeStruct((rp, np_), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tk, rp), jnp.float32),   # t
            pltpu.VMEM((tk, rp), jnp.float32),   # gt
        ],
        # Both grid axes carry accumulation state (ga/gb revisit across k,
        # t across n) — neither may be parallelized.
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return gx[:K, :N], ga[:M, :R], gb[:R, :N]


# ---------------------------------------------------------------------------
# Analytic HBM-traffic / FLOP models (shared by benchmarks and tests).
# ---------------------------------------------------------------------------


def bwd_flops(K: int, M: int, N: int, R: int) -> int:
    """MACs x2 of the five BWD contractions (t, gt, gx, ga, gb)."""
    return 2 * K * R * (2 * M + 3 * N)


def fused_bwd_hbm_bytes(K: int, M: int, N: int, R: int, itemsize: int) -> int:
    """HBM bytes moved by ONE fused-kernel BWD launch (tile-derived).

    Reads: x once, gy once per K row-block (its block index is constant
    across the inner N loop, so one fetch per row = K*M total), b once per
    K row-block, a once (its block index never changes).  Writes: gx, plus
    the single end-of-grid flush of the f32 ga/gb accumulators.  No K-sized
    intermediate appears on either side.  All counts are over the launch's
    padded dims — padded bytes are real bytes on the wire.
    """
    tk, tn, mp, rp, np_, _ = choose_bwd_tiles(M, N, R, itemsize, K=K)
    kp = _round_up(K, tk)
    n_k = kp // tk
    reads = (kp * np_ + kp * mp + n_k * rp * np_ + mp * rp) * itemsize
    writes = kp * np_ * itemsize + (mp * rp + rp * np_) * 4
    return reads + writes


def unfused_bwd_hbm_bytes(K: int, M: int, N: int, R: int,
                          itemsize: int) -> int:
    """HBM bytes moved by the unfused BWD path: four XLA GEMMs for the core
    gradients (the K-sized t/gt round-trip HBM in f32) + the operand-swap
    forward-kernel launch for gx.

    The GEMM operands/results are counted at their (8, 128)-tile-padded
    HBM footprint (how XLA stores TPU arrays), each read/written ONCE per
    GEMM — generous to XLA (perfect in-GEMM fusion, no re-reads).  The gx
    launch uses the forward kernel's own tile chooser, so the comparison
    is tile-for-tile fair with the fused model.
    """
    from .btt_linear import choose_tiles

    k8 = _round_up(K, 8)
    mp = _round_up(M, 128)
    rp = _round_up(R, 128)
    np_ = _round_up(N, 128)
    # t = x @ b^T; gt = gy @ a; ga = gy^T @ t; gb = gt^T @ x   (t/gt in f32)
    gemms = (
        (k8 * np_ + rp * np_) * itemsize + k8 * rp * 4       # t
        + (k8 * mp + mp * rp) * itemsize + k8 * rp * 4       # gt
        + k8 * mp * itemsize + k8 * rp * 4 + mp * rp * 4     # ga
        + k8 * rp * 4 + k8 * np_ * itemsize + rp * np_ * 4   # gb
    )
    # gx via btt_linear_pallas(gy, a^T, b^T): x:=gy streamed once, the
    # "b" operand (a^T, shape (R, M)) refetched per K row-block, the
    # resident "a" operand (b^T, (N, R)) fetched once, y:=gx written once.
    tkf = choose_tiles(N, R, itemsize, K=K)[0]
    kpf = _round_up(K, tkf)
    n_k = kpf // tkf
    gx_launch = (kpf * mp + n_k * rp * mp + np_ * rp + kpf * np_) * itemsize
    return gemms + gx_launch
