"""Pallas TPU kernel: flash-decode attention against a paged KV cache.

The training kernels (PRs 1-6) close the paper's on-chip story for every
*training* stage; this module is the serving mirror.  At decode time each
stream contributes ONE query row per step, and the whole working set the
paper's framework keeps on chip — TT cores, half-factors, softmax state —
still fits, so the only HBM traffic that matters is the KV cache itself.
FTRANS (arXiv 2007.08563) makes the same observation for block-compressed
transformer inference: the energy win compounds when the cache streams once
and nothing else moves.

The cache is **paged** (vLLM-style): physical pages of ``P`` rows live in a
shared pool ``(NP, KV, P, D)`` and each request owns an ordered list of page
ids (its page table).  The kernel never sees a contiguous cache:

  grid = (B, KVh, NPmax), page axis innermost (sequential).
  q block (1, 1, Gp, Dp)  — one stream's query rows for one KV head, ALL
                            GQA group members together (the repeat happens
                            in the block layout, never in memory)
  k/v block (1, 1, P, Dp) — ONE page, fetched page-table-indirectly: the
                            BlockSpec index map reads ``pt[b, p]`` from the
                            scalar-prefetched page table, so only pages the
                            request actually owns are addressed — physical
                            page order is invisible to the math
  o block  (1, 1, Gp, Dp) — written once per (b, h)
  m/l/acc scratch         — online-softmax state carried in VMEM across the
                            page axis (the flash dataflow, single Q row)

Logical positions are slot-ordered: row ``i`` of page-table slot ``p`` is
position ``pos0 + p·P + i`` (``pos0 > 0`` after ring eviction on windowed
layers — whole out-of-window pages are freed by the cache manager, and the
in-page tail is masked here).  Dead pages (``p·P >= len - pos0``) are
skipped via ``pl.when``; ragged page tails are masked by ``lpos < len``.

``paged_decode_ref`` is the pure-JAX fallback AND the oracle: it scans the
page axis with the identical primitive sequence (same ``dot_general`` dims,
same select order), so the two paths are bitwise-comparable in tests and
the VMEM-budget fallback in ``ops.flash_decode_op`` cannot drift.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

from .btt_linear import VMEM_BUDGET, _round_up
from .flash_attention import NEG_INF

__all__ = [
    "flash_decode_pallas",
    "paged_decode_ref",
    "choose_decode_attn_tiles",
    "decode_attn_vmem_fits",
    "decode_attn_stage_vmem_bytes",
    "decode_attn_flops",
    "fused_decode_attn_hbm_bytes",
    "unfused_decode_attn_hbm_bytes",
    "DEFAULT_PAGE_SIZE",
]

DEFAULT_PAGE_SIZE = 64


# ---------------------------------------------------------------------------
# Tile chooser — single residency source for kernel, op gate, and ledger.
# ---------------------------------------------------------------------------


def choose_decode_attn_tiles(G: int, D: int, P: int, itemsize: int, *,
                             budget: int | None = None
                             ) -> tuple[int, int, int]:
    """(gp, dp, vmem_bytes) for one flash-decode grid step.

    ``G`` = GQA group size (query heads per KV head), ``D`` = head dim,
    ``P`` = page size.  The working set is a single query-row tile plus one
    page — there is nothing to shrink (the page size is the cache layout,
    chosen by the serving config), so this chooser only reports; callers
    gate on :func:`decode_attn_vmem_fits` and fall back to the pure-JAX
    paged reference when an oversized page overflows the budget.
    """
    gp = _round_up(G, 8)        # f32 sublane granule; bf16 pads further
    dp = _round_up(D, 128)
    # q + o blocks, k + v page blocks, m/l/acc f32 scratch, (gp, P) score.
    vmem = (2 * gp * dp * itemsize + 2 * P * dp * itemsize
            + gp * (dp + 2) * 4 + gp * P * 4)
    return gp, dp, vmem


def decode_attn_vmem_fits(G: int, D: int, P: int, itemsize: int, *,
                          budget: int | None = None) -> bool:
    """True iff the flash-decode working set fits the kernel VMEM budget.

    THE dispatch predicate: ``ops.flash_decode_op`` takes the kernel path
    iff this holds, and ``core.memory_ledger`` gates its DECODE attention
    row on it too.
    """
    budget = budget or VMEM_BUDGET
    return choose_decode_attn_tiles(G, D, P, itemsize)[2] <= budget


def decode_attn_stage_vmem_bytes(G: int, D: int, P: int, itemsize: int, *,
                                 fused: bool = True,
                                 budget: int | None = None) -> int:
    """VMEM working set the decode attention stage ACTUALLY launches: the
    kernel's (chooser-derived) when ``fused`` and it fits, else 0 (the
    fallback is pure-JAX — no Pallas launch)."""
    if not fused or not decode_attn_vmem_fits(G, D, P, itemsize,
                                              budget=budget):
        return 0
    return choose_decode_attn_tiles(G, D, P, itemsize)[2]


# ---------------------------------------------------------------------------
# The kernel.
# ---------------------------------------------------------------------------


def _kernel(pt_ref, len_ref, pos0_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, np_max: int, page: int, scale: float,
            window: int | None):
    del pt_ref  # consumed by the BlockSpec index maps
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    pos0 = pos0_ref[b]
    live = p * page < length - pos0   # page holds at least one valid row

    @pl.when(live)
    def _step():
        q = q_ref[0, 0]                           # (Gp, Dp)
        k = k_ref[0, 0]                           # (P, Dp)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        lpos = pos0 + p * page + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = lpos < length
        if window is not None:
            mask &= lpos >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                       # (Gp, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        pr = jnp.exp(s - m_new)                   # (Gp, P) f32
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + pr.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0, 0]                           # (P, Dp)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            pr.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(p == np_max - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def flash_decode_pallas(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        page_table: jax.Array, lengths: jax.Array,
                        pos0: jax.Array, *, window: int | None = None,
                        interpret: bool = False) -> jax.Array:
    """``q (B, KV, G, D); k/v pages (NP, KV, P, D) -> o (B, KV, G, D)``.

    ``page_table (B, NPmax) int32`` maps each request's logical page slots
    to physical page ids; ``lengths (B,) int32`` is the number of valid
    cache rows per request (INCLUDING the current token, written before
    attending); ``pos0 (B,) int32`` the logical position of slot 0 row 0
    (nonzero after ring eviction on windowed layers).  Slots at or past
    ``ceil((len - pos0) / P)`` are dead: their table entries may point
    anywhere valid and are never read into the math.
    """
    B, KV, G, D = q.shape
    NP, _, P, _ = k_pages.shape
    np_max = page_table.shape[1]
    scale = 1.0 / (D ** 0.5)
    itemsize = jnp.dtype(q.dtype).itemsize
    gp, dp, _ = choose_decode_attn_tiles(G, D, P, itemsize)

    qp = jnp.pad(q, ((0, 0), (0, 0), (0, gp - G), (0, dp - D)))
    kp = jnp.pad(k_pages, ((0, 0), (0, 0), (0, 0), (0, dp - D)))
    vp = jnp.pad(v_pages, ((0, 0), (0, 0), (0, 0), (0, dp - D)))

    grid = (B, KV, np_max)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,       # page_table, lengths, pos0
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, gp, dp),
                         lambda b, h, p, pt, ln, p0: (b, h, 0, 0)),
            # Page-table indirection: the k/v block for grid step (b, ·, p)
            # is physical page pt[b, p] — only owned pages are addressed.
            pl.BlockSpec((1, 1, P, dp),
                         lambda b, h, p, pt, ln, p0: (pt[b, p], h, 0, 0)),
            pl.BlockSpec((1, 1, P, dp),
                         lambda b, h, p, pt, ln, p0: (pt[b, p], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, gp, dp),
                               lambda b, h, p, pt, ln, p0: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((gp, 1), jnp.float32),     # m
            pltpu.VMEM((gp, 1), jnp.float32),     # l
            pltpu.VMEM((gp, dp), jnp.float32),    # acc
        ],
    )
    o = pl.pallas_call(
        functools.partial(_kernel, np_max=np_max, page=P, scale=scale,
                          window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, gp, dp), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      pos0.astype(jnp.int32), qp, kp, vp)
    return o[:, :, :G, :D]


# ---------------------------------------------------------------------------
# Pure-JAX paged reference — fallback path AND bitwise oracle.
# ---------------------------------------------------------------------------


def paged_decode_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     page_table: jax.Array, lengths: jax.Array,
                     pos0: jax.Array, *,
                     window: int | None = None) -> jax.Array:
    """Same signature/result as :func:`flash_decode_pallas`, pure JAX.

    Scans the page axis with the IDENTICAL primitive sequence the kernel
    executes (same ``dot_general`` dimension numbers, same mask/select
    order) on the SAME sublane/lane-padded operand shapes (XLA picks its
    dot reduction strategy per shape, so matching tiles is what makes the
    two paths bitwise-comparable on CPU — the parity tests in
    ``tests/test_flash_decode.py`` hold both to that).
    """
    B, KV, G, D = q.shape
    P = k_pages.shape[2]
    np_max = page_table.shape[1]
    scale = 1.0 / (D ** 0.5)
    gp, dp, _ = choose_decode_attn_tiles(
        G, D, P, jnp.dtype(q.dtype).itemsize)
    q = jnp.pad(q, ((0, 0), (0, 0), (0, gp - G), (0, dp - D)))
    k_pages = jnp.pad(k_pages, ((0, 0), (0, 0), (0, 0), (0, dp - D)))
    v_pages = jnp.pad(v_pages, ((0, 0), (0, 0), (0, 0), (0, dp - D)))

    def one_request(qb, pt_b, len_b, pos0_b):
        kg = k_pages[pt_b]        # (NPmax, KV, P, D)
        vg = v_pages[pt_b]

        def one_head(qh, kh, vh):  # qh (gp, dp); kh/vh (NPmax, P, dp)
            m0 = jnp.full((gp, 1), NEG_INF, jnp.float32)
            l0 = jnp.zeros((gp, 1), jnp.float32)
            acc0 = jnp.zeros((gp, dp), jnp.float32)

            def step(carry, inp):
                m, l, acc = carry
                p_idx, kp_, vp_ = inp
                live = p_idx * P < len_b - pos0_b
                s = jax.lax.dot_general(
                    qh, kp_, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32) * scale
                lpos = pos0_b + p_idx * P + jax.lax.broadcasted_iota(
                    jnp.int32, s.shape, 1)
                mask = lpos < len_b
                if window is not None:
                    mask &= lpos >= len_b - window
                s = jnp.where(mask, s, NEG_INF)
                m_new = jnp.maximum(m, s.max(axis=1, keepdims=True))
                pr = jnp.exp(s - m_new)
                corr = jnp.exp(m - m_new)
                l_new = l * corr + pr.sum(axis=1, keepdims=True)
                acc_new = acc * corr + jax.lax.dot_general(
                    pr.astype(vp_.dtype), vp_, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                keep = lambda new, old: jnp.where(live, new, old)  # noqa: E731
                return (keep(m_new, m), keep(l_new, l),
                        keep(acc_new, acc)), None

            (m, l, acc), _ = jax.lax.scan(
                step, (m0, l0, acc0),
                (jnp.arange(np_max), kh, vh))
            return (acc / jnp.maximum(l, 1e-30)).astype(qh.dtype)

        # vmap over KV heads: kg (NPmax, KV, P, D) -> per-head (NPmax, P, D)
        return jax.vmap(one_head, in_axes=(0, 1, 1))(qb, kg, vg)

    out = jax.vmap(one_request)(q, page_table.astype(jnp.int32),
                                lengths.astype(jnp.int32),
                                pos0.astype(jnp.int32))
    return out[:, :, :G, :D]


# ---------------------------------------------------------------------------
# Analytic FLOP / HBM-byte models (bench_decode + ledger rows).
# ---------------------------------------------------------------------------


def decode_attn_flops(B: int, H: int, D: int, length: int) -> int:
    """FLOPs of one decode attention step over the valid cache: two matmuls
    (qKᵀ, pV), 2·D FLOPs per live score element."""
    return B * H * length * 2 * D * 2


def fused_decode_attn_hbm_bytes(B: int, H: int, KV: int, D: int, P: int,
                                n_pages: int, itemsize: int) -> int:
    """HBM bytes one flash-decode launch moves (tile-derived).

    q read once per (b, h), k/v pages fetched page-table-indirectly —
    ``n_pages`` live pages per request, each once per KV head (dead slots
    are clamped by the table and never re-fetched) — o written once.  No
    contiguous cache copy, no score row, no probability row: the softmax
    state lives in VMEM scratch.  Padded bytes are real bytes on the wire.
    """
    G = H // KV
    gp, dp, _ = choose_decode_attn_tiles(G, D, P, itemsize)
    q_io = 2 * B * KV * gp * dp * itemsize          # q read + o written
    kv = B * KV * n_pages * 2 * P * dp * itemsize   # pages streamed once
    return q_io + kv


def unfused_decode_attn_hbm_bytes(B: int, H: int, KV: int, D: int,
                                  S: int, itemsize: int) -> int:
    """HBM bytes of the unfused decode path over a length-``S`` cache.

    Counts, generously to XLA (each tensor once per producing/consuming
    pass): the page gather materializing a contiguous ``(B, S, KV, D)``
    copy (pool read + copy write), the copy re-read by qKᵀ, the
    ``(B, H, S)`` f32 score row written, read+rewritten by the softmax,
    and the probability row re-read with the second copy pass for pV.
    This is what the paged kernel deletes: with it the cache streams
    exactly once and no row-sized intermediate exists.
    """
    cache = B * S * KV * D * itemsize
    gather = 2 * cache                       # pool read + contiguous write
    qk = B * H * D * itemsize + cache        # q read + copy re-read
    scores = 3 * B * H * S * 4               # s written; softmax rd+wr
    av = B * H * S * 4 + cache               # p re-read + copy re-read
    o = B * H * D * itemsize
    return gather + qk + scores + av + o
