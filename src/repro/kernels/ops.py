"""jit'd public wrappers around the Pallas kernels, with pure-JAX fallbacks.

* ``btt_linear_op(cores, x, spec)`` — the paper's BTT linear executed by the
  fused Pallas forward (``btt_linear.py``) under a custom VJP that implements
  the paper's fused backward (Sec. V-B2): no K-sized intermediate is saved;
  the backward recomputes ``t`` and routes the data gradient through the same
  fused kernel by operand swap (``gx = btt(gy, A^T, B^T)``).

* ``ttm_embed_op(cores, ids, spec)`` — gather-free TTM lookup via the d=3
  one-hot kernel; falls back to the jnp gather chain when d != 3 or the cores
  exceed the VMEM residency budget.

Kernel selection: on a TPU backend the compiled kernel runs natively; on CPU
(this container) ``interpret=True`` executes the kernel body in Python — the
correctness path used by every test.  ``use_kernel=False`` forces the pure
JAX path (what the production dry-run lowers, keeping HLO analyzable).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.contraction import tt_forward_btt, ttm_lookup, token_digits
from repro.core.tt import TTMSpec, TTSpec, tt_half_factors

from .btt_linear import btt_linear_pallas
from .ttm_embed import ttm_embed_pallas

__all__ = ["btt_linear_op", "ttm_embed_op", "kernel_interpret_default"]

_VMEM_CORE_BUDGET = 8 * 1024 * 1024  # resident-core budget for ttm kernel


def kernel_interpret_default() -> bool:
    """interpret=True everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# BTT linear (kernel-backed, fused custom VJP).
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _btt_kernel_fused(cores: tuple, x: jax.Array, spec: TTSpec,
                      interpret: bool) -> jax.Array:
    a, b = tt_half_factors(cores, spec)
    return btt_linear_pallas(x, b, a, interpret=interpret)


def _btt_kernel_fwd(cores, x, spec, interpret):
    a, b = tt_half_factors(cores, spec)
    y = btt_linear_pallas(x, b, a, interpret=interpret)
    return y, (cores, x)  # paper-faithful: only inputs saved, no K-sized state


def _btt_kernel_bwd(spec, interpret, residuals, gy):
    cores, x = residuals
    d = spec.d

    def build(oc, ic):
        return tt_half_factors(list(oc) + list(ic), spec)

    (a, b), build_vjp = jax.vjp(build, tuple(cores[:d]), tuple(cores[d:]))
    # Data gradient through the SAME fused kernel (operand swap):
    #   gx = (gy @ A) @ B = btt(gy; b=A^T, a=B^T)
    gx = btt_linear_pallas(gy, a.T, b.T, interpret=interpret)
    # Core gradients: small K-reduction GEMMs (outputs are r-sized).
    t = jnp.dot(x, b.T, preferred_element_type=jnp.float32).astype(x.dtype)
    gt = jnp.dot(gy, a, preferred_element_type=jnp.float32).astype(gy.dtype)
    ga = jnp.dot(gy.T, t, preferred_element_type=jnp.float32).astype(a.dtype)
    gb = jnp.dot(gt.T, x, preferred_element_type=jnp.float32).astype(b.dtype)
    g_out, g_in = build_vjp((ga, gb))
    return (tuple(g_out) + tuple(g_in), gx)


_btt_kernel_fused.defvjp(_btt_kernel_fwd, _btt_kernel_bwd)


def btt_linear_op(cores, x: jax.Array, spec: TTSpec, *,
                  use_kernel: bool = True,
                  interpret: bool | None = None) -> jax.Array:
    """``x (K, N) -> y (K, M)`` with W in TT format, BTT contraction."""
    if not use_kernel:
        return tt_forward_btt(cores, x, spec)
    if interpret is None:
        interpret = kernel_interpret_default()
    return _btt_kernel_fused(tuple(cores), x, spec, interpret)


# ---------------------------------------------------------------------------
# TTM embedding (one-hot kernel when eligible).
# ---------------------------------------------------------------------------


def _ttm_kernel_eligible(spec: TTMSpec) -> bool:
    if spec.d != 3:
        return False
    core_bytes = sum(int(np.prod(s)) * 4 for s in spec.core_shapes())
    return core_bytes <= _VMEM_CORE_BUDGET


def ttm_embed_op(cores, ids: jax.Array, spec: TTMSpec, *,
                 use_kernel: bool = True,
                 interpret: bool | None = None) -> jax.Array:
    """``ids (...,) int32 -> (..., H)`` TTM lookup."""
    if not use_kernel or not _ttm_kernel_eligible(spec):
        return ttm_lookup(cores, ids, spec)
    if interpret is None:
        interpret = kernel_interpret_default()
    batch_shape = ids.shape
    flat = ids.reshape(-1)
    dg = token_digits(flat, spec.vocab_factors)  # (K, 3)
    oh = tuple(
        jax.nn.one_hot(dg[:, k], spec.vocab_factors[k], dtype=cores[0].dtype)
        for k in range(3)
    )
    rs = spec.ranks
    spec_dims = (tuple(spec.vocab_factors), tuple(spec.hidden_factors),
                 (rs[1], rs[2]))
    out = ttm_embed_pallas(oh, tuple(cores), spec_dims=spec_dims,
                           interpret=interpret)
    return out.reshape(batch_shape + (spec.hidden_dim,))
