"""jit'd public wrappers around the Pallas kernels, with pure-JAX fallbacks.

* ``btt_linear_op(cores, x, spec)`` — the paper's BTT linear executed by the
  fused Pallas forward (``btt_linear.py``) under a custom VJP that implements
  the paper's fused backward (Sec. V-B2): no K-sized intermediate is saved.
  The half-factors ``(A, B)`` are built from the cores ONCE per invocation
  (``tt_half_factors``) and the custom VJP lives at the half-factor level
  (``_hf_linear``): the bwd reuses the saved (tiny, K-independent) factors
  and plain autodiff chains their cotangents back into per-core gradients —
  no rebuild in either the fwd or the bwd.  With ``fused_bwd=True``
  (default) the whole BWD stage — data gradient AND half-factor gradients —
  runs as ONE Pallas kernel (``btt_backward.py``) with the recomputed
  ``t``/``gt`` intermediates resident in VMEM scratch; shapes whose working
  set exceeds the VMEM budget, or ``fused_bwd=False``, take the reference
  path: ``gx`` through the forward kernel by operand swap
  (``gx = btt(gy, A^T, B^T)``) plus four XLA GEMMs for the core gradients
  (f32 end to end).

* ``btt_ffn_op(up_cores, down_cores, gate_cores, x, ...)`` — the WHOLE FFN
  block (both TT linears + activation; three linears when gated) as one
  fused Pallas forward and one fused Pallas backward (``btt_ffn.py``): the
  ``(K, d_ff)`` hidden state lives only in VMEM scratch, and the backward
  recomputes it from ``x``, so the block's training residual is just the
  layer input.  Shapes whose working set exceeds the VMEM budget
  (``ffn_vmem_fits`` — the ledger gates on the same predicate), or
  ``fused_ffn=False``, take the two-call path through ``_hf_linear``.

* ``ttm_embed_op(cores, ids, spec)`` — gather-free TTM lookup via the d=3
  one-hot kernel; falls back to the jnp gather chain when d != 3 or the cores
  exceed the VMEM residency budget.  A custom VJP routes the core gradients
  through autodiff of the pure-jnp one-hot chain (``ref.ttm_embed_ref``) —
  the same math as the gather-chain oracle, so the kernel path is
  trainable.

* ``flash_mha_op(q, k, v)`` — training/prefill attention as the fused flash
  kernels: forward saves only ``(O, m, l)`` per layer; the backward is ONE
  ``pallas_call`` (``flash_backward.py``) recomputing probability tiles in
  VMEM — no S×S tensor is ever saved or moved.  Shapes whose backward
  working set exceeds the VMEM budget (dK/dV residency grows with S) fall
  back to the pure-JAX ``blockwise_attention`` under plain autodiff.

Kernel selection: on a TPU backend the compiled kernel runs natively; on CPU
(this container) ``interpret=True`` executes the kernel body in Python — the
correctness path used by every test.  ``use_kernel=False`` forces the pure
JAX path (what the production dry-run lowers, keeping HLO analyzable).

Precision: every trainable op takes ``precision`` (a ``PrecisionConfig``).
With a scaled format the custom-VJP *boundary* quantizes the at-rest set —
half-factors at ``param_dtype``, the saved layer input / flash residuals at
``act_dtype`` — per-tensor max-abs RTN, and saves the quantized arrays plus
an f32 scale stack as the residuals.  The fused kernels dequantize those
tiles in VMEM (``scales=`` operand) and keep f32 accumulator chains; no
dense low-precision tensor round-trips HBM between FWD and BWD.  Gradients
follow the straight-through estimator: cotangents are w.r.t. the
*dequantized* operands.  Cast-only ``bfloat16`` rides the same path with
unit scales.  ``precision=None`` (or all-f32) is byte-identical to the
pre-precision kernels.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as _quant
from repro.core.contraction import tt_forward_btt, ttm_lookup, token_digits
from repro.core.tt import TTMSpec, TTSpec, tt_half_factors

from .btt_backward import btt_backward_pallas, bwd_vmem_fits
from .btt_ffn import (
    ACTS as _FFN_ACTS,
    btt_ffn_bwd_pallas,
    btt_ffn_decode_pallas,
    btt_ffn_pallas,
    decode_ffn_vmem_fits,
    ffn_vmem_fits,
)
from .btt_linear import (
    btt_linear_decode_pallas,
    btt_linear_pallas,
    decode_linear_vmem_fits,
)
from .flash_attention import flash_attention_pallas
from .flash_backward import (
    attn_bwd_vmem_fits,
    choose_attn_tiles,
    flash_attention_bwd_pallas,
)
from .flash_decode import (
    decode_attn_vmem_fits,
    flash_decode_pallas,
    paged_decode_ref,
)
from .ttm_embed import ttm_embed_pallas

__all__ = ["btt_linear_op", "btt_ffn_op", "ttm_embed_op", "flash_mha_op",
           "flash_decode_op", "btt_linear_decode_op", "btt_ffn_decode_op",
           "kernel_interpret_default"]

_VMEM_CORE_BUDGET = 8 * 1024 * 1024  # resident-core budget for ttm kernel


def kernel_interpret_default() -> bool:
    """interpret=True everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Precision plumbing (see module docstring).  The VJP boundary stores each
# operand in its at-rest format; scaled formats carry one f32 scale, cast-only
# formats a unit scale — the quant kernels' ``tile.astype(f32) * scale``
# dequant handles both uniformly.
# ---------------------------------------------------------------------------


def _prep(v: jax.Array, fmt: str) -> tuple[jax.Array, jax.Array]:
    """``v -> (stored, scale)`` in the at-rest format ``fmt``."""
    if fmt == "float32":
        return v, jnp.float32(1.0)
    f = _quant.resolve(fmt)
    if not f.needs_scale:
        return v.astype(f.dtype), jnp.float32(1.0)
    return _quant.quantize(v, fmt)


def _deq(v: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (v.astype(jnp.float32) * scale).astype(dtype)


def _precision_fmts(precision, x_dtype) -> tuple[str, str]:
    """``(param_fmt, act_fmt)`` strings from a ``PrecisionConfig`` (or None).

    A format equal to the op's compute dtype is storage-identity (the
    residual already lives in that dtype), so it normalizes to the
    ``"float32"`` sentinel — which ``_prep`` treats as "store as-is, unit
    scale" — keeping such configs on the legacy bit-identical path.
    """
    if precision is None:
        return "float32", "float32"
    name = jnp.dtype(x_dtype).name
    pfmt = precision.param_dtype
    afmt = precision.resolved_act(name)
    if pfmt == name:
        pfmt = "float32"
    if afmt == name:
        afmt = "float32"
    return pfmt, afmt


# ---------------------------------------------------------------------------
# BTT linear (kernel-backed, fused custom VJP at the half-factor level).
#
# The half-factor build is OUTSIDE the custom VJP: ``btt_linear_op`` (and
# ``btt_ffn_op``) call ``tt_half_factors`` exactly once per invocation and
# plain autodiff chains the (tiny, K-independent) build — the fwd/bwd pair
# below never rebuilds the factors from cores.
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _hf_linear(a: jax.Array, b: jax.Array, x: jax.Array,
               interpret: bool, fused_bwd: bool,
               shard_dims: int = 1, pfmt: str = "float32",
               afmt: str = "float32") -> jax.Array:
    return _hf_linear_impl(a, b, x, interpret, pfmt, afmt)[0]


def _hf_linear_impl(a, b, x, interpret, pfmt, afmt):
    if pfmt == "float32" and afmt == "float32":
        y = btt_linear_pallas(x, b, a, interpret=interpret)
        # Residuals: the layer input and the already-built half-factors
        # (O(r) extra state, K-independent) — no K-sized intermediate, no
        # rebuild.
        return y, (a, b, x, None)
    # Quantized-at-rest: the residual SET is the stored set — half-factors
    # at param_dtype, the layer input at act_dtype, plus the (1, 3) f32
    # scale stack [s_x, s_b, s_a].  The forward consumes the same stored
    # tiles (dequantized in VMEM), so fwd and bwd see identical operands
    # and the STE gradients are exact for the quantized model.
    cdt = x.dtype
    aq, sa = _prep(a, pfmt)
    bq, sb = _prep(b, pfmt)
    xq, sx = _prep(x, afmt)
    scales = jnp.stack([sx, sb, sa]).reshape(1, 3)
    y = btt_linear_pallas(xq, bq, aq, scales=scales, out_dtype=cdt,
                          interpret=interpret)
    return y, (aq, bq, xq, scales)


def _hf_linear_fwd(a, b, x, interpret, fused_bwd, shard_dims, pfmt, afmt):
    return _hf_linear_impl(a, b, x, interpret, pfmt, afmt)


def _hf_linear_bwd(interpret, fused_bwd, shard_dims, pfmt, afmt,
                   residuals, gy):
    a, b, x, scales = residuals
    M, R = a.shape
    N = b.shape[1]
    itemsize = max(jnp.dtype(v.dtype).itemsize for v in (x, gy, b, a))
    k_local = -(-x.shape[0] // max(shard_dims, 1))
    if fused_bwd and bwd_vmem_fits(M, N, R, itemsize, K=k_local):
        # ONE kernel launch: gx streamed, ga/gb accumulated on chip —
        # t/gt never leave VMEM (paper Eqs. (10)/(11)/(16) as one stage).
        # With scales the kernel dequantizes the stored tiles in VMEM and
        # returns STE gradients w.r.t. the dequantized operands.
        gx, ga, gb = btt_backward_pallas(
            x, gy, b, a, scales=scales,
            out_dtype=None if scales is None else gy.dtype,
            interpret=interpret)
    else:
        if scales is not None:
            # Fallback dequantizes once at entry (transient f32 copies);
            # at-rest storage between FWD and BWD stays quantized.
            s = scales.reshape(3)
            x = _deq(x, s[0], gy.dtype)
            b = _deq(b, s[1], gy.dtype)
            a = _deq(a, s[2], gy.dtype)
        # Reference path: data gradient through the fused FORWARD kernel by
        # operand swap (gx = (gy @ A) @ B = btt(gy; b=A^T, a=B^T)); core
        # gradients as four XLA GEMMs with t/gt kept f32 through the
        # dependent products (same math as btt_backward_ref, minus its
        # kernel-idiom gx GEMM, which the operand-swap launch replaces).
        gx = btt_linear_pallas(gy, a.T, b.T, interpret=interpret)
        t = jnp.dot(x, b.T, preferred_element_type=jnp.float32)
        gt = jnp.dot(gy, a, preferred_element_type=jnp.float32)
        ga = jnp.dot(gy.T.astype(jnp.float32), t,
                     preferred_element_type=jnp.float32)
        gb = jnp.dot(gt.T, x.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    if scales is None:
        return ga.astype(a.dtype), gb.astype(b.dtype), gx
    return (ga.astype(gy.dtype), gb.astype(gy.dtype), gx.astype(gy.dtype))


_hf_linear.defvjp(_hf_linear_fwd, _hf_linear_bwd)


def _resolve_shard_dims(shard_dims: int | None) -> int:
    """The row-shard divisor for VMEM dispatch predicates.

    ``None`` means "ask the mesh context": ``meshctx.row_shards()`` — 1
    with no mesh and 1 inside shard_map bodies (local shapes already), the
    GSPMD row-shard product otherwise.  Predicates then gate on the
    *per-device* row count, so fused dispatch survives sharding and stays
    in lockstep with ``core.memory_ledger``'s per-shard rows.
    """
    if shard_dims is not None:
        return max(int(shard_dims), 1)
    from repro.core.meshctx import row_shards

    return row_shards()


def btt_linear_op(cores, x: jax.Array, spec: TTSpec, *,
                  use_kernel: bool = True,
                  interpret: bool | None = None,
                  fused_bwd: bool = True,
                  shard_dims: int | None = None,
                  precision=None) -> jax.Array:
    """``x (K, N) -> y (K, M)`` with W in TT format, BTT contraction.

    ``fused_bwd`` selects the single-kernel BWD stage for the gradients
    (falls back automatically when the shape's working set exceeds the
    kernel VMEM budget); ``False`` forces the operand-swap + XLA-GEMM
    reference path.  ``shard_dims`` (default: mesh-resolved) divides K for
    that VMEM gate only — see ``_resolve_shard_dims``.  ``precision``
    (a ``PrecisionConfig``) selects the at-rest storage formats — see the
    module docstring.
    """
    if not use_kernel:
        return tt_forward_btt(cores, x, spec)
    if interpret is None:
        interpret = kernel_interpret_default()
    pfmt, afmt = _precision_fmts(precision, x.dtype)
    a, b = tt_half_factors(list(cores), spec)  # built once; autodiff chains
    return _hf_linear(a, b, x, interpret, fused_bwd,
                      _resolve_shard_dims(shard_dims), pfmt, afmt)


# ---------------------------------------------------------------------------
# Fused tensorized FFN (whole block: both/all TT linears + activation).
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10, 11))
def _ffn_fused(a1, b1, a2, b2, ag, bg, x, act: str, f_logical: int,
               interpret: bool, pfmt: str = "float32",
               afmt: str = "float32") -> jax.Array:
    return _ffn_fused_impl(a1, b1, a2, b2, ag, bg, x, act, f_logical,
                           interpret, pfmt, afmt)[0]


def _ffn_fused_impl(a1, b1, a2, b2, ag, bg, x, act, f_logical, interpret,
                    pfmt, afmt):
    if pfmt == "float32" and afmt == "float32":
        y = btt_ffn_pallas(x, b1, a1, b2, a2, bg, ag, act=act,
                           f_logical=f_logical, interpret=interpret)
        # The block's whole residual set: x and the half-factors.  The
        # hidden state and the activation pre-images are recomputed in
        # VMEM by the backward — FFN residuals are O(K*d_model), never
        # O(K*d_ff).
        return y, (a1, b1, a2, b2, ag, bg, x, None)
    # Quantized-at-rest residual set + the (1, 8) scale stack
    # [s_x, s_b1, s_a1, s_bg, s_ag, s_b2, s_a2, pad] (gate slots zero when
    # ungated — the kernel never reads them then).
    cdt = x.dtype
    xq, sx = _prep(x, afmt)
    b1q, sb1 = _prep(b1, pfmt)
    a1q, sa1 = _prep(a1, pfmt)
    b2q, sb2 = _prep(b2, pfmt)
    a2q, sa2 = _prep(a2, pfmt)
    zero = jnp.float32(0.0)
    if bg is not None:
        bgq, sbg = _prep(bg, pfmt)
        agq, sag = _prep(ag, pfmt)
    else:
        bgq = agq = None
        sbg = sag = zero
    scales = jnp.stack([sx, sb1, sa1, sbg, sag, sb2, sa2,
                        zero]).reshape(1, 8)
    y = btt_ffn_pallas(xq, b1q, a1q, b2q, a2q, bgq, agq, act=act,
                       f_logical=f_logical, scales=scales, out_dtype=cdt,
                       interpret=interpret)
    return y, (a1q, b1q, a2q, b2q, agq, bgq, xq, scales)


def _ffn_fused_fwd(a1, b1, a2, b2, ag, bg, x, act, f_logical, interpret,
                   pfmt, afmt):
    return _ffn_fused_impl(a1, b1, a2, b2, ag, bg, x, act, f_logical,
                           interpret, pfmt, afmt)


def _ffn_fused_bwd(act, f_logical, interpret, pfmt, afmt, residuals, gy):
    a1, b1, a2, b2, ag, bg, x, scales = residuals
    grads = btt_ffn_bwd_pallas(x, gy, b1, a1, b2, a2, bg, ag, act=act,
                               f_logical=f_logical, scales=scales,
                               out_dtype=None if scales is None else gy.dtype,
                               interpret=interpret)
    gdt = gy.dtype
    if bg is not None:
        gx, ga1, gb1, ga2, gb2, gag, gbg = grads
        if scales is not None:
            return (ga1.astype(gdt), gb1.astype(gdt), ga2.astype(gdt),
                    gb2.astype(gdt), gag.astype(gdt), gbg.astype(gdt), gx)
        return (ga1.astype(a1.dtype), gb1.astype(b1.dtype),
                ga2.astype(a2.dtype), gb2.astype(b2.dtype),
                gag.astype(ag.dtype), gbg.astype(bg.dtype), gx)
    gx, ga1, gb1, ga2, gb2 = grads
    if scales is not None:
        return (ga1.astype(gdt), gb1.astype(gdt), ga2.astype(gdt),
                gb2.astype(gdt), None, None, gx)
    return (ga1.astype(a1.dtype), gb1.astype(b1.dtype),
            ga2.astype(a2.dtype), gb2.astype(b2.dtype), None, None, gx)


_ffn_fused.defvjp(_ffn_fused_fwd, _ffn_fused_bwd)


def btt_ffn_op(up_cores, down_cores, gate_cores, x: jax.Array,
               up_spec: TTSpec, down_spec: TTSpec,
               gate_spec: TTSpec | None = None, *, act: str = "gelu",
               f_logical: int | None = None,
               interpret: bool | None = None, fused_bwd: bool = True,
               fused_ffn: bool = True,
               shard_dims: int | None = None,
               precision=None) -> jax.Array:
    """Whole TT FFN block: ``x (K, N) -> y (K, M)`` through
    ``down(act(up(x)))`` (``down(act(gate(x)) * up(x))`` when
    ``gate_cores`` is given), fused forward AND backward.

    The half-factors of every projection are built exactly once here;
    autodiff chains their cotangents back into per-core gradients.  When
    the megakernel's working set exceeds the VMEM budget
    (``ffn_vmem_fits``, evaluated at the per-device row count
    ``ceil(K / shard_dims)`` — see ``_resolve_shard_dims``) or
    ``fused_ffn=False``, the op takes the two-call path through
    ``_hf_linear`` — the exact computation ``models.layers.mlp_apply``
    performs, bit for bit.
    """
    if interpret is None:
        interpret = kernel_interpret_default()
    sd = _resolve_shard_dims(shard_dims)
    pfmt, afmt = _precision_fmts(precision, x.dtype)
    a1, b1 = tt_half_factors(list(up_cores), up_spec)
    a2, b2 = tt_half_factors(list(down_cores), down_spec)
    ag = bg = None
    if gate_cores is not None:
        ag, bg = tt_half_factors(list(gate_cores), gate_spec)
    if f_logical is None:
        f_logical = min(up_spec.out_dim, down_spec.in_dim)

    M, N, F = down_spec.out_dim, up_spec.in_dim, up_spec.out_dim
    R1, R2 = up_spec.mid_rank, down_spec.mid_rank
    Rg = gate_spec.mid_rank if gate_spec is not None else 0
    itemsize = jnp.dtype(x.dtype).itemsize
    if fused_ffn and ffn_vmem_fits(M, N, F, R1, R2, Rg, itemsize,
                                   K=-(-x.shape[0] // sd)):
        return _ffn_fused(a1, b1, a2, b2, ag, bg, x, act, f_logical,
                          interpret, pfmt, afmt)
    # Two-call fallback: the same slice/act/pad sequence mlp_apply runs.
    u = _hf_linear(a1, b1, x, interpret, fused_bwd, sd,
                   pfmt, afmt)[:, :f_logical]
    if bg is not None:
        g = _hf_linear(ag, bg, x, interpret, fused_bwd, sd,
                       pfmt, afmt)[:, :f_logical]
        h = _FFN_ACTS[act](g) * u
    else:
        h = _FFN_ACTS[act](u)
    if f_logical != down_spec.in_dim:
        h = jnp.pad(h, ((0, 0), (0, down_spec.in_dim - f_logical)))
    return _hf_linear(a2, b2, h, interpret, fused_bwd, sd, pfmt, afmt)


# ---------------------------------------------------------------------------
# Flash attention (fused fwd + single-kernel bwd under a custom VJP).
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_fused(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool,
                 window: int | None, group: int, interpret: bool,
                 budget: int | None, afmt: str = "float32") -> jax.Array:
    o, _, _ = _flash_fwd_call(q, k, v, causal, window, group, interpret,
                              budget)
    return o


def _flash_fwd_call(q, k, v, causal, window, group, interpret, budget):
    # One tile choice (under the caller's budget) feeds BOTH launches, so
    # the gate, the forward, and the backward agree on the working set.
    # The (m, l) statistics are per-row and tile-independent; the
    # backward's recomputed probabilities track the forward's to an ulp
    # (its score dot folds the softmax scale into Q — see
    # flash_backward._bwd_kernel), which the oracle tolerances absorb.
    itemsize = jnp.dtype(q.dtype).itemsize
    tq, tk, _, _, _ = choose_attn_tiles(q.shape[1], q.shape[2], itemsize,
                                        budget=budget)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  group=group, tq=tq, tk=tk,
                                  interpret=interpret, return_residuals=True)


def _flash_fused_fwd(q, k, v, causal, window, group, interpret, budget,
                     afmt):
    o, m, l = _flash_fwd_call(q, k, v, causal, window, group, interpret,
                              budget)
    # Paper-faithful residual set: (O, m, l) — never the S×S probabilities.
    # With a quantized act format the big residuals (q, k, v, o) are stored
    # per-tensor-scaled; the per-row (m, l) statistics stay f32 (they are
    # O(S) against O(S*D) and softmax stability depends on them).
    if afmt == "float32":
        return o, (q, k, v, o, m, l, None)
    qq, s_q = _prep(q, afmt)
    kq, s_k = _prep(k, afmt)
    vq, s_v = _prep(v, afmt)
    oq, s_o = _prep(o, afmt)
    scales = jnp.stack([s_q, s_k, s_v, s_o])
    return o, (qq, kq, vq, oq, m, l, scales)


def _flash_fused_bwd(causal, window, group, interpret, budget, afmt,
                     residuals, do):
    q, k, v, o, m, l, scales = residuals
    if scales is not None:
        # Dequantize once at BWD entry (transient copies); the saved
        # residual tier between FWD and BWD stayed quantized.
        cdt = do.dtype
        q = _deq(q, scales[0], cdt)
        k = _deq(k, scales[1], cdt)
        v = _deq(v, scales[2], cdt)
        o = _deq(o, scales[3], cdt)
    itemsize = jnp.dtype(q.dtype).itemsize
    tq, tk, _, _, _ = choose_attn_tiles(q.shape[1], q.shape[2], itemsize,
                                        budget=budget)
    dq, dk, dv = flash_attention_bwd_pallas(
        q, k, v, o, m, l, do, causal=causal, window=window, group=group,
        tq=tq, tk=tk, interpret=interpret)
    return dq, dk, dv


_flash_fused.defvjp(_flash_fused_fwd, _flash_fused_bwd)


def flash_mha_op(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 causal: bool = True, window: int | None = None,
                 q_chunk: int = 512, kv_chunk: int = 1024,
                 use_kernel: bool = True, interpret: bool | None = None,
                 budget: int | None = None,
                 shard_dims: int | None = None,
                 precision=None) -> jax.Array:
    """``q (B, S, H, D); k, v (B, S, KV, D) -> (B, S, H, D)``, trainable.

    The fused path runs the flash forward and the single-kernel flash
    backward with only ``(O, m, l)`` saved between them.  When the
    backward's VMEM working set exceeds ``budget`` (default: the kernel
    VMEM budget) — or ``use_kernel=False`` — the op silently takes the
    pure-JAX ``blockwise_attention`` path under plain autodiff, with the
    given chunk sizes.  ``core.memory_ledger`` gates on the same
    ``attn_bwd_vmem_fits``, so ledger and dispatch cannot drift.

    ``shard_dims`` is accepted for API symmetry with the other ops: row
    (batch) sharding leaves the per-grid-step (S, D) working set — the
    only thing ``attn_bwd_vmem_fits`` depends on — unchanged, so the
    predicate is already per-shard and the hint needs no arithmetic here.
    """
    del shard_dims
    B, S, H, D = q.shape
    KV = k.shape[2]
    group = H // KV
    itemsize = jnp.dtype(q.dtype).itemsize
    if not use_kernel or not attn_bwd_vmem_fits(S, D, itemsize,
                                                budget=budget):
        # Lazy import: kernels must not depend on models at module scope.
        from repro.models.attention import blockwise_attention

        return blockwise_attention(q, k, v, causal=causal, window=window,
                                   q_chunk=q_chunk, kv_chunk=kv_chunk)
    if interpret is None:
        interpret = kernel_interpret_default()
    _, afmt = _precision_fmts(precision, q.dtype)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, D)
    o = _flash_fused(qf, kf, vf, causal, window, group, interpret, budget,
                     afmt)
    return o.reshape(B, H, S, D).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# Decode serving ops (forward-only — no VJP; sampling never differentiates).
# ---------------------------------------------------------------------------


def flash_decode_op(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, lengths: jax.Array,
                    pos0: jax.Array, *, window: int | None = None,
                    use_kernel: bool = True, interpret: bool | None = None,
                    budget: int | None = None) -> jax.Array:
    """One decode attention step against a paged KV cache.

    ``q (B, H, D)`` — one query row per live stream; ``k_pages``/``v_pages``
    ``(NP, KV, P, D)`` — the physical page pools; ``page_table (B, NPmax)``,
    ``lengths (B,)``, ``pos0 (B,)`` — each stream's logical view (see
    ``flash_decode.flash_decode_pallas``).  GQA is the reshape
    ``(B, KV, H//KV, D)``: query head ``h`` shares KV head ``h // group``,
    matching ``models.attention.decode_attention``'s repeat layout.

    When the working set exceeds ``budget`` — or ``use_kernel=False`` —
    the op takes ``paged_decode_ref``, which executes the identical
    primitive sequence: fallback and kernel are bitwise-comparable, and
    ``core.memory_ledger`` gates its DECODE attention row on the same
    ``decode_attn_vmem_fits``.
    """
    B, H, D = q.shape
    KV, P = k_pages.shape[1], k_pages.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    itemsize = jnp.dtype(q.dtype).itemsize
    if not use_kernel or not decode_attn_vmem_fits(G, D, P, itemsize,
                                                   budget=budget):
        o = paged_decode_ref(qg, k_pages, v_pages, page_table, lengths,
                             pos0, window=window)
    else:
        if interpret is None:
            interpret = kernel_interpret_default()
        o = flash_decode_pallas(qg, k_pages, v_pages, page_table, lengths,
                                pos0, window=window, interpret=interpret)
    return o.reshape(B, H, D)


def btt_linear_decode_op(cores, x: jax.Array, spec: TTSpec, *,
                         use_kernel: bool = True,
                         interpret: bool | None = None,
                         precision=None) -> jax.Array:
    """``x (B, N) -> y (B, M)``: the BTT linear at decode shapes — row tiles
    at the dtype sublane granule instead of the training 32-row blocks.
    Forward-only.  Falls back to the training-tile launch when the decode
    working set exceeds VMEM (same predicate as the ledger's DECODE rows).

    ``precision.param_dtype`` serves the half-factors from quantized-at-rest
    storage: decode is forward-only, so the round-trip
    (``quant.cast_format``) IS the storage semantics — the ledger's DECODE
    weight rows account the stored bytes."""
    if not use_kernel:
        return tt_forward_btt(cores, x, spec)
    if interpret is None:
        interpret = kernel_interpret_default()
    a, b = tt_half_factors(list(cores), spec)
    pfmt, _ = _precision_fmts(precision, x.dtype)
    if pfmt != "float32":
        a = _quant.cast_format(a, pfmt)
        b = _quant.cast_format(b, pfmt)
    itemsize = jnp.dtype(x.dtype).itemsize
    if decode_linear_vmem_fits(a.shape[0], a.shape[1], itemsize,
                               B=x.shape[0]):
        return btt_linear_decode_pallas(x, b, a, interpret=interpret)
    return btt_linear_pallas(x, b, a, interpret=interpret)


def btt_ffn_decode_op(up_cores, down_cores, gate_cores, x: jax.Array,
                      up_spec: TTSpec, down_spec: TTSpec,
                      gate_spec: TTSpec | None = None, *, act: str = "gelu",
                      f_logical: int | None = None,
                      interpret: bool | None = None,
                      precision=None) -> jax.Array:
    """Whole TT FFN block at decode shapes, forward-only: the megakernel
    with sublane-granule row tiles when it fits VMEM
    (``decode_ffn_vmem_fits`` — the ledger's DECODE FFN row gates on the
    same predicate), else the two-call decode-linear path — the exact
    slice/act/pad sequence ``btt_ffn_op``'s fallback runs.
    ``precision.param_dtype`` serves every projection's half-factors from
    quantized-at-rest storage (see ``btt_linear_decode_op``)."""
    if interpret is None:
        interpret = kernel_interpret_default()
    a1, b1 = tt_half_factors(list(up_cores), up_spec)
    a2, b2 = tt_half_factors(list(down_cores), down_spec)
    ag = bg = None
    if gate_cores is not None:
        ag, bg = tt_half_factors(list(gate_cores), gate_spec)
    pfmt, _ = _precision_fmts(precision, x.dtype)
    if pfmt != "float32":
        a1, b1, a2, b2 = (_quant.cast_format(v, pfmt)
                          for v in (a1, b1, a2, b2))
        if bg is not None:
            ag, bg = (_quant.cast_format(v, pfmt) for v in (ag, bg))
    if f_logical is None:
        f_logical = min(up_spec.out_dim, down_spec.in_dim)

    M, N, F = down_spec.out_dim, up_spec.in_dim, up_spec.out_dim
    R1, R2 = up_spec.mid_rank, down_spec.mid_rank
    Rg = gate_spec.mid_rank if gate_spec is not None else 0
    itemsize = jnp.dtype(x.dtype).itemsize
    if decode_ffn_vmem_fits(M, N, F, R1, R2, Rg, itemsize, B=x.shape[0]):
        return btt_ffn_decode_pallas(x, b1, a1, b2, a2, bg, ag, act=act,
                                     f_logical=f_logical,
                                     interpret=interpret)
    u = btt_linear_decode_pallas(x, b1, a1,
                                 interpret=interpret)[:, :f_logical]
    if bg is not None:
        g = btt_linear_decode_pallas(x, bg, ag,
                                     interpret=interpret)[:, :f_logical]
        h = _FFN_ACTS[act](g) * u
    else:
        h = _FFN_ACTS[act](u)
    if f_logical != down_spec.in_dim:
        h = jnp.pad(h, ((0, 0), (0, down_spec.in_dim - f_logical)))
    return btt_linear_decode_pallas(h, b2, a2, interpret=interpret)


# ---------------------------------------------------------------------------
# TTM embedding (one-hot kernel when eligible).
# ---------------------------------------------------------------------------


def _ttm_kernel_eligible(spec: TTMSpec) -> bool:
    if spec.d != 3:
        return False
    core_bytes = sum(int(np.prod(s)) * 4 for s in spec.core_shapes())
    return core_bytes <= _VMEM_CORE_BUDGET


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _ttm_kernel_fused(cores: tuple, oh: tuple, spec_dims: tuple,
                      interpret: bool) -> jax.Array:
    return ttm_embed_pallas(oh, cores, spec_dims=spec_dims,
                            interpret=interpret)


def _ttm_kernel_fwd(cores, oh, spec_dims, interpret):
    y = ttm_embed_pallas(oh, cores, spec_dims=spec_dims, interpret=interpret)
    return y, (cores, oh)


def _ttm_kernel_bwd(spec_dims, interpret, residuals, gy):
    # Core gradients via autodiff of the pure-jnp one-hot chain — the same
    # stage-A..E math the kernel executes (paper Eq. (12): scatter-free,
    # the one-hot GEMMs transpose into the scatter-add).
    cores, oh = residuals
    from .ref import ttm_embed_ref

    _, vjp = jax.vjp(
        lambda c, o: ttm_embed_ref(o, c).astype(gy.dtype), cores, oh)
    gc, goh = vjp(gy)
    return gc, goh


_ttm_kernel_fused.defvjp(_ttm_kernel_fwd, _ttm_kernel_bwd)


def ttm_embed_op(cores, ids: jax.Array, spec: TTMSpec, *,
                 use_kernel: bool = True,
                 interpret: bool | None = None) -> jax.Array:
    """``ids (...,) int32 -> (..., H)`` TTM lookup."""
    if not use_kernel or not _ttm_kernel_eligible(spec):
        return ttm_lookup(cores, ids, spec)
    if interpret is None:
        interpret = kernel_interpret_default()
    batch_shape = ids.shape
    flat = ids.reshape(-1)
    dg = token_digits(flat, spec.vocab_factors)  # (K, 3)
    oh = tuple(
        jax.nn.one_hot(dg[:, k], spec.vocab_factors[k], dtype=cores[0].dtype)
        for k in range(3)
    )
    rs = spec.ranks
    spec_dims = (tuple(spec.vocab_factors), tuple(spec.hidden_factors),
                 (rs[1], rs[2]))
    out = _ttm_kernel_fused(tuple(cores), oh, spec_dims, interpret)
    return out.reshape(batch_shape + (spec.hidden_dim,))
