"""jit'd public wrappers around the Pallas kernels, with pure-JAX fallbacks.

* ``btt_linear_op(cores, x, spec)`` — the paper's BTT linear executed by the
  fused Pallas forward (``btt_linear.py``) under a custom VJP that implements
  the paper's fused backward (Sec. V-B2): no K-sized intermediate is saved.
  With ``fused_bwd=True`` (default) the whole BWD stage — data gradient AND
  half-factor gradients — runs as ONE Pallas kernel
  (``btt_backward.py``) with the recomputed ``t``/``gt`` intermediates
  resident in VMEM scratch; shapes whose working set exceeds the VMEM
  budget, or ``fused_bwd=False``, take the reference path: ``gx`` through
  the forward kernel by operand swap (``gx = btt(gy, A^T, B^T)``) plus four
  XLA GEMMs for the core gradients (f32 end to end).

* ``ttm_embed_op(cores, ids, spec)`` — gather-free TTM lookup via the d=3
  one-hot kernel; falls back to the jnp gather chain when d != 3 or the cores
  exceed the VMEM residency budget.

Kernel selection: on a TPU backend the compiled kernel runs natively; on CPU
(this container) ``interpret=True`` executes the kernel body in Python — the
correctness path used by every test.  ``use_kernel=False`` forces the pure
JAX path (what the production dry-run lowers, keeping HLO analyzable).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.contraction import tt_forward_btt, ttm_lookup, token_digits
from repro.core.tt import TTMSpec, TTSpec, tt_half_factors

from .btt_backward import btt_backward_pallas, bwd_vmem_fits
from .btt_linear import btt_linear_pallas
from .ttm_embed import ttm_embed_pallas

__all__ = ["btt_linear_op", "ttm_embed_op", "kernel_interpret_default"]

_VMEM_CORE_BUDGET = 8 * 1024 * 1024  # resident-core budget for ttm kernel


def kernel_interpret_default() -> bool:
    """interpret=True everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# BTT linear (kernel-backed, fused custom VJP).
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _btt_kernel_fused(cores: tuple, x: jax.Array, spec: TTSpec,
                      interpret: bool, fused_bwd: bool) -> jax.Array:
    a, b = tt_half_factors(cores, spec)
    return btt_linear_pallas(x, b, a, interpret=interpret)


def _btt_kernel_fwd(cores, x, spec, interpret, fused_bwd):
    a, b = tt_half_factors(cores, spec)
    y = btt_linear_pallas(x, b, a, interpret=interpret)
    return y, (cores, x)  # paper-faithful: only inputs saved, no K-sized state


def _btt_kernel_bwd(spec, interpret, fused_bwd, residuals, gy):
    cores, x = residuals
    d = spec.d

    def build(oc, ic):
        return tt_half_factors(list(oc) + list(ic), spec)

    (a, b), build_vjp = jax.vjp(build, tuple(cores[:d]), tuple(cores[d:]))
    itemsize = jnp.dtype(x.dtype).itemsize
    if fused_bwd and bwd_vmem_fits(spec.out_dim, spec.in_dim, spec.mid_rank,
                                   itemsize, K=x.shape[0]):
        # ONE kernel launch: gx streamed, ga/gb accumulated on chip —
        # t/gt never leave VMEM (paper Eqs. (10)/(11)/(16) as one stage).
        gx, ga, gb = btt_backward_pallas(x, gy, b, a, interpret=interpret)
    else:
        # Reference path: data gradient through the fused FORWARD kernel by
        # operand swap (gx = (gy @ A) @ B = btt(gy; b=A^T, a=B^T)); core
        # gradients as four XLA GEMMs with t/gt kept f32 through the
        # dependent products (same math as btt_backward_ref, minus its
        # kernel-idiom gx GEMM, which the operand-swap launch replaces).
        gx = btt_linear_pallas(gy, a.T, b.T, interpret=interpret)
        t = jnp.dot(x, b.T, preferred_element_type=jnp.float32)
        gt = jnp.dot(gy, a, preferred_element_type=jnp.float32)
        ga = jnp.dot(gy.T.astype(jnp.float32), t,
                     preferred_element_type=jnp.float32)
        gb = jnp.dot(gt.T, x.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    g_out, g_in = build_vjp((ga.astype(a.dtype), gb.astype(b.dtype)))
    return (tuple(g_out) + tuple(g_in), gx)


_btt_kernel_fused.defvjp(_btt_kernel_fwd, _btt_kernel_bwd)


def btt_linear_op(cores, x: jax.Array, spec: TTSpec, *,
                  use_kernel: bool = True,
                  interpret: bool | None = None,
                  fused_bwd: bool = True) -> jax.Array:
    """``x (K, N) -> y (K, M)`` with W in TT format, BTT contraction.

    ``fused_bwd`` selects the single-kernel BWD stage for the gradients
    (falls back automatically when the shape's working set exceeds the
    kernel VMEM budget); ``False`` forces the operand-swap + XLA-GEMM
    reference path.
    """
    if not use_kernel:
        return tt_forward_btt(cores, x, spec)
    if interpret is None:
        interpret = kernel_interpret_default()
    return _btt_kernel_fused(tuple(cores), x, spec, interpret, fused_bwd)


# ---------------------------------------------------------------------------
# TTM embedding (one-hot kernel when eligible).
# ---------------------------------------------------------------------------


def _ttm_kernel_eligible(spec: TTMSpec) -> bool:
    if spec.d != 3:
        return False
    core_bytes = sum(int(np.prod(s)) * 4 for s in spec.core_shapes())
    return core_bytes <= _VMEM_CORE_BUDGET


def ttm_embed_op(cores, ids: jax.Array, spec: TTMSpec, *,
                 use_kernel: bool = True,
                 interpret: bool | None = None) -> jax.Array:
    """``ids (...,) int32 -> (..., H)`` TTM lookup."""
    if not use_kernel or not _ttm_kernel_eligible(spec):
        return ttm_lookup(cores, ids, spec)
    if interpret is None:
        interpret = kernel_interpret_default()
    batch_shape = ids.shape
    flat = ids.reshape(-1)
    dg = token_digits(flat, spec.vocab_factors)  # (K, 3)
    oh = tuple(
        jax.nn.one_hot(dg[:, k], spec.vocab_factors[k], dtype=cores[0].dtype)
        for k in range(3)
    )
    rs = spec.ranks
    spec_dims = (tuple(spec.vocab_factors), tuple(spec.hidden_factors),
                 (rs[1], rs[2]))
    out = ttm_embed_pallas(oh, tuple(cores), spec_dims=spec_dims,
                           interpret=interpret)
    return out.reshape(batch_shape + (spec.hidden_dim,))
