"""Pallas TPU kernels: fused tensorized-FFN megakernel (FWD + BWD).

The FFN block is the widest thing the model computes: its hidden state is
``(K, d_ff)`` — 4x wider than anything attention touches on the usual
``d_ff = 4 d_model`` configs.  Executed as two (three when gated) separate
``btt_linear_op`` calls, that hidden state round-trips HBM twice per layer
in the forward (written by the up projection, re-read by the down
projection) and again in the backward (saved as the down projection's
input residual, re-read by its backward launch) — exactly the off-chip
traffic the paper's intra-layer MUL1/MUL2 pipelining eliminates (Sec. V),
and the FlashAttention-style producer/consumer locality argument applied
to the paper's bidirectional contraction.

This module runs the whole block as ONE ``pallas_call`` per direction:

    y = A2 @ (B2 @ act(A1 @ (B1 @ x)))                       (ungated)
    y = A2 @ (B2 @ (act(Ag @ (Bg @ x)) * A1 @ (B1 @ x)))     (gated)

Tiling (BlockSpec; grid = (K/TK,), one K row-block per grid step):

  x block    (TK, NP)      — streamed from HBM, read ONCE per direction
  y/gx block (TK, MP/NP)   — streamed out, written once
  B1 (R1P, NP), A1 (FP, R1P), B2 (R2P, FP), A2 (MP, R2P)
  [Bg (RgP, NP), Ag (FP, RgP)]
             — every half-factor fully VMEM-resident (constant index map;
               LoRETTA's observation: the low-rank half-factor structure
               is what makes whole-block fusion feasible — A/B are tiny)
  h scratch  (TK, FP)      — the hidden tile.  It NEVER leaves VMEM: the
                             down contraction consumes it in the same grid
                             step that produced it.
  gA*/gB* blocks (f32)     — backward only: constant-index-map output
                             accumulators, flushed to HBM exactly once
                             (the revisiting-accumulator pattern of
                             ``btt_backward.py``).

The backward recomputes the hidden tile (and the gate pre-activation)
from ``x`` inside the kernel, so the block's training residual shrinks
from ``(K, d_ff)`` + gate pre-activations to just ``x`` — O(K·d_model).

Every contraction mirrors the two-call path's exact GEMM + cast sequence
(``btt_linear_pallas`` / ``btt_backward_pallas``), so on unpadded
single-tile shapes the kernel is bit-identical to the two-call reference
(asserted in tests/test_btt_ffn.py).  Shapes whose working set exceeds the
VMEM budget (``ffn_vmem_fits``) fall back to the two-call path in
``ops.py``; ``core.memory_ledger`` gates its FFN rows on the same
predicate, so ledger and dispatch cannot drift.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

from .btt_linear import (
    DEFAULT_TK,
    VMEM_BUDGET,
    _round_up,
    _sublane as _decode_sublane,
    choose_tiles,
)

__all__ = [
    "btt_ffn_pallas",
    "btt_ffn_bwd_pallas",
    "choose_ffn_tiles",
    "ffn_vmem_fits",
    "ffn_stage_vmem_bytes",
    "ffn_residual_bytes",
    "fused_ffn_hbm_bytes",
    "unfused_ffn_hbm_bytes",
    "ffn_flops",
    "btt_ffn_decode_pallas",
    "choose_decode_ffn_tiles",
    "decode_ffn_vmem_fits",
    "decode_ffn_stage_vmem_bytes",
    "fused_decode_ffn_hbm_bytes",
    "unfused_decode_ffn_hbm_bytes",
]

ACTS = {"gelu": jax.nn.gelu, "silu": jax.nn.silu}


# ---------------------------------------------------------------------------
# Tile chooser — the single residency source for kernel, ledger and op gate.
# ---------------------------------------------------------------------------


def choose_ffn_tiles(M: int, N: int, F: int, R1: int, R2: int, Rg: int,
                     itemsize: int, *, tk: int | None = None,
                     K: int | None = None
                     ) -> tuple[int, int, int, int, int, int, int, int, int]:
    """(tk, mp, np, fp, r1p, r2p, rgp, fwd_vmem, bwd_vmem) for the fused FFN.

    ``M``/``N`` are the down/up projections' model dims (both d_model on
    every shipped config), ``F`` the hidden dim, ``R*`` the mid-ranks;
    ``Rg = 0`` means ungated.  Single source of truth for the megakernel's
    residency: both kernels launch with these tiles, ``ffn_vmem_fits``
    gates the op on the (larger) BWD working set, and
    ``core.memory_ledger`` reports the same numbers — the three cannot
    drift (the FWD/BWD/ATTN stages make the identical promise through
    their own choosers).

    ``K`` caps ``tk`` at the sublane-aligned row count actually present
    (paper regime: K=32).  The half-factor blocks and the f32 gradient
    accumulators do not scale with ``tk``, so oversized layers (d_ff in
    the thousands) may never fit — callers gate on :func:`ffn_vmem_fits`
    and fall back to the two-call path.
    """
    tk = tk or DEFAULT_TK
    if K is not None:
        tk = min(tk, _round_up(K, 32))  # 32: every dtype's sublane tile
    mp = _round_up(M, 128)
    np_ = _round_up(N, 128)
    fp = _round_up(F, 128)
    r1p = _round_up(R1, 128)
    r2p = _round_up(R2, 128)
    rgp = _round_up(Rg, 128) if Rg else 0
    n_hidden = 3 if Rg else 2  # h + u (+ g) hidden-width scratch tiles

    # All half-factors resident for the whole launch.
    hf = (r1p * np_ + fp * r1p + r2p * fp + mp * r2p
          + (rgp * np_ + fp * rgp)) * itemsize
    # BWD-only f32 accumulator blocks (constant index maps).
    acc = (fp * r1p + r1p * np_ + mp * r2p + r2p * fp
           + (fp * rgp + rgp * np_)) * 4

    def fwd(tk_):
        return (tk_ * np_ * itemsize + tk_ * mp * itemsize + hf
                + tk_ * fp * itemsize        # h scratch tile
                + tk_ * fp * 4               # f32 hidden temp (pre-cast)
                + tk_ * (r1p + r2p + rgp) * 4)  # rank-width f32 temps

    def bwd(tk_):
        return (2 * tk_ * np_ * itemsize     # x in, gx out
                + tk_ * mp * itemsize        # gy
                + hf + acc
                + n_hidden * tk_ * fp * itemsize   # h/u(/g) scratch tiles
                + 2 * tk_ * fp * 4                 # gh/gu f32 temps
                + 2 * tk_ * (r1p + r2p + rgp) * 4)  # t/gt rank-width temps

    # Shrink toward the 32-row floor keeping every intermediate size
    # 32-aligned (tk starts at a multiple of 32 but is not in general a
    # power of two — plain halving could yield 48- or 24-row blocks,
    # breaking the bf16 sublane tile on a real TPU).
    while tk > 32 and bwd(tk) > VMEM_BUDGET:
        tk = max(32, _round_up(tk // 2, 32))
    return tk, mp, np_, fp, r1p, r2p, rgp, fwd(tk), bwd(tk)


def ffn_vmem_fits(M: int, N: int, F: int, R1: int, R2: int, Rg: int,
                  itemsize: int, K: int | None = None) -> bool:
    """True iff the fused FFN's (BWD, the larger) working set fits VMEM.

    THE dispatch predicate: ``ops.btt_ffn_op`` takes the megakernel path
    iff this holds, and the memory ledger's ffn rows gate on it too.
    """
    tiles = choose_ffn_tiles(M, N, F, R1, R2, Rg, itemsize, K=K)
    return max(tiles[7], tiles[8]) <= VMEM_BUDGET


def ffn_stage_vmem_bytes(M: int, N: int, F: int, R1: int, R2: int, Rg: int,
                         itemsize: int, *, K: int | None = None,
                         stage: str = "FWD", fused: bool = True) -> int:
    """VMEM working set of the FFN-stage megakernel launch, or 0 when the
    block runs the two-call path (``fused=False`` or over budget — there
    the per-linear launches are charged under the existing kernel rows)."""
    if not fused or not ffn_vmem_fits(M, N, F, R1, R2, Rg, itemsize, K=K):
        return 0
    tiles = choose_ffn_tiles(M, N, F, R1, R2, Rg, itemsize, K=K)
    return tiles[7] if stage == "FWD" else tiles[8]


def ffn_residual_bytes(K: int, F: int, itemsize: int, *,
                       gated: bool, fused: bool) -> int:
    """Training residual of ONE FFN block application beyond the saved
    layer input ``x``: the act pre-activations (u, and g when gated) plus
    the down projection's saved input ``h`` on the two-call path; nothing
    with the megakernel (it recomputes the hidden tile from ``x``)."""
    if fused:
        return 0
    n_pre = 2 if gated else 1
    return (n_pre + 1) * K * F * itemsize


# ---------------------------------------------------------------------------
# Kernel bodies.
# ---------------------------------------------------------------------------


def _mask_cols(v: jax.Array, f_logical: int) -> jax.Array:
    """Zero columns >= f_logical (real half-factor rows past the logical
    d_ff — the two-call path slices them away between the calls)."""
    if f_logical >= v.shape[1]:
        return v
    cols = jax.lax.broadcasted_iota(jnp.int32, v.shape, 1)
    return jnp.where(cols < f_logical, v, jnp.zeros_like(v))


def _dot(x, w, dims, out=jnp.float32):
    return jax.lax.dot_general(x, w, dimension_numbers=(dims, ((), ())),
                               preferred_element_type=out)


def _half_linear(x, b, a, out_dtype):
    """One BTT linear exactly as ``btt_linear_pallas`` computes it:
    ``t = x @ b^T`` (f32), ``y = (t cast) @ a^T`` (f32, cast to out)."""
    t = _dot(x, b, ((1,), (1,)))
    y = _dot(t.astype(a.dtype), a, ((1,), (1,)))
    return t, y.astype(out_dtype)


def _hidden(x, b1, a1, bg, ag, act: str, f_logical: int, dt):
    """Recompute the block's hidden tile (and everything needed for its
    VJP) from x: returns (t1, u, tg, g, h) — tg/g None when ungated."""
    t1, u = _half_linear(x, b1, a1, dt)
    if bg is not None:
        tg, g = _half_linear(x, bg, ag, dt)
        h = ACTS[act](g) * u
    else:
        tg = g = None
        h = ACTS[act](u)
    return t1, u, tg, g, _mask_cols(h, f_logical)


def _deq_operands(s_ref, x_ref, factor_refs):
    """Dequantize the FFN operand refs into f32 VMEM values: x at scale
    slot 0, half-factors at their fixed slots [b1, a1, bg, ag, b2, a2] =
    s[1..6] (gate slots unused when ungated).  The low-precision tiles are
    upcast HERE, in VMEM — the dense f32 tensors never exist in HBM."""
    x = x_ref[...].astype(jnp.float32) * s_ref[0, 0]
    facs = [r[...].astype(jnp.float32) * s_ref[0, 1 + i] if r is not None
            else None for i, r in enumerate(factor_refs)]
    return x, facs


def _ffn_fwd_kernel(*refs, act: str, f_logical: int, gated: bool,
                    quant: bool):
    """Grid (nK,); see module docstring for block shapes."""
    if quant:
        s_ref, *refs = refs
    if gated:
        x_ref, b1_ref, a1_ref, bg_ref, ag_ref, b2_ref, a2_ref, \
            y_ref, h_ref = refs
    else:
        x_ref, b1_ref, a1_ref, b2_ref, a2_ref, y_ref, h_ref = refs
        bg_ref = ag_ref = None

    dt = y_ref.dtype
    if quant:
        x, (b1, a1, bg, ag, b2, a2) = _deq_operands(
            s_ref, x_ref, (b1_ref, a1_ref, bg_ref, ag_ref, b2_ref, a2_ref))
    else:
        x, b1, a1, b2, a2 = (x_ref[...], b1_ref[...], a1_ref[...],
                             b2_ref[...], a2_ref[...])
        bg = bg_ref[...] if gated else None
        ag = ag_ref[...] if gated else None
    _, _, _, _, h = _hidden(x, b1, a1, bg, ag, act, f_logical, dt)
    h_ref[...] = h  # VMEM scratch: produced and consumed in this grid step
    _, y = _half_linear(h_ref[...], b2, a2, y_ref.dtype)
    y_ref[...] = y


def _ffn_bwd_kernel(*refs, act: str, f_logical: int, gated: bool,
                    quant: bool):
    """Grid (nK,): recompute the hidden tile from x, then run the whole
    block's VJP with ga/gb accumulated in VMEM-resident f32 blocks.  In
    quant mode operands dequantize at entry and the gradients are those
    of the dequantized operands (straight-through)."""
    if quant:
        s_ref, *refs = refs
    if gated:
        (x_ref, gy_ref, b1_ref, a1_ref, bg_ref, ag_ref, b2_ref, a2_ref,
         gx_ref, ga1_ref, gb1_ref, gag_ref, gbg_ref, ga2_ref, gb2_ref,
         h_ref, u_ref, g_ref) = refs
    else:
        (x_ref, gy_ref, b1_ref, a1_ref, b2_ref, a2_ref,
         gx_ref, ga1_ref, gb1_ref, ga2_ref, gb2_ref,
         h_ref, u_ref) = refs
        bg_ref = ag_ref = gag_ref = gbg_ref = g_ref = None

    k = pl.program_id(0)

    @pl.when(k == 0)
    def _zero_accumulators():
        for r in (ga1_ref, gb1_ref, ga2_ref, gb2_ref, gag_ref, gbg_ref):
            if r is not None:
                r[...] = jnp.zeros_like(r)

    dt = gx_ref.dtype
    if quant:
        x, (b1, a1, bg, ag, b2, a2) = _deq_operands(
            s_ref, x_ref, (b1_ref, a1_ref, bg_ref, ag_ref, b2_ref, a2_ref))
    else:
        x, b1, a1, b2, a2 = (x_ref[...], b1_ref[...], a1_ref[...],
                             b2_ref[...], a2_ref[...])
        bg = bg_ref[...] if gated else None
        ag = ag_ref[...] if gated else None
    gy = gy_ref[...]

    # Recompute the forward up to the hidden tile (paper-style: residuals
    # are x only; the hidden state never existed in HBM to reload).
    t1, u, tg, g, h = _hidden(x, b1, a1, bg, ag, act, f_logical, dt)
    h_ref[...] = h
    u_ref[...] = u
    if gated:
        g_ref[...] = g

    # Down-projection backward (btt_backward's exact contraction set with
    # x := h): t2 recomputed, gh streamed to the act VJP, ga2/gb2
    # accumulated f32.
    t2 = _dot(h_ref[...], b2, ((1,), (1,)))
    gt2 = _dot(gy, a2, ((1,), (0,)))
    gh = _dot(gt2.astype(b2.dtype), b2, ((1,), (0,))).astype(dt)
    ga2_ref[...] += _dot(gy.astype(jnp.float32), t2, ((0,), (0,)))
    gb2_ref[...] += _dot(gt2, h_ref[...].astype(jnp.float32), ((0,), (0,)))

    # Activation VJP — autodiff of the exact expression the two-call path
    # differentiates, on the recomputed pre-activations.
    if gated:
        _, act_vjp = jax.vjp(lambda gg, uu: ACTS[act](gg) * uu,
                             g_ref[...], u_ref[...])
        gg_, gu = act_vjp(gh)
        gg_ = _mask_cols(gg_, f_logical)
    else:
        _, act_vjp = jax.vjp(ACTS[act], u_ref[...])
        (gu,) = act_vjp(gh)
        gg_ = None
    gu = _mask_cols(gu, f_logical)

    # Up (and gate) projection backward; gx summed across branches in the
    # storage dtype, as autodiff sums the two x-cotangents.
    gt1 = _dot(gu, a1, ((1,), (0,)))
    gx = _dot(gt1.astype(b1.dtype), b1, ((1,), (0,))).astype(dt)
    ga1_ref[...] += _dot(gu.astype(jnp.float32), t1, ((0,), (0,)))
    gb1_ref[...] += _dot(gt1, x.astype(jnp.float32), ((0,), (0,)))
    if gated:
        gtg = _dot(gg_, ag, ((1,), (0,)))
        gx = gx + _dot(gtg.astype(bg.dtype), bg, ((1,), (0,))).astype(dt)
        gag_ref[...] += _dot(gg_.astype(jnp.float32), tg, ((0,), (0,)))
        gbg_ref[...] += _dot(gtg, x.astype(jnp.float32), ((0,), (0,)))
    gx_ref[...] = gx


# ---------------------------------------------------------------------------
# Launch wrappers.
# ---------------------------------------------------------------------------


def _pad2(v, r, c):
    return jnp.pad(v, ((0, r - v.shape[0]), (0, c - v.shape[1])))


def _dims(x, gy, b1, a1, b2, a2, bg):
    K, N = x.shape
    R1, _ = b1.shape
    F, _ = a1.shape
    R2, _ = b2.shape
    M, _ = a2.shape
    Rg = bg.shape[0] if bg is not None else 0
    return K, N, F, M, R1, R2, Rg


def _ffn_itemsize(x, factors) -> int:
    return max(jnp.dtype(v.dtype).itemsize
               for v in (x, *[f for f in factors if f is not None]))


@functools.partial(jax.jit, static_argnames=("act", "f_logical", "tk",
                                             "interpret", "out_dtype"))
def btt_ffn_pallas(x: jax.Array, b1: jax.Array, a1: jax.Array,
                   b2: jax.Array, a2: jax.Array,
                   bg: jax.Array | None = None, ag: jax.Array | None = None,
                   *, act: str = "gelu", f_logical: int | None = None,
                   scales: jax.Array | None = None, out_dtype=None,
                   tk: int | None = None,
                   interpret: bool = False) -> jax.Array:
    """Fused FFN forward: ``x (K, N) -> y (K, M)`` through both (three when
    ``bg``/``ag`` given) TT half-factor pairs and the activation, with the
    ``(TK, F)`` hidden tile living only in VMEM scratch.

    ``f_logical`` is the logical d_ff (< F when ``factorize`` padded the
    hidden dim): hidden columns past it are zeroed, exactly what the
    two-call path's slice-then-repad does.  Padding to hardware tiles is
    exact for every contraction here (``act(0) = 0`` for gelu/silu, so
    padded hidden columns contribute nothing through the zero-padded B2).

    ``scales`` (a (1, 8) f32 ``[s_x, s_b1, s_a1, s_bg, s_ag, s_b2, s_a2,
    pad]``) switches to the quantized-operand kernel: operands stream in
    storage dtypes and dequantize at kernel entry in VMEM; ``out_dtype``
    then names the compute dtype of ``y`` and the hidden scratch.
    """
    gated = bg is not None
    K, N, F, M, R1, R2, Rg = _dims(x, None, b1, a1, b2, a2, bg)
    if f_logical is None:
        f_logical = F
    out_dtype = out_dtype or x.dtype
    itemsize = _ffn_itemsize(x, (b1, a1, b2, a2, bg, ag))
    tk, mp, np_, fp, r1p, r2p, rgp, _, _ = choose_ffn_tiles(
        M, N, F, R1, R2, Rg, itemsize, tk=tk, K=K)

    kp = _round_up(K, tk)
    xp = jnp.pad(x, ((0, kp - K), (0, np_ - N)))
    ops_ = [xp, _pad2(b1, r1p, np_), _pad2(a1, fp, r1p)]
    in_specs = [
        pl.BlockSpec((tk, np_), lambda k: (k, 0)),   # x
        pl.BlockSpec((r1p, np_), lambda k: (0, 0)),  # b1 (resident)
        pl.BlockSpec((fp, r1p), lambda k: (0, 0)),   # a1 (resident)
    ]
    if gated:
        ops_ += [_pad2(bg, rgp, np_), _pad2(ag, fp, rgp)]
        in_specs += [
            pl.BlockSpec((rgp, np_), lambda k: (0, 0)),  # bg (resident)
            pl.BlockSpec((fp, rgp), lambda k: (0, 0)),   # ag (resident)
        ]
    ops_ += [_pad2(b2, r2p, fp), _pad2(a2, mp, r2p)]
    in_specs += [
        pl.BlockSpec((r2p, fp), lambda k: (0, 0)),   # b2 (resident)
        pl.BlockSpec((mp, r2p), lambda k: (0, 0)),   # a2 (resident)
    ]
    if scales is not None:
        ops_ = [scales.astype(jnp.float32).reshape(1, 8)] + ops_
        in_specs = [pl.BlockSpec((1, 8), lambda k: (0, 0),
                                 memory_space=pltpu.SMEM)] + in_specs

    y = pl.pallas_call(
        functools.partial(_ffn_fwd_kernel, act=act, f_logical=f_logical,
                          gated=gated, quant=scales is not None),
        grid=(kp // tk,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tk, mp), lambda k: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((kp, mp), out_dtype),
        scratch_shapes=[pltpu.VMEM((tk, fp), out_dtype)],  # the hidden tile
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(*ops_)
    return y[:K, :M]


@functools.partial(jax.jit, static_argnames=("act", "f_logical", "tk",
                                             "interpret", "out_dtype"))
def btt_ffn_bwd_pallas(x: jax.Array, gy: jax.Array, b1: jax.Array,
                       a1: jax.Array, b2: jax.Array, a2: jax.Array,
                       bg: jax.Array | None = None,
                       ag: jax.Array | None = None, *, act: str = "gelu",
                       f_logical: int | None = None,
                       scales: jax.Array | None = None, out_dtype=None,
                       tk: int | None = None,
                       interpret: bool = False) -> tuple:
    """Fused FFN backward from ``x`` and ``gy`` ONLY (the hidden tile and
    gate pre-activation are recomputed in VMEM): returns
    ``(gx, ga1, gb1, ga2, gb2)`` — plus ``(gag, gbg)`` appended when gated
    — with all half-factor gradients accumulated and returned in f32 (the
    final cast to the core dtype happens once, in ``ops.py``).

    ``scales``/``out_dtype`` as in :func:`btt_ffn_pallas`: quantized
    operands dequantize at kernel entry and the gradients returned are
    those of the dequantized operands (straight-through)."""
    gated = bg is not None
    K, N, F, M, R1, R2, Rg = _dims(x, gy, b1, a1, b2, a2, bg)
    if f_logical is None:
        f_logical = F
    out_dtype = out_dtype or x.dtype
    itemsize = _ffn_itemsize(x, (gy, b1, a1, b2, a2, bg, ag))
    tk, mp, np_, fp, r1p, r2p, rgp, _, _ = choose_ffn_tiles(
        M, N, F, R1, R2, Rg, itemsize, tk=tk, K=K)

    kp = _round_up(K, tk)
    ops_ = [jnp.pad(x, ((0, kp - K), (0, np_ - N))),
            jnp.pad(gy, ((0, kp - K), (0, mp - M))),
            _pad2(b1, r1p, np_), _pad2(a1, fp, r1p)]
    in_specs = [
        pl.BlockSpec((tk, np_), lambda k: (k, 0)),   # x
        pl.BlockSpec((tk, mp), lambda k: (k, 0)),    # gy
        pl.BlockSpec((r1p, np_), lambda k: (0, 0)),  # b1 (resident)
        pl.BlockSpec((fp, r1p), lambda k: (0, 0)),   # a1 (resident)
    ]
    if gated:
        ops_ += [_pad2(bg, rgp, np_), _pad2(ag, fp, rgp)]
        in_specs += [
            pl.BlockSpec((rgp, np_), lambda k: (0, 0)),
            pl.BlockSpec((fp, rgp), lambda k: (0, 0)),
        ]
    ops_ += [_pad2(b2, r2p, fp), _pad2(a2, mp, r2p)]
    in_specs += [
        pl.BlockSpec((r2p, fp), lambda k: (0, 0)),
        pl.BlockSpec((mp, r2p), lambda k: (0, 0)),
    ]

    if scales is not None:
        ops_ = [scales.astype(jnp.float32).reshape(1, 8)] + ops_
        in_specs = [pl.BlockSpec((1, 8), lambda k: (0, 0),
                                 memory_space=pltpu.SMEM)] + in_specs

    out_specs = [
        pl.BlockSpec((tk, np_), lambda k: (k, 0)),   # gx (streamed)
        pl.BlockSpec((fp, r1p), lambda k: (0, 0)),   # ga1 (accumulator)
        pl.BlockSpec((r1p, np_), lambda k: (0, 0)),  # gb1 (accumulator)
    ]
    out_shape = [
        jax.ShapeDtypeStruct((kp, np_), out_dtype),
        jax.ShapeDtypeStruct((fp, r1p), jnp.float32),
        jax.ShapeDtypeStruct((r1p, np_), jnp.float32),
    ]
    if gated:
        out_specs += [
            pl.BlockSpec((fp, rgp), lambda k: (0, 0)),   # gag
            pl.BlockSpec((rgp, np_), lambda k: (0, 0)),  # gbg
        ]
        out_shape += [
            jax.ShapeDtypeStruct((fp, rgp), jnp.float32),
            jax.ShapeDtypeStruct((rgp, np_), jnp.float32),
        ]
    out_specs += [
        pl.BlockSpec((mp, r2p), lambda k: (0, 0)),   # ga2
        pl.BlockSpec((r2p, fp), lambda k: (0, 0)),   # gb2
    ]
    out_shape += [
        jax.ShapeDtypeStruct((mp, r2p), jnp.float32),
        jax.ShapeDtypeStruct((r2p, fp), jnp.float32),
    ]

    scratch = [pltpu.VMEM((tk, fp), out_dtype),   # h
               pltpu.VMEM((tk, fp), out_dtype)]   # u
    if gated:
        scratch.append(pltpu.VMEM((tk, fp), out_dtype))  # g

    outs = pl.pallas_call(
        functools.partial(_ffn_bwd_kernel, act=act, f_logical=f_logical,
                          gated=gated, quant=scales is not None),
        grid=(kp // tk,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        # The K axis carries accumulation state (ga/gb revisit every step).
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(*ops_)

    if gated:
        gx, ga1, gb1, gag, gbg, ga2, gb2 = outs
        return (gx[:K, :N], ga1[:F, :R1], gb1[:R1, :N],
                ga2[:M, :R2], gb2[:R2, :F], gag[:F, :Rg], gbg[:Rg, :N])
    gx, ga1, gb1, ga2, gb2 = outs
    return (gx[:K, :N], ga1[:F, :R1], gb1[:R1, :N],
            ga2[:M, :R2], gb2[:R2, :F])


# ---------------------------------------------------------------------------
# Analytic HBM-traffic / FLOP models (shared by benchmarks and tests).
# ---------------------------------------------------------------------------


def ffn_flops(K: int, M: int, N: int, F: int, R1: int, R2: int,
              Rg: int = 0) -> int:
    """MACs x2 of the block's GEMMs, forward + backward (activation VPU
    work excluded — identical on both paths)."""
    from .btt_backward import bwd_flops

    fwd = 2 * K * (R1 * (N + F) + R2 * (F + M) + Rg * (N + F))
    bwd = bwd_flops(K, F, N, R1) + bwd_flops(K, M, F, R2)
    if Rg:
        bwd += bwd_flops(K, F, N, Rg)
    return fwd + bwd


def _hf_elems(np_, mp, fp, r1p, r2p, rgp):
    return (r1p * np_ + fp * r1p + r2p * fp + mp * r2p
            + rgp * np_ + fp * rgp)


def fused_ffn_hbm_bytes(K: int, M: int, N: int, F: int, R1: int, R2: int,
                        Rg: int, itemsize: int) -> int:
    """HBM bytes of one fused fwd + one fused bwd launch (tile-derived).

    Reads: x once per direction, gy once, every half-factor once per
    launch (constant index maps — Pallas fetches a revisited block once).
    Writes: y, gx, and the single end-of-grid flush of the f32 gradient
    accumulators.  The hidden state appears on NEITHER side — it never
    exists in HBM.  Counts are over padded dims (padded bytes are real
    bytes on the wire).
    """
    tk, mp, np_, fp, r1p, r2p, rgp, _, _ = choose_ffn_tiles(
        M, N, F, R1, R2, Rg, itemsize, K=K)
    kp = _round_up(K, tk)
    hf = _hf_elems(np_, mp, fp, r1p, r2p, rgp)
    fwd = (kp * np_ + hf) * itemsize + kp * mp * itemsize
    bwd = ((kp * np_ + kp * mp + hf) * itemsize   # x, gy, half-factors
           + kp * np_ * itemsize                   # gx
           + hf * 4)                               # f32 grad flush
    return fwd + bwd


def _fwd_launch_bytes(K: int, M: int, N: int, R: int, itemsize: int) -> int:
    """HBM traffic of one ``btt_linear_pallas`` launch (its own tiles):
    x streamed once, the b operand refetched per K row-block, a fetched
    once, y written once."""
    tkf, tnf, mp, rp, _ = choose_tiles(M, R, itemsize, K=K)
    np_ = _round_up(N, tnf)
    kpf = _round_up(K, tkf)
    n_k = kpf // tkf
    return (kpf * np_ + n_k * rp * np_ + mp * rp + kpf * mp) * itemsize


def unfused_ffn_hbm_bytes(K: int, M: int, N: int, F: int, R1: int, R2: int,
                          Rg: int, itemsize: int) -> int:
    """HBM bytes of the two-call (three-call when gated) path, fwd + bwd.

    Generous to the unfused side: its backward launches are the per-linear
    FUSED ``btt_backward`` kernels (the best case short of this module),
    and every activation tensor moves exactly once per use.  What remains
    is the traffic whole-block fusion exists to delete: the ``(K, F)``
    hidden state and pre-activations streaming between the up/act/down
    launches in the forward and into the act VJP in the backward.
    """
    from .btt_backward import fused_bwd_hbm_bytes

    k8 = _round_up(K, 8)
    fp = _round_up(F, 128)
    n_pre = 2 if Rg else 1
    gemms_fwd = (_fwd_launch_bytes(K, F, N, R1, itemsize)
                 + _fwd_launch_bytes(K, M, F, R2, itemsize))
    gemms_bwd = (fused_bwd_hbm_bytes(K, F, N, R1, itemsize)
                 + fused_bwd_hbm_bytes(K, M, F, R2, itemsize))
    if Rg:
        gemms_fwd += _fwd_launch_bytes(K, F, N, Rg, itemsize)
        gemms_bwd += fused_bwd_hbm_bytes(K, F, N, Rg, itemsize)
    # act fwd: read the pre-activation(s), write h; act bwd: read gh and
    # the saved pre-activation(s), write the upstream cotangent(s).
    act_fwd = (n_pre + 1) * k8 * fp * itemsize
    act_bwd = (1 + 2 * n_pre) * k8 * fp * itemsize
    return gemms_fwd + act_fwd + gemms_bwd + act_bwd


# ---------------------------------------------------------------------------
# Decode specialization: one token per stream, half-factors pinned.
# ---------------------------------------------------------------------------
#
# Serving runs the megakernel forward-only with K = the number of live
# decode streams.  Two things change vs training: row tiles pad to the
# dtype's true sublane granule (f32 8) instead of the every-dtype 32, and
# the six half-factors — identical across steps — are VMEM-pinned, so
# their HBM fetch amortizes over the whole decode run (``steps`` in the
# byte model).  The kernel body is btt_ffn_pallas's own, so fused-decode
# FFN output is bit-identical to the training forward at equal shapes.


def choose_decode_ffn_tiles(M: int, N: int, F: int, R1: int, R2: int,
                            Rg: int, itemsize: int, *, B: int
                            ) -> tuple[int, int, int, int, int, int, int,
                                       int]:
    """(tk, mp, np, fp, r1p, r2p, rgp, vmem_bytes) for a forward-only
    decode launch of the FFN megakernel: ``tk`` = live streams padded to
    the dtype sublane tile; nothing shrinks (the half-factor residency is
    the floor — callers gate on :func:`decode_ffn_vmem_fits`).

    Same contract as :func:`choose_ffn_tiles`: decode kernel launch,
    ``ops`` dispatch gate and ledger DECODE rows all read these numbers.
    """
    tk = _round_up(B, _decode_sublane(itemsize))
    mp = _round_up(M, 128)
    np_ = _round_up(N, 128)
    fp = _round_up(F, 128)
    r1p = _round_up(R1, 128)
    r2p = _round_up(R2, 128)
    rgp = _round_up(Rg, 128) if Rg else 0
    hf = (r1p * np_ + fp * r1p + r2p * fp + mp * r2p
          + (rgp * np_ + fp * rgp)) * itemsize
    vmem = (tk * np_ * itemsize + tk * mp * itemsize + hf
            + tk * fp * itemsize + tk * fp * 4
            + tk * (r1p + r2p + rgp) * 4)
    return tk, mp, np_, fp, r1p, r2p, rgp, vmem


def decode_ffn_vmem_fits(M: int, N: int, F: int, R1: int, R2: int, Rg: int,
                         itemsize: int, *, B: int,
                         budget: int | None = None) -> bool:
    """THE decode-FFN dispatch predicate (mirrors ``ffn_vmem_fits``)."""
    budget = budget or VMEM_BUDGET
    return choose_decode_ffn_tiles(M, N, F, R1, R2, Rg, itemsize,
                                   B=B)[7] <= budget


def decode_ffn_stage_vmem_bytes(M: int, N: int, F: int, R1: int, R2: int,
                                Rg: int, itemsize: int, *, B: int,
                                fused: bool = True,
                                budget: int | None = None) -> int:
    if not fused or not decode_ffn_vmem_fits(M, N, F, R1, R2, Rg, itemsize,
                                             B=B, budget=budget):
        return 0
    return choose_decode_ffn_tiles(M, N, F, R1, R2, Rg, itemsize, B=B)[7]


def btt_ffn_decode_pallas(x: jax.Array, b1: jax.Array, a1: jax.Array,
                          b2: jax.Array, a2: jax.Array,
                          bg: jax.Array | None = None,
                          ag: jax.Array | None = None, *,
                          act: str = "gelu", f_logical: int | None = None,
                          interpret: bool = False) -> jax.Array:
    """Decode-shape FFN megakernel launch (same body, sublane row tiles)."""
    itemsize = jnp.dtype(x.dtype).itemsize
    tk = _round_up(x.shape[0], _decode_sublane(itemsize))
    return btt_ffn_pallas(x, b1, a1, b2, a2, bg, ag, act=act,
                          f_logical=f_logical, tk=tk, interpret=interpret)


def fused_decode_ffn_hbm_bytes(B: int, M: int, N: int, F: int, R1: int,
                               R2: int, Rg: int, itemsize: int, *,
                               steps: int = 1) -> int:
    """HBM bytes ONE decode step of the FFN megakernel moves: the (tk, N)
    activation row in, the (tk, M) row out, half-factor fetches amortized
    over ``steps`` pinned steps.  The (tk, F) hidden tile moves nothing."""
    tk, mp, np_, fp, r1p, r2p, rgp, _ = choose_decode_ffn_tiles(
        M, N, F, R1, R2, Rg, itemsize, B=B)
    io = (tk * np_ + tk * mp) * itemsize
    hf = _hf_elems(np_, mp, fp, r1p, r2p, rgp) * itemsize
    return io + -(-hf // steps)


def unfused_decode_ffn_hbm_bytes(B: int, M: int, N: int, F: int, R1: int,
                                 R2: int, Rg: int, itemsize: int) -> int:
    """HBM bytes of the two-call decode forward: per-linear launches at the
    training 32-row granule (half-factors re-fetched every step — XLA pins
    nothing across dispatches), the ``(B, F)`` hidden state round-tripping
    HBM between the up/act/down launches."""
    k8 = _round_up(B, 8)
    fp = _round_up(F, 128)
    n_pre = 2 if Rg else 1
    gemms = (_fwd_launch_bytes(B, F, N, R1, itemsize)
             + _fwd_launch_bytes(B, M, F, R2, itemsize))
    if Rg:
        gemms += _fwd_launch_bytes(B, F, N, Rg, itemsize)
    act_io = (n_pre + 1) * k8 * fp * itemsize
    return gemms + act_io
