"""Optimizers over parameter pytrees (TT cores included — the paper's PU
stage updates cores directly, Sec. III-A step 3).

Functional, pure-pytree design: optimizer state mirrors the parameter tree
leaf-for-leaf so the sharding rules for parameters apply verbatim to the
state (runtime.sharding reuses the same specs).  SGD is the paper-faithful
optimizer; AdamW is the at-scale default for the assigned architectures.

``fused=True`` routes the update through the Pallas fused-PU kernels
(``kernels.fused_update``): flattened grads, params, and moments are tiled
through VMEM once per kernel launch with bias correction and weight decay
computed in-kernel, instead of the ~10-HLO-per-leaf XLA graph the pure
path lowers to (leaves are packed into / unpacked from the flat layout by
ordinary XLA ops around the kernel — see the module docstring there for
the exact aliasing semantics).  State layout, init, and numerics (all math
in f32, params cast back to storage dtype) are identical between the two
paths, so ``fused`` can be toggled without invalidating checkpoints or
sharding specs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgd", "adamw", "master_view",
           "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    # update(grads, params, state, step) -> (new_params, new_state)
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]


def _tree_cast_like(tree, ref):
    return jax.tree.map(lambda x, r: x.astype(r.dtype), tree, ref)


def _scaled_lr(lr_fn, step, state):
    """Effective lr for this step, honoring the guard's ``lr_scale`` leaf.

    ``runtime.guard.TrainGuard.attach`` adds a () f32 ``lr_scale`` to the
    optimizer state; the anomaly-escalation policy backs it off and
    recovers it WITHOUT retracing the jitted step (the schedule closure
    ``lr_fn`` is baked into the compiled update — a state leaf is the only
    knob that can move per-step).  States without the leaf are untouched:
    the multiply never appears in the lowered graph."""
    lr_t = lr_fn(step)
    if isinstance(state, dict) and "lr_scale" in state:
        lr_t = lr_t * state["lr_scale"]
    return lr_t


def _carry_guard(state, new_state):
    """Propagate guard-owned leaves (``lr_scale``) into the fresh state
    dict every update path constructs — optimizer math never writes them,
    but dropping them would change the state pytree structure mid-run."""
    if isinstance(state, dict) and "lr_scale" in state:
        new_state["lr_scale"] = state["lr_scale"]
    return new_state


def sgd(lr: float | Callable[[jax.Array], jax.Array], momentum: float = 0.0,
        *, fused: bool = False, interpret: bool | None = None) -> Optimizer:
    """SGD(+momentum).  ``fused=True`` runs the PU stage as one Pallas kernel
    pass over the flattened parameter buffers (``kernels.fused_update``);
    ``interpret`` follows the kernel default (interpret off-TPU)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
        }

    def update(grads, params, state, step):
        lr_t = _scaled_lr(lr_fn, step, state)
        if fused:
            from repro.kernels.fused_update import fused_sgd_update
            if momentum == 0.0:
                new_params = fused_sgd_update(
                    params, grads, lr_t, interpret=interpret)
                return new_params, _carry_guard(
                    state, {"step": state["step"] + 1})
            new_params, mu = fused_sgd_update(
                params, grads, lr_t, momentum=momentum, mu=state["mu"],
                interpret=interpret)
            return new_params, _carry_guard(
                state, {"step": state["step"] + 1, "mu": mu})
        if momentum == 0.0:
            new_params = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32) - lr_t * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_params, _carry_guard(
                state, {"step": state["step"] + 1})
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr_t * m).astype(p.dtype), params, mu)
        return new_params, _carry_guard(
            state, {"step": state["step"] + 1, "mu": mu})

    return Optimizer("sgd", init, update)


def master_view(state, params):
    """Dequantized view of a quantized-master optimizer state.

    With ``adamw(param_format="int8" | "fp8_e4m3")`` the ONLY copy of the
    parameters lives in the state's packed ``(pq, ps)`` buffers; the tree
    the forward/backward stages consume is this dequantized view.  Call
    after ``init`` so step 1's forward already sees the storage-grid
    values (identity for unquantized states)."""
    if not (isinstance(state, dict) and "pq" in state):
        return params
    from repro.kernels.fused_update import quant_master_unpack
    leaves, treedef = jax.tree.flatten(params)
    views = quant_master_unpack(state["pq"], state["ps"],
                                [x.shape for x in leaves],
                                [x.dtype for x in leaves])
    return jax.tree.unflatten(treedef, views)


def adamw(lr: float | Callable[[jax.Array], jax.Array], b1: float = 0.9,
          b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, *, fused: bool = False,
          sketched: bool = False, sketch_width: int | None = None,
          sketch_depth: int | None = None,
          param_format: str = "float32",
          interpret: bool | None = None) -> Optimizer:
    """AdamW.  ``fused=True`` performs moment EMAs, bias correction, weight
    decay, and the parameter delta in one Pallas kernel pass per step
    (``kernels.fused_update``) — each optimizer buffer is read and written
    exactly once.

    ``sketched=True`` (implies fused) replaces the two dense moment
    buffers with (sketch_depth, sketch_width) hash sketches — a count-min
    sketch for ``v`` and a count-sketch for ``m`` — refreshed and queried
    inside the same kernel, so the dense moments never exist in HBM
    (Count-Sketch Optimizers).  The decision is taken at ``init`` via
    ``sketch_pu_fits`` — the identical predicate ``core.memory_ledger``
    charges from — and is visible in the state layout: sketched state is
    ``{"step", "vs", "ms"}``; when the sketch does not fit (or saves <4x)
    init falls back to dense fused AdamW state ``{"step", "m", "v"}`` and
    ``update`` dispatches on the layout, so checkpoints stay
    self-describing.

    ``param_format`` in {"int8", "fp8_e4m3"} (``fused`` implied) keeps the
    MASTER parameters quantized in the packed PU layout — state gains
    ``{"pq", "ps"}`` and the f32 parameter tree never exists in HBM; each
    step the fused kernel dequantizes a block into VMEM, applies the
    (optionally sketched) AdamW math in f32, and stochastically re-rounds
    (``kernels.fused_update``).  ``update`` then returns the dequantized
    view tree for the next forward; use :func:`master_view` after ``init``
    so step 1 sees the same storage grid.  Moments stay f32 (dense packed
    ``mb``/``vb`` buffers) or sketched — the quantization round-off is
    confined to the parameter write, where stochastic rounding keeps it
    zero-mean."""
    lr_fn = lr if callable(lr) else (lambda _: lr)
    from repro.core.quant import needs_scale
    quant_master = needs_scale(param_format)

    def init(params):
        if quant_master:
            from repro.kernels.fused_update import (
                SKETCH_DEPTH_DEFAULT, default_sketch_width, pack_leaves,
                pu_block_shape, quant_master_pack, sketch_pu_fits)
            from repro.core.quant import itemsize as q_itemsize
            leaves = jax.tree.leaves(params)
            n = sum(int(jnp.size(p)) for p in leaves)
            _, rows_p, lanes = pu_block_shape(n)
            pq, ps = quant_master_pack(leaves, param_format)
            state = {"step": jnp.zeros((), jnp.int32), "pq": pq, "ps": ps}
            if sketched:
                depth = (SKETCH_DEPTH_DEFAULT if sketch_depth is None
                         else sketch_depth)
                width = (default_sketch_width(n, depth)
                         if sketch_width is None else sketch_width)
                if sketch_pu_fits(n, width, depth,
                                  itemsize=q_itemsize(param_format)):
                    state["vs"] = jnp.zeros((depth, width), jnp.float32)
                    state["ms"] = jnp.zeros((depth, width), jnp.float32)
                    return state
            # Two distinct allocations: donation rejects one buffer bound
            # to two jitted-step arguments.
            state["mb"] = jnp.zeros((rows_p, lanes), jnp.float32)
            state["vb"] = jnp.zeros((rows_p, lanes), jnp.float32)
            return state
        if sketched:
            from repro.kernels.fused_update import (
                SKETCH_DEPTH_DEFAULT, default_sketch_width, sketch_pu_fits)
            n = sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
            depth = SKETCH_DEPTH_DEFAULT if sketch_depth is None else sketch_depth
            width = (default_sketch_width(n, depth) if sketch_width is None
                     else sketch_width)
            itemsize = max(jnp.dtype(p.dtype).itemsize
                           for p in jax.tree.leaves(params))
            if sketch_pu_fits(n, width, depth, itemsize=itemsize):
                return {
                    "step": jnp.zeros((), jnp.int32),
                    "vs": jnp.zeros((depth, width), jnp.float32),
                    "ms": jnp.zeros((depth, width), jnp.float32),
                }
            # fallback: dense fused AdamW state (sketch would not fit VMEM
            # or would not shrink the footprint enough to pay for itself)
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, params, state, step):
        lr_t = _scaled_lr(lr_fn, step, state)
        t = (state["step"] + 1).astype(jnp.float32)
        if "pq" in state:
            from repro.kernels.fused_update import (
                fused_adamw_update_quant, pack_leaves, pu_block_shape,
                quant_master_unpack, sketched_adamw_update_quant)
            p_leaves, treedef = jax.tree.flatten(params)
            g_leaves = treedef.flatten_up_to(grads)
            n = sum(int(jnp.size(p)) for p in p_leaves)
            _, rows_p, lanes = pu_block_shape(n)
            gb = pack_leaves(g_leaves, jnp.float32, rows_p, lanes)
            new_state = {"step": state["step"] + 1}
            if "vs" in state:
                pq, ps, vs, ms = sketched_adamw_update_quant(
                    state["pq"], state["ps"], state["vs"], state["ms"],
                    gb, n, lr_t, t, fmt=param_format, b1=b1, b2=b2,
                    eps=eps, weight_decay=weight_decay,
                    interpret=interpret)
                new_state.update(pq=pq, ps=ps, vs=vs, ms=ms)
            else:
                pq, ps, mb, vb = fused_adamw_update_quant(
                    state["pq"], state["ps"], state["mb"], state["vb"],
                    gb, lr_t, t, fmt=param_format, b1=b1, b2=b2, eps=eps,
                    weight_decay=weight_decay, interpret=interpret)
                new_state.update(pq=pq, ps=ps, mb=mb, vb=vb)
            views = quant_master_unpack(pq, ps,
                                        [x.shape for x in p_leaves],
                                        [x.dtype for x in p_leaves])
            return (jax.tree.unflatten(treedef, views),
                    _carry_guard(state, new_state))
        if "vs" in state:
            from repro.kernels.fused_update import sketched_adamw_update
            new_params, vs, ms = sketched_adamw_update(
                params, grads, state["vs"], state["ms"], lr_t, t,
                b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
                interpret=interpret)
            return new_params, _carry_guard(
                state, {"step": state["step"] + 1, "vs": vs, "ms": ms})
        if fused or sketched:
            from repro.kernels.fused_update import fused_adamw_update
            new_params, m, v = fused_adamw_update(
                params, grads, state["m"], state["v"], lr_t, t,
                b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
                interpret=interpret)
            return new_params, _carry_guard(
                state, {"step": state["step"] + 1, "m": m, "v": v})
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)

        def upd(p, m_, v_):
            step_ = lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                step_ = step_ + lr_t * weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step_).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, _carry_guard(
            state, {"step": state["step"] + 1, "m": m, "v": v})

    return Optimizer("adamw", init, update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn
