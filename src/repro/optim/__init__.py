from .optimizers import (Optimizer, adamw, clip_by_global_norm,
                         master_view, sgd)
from .schedule import constant, warmup_cosine

__all__ = ["Optimizer", "sgd", "adamw", "master_view",
           "clip_by_global_norm", "constant", "warmup_cosine"]
