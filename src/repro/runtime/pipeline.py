"""GPipe-style pipeline parallelism over layer stages, shard_map-native.

The paper pipelines MUL1/MUL2 *within* a layer on one chip (Sec. V);
FTRANS-style multi-chip scale-out pipelines *between* layers.  This module
composes that inter-layer pipeline with the repo's fused kernels: the mesh
carries ("stage", "data", "model") axes, every device holds the FULL
replicated parameter tree (TT compression makes it MBs — replication is the
paper's technique acting as a distributed-training optimization), and each
device runs only its stage's contiguous slice of the layer stack on its
("data" × "model") row shard of each microbatch.

Schedule (GPipe fill/drain as ONE ``jax.lax.scan`` over ticks):

    T = M + S - 1 ticks; at tick t, stage s computes microbatch i = t - s
    (ticks outside [0, M) are bubble ticks — computed uniformly for SPMD,
    masked out of the loss so they contribute no gradient).  Stage 0
    substitutes the fresh embedding of microbatch i; other stages consume
    the activation handed off by ``ppermute`` from stage s-1 at t-1.

"model" here is row-wise tensor parallelism: activations shard on their
leading batch dim, TT cores stay replicated, so the fused FFN/attention/BWD
Pallas kernels launch unchanged on local shapes — inside the shard_map body
every shape is already per-device, which is exactly what the VMEM dispatch
predicates (``ffn_vmem_fits``/``attn_bwd_vmem_fits``/``bwd_vmem_fits``)
evaluate.  Gradients ``psum`` over all three axes; the loss is the global
mask-weighted mean, so one optimizer step per device keeps params
replicated bit-for-bit.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.models.transformer import (
    _embed_inputs,
    block_apply,
    lm_head,
    token_nll,
)

__all__ = [
    "PIPELINE_AXES",
    "StagePartition",
    "bubble_fraction",
    "cycles_per_stage",
    "make_pipeline_mesh",
    "pipeline_loss_and_grads",
    "stage_utilization",
]

PIPELINE_AXES = ("stage", "data", "model")


@dataclasses.dataclass(frozen=True)
class StagePartition:
    """Static shape of one multi-device training partition.

    stages × dp × tp must equal the mesh's device count; ``microbatches``
    is the GPipe schedule depth M (per-device batch rows split M ways).
    """

    stages: int = 1
    dp: int = 1
    tp: int = 1
    microbatches: int = 1

    def __post_init__(self):
        for name in ("stages", "dp", "tp", "microbatches"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1, got "
                                 f"{getattr(self, name)}")

    @property
    def devices(self) -> int:
        return self.stages * self.dp * self.tp

    @property
    def ticks(self) -> int:
        """Schedule length M + S - 1 (fill + steady + drain)."""
        return self.microbatches + self.stages - 1

    @classmethod
    def from_mesh(cls, mesh, microbatches: int = 1) -> "StagePartition":
        shape = dict(mesh.shape)
        return cls(stages=shape.get("stage", 1), dp=shape.get("data", 1),
                   tp=shape.get("model", 1), microbatches=microbatches)


def bubble_fraction(part: StagePartition) -> float:
    """Idle fraction of the GPipe schedule: (S-1) / (M+S-1)."""
    return (part.stages - 1) / part.ticks


def stage_utilization(part: StagePartition) -> float:
    """Busy-tick fraction per stage: M / (M+S-1) (uniform across stages)."""
    return part.microbatches / part.ticks


def cycles_per_stage(cfg: ModelConfig, stages: int) -> int:
    """Contiguous layer-cycles per pipeline stage; raises on bad splits.

    The scanned stack is organized in cycles of ``len(hybrid_pattern)``
    layers; a stage boundary inside a cycle (or a tail of unrolled layers)
    would break the uniform per-stage compute the ppermute schedule needs.
    """
    pat = len(cfg.hybrid_pattern)
    n_cycles, rem = divmod(cfg.num_layers, pat)
    if rem:
        raise ValueError(
            f"pipeline stages need tail-free configs: num_layers="
            f"{cfg.num_layers} is not a multiple of the {pat}-block "
            f"hybrid pattern")
    if stages < 1 or n_cycles == 0 or n_cycles % stages:
        raise ValueError(
            f"{n_cycles} layer cycle(s) do not split into {stages} "
            f"contiguous stage(s)")
    return n_cycles // stages


def make_pipeline_mesh(part: StagePartition):
    """(stage, data, model) mesh for ``part`` over the available devices."""
    return jax.make_mesh((part.stages, part.dp, part.tp), PIPELINE_AXES)


def pipeline_loss_and_grads(params, cfg: ModelConfig, batch: dict,
                            part: StagePartition, *, remat: bool = True):
    """One device's slice of the GPipe step.  CALL INSIDE shard_map.

    ``batch`` leaves are this device's (dp × tp) row shard, shape
    ``(B_loc, S)``; ``params`` is the full replicated tree.  Returns
    ``(loss, grads)`` where loss is the global mask-weighted mean NLL and
    grads are f32 and already psum'd over ("stage", "data", "model") —
    identical on every device, so the caller's optimizer step keeps the
    replicated params in lockstep.

    Every psum sits OUTSIDE ``value_and_grad``: the differentiated
    function returns this device's nll contribution over the global mask
    denominator (a param-independent constant), and the psum afterwards
    reassembles both the scalar loss and the full gradient — the same
    layout ``launch.steps.make_ddp_train_step`` uses.  The only collective
    autodiff sees is the ppermute handoff, whose transpose is exact (the
    reversed ring carries activation cotangents back up the pipeline —
    GPipe's backward schedule falls out of the scan transpose for free).
    """
    cps = cycles_per_stage(cfg, part.stages)
    if cfg.frontend == "patch":
        raise NotImplementedError(
            "pipeline training does not support the patch frontend")
    pat = cfg.hybrid_pattern
    M, S_ = part.microbatches, part.stages
    stage = jax.lax.axis_index("stage")
    dt = jnp.dtype(cfg.dtype)

    if batch["tokens"].shape[0] % M:
        raise ValueError(
            f"per-device batch {batch['tokens'].shape[0]} rows do not "
            f"split into {M} microbatches")

    def split(x):
        return x.reshape((M, x.shape[0] // M) + x.shape[1:])

    mb = {k: split(v) for k, v in batch.items()}
    b_mb, seq = mb["tokens"].shape[1], mb["tokens"].shape[2]

    # Global token-weight denominator: a param-independent constant.  The
    # batch shard is replicated across "stage" (only "data"/"model" split
    # rows), so the global sum crosses those two axes only.
    if "mask" in batch:
        m_local = batch["mask"].astype(jnp.float32).sum()
    else:
        m_local = jnp.asarray(float(batch["tokens"].size), jnp.float32)
    m_global = jnp.maximum(jax.lax.psum(m_local, ("data", "model")), 1.0)

    def loss_of(p):
        # This stage's contiguous cycle slice.  dynamic_slice (traced
        # start = stage * cps) transposes to a zero-padded scatter under
        # AD, so other stages' slices get exact zero gradients — the
        # cross-stage psum then reassembles the full layer gradient.
        local_layers = jax.tree.map(
            lambda leaf: jax.lax.dynamic_slice_in_dim(
                leaf, stage * cps, cps, axis=0),
            p["layers"])

        def cycle_fn(hh, layer_params):
            for i, kind in enumerate(pat):
                hh, _ = block_apply(kind, layer_params[i], hh, cfg,
                                    cache=None, mode="train", pos=0)
            return hh, None

        cyc = jax.checkpoint(cycle_fn) if remat else cycle_fn

        def tick(carry, t):
            h_in, nll_acc = carry
            i_mb = t - stage
            valid = (i_mb >= 0) & (i_mb < M)
            idx = jnp.clip(i_mb, 0, M - 1)
            tok = jax.lax.dynamic_index_in_dim(mb["tokens"], idx, 0,
                                               keepdims=False)
            # Every stage embeds uniformly (SPMD: one program, the where
            # selects); only stage 0's embedding is live, and bubble-tick
            # garbage never reaches the loss, so it backpropagates nothing.
            emb = _embed_inputs(p, cfg, tok, None, 0).astype(dt)
            x = jnp.where(stage == 0, emb, h_in)
            y, _ = jax.lax.scan(cyc, x, local_layers)

            hn = rms_norm(y, p["final_norm"], cfg.norm_eps)
            logits = lm_head(p, cfg, hn)
            lbl = jax.lax.dynamic_index_in_dim(mb["labels"], idx, 0,
                                               keepdims=False)
            nll = token_nll(logits, lbl)
            if "mask" in mb:
                mk = jax.lax.dynamic_index_in_dim(
                    mb["mask"], idx, 0, keepdims=False).astype(jnp.float32)
            else:
                mk = jnp.ones(nll.shape, jnp.float32)
            take = (valid & (stage == S_ - 1)).astype(jnp.float32)
            nll_acc = nll_acc + take * jnp.sum(nll * mk)

            if S_ > 1:
                h_out = jax.lax.ppermute(
                    y, "stage", [(s, s + 1) for s in range(S_ - 1)])
            else:
                h_out = y
            return (h_out, nll_acc), None

        h0 = jnp.zeros((b_mb, seq, cfg.d_model), dt)
        (_, nll_sum), _ = jax.lax.scan(
            tick, (h0, jnp.zeros((), jnp.float32)),
            jnp.arange(M + S_ - 1))
        # This device's contribution to the global loss (nonzero only on
        # the last stage); psum'd below, outside autodiff.
        return nll_sum / m_global

    loss, grads = jax.value_and_grad(loss_of)(params)
    loss = jax.lax.psum(loss, PIPELINE_AXES)
    grads = jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.float32), PIPELINE_AXES), grads)
    return loss, grads
