"""Continuous-batching scheduler for decode serving.

Pure policy, no model: the serve loop (``launch/serve.py``) owns the
engine; this module decides WHO runs WHERE and WHEN.  The shape of the
loop is the standard continuous-batching one:

  1. ``admit()``       — FIFO-admit waiting requests into free decode
                         slots, gated by the engine's admission check
                         (enough free KV pages for the prompt).  Each
                         admission is prefilled SOLO before joining the
                         decode batch — prefill/decode disaggregation: a
                         long prompt never stalls the running streams'
                         steady decode cadence inside a mixed batch.
  2. engine decode     — ONE batched step over every running slot.
  3. ``observe()``     — per slot: record the sampled token; retire the
                         request on EOS or its token budget (``finished``)
                         or evict it when the engine ran out of pages
                         (``evicted``) — each admitted request leaves
                         exactly once (conservation, property-tested).

Fairness under oversubscription is FIFO by arrival: a request is never
overtaken by a later one at admission time, and a retired slot is refilled
from the queue head on the next ``admit()`` — no slot starves while work
waits (asserted over random arrival/EOS traces in
``tests/test_scheduler.py``).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable

__all__ = ["Request", "Scheduler"]

WAITING, RUNNING, FINISHED, EVICTED = ("waiting", "running", "finished",
                                       "evicted")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    eos_id: int | None = None
    state: str = WAITING
    slot: int | None = None
    out: list[int] = dataclasses.field(default_factory=list)
    arrived_step: int = 0
    admitted_step: int | None = None
    done_step: int | None = None


class Scheduler:
    """Slot assignment + request lifecycle for one serve loop."""

    def __init__(self, max_concurrency: int):
        if max_concurrency < 1:
            raise ValueError("max_concurrency must be >= 1")
        self.max_concurrency = max_concurrency
        self.slots: list[Request | None] = [None] * max_concurrency
        self.waiting: deque[Request] = deque()
        self.retired: list[Request] = []
        self.step = 0

    # -- intake ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        req.state = WAITING
        req.arrived_step = self.step
        self.waiting.append(req)

    def submit_all(self, reqs: Iterable[Request]) -> None:
        for r in reqs:
            self.submit(r)

    # -- loop protocol ---------------------------------------------------

    def has_work(self) -> bool:
        return bool(self.waiting) or any(r is not None for r in self.slots)

    def running(self) -> list[Request]:
        return [r for r in self.slots if r is not None]

    def admit(self, can_admit=None) -> list[Request]:
        """Move queue-head requests into free slots, in arrival order.

        ``can_admit(req) -> bool`` is the engine's admission gate (page
        availability).  Admission stops at the first refused request —
        skipping it for a cheaper later one would un-FIFO the queue and
        can starve a long prompt forever.
        """
        admitted = []
        for slot in range(self.max_concurrency):
            if self.slots[slot] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            if can_admit is not None and not can_admit(req):
                break
            self.waiting.popleft()
            req.state = RUNNING
            req.slot = slot
            req.admitted_step = self.step
            self.slots[slot] = req
            admitted.append(req)
        return admitted

    def observe(self, slot: int, token: int) -> Request | None:
        """Record one decoded token for the request in ``slot``; retire it
        on EOS or budget.  Returns the request iff it just retired (its
        slot is then free for the next ``admit()``)."""
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"observe on empty slot {slot}")
        req.out.append(token)
        done = (len(req.out) >= req.max_new
                or (req.eos_id is not None and token == req.eos_id))
        if done:
            return self._retire(slot, FINISHED)
        return None

    def evict(self, slot: int) -> Request:
        """Forcibly retire (engine out of pages, shutdown, ...)."""
        return self._retire(slot, EVICTED)

    def _retire(self, slot: int, state: str) -> Request:
        req = self.slots[slot]
        self.slots[slot] = None
        req.state = state
        req.slot = None
        req.done_step = self.step
        self.retired.append(req)
        return req

    def end_step(self) -> None:
        self.step += 1

    # -- reporting -------------------------------------------------------

    def report(self) -> dict:
        fin = [r for r in self.retired if r.state == FINISHED]
        ev = [r for r in self.retired if r.state == EVICTED]
        waits = [r.admitted_step - r.arrived_step for r in self.retired
                 if r.admitted_step is not None]
        return {
            "steps": self.step,
            "finished": len(fin),
            "evicted": len(ev),
            "tokens_out": sum(len(r.out) for r in self.retired),
            "max_wait_steps": max(waits) if waits else 0,
            "still_waiting": len(self.waiting),
        }
