"""Continuous-batching scheduler for decode serving.

Pure policy, no model: the serve loop (``launch/serve.py``) owns the
engine; this module decides WHO runs WHERE and WHEN.  The shape of the
loop is the standard continuous-batching one:

  1. ``expire()``      — retire requests past their deadline (TTL):
                         waiting ones drop out of the queue, running ones
                         are timeout-evicted (the loop releases their
                         engine slot).  Overload degrades to bounded
                         latency, not unbounded queueing.
  2. ``admit()``       — FIFO-admit waiting requests into free decode
                         slots, gated by the engine's admission check
                         (enough free KV pages for the prompt).  Each
                         admission is prefilled SOLO before joining the
                         decode batch — prefill/decode disaggregation: a
                         long prompt never stalls the running streams'
                         steady decode cadence inside a mixed batch.
  3. engine decode     — ONE batched step over every running slot.
  4. ``observe()``     — per slot: record the sampled token; retire the
                         request on EOS or its token budget (``finished``)
                         or evict it when the engine ran out of pages
                         (``evicted``) — each admitted request leaves
                         exactly once (conservation, property-tested).

Intake is load-shed at the door: with ``max_queue`` set, a ``submit``
that would overflow the waiting queue retires the request immediately as
``shed`` (``submit`` returns False) — the overload signal callers turn
into backpressure, instead of a queue that grows until every request
times out.

Fairness under oversubscription is FIFO by arrival: a request is never
overtaken by a later one at admission time, and a retired slot is refilled
from the queue head on the next ``admit()`` — no slot starves while work
waits (asserted over random arrival/EOS/timeout/shed traces in
``tests/test_scheduler.py``).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable

__all__ = ["Request", "Scheduler",
           "WAITING", "RUNNING", "FINISHED", "EVICTED", "TIMEOUT", "SHED"]

WAITING, RUNNING, FINISHED, EVICTED = ("waiting", "running", "finished",
                                       "evicted")
TIMEOUT, SHED = "timeout", "shed"

#: States a retired request can carry (each request reaches exactly one).
TERMINAL_STATES = (FINISHED, EVICTED, TIMEOUT, SHED)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    eos_id: int | None = None
    deadline_steps: int | None = None   # per-request TTL; None = scheduler's
    state: str = WAITING
    slot: int | None = None
    out: list[int] = dataclasses.field(default_factory=list)
    arrived_step: int = 0
    admitted_step: int | None = None
    done_step: int | None = None


class Scheduler:
    """Slot assignment + request lifecycle for one serve loop.

    ``max_queue`` bounds the waiting queue (None = unbounded);
    ``default_deadline`` is the TTL in scheduler steps for requests that
    do not set ``deadline_steps`` themselves (None = no deadline).
    """

    def __init__(self, max_concurrency: int, *, max_queue: int | None = None,
                 default_deadline: int | None = None):
        if max_concurrency < 1:
            raise ValueError("max_concurrency must be >= 1")
        if max_queue is not None and max_queue < 0:
            raise ValueError("max_queue must be >= 0")
        if default_deadline is not None and default_deadline < 1:
            raise ValueError("default_deadline must be >= 1")
        self.max_concurrency = max_concurrency
        self.max_queue = max_queue
        self.default_deadline = default_deadline
        self.slots: list[Request | None] = [None] * max_concurrency
        self.waiting: deque[Request] = deque()
        self.retired: list[Request] = []
        self.step = 0

    # -- intake ----------------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Enqueue ``req``; returns False when the bounded queue is full
        and the request was shed instead (it still appears in ``retired``
        with state ``shed`` — conservation holds for shed work too)."""
        req.arrived_step = self.step
        if self.max_queue is not None and len(self.waiting) >= self.max_queue:
            req.state = SHED
            req.done_step = self.step
            self.retired.append(req)
            return False
        req.state = WAITING
        self.waiting.append(req)
        return True

    def submit_all(self, reqs: Iterable[Request]) -> int:
        """Submit each; returns how many were accepted (not shed)."""
        return sum(self.submit(r) for r in reqs)

    # -- loop protocol ---------------------------------------------------

    def has_work(self) -> bool:
        return bool(self.waiting) or any(r is not None for r in self.slots)

    def running(self) -> list[Request]:
        return [r for r in self.slots if r is not None]

    def _deadline(self, req: Request) -> int | None:
        return (req.deadline_steps if req.deadline_steps is not None
                else self.default_deadline)

    def _expired(self, req: Request) -> bool:
        d = self._deadline(req)
        return d is not None and self.step - req.arrived_step >= d

    def expire(self) -> list[tuple[Request, int | None]]:
        """Retire every request past its deadline; call at the top of each
        loop iteration.  Returns ``(request, freed_slot)`` pairs — the
        slot is an int for running requests (the caller MUST release the
        engine's resources for it) and None for ones that timed out while
        still waiting."""
        out: list[tuple[Request, int | None]] = []
        for slot, req in enumerate(self.slots):
            if req is not None and self._expired(req):
                self._retire(slot, TIMEOUT)
                out.append((req, slot))
        if self.waiting and any(self._expired(r) for r in self.waiting):
            keep: deque[Request] = deque()
            for req in self.waiting:
                if self._expired(req):
                    req.state = TIMEOUT
                    req.done_step = self.step
                    self.retired.append(req)
                    out.append((req, None))
                else:
                    keep.append(req)
            self.waiting = keep
        return out

    def admit(self, can_admit=None) -> list[Request]:
        """Move queue-head requests into free slots, in arrival order.

        ``can_admit(req) -> bool`` is the engine's admission gate (page
        availability).  Admission stops at the first refused request —
        skipping it for a cheaper later one would un-FIFO the queue and
        can starve a long prompt forever.
        """
        admitted = []
        for slot in range(self.max_concurrency):
            if self.slots[slot] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            if can_admit is not None and not can_admit(req):
                break
            self.waiting.popleft()
            req.state = RUNNING
            req.slot = slot
            req.admitted_step = self.step
            self.slots[slot] = req
            admitted.append(req)
        return admitted

    def observe(self, slot: int, token: int) -> Request | None:
        """Record one decoded token for the request in ``slot``; retire it
        on EOS or budget.  Returns the request iff it just retired (its
        slot is then free for the next ``admit()``)."""
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"observe on empty slot {slot}")
        req.out.append(token)
        done = (len(req.out) >= req.max_new
                or (req.eos_id is not None and token == req.eos_id))
        if done:
            return self._retire(slot, FINISHED)
        return None

    def evict(self, slot: int) -> Request:
        """Forcibly retire (engine out of pages, poisoned logits,
        shutdown, ...).  Raises ValueError on an empty slot."""
        return self._retire(slot, EVICTED)

    def _retire(self, slot: int, state: str) -> Request:
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"retire ({state}) on empty slot {slot}")
        self.slots[slot] = None
        req.state = state
        req.slot = None
        req.done_step = self.step
        self.retired.append(req)
        return req

    def end_step(self) -> None:
        self.step += 1

    # -- reporting -------------------------------------------------------

    def report(self) -> dict:
        by_state = {s: sum(1 for r in self.retired if r.state == s)
                    for s in TERMINAL_STATES}
        waits = [r.admitted_step - r.arrived_step for r in self.retired
                 if r.admitted_step is not None]
        return {
            "steps": self.step,
            "finished": by_state[FINISHED],
            "evicted": by_state[EVICTED],
            "timed_out": by_state[TIMEOUT],
            "shed": by_state[SHED],
            "tokens_out": sum(len(r.out) for r in self.retired),
            "max_wait_steps": max(waits) if waits else 0,
            "still_waiting": len(self.waiting),
        }
