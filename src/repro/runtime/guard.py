"""Numerics sentry + escalation policy for unattended training.

The paper's setting is single-batch training on an edge device with nobody
watching: an fp8 overflow, a loss spike, or a corrupted gradient must be
absorbed by the loop itself, not by an operator restarting the job.  This
module is that loop armor, split across the jit boundary:

**Inside the jitted step** (:func:`apply_guarded_update`): ONE fused
reduction — the f32 sum-of-squares over the (tier-cast) gradient tree —
serves simultaneously as

  * the global grad norm (the reported metric and the clip denominator;
    no second reduction),
  * the all-finite probe: NaN/Inf anywhere in the tree propagates into
    the scalar, so ``isfinite(gnorm) & isfinite(loss)`` covers every leaf
    with zero per-leaf host sync,
  * the skip-step mask: the optimizer update runs unconditionally, then a
    ``jnp.where(ok, new, old)`` select on params AND the full optimizer
    state discards it when the probe fails — moments, sketches
    (``vs``/``ms``), quantized masters (``pq``/``ps``) and the step
    counter all stay exactly at their pre-step values, for every state
    layout, without the builder knowing which layout it got.

It also computes the quant-saturation sentinel: for a scaled grad tier
(fp8_e5m2) the per-tensor max-abs scale means nothing ever clips at qmax —
the real hazard is the dual, an outlier inflating the scale until the
bulk of the tensor UNDERFLOWS to zero (``core.quant.lost_fraction``).
Both the fp8 and bf16 casts are computed and selected by a control scalar
(``grad_bf16``), so the host can escalate the tier mid-run without a
retrace.

**On the host** (:class:`TrainGuard`): an EWMA loss/grad-norm anomaly
detector (two ``StragglerMonitor`` instances — the same statistics shape
that flags slow steps flags spiky ones) driving the escalation ladder

    skip-step  ->  lr backoff  ->  rollback to last-good state

Nonfinite steps are true skips (masked in-jit, detected from the metrics
after the fact); finite spikes are flagged one step late, which is what
the lr backoff (an ``lr_scale`` leaf in the optimizer state — see
``optim.optimizers._scaled_lr``) and, after K consecutive bad steps, the
rollback to the last in-memory good snapshot (or the newest VALID on-disk
checkpoint, ``checkpoint.restore_latest_valid``) are for.

The chaos harness (``runtime.chaos``) injects faults through the same
``ctrl`` dict this module consumes, so every path here has a
deterministic, reproducible test (tests/test_robustness.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.runtime.straggler import StragglerMonitor

__all__ = ["GuardPolicy", "TrainGuard", "guard_controls",
           "apply_guarded_update", "make_guarded_step"]

# Actions TrainGuard.observe reports (one per step, most severe wins).
OK, SKIP, BACKOFF, ROLLBACK = "ok", "skip", "backoff", "rollback"


def guard_controls(*, fault_add: float = 0.0, grad_bf16: bool = False,
                   guard_on: bool = True) -> dict:
    """The per-step control scalars the guarded step consumes.

    All three are () device arrays, NOT Python values, so flipping them
    never retraces the jitted step:

    * ``fault_add``  — chaos-injection term added to one gradient element
      (0.0 in production; NaN/Inf/1e28 under ``runtime.chaos``).
    * ``grad_bf16``  — grad-tier escalation: select the bf16 round-trip
      instead of the configured fp8 tier.
    * ``guard_on``   — False disables the skip-step mask (the unguarded
      baseline the robustness tests diverge on purpose).
    """
    return {
        "fault_add": jnp.asarray(fault_add, jnp.float32),
        "grad_bf16": jnp.asarray(grad_bf16, jnp.bool_),
        "guard_on": jnp.asarray(guard_on, jnp.bool_),
    }


def apply_guarded_update(opt, loss, grads, params, opt_state, ctrl, *,
                         grad_fmt: str = "float32", clip_norm: float = 1.0):
    """Shared guarded tail of a training step (runs inside jit).

    ``(loss, grads)`` are this step's raw f32 loss/gradients; ``ctrl`` is
    a :func:`guard_controls` dict.  Applies, in order: chaos fault
    injection, the grad-tier round-trip (+ escalation select + saturation
    sentinel), the single fused norm/finite reduction, global-norm
    clipping, ``opt.update``, and the skip-step select.  Returns
    ``(params, opt_state, metrics)`` with metrics
    ``{loss, grad_norm, nonfinite, sat_frac, applied}``.
    """
    from repro.core import quant

    if grad_fmt == "int8":
        raise ValueError("grad_dtype='int8' is unsupported: gradient "
                         "dynamic range collapses under a per-tensor "
                         "scale; use 'bfloat16' or 'fp8_e5m2'")

    # Chaos injection: additive into ONE element of the first leaf.
    # Additive (not multiplicative) on purpose — a scaled tier rescales a
    # uniform multiply away, but a single huge outlier is exactly the
    # shape that blows up a per-tensor max-abs scale.
    leaves, tdef = jax.tree.flatten(grads)
    first = leaves[0].reshape(-1)
    first = first.at[0].add(ctrl["fault_add"].astype(first.dtype))
    leaves[0] = first.reshape(leaves[0].shape)
    grads = jax.tree.unflatten(tdef, leaves)

    # Grad tier: both casts live in the graph; grad_bf16 selects at run
    # time (elementwise where on a () predicate — no retrace, no branch).
    if grad_fmt == "float32":
        sat_frac = jnp.float32(0.0)
    elif quant.needs_scale(grad_fmt):
        lo = jax.tree.map(lambda g: quant.cast_format(g, grad_fmt), grads)
        hi = jax.tree.map(lambda g: quant.cast_format(g, "bfloat16"), grads)
        fracs = [quant.lost_fraction(g, l) for g, l in
                 zip(jax.tree.leaves(grads), jax.tree.leaves(lo))]
        sat_frac = jnp.max(jnp.stack(fracs))
        esc = ctrl["grad_bf16"]
        grads = jax.tree.map(lambda l, h: jnp.where(esc, h, l), lo, hi)
    else:  # bfloat16: cast-only round trip, nothing to escalate to
        sat_frac = jnp.float32(0.0)
        grads = jax.tree.map(lambda g: quant.cast_format(g, grad_fmt), grads)

    # ONE reduction: grad norm == finite probe == clip denominator.
    sumsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(sumsq)
    finite = jnp.isfinite(gnorm) & jnp.isfinite(loss)
    ok = finite | jnp.logical_not(ctrl["guard_on"])

    if clip_norm:
        cscale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(
            lambda g: (g.astype(jnp.float32) * cscale).astype(g.dtype),
            grads)

    new_params, new_state = opt.update(grads, params, opt_state,
                                       opt_state["step"])
    # Skip-step: masked select on params AND the full state tree.  Old
    # and new leaves agree in shape/dtype for every layout (dense m/v,
    # sketched vs/ms, quantized pq/ps, lr_scale), so one tree.map keeps
    # the whole optimizer consistent on a skipped step — including NOT
    # advancing the bias-correction step counter.
    sel = lambda n, o: jnp.where(ok, n, o)
    params = jax.tree.map(sel, new_params, params)
    opt_state = jax.tree.map(sel, new_state, opt_state)
    metrics = {
        "loss": loss,
        "grad_norm": gnorm,
        "nonfinite": 1.0 - finite.astype(jnp.float32),
        "sat_frac": sat_frac,
        "applied": ok.astype(jnp.float32),
    }
    return params, opt_state, metrics


def make_guarded_step(loss_of: Callable[[Any, Any], jax.Array], opt, *,
                      grad_fmt: str = "float32", clip_norm: float = 1.0):
    """Generic guarded step over any ``loss_of(params, batch)`` scalar loss:
    ``(params, opt_state, batch, ctrl) -> (params, opt_state, metrics)``.
    The model-config-aware equivalent lives in ``launch.steps``
    (``make_train_step(..., guard=True)``); this builder is for tests,
    benchmarks, and custom losses (e.g. the ATIS task head)."""

    def step(params, opt_state, batch, ctrl):
        loss, grads = jax.value_and_grad(loss_of)(params, batch)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return apply_guarded_update(opt, loss, grads, params, opt_state,
                                    ctrl, grad_fmt=grad_fmt,
                                    clip_norm=clip_norm)

    return step


@dataclasses.dataclass(frozen=True)
class GuardPolicy:
    """Escalation-ladder knobs (host side; all thresholds in steps)."""

    spike_z: float = 4.0        # EWMA z-score that flags a loss/gnorm spike
    alpha: float = 0.05         # EWMA weight (StragglerMonitor)
    warmup: int = 8             # samples before spike flagging starts
    backoff_after: int = 2      # consecutive bad steps -> lr backoff
    backoff_factor: float = 0.5
    min_lr_scale: float = 1.0 / 16.0
    recover_after: int = 20     # consecutive good steps -> one recovery step
    recover_factor: float = 2.0
    rollback_after: int = 4     # consecutive bad steps -> rollback
    snapshot_every: int = 20    # good steps between in-memory snapshots
    sat_threshold: float = 0.25  # grad-tier underflow fraction that counts
    sat_after: int = 2          # consecutive saturated steps -> bf16 tier


class TrainGuard:
    """Host-side controller around a guarded train step.

    Wiring (see ``launch.train`` for the full loop)::

        guard = TrainGuard(policy, manager=mgr, template=tmpl)
        opt_state = guard.attach(opt_state)          # adds lr_scale leaf
        step = jax.jit(make_train_step(cfg, opt, guard=True))
        for i in range(steps):
            p, s, metrics = step(p, s, batch, guard.controls())
            p, s, action = guard.observe(i, metrics, p, s)

    ``observe`` syncs the four metric scalars to host (the same sync the
    loop's loss print already pays), updates the EWMA monitors, and walks
    the ladder.  Rollback prefers the in-memory last-good snapshot and
    falls back to the newest checkpoint that passes CRC verification.
    """

    def __init__(self, policy: GuardPolicy | None = None, *,
                 manager=None, template=None):
        self.policy = policy or GuardPolicy()
        p = self.policy
        mon = lambda: StragglerMonitor(alpha=p.alpha, z_threshold=p.spike_z,
                                       warmup=p.warmup,
                                       escalate_after=10**9)
        self.loss_mon = mon()
        self.gnorm_mon = mon()
        self.manager = manager
        self.template = template
        self.lr_scale = 1.0
        self.grad_bf16 = False
        self.consecutive_bad = 0
        self.good_run = 0
        self.sat_run = 0
        self._last_good: tuple[int, Any, Any] | None = None
        self.counters = {"skipped": 0, "flagged": 0, "backoffs": 0,
                         "recoveries": 0, "rollbacks": 0, "escalations": 0,
                         "snapshots": 0}

    # -- jit-side plumbing ------------------------------------------------

    def attach(self, opt_state: dict) -> dict:
        """Add the guard's ``lr_scale`` leaf to a fresh optimizer state
        (and to the eval_shape template — checkpoints include it)."""
        state = dict(opt_state)
        state["lr_scale"] = jnp.asarray(self.lr_scale, jnp.float32)
        return state

    def controls(self, *, fault_add: float = 0.0) -> dict:
        """This step's control scalars (chaos passes ``fault_add``)."""
        return guard_controls(fault_add=fault_add, grad_bf16=self.grad_bf16,
                              guard_on=True)

    def _set_lr_scale(self, opt_state):
        state = dict(opt_state)
        state["lr_scale"] = jnp.asarray(self.lr_scale, jnp.float32)
        return state

    # -- the ladder -------------------------------------------------------

    def observe(self, step: int, metrics: dict, params, opt_state):
        """Digest one step's metrics; returns (params, opt_state, action).

        ``action`` is one of ``"ok" | "skip" | "backoff" | "rollback"``.
        params/opt_state pass through unchanged except on rollback.
        """
        pol = self.policy
        nonfinite = float(metrics["nonfinite"]) > 0.0
        sat = float(metrics["sat_frac"])

        # Saturation sentinel: independent of the bad-step ladder.  The
        # tier cast is destroying the gradient signal even though every
        # value is finite — escalate to bf16 before training stalls.
        if not self.grad_bf16 and sat >= pol.sat_threshold:
            self.sat_run += 1
            if self.sat_run >= pol.sat_after:
                self.grad_bf16 = True
                self.counters["escalations"] += 1
        else:
            self.sat_run = 0

        if nonfinite:
            bad = True
            self.counters["skipped"] += 1  # in-jit mask already held state
        else:
            # Feed ONLY finite samples to the EWMA stats — a NaN would
            # poison the mean and disarm the detector permanently.
            spike = self.loss_mon.observe(float(metrics["loss"]))
            spike |= self.gnorm_mon.observe(float(metrics["grad_norm"]))
            bad = spike
            if spike:
                self.counters["flagged"] += 1

        if bad:
            self.consecutive_bad += 1
            self.good_run = 0
            action = SKIP
            if self.consecutive_bad >= pol.rollback_after:
                params, opt_state = self._rollback(params, opt_state)
                self.consecutive_bad = 0
                action = ROLLBACK
            elif self.consecutive_bad >= pol.backoff_after:
                if self.lr_scale > pol.min_lr_scale:
                    self.lr_scale = max(self.lr_scale * pol.backoff_factor,
                                        pol.min_lr_scale)
                    self.counters["backoffs"] += 1
                    opt_state = self._set_lr_scale(opt_state)
                action = BACKOFF
            return params, opt_state, action

        self.consecutive_bad = 0
        self.good_run += 1
        if self.lr_scale < 1.0 and self.good_run % pol.recover_after == 0:
            self.lr_scale = min(1.0, self.lr_scale * pol.recover_factor)
            self.counters["recoveries"] += 1
            opt_state = self._set_lr_scale(opt_state)
        if self._last_good is None or self.good_run % pol.snapshot_every == 0:
            self._snapshot(step, params, opt_state)
        return params, opt_state, OK

    def _snapshot(self, step: int, params, opt_state) -> None:
        # Host copies (device_get materializes fresh numpy), so donation
        # and in-place device updates can never corrupt the snapshot.
        self._last_good = (step, jax.device_get(params),
                           jax.device_get(opt_state))
        self.counters["snapshots"] += 1

    def _rollback(self, params, opt_state):
        self.counters["rollbacks"] += 1
        restored = None
        if self._last_good is not None:
            _, p_h, s_h = self._last_good
            restored = (p_h, s_h)
        elif self.manager is not None and self.template is not None:
            from repro.checkpoint import restore_latest_valid
            got = restore_latest_valid(self.manager.root, self.template)
            if got is not None:
                (tree, _step), _skipped = got
                restored = tree  # template is the (params, opt_state) pair
        if restored is None:
            # Nothing to roll back to yet (faults before the first good
            # step): keep current state; the skip mask already held it.
            return params, opt_state
        p_h, s_h = restored
        params = jax.tree.map(jnp.asarray, p_h)
        opt_state = jax.tree.map(jnp.asarray, s_h)
        # Retry the replayed steps at the CURRENT (backed-off) lr.
        opt_state = self._set_lr_scale(opt_state)
        return params, opt_state

    # -- reporting --------------------------------------------------------

    def report(self) -> dict:
        return dict(self.counters, lr_scale=self.lr_scale,
                    grad_bf16=self.grad_bf16,
                    last_good_step=(self._last_good[0]
                                    if self._last_good else None))
