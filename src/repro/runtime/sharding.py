"""Axis-name-driven sharding rule engine (params / cache / batch -> PartitionSpec).

Rules are keyed on pytree *paths*, not positions, so they survive arbitrary
nesting (scanned cycle stacking, vmapped experts, optimizer-state mirrors).
A rule yields the spec for the *block-level* array; extra leading axes
(cycle stacking, expert vmap of TT cores) are absorbed by left-padding the
spec with ``None`` to the leaf's rank.

Distribution policy (DESIGN.md §3):
  * batch axes  -> ("pod", "data")           (DP across pods and within)
  * TP (model axis): attention q/k/v out-dim, o in-dim; MLP up/gate out-dim,
    down in-dim; vocab dim of embedding table and LM head (Megatron-style).
  * MoE: expert axis on "model" when divisible, else per-expert FFN dim.
  * SSM / RG-LRU: channel (d_inner / d_rnn) dim on "model" — the recurrences
    are elementwise over channels, so TP is communication-free inside them.
  * TT / TTM cores: **replicated** — the paper's technique as a distributed
    optimization: per-device param+grad+optimizer state is MBs, and the DP
    gradient all-reduce shrinks by the compression ratio (30-52x).
  * norms, biases, scalars: replicated.

The same rules shard optimizer state (it mirrors the param tree leaf-for-leaf
under ``state["m"]/state["v"]/state["mu"]``).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.transformer import CacheLeaf, map_cache

__all__ = [
    "DATA_AXES", "MODEL_AXIS",
    "param_specs", "batch_specs", "cache_specs", "opt_state_specs",
    "named_sharding_tree", "kv_repeat_for_mesh", "spec_report",
]

DATA_AXES = ("pod", "data")  # flattened into one DP spec entry
MODEL_AXIS = "model"


def _dp(mesh: Mesh):
    axes = tuple(a for a in DATA_AXES if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def _divisible(dim: int, mesh: Mesh) -> bool:
    return dim % mesh.shape[MODEL_AXIS] == 0 if MODEL_AXIS in mesh.axis_names else False


# ---------------------------------------------------------------------------
# Param rules: (path regex, base spec builder).  First match wins.  The
# builder receives (leaf shape-struct, cfg, mesh) and returns a PartitionSpec
# for the block-level trailing dims.
# ---------------------------------------------------------------------------


def _spec_linear_out(leaf, cfg, mesh):
    # dense (out, in): shard out on model
    return P(MODEL_AXIS, None) if _divisible(leaf.shape[-2], mesh) else P()


def _spec_linear_in(leaf, cfg, mesh):
    return P(None, MODEL_AXIS) if _divisible(leaf.shape[-1], mesh) else P()


# Above this per-device-bytes threshold, expert weights additionally shard
# FSDP-style over the data axis (the per-layer all-gather is cheaper than
# not fitting); below it, EP-only avoids the gather (§Perf iteration 3).
_EXPERT_FSDP_BYTES = 2 << 30


def _spec_expert_w(col: str):
    def rule(leaf, cfg, mesh):
        # dense expert stack (E, out, in)
        e = leaf.shape[-3]
        if _divisible(e, mesh):
            # EP: experts over model.  Only 400B-class stacks that cannot
            # hold E/tp experts per chip also shard the per-expert FFN dim
            # over *data* (FSDP); GSPMD inserts the per-layer all-gather
            # (visible in §Roofline).
            tp = mesh.shape[MODEL_AXIS]
            leaf_bytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
            need_fsdp = leaf_bytes // tp > _EXPERT_FSDP_BYTES
            ffn_axis = "data" if ("data" in mesh.axis_names and need_fsdp) else None
            f = mesh.shape.get("data", 1) if ffn_axis else 1
            if col in ("up", "gate") and f > 1 and leaf.shape[-2] % f == 0:
                return P(MODEL_AXIS, ffn_axis, None)
            if col == "down" and f > 1 and leaf.shape[-1] % f == 0:
                return P(MODEL_AXIS, None, ffn_axis)
            return P(MODEL_AXIS, None, None)
        if col in ("up", "gate") and _divisible(leaf.shape[-2], mesh):
            return P(None, MODEL_AXIS, None)          # per-expert FFN TP
        if col == "down" and _divisible(leaf.shape[-1], mesh):
            return P(None, None, MODEL_AXIS)
        return P()
    return rule


def _spec_vocab_table(leaf, cfg, mesh):
    return P(MODEL_AXIS, None) if _divisible(leaf.shape[-2], mesh) else P()


def _spec_vec_model(leaf, cfg, mesh):
    return P(MODEL_AXIS) if _divisible(leaf.shape[-1], mesh) else P()


def _spec_replicated(leaf, cfg, mesh):
    return P()


# NOTE: TT cores never match a "w" rule — TTLinearParams flattens its cores
# into list positions under key-path ".cores[i]" and stays replicated.
_PARAM_RULES: tuple[tuple[str, Any], ...] = (
    (r"\.cores\[", _spec_replicated),                       # TT/TTM cores
    (r"attn.*\.(q|k|v)\..*\bw$", _spec_linear_out),
    (r"attn.*\.o\..*\bw$", _spec_linear_in),
    (r"patch_proj\..*\bw$", _spec_linear_out),
    (r"mlp\.(up|gate)\..*\bw$", _spec_linear_out),
    (r"mlp\.down\..*\bw$", _spec_linear_in),
    (r"shared\.(up|gate)\..*\bw$", _spec_linear_out),
    (r"shared\.down\..*\bw$", _spec_linear_in),
    (r"moe\.up\..*\bw$", _spec_expert_w("up")),
    (r"moe\.gate\..*\bw$", _spec_expert_w("gate")),
    (r"moe\.down\..*\bw$", _spec_expert_w("down")),
    (r"moe\.router", _spec_replicated),
    (r"mixer\.(zx_proj|x_proj|gate_proj|a_gate|i_gate)\..*\bw$", _spec_linear_out),
    (r"mixer\.out_proj\..*\bw$", _spec_linear_in),
    (r"mixer\.(conv_kernel|gate_norm)$", _spec_vec_model),
    (r"mixer\.lam$", _spec_vec_model),
    (r"embed.*\btable$", _spec_vocab_table),
    (r"head\..*\bw$", _spec_vocab_table),
    (r"(intent|slot)_out\.w$", _spec_replicated),
    (r"pos_table$", _spec_replicated),
    (r".*", _spec_replicated),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts[-1:] = [parts[-1] + f"[{p.idx}]"] if parts else [f"[{p.idx}]"]
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        elif isinstance(p, jax.tree_util.FlattenedIndexKey):
            parts[-1:] = [parts[-1] + f"[{p.key}]"] if parts else [f"[{p.key}]"]
        else:
            parts.append(str(p))
    return ".".join(parts)


def _pad_spec(spec: P, rank: int) -> P:
    base = tuple(spec)
    if len(base) > rank:
        # scalar leaves matched a vector rule etc. — replicate
        return P()
    return P(*((None,) * (rank - len(base)) + base))


def param_specs(cfg: ModelConfig, params_tree: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree matching ``params_tree`` (arrays or ShapeDtypeStruct)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    specs = []
    for path, leaf in flat:
        ps = _path_str(path)
        for pat, rule in _PARAM_RULES:
            if re.search(pat, ps):
                specs.append(_pad_spec(rule(leaf, cfg, mesh), len(leaf.shape)))
                break
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_state_specs(cfg: ModelConfig, state_tree: Any, param_spec_tree: Any,
                    mesh: Mesh) -> Any:
    """Optimizer state: moment trees mirror param specs, counters replicate."""
    def per_entry(key, sub):
        if key in ("m", "v", "mu"):
            return param_spec_tree
        return jax.tree.map(lambda _: P(), sub)
    return {k: per_entry(k, v) for k, v in state_tree.items()}


# ---------------------------------------------------------------------------
# Batch / cache.
# ---------------------------------------------------------------------------


def batch_specs(batch_tree: Any, mesh: Mesh) -> Any:
    """Shard the leading (global-batch) dim over all DP axes; batch=1 decode
    (long-context) replicates instead."""
    dp = _dp(mesh)

    def one(leaf):
        b = leaf.shape[0] if leaf.shape else 0
        n_dp = int(np.prod([mesh.shape[a] for a in (dp if isinstance(dp, tuple) else (dp,))
                            if a])) if dp else 1
        if dp and b % n_dp == 0 and b > 0:
            return P(dp, *((None,) * (len(leaf.shape) - 1)))
        return P()

    return jax.tree.map(one, batch_tree)


def kv_repeat_for_mesh(cfg: ModelConfig, mesh: Mesh) -> int:
    """Repeat KV heads at cache layout so the head dim shards TP-cleanly
    (MaxText-style).  Only for decode caches; training never materializes
    repeated KV.  The repeat must divide the GQA group size (decode
    attention reshapes H = KV_repeated x G); the smallest repeat achieving
    TP divisibility wins (minimum cache memory), else no repeat."""
    if MODEL_AXIS not in mesh.axis_names:
        return 1
    tp = mesh.shape[MODEL_AXIS]
    kv = cfg.n_kv_heads
    group = max(cfg.n_heads // max(kv, 1), 1)
    for r in range(1, group + 1):
        if group % r == 0 and (kv * r) % tp == 0:
            return r
    return 1


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, seq_len: int) -> Any:
    """PartitionSpec tree for a decode cache built with the same kv_repeat."""
    dp = _dp(mesh)
    kvr = kv_repeat_for_mesh(cfg, mesh)
    n_dp = 1
    if dp:
        axes = dp if isinstance(dp, tuple) else (dp,)
        n_dp = int(np.prod([mesh.shape[a] for a in axes]))
    b_ax = dp if (dp and batch % n_dp == 0 and batch > 1) else None

    def leaf_spec(leaf: CacheLeaf, cycles):
        if leaf.role == "kv":      # (B, S, KV*kvr, dh)
            kvh = leaf.shape[2]
            h_ax = MODEL_AXIS if kvh % mesh.shape[MODEL_AXIS] == 0 else None
            spec = (b_ax, None, h_ax, None)
        elif leaf.role == "conv":  # (B, W, C)
            c_ax = MODEL_AXIS if leaf.shape[2] % mesh.shape[MODEL_AXIS] == 0 else None
            spec = (b_ax, None, c_ax)
        elif leaf.role == "state":  # (B, H, P, N) ssd state
            h_ax = MODEL_AXIS if leaf.shape[1] % mesh.shape[MODEL_AXIS] == 0 else None
            spec = (b_ax, h_ax, None, None)
        elif leaf.role == "vec":   # (B, D)
            d_ax = MODEL_AXIS if leaf.shape[1] % mesh.shape[MODEL_AXIS] == 0 else None
            spec = (b_ax, d_ax)
        else:
            spec = (None,) * len(leaf.shape)
        if cycles is not None:
            spec = (None,) + spec
        return P(*spec)

    return map_cache(leaf_spec, cfg, batch, seq_len, kv_repeat=kvr)


def named_sharding_tree(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def spec_report(cfg: ModelConfig, params_tree: Any, mesh: Mesh) -> str:
    """Human-readable param -> spec mapping (debugging / DESIGN docs)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params_tree)
    spec_flat = jax.tree.leaves(
        param_specs(cfg, params_tree, mesh),
        is_leaf=lambda x: isinstance(x, P))
    lines = []
    for (path, leaf), spec in zip(flat, spec_flat):
        lines.append(f"{_path_str(path):70s} {str(leaf.shape):28s} {spec}")
    return "\n".join(lines)
