"""Straggler detection + adaptive checkpoint cadence (host-side, pure Python).

At 1000+-node scale the slowest worker sets the step time; persistent
stragglers (thermal throttling, failing HBM, noisy neighbors) must be flagged
for replacement before they degrade the whole job.  The monitor keeps an
exponentially weighted mean/variance of per-step (or per-worker) latencies and
flags samples exceeding ``mean + z * std``; repeated flags escalate.

It also drives checkpoint cadence: when the flag rate rises (a node is
wobbling — elevated failure risk) the recommended checkpoint interval
shrinks, bounding lost work.  Tested with injected delays in
tests/test_runtime.py.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["StragglerMonitor", "CheckpointCadence"]


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.05          # EWMA weight
    z_threshold: float = 3.0     # flag at mean + z * std
    escalate_after: int = 3      # consecutive flags -> persistent straggler
    warmup: int = 8              # samples before flagging starts

    mean: float = 0.0
    var: float = 0.0
    count: int = 0
    consecutive_flags: int = 0
    total_flags: int = 0
    persistent: bool = False

    def observe(self, latency_s: float) -> bool:
        """Record one latency sample; returns True if it is a straggler event."""
        self.count += 1
        if self.count == 1:
            self.mean = latency_s
            self.var = 0.0
            return False
        delta = latency_s - self.mean
        # Variance floor (5% of the mean): a perfectly steady baseline must
        # still be able to flag a spike.
        std = max(math.sqrt(self.var), 0.05 * abs(self.mean))
        flagged = (self.count > self.warmup
                   and std > 0.0
                   and delta > self.z_threshold * std)
        if flagged:
            self.consecutive_flags += 1
            self.total_flags += 1
            if self.consecutive_flags >= self.escalate_after:
                self.persistent = True
        else:
            self.consecutive_flags = 0
            # Only fold non-outlier samples into the stats so one spike does
            # not inflate the baseline and mask the next spike.
            self.mean += self.alpha * delta
            self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        return flagged

    @property
    def flag_rate(self) -> float:
        """Fraction of *post-warmup* samples flagged.  Warmup samples can
        never flag, so counting them dilutes the rate — a long warmup would
        make an unstable node look healthy to CheckpointCadence."""
        return self.total_flags / max(self.count - self.warmup, 1)


@dataclasses.dataclass
class CheckpointCadence:
    """Adaptive interval: shrink under instability, relax when healthy."""

    base_interval: int = 1000    # steps between checkpoints when healthy
    min_interval: int = 50

    def interval(self, monitor: StragglerMonitor) -> int:
        if monitor.persistent:
            return self.min_interval
        # flag_rate 0 -> base; 10%+ -> min.
        frac = min(monitor.flag_rate / 0.1, 1.0)
        return max(self.min_interval,
                   int(self.base_interval * (1.0 - frac) + self.min_interval * frac))
