"""Distributed runtime: sharding rules, fault tolerance, elastic scaling —
plus the decode-serving runtime (paged KV cache, continuous-batching
scheduler, paged decode engine) and the training guard / chaos-injection
pair (numerics sentry with skip/backoff/rollback escalation; deterministic
fault harness that proves it)."""
from .chaos import (
    ChaosPlan,
    GradFault,
    LogitPoison,
    StragglerFault,
    async_writer_crash,
    corrupt_checkpoint,
)
from .compress import (
    compressed_allreduce_mean,
    dequantize_int8,
    ef_compress_tree,
    ef_init,
    quantize_int8,
)
from .decode_engine import (
    PagedDecodeEngine,
    finite_logit_rows,
    paged_supported,
)
from .elastic import replan_for_mesh, reshard_tree, validate_divisibility
from .guard import (
    GuardPolicy,
    TrainGuard,
    apply_guarded_update,
    guard_controls,
    make_guarded_step,
)
from .pipeline import (
    PIPELINE_AXES,
    StagePartition,
    bubble_fraction,
    cycles_per_stage,
    make_pipeline_mesh,
    pipeline_loss_and_grads,
    stage_utilization,
)
from .kv_cache import (
    PagedKVCache,
    kv_pool_bytes,
    max_pages_per_request,
    pages_for,
)
from .scheduler import Request, Scheduler
from .sharding import (
    batch_specs,
    cache_specs,
    kv_repeat_for_mesh,
    named_sharding_tree,
    opt_state_specs,
    param_specs,
    spec_report,
)
from .straggler import CheckpointCadence, StragglerMonitor

__all__ = [
    "param_specs", "batch_specs", "cache_specs", "opt_state_specs",
    "named_sharding_tree", "kv_repeat_for_mesh", "spec_report",
    "StragglerMonitor", "CheckpointCadence",
    "PIPELINE_AXES", "StagePartition", "bubble_fraction", "cycles_per_stage",
    "make_pipeline_mesh", "pipeline_loss_and_grads", "stage_utilization",
    "reshard_tree", "replan_for_mesh", "validate_divisibility",
    "quantize_int8", "dequantize_int8", "compressed_allreduce_mean",
    "ef_compress_tree", "ef_init",
    "PagedKVCache", "pages_for", "max_pages_per_request", "kv_pool_bytes",
    "Request", "Scheduler",
    "PagedDecodeEngine", "paged_supported", "finite_logit_rows",
    "GuardPolicy", "TrainGuard", "guard_controls", "apply_guarded_update",
    "make_guarded_step",
    "ChaosPlan", "GradFault", "StragglerFault", "LogitPoison",
    "corrupt_checkpoint", "async_writer_crash",
]
