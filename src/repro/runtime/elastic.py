"""Elastic re-meshing: move a training state onto a different device mesh.

Scenarios at scale: a pod is preempted (shrink DP width), capacity is added
(grow), or a failed host forces a restart on n-1 nodes.  Because (a) model
state lives in a host-visible checkpoint, (b) the data pipeline is a pure
function of (seed, step), and (c) sharding rules are *functions of the mesh*,
elastic restart is: build the new mesh -> re-derive specs -> device_put.

``reshard_tree`` works for live arrays too (mesh-to-mesh moves without a
checkpoint round-trip) — jax.device_put handles cross-sharding transfers.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.runtime.sharding import named_sharding_tree, opt_state_specs, param_specs

__all__ = ["reshard_tree", "replan_for_mesh", "validate_divisibility"]


def reshard_tree(tree: Any, mesh: Mesh, spec_tree: Any) -> Any:
    """device_put every leaf to its NamedSharding on the (new) mesh."""
    sh = named_sharding_tree(mesh, spec_tree)
    return jax.tree.map(jax.device_put, tree, sh)


def replan_for_mesh(cfg: ModelConfig, params: Any, opt_state: Any | None,
                    new_mesh: Mesh) -> tuple[Any, Any | None]:
    """Re-derive specs for ``new_mesh`` and move (params, opt_state) onto it."""
    pspecs = param_specs(cfg, params, new_mesh)
    params = reshard_tree(params, new_mesh, pspecs)
    if opt_state is not None:
        sspecs = opt_state_specs(cfg, opt_state, pspecs, new_mesh)
        opt_state = reshard_tree(opt_state, new_mesh, sspecs)
    return params, opt_state


def validate_divisibility(cfg: ModelConfig, mesh: Mesh,
                          global_batch: int) -> list[str]:
    """Pre-flight checks before adopting a new mesh; returns problem list."""
    problems = []
    import numpy as np
    dp = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                      if a in mesh.axis_names]))
    if global_batch % dp and global_batch > 1:
        problems.append(
            f"global_batch {global_batch} not divisible by DP degree {dp}")
    if "model" in mesh.axis_names:
        tp = mesh.shape["model"]
        if (cfg.n_heads * cfg.d_head) % tp:
            problems.append(f"attention out dim not divisible by TP {tp}")
        if cfg.d_ff and cfg.d_ff % tp:
            problems.append(f"d_ff {cfg.d_ff} not divisible by TP {tp} "
                            "(falls back to replicated FFN)")
    return problems
