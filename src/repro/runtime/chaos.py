"""Deterministic fault-injection harness (chaos testing for the repo's
fault-tolerance story).

Every fault is a pure function of ``(seed, step)`` — running the same plan
twice produces byte-identical corruption, so each recovery path in
``runtime.guard`` / ``checkpoint`` / ``launch.serve`` has a reproducible
test instead of a flaky one.  Five fault families:

  * **Gradient faults** (:class:`GradFault` + :class:`ChaosPlan`) — NaN,
    Inf, or a finite 1e28-scale spike added to one gradient element at
    step ``k`` for ``length`` steps, delivered through the guarded step's
    ``ctrl["fault_add"]`` scalar (``runtime.guard.guard_controls``), so
    injection costs nothing when off and nothing is recompiled when on.
  * **Checkpoint corruption** (:func:`corrupt_checkpoint`) — flip a byte,
    truncate at a random offset, delete a leaf file, or mangle
    ``meta.json`` in a written step dir; the offset/leaf choice is drawn
    from ``np.random.default_rng(seed)``.
  * **Async-writer kill** (:func:`async_writer_crash`) — patch the
    checkpoint writer so the background thread dies mid-save, exercising
    the manager's exception re-raise and the atomicity guarantee.
  * **Decode-logit poisoning** (:class:`LogitPoison`) — NaN a slot's
    logits row at a chosen decode step; the serve loop must evict that
    request, not crash the batch.
  * **Straggler delay** (:class:`StragglerFault`) — per-step synthetic
    latency for the EWMA monitors.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import os
from typing import Iterable

import numpy as np

__all__ = ["GradFault", "StragglerFault", "ChaosPlan", "LogitPoison",
           "corrupt_checkpoint", "async_writer_crash", "WriterCrash"]


# ---------------------------------------------------------------------------
# Gradient faults (delivered via guard_controls(fault_add=...)).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GradFault:
    """Additive gradient fault active on steps [step, step+length)."""

    step: int
    length: int = 1
    mode: str = "nan"          # "nan" | "inf" | "spike"
    magnitude: float = 1e28    # spike amplitude (finite-overflow shape)

    def __post_init__(self):
        if self.mode not in ("nan", "inf", "spike"):
            raise ValueError(f"unknown GradFault mode {self.mode!r}")

    @property
    def value(self) -> float:
        if self.mode == "nan":
            return math.nan
        if self.mode == "inf":
            return math.inf
        return self.magnitude


@dataclasses.dataclass(frozen=True)
class StragglerFault:
    """Synthetic per-step latency (seconds) on steps [step, step+length)."""

    step: int
    length: int = 1
    seconds: float = 1.0


class ChaosPlan:
    """A fault schedule for one training run: ``fault_add(step)`` feeds
    ``TrainGuard.controls(fault_add=...)``; ``delay_s(step)`` adds to the
    observed step latency.  Purely host-side and stateless per query."""

    def __init__(self, grad_faults: Iterable[GradFault] = (),
                 straggler_faults: Iterable[StragglerFault] = ()):
        self.grad_faults = tuple(grad_faults)
        self.straggler_faults = tuple(straggler_faults)

    def fault_add(self, step: int) -> float:
        for f in self.grad_faults:
            if f.step <= step < f.step + f.length:
                return f.value
        return 0.0

    def delay_s(self, step: int) -> float:
        return sum(f.seconds for f in self.straggler_faults
                   if f.step <= step < f.step + f.length)


# ---------------------------------------------------------------------------
# Checkpoint corruption (power loss / bit rot on the written files).
# ---------------------------------------------------------------------------


def _leaf_files(step_dir: str) -> list[str]:
    return sorted(f for f in os.listdir(step_dir)
                  if f.startswith("leaf_") and f.endswith(".npy"))


def corrupt_checkpoint(root: str, step: int | None = None, *,
                       leaf: int | None = None, mode: str = "flip",
                       seed: int = 0) -> dict:
    """Deterministically corrupt one written checkpoint step.

    ``mode``: ``"flip"`` xors one byte at a seeded offset, ``"truncate"``
    cuts the file at a seeded offset (power loss mid-write), ``"delete"``
    removes the leaf file, ``"meta"`` truncates ``meta.json`` mid-token.
    ``step`` defaults to the manifest's latest; ``leaf`` to a seeded
    choice.  Returns what was done (step/path/mode/offset) so tests can
    assert determinism: same (root layout, seed) -> same report.
    """
    from repro.checkpoint.checkpoint import _step_dir, latest_step

    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = _step_dir(root, step)
    rng = np.random.default_rng(seed)
    if mode == "meta":
        path = os.path.join(d, "meta.json")
        with open(path) as f:
            text = f.read()
        cut = int(rng.integers(1, max(len(text), 2)))
        with open(path, "w") as f:
            f.write(text[:cut])
        return {"step": step, "path": path, "mode": mode, "offset": cut}
    files = _leaf_files(d)
    if not files:
        raise FileNotFoundError(f"no leaf files in {d}")
    if leaf is None:
        leaf = int(rng.integers(0, len(files)))
    path = os.path.join(d, files[leaf])
    if mode == "delete":
        os.remove(path)
        return {"step": step, "path": path, "mode": mode, "offset": None}
    size = os.path.getsize(path)
    offset = int(rng.integers(0, max(size - 1, 1)))
    if mode == "truncate":
        with open(path, "r+b") as f:
            f.truncate(offset)
    elif mode == "flip":
        with open(path, "r+b") as f:
            f.seek(offset)
            b = f.read(1)
            f.seek(offset)
            f.write(bytes([b[0] ^ 0xFF]))
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    return {"step": step, "path": path, "mode": mode, "offset": offset}


class WriterCrash(RuntimeError):
    """The injected async-checkpoint-writer failure."""


@contextlib.contextmanager
def async_writer_crash(after_leaves: int | None = 0):
    """Kill the checkpoint writer as if the process died mid-save.

    Patches ``checkpoint.checkpoint._write_step`` (``save`` resolves the
    module global at call time, so in-flight threads started inside the
    context hit the patch) to write ``after_leaves`` real leaf files into
    the temp dir and then raise :class:`WriterCrash`.  The step directory
    must never appear (atomicity) and ``CheckpointManager.wait()`` must
    re-raise the failure.  ``after_leaves=None`` crashes before writing
    anything.
    """
    from repro.checkpoint import checkpoint as ckpt_mod

    real = ckpt_mod._write_step

    def dying_write_step(root, step, leaves, paths, keep):
        import tempfile
        os.makedirs(root, exist_ok=True)
        tmp = tempfile.mkdtemp(dir=root, prefix=".tmp_save_")
        try:
            n = 0 if after_leaves is None else after_leaves
            for i, a in enumerate(leaves[:n]):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), a)
            raise WriterCrash(
                f"injected writer crash at step {step} "
                f"(wrote {min(n, len(leaves))}/{len(leaves)} leaves)")
        finally:
            # mirror the real writer's cleanup-on-failure
            import shutil
            shutil.rmtree(tmp, ignore_errors=True)

    ckpt_mod._write_step = dying_write_step
    try:
        yield
    finally:
        ckpt_mod._write_step = real


# ---------------------------------------------------------------------------
# Decode-logit poisoning (serving).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LogitPoison:
    """NaN the logits of ``slots`` at decode step ``at_step`` (0-based
    count of batched decode steps).  ``launch.serve.serve_paged`` accepts
    any object with this ``poison_logits`` signature as its ``chaos``
    hook."""

    at_step: int
    slots: tuple[int, ...] = (0,)
    value: float = math.nan

    def poison_logits(self, logits: np.ndarray,
                      decode_step: int) -> np.ndarray:
        if decode_step != self.at_step:
            return logits
        logits = np.array(logits, copy=True)
        for s in self.slots:
            if 0 <= s < logits.shape[0]:
                logits[s, 0] = self.value
        return logits
