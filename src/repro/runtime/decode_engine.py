"""Paged decode engine: the serving mirror of ``models.transformer.forward``.

``forward(mode="decode")`` carries a dense per-request ``(B, Smax, KV, dh)``
cache with one shared scalar position — fine for lockstep batch decode,
wrong for continuous batching, where every slot sits at a different
position and requests come and go mid-flight.  This engine runs the same
block walk (cycle-major scan over the stacked layers, then the unrolled
tail) against the PAGED cache of ``runtime.kv_cache``:

  * attention is one :func:`repro.kernels.ops.flash_decode_op` launch per
    layer — page-table-indirect, GQA-grouped, online-softmax in VMEM;
  * q/k/v/o projections and the FFN run the decode-shape kernel
    specializations (``btt_linear_decode_op`` / ``btt_ffn_decode_op``:
    sublane-granule row tiles, half-factors pinned) when ``fused_decode``
    and the shape fits VMEM, else the standard apply path;
  * per-slot positions are a ``(n_slots,)`` vector — rope, learned and
    sinusoidal position embeddings all take the slot's own position.

The decode step's batch shape is ALWAYS ``(max_concurrency,)``: free slots
ride along as masked lanes (token 0, length 0, KV writes routed to the
trash page), so the jitted step compiles once, and — because every lane's
math is row-independent at fixed shapes — a request decodes bit-identically
whether it shares the batch or runs alone (the token-identity property
``tests/test_scheduler.py`` asserts).

One engine instance serves ONE config + param set; the scheduler decides
which request occupies which slot.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.tt_linear import TTLinearParams
from repro.models.layers import embedding_apply, linear_apply, rms_norm, rope
from repro.models.transformer import forward
from repro.runtime.kv_cache import PagedKVCache

__all__ = ["PagedDecodeEngine", "paged_supported", "finite_logit_rows"]

ATTN_KINDS = ("attn", "attn_moe", "attn_local")


def finite_logit_rows(logits) -> np.ndarray:
    """(B, Vp) logits -> (B,) bool mask of rows that are entirely finite.

    The NaN-logit guard for the serve loop: a poisoned request (numerics
    fault, corrupted KV page) must be EVICTED from its slot, not allowed
    to crash the whole batch in the sampler or propagate NaN tokens.  One
    host reduction over the already-fetched logits — the decode step's
    output is on host anyway for sampling, so this costs no extra sync.
    """
    arr = np.asarray(logits)
    return np.isfinite(arr).all(axis=tuple(range(1, arr.ndim)))


def paged_supported(cfg: ModelConfig) -> bool:
    """True iff every block kind has a KV cache this engine can page
    (ssm/rec state is O(1) per stream — nothing to page; those families
    stay on the dense-cache serve path)."""
    return all(k in ATTN_KINDS for k in cfg.hybrid_pattern)


def _layout(cfg: ModelConfig):
    """Static walk layout: per-block (kind, gid, offset) for the pattern
    and the tail, plus per-group pat counts and window values."""
    pat = cfg.hybrid_pattern
    n_cycles = cfg.num_layers // len(pat)
    tail = pat[: cfg.num_layers - n_cycles * len(pat)]
    windows: dict[str, int | None] = {}
    counts: dict[str, int] = {}

    def classify(kinds):
        info = []
        for kind in kinds:
            gid = "local" if kind == "attn_local" else "global"
            windows[gid] = cfg.window if kind == "attn_local" else None
            info.append((kind, gid, counts.get(gid, 0)))
            counts[gid] = counts.get(gid, 0) + 1
        return tuple(info)

    pat_info = classify(pat)
    n_pat = dict(counts)
    counts = {g: 0 for g in counts}
    tail_info = classify(tail)
    n_tail = dict(counts)
    return n_cycles, pat_info, tail_info, n_pat, n_tail, windows


class PagedDecodeEngine:
    def __init__(self, cfg: ModelConfig, params, *, page_size: int,
                 max_concurrency: int, max_len: int,
                 fused_decode: bool = True, interpret: bool | None = None):
        if not paged_supported(cfg):
            raise ValueError(
                f"paged decode needs attention-family blocks only, got "
                f"{cfg.hybrid_pattern}")
        self.cfg = cfg
        self.params = params
        self.fused = fused_decode
        self.interpret = interpret
        self.n_slots = max_concurrency
        self.max_len = max_len
        self.page_size = page_size
        (self.n_cycles, self.pat_info, self.tail_info, self.n_pat,
         self.n_tail, self.windows) = _layout(cfg)
        dtype = jnp.dtype(cfg.dtype)
        self.caches: dict[str, PagedKVCache] = {}
        for gid, window in self.windows.items():
            n_layers = (self.n_cycles * self.n_pat.get(gid, 0)
                        + self.n_tail.get(gid, 0))
            self.caches[gid] = PagedKVCache(
                n_layers, cfg.n_kv_heads, cfg.d_head, page_size=page_size,
                max_len=max_len, max_concurrency=max_concurrency,
                window=window, dtype=dtype)
        self._prefill_jit = jax.jit(partial(forward, mode="prefill",
                                            remat=False),
                                    static_argnames=("cfg", "mode", "remat"))
        self._step_jit = jax.jit(self._decode_forward)

    # -- projections (decode-shape kernel dispatch) ----------------------

    def _lin(self, p, x: jax.Array) -> jax.Array:
        """Decode-shape linear: ``btt_linear_decode_op`` for TT projections
        under the kernel flow (sublane row tiles, forward-only), mirroring
        ``tt_linear_apply``'s pad/slice/bias exactly; everything else runs
        the standard apply."""
        cfg = self.cfg
        if (self.fused and cfg.tt.flow == "kernel"
                and isinstance(p, TTLinearParams)):
            from repro.kernels.ops import btt_linear_decode_op

            lead = x.shape[:-1]
            xk = x.reshape(-1, x.shape[-1])
            if p.in_dim != p.spec.in_dim:
                xk = jnp.pad(xk, ((0, 0), (0, p.spec.in_dim - p.in_dim)))
            y = btt_linear_decode_op(p.cores, xk, p.spec,
                                     interpret=self.interpret)
            y = y[:, : p.out_dim].reshape(lead + (p.out_dim,))
            if p.bias is not None:
                y = y + p.bias
            return y
        return linear_apply(p, x, flow=cfg.tt.flow,
                            fused_bwd=cfg.tt.fused_bwd)

    def _mlp(self, p: dict, x: jax.Array) -> jax.Array:
        """Decode-shape FFN: the megakernel at sublane row tiles when every
        projection is TT (``btt_ffn_decode_op`` gates on VMEM internally),
        else the unfused decode-linear walk — same math as
        ``layers.mlp_apply``."""
        cfg = self.cfg
        gate = p.get("gate") if cfg.mlp_gated else None
        mods = (p["up"], p["down"]) if gate is None else (p["up"], p["down"],
                                                         gate)
        if (self.fused and cfg.fused_ffn and cfg.tt.flow == "kernel"
                and all(isinstance(m, TTLinearParams) and m.bias is None
                        for m in mods)):
            from repro.kernels.ops import btt_ffn_decode_op

            up, down = p["up"], p["down"]
            lead = x.shape[:-1]
            xk = x.reshape(-1, x.shape[-1])
            if up.in_dim != up.spec.in_dim:
                xk = jnp.pad(xk, ((0, 0), (0, up.spec.in_dim - up.in_dim)))
            y = btt_ffn_decode_op(
                up.cores, down.cores,
                gate.cores if gate is not None else None, xk,
                up.spec, down.spec,
                gate.spec if gate is not None else None, act=cfg.act,
                f_logical=min(up.out_dim, down.in_dim),
                interpret=self.interpret)
            return y[:, : down.out_dim].reshape(lead + (down.out_dim,))
        up_h = self._lin(p["up"], x)
        if gate is not None:
            g = self._lin(gate, x)
            act = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
            h = act * up_h
        else:
            h = jax.nn.gelu(up_h) if cfg.act == "gelu" \
                else jax.nn.silu(up_h)
        return self._lin(p["down"], h)

    # -- the jitted decode step ------------------------------------------

    def _attn_block(self, p: dict, x: jax.Array, positions: jax.Array,
                    pools: dict, views: dict, writes: dict, gid: str,
                    li) -> tuple[jax.Array, dict]:
        """One attention sub-block at decode: project, write this step's KV
        column into the paged pool, flash-decode against it."""
        from repro.kernels.ops import flash_decode_op

        cfg = self.cfg
        B = x.shape[0]
        H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        q = self._lin(p["q"], x).reshape(B, 1, H, dh)
        k = self._lin(p["k"], x).reshape(B, 1, KV, dh)
        v = self._lin(p["v"], x).reshape(B, 1, KV, dh)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        if cfg.pos_embed == "rope":
            q = rope(q, positions[:, None], cfg.rope_theta)
            k = rope(k, positions[:, None], cfg.rope_theta)

        k_pool, v_pool = pools[gid]
        pids, rows = writes[gid]
        # Scatter this step's KV column to each slot's (page, row) target;
        # free slots write the trash page (see kv_cache.TRASH_PAGE).
        k_pool = k_pool.at[li, pids, :, rows].set(
            k[:, 0].astype(k_pool.dtype))
        v_pool = v_pool.at[li, pids, :, rows].set(
            v[:, 0].astype(v_pool.dtype))
        table, lengths, pos0 = views[gid]
        k_layer = jax.lax.dynamic_index_in_dim(k_pool, li, 0,
                                               keepdims=False)
        v_layer = jax.lax.dynamic_index_in_dim(v_pool, li, 0,
                                               keepdims=False)
        out = flash_decode_op(q[:, 0], k_layer, v_layer, table, lengths,
                              pos0, window=self.windows[gid],
                              use_kernel=self.fused,
                              interpret=self.interpret)
        out = out.reshape(B, 1, H * dh)
        pools = dict(pools)
        pools[gid] = (k_pool, v_pool)
        return self._lin(p["o"], out), pools

    def _block(self, kind: str, gid: str, blk: dict, h: jax.Array,
               positions, pools, views, writes, li):
        cfg = self.cfg
        hn = rms_norm(h, blk["norm1"], cfg.norm_eps)
        out, pools = self._attn_block(blk["attn"], hn, positions, pools,
                                      views, writes, gid, li)
        h = h + out
        h2 = rms_norm(h, blk["norm2"], cfg.norm_eps)
        if kind == "attn_moe":
            from repro.models.moe import moe_apply

            h = h + moe_apply(blk["moe"], h2, cfg)
        else:
            h = h + self._mlp(blk["mlp"], h2)
        return h, pools

    def _embed(self, params, tokens: jax.Array,
               positions: jax.Array) -> jax.Array:
        cfg = self.cfg
        h = embedding_apply(params["embed"], tokens)  # (B, 1, D)
        if cfg.pos_embed == "learned":
            h = h + jnp.take(params["pos_table"], positions,
                             axis=0)[:, None]
        elif cfg.pos_embed == "sinusoidal":
            d = cfg.d_model
            pos = positions[:, None].astype(jnp.float32)  # (B, 1)
            div = jnp.exp(jnp.arange(0, d, 2, jnp.float32)
                          * (-jnp.log(10000.0) / d))
            pe = jnp.zeros((tokens.shape[0], d), jnp.float32)
            pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
            pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
            h = h + pe.astype(h.dtype)[:, None]
        return h

    def _head(self, params, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        if cfg.tie_embeddings:
            if isinstance(params["embed"], dict):
                table = params["embed"]["table"]
            else:
                from repro.core.tt import ttm_reconstruct

                emb = params["embed"]
                table = ttm_reconstruct(emb.cores, emb.spec)[
                    : cfg.vocab_padded, : cfg.d_model].astype(h.dtype)
            return jnp.einsum("bsd,vd->bsv", h, table,
                              preferred_element_type=jnp.float32
                              ).astype(h.dtype)
        return self._lin(params["head"], h)

    def _decode_forward(self, params, pools, views, writes, tokens,
                        positions):
        """One batched decode step: ``tokens (B, 1)``, ``positions (B,)``
        -> (logits (B, Vp), new pools).  B is always ``n_slots``."""
        h = self._embed(params, tokens, positions)

        if self.n_cycles > 0:
            def cycle(carry, layer_params):
                hh, pools_c, idx = carry
                for i, (kind, gid, off) in enumerate(self.pat_info):
                    li = idx * self.n_pat[gid] + off
                    hh, pools_c = self._block(kind, gid, layer_params[i],
                                              hh, positions, pools_c,
                                              views, writes, li)
                return (hh, pools_c, idx + 1), None

            (h, pools, _), _ = jax.lax.scan(
                cycle, (h, pools, jnp.asarray(0, jnp.int32)),
                params["layers"])

        for i, (kind, gid, off) in enumerate(self.tail_info):
            li = self.n_cycles * self.n_pat.get(gid, 0) + off
            h, pools = self._block(kind, gid, params["tail"][i], h,
                                   positions, pools, views, writes, li)

        logits = self._head(params, h)
        return logits[:, 0], pools

    # -- host-side protocol ----------------------------------------------

    def can_admit(self, prompt_len: int) -> bool:
        """Enough free pages in EVERY group for this prompt's prefill."""
        return all(c.can_admit(min(prompt_len, self.max_len))
                   for c in self.caches.values())

    def prefill(self, slot: int, prompt) -> jax.Array:
        """Prefill one request solo; page its KV; return last-position
        logits ``(Vp,)``."""
        tokens = jnp.asarray(prompt, jnp.int32)[None, :]
        logits, cache = self._prefill_jit(self.params, self.cfg, tokens)
        for gid, pc in self.caches.items():
            k_rows, v_rows = self._group_rows(cache, gid)
            pc.write_prefill(slot, k_rows, v_rows)
        return logits[0, -1]

    def _group_rows(self, cache, gid: str):
        """Extract one group's per-layer contiguous KV from a prefill
        cache, in the engine's walk order (cycle-major, tail last)."""
        ks, vs = [], []
        pat_idx = [i for i, (_, g, _) in enumerate(self.pat_info)
                   if g == gid]
        if self.n_cycles > 0 and pat_idx:
            # each leaf (n_cycles, 1, S, KV, dh) -> (n_cycles, n_in_pat, ...)
            kc = jnp.stack([cache["layers"][i]["k"] for i in pat_idx],
                           axis=1)
            vc = jnp.stack([cache["layers"][i]["v"] for i in pat_idx],
                           axis=1)
            L = self.n_cycles * len(pat_idx)
            ks.append(kc.reshape((L,) + kc.shape[3:]))
            vs.append(vc.reshape((L,) + vc.shape[3:]))
        for i, (_, g, _) in enumerate(self.tail_info):
            if g == gid:
                ks.append(cache["tail"][i]["k"])
                vs.append(cache["tail"][i]["v"])
        return jnp.concatenate(ks, axis=0), jnp.concatenate(vs, axis=0)

    def decode_step(self, tokens, positions) -> jax.Array:
        """One continuous-batched decode step.  ``tokens``/``positions``
        are ``(n_slots,)`` int (free slots: 0).  Returns logits
        ``(n_slots, Vp)``."""
        writes, views = {}, {}
        for gid, c in self.caches.items():
            writes[gid] = c.write_targets(self.n_slots)
            views[gid] = c.device_view(self.n_slots)
        pools = {gid: (c.k_pool, c.v_pool)
                 for gid, c in self.caches.items()}
        tokens = jnp.asarray(tokens, jnp.int32)[:, None]
        positions = jnp.asarray(positions, jnp.int32)
        logits, new_pools = self._step_jit(self.params, pools, views,
                                           writes, tokens, positions)
        for gid, (kp, vp) in new_pools.items():
            self.caches[gid].k_pool = kp
            self.caches[gid].v_pool = vp
        return logits

    def release(self, slot: int) -> None:
        for c in self.caches.values():
            c.free_slot(slot)
