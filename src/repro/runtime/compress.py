"""int8 gradient compression with error feedback for the DP all-reduce.

The paper's TT compression already shrinks the DP gradient all-reduce by the
model compression ratio (30-52x) — this module stacks a further ~4x on the
*wire format*: a manual ring all-reduce (shard_map + ppermute) whose chunks
travel as int8 (value) + f32 (per-chunk scale), with f32 local accumulation
and error-feedback residuals so quantization noise does not bias SGD.

Why a manual ring: ``jax.lax.psum`` fixes the wire dtype to the operand
dtype, and int8 psum would overflow.  The ring moves int8 on the wire and
accumulates in f32 locally — the standard deep-gradient-compression layout,
expressed with jax-native collectives (ppermute), not emulated NCCL.

``compressed_allreduce_mean(x, axis)`` is a drop-in for
``lax.pmean(x, axis)`` inside shard_map.  Error feedback state is carried by
the caller (one residual tree, same shapes as grads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import axis_size

__all__ = [
    "quantize_int8", "dequantize_int8",
    "compressed_allreduce_mean", "ef_compress_tree", "ef_init",
]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_allreduce_mean(x: jax.Array, axis_name: str) -> jax.Array:
    """Ring all-reduce with int8 wire format.  Call inside shard_map.

    Each device quantizes its own contribution ONCE; every one of the n-1
    ring steps forwards the received ``(q int8, scale)`` chunk VERBATIM one
    hop and accumulates its dequantization locally in f32.  A contribution
    crossing k hops is therefore quantized exactly once, so the per-element
    error of the mean is bounded by ``max_j scale_j / 2`` *independent of
    ring size n* (asserted in tests/test_pipeline.py).  Re-quantizing the
    dequantized receive at each hop — the previous scheme — compounds error
    with n, and the EF residuals (``ef_compress_tree``) only ever see the
    first quantization, so the compounding would go uncompensated.
    Bytes on wire per element per step: 1 (plus one f32 scale per tensor).
    """
    n = axis_size(axis_name)
    if n == 1:
        return x
    perm = [(i, (i + 1) % n) for i in range(n)]
    q0, s0 = quantize_int8(x)

    def body(i, carry):
        acc, q, s = carry
        q = jax.lax.ppermute(q, axis_name, perm)
        s = jax.lax.ppermute(s, axis_name, perm)
        return acc + dequantize_int8(q, s), q, s

    # The local contribution enters acc unquantized (it never crosses the
    # wire); only remote chunks pay the one int8 round trip.
    acc, _, _ = jax.lax.fori_loop(0, n - 1, body,
                                  (x.astype(jnp.float32), q0, s0))
    return (acc / n).astype(x.dtype)


def ef_init(grads) -> dict:
    """Zero error-feedback residuals, one per gradient leaf."""
    return jax.tree.map(lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads)


def ef_compress_tree(grads, residuals):
    """Error-feedback quantization of a gradient tree.

    Returns (quantized_dequantized_grads, new_residuals): the compensated
    gradient ``g + r`` is quantized; the quantization error becomes the next
    residual, so the *accumulated* update is unbiased (EF-SGD).
    """
    def one(g, r):
        comp = g.astype(jnp.float32) + r
        q, s = quantize_int8(comp)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), comp - deq

    # map twice rather than unzip: structural tuples in real grad trees
    # (e.g. empty tail tuples) would defeat an is_leaf tuple test, and XLA
    # CSEs the duplicated quantize ops anyway.
    new_g = jax.tree.map(lambda g, r: one(g, r)[0], grads, residuals)
    new_r = jax.tree.map(lambda g, r: one(g, r)[1], grads, residuals)
    return new_g, new_r
